"""Parallelism: logical sharding rules, pipeline parallelism."""
