"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Model code annotates tensors with *logical* axis names; the active rule set
maps them to mesh axes.  Changing distribution strategy (FSDP on/off, TP
degree, sequence parallelism, expert placement) is a rules edit, not a model
edit — which is what makes the §Perf hillclimbs cheap to express.

Mesh axes: ("pod", "data", "model") multi-pod, ("data", "model") single pod.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass, field, replace

import jax
from jax.sharding import PartitionSpec as P

from repro.jax_compat import get_abstract_mesh

MESH_AXES = ("pod", "data", "model")

# logical axis -> mesh axis (or tuple, or None)
DEFAULT_RULES: dict[str, object] = {
    "batch": ("pod", "data"),
    "seq": None,            # "model" under sequence parallelism
    "embed": "data",        # FSDP: weight d_model dim sharded over data
    "embed_act": None,      # activation d_model dim (None; "model" under SP)
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "expert_mlp": None,     # d_ff inside experts when experts aren't sharded
    "vocab": "model",
    "state": None,          # SSM / RG-LRU recurrent state dim
    "stage": None,          # layer-stack dim under scan
    "kv_batch": ("pod", "data"),  # KV-cache batch dim
    "kv_seq": None,
}


@dataclass(frozen=True)
class ShardingRules:
    rules: dict = field(default_factory=lambda: dict(DEFAULT_RULES))

    def spec(self, *logical: str | None) -> P:
        return P(*(self.rules.get(ax) if ax is not None else None
                   for ax in logical))

    def with_overrides(self, **kw) -> "ShardingRules":
        new = dict(self.rules)
        new.update(kw)
        return ShardingRules(new)


_state = threading.local()


def current_rules() -> ShardingRules:
    return getattr(_state, "rules", None) or ShardingRules()


@contextmanager
def use_rules(rules: ShardingRules):
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield rules
    finally:
        _state.rules = prev


def logical(*axes: str | None) -> P:
    """PartitionSpec for logical axes under the active rules, pruned to the
    axes that exist in the current mesh (so single-pod meshes accept
    ('pod','data') batch rules transparently)."""
    spec = current_rules().spec(*axes)
    mesh = _current_mesh()
    if mesh is None:
        return spec
    names = set(mesh.axis_names)

    def prune(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in names)
            return kept if kept else None
        return entry if entry in names else None

    return P(*(prune(e) for e in spec))


def _current_mesh():
    # version-guarded: jax.sharding.get_abstract_mesh on new JAX, the
    # thread-local physical mesh (``with Mesh(...):``) on 0.4.x
    return get_abstract_mesh()


def shard(x, *axes: str | None):
    """with_sharding_constraint under the active logical rules (no-op when
    tracing without a mesh).  Axes whose mesh-shard product does not divide
    the tensor dim are pruned — e.g. a 51865-entry vocab stays unsharded on a
    16-way model axis, and batch=1 long-context decode replicates batch."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    spec = prune_spec_for_shape(logical(*axes), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, spec)


def _axis_size(mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def prune_spec_for_shape(spec: P, shape, mesh) -> P:
    """Drop spec entries that do not evenly divide the corresponding dim, and
    de-duplicate mesh axes (first positional use wins — e.g. under sequence
    parallelism `seq` and `heads` both map to 'model'; the earlier dim keeps
    the sharding)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    used: set = set()
    out = []
    for dim, entry in zip(shape, entries):
        names = (entry if isinstance(entry, (tuple, list))
                 else [entry]) if entry is not None else []
        if any(a in used for a in names) or dim % _axis_size(mesh, entry) != 0:
            out.append(None)
            continue
        used.update(names)
        out.append(entry)
    return P(*out)


def prune_tree_specs(spec_tree, abstract_tree, mesh):
    """prune_spec_for_shape over matching pytrees (params/opt-state/caches)."""
    return jax.tree.map(
        lambda s, a: prune_spec_for_shape(s, a.shape, mesh),
        spec_tree, abstract_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def param_specs(tree_axes):
    """Map a pytree of logical-axis tuples to PartitionSpecs."""
    return jax.tree.map(
        lambda axes: logical(*axes),
        tree_axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x),
    )
