"""Pipeline parallelism: GPipe-style microbatch schedule over a 'stage' mesh
axis with collective_permute handoffs.

shard_map over the stage axis: each device owns one pipeline stage's layer
block; microbatches stream through with a rotating buffer.  The schedule runs
S + M - 1 ticks (S stages, M microbatches); each tick every stage processes
the microbatch it holds and `ppermute`s activations to its successor, so the
steady state keeps all stages busy — the standard bubble fraction
(S-1)/(S+M-1) shrinks with M.

This is the feature path for depth-dominant models at >16-way sharding; the
production dry-run mesh keeps (pod, data, model) per the assignment, and PP
is exercised by tests/test_pipeline.py on a host-device mesh and selectable
via Layout in the autotuner.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.jax_compat import pcast, shard_map


def pipeline_forward(stage_fn, params_per_stage, x, *, mesh, n_microbatches,
                     stage_axis: str = "stage"):
    """Run x (B, ...) through `n_stages` stage_fns pipelined over microbatches.

    params_per_stage: pytree with leading stage axis, sharded over
    `stage_axis`.  x is split into n_microbatches along batch.
    """
    n_stages = mesh.shape[stage_axis]
    m = n_microbatches
    assert x.shape[0] % m == 0

    def per_stage(params, xs):
        # params: this stage's params (leading axis 1); xs: (M, mb, ...)
        params = jax.tree.map(lambda t: t[0], params)
        stage_id = jax.lax.axis_index(stage_axis)
        mb = xs.shape[1]
        # mark carries as stage-varying (shard_map vma typing): the loop body
        # writes stage-dependent values into them (identity pre-vma JAX)
        buf = pcast(jnp.zeros((mb,) + xs.shape[2:], xs.dtype),
                    (stage_axis,), to="varying")
        outs = pcast(jnp.zeros_like(xs), (stage_axis,), to="varying")

        def tick(t, carry):
            buf, outs = carry
            # stage 0 injects microbatch t (if in range); others use buf
            inject = jnp.where(t < m, t, m - 1)
            x_in = jnp.where(stage_id == 0, xs[inject], buf)
            y = stage_fn(params, x_in)
            # last stage records output for microbatch (t - (S-1))
            out_idx = jnp.clip(t - (n_stages - 1), 0, m - 1)
            record = (stage_id == n_stages - 1) & (t >= n_stages - 1)
            outs = jnp.where(record, outs.at[out_idx].set(y), outs)
            # hand activations to the next stage
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = jax.lax.ppermute(y, stage_axis, perm)
            return buf, outs

        _, outs = jax.lax.fori_loop(0, n_stages + m - 1, tick, (buf, outs))
        # every stage's `outs` is only valid on the last stage; broadcast it
        outs = jax.lax.psum(
            jnp.where(stage_id == n_stages - 1, outs, jnp.zeros_like(outs)),
            stage_axis)
        return outs

    shmapped = shard_map(
        per_stage, mesh=mesh,
        in_specs=(P(stage_axis), P(None)),
        out_specs=P(None),
    )
    xs = x.reshape(m, x.shape[0] // m, *x.shape[1:])
    out = shmapped(params_per_stage, xs)
    return out.reshape(x.shape)
