"""Batched serving runtime: prefill + decode with slot-based batching.

A fixed pool of `slots` sequences decodes in lock-step (one pjit'd decode
step per tick); finished sequences free their slot and queued requests are
prefilled into it (continuous batching at slot granularity).  Sampling:
greedy or temperature.  The decode step is the same function the dry-run
lowers for the decode_32k / long_500k cells.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as TF


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: list = field(default_factory=list)
    done: bool = False


class Server:
    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 256,
                 temperature: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.temperature = temperature
        self.key = jax.random.key(seed)
        self.cache = TF.init_cache(cfg, slots, max_len)
        self.slot_req: list[Request | None] = [None] * slots
        self.positions = np.zeros((slots, 1), np.int32)
        self.tokens = np.zeros((slots, 1), np.int32)
        self.budget = np.zeros(slots, np.int32)

        self._decode = jax.jit(
            lambda p, c, t, q: TF.decode_step(p, cfg, c, t, q))
        self._prefill1 = jax.jit(
            lambda p, t: TF.prefill(p, cfg, t, max_len=max_len))

    # ------------------------------------------------------------------
    def _admit(self, queue: list[Request]):
        for s in range(self.slots):
            if self.slot_req[s] is None and queue:
                req = queue.pop(0)
                logits, cache1 = self._prefill1(
                    self.params, jnp.asarray(req.prompt[None]))
                # splice the single-sequence cache into slot s: stage-stacked
                # leaves are (stages, B, ...), tail leaves are (B, ...)
                self.cache["stages"] = jax.tree.map(
                    lambda full, one, s=s: full.at[:, s:s + 1].set(
                        one.astype(full.dtype)),
                    self.cache["stages"], cache1["stages"])
                if "tail" in self.cache:
                    self.cache["tail"] = jax.tree.map(
                        lambda full, one, s=s: full.at[s:s + 1].set(
                            one.astype(full.dtype)),
                        self.cache["tail"], cache1["tail"])
                nxt = self._sample(logits[:, 0])
                self.slot_req[s] = req
                self.tokens[s, 0] = int(nxt[0])
                self.positions[s, 0] = len(req.prompt)
                self.budget[s] = req.max_new - 1
                req.out.append(int(nxt[0]))

    def _stage_first(self, cache1):
        return cache1

    def _sample(self, logits):
        if self.temperature <= 0:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(
            sub, logits.astype(jnp.float32) / self.temperature).astype(jnp.int32)

    # ------------------------------------------------------------------
    def run(self, requests: list[Request], max_ticks: int = 10_000) -> dict:
        queue = list(requests)
        ticks = 0
        generated = 0
        while (queue or any(r is not None for r in self.slot_req)) \
                and ticks < max_ticks:
            self._admit(queue)
            if all(r is None for r in self.slot_req):
                break
            logits, self.cache = self._decode(
                self.params, self.cache,
                jnp.asarray(self.tokens), jnp.asarray(self.positions))
            nxt = np.asarray(self._sample(logits[:, 0]))
            for s, req in enumerate(self.slot_req):
                if req is None:
                    continue
                generated += 1
                req.out.append(int(nxt[s]))
                self.tokens[s, 0] = int(nxt[s])
                self.positions[s, 0] += 1
                self.budget[s] -= 1
                if self.budget[s] <= 0 or \
                        self.positions[s, 0] >= self.max_len - 1:
                    req.done = True
                    self.slot_req[s] = None
            ticks += 1
        return {"ticks": ticks, "generated": generated}
