"""Elastic scaling: mesh selection for whatever devices survive.

`choose_mesh` picks the best (pod, data, model) factorization for an
arbitrary live-device count (largest usable power-of-two block, TP capped by
the arch's shardable width), and `resize_plan` describes the checkpoint-based
transition — with stateless data (data.pipeline) and sharding-on-restore
checkpoints (checkpoint.restore), a resize is: save -> rebuild mesh -> restore.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class MeshPlan:
    shape: tuple[int, ...]
    axes: tuple[str, ...]
    usable_devices: int
    dropped_devices: int


def _pow2_floor(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def choose_mesh(n_devices: int, *, model_cap: int = 16,
                prefer_pods: int = 1) -> MeshPlan:
    usable = _pow2_floor(n_devices)
    pods = prefer_pods if usable % prefer_pods == 0 and prefer_pods > 1 else 1
    rest = usable // pods
    model = min(model_cap, _pow2_floor(max(int(rest ** 0.5), 1)))
    data = rest // model
    if pods > 1:
        return MeshPlan((pods, data, model), ("pod", "data", "model"),
                        usable, n_devices - usable)
    return MeshPlan((data, model), ("data", "model"),
                    usable, n_devices - usable)


def resize_plan(old: MeshPlan, n_devices_now: int, **kw) -> dict:
    new = choose_mesh(n_devices_now, **kw)
    return {
        "old": old,
        "new": new,
        "action": "none" if new.shape == old.shape else "save_restore",
        "steps": (
            "1. checkpoint.save (atomic)",
            f"2. rebuild mesh {new.shape} over {new.usable_devices} devices",
            "3. checkpoint.restore with new NamedShardings",
            "4. data pipeline continues at saved step (stateless)",
        ),
    }
