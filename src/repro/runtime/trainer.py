"""Trainer: sharded train step, microbatching, fault tolerance, metrics.

The step function is one pjit'd program: microbatch gradient accumulation via
lax.scan (overlappable with the FSDP gathers by XLA), AdamW with
ZeRO-sharded state, LR schedule, gradient clipping.  Around it: checkpoint
save/auto-resume (atomic, async), straggler detection hooks, and the ESF
fabric cost model for step-time sanity reports.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpoint as ckpt
from repro.jax_compat import set_mesh, tree_as_shardings
from repro.models import transformer as TF
from repro.models import model_zoo as zoo
from repro.optim import adamw, schedules
from repro.parallel.sharding import ShardingRules, logical, param_specs, use_rules
from repro.runtime.straggler import StragglerDetector


@dataclass
class TrainConfig:
    steps: int = 100
    microbatches: int = 1
    peak_lr: float = 3e-4
    warmup_steps: int = 20
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    ckpt_dir: str = ""
    ckpt_every: int = 200
    async_ckpt: bool = True
    log_every: int = 10
    schedule: str = "warmup_cosine"


class Trainer:
    def __init__(self, cfg, train_cfg: TrainConfig, mesh,
                 rules: ShardingRules | None = None):
        self.cfg = cfg
        self.tc = train_cfg
        self.mesh = mesh
        self.rules = rules or ShardingRules()
        self.detector = StragglerDetector()
        self.metrics_log: list[dict] = []
        self._build()

    # ------------------------------------------------------------------
    def _build(self):
        cfg, tc = self.cfg, self.tc
        sched_fn = getattr(schedules, tc.schedule)

        def train_step(params, opt_state, batch):
            mb = tc.microbatches

            def micro(carry, mb_batch):
                acc = carry
                (loss, metrics), grads = jax.value_and_grad(
                    lambda p: TF.loss_fn(p, cfg, mb_batch), has_aux=True
                )(params)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / mb, acc, grads)
                return acc, (loss, metrics["xent"])

            zeros = jax.tree.map(
                lambda x: jnp.zeros(x.shape, jnp.float32), params)
            split = jax.tree.map(
                lambda x: x.reshape(mb, x.shape[0] // mb, *x.shape[1:]), batch)
            grads, (losses, xents) = jax.lax.scan(micro, zeros, split)
            lr = sched_fn(opt_state.step, peak_lr=tc.peak_lr,
                          warmup_steps=tc.warmup_steps, total_steps=tc.steps)
            new_params, new_state, om = adamw.update(
                opt_state, grads, params, lr=lr,
                weight_decay=tc.weight_decay, grad_clip=tc.grad_clip)
            return new_params, new_state, {
                "loss": jnp.mean(losses), "xent": jnp.mean(xents),
                "lr": lr, **om}

        with set_mesh(self.mesh), use_rules(self.rules):
            axes = TF.param_axes(cfg)
            pspecs = param_specs(axes)
            ospecs = adamw.state_axes(pspecs)
            bspec = logical("batch", None)
            # PartitionSpecs wrapped into NamedShardings: 0.4.x jit accepts
            # only Sharding instances (jax_compat), and it is a no-op upgrade
            # on new JAX
            self.param_shardings = psh = tree_as_shardings(self.mesh, pspecs)
            osh = tree_as_shardings(self.mesh, ospecs)
            bsh = tree_as_shardings(
                self.mesh, jax.tree.map(lambda _: bspec,
                                        {"tokens": 0, "labels": 0}))
            self.step_fn = jax.jit(
                train_step,
                in_shardings=(psh, osh, bsh),
                out_shardings=(psh, osh, None),
                donate_argnums=(0, 1),
            )

    # ------------------------------------------------------------------
    def init_state(self, seed: int = 0):
        cfg = self.cfg
        with set_mesh(self.mesh), use_rules(self.rules):
            pspecs = param_specs(TF.param_axes(cfg))
            init = jax.jit(lambda k: TF.init_params(cfg, k),
                           out_shardings=tree_as_shardings(self.mesh, pspecs))
            params = init(jax.random.key(seed))
            opt = jax.jit(adamw.init,
                          out_shardings=tree_as_shardings(
                              self.mesh, adamw.state_axes(pspecs)))(params)
        return params, opt

    def maybe_resume(self, params, opt_state):
        if not self.tc.ckpt_dir:
            return params, opt_state, 0
        step = ckpt.latest_step(self.tc.ckpt_dir)
        if step is None:
            return params, opt_state, 0
        (params, opt_state), step = ckpt.restore(
            self.tc.ckpt_dir, (params, opt_state))
        return params, opt_state, step

    # ------------------------------------------------------------------
    def fit(self, source, params=None, opt_state=None, start_step: int = 0):
        if params is None:
            params, opt_state = self.init_state()
            params, opt_state, start_step = self.maybe_resume(params, opt_state)
        tc = self.tc
        with set_mesh(self.mesh), use_rules(self.rules):
            for step in range(start_step, tc.steps):
                batch = source.batch(step)
                t0 = time.perf_counter()
                params, opt_state, metrics = self.step_fn(
                    params, opt_state, batch)
                metrics = {k: float(v) for k, v in metrics.items()}
                dt = time.perf_counter() - t0
                verdict = self.detector.observe(0, dt)
                metrics.update(step=step, step_time_s=dt, straggler=verdict)
                self.metrics_log.append(metrics)
                if step % tc.log_every == 0:
                    print(f"step {step:5d} loss {metrics['loss']:.4f} "
                          f"lr {metrics['lr']:.2e} {dt * 1e3:.0f} ms",
                          flush=True)
                if tc.ckpt_dir and step and step % tc.ckpt_every == 0:
                    ckpt.save(tc.ckpt_dir, step, (params, opt_state),
                              blocking=not tc.async_ckpt)
        if tc.ckpt_dir:
            ckpt.save(tc.ckpt_dir, tc.steps, (params, opt_state))
        return params, opt_state
