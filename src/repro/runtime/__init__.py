"""Runtime: trainer, server, straggler mitigation, elastic scaling."""
