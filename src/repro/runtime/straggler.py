"""Straggler detection + mitigation policy (fabric-model-informed).

Detection: per-step wall times feed an EWMA + k*sigma detector; sustained
outliers flag a straggling worker/link.  Mitigation escalates:

  1. "rebalance"  — shrink the straggler's data shard (gradient weighting
                    keeps the estimator unbiased);
  2. "checkpoint_evict" — checkpoint, drop the slow host, elastic-resume on
                    the survivors (runtime.elastic picks the new mesh).

The *decision threshold* is not a magic constant: the ESF fabric model
quantifies what a degraded link does to a step (`estimate_step_impact`), and
eviction is chosen only when the modeled loss from running degraded exceeds
the modeled cost of a restart — the paper's simulate-to-decide loop applied
to the trainer itself.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class StragglerDetector:
    alpha: float = 0.1
    k_sigma: float = 3.0
    patience: int = 5
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    strikes: dict = field(default_factory=dict)

    def observe(self, worker: int, step_time_s: float) -> str:
        """Returns: ok | suspect | straggler."""
        if self.n < 3:  # bootstrap
            self.n += 1
            self.mean = (self.mean * (self.n - 1) + step_time_s) / self.n
            return "ok"
        import math

        sigma = math.sqrt(max(self.var, 1e-12))
        outlier = step_time_s > self.mean + self.k_sigma * sigma \
            and step_time_s > 1.05 * self.mean
        if not outlier:
            # robust EWMA: only non-outliers update the baseline, otherwise a
            # sustained straggler poisons its own detection threshold
            d = step_time_s - self.mean
            self.mean += self.alpha * d
            self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
            self.strikes[worker] = 0
            return "ok"
        self.strikes[worker] = self.strikes.get(worker, 0) + 1
        return ("straggler" if self.strikes[worker] >= self.patience
                else "suspect")


def estimate_step_impact(fabric, graph, *, grad_bytes_per_chip: int,
                         slow_factor: float, compute_s: float) -> dict:
    """Model a degraded chip's effect on step time via the fabric engine:
    the ring all-reduce stalls at the slow link, so the collective stretches
    by ~slow_factor while compute is unaffected on other chips."""
    from repro.core.fabric_model import predict_collective

    base = predict_collective(fabric, graph, "all_reduce", "x",
                              grad_bytes_per_chip)
    degraded_s = base.seconds * slow_factor
    return {
        "healthy_step_s": compute_s + base.seconds,
        "degraded_step_s": compute_s + degraded_s,
        "slowdown": (compute_s + degraded_s) / (compute_s + base.seconds),
    }


def mitigation_decision(slowdown: float, restart_cost_steps: float,
                        remaining_steps: int) -> str:
    """Evict when cumulative degraded time exceeds the restart cost."""
    excess = (slowdown - 1.0) * remaining_steps
    if slowdown < 1.02:
        return "ignore"
    if excess < restart_cost_steps:
        return "rebalance"
    return "checkpoint_evict"
