import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimbs: hypothesis -> change -> re-lower -> validate, logged.

Each experiment edits ONE knob (sharding rule or model tiling constant),
re-runs the dry-run cell, and records the three roofline terms before/after
plus whether the napkin-math hypothesis was confirmed.  Driven by a declared
experiment list so the log in artifacts/perf_log.json is reproducible:

  PYTHONPATH=src python -m repro.launch.hillclimb --cell mamba2
"""

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json      # noqa: E402

from repro.configs import SHAPES, get_config  # noqa: E402
from repro.launch.dryrun import lower_cell  # noqa: E402
from repro.launch.roofline import analyze_cell  # noqa: E402
from repro.parallel.sharding import ShardingRules  # noqa: E402


def run_variant(arch, shape, *, rules=None, cfg_override=None, tag=""):
    rec = lower_cell(arch, shape, False, rules=rules,
                     cfg_override=cfg_override)
    cell = analyze_cell(tag, rec)
    return {
        "tag": tag,
        "compute_ms": cell["compute_ms"],
        "memory_ms": cell["memory_ms"],
        "collective_ms": cell["collective_ms"],
        "dominant": cell["dominant"],
        "useful_flops_ratio": cell["useful_flops_ratio"],
        "roofline_fraction": cell["roofline_fraction"],
        "mem_gib": cell["memory_gib"],
    }


# ---------------------------------------------------------------------------
# experiment definitions: (hypothesis, knob-apply fn)
# ---------------------------------------------------------------------------

def experiments_mamba2():
    """mamba2-1.3b train_4k — worst roofline fraction (memory-dominated).

    Dominant term: HBM bytes, driven by the SSD intra-chunk L/score tensors,
    whose traffic is b*S*h*q*4 bytes (linear in chunk size q)."""
    cfg = get_config("mamba2-1.3b")
    yield ("ssd_chunk 128->64: score traffic ~ S*q per head, so halving q "
           "should cut the SSD share of HBO bytes ~2x; FLOPs in the diagonal "
           "term also halve (q^2 * nc = S*q)",
           dict(cfg_override=dataclasses.replace(cfg, ssd_chunk=64),
                tag="ssd_chunk=64"))
    yield ("ssd_chunk 128->256: inverse control — traffic should grow ~2x",
           dict(cfg_override=dataclasses.replace(cfg, ssd_chunk=256),
                tag="ssd_chunk=256"))
    yield ("ssd_chunk 64 + state dim sharded over model axis is already "
           "active; try chunk 32 — expect diminishing returns as the "
           "inter-chunk state scan (S/q steps) and conv/proj bytes start to "
           "dominate",
           dict(cfg_override=dataclasses.replace(cfg, ssd_chunk=32),
                tag="ssd_chunk=32"))


def experiments_crplus():
    """command-r-plus-104b prefill_32k — most collective-bound cell.

    Dominant: per-layer TP all-reduces of (B,S,D) activations at S=32k."""
    cfg = get_config("command-r-plus-104b")
    yield ("sequence parallelism (seq->model on the residual stream): the "
           "2x all-reduce per layer becomes reduce-scatter + all-gather on "
           "1/16-size shards; expect collective bytes to drop toward ~1/2 "
           "and the norm/mlp memory term to shrink 16x on those segments",
           dict(rules=ShardingRules().with_overrides(
               seq="model", embed_act=None), tag="seq-parallel"))
    yield ("attn_chunk 1024->2048: fewer online-softmax passes means fewer "
           "re-reads of q (memory term), no collective change expected "
           "(control for term independence)",
           dict(cfg_override=dataclasses.replace(cfg, attn_chunk=2048),
                tag="attn_chunk=2048"))
    yield ("combine both winners",
           dict(rules=ShardingRules().with_overrides(seq="model"),
                cfg_override=dataclasses.replace(cfg, attn_chunk=2048),
                tag="seq-parallel+attn_chunk=2048"))


def experiments_qwen3():
    """qwen3-moe-30b-a3b train_4k — paper-representative cell (EP dispatch
    traffic is the fabric-sensitive collective the ESF engine models).

    MODEL/HLO = 0.63: ~30% of compiled FLOPs are dispatch/combine one-hot
    einsums, whose cost is T*E*C*d with C ∝ group_size."""
    cfg = get_config("qwen3-moe-30b-a3b")
    yield ("moe_group 512->256 halves capacity C hence dispatch/combine "
           "FLOPs ~2x on that term; expect compute_ms down ~15-25% and "
           "MODEL/HLO up",
           dict(cfg_override=dataclasses.replace(cfg, moe_group=256),
                tag="moe_group=256"))
    yield ("moe_group 256->128: same direction, diminishing because the "
           "expert FFN einsum now dominates; watch for capacity-drop risk "
           "(C=16 at tg=128) which the loss would pay, not the roofline",
           dict(cfg_override=dataclasses.replace(cfg, moe_group=128),
                tag="moe_group=128"))
    yield ("capacity_factor 1.25->1.0 at moe_group=256: C shrinks another "
           "20%; same-direction smaller effect",
           dict(cfg_override=dataclasses.replace(
               cfg, moe_group=256,
               moe=dataclasses.replace(cfg.moe, capacity_factor=1.0)),
               tag="moe_group=256+cf=1.0"))


CELLS = {
    "mamba2": ("mamba2-1.3b", "train_4k", experiments_mamba2),
    "crplus": ("command-r-plus-104b", "prefill_32k", experiments_crplus),
    "qwen3": ("qwen3-moe-30b-a3b", "train_4k", experiments_qwen3),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=tuple(CELLS) + ("all",), default="all")
    ap.add_argument("--out", default="artifacts/perf_log.json")
    args = ap.parse_args()

    log = {}
    if os.path.exists(args.out):
        log = json.load(open(args.out))

    for name, (arch, shape, gen) in CELLS.items():
        if args.cell not in ("all", name):
            continue
        print(f"=== hillclimb {name}: {arch} x {shape} ===", flush=True)
        entry = log.setdefault(name, {"arch": arch, "shape": shape,
                                      "iterations": []})
        base = run_variant(arch, shape, tag="baseline(paper-faithful)")
        print(json.dumps(base), flush=True)
        entry["baseline"] = base
        for hypothesis, kw in gen():
            tag = kw.pop("tag")
            print(f"--- {tag}: {hypothesis[:100]}...", flush=True)
            var = run_variant(arch, shape, tag=tag, **kw)
            dom = base["dominant"] + "_ms"
            delta = (var[dom] - base[dom]) / base[dom]
            var["hypothesis"] = hypothesis
            var["dominant_term_delta"] = round(delta, 4)
            print(json.dumps({k: var[k] for k in
                              ("tag", "compute_ms", "memory_ms",
                               "collective_ms", "dominant_term_delta")}),
                  flush=True)
            entry["iterations"].append(var)
        json.dump(log, open(args.out, "w"), indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
