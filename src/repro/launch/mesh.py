"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (data, model);
multi-pod: 2x16x16 = 512 chips (pod, data, model).  The dry-run
(launch/dryrun.py) sets XLA_FLAGS for 512 host placeholder devices *before*
importing jax; everything else sees the real device count.

``AxisType`` / ``make_mesh`` come from `repro.jax_compat`: on jax 0.4.x
(which has neither ``jax.sharding.AxisType`` nor the ``axis_types`` kwarg)
they degrade to untyped meshes, which is semantically what 0.4.x built
anyway.  Import them from here (or from jax_compat directly) instead of
``jax.sharding`` so module import never fails on the installed JAX.
"""

from __future__ import annotations

import jax

from repro.jax_compat import AxisType, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1):
    """Whatever-is-available mesh for local smoke runs."""
    n = len(jax.devices())
    model = min(model, n)
    return make_mesh((n // model, model), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)
