"""Serving driver: batched decode over a slot pool.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b \
        --requests 8 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import repro.core  # noqa: F401
import jax
import numpy as np

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import transformer as TF
from repro.runtime.server import Request, Server


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3-8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = TF.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        int(rng.integers(4, 16)))
                    .astype(np.int32),
                    max_new=args.max_new)
            for i in range(args.requests)]
    srv = Server(cfg, params, slots=args.slots, max_len=args.max_len,
                 temperature=args.temperature)
    t0 = time.perf_counter()
    stats = srv.run(reqs)
    dt = time.perf_counter() - t0
    print(f"arch={cfg.name} served {len(reqs)} reqs, "
          f"{stats['generated']} tokens in {stats['ticks']} ticks "
          f"({dt:.1f}s, {stats['generated'] / dt:.1f} tok/s on CPU)")


if __name__ == "__main__":
    main()
