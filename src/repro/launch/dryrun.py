import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell the appropriate step function (train / prefill / decode) is
pjit'd with the production sharding rules, lowered against ShapeDtypeStruct
inputs (no allocation), and compiled for the 16x16 single-pod and 2x16x16
multi-pod meshes.  Recorded per cell into the artifacts JSON:

  * memory_analysis()   — per-device argument/temp/output/alias bytes
                          (proves the cell fits 16 GB v5e HBM);
  * cost_analysis()     — per-device HLO FLOPs/bytes.  A `lax.scan` body is
                          counted ONCE (verified empirically), so a second
                          "period" program (one pattern period, same
                          shardings) is compiled and the roofline applies
                          total = full + (n_periods - 1) * period;
  * the collective schedule — op counts + per-device result bytes parsed
                          from compiled.as_text(), same trip-count correction.

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all --out artifacts/dryrun.json
"""

import argparse   # noqa: E402
import json       # noqa: E402
import re         # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs import (ARCH_IDS, SHAPES, get_config,  # noqa: E402
                           shape_applicable)
from repro.jax_compat import set_mesh, tree_as_shardings  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import model_zoo as zoo  # noqa: E402
from repro.models import transformer as TF  # noqa: E402
from repro.models.layers import DTYPE  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.parallel.sharding import (ShardingRules, logical,  # noqa: E402
                                     param_specs, prune_tree_specs, use_rules)

COLLECTIVE_RE = re.compile(
    r"(\w[\w.]*)\s*=\s*([a-z0-9]+)\[([\d,]*)\][^=]*?"
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def parse_collectives(hlo_text: str) -> dict:
    """op kind -> [count, total per-device result bytes]."""
    out: dict[str, list] = {}
    for m in COLLECTIVE_RE.finditer(hlo_text):
        _, dt, dims, kind = m.groups()
        nbytes = DTYPE_BYTES.get(dt, 4)
        for d in dims.split(","):
            if d:
                nbytes *= int(d)
        ent = out.setdefault(kind, [0, 0])
        ent[0] += 1
        ent[1] += nbytes
    return out


def batch_specs_for(cfg, shape, kind):
    b = logical("batch", None)
    if kind == "train":
        specs = {"tokens": b, "labels": b}
        if cfg.vision_patches or cfg.enc_layers:
            specs["frontend_embeds"] = logical("batch", None, "embed_act")
        return {"batch": specs}
    if kind == "prefill":
        out = {"tokens": b}
        if cfg.vision_patches or cfg.enc_layers:
            out["frontend_embeds"] = logical("batch", None, "embed_act")
        return out
    return {"tokens": b, "positions": b}


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               rules: ShardingRules | None = None,
               with_period: bool = True, cfg_override=None) -> dict:
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules or ShardingRules()
    kind = shape.kind

    t0 = time.time()
    with set_mesh(mesh), use_rules(rules):
        aparams = zoo.abstract_params(cfg)
        pspecs = prune_tree_specs(param_specs(TF.param_axes(cfg)), aparams,
                                  mesh)
        inputs = zoo.input_specs(cfg, shape)
        bspecs = batch_specs_for(cfg, shape, kind)
        # prune batch shardings against the actual input shapes (e.g. the
        # long_500k global batch of 1 cannot shard over (pod, data))
        from repro.parallel.sharding import prune_spec_for_shape

        def _prune_inputs(specs, ins):
            return {k: prune_spec_for_shape(v, ins[k].shape, mesh)
                    if k in ins and hasattr(ins[k], "shape") else v
                    for k, v in specs.items()}

        if kind == "train":
            bspecs["batch"] = _prune_inputs(bspecs["batch"], inputs["batch"])
        else:
            bspecs = _prune_inputs(bspecs, inputs)

        if kind == "train":
            aopt = jax.eval_shape(adamw.init, aparams)
            ospecs = prune_tree_specs(adamw.state_axes(pspecs), aopt, mesh)

            def step(params, opt_state, batch):
                (loss, metrics), grads = jax.value_and_grad(
                    lambda p: TF.loss_fn(p, cfg, batch), has_aux=True)(params)
                new_p, new_s, om = adamw.update(opt_state, grads, params,
                                                lr=jnp.float32(1e-4))
                return new_p, new_s, loss

            jitted = jax.jit(
                step,
                in_shardings=tree_as_shardings(
                    mesh, (pspecs, ospecs, bspecs["batch"])),
                out_shardings=tree_as_shardings(mesh, (pspecs, ospecs, None)),
                donate_argnums=(0, 1))
            args = (aparams, aopt, inputs["batch"])
        elif kind == "prefill":
            acache = zoo.abstract_cache(cfg, shape.global_batch,
                                        shape.seq_len)
            cspecs = prune_tree_specs(
                param_specs(TF.cache_axes(cfg)), acache, mesh)

            def step(params, tokens, frontend_embeds=None):
                return TF.prefill(params, cfg, tokens, max_len=shape.seq_len,
                                  frontend_embeds=frontend_embeds)

            in_sh = [pspecs, bspecs["tokens"]]
            args = [aparams, inputs["tokens"]]
            if "frontend_embeds" in inputs:
                in_sh.append(bspecs["frontend_embeds"])
                args.append(inputs["frontend_embeds"])
            jitted = jax.jit(
                step, in_shardings=tree_as_shardings(mesh, tuple(in_sh)),
                out_shardings=tree_as_shardings(mesh, (None, cspecs)))
            args = tuple(args)
        else:  # decode / long_decode
            acache = inputs["cache"]
            cspecs = prune_tree_specs(
                param_specs(TF.cache_axes(cfg)), acache, mesh)

            def step(params, cache, tokens, positions):
                return TF.decode_step(params, cfg, cache, tokens, positions)

            jitted = jax.jit(
                step,
                in_shardings=tree_as_shardings(
                    mesh, (pspecs, cspecs, bspecs["tokens"],
                           bspecs["positions"])),
                out_shardings=tree_as_shardings(mesh, (None, cspecs)),
                donate_argnums=(1,))
            args = (aparams, acache, inputs["tokens"], inputs["positions"])

        lowered = jitted.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_per_device_gib": round(
                (ma.argument_size_in_bytes + ma.output_size_in_bytes
                 + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30, 3),
        }
        ca = compiled.cost_analysis()
        rec["flops_once"] = float(ca.get("flops", 0.0))
        rec["bytes_once"] = float(ca.get("bytes accessed", 0.0))
        rec["collectives_once"] = parse_collectives(compiled.as_text())
        rec["n_periods"] = cfg.n_periods
        rec["status"] = "ok"

        # ---- per-period program for scan trip-count correction ----------
        if with_period and cfg.n_periods > 1:
            rec["period"] = _lower_period(cfg, shape, mesh, rules, pspecs,
                                          aparams, kind)
    return rec


def _lower_period(cfg, shape, mesh, rules, pspecs, aparams, kind) -> dict:
    """Compile ONE pattern period with identical shardings; its costs scale
    the scan body (n_periods - 1) more times in the roofline."""
    from repro.models.transformer import _period_fn

    b = shape.global_batch
    s = 1 if kind in ("decode", "long_decode") else shape.seq_len
    x_spec = jax.ShapeDtypeStruct((b, s, cfg.d_model), DTYPE)
    pos_spec = jax.ShapeDtypeStruct((b, s), jnp.int32)
    stage0 = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype),
        aparams["stages"])
    sspecs = jax.tree.map(lambda sp: P(*sp[1:]), pspecs["stages"],
                          is_leaf=lambda x: isinstance(x, P))

    enc_kv_spec = None
    if cfg.enc_layers:
        enc_kv_spec = {
            "k": jax.ShapeDtypeStruct((b, cfg.enc_frames, cfg.n_kv,
                                       cfg.head_dim), DTYPE),
            "v": jax.ShapeDtypeStruct((b, cfg.enc_frames, cfg.n_kv,
                                       cfg.head_dim), DTYPE),
        }

    if kind == "train":
        def period(sp, x, pos, ekv=None):
            def f(sp_, x_):
                y, _, aux = _period_fn(sp_, x_, pos, cfg, mode="train",
                                       enc_kv=ekv)
                return jnp.sum(y.astype(jnp.float32)) + aux
            g = jax.grad(f, argnums=(0, 1))(sp, x)
            return g
    else:
        def period(sp, x, pos, ekv=None):
            y, _, _ = _period_fn(sp, x, pos, cfg, mode="train",
                                 cache_len=shape.seq_len, enc_kv=ekv)
            return y

    from repro.parallel.sharding import prune_spec_for_shape
    x_sh = prune_spec_for_shape(logical("batch", None, "embed_act"),
                                x_spec.shape, mesh)
    pos_sh = prune_spec_for_shape(logical("batch", None), pos_spec.shape, mesh)
    in_sh = [sspecs, x_sh, pos_sh]
    args = [stage0, x_spec, pos_spec]
    if enc_kv_spec is not None:
        in_sh.append(jax.tree.map(
            lambda a: prune_spec_for_shape(
                logical("batch", None, "kv_heads", None), a.shape, mesh),
            enc_kv_spec))
        args.append(enc_kv_spec)
    jitted = jax.jit(period,
                     in_shardings=tree_as_shardings(mesh, tuple(in_sh)))
    lowered = jitted.lower(*args)
    compiled = lowered.compile()
    ca = compiled.cost_analysis()
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "collectives": parse_collectives(compiled.as_text()),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun.json")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-period", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                for mp in (False, True):
                    cells.append((arch, shape, mp))
    else:
        cells.append((args.arch, args.shape, args.multi_pod))

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = {}
    if os.path.exists(args.out) and not args.force:
        results = json.load(open(args.out))

    for arch, shape, mp in cells:
        key = f"{arch}|{shape}|{'multi' if mp else 'single'}"
        if key in results and results[key].get("status") in ("ok", "skipped") \
                and not args.force:
            print(f"[skip cached] {key}")
            continue
        print(f"[dryrun] {key}", flush=True)
        try:
            rec = lower_cell(arch, shape, mp, with_period=not args.no_period)
        except Exception as e:  # noqa: BLE001
            rec = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if mp else "16x16",
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
        results[key] = rec
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        status = rec.get("status")
        mem = rec.get("memory", {}).get("peak_per_device_gib", "-")
        print(f"  -> {status} (peak/device {mem} GiB, "
              f"lower {rec.get('lower_s', '-')}s, "
              f"compile {rec.get('compile_s', '-')}s)", flush=True)

    n_ok = sum(1 for r in results.values() if r.get("status") == "ok")
    n_skip = sum(1 for r in results.values() if r.get("status") == "skipped")
    n_err = sum(1 for r in results.values() if r.get("status") == "error")
    print(f"dryrun summary: ok={n_ok} skipped={n_skip} error={n_err}")
    if n_err:
        for k, r in results.items():
            if r.get("status") == "error":
                print(f"  ERROR {k}: {r['error']}")


if __name__ == "__main__":
    main()
