"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --preset smoke \
        --steps 200 --ckpt-dir /tmp/ckpt

Presets: smoke (per-arch reduced config), 100m (a ~100M-param llama-style
config for the end-to-end example), full (the assigned config — dry-run scale,
needs a real pod).  Runs on whatever devices exist (host mesh).
"""

from __future__ import annotations

import argparse
import dataclasses

import repro.core  # noqa: F401  (x64 first)
import jax

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.configs.base import ArchConfig
from repro.data.pipeline import DataConfig, make_source
from repro.launch.mesh import make_host_mesh
from repro.runtime.trainer import TrainConfig, Trainer

PRESET_100M = ArchConfig(
    name="lm-100m", family="dense", n_layers=12, d_model=768, n_heads=12,
    n_kv=4, d_ff=2048, vocab=32768, head_dim=64, max_seq=2048)


def pick_config(arch: str, preset: str) -> ArchConfig:
    if preset == "100m":
        return PRESET_100M
    if preset == "smoke":
        return get_smoke_config(arch)
    return get_config(arch)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3-8b")
    ap.add_argument("--preset", choices=("smoke", "100m", "full"),
                    default="smoke")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--data", choices=("synthetic", "trace"),
                    default="synthetic")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--model-par", type=int, default=1)
    args = ap.parse_args()

    cfg = pick_config(args.arch, args.preset)
    mesh = make_host_mesh(model=args.model_par)
    print(f"arch={cfg.name} params~{cfg.params_count() / 1e6:.1f}M "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    tc = TrainConfig(steps=args.steps, microbatches=args.microbatches,
                     peak_lr=args.lr, warmup_steps=max(args.steps // 10, 5),
                     ckpt_dir=args.ckpt_dir, log_every=10)
    trainer = Trainer(cfg, tc, mesh)
    source = make_source(args.data, DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch))
    trainer.fit(source)
    losses = [m["loss"] for m in trainer.metrics_log]
    print(f"loss: first={losses[0]:.4f} last={losses[-1]:.4f} "
          f"steps={len(losses)}")


if __name__ == "__main__":
    main()
