"""Launchers: mesh, dryrun, roofline, hillclimb, train, serve."""
