"""Roofline analysis from the dry-run artifacts (single-pod table).

Three terms per (arch x shape), v5e constants (197 TFLOP/s bf16, 819 GB/s
HBM, ~50 GB/s/link ICI):

  compute    = HLO_FLOPs_dev / peak_FLOPs          (cost_analysis is per-device)
  memory     = HLO_bytes_dev / HBM_bw
  collective = coll_bytes_dev / link_bw

with the scan-body trip-count correction: total = once + (n_periods-1) x
period program (see launch/dryrun.py).  MODEL_FLOPS = 6*N*D (train) /
2*N_active*D (decode); the ratio MODEL/HLO exposes remat/recompute and
padding waste.  The ESF fabric engine independently predicts the dominant
collective (cross-check column) — the paper's simulate-the-fabric loop
applied to our own roofline.

Usage: PYTHONPATH=src python -m repro.launch.roofline \
           [--dryrun artifacts/dryrun.json] [--out artifacts/roofline.json]
"""

from __future__ import annotations

import argparse
import json

from repro.configs import ARCH_IDS, SHAPES, get_config

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_LINK = 50e9
CHIPS = 256


def model_flops_global(cfg, shape) -> float:
    n_act = cfg.active_params_count()
    if shape.kind == "train":
        return 6.0 * n_act * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_act * shape.global_batch * shape.seq_len
    return 2.0 * n_act * shape.global_batch  # one token per sequence


def corrected(rec: dict) -> dict:
    np_ = rec.get("n_periods", 1)
    flops = rec["flops_once"]
    nbytes = rec["bytes_once"]
    colls = {k: list(v) for k, v in rec["collectives_once"].items()}
    per = rec.get("period")
    if per and np_ > 1:
        flops += (np_ - 1) * per["flops"]
        nbytes += (np_ - 1) * per["bytes"]
        for k, (c, b) in per["collectives"].items():
            ent = colls.setdefault(k, [0, 0])
            ent[0] += (np_ - 1) * c
            ent[1] += (np_ - 1) * b
    return {"flops_dev": flops, "bytes_dev": nbytes, "collectives": colls}


def analyze_cell(key: str, rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    c = corrected(rec)
    coll_bytes = sum(b for _, b in c["collectives"].values())
    terms = {
        "compute_s": c["flops_dev"] / PEAK_FLOPS,
        "memory_s": c["bytes_dev"] / HBM_BW,
        "collective_s": coll_bytes / ICI_LINK,
    }
    dominant = max(terms, key=terms.get)
    mf = model_flops_global(cfg, shape) / CHIPS
    bound_s = max(terms.values())
    # useful work: compute OR the unavoidable HBM stream (params + caches =
    # the step's argument bytes), whichever is larger — decode steps are
    # legitimately bandwidth-rooflined, not FLOP-rooflined
    min_stream_s = rec["memory"]["argument_bytes"] / HBM_BW
    useful_s = max(mf / PEAK_FLOPS, min_stream_s)
    out = {
        "arch": rec["arch"], "shape": rec["shape"],
        **{k: round(v * 1e3, 3) for k, v in
           {"compute_ms": terms["compute_s"],
            "memory_ms": terms["memory_s"],
            "collective_ms": terms["collective_s"]}.items()},
        "dominant": dominant.replace("_s", ""),
        "model_flops_dev": mf,
        "hlo_flops_dev": c["flops_dev"],
        "useful_flops_ratio": round(mf / max(c["flops_dev"], 1), 3),
        "roofline_fraction": round(useful_s / max(bound_s, 1e-12), 3),
        "collective_bytes_dev": coll_bytes,
        "collectives": c["collectives"],
        "memory_gib": rec["memory"]["peak_per_device_gib"],
        "note": _note(dominant, rec, cfg, shape),
    }
    return out


def _note(dominant: str, rec, cfg, shape) -> str:
    if dominant == "compute_s":
        return ("compute-bound: raise MFU via fused attention kernels and "
                "less recompute (remat policy)")
    if dominant == "memory_s":
        if shape.kind in ("decode", "long_decode"):
            return ("HBM-bound decode: weights+KV stream per token; shrink "
                    "via KV sharding/quantization or larger batch")
        return ("HBM-bound: fuse ops to cut activation traffic; check CPU "
                "bf16-emulation inflation (DESIGN.md)")
    return ("collective-bound: re-span sharding axes (autotuner), overlap "
            "gathers with compute, or compress cross-pod gradients")


def fabric_crosscheck(cells: list[dict], top_n: int = 3) -> list[dict]:
    """ESF-engine prediction for the most collective-bound cells."""
    from repro.core.fabric_model import TPUFabric, predict_collective

    fab = TPUFabric(16, 16)
    graph = fab.build()
    worst = sorted((c for c in cells if c), key=lambda c: -c["collective_ms"])
    out = []
    for c in worst[:top_n]:
        per_kind = {}
        for kind, (cnt, nbytes) in c["collectives"].items():
            op = {"all-gather": "all_gather", "all-reduce": "all_reduce",
                  "reduce-scatter": "reduce_scatter",
                  "all-to-all": "all_to_all"}.get(kind)
            if op is None or nbytes == 0 or cnt == 0:
                continue
            mean = int(nbytes) // int(cnt)
            est = predict_collective(fab, graph, op, "y", mean)
            per_kind[kind] = {"hlo_bytes": nbytes, "n_ops": cnt,
                              "esf_pred_ms": round(est.seconds * cnt * 1e3, 3)}
        out.append({"arch": c["arch"], "shape": c["shape"],
                    "alpha_beta_ms": c["collective_ms"],
                    "esf_engine": per_kind})
    return out


def render_table(cells: list[dict]) -> str:
    hdr = ("| arch | shape | compute ms | memory ms | collective ms | "
           "dominant | MODEL/HLO | roofline frac | mem GiB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|")
    rows = [hdr]
    for c in cells:
        if not c:
            continue
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['compute_ms']} | "
            f"{c['memory_ms']} | {c['collective_ms']} | {c['dominant']} | "
            f"{c['useful_flops_ratio']} | {c['roofline_fraction']} | "
            f"{c['memory_gib']} |")
    return "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="artifacts/dryrun.json")
    ap.add_argument("--out", default="artifacts/roofline.json")
    ap.add_argument("--mesh", default="single", choices=("single", "multi"))
    args = ap.parse_args()

    recs = json.load(open(args.dryrun))
    cells = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            key = f"{arch}|{shape}|{args.mesh}"
            if key in recs:
                cells.append(analyze_cell(key, recs[key]))
    live = [c for c in cells if c]
    cross = fabric_crosscheck(live)
    json.dump({"cells": live, "fabric_crosscheck": cross},
              open(args.out, "w"), indent=1)
    print(render_table(live))
    print("\nESF fabric cross-check (most collective-bound cells):")
    print(json.dumps(cross, indent=1))
    print(f"\nwrote {args.out} ({len(live)} cells)")


if __name__ == "__main__":
    main()
