"""GQA attention: plain, KV-chunked (long-context), sliding-window, decode.

Three execution paths share one parameter layout:

  * plain     — masked S x S attention; used for training shapes (<= ~8k)
                under remat, where the S^2 block fits comfortably;
  * chunked   — lax.scan over KV chunks with online softmax (a pure-jnp
                flash formulation): O(S * chunk) memory; used for 32k+
                prefill lowering.  The Pallas kernel
                (`repro.kernels.flash_attention`) is the TPU fast path with
                this as its oracle semantics;
  * decode    — one query token against the KV cache (O(S) per step), with
                GQA head grouping and optional sliding-window ring cache.

dtype: qk products and softmax accumulate in f32.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.parallel.sharding import shard

from .layers import DTYPE, _normal, rope

NEG = -2.0e38


def init_attn(key, d: int, n_heads: int, n_kv: int, head_dim: int):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": _normal(kq, (d, n_heads * head_dim), d ** -0.5),
        "wk": _normal(kk, (d, n_kv * head_dim), d ** -0.5),
        "wv": _normal(kv, (d, n_kv * head_dim), d ** -0.5),
        "wo": _normal(ko, (n_heads * head_dim, d), (n_heads * head_dim) ** -0.5),
    }


def attn_axes():
    return {"wq": ("embed", "heads"), "wk": ("embed", "kv_heads"),
            "wv": ("embed", "kv_heads"), "wo": ("heads", "embed")}


def _project(p, x, n_heads, n_kv, head_dim, positions, rope_theta):
    b, s, _ = x.shape
    q = (x @ p["wq"]).reshape(b, s, n_heads, head_dim)
    k = (x @ p["wk"]).reshape(b, s, n_kv, head_dim)
    v = (x @ p["wv"]).reshape(b, s, n_kv, head_dim)
    if rope_theta:
        q = rope(q, positions, rope_theta)
        k = rope(k, positions, rope_theta)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    return q, k, v


def _gqa_scores(q, k):
    """q: (B,S,KV,G,D), k: (B,T,KV,D) -> (B,KV,G,S,T) f32."""
    return jnp.einsum("bskgd,btkd->bkgst", q, k,
                      preferred_element_type=jnp.float32)


def plain_attention(q, k, v, *, causal=True, window: int | None = None,
                    q_offset=0):
    b, s, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, d)
    scores = _gqa_scores(qg, k) / jnp.sqrt(d).astype(jnp.float32)
    t = k.shape[1]
    qpos = jnp.arange(s)[:, None] + q_offset
    kpos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, NEG)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w.astype(v.dtype), v)
    return out.reshape(b, s, h, d)


def chunked_attention(q, k, v, *, chunk: int = 1024, causal=True,
                      window: int | None = None):
    """Online-softmax scan over KV chunks (flash semantics, pure jnp)."""
    b, s, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    t = k.shape[1]
    n_chunks = (t + chunk - 1) // chunk
    pad = n_chunks * chunk - t
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, chunk, kvh, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, kvh, d).transpose(1, 0, 2, 3, 4)
    qg = q.reshape(b, s, kvh, g, d)
    qpos = jnp.arange(s)[:, None]

    @jax.checkpoint  # keep only the O(S) carry per chunk under outer-remat bwd
    def step(carry, xs):
        m, l, acc, idx = carry
        kb, vb = xs
        scores = _gqa_scores(qg, kb) / jnp.sqrt(d).astype(jnp.float32)
        kpos = idx * chunk + jnp.arange(chunk)[None, :]
        mask = kpos < t
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        scores = jnp.where(mask[None, None, None], scores, NEG)
        m_new = jnp.maximum(m, scores.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bkgst,btkd->bkgsd", p.astype(vb.dtype), vb)
        acc_new = acc * alpha[..., None].astype(acc.dtype) + pv
        return (m_new, l_new, acc_new, idx + 1), None

    m0 = jnp.full((b, kvh, g, s), NEG, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, s), jnp.float32)
    a0 = jnp.zeros((b, kvh, g, s, d), DTYPE)
    (m, l, acc, _), _ = jax.lax.scan(step, (m0, l0, a0, jnp.int32(0)), (kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, d)


def decode_attention(q, k_cache, v_cache, lengths, *, window: int | None = None):
    """q: (B,1,H,D); caches: (B,T,KV,D); lengths: (B,) valid prefix length.

    For sliding-window layers the cache is a ring buffer of size W; masking
    is by *slot validity*, handled by the caller via `lengths` semantics.
    """
    b, _, h, d = q.shape
    kvh = k_cache.shape[2]
    g = h // kvh
    t = k_cache.shape[1]
    qg = q.reshape(b, 1, kvh, g, d)
    scores = _gqa_scores(qg, k_cache) / jnp.sqrt(d).astype(jnp.float32)
    kpos = jnp.arange(t)[None, :]
    mask = kpos < lengths[:, None]
    if window is not None:
        mask &= kpos > (lengths[:, None] - 1 - window)
    scores = jnp.where(mask[:, None, None, None], scores, NEG)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w.astype(v_cache.dtype), v_cache)
    return out.reshape(b, 1, h, d)


def attention_block(p, x, positions, cfg, *, mode, cache=None, window=None,
                    cache_len=None):
    """Full attention sub-block.  mode: train | prefill | decode.

    Returns (out, new_cache).  Caches: dict(k, v, len) where k/v are
    (B, T, KV, D); T = min(window, cache_len) for windowed layers.  Windowed
    caches are ring buffers: token at position p lives in slot p % T, both
    at prefill handoff and during decode.
    """
    n_heads, n_kv, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    q, k, v = _project(p, x, n_heads, n_kv, hd, positions, cfg.rope_theta)

    if mode == "decode":
        assert cache is not None
        b = x.shape[0]
        t = cache["k"].shape[1]
        # ring-buffer write position for windowed caches, linear otherwise
        pos = cache["len"]
        slot = pos % jnp.int32(t)
        # per-sequence scatter at `slot` via one-hot mix (B,T)
        oh = jax.nn.one_hot(slot, t, dtype=cache["k"].dtype)
        k_upd = cache["k"] * (1 - oh)[:, :, None, None] + \
            oh[:, :, None, None] * k.astype(cache["k"].dtype)
        v_upd = cache["v"] * (1 - oh)[:, :, None, None] + \
            oh[:, :, None, None] * v.astype(cache["v"].dtype)
        lengths = jnp.minimum(pos + 1, t)
        out = decode_attention(q, k_upd, v_upd, lengths,
                               window=None)  # ring slots are all valid-masked
        y = out.reshape(b, 1, n_heads * hd) @ p["wo"]
        new_cache = {"k": k_upd, "v": v_upd, "len": pos + 1}
        return y, new_cache

    # plain materializes S^2 scores: fine to 2k; beyond that the chunked
    # (flash-semantics) path bounds memory to O(S x chunk) per head
    if window is not None:
        out = plain_attention(q, k, v, causal=True, window=window) \
            if x.shape[1] <= 2048 else \
            chunked_attention(q, k, v, causal=True, window=window,
                              chunk=cfg.attn_chunk)
    elif x.shape[1] <= 2048:
        out = plain_attention(q, k, v, causal=cfg.causal)
    else:
        out = chunked_attention(q, k, v, causal=cfg.causal,
                                chunk=cfg.attn_chunk)
    out = shard(out, "batch", "seq", "heads", None)
    y = out.reshape(*x.shape[:2], n_heads * hd) @ p["wo"]
    new_cache = None
    if mode == "prefill" and not cfg.causal:
        pass  # encoder layers carry no cache
    elif mode == "prefill":
        full = cache_len if cache_len is not None else cfg.max_seq
        t = min(window, full) if window else full
        s = x.shape[1]
        keep = min(s, t)
        kk = jnp.zeros((x.shape[0], t, n_kv, hd), DTYPE).at[:, :keep].set(
            k[:, -keep:].astype(DTYPE))
        vv = jnp.zeros((x.shape[0], t, n_kv, hd), DTYPE).at[:, :keep].set(
            v[:, -keep:].astype(DTYPE))
        if s > t:
            # ring alignment: token p must live in slot p % t
            kk = jnp.roll(kk, shift=s % t, axis=1)
            vv = jnp.roll(vv, shift=s % t, axis=1)
        new_cache = {"k": kk, "v": vv,
                     "len": jnp.full((x.shape[0],), s, jnp.int32)}
    return shard(y, "batch", "seq", "embed_act"), new_cache


# ---------------------------------------------------------------------------
# Cross attention (enc-dec decoders, e.g. whisper)
# ---------------------------------------------------------------------------

def cross_attention_block(p, x, enc_kv, cfg):
    """x: decoder states (B,S,D); enc_kv: dict(k, v) precomputed from the
    encoder output — (B, T_enc, KV, D).  Non-causal over encoder positions."""
    n_heads, n_kv, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    b, s, _ = x.shape
    q = (x @ p["wq"]).reshape(b, s, n_heads, hd)
    out = plain_attention(q, enc_kv["k"], enc_kv["v"], causal=False)
    y = out.reshape(b, s, n_heads * hd) @ p["wo"]
    return shard(y, "batch", "seq", "embed_act"), None


def encode_cross_kv(p, enc_out, cfg):
    """Project encoder output once into this layer's cross K/V."""
    b, t, _ = enc_out.shape
    k = (enc_out @ p["wk"]).reshape(b, t, cfg.n_kv, cfg.head_dim)
    v = (enc_out @ p["wv"]).reshape(b, t, cfg.n_kv, cfg.head_dim)
    return {"k": k, "v": v}
