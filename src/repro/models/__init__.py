"""Model zoo: layers, attention, MoE, RG-LRU, SSD, transformer assembly."""
