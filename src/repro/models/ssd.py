"""Mamba-2 SSD (state-space duality) block.

Chunked SSD algorithm (Dao & Gu 2024, "minimal ssd"): the sequence is split
into chunks of length Q; within a chunk the output is a masked quadratic
(attention-like) term, across chunks a small recurrent state (H, P, N) is
passed through a cumulative-decay scan.  This keeps everything dense matmuls
(MXU-friendly) with O(S*Q + S*N) work instead of a length-S sequential
recurrence — the hardware adaptation the SSD paper itself argues for, and the
reference semantics for the Pallas kernel `repro.kernels.ssd_chunk`.

Block structure (simplified mamba2): in_proj -> [z | x | B | C | dt],
depthwise causal conv on (x,B,C), SSD core, gated RMSNorm, out_proj.
Decode is the O(1) recurrence h = a h + dt*x (x) B; y = C . h.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import shard

from .layers import DTYPE, _normal, rmsnorm, init_rmsnorm

CONV_W = 4


def init_ssd(key, d: int, *, n_heads: int, head_dim: int, state: int):
    ks = jax.random.split(key, 5)
    d_in = n_heads * head_dim
    return {
        "in_proj": _normal(ks[0], (d, 2 * d_in + 2 * state + n_heads), d ** -0.5),
        "conv": _normal(ks[1], (CONV_W, d_in + 2 * state), 0.1),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm": init_rmsnorm(d_in),
        "out_proj": _normal(ks[2], (d_in, d), d_in ** -0.5),
    }


def ssd_axes():
    return {"in_proj": ("embed", "mlp"), "conv": (None, None),
            "A_log": (None,), "dt_bias": (None,),
            "norm": {"scale": (None,)}, "out_proj": ("mlp", "embed")}


def _split(p, x, n_heads, head_dim, state):
    d_in = n_heads * head_dim
    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :d_in]
    xs = zxbcdt[..., d_in:2 * d_in]
    bc = zxbcdt[..., 2 * d_in:2 * d_in + 2 * state]
    dt = zxbcdt[..., 2 * d_in + 2 * state:]
    return z, xs, bc, dt


def _conv(x, w, cache=None):
    if cache is None:
        pad = jnp.zeros((x.shape[0], CONV_W - 1, x.shape[2]), x.dtype)
    else:
        pad = cache.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(CONV_W))
    return jax.nn.silu(out.astype(jnp.float32)).astype(x.dtype), xp[:, -(CONV_W - 1):]


def _segsum(loga):
    """(..., Q) -> (..., Q, Q) lower-tri cumulative sums (log decays)."""
    q = loga.shape[-1]
    cs = jnp.cumsum(loga, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(xs, dt, A, B, C, chunk: int = 128):
    """Minimal-SSD over chunks.

    xs: (b,s,h,p)  dt: (b,s,h)  A: (h,)  B,C: (b,s,n)  ->  y: (b,s,h,p)
    """
    b, s, h, p = xs.shape
    n = B.shape[-1]
    q = min(chunk, s)
    nc = (s + q - 1) // q
    pad = nc * q - s
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    xs_c = xs.reshape(b, nc, q, h, p)
    dt_c = dt.reshape(b, nc, q, h).astype(jnp.float32)
    B_c = B.reshape(b, nc, q, n).astype(jnp.float32)
    C_c = C.reshape(b, nc, q, n).astype(jnp.float32)

    logA = -jnp.exp(A)[None, None, None, :] * dt_c          # (b,c,q,h) < 0
    logA_h = logA.transpose(0, 1, 3, 2)                      # (b,c,h,q)
    xdt = xs_c.astype(jnp.float32) * dt_c[..., None]

    # intra-chunk (diagonal) term
    L = jnp.exp(_segsum(logA_h))                             # (b,c,h,q,q)
    scores = jnp.einsum("bcin,bcjn,bchij->bchij", C_c, B_c, L)
    y_diag = jnp.einsum("bchij,bcjhp->bcihp", scores, xdt)

    # chunk states and inter-chunk scan
    decay_to_end = jnp.exp(cs_last := (jnp.cumsum(logA_h, axis=-1)))
    decay_rest = jnp.exp(cs_last[..., -1:] - cs_last)        # (b,c,h,q)
    states = jnp.einsum("bcjn,bchj,bcjhp->bchpn", B_c, decay_rest, xdt)
    chunk_decay = jnp.exp(cs_last[..., -1])                  # (b,c,h)

    def scan_fn(carry, xc):
        st, dec = xc
        new = carry * dec[..., None, None] + st
        return new, carry  # emit state *before* this chunk

    init = jnp.zeros((b, h, p, n), jnp.float32)
    _, prev_states = jax.lax.scan(
        scan_fn, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)       # (b,c,h,p,n)

    # inter-chunk (off-diagonal) term
    decay_in = jnp.exp(cs_last)                              # (b,c,h,q)
    y_off = jnp.einsum("bcin,bchi,bchpn->bcihp", C_c, decay_in, prev_states)

    y = (y_diag + y_off).reshape(b, nc * q, h, p)[:, :s]
    return y.astype(xs.dtype)


def ssd_block(p, x, cfg, *, mode, cache=None):
    """cache: dict(conv (B,W-1,d_conv), h (B,H,P,N))."""
    nh, hd, st = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z, xs, bc, dt = _split(p, x, nh, hd, st)
    conv_in = jnp.concatenate([xs, bc], axis=-1)
    A = jnp.exp(p["A_log"])

    if mode == "decode":
        conv_out, conv_state = _conv(conv_in, p["conv"], cache["conv"])
        xs_c = conv_out[..., :nh * hd].reshape(x.shape[0], 1, nh, hd)
        B = conv_out[..., nh * hd:nh * hd + st].astype(jnp.float32)
        C = conv_out[..., nh * hd + st:].astype(jnp.float32)
        dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])
        a = jnp.exp(-A[None] * dtv)                          # (B,H)
        xdt = xs_c[:, 0].astype(jnp.float32) * dtv[..., None]
        h = cache["h"] * a[..., None, None] + \
            jnp.einsum("bhp,bn->bhpn", xdt, B[:, 0])
        y = jnp.einsum("bhpn,bn->bhp", h, C[:, 0])
        y = y.reshape(x.shape[0], 1, nh * hd).astype(DTYPE)
        y = rmsnorm(p["norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(DTYPE))
        return y @ p["out_proj"], {"conv": conv_state, "h": h}

    conv_out, conv_state = _conv(conv_in, p["conv"])
    xs_c = conv_out[..., :nh * hd].reshape(*x.shape[:2], nh, hd)
    B = conv_out[..., nh * hd:nh * hd + st]
    C = conv_out[..., nh * hd + st:]
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    y = ssd_chunked(xs_c, dtv, p["A_log"], B, C, chunk=cfg.ssd_chunk)
    y = y.reshape(*x.shape[:2], nh * hd)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(DTYPE))
    y = shard(y @ p["out_proj"], "batch", "seq", "embed_act")
    new_cache = None
    if mode == "prefill":
        # final state: recompute last-chunk state cheaply via decode-style
        # accumulation is O(S); reuse the chunked states by one extra scan —
        # here we simply run the last `CONV_W`-aware step on the final token
        # for state handoff fidelity at block granularity.
        b = x.shape[0]
        new_cache = {"conv": conv_state.astype(DTYPE),
                     "h": _final_state(xs_c, dtv, p["A_log"], B, C,
                                       chunk=cfg.ssd_chunk)}
    return y, new_cache


def _final_state(xs, dt, A_log, B, C, chunk: int = 128):
    """Exact final recurrent state h_S (B,H,P,N) via the same chunk scan."""
    b, s, h, p = xs.shape
    n = B.shape[-1]
    q = min(chunk, s)
    nc = (s + q - 1) // q
    pad = nc * q - s
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
    xs_c = xs.reshape(b, nc, q, h, p).astype(jnp.float32)
    dt_c = dt.reshape(b, nc, q, h).astype(jnp.float32)
    B_c = B.reshape(b, nc, q, n).astype(jnp.float32)
    logA = (-jnp.exp(A_log)[None, None, None, :] * dt_c).transpose(0, 1, 3, 2)
    cs = jnp.cumsum(logA, axis=-1)
    decay_rest = jnp.exp(cs[..., -1:] - cs)
    states = jnp.einsum("bcjn,bchj,bcjhp->bchpn", B_c, decay_rest,
                        xs_c * dt_c[..., None])
    chunk_decay = jnp.exp(cs[..., -1])

    def scan_fn(carry, xc):
        st, dec = xc
        return carry * dec[..., None, None] + st, None

    final, _ = jax.lax.scan(
        scan_fn, jnp.zeros((b, h, p, n), jnp.float32),
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    return final


def init_ssd_cache(b: int, cfg):
    nh, hd, st = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    return {"conv": jnp.zeros((b, CONV_W - 1, nh * hd + 2 * st), DTYPE),
            "h": jnp.zeros((b, nh, hd, st), jnp.float32)}
