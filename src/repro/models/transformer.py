"""Model assembly: block stacks, layer scan + remat, train/prefill/decode.

The stack is organized in *periods* (one repetition of cfg.pattern); periods
are structurally identical, so their parameters stack on a leading 'stage'
axis and the whole stack runs under `lax.scan(jax.checkpoint(period_fn))` —
compact HLO (512-device lowering in seconds) and O(1-period) activation
memory.  Heterogeneous families (recurrentgemma's rglru/rglru/attn_local
pattern) are one period of three blocks.

Entry points:
  init_params / param_axes          parameter pytree + logical sharding axes
  loss_fn(params, batch, cfg)       next-token CE (+ MoE aux)
  prefill(params, tokens, cfg)      logits + cache
  decode_step(params, cache, tok)   one-token serve step with KV/state cache
  init_cache(cfg, batch, max_len)   cache pytree (for dry-run specs too)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.parallel.sharding import shard

from . import attention as A
from . import moe as MOE
from . import rglru as RG
from . import ssd as SSD
from .layers import (DTYPE, embed, embed_axes, init_embed, init_mlp,
                     init_rmsnorm, mlp, mlp_axes, rmsnorm, rmsnorm_axes,
                     softmax_xent, unembed)

# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_block(key, kind: str, cfg):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {"norm1": init_rmsnorm(cfg.d_model)}
    if kind in ("attn", "attn_local", "attn_moe", "cross"):
        p["attn"] = A.init_attn(k1, cfg.d_model, cfg.n_heads, cfg.n_kv,
                                cfg.head_dim)
        p["norm2"] = init_rmsnorm(cfg.d_model)
        if kind == "attn_moe":
            p["moe"] = MOE.init_moe(k2, cfg.d_model, cfg.d_ff,
                                    cfg.moe.n_experts)
        else:
            p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff)
        if kind == "cross":
            p["xattn"] = A.init_attn(k3, cfg.d_model, cfg.n_heads, cfg.n_kv,
                                     cfg.head_dim)
            p["norm3"] = init_rmsnorm(cfg.d_model)
    elif kind == "rglru":
        p["rglru"] = RG.init_rglru(k1, cfg.d_model)
        p["norm2"] = init_rmsnorm(cfg.d_model)
        p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff)
    elif kind == "ssd":
        p["ssd"] = SSD.init_ssd(k1, cfg.d_model, n_heads=cfg.ssm_heads,
                                head_dim=cfg.ssm_head_dim, state=cfg.ssm_state)
    else:  # pragma: no cover
        raise ValueError(kind)
    return p


def _block_axes(kind: str, cfg):
    ax = {"norm1": rmsnorm_axes()}
    if kind in ("attn", "attn_local", "attn_moe", "cross"):
        ax["attn"] = A.attn_axes()
        ax["norm2"] = rmsnorm_axes()
        if kind == "attn_moe":
            es = "expert" if cfg.moe.n_experts % 16 == 0 else "ffn"
            ax["moe"] = MOE.moe_axes(es)
        else:
            ax["mlp"] = mlp_axes()
        if kind == "cross":
            ax["xattn"] = A.attn_axes()
            ax["norm3"] = rmsnorm_axes()
    elif kind == "rglru":
        ax["rglru"] = RG.rglru_axes()
        ax["norm2"] = rmsnorm_axes()
        ax["mlp"] = mlp_axes()
    elif kind == "ssd":
        ax["ssd"] = SSD.ssd_axes()
    return ax


def tail_pattern(cfg):
    """Blocks left over when n_layers is not a multiple of the period."""
    return cfg.pattern[: cfg.n_layers % len(cfg.pattern)]


def init_params(cfg, key):
    keys = jax.random.split(key, cfg.n_periods + 3 + max(cfg.enc_layers, 1))
    # one period of blocks, stacked over stages
    def one_period(k):
        ks = jax.random.split(k, len(cfg.pattern))
        return {f"b{i}_{kind}": _init_block(ks[i], kind, cfg)
                for i, kind in enumerate(cfg.pattern)}

    stages = jax.vmap(one_period)(keys[:cfg.n_periods]) if cfg.n_periods > 1 \
        else jax.tree.map(lambda x: x[None], one_period(keys[0]))
    params = {
        "embed": init_embed(keys[-1], cfg.vocab, cfg.d_model),
        "stages": stages,
        "final_norm": init_rmsnorm(cfg.d_model),
    }
    tail = tail_pattern(cfg)
    if tail:
        tk = jax.random.split(keys[-3], len(tail))
        params["tail"] = {f"t{i}_{kind}": _init_block(tk[i], kind, cfg)
                          for i, kind in enumerate(tail)}
    if cfg.enc_layers:
        ek = jax.random.split(keys[-2], cfg.enc_layers)
        params["encoder"] = jax.vmap(
            lambda k: _init_block(k, "attn", cfg))(ek)
        params["enc_norm"] = init_rmsnorm(cfg.d_model)
    return params


def param_axes(cfg):
    def stage_axes():
        out = {}
        for i, kind in enumerate(cfg.pattern):
            blk = _block_axes(kind, cfg)
            out[f"b{i}_{kind}"] = jax.tree.map(
                lambda t: ("stage",) + t, blk,
                is_leaf=lambda x: isinstance(x, tuple))
        return out

    axes = {
        "embed": embed_axes(),
        "stages": stage_axes(),
        "final_norm": rmsnorm_axes(),
    }
    tail = tail_pattern(cfg)
    if tail:
        axes["tail"] = {f"t{i}_{kind}": _block_axes(kind, cfg)
                        for i, kind in enumerate(tail)}
    if cfg.enc_layers:
        axes["encoder"] = jax.tree.map(
            lambda t: ("stage",) + t, _block_axes("attn", cfg),
            is_leaf=lambda x: isinstance(x, tuple))
        axes["enc_norm"] = rmsnorm_axes()
    return axes


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------


def _apply_block(p, kind, x, positions, cfg, *, mode, cache=None, enc_kv=None,
                 cache_len=None):
    aux = jnp.float32(0)
    h = rmsnorm(p["norm1"], x)
    new_cache = {}
    if kind in ("attn", "attn_local", "attn_moe", "cross"):
        window = cfg.window if kind == "attn_local" else None
        a_out, a_cache = A.attention_block(
            p["attn"], h, positions, cfg, mode=mode,
            cache=None if cache is None else cache.get("attn"), window=window,
            cache_len=cache_len)
        x = x + a_out
        if a_cache is not None:
            new_cache["attn"] = a_cache
        if kind == "cross":
            hx = rmsnorm(p["norm3"], x)
            if mode in ("train",):
                kv = enc_kv
            else:
                kv = cache.get("xattn") if (cache and "xattn" in cache) else enc_kv
                if mode == "prefill":
                    new_cache["xattn"] = kv
                elif cache and "xattn" in cache:
                    new_cache["xattn"] = kv
            xa_out, _ = A.cross_attention_block(p["xattn"], hx, kv, cfg)
            x = x + xa_out
        h2 = rmsnorm(p["norm2"], x)
        if kind == "attn_moe":
            m_out, aux = MOE.moe_mlp(p["moe"], h2, top_k=cfg.moe.top_k,
                                     capacity_factor=cfg.moe.capacity_factor,
                                     group_size=cfg.moe_group)
        else:
            m_out = mlp(p["mlp"], h2)
        x = x + m_out
    elif kind == "rglru":
        r_out, r_cache = RG.rglru_block(
            p["rglru"], h, cfg, mode=mode,
            cache=None if cache is None else cache.get("rglru"))
        x = x + r_out
        if r_cache is not None:
            new_cache["rglru"] = r_cache
        h2 = rmsnorm(p["norm2"], x)
        x = x + mlp(p["mlp"], h2)
    elif kind == "ssd":
        s_out, s_cache = SSD.ssd_block(
            p["ssd"], h, cfg, mode=mode,
            cache=None if cache is None else cache.get("ssd"))
        x = x + s_out
        if s_cache is not None:
            new_cache["ssd"] = s_cache
    return x, new_cache, aux


def _period_fn(stage_params, x, positions, cfg, *, mode, stage_cache=None,
               enc_kv=None, cache_len=None):
    new_caches = {}
    aux_total = jnp.float32(0)
    for i, kind in enumerate(cfg.pattern):
        key = f"b{i}_{kind}"
        cache_i = None if stage_cache is None else stage_cache.get(key)
        x, nc, aux = _apply_block(stage_params[key], kind, x, positions, cfg,
                                  mode=mode, cache=cache_i, enc_kv=enc_kv,
                                  cache_len=cache_len)
        if nc:
            new_caches[key] = nc
        aux_total = aux_total + aux
    return x, new_caches, aux_total


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------


def _run_encoder(params, cfg, frontend_embeds):
    """Whisper-style encoder over precomputed (stub) frame embeddings."""
    x = frontend_embeds.astype(DTYPE)
    pos = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32)[None],
                           x.shape[:2])

    def enc_layer(x, lp):
        h = rmsnorm(lp["norm1"], x)
        a_out = A.plain_attention(
            *(A._project(lp["attn"], h, cfg.n_heads, cfg.n_kv, cfg.head_dim,
                         pos, cfg.rope_theta)), causal=False)
        x = x + a_out.reshape(*x.shape[:2], -1) @ lp["attn"]["wo"]
        h2 = rmsnorm(lp["norm2"], x)
        return x + mlp(lp["mlp"], h2), None

    x, _ = jax.lax.scan(lambda c, lp: enc_layer(c, lp), x, params["encoder"])
    return rmsnorm(params["enc_norm"], x)


def forward(params, cfg, tokens, *, mode="train", frontend_embeds=None,
            positions=None):
    """tokens: (B,S) int32.  Returns (logits, caches, aux)."""
    x = embed(params["embed"], tokens)
    if cfg.vision_patches and frontend_embeds is not None:
        # VLM stub: patch embeddings replace the first `vision_patches` slots
        x = jnp.concatenate(
            [frontend_embeds.astype(DTYPE), x[:, cfg.vision_patches:]], axis=1)
    if positions is None:
        positions = jnp.broadcast_to(
            jnp.arange(tokens.shape[1], dtype=jnp.int32)[None], tokens.shape)
    enc_out = None
    if cfg.enc_layers:
        enc_out = _run_encoder(params, cfg, frontend_embeds)

    def period(x_carry, stage_params):
        ekv = None
        if cfg.enc_layers:
            # project encoder output into this stage's cross-KV
            key = next(k for k in stage_params if k.endswith("cross"))
            ekv = A.encode_cross_kv(stage_params[key]["xattn"], enc_out, cfg)
        x_new, _, aux = _period_fn(stage_params, x_carry, positions, cfg,
                                   mode=mode, enc_kv=ekv)
        return x_new, aux

    period_remat = jax.checkpoint(period)
    x, auxs = jax.lax.scan(lambda c, sp: period_remat(c, sp), x,
                           params["stages"])
    aux_total = jnp.sum(auxs)
    for i, kind in enumerate(tail_pattern(cfg)):
        x, _, aux = _apply_block(params["tail"][f"t{i}_{kind}"], kind, x,
                                 positions, cfg, mode=mode)
        aux_total = aux_total + aux
    x = rmsnorm(params["final_norm"], x)
    logits = unembed(params["embed"], x)
    return logits, None, aux_total


def loss_fn(params, cfg, batch):
    logits, _, aux = forward(params, cfg, batch["tokens"], mode="train",
                             frontend_embeds=batch.get("frontend_embeds"))
    loss = softmax_xent(logits[:, :-1], batch["labels"][:, 1:],
                        batch.get("mask"))
    return loss + 0.01 * aux, {"xent": loss, "aux": aux}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def prefill(params, cfg, tokens, max_len: int, *, frontend_embeds=None):
    """Process the prompt, returning (last-token logits, cache).

    The period scan emits each stage's cache as a ys output, giving the same
    stage-stacked cache layout `init_cache` declares.
    """
    x = embed(params["embed"], tokens)
    if cfg.vision_patches and frontend_embeds is not None:
        x = jnp.concatenate(
            [frontend_embeds.astype(DTYPE), x[:, cfg.vision_patches:]], axis=1)
    positions = jnp.broadcast_to(
        jnp.arange(tokens.shape[1], dtype=jnp.int32)[None], tokens.shape)
    enc_out = None
    if cfg.enc_layers:
        enc_out = _run_encoder(params, cfg, frontend_embeds)

    def period(x_carry, stage_params):
        ekv = None
        if cfg.enc_layers:
            key = next(k for k in stage_params if k.endswith("cross"))
            ekv = A.encode_cross_kv(stage_params[key]["xattn"], enc_out, cfg)
        x_new, caches, _ = _period_fn(stage_params, x_carry, positions, cfg,
                                      mode="prefill", enc_kv=ekv,
                                      cache_len=max_len)
        return x_new, caches

    x, stage_caches = jax.lax.scan(period, x, params["stages"])
    tail_caches = {}
    for i, kind in enumerate(tail_pattern(cfg)):
        key = f"t{i}_{kind}"
        x, nc, _ = _apply_block(params["tail"][key], kind, x, positions, cfg,
                                mode="prefill", cache_len=max_len)
        tail_caches[key] = nc
    x = rmsnorm(params["final_norm"], x)
    logits = unembed(params["embed"], x[:, -1:])
    caches = {"stages": stage_caches}
    if tail_caches:
        caches["tail"] = tail_caches
    return logits, caches


def init_cache(cfg, batch: int, max_len: int):
    """Cache pytree matching the stage scan layout (leading stage dim)."""
    def cache_for(kind, key_prefix, i):
        kk = f"{key_prefix}{i}_{kind}"
        if kind in ("attn", "attn_moe", "cross"):
            t = min(max_len, cfg.max_seq)
            c = {"attn": {
                "k": jnp.zeros((batch, t, cfg.n_kv, cfg.head_dim), DTYPE),
                "v": jnp.zeros((batch, t, cfg.n_kv, cfg.head_dim), DTYPE),
                "len": jnp.zeros((batch,), jnp.int32)}}
            if kind == "cross":
                c["xattn"] = {
                    "k": jnp.zeros((batch, cfg.enc_frames, cfg.n_kv,
                                    cfg.head_dim), DTYPE),
                    "v": jnp.zeros((batch, cfg.enc_frames, cfg.n_kv,
                                    cfg.head_dim), DTYPE)}
            return kk, c
        if kind == "attn_local":
            t = min(max_len, cfg.window)
            return kk, {"attn": {
                "k": jnp.zeros((batch, t, cfg.n_kv, cfg.head_dim), DTYPE),
                "v": jnp.zeros((batch, t, cfg.n_kv, cfg.head_dim), DTYPE),
                "len": jnp.zeros((batch,), jnp.int32)}}
        if kind == "rglru":
            return kk, {"rglru": RG.init_rglru_cache(batch, cfg.d_model)}
        if kind == "ssd":
            return kk, {"ssd": SSD.init_ssd_cache(batch, cfg)}
        raise ValueError(kind)

    one = dict(cache_for(kind, "b", i) for i, kind in enumerate(cfg.pattern))
    caches = {"stages": jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_periods,) + x.shape), one)}
    tail = tail_pattern(cfg)
    if tail:
        caches["tail"] = dict(cache_for(kind, "t", i)
                              for i, kind in enumerate(tail))
    return caches


def cache_axes(cfg):
    """Logical sharding axes for the cache pytree (mirrors init_cache).

    KV heads shard over the model axis when divisible (pruned otherwise —
    MQA caches fall back to batch sharding); SSD/RG-LRU states shard their
    head/feature dims.
    """
    def axes_for(kind):
        if kind in ("attn", "attn_moe", "attn_local", "cross"):
            c = {"attn": {"k": ("kv_batch", "kv_seq", "kv_heads", None),
                          "v": ("kv_batch", "kv_seq", "kv_heads", None),
                          "len": ("kv_batch",)}}
            if kind == "cross":
                c["xattn"] = {"k": ("kv_batch", None, "kv_heads", None),
                              "v": ("kv_batch", None, "kv_heads", None)}
            return c
        if kind == "rglru":
            return {"rglru": {"conv": ("kv_batch", None, "mlp"),
                              "h": ("kv_batch", "mlp")}}
        if kind == "ssd":
            return {"ssd": {"conv": ("kv_batch", None, None),
                            "h": ("kv_batch", "heads", None, None)}}
        raise ValueError(kind)

    stage = {f"b{i}_{kind}": jax.tree.map(
        lambda t: ("stage",) + t, axes_for(kind),
        is_leaf=lambda x: isinstance(x, tuple))
        for i, kind in enumerate(cfg.pattern)}
    out = {"stages": stage}
    tail = tail_pattern(cfg)
    if tail:
        out["tail"] = {f"t{i}_{kind}": axes_for(kind)
                       for i, kind in enumerate(tail)}
    return out


def decode_step(params, cfg, cache, tokens, positions):
    """One serve step.  tokens: (B,1); positions: (B,1) absolute positions.

    Returns (logits (B,1,V), new_cache).  The stage scan threads the cache.
    """
    x = embed(params["embed"], tokens)

    def period(x_carry, scan_in):
        stage_params, stage_cache = scan_in
        x_new, new_cache, _ = _period_fn(stage_params, x_carry, positions,
                                         cfg, mode="decode",
                                         stage_cache=stage_cache,
                                         enc_kv=None)
        return x_new, new_cache

    x, new_stage_caches = jax.lax.scan(period, x,
                                       (params["stages"], cache["stages"]))
    new_caches = {"stages": new_stage_caches}
    if "tail" in cache:
        new_tail = {}
        for i, kind in enumerate(tail_pattern(cfg)):
            key = f"t{i}_{kind}"
            x, nc, _ = _apply_block(params["tail"][key], kind, x, positions,
                                    cfg, mode="decode", cache=cache["tail"][key])
            new_tail[key] = nc
        new_caches["tail"] = new_tail
    x = rmsnorm(params["final_norm"], x)
    logits = unembed(params["embed"], x)
    return logits, new_caches
