"""Model zoo facade: step functions + abstract input specs per (arch, shape).

`input_specs` returns ShapeDtypeStruct stand-ins for every model input —
weak-type-correct and shardable, with no device allocation — which is what the
multi-pod dry-run lowers against.  Modality frontends are stubs by contract:
whisper gets precomputed frame embeddings, phi-3-vision gets projected patch
embeddings.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import transformer as TF
from repro.models.layers import DTYPE


def train_step_fn(cfg: ArchConfig):
    def loss(params, batch):
        return TF.loss_fn(params, cfg, batch)
    return loss


def init_params(cfg: ArchConfig, key):
    return TF.init_params(cfg, key)


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(lambda k: TF.init_params(cfg, k), jax.random.key(0))


def abstract_cache(cfg: ArchConfig, batch: int, max_len: int):
    return jax.eval_shape(lambda: TF.init_cache(cfg, batch, max_len))


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """Abstract inputs for the step function selected by shape.kind."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    def sds(shp, dt):
        return jax.ShapeDtypeStruct(shp, dt)

    extra = {}
    if cfg.vision_patches:
        extra["frontend_embeds"] = sds((b, cfg.vision_patches, cfg.d_model),
                                       DTYPE)
    if cfg.enc_layers:
        extra["frontend_embeds"] = sds((b, cfg.enc_frames, cfg.d_model), DTYPE)

    if shape.kind == "train":
        batch = {"tokens": sds((b, s), i32), "labels": sds((b, s), i32)}
        batch.update(extra)
        return {"batch": batch}
    if shape.kind == "prefill":
        out = {"tokens": sds((b, s), i32), "max_len": s}
        out.update(extra)
        return out
    # decode / long_decode: one new token against a seq_len-deep cache
    cache = abstract_cache(cfg, b, s)
    return {
        "cache": cache,
        "tokens": sds((b, 1), i32),
        "positions": sds((b, 1), i32),
    }


def step_fn(cfg: ArchConfig, kind: str):
    """The jit-able step for a shape kind (dry-run + runtime entry point)."""
    if kind == "train":
        def train_loss(params, batch):
            return TF.loss_fn(params, cfg, batch)
        return train_loss
    if kind == "prefill":
        def prefill(params, tokens, max_len, frontend_embeds=None):
            return TF.prefill(params, cfg, tokens, max_len,
                              frontend_embeds=frontend_embeds)
        return prefill
    if kind in ("decode", "long_decode"):
        def decode(params, cache, tokens, positions):
            return TF.decode_step(params, cfg, cache, tokens, positions)
        return decode
    raise ValueError(kind)
