"""Shared model layers: norms, embeddings, RoPE, gated MLPs.

Conventions:
  * params are plain pytrees (dicts of jnp arrays); every init_* function has
    a matching *_axes function returning the logical sharding axes tuple per
    leaf (consumed by `parallel.sharding.param_specs`);
  * compute dtype bf16, accumulation/normalization f32 — explicit everywhere
    (repro.core enables x64 globally; nothing here may rely on default dtypes);
  * activations are annotated with logical axes via `sharding.shard`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import shard

DTYPE = jnp.bfloat16


def _normal(key, shape, scale, dtype=DTYPE):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int):
    return {"scale": jnp.zeros((d,), jnp.float32)}


def rmsnorm_axes():
    return {"scale": ("embed_act",)}


def rmsnorm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * (1.0 + p["scale"])
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding (tied LM head)
# ---------------------------------------------------------------------------

def init_embed(key, vocab: int, d: int):
    return {"tok": _normal(key, (vocab, d), d ** -0.5)}


def embed_axes():
    return {"tok": ("vocab", "embed")}


def embed(p, tokens):
    x = jnp.take(p["tok"], tokens, axis=0)
    return shard(x.astype(DTYPE), "batch", "seq", "embed_act")


def unembed(p, x):
    logits = jnp.einsum("bsd,vd->bsv", x, p["tok"])
    return shard(logits, "batch", "seq", "vocab")


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float = 10_000.0):
    """x: (B, S, H, D) with D even; positions: (B, S) int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, f: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": _normal(k1, (d, f), d ** -0.5),
        "wi_up": _normal(k2, (d, f), d ** -0.5),
        "wo": _normal(k3, (f, d), f ** -0.5),
    }


def mlp_axes():
    return {"wi_gate": ("embed", "mlp"), "wi_up": ("embed", "mlp"),
            "wo": ("mlp", "embed")}


def mlp(p, x, act=jax.nn.silu):
    h = act(x @ p["wi_gate"]) * (x @ p["wi_up"])
    h = shard(h, "batch", "seq", "mlp")
    return shard(h @ p["wo"], "batch", "seq", "embed_act")


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def softmax_xent(logits, labels, mask=None):
    """Mean next-token cross entropy; logits f32 accumulation."""
    lf = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        m = mask.astype(jnp.float32)
        return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(nll)
