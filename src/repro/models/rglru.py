"""RG-LRU recurrence block (RecurrentGemma / Griffin).

The recurrent sub-block: linear projections, a short causal temporal conv,
and the Real-Gated Linear Recurrent Unit

    r_t = sigmoid(W_r x_t);  i_t = sigmoid(W_i x_t)
    a_t = a^(c * r_t)            with a = sigmoid(Lambda), c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The recurrence h_t = a_t h_{t-1} + b_t is affine and associative, so training
and prefill run as a parallel `lax.associative_scan` over the sequence — the
TPU-friendly formulation (the Pallas kernel `repro.kernels.rglru_scan` is the
blocked fast path; this module is its oracle).  Decode is the O(1) single
step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import shard

from .layers import DTYPE, _normal

C_EXP = 8.0
CONV_W = 4


def init_rglru(key, d: int):
    ks = jax.random.split(key, 6)
    return {
        "w_x": _normal(ks[0], (d, d), d ** -0.5),
        "w_gate": _normal(ks[1], (d, d), d ** -0.5),
        "conv": _normal(ks[2], (CONV_W, d), 0.1),
        "w_r": _normal(ks[3], (d, d), d ** -0.5, jnp.float32),
        "w_i": _normal(ks[4], (d, d), d ** -0.5, jnp.float32),
        # Lambda init so a = sigmoid(L) in ~(0.9, 0.999)
        "lam": jnp.linspace(2.2, 6.9, d).astype(jnp.float32),
        "w_o": _normal(ks[5], (d, d), d ** -0.5),
    }


def rglru_axes():
    return {"w_x": ("embed", "mlp"), "w_gate": ("embed", "mlp"),
            "conv": (None, "mlp"), "w_r": ("embed", "mlp"),
            "w_i": ("embed", "mlp"), "lam": ("mlp",),
            "w_o": ("mlp", "embed")}


def _causal_conv(x, w, state=None):
    """x: (B,S,D); w: (W,D) depthwise causal conv.  state: (B,W-1,D)."""
    if state is None:
        pad = jnp.zeros((x.shape[0], CONV_W - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(CONV_W))
    new_state = xp[:, -(CONV_W - 1):]
    return out, new_state


def _gates(p, u):
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["w_r"])
    i = jax.nn.sigmoid(uf @ p["w_i"])
    log_a = C_EXP * r * jax.nn.log_sigmoid(p["lam"])   # log a_t  (<0)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-8)) * (i * uf)
    return a, b


def rglru_scan(p, u):
    """Parallel associative scan over S.  u: (B,S,D) -> h: (B,S,D) f32."""
    a, b = _gates(p, u)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_block(p, x, cfg, *, mode, cache=None):
    """Full recurrent sub-block.  cache: dict(conv (B,W-1,D), h (B,D))."""
    u = x @ p["w_x"]
    gate = jax.nn.gelu((x @ p["w_gate"]).astype(jnp.float32)).astype(DTYPE)
    if mode == "decode":
        u_c, conv_state = _causal_conv(u, p["conv"], cache["conv"])
        a, b = _gates(p, u_c)
        h = a[:, 0] * cache["h"] + b[:, 0]                  # (B, D)
        y = (h[:, None] * gate.astype(jnp.float32)).astype(DTYPE) @ p["w_o"]
        return y, {"conv": conv_state, "h": h}
    u_c, conv_state = _causal_conv(u, p["conv"])
    h = rglru_scan(p, u_c)
    h = shard(h, "batch", "seq", "mlp")
    y = (h * gate.astype(jnp.float32)).astype(DTYPE) @ p["w_o"]
    new_cache = None
    if mode == "prefill":
        new_cache = {"conv": conv_state.astype(DTYPE), "h": h[:, -1]}
    return shard(y, "batch", "seq", "embed_act"), new_cache


def init_rglru_cache(b: int, d: int):
    return {"conv": jnp.zeros((b, CONV_W - 1, d), DTYPE),
            "h": jnp.zeros((b, d), jnp.float32)}
