"""Mixture-of-Experts MLP: token-choice top-k with capacity dispatch.

Dense one-hot dispatch/combine einsums (T5X/MaxText "dropping" MoE): fully
static shapes (dry-run friendly), expert-parallel over the 'model' mesh axis
when n_experts divides it, otherwise experts replicated with tensor-parallel
expert FFN (grok-1: 8 experts on a 16-way model axis — see
DESIGN.md §Arch-applicability).

The per-device dispatch tensor is (tokens/device, E, C) in bf16 under remat —
transient, sized by capacity C = ceil(top_k * tokens_per_group / E * cf).
Aux load-balance loss follows Switch/ST-MoE.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import shard

from .layers import DTYPE, _normal


def init_moe(key, d: int, f: int, n_experts: int):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": _normal(k1, (d, n_experts), d ** -0.5, jnp.float32),
        "wi_gate": _normal(k2, (n_experts, d, f), d ** -0.5),
        "wi_up": _normal(k3, (n_experts, d, f), d ** -0.5),
        "wo": _normal(k4, (n_experts, f, d), f ** -0.5),
    }


def moe_axes(expert_sharding: str):
    """expert_sharding: 'expert' (E over model) or 'ffn' (d_ff over model)."""
    if expert_sharding == "expert":
        e, f = "experts", "expert_mlp"
    else:
        e, f = None, "mlp"
    return {"router": ("embed", None),
            "wi_gate": (e, "embed", f), "wi_up": (e, "embed", f),
            "wo": (e, f, "embed")}


def moe_mlp(p, x, *, top_k: int, capacity_factor: float = 1.25,
            group_size: int = 512):
    """x: (B, S, D) -> (B, S, D), aux_loss (f32 scalar).

    Tokens dispatch within groups of `group_size` (T5X-style): the dispatch
    tensor is (G, tg, E, C_g) with C_g = ceil(top_k * tg / E * cf), so its
    total size scales with tg (not T) — a flat 32k-token dispatch for a
    128-expert layer would be ~14 GB/device, grouped it is ~300 MB.
    """
    b, s, d = x.shape
    e = p["router"].shape[1]
    t = b * s
    tg = min(group_size, t)
    g = t // tg
    assert g * tg == t, (t, tg)
    xt = x.reshape(g, tg, d)
    xt = shard(xt, "batch", None, None)

    logits = (xt.astype(jnp.float32) @ p["router"])          # (G, tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)        # (G, tg, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)

    cap = min(max(int(top_k * tg / e * capacity_factor), 1), tg)

    # position of each (token, k) within its expert's per-group buffer
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)    # (G, tg, K, E)
    flatoh = onehot.reshape(g, tg * top_k, e)
    pos_in_expert = (jnp.cumsum(flatoh, axis=1) - flatoh) \
        .reshape(g, tg, top_k, e)
    pos = jnp.sum(pos_in_expert * onehot, axis=-1)           # (G, tg, K)
    keep = pos < cap

    pos_oh = jax.nn.one_hot(pos, cap, dtype=jnp.float32)     # (G, tg, K, C)
    disp = jnp.einsum("gtke,gtk,gtkc->gtec", onehot.astype(jnp.float32),
                      keep.astype(jnp.float32), pos_oh)
    comb = jnp.einsum("gtke,gtk,gtkc->gtec", onehot.astype(jnp.float32),
                      (gate_vals * keep).astype(jnp.float32), pos_oh)

    xe = jnp.einsum("gtd,gtec->gecd", xt, disp.astype(DTYPE))  # (G, E, C, D)
    xe = shard(xe, "batch", "experts", None, None)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["wi_gate"])) \
        * jnp.einsum("gecd,edf->gecf", xe, p["wi_up"])
    ye = jnp.einsum("gecf,efd->gecd", h, p["wo"])
    ye = shard(ye, "batch", "experts", None, None)
    yt = jnp.einsum("gecd,gtec->gtd", ye, comb.astype(DTYPE))

    # Switch aux loss: E * sum(frac_tokens * frac_probs)
    frac_tokens = jnp.mean(onehot[..., 0, :].astype(jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return shard(yt.reshape(b, s, d), "batch", "seq", "embed_act"), aux
