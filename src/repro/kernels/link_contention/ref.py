"""Oracle for the segmented depart kernel: direct lax.scan recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def segmented_depart_ref(chan, arrive, ser):
    """depart_i = max(arrive_i, depart_{i-1} if same channel) + ser_i."""

    def step(carry, x):
        prev_chan, prev_dep = carry
        c, a, s = x
        same = c == prev_chan
        dep = jnp.where(same, jnp.maximum(a, prev_dep), a) + s
        return (c, dep), dep

    (_, _), dep = jax.lax.scan(
        step, (jnp.int32(-1), jnp.int32(0)),
        (chan.astype(jnp.int32), arrive.astype(jnp.int32),
         ser.astype(jnp.int32)))
    return dep
