"""Segmented tropical ((max,+)) scan — the ESF engine hotspot (Pallas TPU).

One fixpoint round of the schedule engine reduces to: given items sorted by
(channel, arrival), compute per item

    depart_i = max(arrive_i, depart_{i-1 within same channel}) + ser_i

Each item is the affine-max map f_i(x) = max(arrive_i, x) + ser_i; maps
compose as (c, m): f(x) = max(c, x + m), f2.f1 = (max(c2, c1+m2), m1+m2),
with a reset at channel boundaries — a *segmented associative scan*.  The
kernel processes the item stream in VMEM blocks: an intra-block Hillis–Steele
scan over log2(block) shifted combines (VPU-vectorized), then an absolute
(depart, channel) carry across blocks in scratch (sequential grid; the
carried map's m folds into c once departs are absolute).

Times are int32 (the engine's int64 picoseconds are range-reduced by the ops
wrapper before dispatch; exactness is preserved because one round's spans fit
32 bits after rebasing).  This kernel covers the full-duplex no-row-state
fast path — the general case (turnaround, DRAM rows) stays on the lax.scan
path in `core.engine`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -(2 ** 30)  # python int: keeps the kernel free of captured consts


def _seg_kernel(chan_ref, arrive_ref, ser_ref, depart_ref,
                carry_c, carry_chan, *, blk: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_c[...] = jnp.full_like(carry_c, NEG)
        carry_chan[...] = jnp.full_like(carry_chan, -1)

    chan = chan_ref[...]
    arrive = arrive_ref[...]
    ser = ser_ref[...]

    # per-item map (c, m) = (arrive + ser, ser); segment id = channel
    c = arrive + ser
    m = ser

    # segmented Hillis–Steele inclusive scan over the block
    seg = chan
    k = 1
    while k < blk:
        c_prev = jnp.concatenate([jnp.full((k,), NEG, jnp.int32), c[:-k]])
        m_prev = jnp.concatenate([jnp.zeros((k,), jnp.int32), m[:-k]])
        seg_prev = jnp.concatenate([jnp.full((k,), -1, jnp.int32), seg[:-k]])
        same = seg_prev == seg
        c = jnp.where(same, jnp.maximum(c, c_prev + m), c)
        m = jnp.where(same, m + m_prev, m)
        k *= 2

    # compose with the inter-block carry where the first run continues it
    # (the carry is an absolute depart time: m folds into c after the scan)
    cc = carry_c[0]
    cchan = carry_chan[0]
    first_chan = chan[0]
    # items whose whole prefix (within block) is one run starting at item 0
    run0 = jnp.cumprod((chan == first_chan).astype(jnp.int32)) == 1
    cont = run0 & (cchan == first_chan)
    c = jnp.where(cont, jnp.maximum(c, cc + m), c)

    depart_ref[...] = c

    # new carry = composed map of the trailing run of the block
    last_chan = chan[blk - 1]
    carry_c[0] = c[blk - 1]
    carry_chan[0] = last_chan


@functools.partial(jax.jit, static_argnames=("blk", "interpret"))
def segmented_depart(chan, arrive, ser, *, blk: int = 2048,
                     interpret: bool = False):
    """chan: (K,) int32 sorted; arrive, ser: (K,) int32 -> depart (K,) int32."""
    k = chan.shape[0]
    pad = (-k) % blk
    if pad:
        chan = jnp.concatenate([chan, jnp.full((pad,), -2, jnp.int32)])
        arrive = jnp.concatenate([arrive, jnp.zeros((pad,), jnp.int32)])
        ser = jnp.concatenate([ser, jnp.zeros((pad,), jnp.int32)])
    n = chan.shape[0]
    steps = n // blk
    out = pl.pallas_call(
        functools.partial(_seg_kernel, blk=blk),
        grid=(steps,),
        in_specs=[pl.BlockSpec((blk,), lambda i: (i,)),
                  pl.BlockSpec((blk,), lambda i: (i,)),
                  pl.BlockSpec((blk,), lambda i: (i,))],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        scratch_shapes=[pltpu.VMEM((1,), jnp.int32),
                        pltpu.VMEM((1,), jnp.int32)],
        interpret=interpret,
    )(chan, arrive, ser)
    return out[:k]
