"""Public wrapper: int64-picosecond engine round -> int32 kernel dispatch.

The engine keeps exact int64 picoseconds; one schedule round's time span fits
comfortably in int32 after rebasing to the round's minimum arrival, so the
wrapper rebases, dispatches, and restores the offset.  Falls back to the
lax.scan oracle when the span would overflow (never observed at bench sizes)
or off-TPU unless interpret is forced.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import segmented_depart
from .ref import segmented_depart_ref

_SPAN_LIMIT = (1 << 30) - 1


@functools.partial(jax.jit, static_argnames=("impl",))
def depart_times(chan, arrive_ps, ser_ps, *, impl: str = "auto"):
    """chan (K,) sorted int; arrive/ser (K,) int64 ps -> depart int64 ps."""
    base = jnp.min(arrive_ps)
    arr32 = (arrive_ps - base).astype(jnp.int32)
    ser32 = ser_ps.astype(jnp.int32)
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        dep = segmented_depart_ref(chan.astype(jnp.int32), arr32, ser32)
    else:
        dep = segmented_depart(chan.astype(jnp.int32), arr32, ser32,
                               interpret=(impl == "interpret"))
    return dep.astype(jnp.int64) + base
