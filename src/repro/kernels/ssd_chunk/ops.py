"""jit'd wrapper with backend dispatch (pallas on TPU, oracle elsewhere)."""

from __future__ import annotations

import functools

import jax

from .kernel import ssd_chunk_pallas
from .ref import ssd_chunk_ref


@functools.partial(jax.jit, static_argnames=("chunk", "impl"))
def ssd_chunk(x, dt, a_log, b, c, *, chunk: int = 128, impl: str = "auto"):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return ssd_chunk_ref(x, dt, a_log, b, c, chunk=chunk)
    return ssd_chunk_pallas(x, dt, a_log, b, c, chunk=chunk,
                            interpret=(impl == "interpret"))
