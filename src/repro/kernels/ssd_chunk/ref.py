"""Oracle: the model's chunked SSD (shared semantics)."""

from repro.models.ssd import ssd_chunked


def ssd_chunk_ref(x, dt, a_log, b, c, *, chunk: int = 128):
    return ssd_chunked(x, dt, a_log, b, c, chunk=chunk)
