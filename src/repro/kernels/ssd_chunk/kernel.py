"""Mamba-2 SSD chunk scan (Pallas TPU kernel).

Grid: (batch, head, chunk) with the chunk axis innermost and sequential; the
inter-chunk recurrent state (P, N) rides in VMEM scratch.  Per chunk (length
Q) everything is dense MXU matmuls on (Q,Q)/(Q,N)/(Q,P) tiles:

  y_diag = (C B^T  .  exp(segsum(logA)))  (x*dt)       intra-chunk
  y_off  = C  state_in . decay_in                       inter-chunk
  state  = state_in * total_decay + B^T (x*dt . decay_rest)

Default Q=128, P,N multiples of 64/128: VMEM footprint ~ (Q*Q + 2*Q*N +
2*Q*P + P*N) * 4B ~= 0.5 MB.  The pure-jnp oracle is
`repro.models.ssd.ssd_chunked` (shared semantics with the model block).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref, y_ref, state_scr, *,
                q: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0, 0].astype(jnp.float32)     # (Q, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)   # (Q,)
    a_log = alog_ref[0]                        # ()
    bmat = b_ref[0, 0].astype(jnp.float32)     # (Q, N)
    cmat = c_ref[0, 0].astype(jnp.float32)     # (Q, N)

    loga = -jnp.exp(a_log) * dt                # (Q,) < 0
    cs = jnp.cumsum(loga)                      # (Q,)
    seg = cs[:, None] - cs[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, seg.shape, 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, seg.shape, 1)
    L = jnp.where(tri, jnp.exp(seg), 0.0)      # (Q, Q)

    xdt = x * dt[:, None]                      # (Q, P)
    scores = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * L
    y = jax.lax.dot_general(scores, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the incoming state
    decay_in = jnp.exp(cs)                     # (Q,)
    state_in = state_scr[...]                  # (P, N)
    y += (jax.lax.dot_general(cmat, state_in, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
          * decay_in[:, None])

    # state update
    decay_rest = jnp.exp(cs[-1] - cs)          # (Q,)
    new_state = state_in * jnp.exp(cs[-1]) + jax.lax.dot_general(
        xdt * decay_rest[:, None], bmat, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    state_scr[...] = new_state
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunk_pallas(x, dt, a_log, b, c, *, chunk: int = 128,
                     interpret: bool = False):
    """x: (B,S,H,P); dt: (B,S,H); a_log: (H,); b,c: (B,S,N) -> y (B,S,H,P)."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, s)
    assert s % q == 0
    nc = s // q
    xt = x.transpose(0, 2, 1, 3).reshape(bsz, h, nc, q, p)
    dtt = dt.transpose(0, 2, 1).reshape(bsz, h, nc, q)
    bt = b.reshape(bsz, nc, q, n)
    ct = c.reshape(bsz, nc, q, n)
    out = pl.pallas_call(
        functools.partial(_ssd_kernel, q=q),
        grid=(bsz, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, q, p), lambda b_, h_, ci: (b_, h_, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, q), lambda b_, h_, ci: (b_, h_, ci, 0)),
            pl.BlockSpec((1,), lambda b_, h_, ci: (h_,)),
            pl.BlockSpec((1, 1, q, n), lambda b_, h_, ci: (b_, ci, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda b_, h_, ci: (b_, ci, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, q, p),
                               lambda b_, h_, ci: (b_, h_, ci, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, h, nc, q, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, a_log, bt, ct)
    return out.reshape(bsz, h, s, p).transpose(0, 2, 1, 3)
