"""jit'd public wrapper: model-layout GQA flash attention.

Accepts the model's (B, S, H, D) / (B, T, KV, D) layout, regroups query heads
per KV head (no K/V replication), and dispatches to the Pallas kernel —
interpret mode off-TPU so the same call validates on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_gqa
from .ref import flash_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "impl",
                                             "q_blk", "kv_blk"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    impl: str = "auto", q_blk: int = 512, kv_blk: int = 512):
    """q: (B, S, H, D); k, v: (B, T, KV, D) -> (B, S, H, D).

    impl: auto | pallas | interpret | ref
    """
    b, s, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, d).transpose(0, 2, 3, 1, 4)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        out = flash_attention_ref(qg, kt, vt, causal=causal, window=window)
    else:
        out = flash_attention_gqa(qg, kt, vt, causal=causal, window=window,
                                  q_blk=q_blk, kv_blk=kv_blk,
                                  interpret=(impl == "interpret"))
    return out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, d)
