"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -2.0e38


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B, KV, G, S, D); k, v: (B, KV, T, D)."""
    d = q.shape[-1]
    s = jnp.einsum("bkgsd,bktd->bkgst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(d).astype(jnp.float32)
    sq, t = q.shape[3], k.shape[2]
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(t)[None, :]
    mask = jnp.ones((sq, t), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, NEG)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgst,bktd->bkgsd", w,
                      v.astype(jnp.float32)).astype(q.dtype)
