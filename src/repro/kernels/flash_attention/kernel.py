"""Blocked causal flash attention (Pallas TPU kernel).

Grid: (batch, kv_head, q_group, q_block, kv_block) with the kv_block axis
innermost and sequential — online-softmax statistics (m, l) and the output
accumulator are carried across kv steps in VMEM scratch.  Block shapes are
MXU-aligned (multiples of 128 on the matmul dims; q/kv block defaults 512/512
keep the working set q(512x128) + k/v(2x512x128) + acc ~= 0.6 MB well inside
VMEM).  Causal blocks above the diagonal are masked; fully-masked kv blocks
still execute (Pallas grids are dense) but contribute zero — the ops wrapper
chooses block sizes so at most half the steps are dead for causal runs.

GQA is handled by the wrapper: query heads are grouped per KV head and the
grid iterates (kv_head, group) pairs, so K/V blocks are never materialized
`G` times.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -2.0e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, q_blk: int, kv_blk: int,
                  kv_steps: int, window: int):
    qi = pl.program_id(3)
    ki = pl.program_id(4)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0, 0].astype(jnp.float32)            # (q_blk, d)
    k = k_ref[0, 0].astype(jnp.float32)               # (kv_blk, d)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = qi * q_blk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = ki * kv_blk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_new = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ki == kv_steps - 1)
    def _finish():
        o_ref[0, 0, 0] = (acc_scr[...] /
                          jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "q_blk", "kv_blk", "window", "interpret"))
def flash_attention_gqa(q, k, v, *, causal: bool = True, q_blk: int = 512,
                        kv_blk: int = 512, window: int = 0,
                        interpret: bool = False):
    """q: (B, KV, G, S, D); k, v: (B, KV, T, D).  Returns (B, KV, G, S, D)."""
    b, kvh, g, s, d = q.shape
    t = k.shape[2]
    q_blk = min(q_blk, s)
    kv_blk = min(kv_blk, t)
    assert s % q_blk == 0 and t % kv_blk == 0
    kv_steps = t // kv_blk
    scale = d ** -0.5

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, q_blk=q_blk,
        kv_blk=kv_blk, kv_steps=kv_steps, window=window)

    return pl.pallas_call(
        kernel,
        grid=(b, kvh, g, s // q_blk, kv_steps),
        in_specs=[
            pl.BlockSpec((1, 1, 1, q_blk, d),
                         lambda b_, h, g_, qi, ki: (b_, h, g_, qi, 0)),
            pl.BlockSpec((1, 1, kv_blk, d),
                         lambda b_, h, g_, qi, ki: (b_, h, ki, 0)),
            pl.BlockSpec((1, 1, kv_blk, d),
                         lambda b_, h, g_, qi, ki: (b_, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, q_blk, d),
                               lambda b_, h, g_, qi, ki: (b_, h, g_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_blk, 1), jnp.float32),
            pltpu.VMEM((q_blk, 1), jnp.float32),
            pltpu.VMEM((q_blk, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
