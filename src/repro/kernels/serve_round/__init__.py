"""Pallas TPU kernel package: kernel.py + ops.py + ref.py."""
