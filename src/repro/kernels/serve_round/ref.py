"""Oracle for the serve-round affine scan: direct lax.scan composition.

One step applies item ``i``'s (max,+) affine map to the running channel
state ``v = (depart, down)``:

    v' = M_i (x) v  (+)  c_i        (x) = tropical matmul, (+) = max

with saturation at ``NEG`` (the tropical -inf sentinel shared with the
kernel).  The ops wrapper builds the per-item maps; this oracle is the
sequential ground truth the Hillis-Steele kernel must match bit-for-bit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -(2 ** 30)


def serve_scan_ref(m00, m01, m10, m11, c0, c1):
    """Inclusive scan of the affine-map composition; returns the depart
    state component per item (int32)."""

    def step(v, m):
        d, w = v
        a00, a01, a10, a11, b0, b1 = m
        d2 = jnp.maximum(jnp.maximum(a00 + d, a01 + w), b0)
        w2 = jnp.maximum(jnp.maximum(a10 + d, a11 + w), b1)
        d2 = jnp.maximum(d2, NEG)
        w2 = jnp.maximum(w2, NEG)
        return (d2, w2), d2

    (_, _), d = jax.lax.scan(
        step, (jnp.int32(NEG), jnp.int32(NEG)),
        (m00, m01, m10, m11, c0, c1))
    return d
