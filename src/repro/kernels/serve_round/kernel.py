"""Serve-round (max,+) affine scan — the full ESF engine round (Pallas TPU).

One fixpoint round of the schedule engine — turnaround gaps, DRAM
row-buffer penalties, retraining down-until clocks, link-down markers and
streaming carry seeds included — reduces to an *unsegmented* associative
scan once the ops wrapper has done its static pre-pass:

  * the previous direction / DRAM row a sorted item reacts to depend only
    on the item ordering, never on the departure times, so the turnaround
    gap and row penalty fold into per-item constants;
  * what remains dynamic is the two-component channel state
    ``v = (depart, down_until)``, which every item transforms by a (max,+)
    affine map ``v' = M (x) v (+) c`` (serving item, link-down marker, or
    identity pass-through);
  * segment heads fold their channel's carried seed state into ``c`` and
    kill the incoming state (``M = NEG``), which removes segmentation from
    the scan entirely — maps compose across channel boundaries as plain
    (max,+) matrix products.

The kernel runs a Hillis-Steele inclusive composition scan over VMEM
blocks (log2(block) shifted combines, VPU-vectorized) and threads an
absolute ``(depart, down)`` state across blocks in scratch (sequential
grid).  Times are int32: the ops wrapper rebases the engine's int64
picoseconds to the round's minimum arrival, whose span must stay under
2**29 so composed sums never overflow (compositions add at most two
rebased times before the ``NEG`` saturation clamp).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -(2 ** 30)  # tropical -inf; python int keeps the kernel const-free


def _serve_kernel(m00_ref, m01_ref, m10_ref, m11_ref, c0_ref, c1_ref,
                  d_ref, carry_d, carry_w, *, blk: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_d[...] = jnp.full_like(carry_d, NEG)
        carry_w[...] = jnp.full_like(carry_w, NEG)

    m00 = m00_ref[...]
    m01 = m01_ref[...]
    m10 = m10_ref[...]
    m11 = m11_ref[...]
    c0 = c0_ref[...]
    c1 = c1_ref[...]

    # Hillis-Steele inclusive scan of map composition; shifted-in slots are
    # the identity map (M = [[0, NEG], [NEG, 0]], c = NEG)
    k = 1
    while k < blk:
        def sh(x, fill, k=k):
            return jnp.concatenate(
                [jnp.full((k,), fill, jnp.int32), x[:-k]])
        p00, p01 = sh(m00, 0), sh(m01, NEG)
        p10, p11 = sh(m10, NEG), sh(m11, 0)
        q0, q1 = sh(c0, NEG), sh(c1, NEG)
        # (M, c) := (M, c) . (P, q) — P applied first:
        #   M' = M (x) P,  c' = M (x) q (+) c   (all saturated at NEG)
        n00 = jnp.maximum(jnp.maximum(m00 + p00, m01 + p10), NEG)
        n01 = jnp.maximum(jnp.maximum(m00 + p01, m01 + p11), NEG)
        n10 = jnp.maximum(jnp.maximum(m10 + p00, m11 + p10), NEG)
        n11 = jnp.maximum(jnp.maximum(m10 + p01, m11 + p11), NEG)
        nc0 = jnp.maximum(jnp.maximum(m00 + q0, m01 + q1), c0)
        nc1 = jnp.maximum(jnp.maximum(m10 + q0, m11 + q1), c1)
        m00, m01, m10, m11 = n00, n01, n10, n11
        c0 = jnp.maximum(nc0, NEG)
        c1 = jnp.maximum(nc1, NEG)
        k *= 2

    # apply the block-prefix maps to the inter-block absolute state
    d_in = carry_d[0]
    w_in = carry_w[0]
    d = jnp.maximum(jnp.maximum(m00 + d_in, m01 + w_in), c0)
    w = jnp.maximum(jnp.maximum(m10 + d_in, m11 + w_in), c1)
    d_ref[...] = d
    carry_d[0] = d[blk - 1]
    carry_w[0] = w[blk - 1]


@functools.partial(jax.jit, static_argnames=("blk", "interpret"))
def serve_scan(m00, m01, m10, m11, c0, c1, *, blk: int = 2048,
               interpret: bool = False):
    """Six (K,) int32 map components -> (K,) int32 depart state per item."""
    k = m00.shape[0]
    pad = (-k) % blk
    if pad:
        def ext(x, fill):
            return jnp.concatenate([x, jnp.full((pad,), fill, jnp.int32)])
        m00, m11 = ext(m00, 0), ext(m11, 0)
        m01, m10 = ext(m01, NEG), ext(m10, NEG)
        c0, c1 = ext(c0, NEG), ext(c1, NEG)
    n = m00.shape[0]
    steps = n // blk
    out = pl.pallas_call(
        functools.partial(_serve_kernel, blk=blk),
        grid=(steps,),
        in_specs=[pl.BlockSpec((blk,), lambda i: (i,))] * 6,
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        scratch_shapes=[pltpu.VMEM((1,), jnp.int32),
                        pltpu.VMEM((1,), jnp.int32)],
        interpret=interpret,
    )(m00, m01, m10, m11, c0, c1)
    return out[:k]
