"""Public wrapper: one engine serve round -> (max,+) affine-scan dispatch.

`core.engine._one_round` hands this wrapper the *sorted* per-item arrays of
one fixpoint round (items lexsorted by (channel, arrival, flat index), with
per-channel table gathers and seed gathers already done).  The wrapper

  1. runs the **static pre-pass**: the direction / DRAM row each item
     reacts to is the direction/row of the last *serving* (row-managed)
     item before it in its channel segment — a property of the ordering
     alone, resolved with exclusive running-max index gathers.  The
     turnaround gap and row hit/miss penalty then fold into per-item
     constants, and ``s = ser + row_extra`` is each item's total occupancy;
  2. builds each item's (max,+) affine map over the channel state
     ``v = (depart, down_until)`` — serving items advance ``depart`` (and
     ``down`` when they carry a retrain interval), link-down markers only
     raise ``down``, everything else is the identity — and folds the
     carried seed state into segment heads (which then kill the incoming
     state, making the scan unsegmented);
  3. rebases int64 picoseconds to int32 around the round's minimum arrival
     (seed clamps keep the rebase exact: a seed below the clamp floor is
     provably non-binding both before and after), dispatches the scan
     (Pallas kernel on TPU, interpret mode when forced, lax.scan oracle
     otherwise), and restores absolute times.

Returns the engine's masked per-item ``(start, depart, retrain_stall)``
triple in int64 picoseconds; non-serving items pass through at their
arrival with zero stall, exactly like the lax scan path.  One round's time
span must fit 2**29 after rebasing (documented kernel contract; holds by
orders of magnitude at bench sizes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import NEG, serve_scan
from .ref import serve_scan_ref

_SPAN_LIMIT = (1 << 29) - 1


@functools.partial(jax.jit, static_argnames=("impl",))
def serve_round(chan, serving, marker, arrive, direction, row, ser, turn,
                rhit, rmiss, retrain, sd_dep, sd_dir, sd_row, sd_down, *,
                impl: str = "auto"):
    """One sorted serve round.  All inputs (K,): ``chan`` int32 sorted with
    invalid items in a trailing dummy segment; ``serving``/``marker`` bool
    item classes; ``arrive``/``ser``/``turn``/``rhit``/``rmiss``/
    ``retrain``/``sd_dep``/``sd_down`` int64 ps; ``direction``/``sd_dir``
    int8; ``row``/``sd_row`` int32.  ``sd_*`` are the per-item gathered
    channel seed frontiers (cold: 0 / -1 / -2 / 0).  Returns int64
    ``(start, depart, stall)``."""
    k = chan.shape[0]
    idx = jnp.arange(k, dtype=jnp.int32)
    active = serving | marker
    dirn = direction.astype(jnp.int32)
    sdir = sd_dir.astype(jnp.int32)

    def prev_ix(mask):
        # index of the last item before me satisfying mask (-1 = none)
        inc = jax.lax.cummax(jnp.where(mask, idx, jnp.int32(-1)))
        return jnp.concatenate([jnp.full((1,), -1, jnp.int32), inc[:-1]])

    def in_seg(p):
        return (p >= 0) & (chan[jnp.maximum(p, 0)] == chan)

    p_act = prev_ix(active)
    p_srv = prev_ix(serving)
    p_row = prev_ix(serving & (row >= 0))
    head = active & ~in_seg(p_act)
    eff_dir = jnp.where(in_seg(p_srv), dirn[jnp.maximum(p_srv, 0)], sdir)
    eff_row = jnp.where(in_seg(p_row), row[jnp.maximum(p_row, 0)], sd_row)

    gap = jnp.where((eff_dir != -1) & (dirn != eff_dir), turn, jnp.int64(0))
    rx = jnp.where(row >= 0, jnp.where(row == eff_row, rhit, rmiss),
                   jnp.int64(0))
    s = ser + rx

    # int64 ps -> int32 rebased to the round's min arrival.  Seed clamps:
    # a depart seed below (base - turn) / a down seed below base can never
    # bind (every start is >= arrive >= base), so clamping preserves the
    # schedule bit-for-bit while keeping the rebase in range.
    base = jnp.min(arrive)
    arr = (arrive - base).astype(jnp.int32)
    sdep = (jnp.maximum(sd_dep, base - turn) - base).astype(jnp.int32)
    sdwn = (jnp.maximum(sd_down, base) - base).astype(jnp.int32)
    gap32 = gap.astype(jnp.int32)
    s32 = s.astype(jnp.int32)
    r32 = retrain.astype(jnp.int32)

    neg = jnp.full(k, NEG, jnp.int32)
    zero = jnp.zeros(k, jnp.int32)
    rp = jnp.where(r32 > 0, r32, neg)  # NEG = no retrain contribution

    # serving map: depart' = max(arr+s, depart+gap+s, down+s);
    #              down'   = max(down, depart' + retrain?)
    m00, m01, c0 = gap32 + s32, s32, arr + s32
    m10 = jnp.maximum(m00 + rp, neg)
    m11 = jnp.maximum(jnp.maximum(s32 + rp, zero), neg)
    c1 = jnp.maximum(c0 + rp, neg)
    # marker: depart' = depart; down' = max(down, arr + retrain)
    m00 = jnp.where(serving, m00, zero)
    m01 = jnp.where(serving, m01, neg)
    c0 = jnp.where(serving, c0, neg)
    m10 = jnp.where(serving, m10, neg)
    m11 = jnp.where(serving, m11, zero)
    c1 = jnp.where(serving, c1, jnp.where(marker, arr + r32, neg))
    # heads fold the seed state into c and kill the incoming state — this
    # is what de-segments the scan
    h0 = jnp.maximum(jnp.maximum(m00 + sdep, m01 + sdwn), c0)
    h1 = jnp.maximum(jnp.maximum(m10 + sdep, m11 + sdwn), c1)
    c0 = jnp.where(head, jnp.maximum(h0, NEG), c0)
    c1 = jnp.where(head, jnp.maximum(h1, NEG), c1)
    m00 = jnp.where(head, neg, m00)
    m01 = jnp.where(head, neg, m01)
    m10 = jnp.where(head, neg, m10)
    m11 = jnp.where(head, neg, m11)

    use = impl
    if use == "auto":
        use = "pallas" if jax.default_backend() == "tpu" else "ref"
    if use == "ref":
        d32 = serve_scan_ref(m00, m01, m10, m11, c0, c1)
    else:
        d32 = serve_scan(m00, m01, m10, m11, c0, c1,
                         interpret=(use == "interpret"))
    d = d32.astype(jnp.int64) + base

    # stall = grant delay the down-until clock added on top of contention
    eff_dep = jnp.where(head, sd_dep,
                        jnp.concatenate([sd_dep[:1], d[:-1]]))
    start = d - s
    out_start = jnp.where(serving, start, arrive)
    out_depart = jnp.where(serving, d, arrive)
    out_stall = jnp.where(
        serving, start - jnp.maximum(arrive, eff_dep + gap), jnp.int64(0))
    return out_start, out_depart, out_stall
