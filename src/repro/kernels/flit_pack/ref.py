"""Oracle for the flit-pack kernel: direct jnp elementwise evaluation."""

from __future__ import annotations

import jax.numpy as jnp

PPM = 1_000_000


def flit_pack_ref(payload, flit_size, flit_payload, replay_ppm):
    """Wire bytes + goodput efficiency of each packet (elementwise).

    payload       (K,) int32 logical TLP bytes per packet
    flit_size     (K,) int32 flit wire bytes; 0 = byte-exact channel
    flit_payload  (K,) int32 TLP bytes per flit
    replay_ppm    (K,) int32 expected extra CRC-replay transmissions (ppm)

    Returns (wire_bytes int32, efficiency float32) where efficiency is
    payload / (wire * (1 + ppm/1e6)) — the goodput fraction of wire time.
    """
    payload = payload.astype(jnp.int32)
    fsize = flit_size.astype(jnp.int32)
    fpay = jnp.maximum(flit_payload.astype(jnp.int32), 1)
    ppm = replay_ppm.astype(jnp.int32)
    n_flits = (payload + fpay - 1) // fpay
    wire = jnp.where(fsize > 0, n_flits * fsize, payload)
    scale = 1.0 + ppm.astype(jnp.float32) * (1.0 / PPM)
    eff = payload.astype(jnp.float32) / jnp.maximum(
        wire.astype(jnp.float32) * scale, 1.0)
    return wire, eff
