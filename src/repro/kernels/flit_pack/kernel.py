"""Vectorized flit packing / goodput-efficiency kernel (Pallas TPU).

The link-layer design loop evaluates flit efficiency over large cross
products — packet-size distribution x flit geometry x BER-derived replay
overhead x credit config — before committing to a full schedule simulation.
That evaluation is a pure elementwise map:

    n_flits = ceil(payload / flit_payload)
    wire    = n_flits * flit_size          (byte-exact channels: payload)
    eff     = payload / (wire * (1 + replay_ppm/1e6))

This kernel streams the flattened evaluation points through VMEM in 1-D
blocks on the VPU (same layout discipline as `kernels.link_contention`).
Integer ceil-division stays in int32 (Pallas TPU has no int64 path), so
wire bytes are exact only while ``ceil(payload/flit_payload) * flit_size``
fits int32 — payloads up to ``ops.MAX_PAYLOAD_B`` (~1.9 GB, far above any
real TLP); the ops wrapper rejects larger inputs rather than wrapping.
``ops.flit_sweep`` builds the cross product and ``vmap``s whole BER x
bandwidth x flit-mode sweeps into one jit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PPM = 1_000_000


def _flit_kernel(pay_ref, fsize_ref, fpay_ref, ppm_ref, wire_ref, eff_ref):
    pay = pay_ref[...]
    fsize = fsize_ref[...]
    fpay = jnp.maximum(fpay_ref[...], 1)
    ppm = ppm_ref[...]
    n_flits = (pay + fpay - 1) // fpay
    wire = jnp.where(fsize > 0, n_flits * fsize, pay)
    wire_ref[...] = wire
    scale = 1.0 + ppm.astype(jnp.float32) * (1.0 / PPM)
    eff_ref[...] = pay.astype(jnp.float32) / jnp.maximum(
        wire.astype(jnp.float32) * scale, 1.0)


@functools.partial(jax.jit, static_argnames=("blk", "interpret"))
def flit_pack_pallas(payload, flit_size, flit_payload, replay_ppm, *,
                     blk: int = 1024, interpret: bool = False):
    """payload/flit_size/flit_payload/replay_ppm: (K,) int32 ->
    (wire_bytes (K,) int32, efficiency (K,) float32)."""
    k = payload.shape[0]
    pad = (-k) % blk
    args = [payload.astype(jnp.int32), flit_size.astype(jnp.int32),
            flit_payload.astype(jnp.int32), replay_ppm.astype(jnp.int32)]
    if pad:
        args = [jnp.concatenate([a, jnp.zeros((pad,), jnp.int32)])
                for a in args]
    n = args[0].shape[0]
    wire, eff = pl.pallas_call(
        _flit_kernel,
        grid=(n // blk,),
        in_specs=[pl.BlockSpec((blk,), lambda i: (i,)) for _ in range(4)],
        out_specs=[pl.BlockSpec((blk,), lambda i: (i,)),
                   pl.BlockSpec((blk,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.int32),
                   jax.ShapeDtypeStruct((n,), jnp.float32)],
        interpret=interpret,
    )(*args)
    return wire[:k], eff[:k]
