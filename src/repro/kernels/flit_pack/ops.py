"""Public flit-efficiency ops: mode/BER handling + whole-sweep dispatch.

``flit_pack`` evaluates one array of packets under one link config;
``flit_sweep`` builds the BER x flit-mode cross product the link-layer
benches plot, entirely as arrays so the evaluation jits (and nests under
an outer ``vmap`` over bandwidths or credit counts).  Off-TPU the pure-jnp
oracle is used unless the Pallas interpreter is forced — same dispatch
discipline as `kernels.link_contention.ops`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import link_layer

from .kernel import flit_pack_pallas
from .ref import flit_pack_ref

# Largest payload whose wire bytes (ceil(p/236)*256, the worst expansion
# ratio) still fit the kernel's int32 arithmetic; larger inputs would wrap
# silently, so the public entry points reject them.
MAX_PAYLOAD_B = 1_900_000_000


def _check_payload(payload_bytes) -> None:
    arr = np.asarray(payload_bytes)
    if arr.size and int(arr.max()) > MAX_PAYLOAD_B:
        raise ValueError(
            f"payload {int(arr.max())} B exceeds MAX_PAYLOAD_B "
            f"({MAX_PAYLOAD_B}); wire bytes would overflow the kernel's "
            "int32 arithmetic")


def _dispatch(payload, fsize, fpay, ppm, impl: str):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return flit_pack_ref(payload, fsize, fpay, ppm)
    return flit_pack_pallas(payload, fsize, fpay, ppm,
                            interpret=(impl == "interpret"))


def flit_pack(payload_bytes, mode: str = "flit256", ber: float = 0.0,
              retry_window: int = 16, *, impl: str = "auto"):
    """(wire_bytes, goodput_efficiency) of packets under one link config."""
    _check_payload(payload_bytes)
    pay = jnp.asarray(payload_bytes, jnp.int32)
    size, fp = link_layer.FLIT_GEOMETRY[mode]
    ppm = link_layer.replay_overhead_ppm(ber, mode, retry_window)
    full = functools.partial(jnp.full_like, pay)
    return _dispatch(pay, full(size), full(fp), full(ppm), impl)


def flit_sweep(payload_bytes, modes, bers, retry_window: int = 16, *,
               impl: str = "auto"):
    """Mean goodput efficiency over a flit-mode x BER grid, in one dispatch.

    payload_bytes: (K,) packet sizes (e.g. a workload's payload histogram).
    Returns (M, B) float32 — rows follow ``modes``, columns follow ``bers``.
    The whole grid is flattened into one kernel call: M*B*K evaluation
    points streamed through VMEM, then reduced per cell.
    """
    _check_payload(payload_bytes)
    pay = jnp.asarray(payload_bytes, jnp.int32)
    k = pay.shape[0]
    m, b = len(modes), len(bers)
    size = np.empty((m, b), np.int32)
    fp = np.empty((m, b), np.int32)
    ppm = np.empty((m, b), np.int32)
    for i, mode in enumerate(modes):
        size[i, :], fp[i, :] = link_layer.FLIT_GEOMETRY[mode]
        for j, ber in enumerate(bers):
            ppm[i, j] = link_layer.replay_overhead_ppm(ber, mode, retry_window)
    tile = lambda a: jnp.repeat(jnp.asarray(a.reshape(-1), jnp.int32), k)
    pays = jnp.tile(pay, m * b)
    _, eff = _dispatch(pays, tile(size), tile(fp), tile(ppm), impl)
    return jnp.mean(eff.reshape(m * b, k), axis=1).reshape(m, b)
