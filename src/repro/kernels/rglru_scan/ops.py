"""jit'd wrapper with backend dispatch (pallas on TPU, oracle elsewhere)."""

from __future__ import annotations

import functools

import jax

from .kernel import rglru_scan_pallas
from .ref import rglru_scan_ref


@functools.partial(jax.jit, static_argnames=("impl",))
def rglru_scan(a, b, *, impl: str = "auto"):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return rglru_scan_ref(a, b)
    return rglru_scan_pallas(a, b, interpret=(impl == "interpret"))
