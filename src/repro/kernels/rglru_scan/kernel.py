"""Blocked RG-LRU linear recurrence (Pallas TPU kernel).

h_t = a_t * h_{t-1} + b_t over the sequence, vectorized across the feature
dim.  Grid: (batch, feature_block, seq_chunk) with seq_chunk innermost and
sequential; the inter-chunk state h rides in VMEM scratch.  Within a chunk a
Hillis–Steele scan composes the affine maps (A, B) -> (a2*a1, a2*b1 + b2) in
log2(chunk) vector steps — the same reformulation `models.rglru` uses via
lax.associative_scan, here with explicit VMEM blocking (feature block 512
keeps a/b/h under ~1.5 MB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, h_ref, h_scr, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[0].astype(jnp.float32)   # (chunk, dblk)
    b = b_ref[0].astype(jnp.float32)

    # Hillis–Steele over the affine maps
    A, B = a, b
    k = 1
    while k < chunk:
        A_prev = jnp.concatenate([jnp.ones((k, A.shape[1]), jnp.float32),
                                  A[:-k]])
        B_prev = jnp.concatenate([jnp.zeros((k, B.shape[1]), jnp.float32),
                                  B[:-k]])
        B = jnp.where(
            (jax.lax.broadcasted_iota(jnp.int32, A.shape, 0) >= k),
            A * B_prev + B, B)
        A = jnp.where(
            (jax.lax.broadcasted_iota(jnp.int32, A.shape, 0) >= k),
            A * A_prev, A)
        k *= 2

    h_in = h_scr[...]
    h = A * h_in[None] + B
    h_ref[0] = h.astype(h_ref.dtype)
    h_scr[...] = h[chunk - 1]


@functools.partial(jax.jit, static_argnames=("chunk", "d_blk", "interpret"))
def rglru_scan_pallas(a, b, *, chunk: int = 256, d_blk: int = 512,
                      interpret: bool = False):
    """a, b: (B, S, D) f32 -> h: (B, S, D) f32."""
    bsz, s, d = a.shape
    chunk = min(chunk, s)
    d_blk = min(d_blk, d)
    assert s % chunk == 0 and d % d_blk == 0
    return pl.pallas_call(
        functools.partial(_rglru_kernel, chunk=chunk),
        grid=(bsz, d // d_blk, s // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, d_blk), lambda b_, di, ci: (b_, ci, di)),
            pl.BlockSpec((1, chunk, d_blk), lambda b_, di, ci: (b_, ci, di)),
        ],
        out_specs=pl.BlockSpec((1, chunk, d_blk),
                               lambda b_, di, ci: (b_, ci, di)),
        out_shape=jax.ShapeDtypeStruct(a.shape, jnp.float32),
        scratch_shapes=[pltpu.VMEM((d_blk,), jnp.float32)],
        interpret=interpret,
    )(a, b)
