"""JAX API compatibility shims (installed floor: jax 0.4.37).

The model/runtime stack was written against the post-0.6 sharding surface
(``jax.sharding.get_abstract_mesh``, ``jax.sharding.AxisType``,
``jax.set_mesh``, ``jax.shard_map``, ``jax.lax.pcast``, PartitionSpec-typed
``jit`` shardings).  None of those exist in the 0.4.x series this environment
pins, so every use site goes through this module instead: each shim probes
for the new symbol and falls back to the 0.4.x equivalent —

  =====================  =====================================================
  new API                0.4.x fallback
  =====================  =====================================================
  get_abstract_mesh()    thread-local physical mesh (``with Mesh(...):``)
  AxisType               inert enum stand-in (axis typing didn't exist yet)
  make_mesh(axis_types=) kwarg dropped (meshes were untyped)
  set_mesh(mesh)         the mesh itself — ``Mesh`` is a context manager
  shard_map(...)         jax.experimental.shard_map (check_rep off: the vma
                         varying-type system the new API checks didn't exist)
  pcast(x, ..)           identity (vma typing again)
  tree_as_shardings      PartitionSpec leaves wrapped into NamedSharding —
                         0.4.x ``jit`` only accepts Sharding instances
  =====================  =====================================================

Every shim resolves the new path when it exists, so this module is a no-op
pass-through on current JAX; ``tests/test_jax_compat.py`` asserts the whole
table resolves on whatever is installed.
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.6: explicit/auto/manual axis typing
    from jax.sharding import AxisType  # type: ignore[attr-defined]

    HAS_AXIS_TYPE = True
except ImportError:  # 0.4.x: meshes are untyped; accept and ignore
    HAS_AXIS_TYPE = False

    class AxisType:  # type: ignore[no-redef]
        """Inert stand-in so call sites can always name an axis type."""

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
    """``jax.make_mesh`` accepting ``axis_types`` on every version."""
    kw = {}
    if devices is not None:
        kw["devices"] = devices
    if axis_types is not None and HAS_AXIS_TYPE:
        kw["axis_types"] = axis_types
    return jax.make_mesh(axis_shapes, axis_names, **kw)


def set_mesh(mesh):
    """Context manager activating ``mesh`` for sharding resolution.

    New JAX: ``jax.set_mesh``.  0.4.x: a concrete ``Mesh`` is itself a
    context manager that installs the thread-local physical mesh, which is
    exactly what `get_abstract_mesh` below (and PartitionSpec resolution
    inside `shard`) reads back.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def get_abstract_mesh():
    """The mesh currently in scope, or None outside any mesh context."""
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        m = fn()
        if m is not None and m.axis_names:
            return m
        return None
    from jax._src import mesh as mesh_lib  # 0.4.x thread-local mesh state

    env = getattr(mesh_lib.thread_resources, "env", None)
    m = getattr(env, "physical_mesh", None)
    if m is not None and not m.empty:
        return m
    return None


def pcast(x, axes, *, to="varying"):
    """``jax.lax.pcast`` or identity: pre-vma shard_map has no varying types."""
    fn = getattr(jax.lax, "pcast", None)
    if fn is None:
        return x
    return fn(x, axes, to=to)


def shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` with the experimental 0.4.x module as fallback."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def tree_as_shardings(mesh, tree):
    """Wrap PartitionSpec leaves into NamedSharding (None leaves pass through).

    0.4.x ``jit`` rejects raw PartitionSpecs in in_/out_shardings; wrapping is
    version-independent, so call sites use this unconditionally.
    """
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        tree,
        is_leaf=lambda x: isinstance(x, P) or x is None,
    )
