"""TPU fabric as an ESF topology: collective cost prediction (beyond-paper).

The paper's insight — make the interconnect a first-class simulated object
(topology graph + per-link contention + duplex semantics) and use it to
predict system behaviour — applied to the fabric this framework actually
targets: TPU v5e pods (16x16 chips, 2D torus ICI) joined by DCN.

Collectives lower to transaction sets over the fabric graph and the exact
FCFS engine resolves their completion time, *including* contention between
overlapping collectives — the analogue of ESF's bridge-route congestion
analysis.  The roofline report (launch/roofline.py) uses these predictions as
an independent cross-check of the HLO-derived collective term, and the
sharding autotuner (core/autotune.py) uses them as its cost model.

Hardware constants (v5e): 197 bf16 TFLOP/s and 819 GB/s HBM per chip; ~50 GB/s
per ICI link per direction; DCN per-chip share defaults to 6.4 GB/s.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from . import engine
from .engine import Channels, Hops, make_channels
from .topology import REQUESTER, SWITCH, EndpointSpec, LinkSpec, Topology

import jax.numpy as jnp

V5E_PEAK_FLOPS = 197e12
V5E_HBM_BPS = 819e9
V5E_ICI_MBPS = 50_000          # per link per direction
V5E_DCN_MBPS = 6_400           # per chip share of cross-pod bandwidth
ICI_HOP_PS = 1_000             # per-hop fixed latency
DCN_RTT_PS = 5_000_000


@dataclass(frozen=True)
class TPUFabric:
    """A pod-of-chips fabric graph. Chips are REQUESTER nodes; the engine's
    generic channels model ICI links (full duplex, both directions)."""

    nx: int
    ny: int
    pods: int = 1
    ici_MBps: int = V5E_ICI_MBPS
    dcn_MBps: int = V5E_DCN_MBPS

    def chip(self, pod: int, x: int, y: int) -> int:
        return pod * self.nx * self.ny + (x % self.nx) * self.ny + (y % self.ny)

    def build(self):
        n_chips = self.pods * self.nx * self.ny
        kinds = [REQUESTER] * n_chips
        links: list[LinkSpec] = []
        for p in range(self.pods):
            for x in range(self.nx):
                for y in range(self.ny):
                    a = self.chip(p, x, y)
                    if self.nx > 1:
                        links.append(LinkSpec(a, self.chip(p, x + 1, y),
                                              self.ici_MBps, ICI_HOP_PS))
                    if self.ny > 1:
                        links.append(LinkSpec(a, self.chip(p, x, y + 1),
                                              self.ici_MBps, ICI_HOP_PS))
        # cross-pod DCN: per-chip NIC into a per-pod aggregation switch
        if self.pods > 1:
            agg = []
            for p in range(self.pods):
                kinds.append(SWITCH)  # routes traffic, owns no endpoint
                agg.append(n_chips + p)
            for p in range(self.pods):
                for q in range(p + 1, self.pods):
                    links.append(LinkSpec(agg[p], agg[q],
                                          self.dcn_MBps * self.nx * self.ny,
                                          DCN_RTT_PS))
                for x in range(self.nx):
                    for y in range(self.ny):
                        links.append(LinkSpec(self.chip(p, x, y), agg[p],
                                              self.dcn_MBps, DCN_RTT_PS // 4))
        topo = Topology(np.asarray(kinds, np.int64), links, name="tpu-fabric",
                        endpoint=EndpointSpec(bw_MBps=1, banks=1), switching_ps=0)
        return topo.build()


def _transfer_hops(graph, pairs, nbytes):
    """Build hop tables for a set of simultaneous point-to-point transfers.

    pairs: list of (src, dst); nbytes: per-transfer payload bytes.
    Dimension-ordered shortest-path routes from the interconnect layer.
    """
    paths = [graph.route(s, d) for s, d in pairs]
    h = max(len(p) - 1 for p in paths)
    n = len(pairs)
    channel = np.full((n, h), -1, np.int32)
    nb = np.zeros((n, h), np.int64)
    fixed = np.zeros((n, h), np.int64)
    valid = np.zeros((n, h), bool)
    for j, p in enumerate(paths):
        for k, (u, v) in enumerate(zip(p[:-1], p[1:])):
            c, _ = graph.edge_channel(u, v)
            channel[j, k] = c
            nb[j, k] = nbytes[j] if np.ndim(nbytes) else nbytes
            fixed[j, k] = graph.chan_fixed_ps[c]
            valid[j, k] = True
    hops = Hops(
        channel=jnp.asarray(channel), nbytes=jnp.asarray(nb),
        direction=jnp.asarray(np.zeros((n, h), np.int8)),
        row=jnp.asarray(np.full((n, h), -1, np.int32)),
        fixed_after_ps=jnp.asarray(fixed),
        is_payload=jnp.asarray(valid), valid=jnp.asarray(valid),
    )
    return hops


def simulate_transfers(graph, pairs, nbytes) -> float:
    """Makespan (seconds) of simultaneous transfers under exact contention."""
    hops = _transfer_hops(graph, pairs, nbytes)
    ch = make_channels(graph)
    sched = engine.simulate(hops, ch, jnp.zeros(len(pairs), jnp.int64))
    return float(jnp.max(sched.complete)) / 1e12


@dataclass
class CollectiveEstimate:
    kind: str
    axis_size: int
    bytes_per_chip: int
    seconds: float
    steps: int
    detail: str = ""


def ring_neighbors(fabric: TPUFabric, axis: str):
    """Chip pairs forming the bidirectional ring steps along a mesh axis."""
    pairs = []
    for p in range(fabric.pods):
        for x in range(fabric.nx):
            for y in range(fabric.ny):
                a = fabric.chip(p, x, y)
                b = (fabric.chip(p, x + 1, y) if axis == "x"
                     else fabric.chip(p, x, y + 1))
                pairs.append((a, b))
                pairs.append((b, a))
    return pairs


def predict_collective(fabric: TPUFabric, graph, kind: str, axis: str,
                       bytes_per_chip: int) -> CollectiveEstimate:
    """Predict collective completion time on the fabric.

    ring collectives (all_reduce / all_gather / reduce_scatter) run
    bidirectional rings along a torus axis; all_to_all issues all pairwise
    transfers at once (the contention-heavy case the ESF engine exists for).
    """
    ax = fabric.nx if axis == "x" else fabric.ny
    if kind in ("all_reduce", "all_gather", "reduce_scatter"):
        shard = max(bytes_per_chip // ax, 1) // 2  # bidirectional: half each way
        pairs = ring_neighbors(fabric, axis)
        t_step = simulate_transfers(graph, pairs, shard)
        steps = (2 * (ax - 1)) if kind == "all_reduce" else (ax - 1)
        return CollectiveEstimate(kind, ax, bytes_per_chip, t_step * steps,
                                  steps, f"bidir ring along {axis}")
    if kind == "all_to_all":
        pairs, sizes = [], []
        per = max(bytes_per_chip // ax, 1)
        for p in range(fabric.pods):
            for x in range(fabric.nx):
                for y in range(fabric.ny):
                    a = fabric.chip(p, x, y)
                    for k in range(1, ax):
                        b = (fabric.chip(p, x + k, y) if axis == "x"
                             else fabric.chip(p, x, y + k))
                        pairs.append((a, b))
                        sizes.append(per)
        t = simulate_transfers(graph, pairs, np.asarray(sizes))
        return CollectiveEstimate(kind, ax, bytes_per_chip, t, 1,
                                  f"direct pairwise along {axis}")
    if kind == "pod_all_reduce":
        # cross-pod gradient reduction over DCN aggregation
        pairs = []
        for p in range(fabric.pods):
            for x in range(fabric.nx):
                for y in range(fabric.ny):
                    a = fabric.chip(p, x, y)
                    b = fabric.chip((p + 1) % fabric.pods, x, y)
                    if a != b:
                        pairs.append((a, b))
        shard = max(bytes_per_chip // max(fabric.pods, 2), 1)
        t_step = simulate_transfers(graph, pairs, shard)
        steps = 2 * (fabric.pods - 1)
        return CollectiveEstimate(kind, fabric.pods, bytes_per_chip,
                                  t_step * steps, steps, "DCN ring across pods")
    raise ValueError(f"unknown collective {kind!r}")


def analytic_ring_seconds(bytes_per_chip: int, axis: int,
                          link_MBps: int = V5E_ICI_MBPS) -> float:
    """alpha-beta ring model for cross-checking the simulated estimate."""
    return 2 * (axis - 1) / axis * bytes_per_chip / (2 * link_MBps * 1e6)
