"""Real-world workload traces (ESF trace-based mode, paper §V-E).

The paper replays one-million-access memory traces of five representative
workloads (BTree, liblinear, redis, silo, XSBench) collected with the tool of
MQSim_CXL [61].  Those binary traces are not redistributable here, so this
module provides:

  * generators that synthesize traces with the published access-pattern
    statistics of each workload (read/write **mix degree** = min(read ratio,
    write ratio) — the x-axis of Fig. 20a —, spatial locality, working-set
    shape), clearly labeled as synthetic stand-ins; and
  * a loader for the MQSim_CXL-style CSV schema (``cycle,address,is_write``)
    so genuine traces drop in unchanged.

Mix degrees below follow the ordering visible in Fig. 20a (BTree and XSBench
read-dominated; silo the most mixed).
"""

from __future__ import annotations

import zlib

import numpy as np

# name -> (write_ratio, pattern, locality notes)
WORKLOADS = {
    # write_ratio, pattern
    "xsbench":   (0.02, "random"),    # MC neutronics: huge read-only lookups
    "btree":     (0.08, "pointer"),   # index probes, occasional inserts
    "liblinear": (0.18, "scan"),      # feature-matrix scans + model updates
    "redis":     (0.30, "zipf"),      # YCSB-style mixed GET/SET
    "silo":      (0.45, "oltp"),      # in-memory OLTP, read-modify-write
}


def mix_degree(is_write: np.ndarray) -> float:
    w = float(np.mean(is_write))
    return min(w, 1.0 - w)


def generate(name: str, n: int = 100_000, footprint_lines: int = 1 << 16,
             seed: int = 0) -> dict:
    """Synthesize a trace with the workload's characteristic statistics."""
    if name not in WORKLOADS:
        raise KeyError(f"unknown workload {name!r}; have {sorted(WORKLOADS)}")
    write_ratio, pattern = WORKLOADS[name]
    # stable per-workload stream: zlib.crc32 is process-independent, unlike
    # hash() under PYTHONHASHSEED randomization — traces must reproduce
    rng = np.random.default_rng(seed + zlib.crc32(name.encode()) % 65536)

    if pattern == "random":
        addr = rng.integers(0, footprint_lines, n)
    elif pattern == "pointer":
        # random walk through a tree: bursts of depth ~4 with random restarts
        restarts = rng.integers(0, footprint_lines, n)
        addr = restarts.copy()
        depth = rng.integers(0, 4, n)
        addr = (addr // (1 << depth) + depth) % footprint_lines
    elif pattern == "scan":
        # long sequential scans with occasional jumps
        jump = rng.random(n) < 0.01
        steps = np.where(jump, rng.integers(0, footprint_lines, n), 1)
        addr = np.cumsum(steps) % footprint_lines
    elif pattern == "zipf":
        ranks = rng.zipf(1.2, n)
        addr = (ranks * 2654435761) % footprint_lines
    elif pattern == "oltp":
        # hot rows + uniform tail; read-modify-write pairs
        hot = rng.random(n) < 0.6
        addr = np.where(hot, rng.integers(0, footprint_lines // 16, n),
                        rng.integers(0, footprint_lines, n))
    else:  # pragma: no cover
        raise AssertionError(pattern)

    is_write = rng.random(n) < write_ratio
    if pattern == "oltp":
        # RMW: a write tends to follow a read of the same line
        is_write[1:] &= True
        addr[1:] = np.where(is_write[1:], addr[:-1], addr[1:])
    return {
        "name": name,
        "addr": addr.astype(np.int64),
        "is_write": is_write.astype(bool),
        "mix_degree": mix_degree(is_write),
        "synthetic": True,
    }


ARRIVAL_PATTERNS = ("uniform", "poisson", "bursty", "periodic")


def arrival_times(n: int, mean_gap_ps: int = 2000,
                  pattern: str = "uniform", seed: int = 0,
                  burst_len: int = 64, duty: float = 0.25,
                  period: int = 4096) -> np.ndarray:
    """Issue times (ps, non-decreasing, first at 0) for an ``n``-request
    open-loop stream at a target mean inter-arrival gap.

      uniform    constant gap (the seed benches' implicit timing);
      poisson    exponential gaps — memoryless datacenter arrivals;
      bursty     ON-OFF: bursts of ``burst_len`` requests at ``duty`` of the
                 mean gap, separated by pauses that restore the mean rate —
                 the tail-stressing shape (queue builds inside every burst);
      periodic   sinusoid-modulated gap (±60 % over ``period`` requests) —
                 diurnal-style load swings.

    De-randomized like `generate`: crc32 of the pattern name folds into the
    seed, so streams reproduce across processes.
    """
    if pattern not in ARRIVAL_PATTERNS:
        raise KeyError(f"unknown arrival pattern {pattern!r}; "
                       f"have {ARRIVAL_PATTERNS}")
    rng = np.random.default_rng(
        seed + zlib.crc32(("arr:" + pattern).encode()) % 65536)
    if pattern == "uniform":
        gaps = np.full(n, mean_gap_ps, np.int64)
    elif pattern == "poisson":
        gaps = rng.exponential(mean_gap_ps, n).astype(np.int64)
    elif pattern == "bursty":
        on_gap = max(int(mean_gap_ps * duty), 1)
        pause = burst_len * mean_gap_ps - (burst_len - 1) * on_gap
        gaps = np.where(np.arange(n) % burst_len == 0,
                        np.int64(max(pause, 0)), np.int64(on_gap))
    else:  # periodic
        phase = 2.0 * np.pi * (np.arange(n) % period) / period
        gaps = (mean_gap_ps * (1.0 + 0.6 * np.sin(phase))).astype(np.int64)
    gaps = np.maximum(gaps, 0)
    if n:
        gaps[0] = 0
    return np.cumsum(gaps).astype(np.int64)


def tenant_mix(tenants, n: int = 10_000, footprint_lines: int = 4096,
               seed: int = 0) -> dict:
    """Multi-tenant trace: each named workload runs in a private partition
    of the footprint and requests interleave round-robin — the noisy-
    neighbour shape (one tenant's bursts queue behind another's scans on the
    shared fabric).  ``tenant`` gives each request's tenant index; tenant
    substreams are crc32-de-randomized and decorrelated by tenant slot."""
    tenants = list(tenants)
    t = max(len(tenants), 1)
    share = max(footprint_lines // t, 1)
    tid = (np.arange(n) % t).astype(np.int32)
    addr = np.zeros(n, np.int64)
    is_write = np.zeros(n, bool)
    for i, name in enumerate(tenants):
        m = tid == i
        tr = generate(name, n=int(m.sum()), footprint_lines=share,
                      seed=seed + 7919 * i)
        addr[m] = (tr["addr"] % share) + i * share
        is_write[m] = tr["is_write"]
    return {
        "name": "mix:" + "+".join(tenants),
        "addr": addr,
        "is_write": is_write,
        "tenant": tid,
        "mix_degree": mix_degree(is_write),
        "synthetic": True,
    }


def _block(name: str, m: int, footprint_lines: int, seed: int):
    """One (addr, is_write, rid-or-None) block; ``mix:a+b`` names build a
    `tenant_mix` whose tenant index doubles as the requester id."""
    if name.startswith("mix:"):
        tr = tenant_mix(name[4:].split("+"), n=m,
                        footprint_lines=footprint_lines, seed=seed)
        return (tr["addr"] % footprint_lines).astype(np.int32), \
            tr["is_write"], tr["tenant"]
    tr = generate(name, n=m, footprint_lines=footprint_lines, seed=seed)
    return (tr["addr"] % footprint_lines).astype(np.int32), \
        tr["is_write"], None


def request_stream(name: str, n: int = 10_000, footprint_lines: int = 4096,
                   n_requesters: int = 1, seed: int = 0,
                   chunk: int | None = None, timing: str | None = None,
                   mean_gap_ps: int = 2000):
    """Trace-driven request stream for the snoop-filter / coherence-fabric
    pipeline (paper §V-E trace mode driving the §V-B/§V-C machinery).

    Generates the named workload's synthetic trace, folds addresses into
    the DCOH footprint, and interleaves requesters round-robin — the same
    ``(addr, is_write, req_id)`` contract as
    `snoop_filter.make_skewed_stream`, so any bench accepting a stream
    source runs real-workload mixes unchanged.  Returns
    ``(addr, is_write, req_id)`` jnp arrays.

    Extensions (the streaming engine's front end):

      * ``name="mix:redis+silo"`` runs a `tenant_mix`; the tenant index
        becomes the requester id.
      * ``timing`` (an `ARRIVAL_PATTERNS` name) appends an ``issue_ps``
        array from `arrival_times` — a 4-tuple instead of 3.
      * ``chunk=m`` returns a **generator** of such tuples, ``m`` requests
        each, for `streaming.simulate_stream`-style consumption at flat
        memory.  Chunks are independent per-chunk substreams (block ``b``
        reseeds at ``seed + 1000003·b`` — chunked output is deterministic
        but intentionally *not* request-for-request equal to the monolithic
        trace); issue times chain across chunks so the stream stays
        time-ordered.
    """
    import jax.numpy as jnp

    if timing is None and chunk is not None:
        timing = "uniform"

    def emit(m, blk_seed, t0):
        addr, is_write, tenant = _block(name, m, footprint_lines, blk_seed)
        rid = (tenant if tenant is not None
               else (np.arange(m) % max(n_requesters, 1)).astype(np.int32))
        out = (jnp.asarray(addr), jnp.asarray(is_write), jnp.asarray(rid))
        if timing is None:
            return out
        iss = t0 + arrival_times(m, mean_gap_ps=mean_gap_ps,
                                 pattern=timing, seed=blk_seed)
        return out + (jnp.asarray(iss),)

    if chunk is None:
        return emit(n, seed, 0)

    def gen():
        t0 = 0
        b = 0
        left = n
        while left > 0:
            m = min(chunk, left)
            yield emit(m, seed + 1000003 * b, t0)
            t0 += m * mean_gap_ps
            b += 1
            left -= m

    return gen()


def load_csv(path: str) -> dict:
    """Load an MQSim_CXL-schema trace: lines of ``cycle,address,is_write``."""
    raw = np.loadtxt(path, delimiter=",", dtype=np.int64, ndmin=2)
    return {
        "name": path,
        "cycle": raw[:, 0],
        "addr": raw[:, 1] // 64,     # byte address -> line
        "is_write": raw[:, 2].astype(bool),
        "mix_degree": mix_degree(raw[:, 2].astype(bool)),
        "synthetic": False,
    }


def save_csv(path: str, trace: dict) -> None:
    n = len(trace["addr"])
    cyc = trace.get("cycle", np.arange(n, dtype=np.int64))
    np.savetxt(path, np.stack([cyc, trace["addr"] * 64,
                               trace["is_write"].astype(np.int64)], axis=1),
               fmt="%d", delimiter=",")
