"""Real-world workload traces (ESF trace-based mode, paper §V-E).

The paper replays one-million-access memory traces of five representative
workloads (BTree, liblinear, redis, silo, XSBench) collected with the tool of
MQSim_CXL [61].  Those binary traces are not redistributable here, so this
module provides:

  * generators that synthesize traces with the published access-pattern
    statistics of each workload (read/write **mix degree** = min(read ratio,
    write ratio) — the x-axis of Fig. 20a —, spatial locality, working-set
    shape), clearly labeled as synthetic stand-ins; and
  * a loader for the MQSim_CXL-style CSV schema (``cycle,address,is_write``)
    so genuine traces drop in unchanged.

Mix degrees below follow the ordering visible in Fig. 20a (BTree and XSBench
read-dominated; silo the most mixed).
"""

from __future__ import annotations

import zlib

import numpy as np

# name -> (write_ratio, pattern, locality notes)
WORKLOADS = {
    # write_ratio, pattern
    "xsbench":   (0.02, "random"),    # MC neutronics: huge read-only lookups
    "btree":     (0.08, "pointer"),   # index probes, occasional inserts
    "liblinear": (0.18, "scan"),      # feature-matrix scans + model updates
    "redis":     (0.30, "zipf"),      # YCSB-style mixed GET/SET
    "silo":      (0.45, "oltp"),      # in-memory OLTP, read-modify-write
}


def mix_degree(is_write: np.ndarray) -> float:
    w = float(np.mean(is_write))
    return min(w, 1.0 - w)


def generate(name: str, n: int = 100_000, footprint_lines: int = 1 << 16,
             seed: int = 0) -> dict:
    """Synthesize a trace with the workload's characteristic statistics."""
    if name not in WORKLOADS:
        raise KeyError(f"unknown workload {name!r}; have {sorted(WORKLOADS)}")
    write_ratio, pattern = WORKLOADS[name]
    # stable per-workload stream: zlib.crc32 is process-independent, unlike
    # hash() under PYTHONHASHSEED randomization — traces must reproduce
    rng = np.random.default_rng(seed + zlib.crc32(name.encode()) % 65536)

    if pattern == "random":
        addr = rng.integers(0, footprint_lines, n)
    elif pattern == "pointer":
        # random walk through a tree: bursts of depth ~4 with random restarts
        restarts = rng.integers(0, footprint_lines, n)
        addr = restarts.copy()
        depth = rng.integers(0, 4, n)
        addr = (addr // (1 << depth) + depth) % footprint_lines
    elif pattern == "scan":
        # long sequential scans with occasional jumps
        jump = rng.random(n) < 0.01
        steps = np.where(jump, rng.integers(0, footprint_lines, n), 1)
        addr = np.cumsum(steps) % footprint_lines
    elif pattern == "zipf":
        ranks = rng.zipf(1.2, n)
        addr = (ranks * 2654435761) % footprint_lines
    elif pattern == "oltp":
        # hot rows + uniform tail; read-modify-write pairs
        hot = rng.random(n) < 0.6
        addr = np.where(hot, rng.integers(0, footprint_lines // 16, n),
                        rng.integers(0, footprint_lines, n))
    else:  # pragma: no cover
        raise AssertionError(pattern)

    is_write = rng.random(n) < write_ratio
    if pattern == "oltp":
        # RMW: a write tends to follow a read of the same line
        is_write[1:] &= True
        addr[1:] = np.where(is_write[1:], addr[:-1], addr[1:])
    return {
        "name": name,
        "addr": addr.astype(np.int64),
        "is_write": is_write.astype(bool),
        "mix_degree": mix_degree(is_write),
        "synthetic": True,
    }


def request_stream(name: str, n: int = 10_000, footprint_lines: int = 4096,
                   n_requesters: int = 1, seed: int = 0):
    """Trace-driven request stream for the snoop-filter / coherence-fabric
    pipeline (paper §V-E trace mode driving the §V-B/§V-C machinery).

    Generates the named workload's synthetic trace, folds addresses into
    the DCOH footprint, and interleaves requesters round-robin — the same
    ``(addr, is_write, req_id)`` contract as
    `snoop_filter.make_skewed_stream`, so any bench accepting a stream
    source runs real-workload mixes unchanged.  Returns
    ``(addr, is_write, req_id)`` jnp arrays.
    """
    import jax.numpy as jnp

    tr = generate(name, n=n, footprint_lines=footprint_lines, seed=seed)
    addr = (tr["addr"] % footprint_lines).astype(np.int32)
    rid = (np.arange(n) % max(n_requesters, 1)).astype(np.int32)
    return jnp.asarray(addr), jnp.asarray(tr["is_write"]), jnp.asarray(rid)


def load_csv(path: str) -> dict:
    """Load an MQSim_CXL-schema trace: lines of ``cycle,address,is_write``."""
    raw = np.loadtxt(path, delimiter=",", dtype=np.int64, ndmin=2)
    return {
        "name": path,
        "cycle": raw[:, 0],
        "addr": raw[:, 1] // 64,     # byte address -> line
        "is_write": raw[:, 2].astype(bool),
        "mix_degree": mix_degree(raw[:, 2].astype(bool)),
        "synthetic": False,
    }


def save_csv(path: str, trace: dict) -> None:
    n = len(trace["addr"])
    cyc = trace.get("cycle", np.arange(n, dtype=np.int64))
    np.savetxt(path, np.stack([cyc, trace["addr"] * 64,
                               trace["is_write"].astype(np.int64)], axis=1),
               fmt="%d", delimiter=",")
