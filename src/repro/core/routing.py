"""Routing strategies over the PBR fabric (paper §V-A, Fig. 13).

Oblivious routing fixes each packet's path statically from (source,
destination) — the interconnect layer's default shortest path (alternative 0),
or hash-spread over the equal-cost set (ECMP flavour).  Adaptive routing picks
among equal-cost alternatives by congestion.  ESF switches adapt hop-by-hop;
here adaptation is expressed as fixpoint route re-selection: simulate, measure
per-channel busy time, re-route every transaction onto its least-loaded
equal-cost alternative, and repeat until the assignment stabilizes.  This is
the same control loop a PBR switch's adaptive arbiter converges to in steady
state, reformulated to keep the data plane tensorized.
"""

from __future__ import annotations

import numpy as np

from .devices import RequesterSpec, Workload, build_workload
from .engine import channel_stats, simulate
from .topology import FabricGraph

STRATEGIES = ("oblivious", "ecmp", "adaptive")


def _route_channels(graph: FabricGraph, src: int, dst: int, alt: int) -> list[int]:
    path = graph.route(src, dst, alt=alt)
    chans = []
    for u, v in zip(path[:-1], path[1:]):
        chans.append(graph.edge_channel(u, v)[0])
    for u, v in zip(path[::-1][:-1], path[::-1][1:]):
        chans.append(graph.edge_channel(u, v)[0])
    return chans


def route_and_simulate(graph: FabricGraph, specs, strategy: str = "oblivious",
                       adapt_iters: int = 4, seed: int = 0, **build_kw):
    """Build + schedule a workload under the given routing strategy.

    Returns (workload, schedule, per-channel stats dict).
    """
    assert strategy in STRATEGIES
    rng = np.random.default_rng(seed)

    wl = build_workload(graph, specs, **build_kw)
    # real transactions only: pseudo-rows (requester -1, e.g. credit-return
    # DLLPs) ride after the demand rows and their count is route-dependent —
    # route choices index the demand prefix (`Workload.n_demand`)
    n = wl.n_demand

    if strategy == "oblivious":
        sched = simulate(wl.hops, wl.channels, wl.issue_ps)
        return wl, sched, channel_stats(wl.hops, sched, wl.channels)

    # alternative-route universe per transaction
    n_alts = np.array([
        graph.n_route_alternatives(int(s), int(d))
        for s, d in zip(wl.requester[:n], wl.target[:n])
    ])
    if strategy == "ecmp":
        choice = rng.integers(0, 1 << 30, n) % n_alts
        wl = build_workload(graph, specs, route_choice=choice, **build_kw)
        sched = simulate(wl.hops, wl.channels, wl.issue_ps)
        return wl, sched, channel_stats(wl.hops, sched, wl.channels)

    # adaptive: incremental greedy congestion balancing.  A synchronous
    # everyone-flips update oscillates between spines (herd behaviour), so we
    # re-assign transactions one at a time against a live per-channel load
    # estimate — the steady state a per-packet adaptive arbiter converges to.
    alt_chans = {}
    for s, d in set(zip(wl.requester[:n].tolist(), wl.target[:n].tolist())):
        for a in range(graph.n_route_alternatives(s, d)):
            alt_chans[(s, d, a)] = _route_channels(graph, s, d, a)

    bw = np.asarray(wl.channels.bw_MBps, dtype=np.float64)
    load = np.zeros(graph.n_channels)
    contrib = 64.0 * 1e6 / np.maximum(bw, 1)  # ~per-packet channel time

    choice = np.zeros(n, dtype=np.int64)
    for j in range(n):  # initial: least-loaded insertion
        s, d = int(wl.requester[j]), int(wl.target[j])
        k = graph.n_route_alternatives(s, d)
        if k > 1:
            costs = [(load[alt_chans[(s, d, a)]]
                      * contrib[alt_chans[(s, d, a)]]).sum() for a in range(k)]
            choice[j] = int(np.argmin(costs))
        load[alt_chans[(s, d, int(choice[j]))]] += 1

    sched = stats = None
    for _ in range(adapt_iters):
        wl = build_workload(graph, specs, route_choice=choice, **build_kw)
        sched = simulate(wl.hops, wl.channels, wl.issue_ps)
        stats = channel_stats(wl.hops, sched, wl.channels)
        busy = np.asarray(stats["busy_ps"]).astype(np.float64)
        changed = 0
        order = rng.permutation(n)
        for j in order:
            s, d = int(wl.requester[j]), int(wl.target[j])
            k = graph.n_route_alternatives(s, d)
            if k <= 1:
                continue
            cur = int(choice[j])
            busy[alt_chans[(s, d, cur)]] -= contrib[alt_chans[(s, d, cur)]] * 1e6
            costs = [busy[alt_chans[(s, d, a)]].sum() for a in range(k)]
            new = int(np.argmin(costs))
            busy[alt_chans[(s, d, new)]] += contrib[alt_chans[(s, d, new)]] * 1e6
            if new != cur:
                choice[j] = new
                changed += 1
        if changed == 0:
            break
    return wl, sched, stats
