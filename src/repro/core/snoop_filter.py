"""Device coherency agent (DCOH): device-side inclusive snoop filter.

ESF §III-D: devices with device-managed coherence (HDM-DB mode) carry a DCOH;
the reference implementation is an *inclusive* snoop filter (SF) — a fully
associative buffer recording every cacheline of the device's HDM that any
requester currently caches, with coherence state + owner list per entry.  When
an entry must be cleared (conflict or capacity victim), the SF sends
Back-Invalidate Snoops (BISnp) to the owners and waits for BIRsp before
serving the new request.  Victim selection is modularized (paper §V-B studies
FIFO/LRU/LFI/LIFO/MRU; §V-C adds block-length-prioritized selection driving
InvBlk commands that clear up to 4 address-contiguous entries per BISnp).

Tensorization: the protocol is inherently sequential, so it runs as a
``lax.scan`` over the request stream; per-step state (requester cache tags,
SF tags/owners/metadata, the LFI global insert-count table, an address
presence bitmap for InvBlk run detection) is dense and fixed-shape.  The whole
sweep over victim policies jits once per policy and runs in milliseconds —
and coherence invariants (inclusivity, owner consistency) are checked by
property tests over the traced state history.

Timing model (closed loop, per paper §V-B setup): the requester's local cache
filters hits; a miss pays the link round trip + device controller + SF
processing; any required BISnp adds a BISnp round trip (plus per-extra-line
cache access cost and bus occupancy for InvBlk flows).  The §V-B bus is
configured with infinite bandwidth (transfer_ps=0) to isolate SF behaviour,
exactly as in the paper; the §V-C InvBlk study uses a finite bus.

Fabric coupling (`core.coherence_traffic`): the analytic miss/BISnp
constants above describe an *isolated* device on an infinite bus.  Two
hooks close the loop with the fabric engine without touching the default
path:

  * ``return_events=True`` additionally returns a dense per-request
    `SFEvents` log — the protocol decisions (hit/miss, BISnp target owner
    mask, InvBlk run length, writeback lines) plus a per-request issue
    clock (every request, hits included — the hook the upgrade-BISnp
    lowering issues its fork groups at).  Decisions depend only on the
    request stream order, never on latencies, so the log is a fixed point
    of the outer coupling loop by construction.
  * ``fabric_lat_ps`` (per-request int64) replaces the whole analytic
    miss path (bus + link RTT + controller + BISnp round trips +
    writebacks) with a measured fabric latency: ``lat_miss = t_cache +
    fabric_lat_ps[i] + t_sf``.  ``None`` — the default — compiles the
    exact pre-coupling scan.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

POLICIES = ("fifo", "lru", "lfi", "lifo", "mru", "blp")

_BIG = jnp.int64(1) << 40
_SMALL = jnp.int64(1) << 36


@dataclass(frozen=True)
class SFConfig:
    capacity: int
    policy: str = "fifo"
    invblk_max: int = 1            # 1 = plain BISnp; 2..4 = InvBlk lengths
    footprint_lines: int = 4096
    # timing (picoseconds)
    t_cache_ps: int = 12_000       # Table III cache access
    t_sf_ps: int = 12_000          # SF lookup/update
    miss_path_ps: int = 122_000    # link RTT + controller + DRAM on a miss
    bisnp_rtt_ps: int = 64_000     # BISnp/BIRsp round trip
    writeback_ps: int = 15_000     # dirty flush to endpoint
    probe_conflict_ps: int = 6_000  # DCOH response-assembly serialization per
    # extra InvBlk line beyond the first pair (owner cache probes and BIRsp
    # collection serialize; grows superlinearly with block length, §V-C)
    line_bytes: int = 64
    bus_MBps: int = 0              # 0 = infinite bus (paper §V-B isolation)


@dataclass(frozen=True)
class CacheConfig:
    capacity: int
    t_cache_ps: int = 12_000


class SFEvents(NamedTuple):
    """Dense per-request protocol-decision log (fabric lowering contract).

    Decisions are functions of the request stream order only (the scan
    processes requests in input order regardless of clocks), so the log is
    identical whether latencies come from the analytic constants or from a
    fabric measurement — the invariant `core.coherence_traffic` relies on.

    ``fab_issue_ps`` is recorded for **every** request, hits included: it
    is the per-requester clock after the local cache access (``t +
    t_cache``) — the moment a miss leaves the requester, and the issue
    clock of the upgrade-BISnp fork group a write-conflict *hit* triggers
    (`coherence_traffic.lower_coherence(fanout="concurrent")`; the hit's
    own latency never sees the fabric, preserving the seed's
    "hits never leave the requester" timing bit-exactly).
    """

    fab_issue_ps: jnp.ndarray   # (T,) per-request issue clock (see above)
    cache_hit: jnp.ndarray      # (T,) bool — hits never reach the fabric
    bisnp_mask: jnp.ndarray     # (T,) int32 bitmask of snooped requesters
    inv_lines: jnp.ndarray      # (T,) int32 lines invalidated by this request
    wb_lines: jnp.ndarray       # (T,) int32 dirty lines flushed (writeback)
    need_victim: jnp.ndarray    # (T,) bool capacity victim selected
    conflict: jnp.ndarray       # (T,) bool write-conflict BISnp
    invblk_len: jnp.ndarray     # (T,) int32 InvBlk run length (0 if none)


class SFState(NamedTuple):
    """Dense per-step protocol state of the `simulate_sf` scan (hoisted to
    module level so chunked streaming can thread it across calls: protocol
    decisions depend only on request order, so running a stream chunk by
    chunk with the state carried — `sf_init_state` / ``init_state=`` /
    ``return_state=True`` — reproduces the monolithic scan bit-exactly)."""

    cache_tag: jnp.ndarray   # (R, Cc) int32, -1 empty
    cache_seq: jnp.ndarray   # (R, Cc) int64 LRU stamps
    sf_tag: jnp.ndarray      # (Cs,) int32, -1 empty
    sf_owner: jnp.ndarray    # (Cs,) int32 bitmask
    sf_dirty: jnp.ndarray    # (Cs,) bool
    sf_ins: jnp.ndarray      # (Cs,) int64 insertion stamps
    sf_acc: jnp.ndarray      # (Cs,) int64 access stamps
    lfi_count: jnp.ndarray   # (F,) int32 per-address insert counts
    present: jnp.ndarray     # (F,) bool SF presence bitmap
    clock: jnp.ndarray       # (R,) int64 per-requester time
    bus_free: jnp.ndarray    # () int64
    seq: jnp.ndarray         # () int64
    bisnp: jnp.ndarray       # () int64
    inval: jnp.ndarray       # () int64


def sf_init_state(sf_cfg: SFConfig, cache_cfg: CacheConfig,
                  n_requesters: int = 1) -> SFState:
    """Cold protocol state (what `simulate_sf` starts from by default)."""
    R, Cc, Cs = n_requesters, cache_cfg.capacity, sf_cfg.capacity
    F = sf_cfg.footprint_lines
    return SFState(
        cache_tag=jnp.full((R, Cc), -1, jnp.int32),
        cache_seq=jnp.zeros((R, Cc), jnp.int64),
        sf_tag=jnp.full((Cs,), -1, jnp.int32),
        sf_owner=jnp.zeros((Cs,), jnp.int32),
        sf_dirty=jnp.zeros((Cs,), bool),
        sf_ins=jnp.zeros((Cs,), jnp.int64),
        sf_acc=jnp.zeros((Cs,), jnp.int64),
        lfi_count=jnp.zeros((F,), jnp.int32),
        present=jnp.zeros((F,), bool),
        clock=jnp.zeros((R,), jnp.int64),
        bus_free=jnp.int64(0),
        seq=jnp.int64(1),
        bisnp=jnp.int64(0),
        inval=jnp.int64(0),
    )


class SFResult(NamedTuple):
    latency_ps: jnp.ndarray       # (T,) per-request latency
    cache_hit: jnp.ndarray        # (T,) bool
    bisnp_events: jnp.ndarray     # () total BISnp requests sent
    invalidated_lines: jnp.ndarray  # () total lines invalidated
    total_time_ps: jnp.ndarray    # () max requester clock
    bandwidth_MBps: jnp.ndarray   # () delivered line bytes / total time
    # traced state history for invariant property tests (sampled per step):
    owner_lines: jnp.ndarray      # (T,) lines owned in SF by requester 0
    cached_lines: jnp.ndarray     # (T,) lines present in requester 0 cache
    # final protocol state (for inclusivity/owner-consistency checks):
    final_sf_tag: jnp.ndarray     # (Cs,)
    final_sf_owner: jnp.ndarray   # (Cs,)
    final_cache_tag: jnp.ndarray  # (R, Cc)


def owner_count(mask: jnp.ndarray) -> jnp.ndarray:
    """Popcount of requester bitmasks (`SFEvents.bisnp_mask`) — the BISnp
    fan-out of each request.  Branch-free SWAR on uint32; jit/vmap-safe."""
    v = jnp.asarray(mask).astype(jnp.uint32)
    v = v - ((v >> 1) & 0x55555555)
    v = (v & 0x33333333) + ((v >> 2) & 0x33333333)
    v = (v + (v >> 4)) & 0x0F0F0F0F
    return ((v * 0x01010101) >> 24).astype(jnp.int32)


def _victim_scores(policy: str, sf_tag, sf_ins, sf_acc, lfi_count, runlen):
    """Lower score = better victim.  Invalid entries are excluded by caller."""
    if policy == "fifo":
        return sf_ins
    if policy == "lifo":
        return -sf_ins
    if policy == "lru":
        return sf_acc
    if policy == "mru":
        return -sf_acc
    if policy == "lfi":
        # least frequently inserted address; ties broken LIFO
        cnt = lfi_count[jnp.clip(sf_tag, 0, lfi_count.shape[0] - 1)]
        return cnt.astype(jnp.int64) * _BIG + (_SMALL - sf_ins)
    if policy == "blp":
        # block-length-prioritized: longest contiguous run, ties broken LIFO
        return -(runlen.astype(jnp.int64) * _BIG + sf_ins)
    raise ValueError(f"unknown policy {policy!r}")


@functools.partial(jax.jit, static_argnames=("sf_cfg", "cache_cfg",
                                              "n_requesters", "return_events",
                                              "return_state"))
def simulate_sf(addr: jnp.ndarray, is_write: jnp.ndarray, req_id: jnp.ndarray,
                sf_cfg: SFConfig, cache_cfg: CacheConfig,
                n_requesters: int = 1,
                fabric_lat_ps: jnp.ndarray | None = None,
                return_events: bool = False,
                init_state: SFState | None = None,
                return_state: bool = False):
    """Run the DCOH protocol over a merged request stream.

    addr      (T,) int32 line addresses in [0, footprint)
    is_write  (T,) bool
    req_id    (T,) int32 in [0, n_requesters)

    ``fabric_lat_ps`` (optional, (T,) int64) replaces the analytic miss
    path with per-request fabric-measured latencies (`core.
    coherence_traffic` feedback); ``return_events=True`` returns
    ``(SFResult, SFEvents)``.  The defaults compile the exact isolated
    scan, bit for bit.

    ``init_state`` (an `SFState`, e.g. a previous call's ``return_state``
    output) resumes the protocol scan mid-stream: decisions depend only on
    request order, so chunked runs threading the state equal the monolithic
    scan bit for bit.  Carried clocks/counters are cumulative, so a chunk's
    ``total_time_ps`` / ``bisnp_events`` are absolute (streaming callers
    diff across chunks if they want per-chunk figures; ``bandwidth_MBps``
    divides only this chunk's bytes and is meaningful on the last chunk).
    ``return_state=True`` appends the final `SFState` to the return tuple.
    """
    T = addr.shape[0]
    R, Cc, Cs = n_requesters, cache_cfg.capacity, sf_cfg.capacity
    F = sf_cfg.footprint_lines

    transfer_ps = (
        0 if sf_cfg.bus_MBps == 0
        else (sf_cfg.line_bytes * 1_000_000_000_000) // (sf_cfg.bus_MBps * 1_000_000)
    )

    S = SFState
    init = (sf_init_state(sf_cfg, cache_cfg, n_requesters)
            if init_state is None else init_state)

    maxlen = max(int(sf_cfg.invblk_max), 1)

    def step(s: SFState, x):
        if fabric_lat_ps is None:
            a, w, r = x
        else:
            a, w, r, fab = x
        t = s.clock[r]
        rbit = jnp.int32(1) << r

        # ---- requester local cache -------------------------------------
        cline = s.cache_tag[r] == a
        chit = jnp.any(cline)
        lat_hit = jnp.int64(cache_cfg.t_cache_ps)

        # ---- miss path: bus + controller + SF ---------------------------
        t_bus_ready = jnp.maximum(t + lat_hit, s.bus_free)
        sline = s.sf_tag == a
        sf_hit = jnp.any(sline)

        # conflict: write while other requesters own the line
        owners_a = jnp.sum(jnp.where(sline, s.sf_owner, 0)).astype(jnp.int32)
        others = owners_a & ~rbit
        conflict = sf_hit & w & (others != 0)

        # capacity: SF full and no entry for a
        sf_valid = s.sf_tag >= 0
        sf_full = jnp.all(sf_valid)
        need_victim = (~sf_hit) & sf_full

        # ---- victim selection (policy) ----------------------------------
        run = jnp.ones((Cs,), jnp.int32)
        for d in range(1, maxlen):
            nxt = jnp.clip(s.sf_tag + d, 0, F - 1)
            step_ok = (run == d) & s.present[nxt] & ((s.sf_tag + d) < F)
            run = run + step_ok.astype(jnp.int32)
        scores = _victim_scores(sf_cfg.policy, s.sf_tag, s.sf_ins, s.sf_acc,
                                s.lfi_count, run)
        scores = jnp.where(sf_valid, scores, jnp.int64(1) << 60)
        victim = jnp.argmin(scores)
        v_tag = s.sf_tag[victim]
        v_len = jnp.minimum(run[victim], maxlen)

        # lines cleared by the (Inv)Blk BISnp: v_tag .. v_tag+v_len-1
        offs = jnp.arange(maxlen, dtype=jnp.int32)
        blk_addrs = v_tag + offs
        blk_live = (offs < v_len) & need_victim
        clear_entry = need_victim & jnp.isin(s.sf_tag, jnp.where(blk_live, blk_addrs, -7))
        n_clear = jnp.sum(clear_entry)
        any_dirty = jnp.any(clear_entry & s.sf_dirty)

        # BISnp also invalidates the lines in the owners' caches (the feedback
        # that makes FIFO/LRU victimization of hot lines expensive, Fig. 14)
        cleared_tags = jnp.where(clear_entry, s.sf_tag, -7)
        cache_inval = jnp.isin(s.cache_tag, cleared_tags) & (s.cache_tag >= 0)
        # conflict BISnp invalidates line a in other requesters' caches
        mask_others = (jnp.arange(R)[:, None] != r) & conflict
        cache_inval = cache_inval | ((s.cache_tag == a) & mask_others)

        do_bisnp = need_victim | conflict
        lat_bisnp = jnp.where(do_bisnp, sf_cfg.bisnp_rtt_ps, 0)
        extra = jnp.maximum(v_len - 1, 0).astype(jnp.int64)
        lat_bisnp += jnp.where(
            need_victim,
            extra * sf_cfg.t_cache_ps + extra * extra * sf_cfg.probe_conflict_ps,
            0,
        )
        n_dirty = jnp.sum(clear_entry & s.sf_dirty)
        lat_wb = jnp.where(any_dirty, n_dirty * sf_cfg.writeback_ps, 0)

        # bus occupancy: miss transfer + InvBlk flush data competes (Fig. 15)
        bus_occupancy = transfer_ps * (1 + jnp.where(need_victim, v_len, 0))
        lat_bus = (t_bus_ready - (t + lat_hit)) + transfer_ps

        if fabric_lat_ps is None:
            lat_miss = (lat_hit + lat_bus + sf_cfg.miss_path_ps
                        + sf_cfg.t_sf_ps + lat_bisnp + lat_wb)
        else:
            # fabric coupling: the measured round trip subsumes the bus,
            # link RTT, controller, BISnp legs and writebacks
            lat_miss = lat_hit + fab + jnp.int64(sf_cfg.t_sf_ps)
        latency = jnp.where(chit, lat_hit, lat_miss)

        # ---- state updates ----------------------------------------------
        seq = s.seq
        # cache: on hit refresh LRU; on miss allocate LRU victim slot
        cache_tag = jnp.where(cache_inval, -1, s.cache_tag)
        cache_seq = jnp.where(cache_inval, 0, s.cache_seq)
        row_tag, row_seq = cache_tag[r], cache_seq[r]
        hit_slot = jnp.argmax(row_tag == a)
        empty = row_tag < 0
        fill_slot = jnp.where(jnp.any(empty), jnp.argmax(empty), jnp.argmin(row_seq))
        slot = jnp.where(chit, hit_slot, fill_slot)
        row_tag = row_tag.at[slot].set(a)
        row_seq = row_seq.at[slot].set(seq)
        cache_tag = cache_tag.at[r].set(row_tag)
        cache_seq = cache_seq.at[r].set(row_seq)

        # SF: clear victims, then upsert entry for a (only on cache miss —
        # hits are filtered by the local cache and never reach the device)
        upsert = ~chit
        sf_tag = jnp.where(clear_entry, -1, s.sf_tag)
        sf_owner = jnp.where(clear_entry, 0, s.sf_owner)
        sf_dirty = jnp.where(clear_entry, False, s.sf_dirty)
        sf_ins = jnp.where(clear_entry, 0, s.sf_ins)
        sf_acc = jnp.where(clear_entry, 0, s.sf_acc)
        sf_owner = jnp.where((s.sf_tag == a) & conflict, rbit, sf_owner)

        entry_live = sf_tag == a
        have_entry = jnp.any(entry_live)
        free = sf_tag < 0
        new_slot = jnp.argmax(free)  # guaranteed free after clearing victims
        tgt = jnp.where(have_entry, jnp.argmax(entry_live), new_slot)
        sf_tag = jnp.where(upsert, sf_tag.at[tgt].set(a), sf_tag)
        sf_owner = jnp.where(upsert, sf_owner.at[tgt].set(sf_owner[tgt] | rbit), sf_owner)
        sf_dirty = jnp.where(upsert, sf_dirty.at[tgt].set(sf_dirty[tgt] | w), sf_dirty)
        sf_ins = jnp.where(upsert & ~have_entry, sf_ins.at[tgt].set(seq), sf_ins)
        sf_acc = jnp.where(upsert, sf_acc.at[tgt].set(seq), sf_acc)

        present = s.present
        blk_idx = jnp.clip(blk_addrs, 0, F - 1)
        present = present.at[blk_idx].set(present[blk_idx] & ~blk_live)
        present = jnp.where(upsert, present.at[a].set(True), present)
        lfi_count = jnp.where(
            upsert & ~have_entry, s.lfi_count.at[a].add(1), s.lfi_count
        )

        new = S(
            cache_tag=cache_tag, cache_seq=cache_seq,
            sf_tag=sf_tag, sf_owner=sf_owner, sf_dirty=sf_dirty,
            sf_ins=sf_ins, sf_acc=sf_acc,
            lfi_count=lfi_count, present=present,
            clock=s.clock.at[r].set(t + latency),
            bus_free=jnp.where(chit, s.bus_free, t_bus_ready + bus_occupancy),
            seq=seq + 1,
            bisnp=s.bisnp + do_bisnp,
            inval=s.inval + jnp.where(need_victim, n_clear, 0) + conflict,
        )
        out = (
            latency, chit,
            jnp.sum((sf_owner_bit := (new.sf_owner & 1) > 0) & (new.sf_tag >= 0)),
            jnp.sum(new.cache_tag[0] >= 0),
        )
        if return_events:
            # BISnp targets: owners of cleared victim lines, plus the other
            # requesters on a write conflict (R is static and small)
            vmask = jnp.int32(0)
            for rr in range(R):
                owned = jnp.any(clear_entry & (((s.sf_owner >> rr) & 1) > 0))
                vmask = vmask | jnp.where(owned, jnp.int32(1 << rr),
                                          jnp.int32(0))
            bisnp_mask = (jnp.where(need_victim, vmask, 0)
                          | jnp.where(conflict, others, 0)).astype(jnp.int32)
            out = out + (
                t + lat_hit,
                bisnp_mask,
                (jnp.where(need_victim, n_clear, 0)
                 + conflict.astype(jnp.int64)).astype(jnp.int32),
                jnp.where(any_dirty, n_dirty, 0).astype(jnp.int32),
                need_victim, conflict,
                jnp.where(need_victim, v_len, 0).astype(jnp.int32),
            )
        return new, out

    xs = (addr.astype(jnp.int32), is_write, req_id.astype(jnp.int32))
    if fabric_lat_ps is not None:
        xs = xs + (jnp.asarray(fabric_lat_ps, jnp.int64),)
    final, outs = jax.lax.scan(step, init, xs)
    lat, chit, owner0, cached0 = outs[:4]
    total = jnp.max(final.clock)
    bw = (T * sf_cfg.line_bytes * jnp.int64(1_000_000_000_000)
          // jnp.maximum(total, 1) // 1_000_000)
    res = SFResult(
        latency_ps=lat, cache_hit=chit,
        bisnp_events=final.bisnp, invalidated_lines=final.inval,
        total_time_ps=total, bandwidth_MBps=bw,
        owner_lines=owner0, cached_lines=cached0,
        final_sf_tag=final.sf_tag, final_sf_owner=final.sf_owner,
        final_cache_tag=final.cache_tag,
    )
    out = (res,)
    if return_events:
        fab_issue, bisnp_mask, inv_lines, wb_lines, need_victim, conflict, \
            invblk_len = outs[4:]
        out = out + (SFEvents(
            fab_issue_ps=fab_issue, cache_hit=chit, bisnp_mask=bisnp_mask,
            inv_lines=inv_lines, wb_lines=wb_lines, need_victim=need_victim,
            conflict=conflict, invblk_len=invblk_len,
        ),)
    if return_state:
        out = out + (final,)
    return out if len(out) > 1 else res


def make_skewed_stream(n: int, footprint: int, hot_frac: float = 0.1,
                       hot_ratio: float = 0.9, write_ratio: float = 0.0,
                       n_requesters: int = 1, seed: int = 0):
    """Paper §V-B request pattern: 90% of accesses to the hot 10% of lines."""
    rng = np.random.default_rng(seed)
    hot_n = max(int(footprint * hot_frac), 1)
    is_hot = rng.random(n) < hot_ratio
    addr = np.where(is_hot, rng.integers(0, hot_n, n),
                    hot_n + rng.integers(0, footprint - hot_n, n)).astype(np.int32)
    wr = rng.random(n) < write_ratio
    rid = (np.arange(n) % n_requesters).astype(np.int32)
    return jnp.asarray(addr), jnp.asarray(wr), jnp.asarray(rid)


def make_sequential_stream(n: int, footprint: int, n_requesters: int = 2,
                           write_ratio: float = 0.0, seed: int = 0):
    """Paper §V-C pattern: requesters issue sequential (streaming) addresses."""
    rng = np.random.default_rng(seed)
    per = n // n_requesters
    addr = np.concatenate(
        [np.arange(per, dtype=np.int32) % footprint for _ in range(n_requesters)]
    )
    rid = np.concatenate(
        [np.full(per, r, np.int32) for r in range(n_requesters)]
    )
    order = np.arange(per * n_requesters).reshape(n_requesters, per).T.reshape(-1)
    wr = rng.random(per * n_requesters) < write_ratio
    return jnp.asarray(addr[order]), jnp.asarray(wr), jnp.asarray(rid[order])
