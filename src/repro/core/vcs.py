"""Virtual CXL Switch configurations (paper §II-B, Fig. 3).

A physical CXL switch can present as:

  * a **Single VCS** — one upstream port (USP), N downstream ports (DSP),
    connected by virtual PCI-to-PCI bridges (vPPB): PCIe-compatible, behaves
    like a PCIe switch with CXL link/transaction layers;
  * a **Multiple VCS** — several USPs, each exposing its own Single-VCS view;
    the DSP->USP *binding* is dynamic and even software-composable during
    execution, and one physical DSP can expose multiple **logical devices**
    (resource pooling) bound to different USPs;
  * a **PBR fabric switch** — edge ports with 12-bit port IDs, non-tree
    topologies, true peer-to-peer (modeled by `core.topology` directly).

This module models the first two on top of the interconnect layer: a VCS
compiles down to a Topology fragment whose connectivity *is* the current
binding table, so rebinding = rebuilding routes (exactly how ESF's switch
rebuilds its routing table from interconnect-layer data).  The binding/pool
invariants (a logical device serves exactly one USP at a time; rebinding
moves capacity without physical re-cabling) are property-tested.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .link_layer import FlitConfig
from .topology import (MEMORY, REQUESTER, SWITCH, EndpointSpec, LinkSpec,
                       Topology)


@dataclass
class LogicalDevice:
    """A slice of a physical device under a DSP (resource pooling)."""

    phys_id: int
    fraction: float = 1.0
    bound_usp: int | None = None


@dataclass
class MultiVCS:
    """A multi-USP virtual switch over one physical switch.

    hosts: node descriptors for each USP's root port (requesters).
    devices: physical memory devices under the DSPs; each may be split into
    logical devices bound to different USPs.
    """

    n_usp: int
    n_logical_per_device: int = 1
    bw_MBps: int = 64_000
    fixed_ps: int = 26_000
    devices: int = 4
    pool: list[LogicalDevice] = field(default_factory=list)
    # link layer of every vPPB link (host<->USP and DSP<->device): a
    # FlitConfig / mode string moves the whole VCS between CXL 2.0 (68 B
    # flits) and CXL 3.x (256 B flits); None keeps byte-exact seed semantics.
    # Reliability rides along: a FlitConfig(reliability="stochastic") makes
    # every vPPB link sample seeded per-flit replays + retraining stalls
    # (each channel gets its own substream, so one seed covers the fabric)
    flit: FlitConfig | str | None = None

    def __post_init__(self):
        if not self.pool:
            self.pool = [
                LogicalDevice(phys_id=d, fraction=1.0 / self.n_logical_per_device)
                for d in range(self.devices)
                for _ in range(self.n_logical_per_device)
            ]
            # default: round-robin binding across USPs
            for i, ld in enumerate(self.pool):
                ld.bound_usp = i % self.n_usp

    # ------------------------------------------------------------------
    def bind(self, logical_idx: int, usp: int) -> None:
        """Dynamic DSP->USP (re)binding — software-composed, no re-cabling."""
        if not 0 <= usp < self.n_usp:
            raise ValueError(f"usp {usp} out of range")
        self.pool[logical_idx].bound_usp = usp

    def visible_capacity(self, usp: int) -> float:
        """Memory capacity fraction currently visible to a USP."""
        return sum(ld.fraction for ld in self.pool if ld.bound_usp == usp)

    def check_invariants(self) -> None:
        for ld in self.pool:
            assert ld.bound_usp is None or 0 <= ld.bound_usp < self.n_usp
        # one physical device's logical slices never exceed the device
        by_phys: dict[int, float] = {}
        for ld in self.pool:
            by_phys[ld.phys_id] = by_phys.get(ld.phys_id, 0.0) + ld.fraction
        assert all(f <= 1.0 + 1e-9 for f in by_phys.values())

    # ------------------------------------------------------------------
    def build_topology(self) -> tuple[Topology, dict]:
        """Materialize the current binding as a Topology.

        Each USP's Single-VCS view is one switch node; a logical device
        attaches to the switch of the USP it is bound to, with bandwidth
        scaled by its pooling fraction (the paper's resource-isolation
        semantics).  Unbound logical devices are not reachable.
        """
        self.check_invariants()
        kinds: list[int] = []
        links: list[LinkSpec] = []

        def add(kind):
            kinds.append(kind)
            return len(kinds) - 1

        hosts = [add(REQUESTER) for _ in range(self.n_usp)]
        vcs = [add(SWITCH) for _ in range(self.n_usp)]
        for h, s in zip(hosts, vcs):
            links.append(LinkSpec(h, s, self.bw_MBps, self.fixed_ps,
                                  flit=self.flit))
        mapping = {"hosts": hosts, "vcs": vcs, "logical": []}
        for ld in self.pool:
            if ld.bound_usp is None:
                mapping["logical"].append(None)
                continue
            m = add(MEMORY)
            mapping["logical"].append(m)
            links.append(LinkSpec(
                vcs[ld.bound_usp], m,
                max(int(self.bw_MBps * ld.fraction), 1), self.fixed_ps,
                flit=self.flit))
        topo = Topology(np.asarray(kinds, np.int64), links, name="multi-vcs",
                        endpoint=EndpointSpec())
        return topo, mapping
