"""Fabric-aware sharding autotuner (beyond-paper application of ESF).

Enumerates candidate parallel layouts for a transformer stack on the
production mesh, scores each with a three-term roofline (compute / HBM /
collectives) where the collective term comes from the ESF fabric engine
(`core.fabric_model`) rather than a closed-form alpha-beta guess, and ranks
them.  This is the paper's "simulate the interconnect to design the system"
loop pointed at our own framework; the §Perf hillclimbs use it to pick
candidates before re-lowering.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .fabric_model import (
    TPUFabric, V5E_DCN_MBPS, V5E_HBM_BPS, V5E_ICI_MBPS, V5E_PEAK_FLOPS,
    analytic_ring_seconds, predict_collective,
)


@dataclass(frozen=True)
class WorkloadDims:
    """Per-step model/workload dimensions (training unless decode=True)."""

    n_layers: int
    d_model: int
    d_ff: int
    n_heads: int
    n_kv: int
    head_dim: int
    vocab: int
    batch: int
    seq: int
    n_experts: int = 0
    top_k: int = 0
    decode: bool = False

    @property
    def layer_params(self) -> int:
        att = self.d_model * (self.n_heads + 2 * self.n_kv) * self.head_dim \
            + self.n_heads * self.head_dim * self.d_model
        ff = 3 * self.d_model * self.d_ff
        if self.n_experts:
            ff *= self.n_experts
        return att + ff

    @property
    def params(self) -> int:
        return self.n_layers * self.layer_params + self.vocab * self.d_model

    @property
    def active_params(self) -> int:
        att = self.d_model * (self.n_heads + 2 * self.n_kv) * self.head_dim \
            + self.n_heads * self.head_dim * self.d_model
        ff = 3 * self.d_model * self.d_ff * (self.top_k or 1) \
            * (1 if self.n_experts else 1)
        return self.n_layers * (att + ff) + self.vocab * self.d_model


@dataclass(frozen=True)
class Layout:
    """One candidate distribution layout on the (pod, data, model) mesh."""

    name: str
    batch_over: tuple[str, ...] = ("pod", "data")
    fsdp: bool = True              # shard params over 'data' + gather per layer
    tp: bool = True                # shard heads/mlp over 'model'
    seq_shard: bool = False        # sequence parallelism for activations
    zero_pod: bool = True          # optimizer state sharded across pods


DEFAULT_CANDIDATES = (
    Layout("fsdp+tp", fsdp=True, tp=True),
    Layout("fsdp-only", fsdp=True, tp=False),
    Layout("tp-only", fsdp=False, tp=True),
    Layout("fsdp+tp+sp", fsdp=True, tp=True, seq_shard=True),
    Layout("ddp", fsdp=False, tp=False),
)


@dataclass
class Score:
    layout: Layout
    compute_s: float
    memory_s: float
    collective_s: float
    step_s: float
    hbm_bytes_per_chip: float
    detail: dict = field(default_factory=dict)

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)


def score_layout(dims: WorkloadDims, layout: Layout, fabric: TPUFabric,
                 graph=None, use_engine: bool = False) -> Score:
    """Roofline-score one layout.  With use_engine=True the collective term is
    simulated on the fabric graph (exact contention); otherwise the analytic
    ring model is used (fast path for wide sweeps)."""
    chips = fabric.pods * fabric.nx * fabric.ny
    data_ax, model_ax = fabric.nx, fabric.ny
    dp = fabric.pods * data_ax if "pod" in layout.batch_over else data_ax
    tp = model_ax if layout.tp else 1

    # ---- compute: 6ND for train, 2ND for decode ----
    flops = (2 if dims.decode else 6) * dims.active_params * dims.batch * dims.seq
    if dims.decode:
        flops = 2 * dims.active_params * dims.batch  # one token per sequence
    compute_s = flops / (chips * V5E_PEAK_FLOPS)

    # ---- memory: weights + activations traffic per chip ----
    shard = (dp if layout.fsdp else 1) * tp
    wbytes = 2 * dims.params / shard
    passes = 1 if dims.decode else 3  # fwd + bwd(2x) weight reads
    act = 2 * dims.batch * dims.seq * dims.d_model * dims.n_layers / max(dp, 1) \
        / (tp if layout.seq_shard else 1)
    kv = (2 * dims.batch * dims.seq * dims.n_kv * dims.head_dim * 2
          * dims.n_layers / max(dp, 1) / max(tp if dims.n_kv >= tp else 1, 1)
          if dims.decode else 0)
    hbm = passes * wbytes + 4 * act + kv
    memory_s = hbm / V5E_HBM_BPS

    # ---- collectives ----
    coll_s = 0.0
    detail = {}

    def ring(nbytes, axis, kind="all_reduce"):
        if use_engine and graph is not None:
            return predict_collective(fabric, graph, kind, axis, int(nbytes)).seconds
        ax = fabric.nx if axis == "x" else fabric.ny
        t = analytic_ring_seconds(int(nbytes), ax)
        return t if kind == "all_reduce" else t / 2

    if layout.fsdp and not dims.decode:
        # per-layer param all-gather (fwd+bwd) + grad reduce-scatter over data
        per_layer = 2 * dims.layer_params / tp
        t = (2 * ring(per_layer, "x", "all_gather")
             + ring(per_layer, "x", "reduce_scatter")) * dims.n_layers
        coll_s += t
        detail["fsdp"] = t
    if not layout.fsdp and not dims.decode:
        t = ring(2 * dims.params / tp, "x", "all_reduce")
        coll_s += t
        detail["grad_allreduce"] = t
    if layout.tp:
        # 2 activation all-reduces per layer over 'model'
        act_bytes = 2 * dims.batch * dims.seq * dims.d_model / max(dp, 1)
        if dims.decode:
            act_bytes = 2 * dims.batch * dims.d_model / max(dp, 1)
        t = 2 * dims.n_layers * ring(act_bytes, "y")
        if layout.seq_shard:
            t *= 0.6  # RS+AG replaces 2xAR on the sharded dimension
        coll_s += t
        detail["tp"] = t
    if dims.n_experts and layout.tp:
        a2a = 2 * dims.batch * dims.seq * dims.d_model * dims.top_k / max(dp, 1)
        if use_engine and graph is not None:
            t = 2 * dims.n_layers * predict_collective(
                fabric, graph, "all_to_all", "y", int(a2a)).seconds
        else:
            t = 2 * dims.n_layers * a2a / (V5E_ICI_MBPS * 1e6 * 4)
        coll_s += t
        detail["moe_a2a"] = t
    if fabric.pods > 1 and "pod" in layout.batch_over and not dims.decode:
        g = 2 * dims.params / (data_ax * tp)
        t = g / (V5E_DCN_MBPS * 1e6)
        coll_s += t
        detail["dcn_grad"] = t

    # HBM residency check (params+opt+grads, bf16 + f32 m/v/master)
    state_bytes = dims.params * (2 + 12 / (chips / shard if layout.zero_pod else shard)) / shard

    step = max(compute_s, memory_s) + coll_s  # collectives partly exposed
    return Score(layout, compute_s, memory_s, coll_s, step,
                 hbm_bytes_per_chip=state_bytes, detail=detail)


def autotune(dims: WorkloadDims, fabric: TPUFabric,
             candidates=DEFAULT_CANDIDATES, graph=None,
             use_engine: bool = False, hbm_cap: float = 16e9) -> list[Score]:
    """Rank layouts; layouts whose state can't fit HBM are filtered."""
    scored = [score_layout(dims, c, fabric, graph, use_engine)
              for c in candidates]
    feasible = [s for s in scored if s.hbm_bytes_per_chip < hbm_cap * 0.9]
    return sorted(feasible or scored, key=lambda s: s.step_s)
