"""Chrome-trace-event export: Perfetto-loadable timelines from schedules.

Renders a resolved `(Hops, Channels, Schedule)` triple — and optionally a
`CoupledResult`'s convergence history — to the Chrome trace event format
(the JSON Perfetto and ``chrome://tracing`` load natively):

  * one thread track per fabric channel (pid 0, tid = channel index),
    hop transmissions as "B"/"E" duration pairs — FCFS grants never
    overlap on a channel, so the pairs nest trivially;
  * per-channel *link-down* tracks (tid = C + channel) with merged
    retraining intervals as duration pairs, plus an "i" instant at each
    retrain trigger;
  * fixpoint convergence as a "C" counter series on pid 1 (`ts` =
    iteration index): `Schedule.rounds` and, for coupled runs,
    `simulate_coupled`'s per-iteration max-abs residual;
  * optionally (``flows=`` a `critical_path.Backpointers`) the gating
    structure as Chrome flow events (cat ``critical_path``): one "s"/"f"
    arrow per cross-row QUEUE grant (FCFS predecessor's depart -> grant),
    per cross-row RETRAIN release (down-window source -> grant, drawn
    from the link-down track) and per binding JOIN contributor (slowest
    fork leg's last transmission -> waiter's first grant);
  * optionally (``blame=`` a `critical_path.Blame`) the aggregated blame
    tables as a "C" counter series on pid 2 (`ts` = channel index).

Everything here runs host-side on concrete arrays (one ``np.asarray`` pull
per field — no per-event device sync) and never feeds back into
simulation: the exporter is an observer of finished schedules, exactly
like `core.telemetry`.  `validate_trace` is the schema gate CI runs on the
example's output: valid JSON, monotone ``ts``, matched B/E pairs per
track.

Timestamps: the trace format's native unit is microseconds; events are
emitted in integer **nanoseconds** with ``displayTimeUnit: "ns"`` so
sub-ns picosecond detail rounds (ps % 1000) only at display, never
reorders (monotonicity is preserved under the floor because event order
is sorted on the ns values themselves).
"""

from __future__ import annotations

import json

import numpy as np

from .critical_path import B_QUEUE, B_RETRAIN, KIND_NAMES
from .engine import Channels, Hops, Schedule
from .topology import MEMORY, REQUESTER, FabricGraph

_KIND = {REQUESTER: "req", MEMORY: "mem"}


def channel_names(graph: FabricGraph) -> list[str]:
    """Human-readable per-channel track names for a built fabric graph:
    directed link channels as ``u->v`` / ``u<->v`` (half-duplex) with node
    kinds, service channels as ``mem m bank k``."""
    names = [""] * graph.n_channels

    def node(i: int) -> str:
        return f"{_KIND.get(int(graph.topo.kinds[i]), 'sw')}{i}"

    for (u, v), (c, d) in sorted(graph._edge.items()):
        if d == 0 and not names[c]:
            arrow = "<->" if int(graph.chan_pair[c]) < 0 else "->"
            names[c] = f"{node(u)} {arrow} {node(v)}"
    for m in range(graph._service_chan.shape[0]):
        for bk in range(graph._service_chan.shape[1]):
            c = int(graph._service_chan[m, bk])
            if c >= 0:
                names[c] = f"{node(m)} bank{bk}"
    for c, n in enumerate(names):
        if not n:
            names[c] = f"chan{c}"
    return names


def _merge_intervals(spans: list[tuple[int, int]]) -> list[tuple[int, int]]:
    """Merge overlapping/touching [lo, hi) intervals (sorted output)."""
    out: list[tuple[int, int]] = []
    for lo, hi in sorted(spans):
        if out and lo <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], hi))
        else:
            out.append((lo, hi))
    return out


def _flow_events(bp, c: int, ns) -> list[dict]:
    """Flow "s"/"f" arrows (cat ``critical_path``) for the cross-row gating
    edges recorded in a `critical_path.Backpointers`: QUEUE grants chained
    from another row's depart, RETRAIN grants chained from the down-window
    source (drawn off the link-down track, tid ``c + channel``), and the
    binding JOIN contributor per gated row."""
    evs: list[dict] = []
    fid = 0

    def arrow(name, s_tid, s_ts, f_tid, f_ts):
        nonlocal fid
        evs.append({"ph": "s", "pid": 0, "tid": s_tid, "ts": ns(s_ts),
                    "cat": "critical_path", "name": name, "id": fid})
        evs.append({"ph": "f", "bp": "e", "pid": 0, "tid": f_tid,
                    "ts": ns(f_ts), "cat": "critical_path", "name": name,
                    "id": fid})
        fid += 1

    last_occ = np.where(bp.serving.any(axis=1),
                        bp.serving.shape[1] - 1
                        - bp.serving[:, ::-1].argmax(axis=1), -1)
    first_occ = np.where(bp.serving.any(axis=1),
                         bp.serving.argmax(axis=1), -1)
    for r, j in zip(*np.nonzero(bp.valid)):
        ci = int(bp.channel[r, j])
        if bp.bind[r, j] == B_QUEUE:
            p, i = int(bp.qpred_row[r, j]), int(bp.qpred_hop[r, j])
            if p != r:
                arrow("queue", int(bp.channel[p, i]), bp.depart[p, i],
                      ci, bp.start[r, j])
        elif bp.bind[r, j] == B_RETRAIN:
            p, i = int(bp.rsrc_row[r, j]), int(bp.rsrc_hop[r, j])
            if p != r:
                # the down window lives on the grant's own channel; its
                # source is by construction a same-channel item/marker
                arrow("retrain", c + ci, bp.depart[p, i],
                      ci, bp.start[r, j])
    for r in range(bp.n):
        g = int(bp.gate_row[r])
        if g >= 0 and g != r and last_occ[g] >= 0 and first_occ[r] >= 0:
            gj, rj = int(last_occ[g]), int(first_occ[r])
            arrow("join", int(bp.channel[g, gj]), bp.depart[g, gj],
                  int(bp.channel[r, rj]), bp.start[r, rj])
    return evs


def schedule_trace(hops: Hops, channels: Channels, sched: Schedule,
                   names: list[str] | None = None,
                   residual_ps=None, flows=None, blame=None) -> dict:
    """Render one schedule as a Chrome-trace-event dict (see module doc).

    ``names`` labels the channel tracks (`channel_names(graph)`);
    ``residual_ps`` (optional, from `CoupledResult.residual_ps`) adds the
    coupled-fixpoint residual counter series; ``flows`` (optional, a
    `critical_path.Backpointers` for this schedule) adds the gating-edge
    flow arrows; ``blame`` (optional, a `critical_path.Blame`) adds the
    pid-2 blame counter series.
    """
    c = int(np.asarray(channels.bw_MBps).shape[0])
    chan = np.asarray(hops.channel)
    nbytes = np.asarray(hops.nbytes)
    valid = np.asarray(hops.valid)
    start = np.asarray(sched.start)
    depart = np.asarray(sched.depart)
    arrive = np.asarray(sched.arrive)
    retrain = (np.asarray(hops.retrain_after_ps)
               if hops.retrain_after_ps is not None else None)
    names = names or [f"chan{i}" for i in range(c)]

    def ns(ps: int) -> int:
        return int(ps) // 1000

    events: list[dict] = []
    meta: list[dict] = []
    meta.append({"ph": "M", "pid": 0, "name": "process_name",
                 "args": {"name": "fabric channels"}})
    meta.append({"ph": "M", "pid": 1, "name": "process_name",
                 "args": {"name": "fixpoint convergence"}})
    have_down = retrain is not None and bool(np.any(retrain[valid] > 0))
    for i in range(c):
        label = names[i] if i < len(names) else f"chan{i}"
        meta.append({"ph": "M", "pid": 0, "tid": i, "name": "thread_name",
                     "args": {"name": label}})
        if have_down:
            meta.append({"ph": "M", "pid": 0, "tid": c + i,
                         "name": "thread_name",
                         "args": {"name": f"{label} [link down]"}})

    occupied = valid & (nbytes > 0)
    tx_spans: list[list[tuple]] = [[] for _ in range(c)]
    down_spans: list[list[tuple[int, int]]] = [[] for _ in range(c)]
    for p, hop in zip(*np.nonzero(valid)):
        ci = int(chan[p, hop])
        if ci < 0 or ci >= c:
            continue
        t0, t1 = int(start[p, hop]), int(depart[p, hop])
        if occupied[p, hop]:
            tx_spans[ci].append((t0, t1, int(p), int(hop),
                                 int(nbytes[p, hop]),
                                 t0 - int(arrive[p, hop])))
        if retrain is not None and int(retrain[p, hop]) > 0:
            # transmissions trigger the down window at depart; zero-byte
            # retrain markers carry it at their arrival instant
            at = t1 if occupied[p, hop] else int(arrive[p, hop])
            down_spans[ci].append((at, at + int(retrain[p, hop])))
            events.append({"ph": "i", "pid": 0, "tid": ci, "ts": ns(at),
                           "name": "retrain", "s": "t"})
    # FCFS serializes each channel's grants, so spans sorted by start are
    # disjoint; emitting B,E consecutively per track keeps every track's
    # file order balanced through the stable global ts sort below (events
    # with equal ts never reorder within a track).
    for ci in range(c):
        for t0, t1, p, hop, nb, wait in sorted(tx_spans[ci]):
            events.append({"ph": "B", "pid": 0, "tid": ci, "ts": ns(t0),
                           "name": f"req{p}.h{hop}",
                           "args": {"bytes": nb, "wait_ps": wait}})
            events.append({"ph": "E", "pid": 0, "tid": ci, "ts": ns(t1)})
        for lo, hi in _merge_intervals(down_spans[ci]):
            events.append({"ph": "B", "pid": 0, "tid": c + ci, "ts": ns(lo),
                           "name": "retraining"})
            events.append({"ph": "E", "pid": 0, "tid": c + ci, "ts": ns(hi)})

    if flows is not None:
        # appended after the B/E spans so equal-ts flow endpoints sort
        # after their enclosing slice boundaries (stable sort below)
        events.extend(_flow_events(flows, c, ns))
    if blame is not None:
        meta.append({"ph": "M", "pid": 2, "name": "process_name",
                     "args": {"name": "bottleneck blame"}})
        meta.append({"ph": "M", "pid": 2, "tid": 0, "name": "thread_name",
                     "args": {"name": "blame (ps)"}})
        for ci in range(c):
            label = names[ci] if ci < len(names) else f"chan{ci}"
            events.append({
                "ph": "C", "pid": 2, "tid": 0, "ts": ci,
                "name": f"blame {label}",
                "args": {KIND_NAMES[k]: int(blame.table[ci, k])
                         for k in range(blame.table.shape[1])
                         if int(blame.table[ci, k])}})
        events.append({"ph": "C", "pid": 2, "tid": 0, "ts": c,
                       "name": "blame total",
                       "args": {k: int(v)
                                for k, v in blame.by_kind().items() if v}})

    events.append({"ph": "C", "pid": 1, "tid": 0, "ts": 0,
                   "name": "engine rounds",
                   "args": {"rounds": int(np.asarray(sched.rounds))}})
    if residual_ps is not None:
        for it, r in enumerate(np.asarray(residual_ps).reshape(-1)):
            events.append({"ph": "C", "pid": 1, "tid": 0, "ts": it + 1,
                           "name": "coupled residual",
                           "args": {"residual_ps": int(r)}})

    events.sort(key=lambda e: e["ts"])  # stable: per-track order survives
    return {"traceEvents": meta + events, "displayTimeUnit": "ns"}


def coupled_trace(result, graph: FabricGraph) -> dict:
    """Trace a `CoupledResult`: final-iteration schedule (coherence rows
    plus any background rows) on named channel tracks + the coupled-
    fixpoint residual counter series."""
    from .engine import make_channels

    channels = make_channels(graph)
    hops = (result.fabric_hops if result.fabric_hops is not None
            else result.lowering.hops)
    return schedule_trace(hops, channels, result.schedule,
                          names=channel_names(graph),
                          residual_ps=result.residual_ps)


def write_trace(trace: dict, path: str) -> str:
    with open(path, "w") as f:
        json.dump(trace, f, separators=(",", ":"))
    return path


def validate_trace(obj) -> list[str]:
    """Schema gate (CI): returns a list of violations, empty when clean.

    Checks: top-level shape, required event fields, non-negative integer
    ``ts`` monotone in file order (per the format's requirement for
    same-track nesting we check globally — the exporter sorts), matched,
    properly nested B/E pairs per (pid, tid) track, and well-formed flow
    sequences per (cat, id): every "s" unique, every "t"/"f" preceded by
    its "s", no flow left dangling at end of file.
    """
    errs: list[str] = []
    if isinstance(obj, (str, bytes)):
        try:
            obj = json.loads(obj)
        except json.JSONDecodeError as e:
            return [f"invalid JSON: {e}"]
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return ["missing traceEvents object"]
    evs = obj["traceEvents"]
    if not isinstance(evs, list):
        return ["traceEvents is not a list"]
    last_ts = None
    stacks: dict[tuple, int] = {}
    flows_open: set[tuple] = set()
    for i, e in enumerate(evs):
        if not isinstance(e, dict) or "ph" not in e:
            errs.append(f"event {i}: not an event object")
            continue
        ph = e["ph"]
        if ph == "M":
            continue
        ts = e.get("ts")
        if not isinstance(ts, int) or ts < 0:
            errs.append(f"event {i}: bad ts {ts!r}")
            continue
        if last_ts is not None and ts < last_ts:
            errs.append(f"event {i}: ts {ts} < previous {last_ts}")
        last_ts = ts
        key = (e.get("pid"), e.get("tid"))
        if ph == "B":
            if "name" not in e:
                errs.append(f"event {i}: B without name")
            stacks[key] = stacks.get(key, 0) + 1
        elif ph == "E":
            if stacks.get(key, 0) <= 0:
                errs.append(f"event {i}: E without matching B on {key}")
            else:
                stacks[key] -= 1
        elif ph in ("s", "t", "f"):
            if "name" not in e:
                errs.append(f"event {i}: flow {ph} without name")
            if "id" not in e:
                errs.append(f"event {i}: flow {ph} without id")
                continue
            fkey = (e.get("cat"), e["id"])
            if ph == "s":
                if fkey in flows_open:
                    errs.append(f"event {i}: duplicate flow s for {fkey}")
                flows_open.add(fkey)
            elif fkey not in flows_open:
                errs.append(f"event {i}: flow {ph} without open s "
                            f"for {fkey}")
            elif ph == "f":
                flows_open.discard(fkey)
    for key, depth in stacks.items():
        if depth:
            errs.append(f"track {key}: {depth} unclosed B event(s)")
    for fkey in sorted(flows_open, key=repr):
        errs.append(f"flow {fkey}: no terminating f event")
    return errs
