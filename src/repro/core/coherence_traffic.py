"""Fabric-coupled device coherence: BISnp/BIRsp/InvBlk as fabric traffic.

The snoop-filter reproduction (`core.snoop_filter`) runs the DCOH protocol
against an analytic closed-loop timing model — an *isolated* device on an
infinite bus, exactly the paper's §V-B setup.  Real CXL.mem coherence is
not isolated: BISnp/BIRsp are transactions on the same links as demand
traffic (Das Sharma et al., arXiv 2306.11227), and coherence traffic
contends with the demand traffic it serializes against (Cohet, arXiv
2511.23011).  This module closes that loop against the tensorized FCFS
engine:

  * **Event lowering** — the scan's dense per-request `SFEvents` log
    (hit/miss, BISnp target owners, InvBlk run length, writeback lines)
    lowers onto a `FabricGraph` as one hop chain per request: demand
    request hops requester→device, then per snooped owner a BISnp leg
    device→owner (reverse-direction traffic — it shares channels with
    demand *responses*, exercising the full-duplex asymmetry of §V-D)
    and a BIRsp leg owner→device (carrying writeback bytes), then the
    endpoint service hop and the response hops back.  Cache hits lower to
    empty rows; everything is co-scheduled with any background demand
    workload by ``engine.simulate`` and mirrored exactly by the
    `ref_des` oracle (device-initiated hops are ordinary hop records — the
    oracle needs no special case, which is the point of the hop-table
    contract).

  * **Outer fixpoint** — SF service time depends on fabric round trips,
    which depend on congestion, which depends on when the SF issues.  The
    same control-loop shape as `routing.adaptive`: simulate the fabric,
    measure each miss's round trip, feed it back as the request's SF
    stall time (`simulate_sf(fabric_lat_ps=...)`), re-derive issue
    times, iterate to convergence.  Protocol *decisions* are functions
    of stream order only (never of latencies), so the event log — and
    therefore the hop layout — is a fixpoint invariant; only issue times
    and measured latencies iterate.

The isolated analytic mode stays the default everywhere: nothing here is
on any path unless `simulate_coupled` is called, and the §V-B/§V-C
reproductions are bit-for-bit untouched.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from . import link_layer
from .devices import Workload, finish_hops, marker_column_map, packetize
from .engine import Hops, Schedule, make_channels, simulate_auto
from .snoop_filter import CacheConfig, SFConfig, SFEvents, SFResult, simulate_sf
from .topology import SWITCH, FabricGraph


@dataclass(frozen=True)
class CoherenceFabricSpec:
    """Placement of the DCOH protocol onto a fabric.

    dev_node      the device (MEMORY node) whose HDM the stream targets —
                  it owns the SF and initiates BISnp traffic.
    req_nodes     fabric node of each requester id (REQUESTER nodes).
    header_bytes  BISnp/BIRsp/demand-header packet size (CXL.mem carries
                  them as header-class slots).
    max_snoop     snoop legs lowered per request; owners beyond it are
                  dropped from the hop table (0 = all requesters, the
                  exact default).
    """

    dev_node: int
    req_nodes: tuple[int, ...]
    header_bytes: int = 16
    max_snoop: int = 0

    def n_snoop(self) -> int:
        return self.max_snoop if self.max_snoop > 0 else len(self.req_nodes)


class CoherenceLowering(NamedTuple):
    """Dense hop tables for one event log + the column map to read the
    schedule back.  The ``*_cols`` fields index the *logical* (pre-marker)
    layout; ``col_map[j, i]`` translates logical column ``i`` of row ``j``
    to its physical column in ``hops`` (identity unless the graph samples
    retraining stalls, whose mirror markers shift columns per row)."""

    hops: Hops
    miss: np.ndarray          # (T,) bool — rows with fabric traffic
    fwd_cols: int             # demand request hops span [0, fwd_cols)
    snoop_cols: int           # per-leg hop span (device->owner == owner->device)
    n_snoop: int              # snoop slots per request
    svc_col: int              # endpoint service hop column (logical)
    col_map: np.ndarray       # (T, logical H) -> physical column
    n_cols: int               # total physical hop columns (markers included)


class CoupledResult(NamedTuple):
    sf: SFResult              # SF view under fabric-measured stall times
    events: SFEvents          # protocol decisions (fixpoint invariant)
    schedule: Schedule        # fabric schedule of the final iteration
    lowering: CoherenceLowering
    fabric_lat_ps: jnp.ndarray   # (T,) measured miss round trips
    bisnp_lat_ps: jnp.ndarray    # (T, n_snoop) per-BISnp round trips
    issue_ps: jnp.ndarray        # (T,) fabric issue times of the final pass
    iters: int
    converged: bool
    used_oracle: bool


def _route_chans(graph: FabricGraph, src: int, dst: int):
    """[(channel, direction, fixed_after)] of the default route src -> dst."""
    path = graph.route(src, dst)
    sw_ps = graph.topo.switching_ps
    out = []
    for u, v in zip(path[:-1], path[1:]):
        c, d = graph.edge_channel(u, v)
        fixed = int(graph.chan_fixed_ps[c]) + (
            sw_ps if graph.topo.kinds[v] == SWITCH else 0)
        out.append((c, d, fixed))
    return out


def lower_coherence(graph: FabricGraph, spec: CoherenceFabricSpec,
                    sf_cfg: SFConfig, addr, is_write, rid,
                    events: SFEvents) -> CoherenceLowering:
    """Lower a protocol event log onto the fabric as per-request hop chains.

    Row layout (fixed shape; unused spans are invalid pass-through hops):

        [demand request] [BISnp out | BIRsp back] * n_snoop [service] [response]

    The chain order is the protocol order: the DCOH collects every BIRsp
    before serving the demand miss.  All writeback bytes ride the first
    snooped owner's BIRsp leg, and the InvBlk response-assembly
    serialization (the §V-C superlinear term, same formula as the isolated
    model) lands on that leg's last hop.  Stochastic link reliability, if
    the graph carries it, samples per-hop tables and mirrors full-duplex
    retraining stalls exactly as `devices.build_workload` does.

    Only cache *misses* lower to fabric traffic.  Write-upgrade BISnps on
    local-cache hits are counted by ``SFResult.bisnp_events`` (and appear
    in ``SFEvents.bisnp_mask``) but stay off the fabric — the isolated
    model's "hits never leave the requester" timing semantics, preserved
    so coupled and isolated modes agree on every protocol decision.
    """
    addr = np.asarray(addr)
    is_write = np.asarray(is_write, bool)
    rid = np.asarray(rid)
    hit = np.asarray(events.cache_hit)
    mask = np.asarray(events.bisnp_mask)
    wb = np.asarray(events.wb_lines)
    blk = np.asarray(events.invblk_len)
    T = int(hit.shape[0])
    K = spec.n_snoop()
    ep = graph.topo.endpoint
    hdr = spec.header_bytes
    line = sf_cfg.line_bytes

    to_dev = [_route_chans(graph, rq, spec.dev_node) for rq in spec.req_nodes]
    to_req = [_route_chans(graph, spec.dev_node, rq) for rq in spec.req_nodes]
    # one span width for every leg: forward and reverse routes may pick
    # different equal-cost paths (next-hops are chosen per direction), so
    # a direction-asymmetric fabric can have unequal hop counts
    Fmax = Smax = max(max(len(p) for p in to_dev),
                      max(len(p) for p in to_req))
    svc = Fmax + 2 * K * Smax
    H = svc + 1 + Fmax

    chan = np.full((T, H), -1, np.int32)
    nbytes = np.zeros((T, H), np.int64)
    direction = np.zeros((T, H), np.int8)
    row_id = np.full((T, H), -1, np.int32)
    fixed_after = np.zeros((T, H), np.int64)
    is_payload = np.zeros((T, H), bool)
    valid = np.zeros((T, H), bool)

    def fill_leg(j, k0, leg, nb, payload_flag):
        for i, (c, d, fx) in enumerate(leg):
            chan[j, k0 + i] = c
            nbytes[j, k0 + i] = nb
            direction[j, k0 + i] = d
            fixed_after[j, k0 + i] = fx
            is_payload[j, k0 + i] = payload_flag
            valid[j, k0 + i] = True
        return k0 + len(leg)

    for j in range(T):
        if hit[j]:
            continue                       # hits never reach the fabric
        r = int(rid[j])
        fwd_b, bwd_b, fwd_pay, bwd_pay = packetize(
            "esf", bool(is_write[j]), line, hdr)
        fill_leg(j, 0, to_dev[r], fwd_b, fwd_pay)
        owners = [b for b in range(len(spec.req_nodes))
                  if (int(mask[j]) >> b) & 1][:K]
        for k, o in enumerate(owners):
            k0 = Fmax + 2 * k * Smax
            end = fill_leg(j, k0, to_req[o], hdr, False)      # BISnp out
            fixed_after[j, end - 1] += sf_cfg.t_cache_ps      # owner probe
            back_b = hdr + (int(wb[j]) * line if k == 0 else 0)
            end = fill_leg(j, k0 + Smax, to_dev[o], back_b,
                           k == 0 and int(wb[j]) > 0)         # BIRsp back
            if k == 0:
                extra = max(int(blk[j]) - 1, 0)
                fixed_after[j, end - 1] += (extra * sf_cfg.t_cache_ps
                                            + extra * extra
                                            * sf_cfg.probe_conflict_ps)
        bank = int(addr[j]) % ep.banks
        chan[j, svc] = graph.service_channel(spec.dev_node, bank)
        nbytes[j, svc] = line
        row_id[j, svc] = (int(addr[j]) // ep.lines_per_row) % (1 << 30)
        fixed_after[j, svc] = ep.fixed_ps
        is_payload[j, svc] = True
        valid[j, svc] = True
        fill_leg(j, svc + 1, to_req[r], bwd_b, bwd_pay)

    # distinct reliability stream salt: coherence rows are co-scheduled
    # with demand workloads sampled from the unsalted streams, and the two
    # must draw independent fault histories
    hops = finish_hops(graph, link_layer.normalize(None), chan, nbytes,
                       direction, row_id, fixed_after, is_payload, valid,
                       stream_salt=0x636F68)   # "coh"
    return CoherenceLowering(
        hops=hops, miss=~hit, fwd_cols=Fmax, snoop_cols=Smax, n_snoop=K,
        svc_col=svc, col_map=marker_column_map(hops),
        n_cols=int(hops.channel.shape[1]),
    )


def bisnp_latencies(sched: Schedule, low: CoherenceLowering) -> jnp.ndarray:
    """Per-request, per-slot BISnp round trips: arrival after the BIRsp leg
    minus arrival at the BISnp leg (0 for unused slots — invalid hops pass
    arrivals through unchanged).  Logical columns go through ``col_map``,
    so the read is exact even when retraining markers shifted the rows.
    A hop's arrival is unchanged by the marker *behind* it, so mapping the
    logical column to its physical hop indexes the same arrival; the
    one-past-the-end logical column maps to the physical end column."""
    t = low.col_map.shape[0]
    arrive = sched.arrive[:t]            # background rows ride behind
    cm = np.concatenate(
        [low.col_map, np.full((t, 1), low.n_cols, np.int64)], axis=1)
    outs = []
    for k in range(low.n_snoop):
        k0 = low.fwd_cols + 2 * k * low.snoop_cols
        k1 = k0 + 2 * low.snoop_cols
        a0 = jnp.take_along_axis(arrive, jnp.asarray(cm[:, [k0]]),
                                 axis=1)[:, 0]
        a1 = jnp.take_along_axis(arrive, jnp.asarray(cm[:, [k1]]),
                                 axis=1)[:, 0]
        outs.append(a1 - a0)
    return jnp.stack(outs, axis=1)


def concat_background(low: CoherenceLowering, issue_ps,
                      background: "Workload | None"):
    """Stack the coherence rows (first) with a background demand Workload
    built on the same graph, padding hop columns and reliability tables.
    Returns ``(hops, issue)`` for the engine."""
    if background is None:
        return low.hops, jnp.asarray(issue_ps)
    a, b = low.hops, background.hops
    ha, hb = a.channel.shape[1], b.channel.shape[1]
    h = max(ha, hb)

    def pad(x, fill):
        x = np.asarray(x)
        if x.shape[1] == h:
            return x
        return np.pad(x, ((0, 0), (0, h - x.shape[1])), constant_values=fill)

    def join(name, fill):
        return jnp.asarray(np.concatenate(
            [pad(getattr(a, name), fill), pad(getattr(b, name), fill)]))

    hops = Hops(
        channel=join("channel", -1), nbytes=join("nbytes", 0),
        direction=join("direction", 0), row=join("row", -1),
        fixed_after_ps=join("fixed_after_ps", 0),
        is_payload=join("is_payload", False), valid=join("valid", False),
    )
    if a.extra_wire_bytes is not None or b.extra_wire_bytes is not None:
        def rel(x, name):
            f = getattr(x, name)
            return (np.asarray(f) if f is not None
                    else np.zeros(np.asarray(x.channel).shape, np.int64))

        hops = hops._replace(
            extra_wire_bytes=jnp.asarray(np.concatenate(
                [pad(rel(a, "extra_wire_bytes"), 0),
                 pad(rel(b, "extra_wire_bytes"), 0)])),
            retrain_after_ps=jnp.asarray(np.concatenate(
                [pad(rel(a, "retrain_after_ps"), 0),
                 pad(rel(b, "retrain_after_ps"), 0)])),
        )
    issue = jnp.concatenate(
        [jnp.asarray(issue_ps), jnp.asarray(background.issue_ps)])
    return hops, issue


def simulate_coupled(addr, is_write, rid, sf_cfg: SFConfig,
                     cache_cfg: CacheConfig, graph: FabricGraph,
                     spec: CoherenceFabricSpec, n_requesters: int = 1,
                     background: "Workload | None" = None,
                     max_iters: int = 8, tol_ps: int = 0,
                     max_rounds: int = 0) -> CoupledResult:
    """Fabric-coupled DCOH simulation (the §V-B/§V-C studies with the
    infinite bus replaced by real routed CXL traffic).

    Outer fixpoint (the `routing.adaptive` control-loop shape): (1) run
    the SF scan with the current per-request stall times (the analytic
    constants seed the first pass), (2) lower its event log + issue
    clocks onto the fabric and co-schedule with ``background`` demand
    traffic, (3) feed each miss's measured round trip back as its stall
    time.  Decisions never change across iterations (stream-order
    property), so the lowering happens once; only issue times and
    latencies iterate.  Convergence: max |lat - lat_prev| <= tol_ps.
    """
    if max_iters < 1:
        raise ValueError("max_iters must be >= 1")
    addr_j = jnp.asarray(addr)
    wr_j = jnp.asarray(is_write)
    rid_j = jnp.asarray(rid)
    channels = make_channels(graph, graph.topo.endpoint.row_hit_extra_ps,
                             graph.topo.endpoint.row_miss_extra_ps)

    res, ev = simulate_sf(addr_j, wr_j, rid_j, sf_cfg, cache_cfg,
                          n_requesters=n_requesters, return_events=True)
    low = lower_coherence(graph, spec, sf_cfg, addr, is_write, rid, ev)
    miss = jnp.asarray(low.miss)
    T = int(miss.shape[0])
    # hop tables are a fixpoint invariant — concat with the background once;
    # only the issue vector changes across iterations
    hops_all, _ = concat_background(low, ev.fab_issue_ps, background)
    bg_issue = (None if background is None
                else jnp.asarray(background.issue_ps))

    fab = None
    sched = None
    used_oracle = False
    iters = 0
    converged = False
    for iters in range(1, max_iters + 1):
        if fab is not None:
            res, ev = simulate_sf(addr_j, wr_j, rid_j, sf_cfg, cache_cfg,
                                  n_requesters=n_requesters,
                                  fabric_lat_ps=fab, return_events=True)
        issue_all = (ev.fab_issue_ps if bg_issue is None
                     else jnp.concatenate([ev.fab_issue_ps, bg_issue]))
        sched, used_oracle = simulate_auto(hops_all, channels, issue_all,
                                           max_rounds=max_rounds)
        new_fab = jnp.where(miss, sched.complete[:T] - issue_all[:T],
                            jnp.int64(0))
        if fab is not None and int(jnp.max(jnp.abs(new_fab - fab))) <= tol_ps:
            fab = new_fab
            converged = True
            break
        fab = new_fab

    # On exact convergence (tol 0) the loop's last SF/fabric pair already
    # used the final ``fab`` — every reported field is consistent as is.
    # Otherwise (tolerance break or max_iters limit cycle) run one final
    # SF + fabric pass so sf, schedule, bisnp_lat_ps and issue_ps all
    # belong to the same iteration.
    if not (converged and tol_ps == 0):
        res, ev = simulate_sf(addr_j, wr_j, rid_j, sf_cfg, cache_cfg,
                              n_requesters=n_requesters, fabric_lat_ps=fab,
                              return_events=True)
        issue_all = (ev.fab_issue_ps if bg_issue is None
                     else jnp.concatenate([ev.fab_issue_ps, bg_issue]))
        sched, used_oracle = simulate_auto(hops_all, channels, issue_all,
                                           max_rounds=max_rounds)
    return CoupledResult(
        sf=res, events=ev, schedule=sched, lowering=low, fabric_lat_ps=fab,
        bisnp_lat_ps=bisnp_latencies(sched, low),
        issue_ps=ev.fab_issue_ps, iters=iters, converged=converged,
        used_oracle=used_oracle,
    )
