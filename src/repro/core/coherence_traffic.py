"""Fabric-coupled device coherence: BISnp/BIRsp/InvBlk as fabric traffic.

The snoop-filter reproduction (`core.snoop_filter`) runs the DCOH protocol
against an analytic closed-loop timing model — an *isolated* device on an
infinite bus, exactly the paper's §V-B setup.  Real CXL.mem coherence is
not isolated: BISnp/BIRsp are transactions on the same links as demand
traffic (Das Sharma et al., arXiv 2306.11227), and coherence traffic
contends with the demand traffic it serializes against (Cohet, arXiv
2511.23011).  This module closes that loop against the tensorized FCFS
engine:

  * **Event lowering** — the scan's dense per-request `SFEvents` log
    (hit/miss, BISnp target owners, InvBlk run length, writeback lines)
    lowers onto a `FabricGraph` as hop rows per request.  Two fan-out
    models:

    ``fanout="concurrent"`` (default) — the CXL 3.x BI flow: a miss with
    k owners forks k BISnp rows (device→owner, sharing response channels)
    that issue together once the demand request reaches the device, and
    the demand leg (endpoint service + response) joins on the *slowest*
    BIRsp — the engine's fork/join primitive (`engine.Hops.join_id` /
    ``join_wait``: max-of-arrivals, not summed chains).  Write conflicts
    on local-cache *hits* additionally lower as **upgrade-BISnp** fork
    groups — BISnp round trips with no demand leg, issued at the hit's
    issue clock (`SFEvents.fab_issue_ps`, recorded per request by the SF
    scan): reverse traffic the hit's own latency never sees (the seed's
    "hits never leave the requester" timing model is preserved — upgrade
    traffic congests *other* transactions only).

    ``fanout="chain"`` — the PR-4 serialized model, bit-for-bit: one hop
    chain per request, owners snooped one after another
    (device→owner1→device→owner2…), upgrade-BISnps dropped.

    Either way BISnp legs are reverse-direction traffic — they share
    channels with demand *responses*, exercising the full-duplex asymmetry
    of §V-D — and everything is co-scheduled with any background demand
    workload by ``engine.simulate`` and mirrored exactly by the `ref_des`
    oracle (fork/join rows are ordinary hop records plus the per-row join
    tables — the oracle's release bookkeeping is the only special case).

  * **Outer fixpoint** — SF service time depends on fabric round trips,
    which depend on congestion, which depends on when the SF issues.  The
    same control-loop shape as `routing.adaptive`: simulate the fabric,
    measure each miss's round trip, feed it back as the request's SF
    stall time (`simulate_sf(fabric_lat_ps=...)`), re-derive issue
    times, iterate to convergence.  Protocol *decisions* are functions
    of stream order only (never of latencies), so the event log — and
    therefore the hop layout — is a fixpoint invariant; only issue times
    and measured latencies iterate.  Over half-duplex links or under
    heavy background load the undamped Picard iteration can oscillate for
    tens of iterations (re-timed issues collide with different packets
    and flip bus turnarounds — the latency map is a step function, and
    the iterate bounces between its plateaus far past any practical
    ``max_iters``); ``damping=True`` switches to the ROADMAP's averaged
    update ``fab <- (fab + measured) // 2``, which collapses
    hundreds-of-ns oscillation amplitudes geometrically and converges
    within ``tol_ps`` — measured, within ~1 ps of the exact fixpoint —
    in a budget the undamped loop blows through.  The default stays
    undamped: exact PR-4 trajectories, bit-for-bit.

The isolated analytic mode stays the default everywhere: nothing here is
on any path unless `simulate_coupled` is called, and the §V-B/§V-C
reproductions are bit-for-bit untouched.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from . import link_layer
from .devices import Workload, finish_hops, marker_column_map, packetize
from .engine import (Hops, Schedule, SimOptions, _merge_options,
                     make_channels, round_bound, simulate_auto)
from .snoop_filter import (CacheConfig, SFConfig, SFEvents, SFResult,
                           sf_init_state, simulate_sf)
from .topology import SWITCH, FabricGraph

FANOUT_MODES = ("concurrent", "chain")


@dataclass(frozen=True)
class CoherenceFabricSpec:
    """Placement of the DCOH protocol onto a fabric.

    dev_node      the device (MEMORY node) whose HDM the stream targets —
                  it owns the SF and initiates BISnp traffic.
    req_nodes     fabric node of each requester id (REQUESTER nodes).
    header_bytes  BISnp/BIRsp/demand-header packet size (CXL.mem carries
                  them as header-class slots).
    max_snoop     snoop legs lowered per request; owners beyond it are
                  dropped from the hop table (0 = all requesters, the
                  exact default).
    """

    dev_node: int
    req_nodes: tuple[int, ...]
    header_bytes: int = 16
    max_snoop: int = 0

    def n_snoop(self) -> int:
        return self.max_snoop if self.max_snoop > 0 else len(self.req_nodes)


class CoherenceLowering(NamedTuple):
    """Dense hop tables for one event log + the maps to read the schedule
    back.

    Chain layout (``fanout="chain"``): one row per request; the ``*_cols``
    fields index the *logical* (pre-marker) hop layout, and ``col_map[j,
    i]`` translates logical column ``i`` of row ``j`` to its physical
    column (identity unless the graph samples retraining stalls, whose
    mirror markers shift columns per row).  ``snoop_rows`` is None.

    Concurrent layout (``fanout="concurrent"``): the first T rows are the
    per-request *primary* rows (the demand leg of snooped misses — join-
    gated service + response — or the full chain of snoop-free misses;
    hits stay empty), followed by the fork rows (request legs, BISnp
    rows, upgrade-BISnp rows).  ``row_req`` maps every row to its request
    index (issue vectors rebuild as ``fab_issue_ps[row_req]`` each
    fixpoint iteration), and ``snoop_rows[j, k]`` is the row index of
    request ``j``'s k-th BISnp round trip (-1 unused) — `bisnp_latencies`
    reads round trips per *row* (post-join issue at column 0 to row
    completion), so no column map is needed.  The ``*_cols`` fields still
    describe the per-row leg spans (service hop at ``svc_col`` on demand
    rows; BISnp out at 0 and BIRsp back at ``snoop_cols`` on snoop rows).
    """

    hops: Hops
    miss: np.ndarray          # (T,) bool — demand rows with fabric traffic
    fwd_cols: int             # demand request hops span [0, fwd_cols)
    snoop_cols: int           # per-leg hop span (device->owner == owner->device)
    n_snoop: int              # snoop slots per request
    svc_col: int              # endpoint service hop column (logical)
    col_map: np.ndarray       # (T, logical H) -> physical column
    n_cols: int               # total physical hop columns (markers included)
    fanout: str = "chain"
    row_req: np.ndarray | None = None     # (N,) request index of each row
    snoop_rows: np.ndarray | None = None  # (T, n_snoop) BISnp row index


class CoupledResult(NamedTuple):
    sf: SFResult              # SF view under fabric-measured stall times
    events: SFEvents          # protocol decisions (fixpoint invariant)
    schedule: Schedule        # fabric schedule of the final iteration
    lowering: CoherenceLowering
    fabric_lat_ps: jnp.ndarray   # (T,) measured miss round trips
    bisnp_lat_ps: jnp.ndarray    # (T, n_snoop) per-BISnp round trips
    issue_ps: jnp.ndarray        # (T,) fabric issue times of the final pass
    iters: int
    converged: bool
    used_oracle: bool
    damped: int = 0              # averaged (damped) updates applied
    rounds: int = 0              # total engine rounds across all iterations
    residual_ps: "np.ndarray | None" = None  # per-iteration max |Δfabric_lat|
    # engine-level view of the final pass (coherence rows first, then any
    # background rows) — what `schedule` actually scheduled; feed these to
    # `core.telemetry` / `core.trace_export`:
    fabric_hops: "Hops | None" = None
    fabric_issue_ps: "jnp.ndarray | None" = None


def _route_chans(graph: FabricGraph, src: int, dst: int):
    """[(channel, direction, fixed_after)] of the default route src -> dst."""
    path = graph.route(src, dst)
    sw_ps = graph.topo.switching_ps
    out = []
    for u, v in zip(path[:-1], path[1:]):
        c, d = graph.edge_channel(u, v)
        fixed = int(graph.chan_fixed_ps[c]) + (
            sw_ps if graph.topo.kinds[v] == SWITCH else 0)
        out.append((c, d, fixed))
    return out


def _owner_bits(mask: int, n_req: int, k: int) -> list[int]:
    """First ``k`` requester indices set in a BISnp owner bitmask.  The
    scan is bounded by the requester count (an int32 mask with bit 31 set
    sign-extends in Python — unbounded bit positions would be phantoms)."""
    return [b for b in range(n_req) if (mask >> b) & 1][:k]


class _RowBuilder:
    """Growable (rows x H) hop-table builder shared by both lowerings."""

    def __init__(self, n_rows: int, h: int):
        self.h = h
        self.chan = np.full((n_rows, h), -1, np.int32)
        self.nbytes = np.zeros((n_rows, h), np.int64)
        self.direction = np.zeros((n_rows, h), np.int8)
        self.row_id = np.full((n_rows, h), -1, np.int32)
        self.fixed_after = np.zeros((n_rows, h), np.int64)
        self.is_payload = np.zeros((n_rows, h), bool)
        self.valid = np.zeros((n_rows, h), bool)

    def fill_leg(self, j, k0, leg, nb, payload_flag):
        for i, (c, d, fx) in enumerate(leg):
            self.chan[j, k0 + i] = c
            self.nbytes[j, k0 + i] = nb
            self.direction[j, k0 + i] = d
            self.fixed_after[j, k0 + i] = fx
            self.is_payload[j, k0 + i] = payload_flag
            self.valid[j, k0 + i] = True
        return k0 + len(leg)

    def service_hop(self, j, col, graph, spec, sf_cfg, a):
        ep = graph.topo.endpoint
        bank = a % ep.banks
        self.chan[j, col] = graph.service_channel(spec.dev_node, bank)
        self.nbytes[j, col] = sf_cfg.line_bytes
        self.row_id[j, col] = (a // ep.lines_per_row) % (1 << 30)
        self.fixed_after[j, col] = ep.fixed_ps
        self.is_payload[j, col] = True
        self.valid[j, col] = True


def lower_coherence(graph: FabricGraph, spec: CoherenceFabricSpec,
                    sf_cfg: SFConfig, addr, is_write, rid,
                    events: SFEvents, fanout: str = "concurrent",
                    upgrade_bisnp: bool | None = None) -> CoherenceLowering:
    """Lower a protocol event log onto the fabric as per-request hop rows.

    ``fanout="concurrent"`` (default) — misses with k snooped owners fork
    k concurrent BISnp rows gated on the demand request's arrival at the
    device and join the demand leg on the slowest BIRsp (the engine's
    max-of-arrivals primitive); write-conflict BISnps on local-cache hits
    (``upgrade_bisnp``, default on in this mode) lower as BISnp-only fork
    groups with no demand leg.  All writeback bytes ride the first snooped
    owner's BIRsp leg and the InvBlk response-assembly serialization (the
    §V-C superlinear term) lands on that leg's last hop — the same
    protocol-cost assignment as the chain model, so the two lowerings
    differ only in concurrency.

    ``fanout="chain"`` — the serialized PR-4 lowering, bit-for-bit: one
    hop chain per request in protocol order

        [demand request] [BISnp out | BIRsp back] * n_snoop [service] [response]

    (the DCOH collecting each BIRsp before snooping the next owner), and
    upgrade-BISnps on hits stay off the fabric (counted by
    ``SFResult.bisnp_events`` only) — the isolated model's timing
    semantics, preserved so coupled and isolated modes agree on every
    protocol decision.

    Stochastic link reliability, if the graph carries it, samples per-hop
    tables and mirrors full-duplex retraining stalls exactly as
    `devices.build_workload` does.
    """
    if fanout not in FANOUT_MODES:
        raise ValueError(f"unknown fanout {fanout!r}")
    if upgrade_bisnp is None:
        upgrade_bisnp = fanout == "concurrent"
    if upgrade_bisnp and fanout == "chain":
        raise ValueError("upgrade-BISnp lowering needs fanout='concurrent' "
                         "(the chain layout is the exact PR-4 one)")
    addr = np.asarray(addr)
    is_write = np.asarray(is_write, bool)
    rid = np.asarray(rid)
    hit = np.asarray(events.cache_hit)
    conflict = np.asarray(events.conflict)
    mask = np.asarray(events.bisnp_mask)
    wb = np.asarray(events.wb_lines)
    blk = np.asarray(events.invblk_len)
    T = int(hit.shape[0])
    K = spec.n_snoop()
    hdr = spec.header_bytes
    line = sf_cfg.line_bytes

    to_dev = [_route_chans(graph, rq, spec.dev_node) for rq in spec.req_nodes]
    to_req = [_route_chans(graph, spec.dev_node, rq) for rq in spec.req_nodes]
    # one span width for every leg: forward and reverse routes may pick
    # different equal-cost paths (next-hops are chosen per direction), so
    # a direction-asymmetric fabric can have unequal hop counts
    Fmax = Smax = max(max(len(p) for p in to_dev),
                      max(len(p) for p in to_req))

    if fanout == "chain":
        b = _chain_rows(graph, spec, sf_cfg, addr, is_write, rid,
                        hit, mask, wb, blk, T, K, Fmax, Smax, hdr, line,
                        to_dev, to_req)
        svc = Fmax + 2 * K * Smax
        hops = finish_hops(graph, link_layer.normalize(None), b.chan,
                           b.nbytes, b.direction, b.row_id, b.fixed_after,
                           b.is_payload, b.valid, stream_salt=0x636F68)
        return CoherenceLowering(
            hops=hops, miss=~hit, fwd_cols=Fmax, snoop_cols=Smax, n_snoop=K,
            svc_col=svc, col_map=marker_column_map(hops),
            n_cols=int(hops.channel.shape[1]), fanout="chain",
            row_req=np.arange(T, dtype=np.int64), snoop_rows=None,
        )

    # ---- concurrent fan-out ------------------------------------------------
    # Row budget: each snooped miss adds a request-leg (fork) row + k BISnp
    # rows; each upgrade conflict adds its k BISnp rows.  Primary rows keep
    # the request index, so the coupled loop's completion reads stay [:T].
    owners_of = [_owner_bits(int(mask[j]), len(spec.req_nodes), K)
                 for j in range(T)]
    n_extra = 0
    for j in range(T):
        if hit[j]:
            if upgrade_bisnp and conflict[j]:
                n_extra += len(owners_of[j])
        elif owners_of[j]:
            n_extra += 1 + len(owners_of[j])
    svc = Fmax                       # service col on every demand row
    H = 2 * Fmax + 1                 # [request] [service] [response]
    N = T + n_extra
    b = _RowBuilder(N, H)
    join_id = np.full(N, -1, np.int32)
    join_wait = np.full(N, -1, np.int32)
    join_arity = np.zeros(N, np.int32)
    row_req = np.concatenate(
        [np.arange(T, dtype=np.int64), np.zeros(n_extra, np.int64)])
    snoop_rows = np.full((T, K), -1, np.int64)
    nxt_row = T
    nxt_grp = 0

    def snoop_row(j, k, o, with_payload):
        """One BISnp round trip: device->owner out leg (+owner cache probe),
        owner->device BIRsp back (first slot carries writebacks + the InvBlk
        response-assembly serialization when ``with_payload``)."""
        nonlocal nxt_row
        rrow = nxt_row
        nxt_row += 1
        row_req[rrow] = j
        end = b.fill_leg(rrow, 0, to_req[o], hdr, False)          # BISnp out
        b.fixed_after[rrow, end - 1] += sf_cfg.t_cache_ps         # owner probe
        back_b = hdr + (int(wb[j]) * line if with_payload else 0)
        end = b.fill_leg(rrow, Smax, to_dev[o], back_b,
                         with_payload and int(wb[j]) > 0)         # BIRsp back
        if with_payload:
            extra = max(int(blk[j]) - 1, 0)
            b.fixed_after[rrow, end - 1] += (extra * sf_cfg.t_cache_ps
                                             + extra * extra
                                             * sf_cfg.probe_conflict_ps)
        snoop_rows[j, k] = rrow
        return rrow

    for j in range(T):
        owners = owners_of[j]
        if hit[j]:
            # upgrade-BISnp: the write hit's conflict snoops the other
            # sharers — reverse traffic with no demand leg; the hit's own
            # latency is untouched (decisions and timing stay the isolated
            # model's; only *other* traffic feels the congestion)
            if upgrade_bisnp and conflict[j]:
                for k, o in enumerate(owners):
                    snoop_row(j, k, o, with_payload=False)
            continue
        r = int(rid[j])
        fwd_b, bwd_b, fwd_pay, bwd_pay = packetize(
            "esf", bool(is_write[j]), line, hdr)
        if not owners:               # snoop-free miss: plain chain row
            b.fill_leg(j, 0, to_dev[r], fwd_b, fwd_pay)
        else:
            # fork: the request leg completes at the device and releases
            # the k concurrent BISnp rows; the demand leg joins on the
            # slowest BIRsp (max-of-arrivals) before the endpoint serves
            g_req, g_rsp = nxt_grp, nxt_grp + 1
            nxt_grp += 2
            arow = nxt_row
            nxt_row += 1
            row_req[arow] = j
            b.fill_leg(arow, 0, to_dev[r], fwd_b, fwd_pay)
            join_id[arow] = g_req
            for k, o in enumerate(owners):
                rrow = snoop_row(j, k, o, with_payload=k == 0)
                join_wait[rrow] = g_req
                join_arity[rrow] = 1
                join_id[rrow] = g_rsp
            join_wait[j] = g_rsp
            join_arity[j] = len(owners)
        b.service_hop(j, svc, graph, spec, sf_cfg, int(addr[j]))
        b.fill_leg(j, svc + 1, to_req[r], bwd_b, bwd_pay)

    hops = finish_hops(graph, link_layer.normalize(None), b.chan, b.nbytes,
                       b.direction, b.row_id, b.fixed_after, b.is_payload,
                       b.valid, stream_salt=0x636F68,
                       join_id=join_id, join_wait=join_wait,
                       join_arity=join_arity)
    return CoherenceLowering(
        hops=hops, miss=~hit, fwd_cols=Fmax, snoop_cols=Smax, n_snoop=K,
        svc_col=svc, col_map=marker_column_map(hops),
        n_cols=int(hops.channel.shape[1]), fanout="concurrent",
        row_req=row_req, snoop_rows=snoop_rows,
    )


def _chain_rows(graph, spec, sf_cfg, addr, is_write, rid, hit, mask, wb, blk,
                T, K, Fmax, Smax, hdr, line, to_dev, to_req) -> _RowBuilder:
    """The serialized PR-4 row layout (fixed shape; unused spans are invalid
    pass-through hops):

        [demand request] [BISnp out | BIRsp back] * n_snoop [service] [response]

    The chain order is the protocol order: the DCOH collects every BIRsp
    before serving the demand miss.  Only cache *misses* lower to fabric
    traffic here (upgrade-BISnps need the concurrent layout's extra rows).
    """
    svc = Fmax + 2 * K * Smax
    H = svc + 1 + Fmax
    b = _RowBuilder(T, H)
    for j in range(T):
        if hit[j]:
            continue                       # hits never reach the fabric
        r = int(rid[j])
        fwd_b, bwd_b, fwd_pay, bwd_pay = packetize(
            "esf", bool(is_write[j]), line, hdr)
        b.fill_leg(j, 0, to_dev[r], fwd_b, fwd_pay)
        owners = _owner_bits(int(mask[j]), len(spec.req_nodes), K)
        for k, o in enumerate(owners):
            k0 = Fmax + 2 * k * Smax
            end = b.fill_leg(j, k0, to_req[o], hdr, False)        # BISnp out
            b.fixed_after[j, end - 1] += sf_cfg.t_cache_ps        # owner probe
            back_b = hdr + (int(wb[j]) * line if k == 0 else 0)
            end = b.fill_leg(j, k0 + Smax, to_dev[o], back_b,
                             k == 0 and int(wb[j]) > 0)           # BIRsp back
            if k == 0:
                extra = max(int(blk[j]) - 1, 0)
                b.fixed_after[j, end - 1] += (extra * sf_cfg.t_cache_ps
                                              + extra * extra
                                              * sf_cfg.probe_conflict_ps)
        b.service_hop(j, svc, graph, spec, sf_cfg, int(addr[j]))
        b.fill_leg(j, svc + 1, to_req[r], bwd_b, bwd_pay)
    return b


def bisnp_latencies(sched: Schedule, low: CoherenceLowering) -> jnp.ndarray:
    """Per-request, per-slot BISnp round trips (0 for unused slots).

    Concurrent layout: each slot is its own row — round trip = row
    completion minus the row's post-join issue (``arrive[:, 0]``, the
    moment the demand request released the fan-out; upgrade rows issue at
    the hit's clock directly).

    Chain layout: arrival after the BIRsp leg minus arrival at the BISnp
    leg, read through ``col_map`` so retraining markers that shifted hop
    columns keep the read exact (a hop's arrival is unchanged by the
    marker *behind* it, so mapping the logical column to its physical hop
    indexes the same arrival; the one-past-the-end logical column maps to
    the physical end column).
    """
    if low.snoop_rows is not None:
        nrow = sched.complete.shape[0]
        sr = np.minimum(np.maximum(low.snoop_rows, 0), nrow - 1)
        rows = jnp.asarray(sr)
        rt = sched.complete[rows] - sched.arrive[rows, 0]
        return jnp.where(jnp.asarray(low.snoop_rows >= 0), rt, 0)
    t = low.col_map.shape[0]
    arrive = sched.arrive[:t]            # background rows ride behind
    cm = np.concatenate(
        [low.col_map, np.full((t, 1), low.n_cols, np.int64)], axis=1)
    outs = []
    for k in range(low.n_snoop):
        k0 = low.fwd_cols + 2 * k * low.snoop_cols
        k1 = k0 + 2 * low.snoop_cols
        a0 = jnp.take_along_axis(arrive, jnp.asarray(cm[:, [k0]]),
                                 axis=1)[:, 0]
        a1 = jnp.take_along_axis(arrive, jnp.asarray(cm[:, [k1]]),
                                 axis=1)[:, 0]
        outs.append(a1 - a0)
    return jnp.stack(outs, axis=1)


LEG_DEMAND_REQ, LEG_SERVICE, LEG_DEMAND_RSP, LEG_BISNP, LEG_BIRSP, \
    LEG_WRITEBACK = range(6)
LEG_NAMES = ("demand_req", "service", "demand_rsp", "bisnp", "birsp",
             "writeback")


def hop_legs(low: CoherenceLowering) -> np.ndarray:
    """Protocol-leg code of every physical hop: ``legs[j, k]`` is a
    `LEG_NAMES` index, -1 for invalid hops and retraining markers.

    Spans come from the lowering's logical layout (`fwd_cols` /
    `snoop_cols` / `svc_col`) scattered to physical columns through
    ``col_map``, so marker-shifted rows keep their labels exact.  A
    payload-carrying BIRsp hop is the dirty-line writeback."""
    valid = np.asarray(low.hops.valid)
    pay = np.asarray(low.hops.is_payload)
    n_rows = valid.shape[0]
    F, S, svc = low.fwd_cols, low.snoop_cols, low.svc_col
    h_old = low.col_map.shape[1]
    logical = np.full((n_rows, h_old), -1, np.int8)
    if low.fanout == "concurrent":
        t = low.miss.shape[0]
        logical[:, :F] = LEG_DEMAND_REQ      # demand + fork request legs
        logical[:t, svc] = LEG_SERVICE
        logical[:t, svc + 1:] = LEG_DEMAND_RSP
        sr = low.snoop_rows[low.snoop_rows >= 0]
        if sr.size:
            logical[sr, :S] = LEG_BISNP
            logical[sr, S:2 * S] = LEG_BIRSP
            logical[sr, 2 * S:] = -1
    else:
        logical[:, :F] = LEG_DEMAND_REQ
        for k in range(low.n_snoop):
            lo = F + 2 * k * S
            logical[:, lo:lo + S] = LEG_BISNP
            logical[:, lo + S:lo + 2 * S] = LEG_BIRSP
        logical[:, svc] = LEG_SERVICE
        logical[:, svc + 1:] = LEG_DEMAND_RSP
    legs = np.full((n_rows, low.n_cols), -1, np.int8)
    np.put_along_axis(legs, low.col_map, logical, axis=1)
    legs = np.where(valid, legs, -1)
    return np.where((legs == LEG_BIRSP) & pay, LEG_WRITEBACK, legs)


def leg_blame(low: CoherenceLowering, paths) -> dict[str, int]:
    """Critical-path picoseconds per protocol leg.

    ``paths`` is `critical_path.critical_paths` output for the *fabric*
    schedule the lowering ran in (background rows appended after the
    coherence rows are fine).  Each edge bills the leg of its gated item;
    edges on rows past the lowering (background traffic) land in
    ``"background"``; row-level edges (issue, join) and marker hops land
    in ``"protocol"``.  Values sum to the summed path totals."""
    legs = hop_legs(low)
    out = dict.fromkeys(LEG_NAMES + ("protocol", "background"), 0)
    for path in paths:
        for e in path:
            if e.ps == 0:
                continue
            if e.row >= legs.shape[0]:
                out["background"] += e.ps
            elif e.hop >= 0 and legs[e.row, e.hop] >= 0:
                out[LEG_NAMES[int(legs[e.row, e.hop])]] += e.ps
            else:
                out["protocol"] += e.ps
    return out


def coherence_issue(low: CoherenceLowering, fab_issue_ps) -> jnp.ndarray:
    """Per-row issue vector of a lowering: fork/BISnp/upgrade rows inherit
    their request's issue clock (``row_req``), which moves every fixpoint
    iteration while the hop layout stays invariant."""
    fab_issue_ps = jnp.asarray(fab_issue_ps)
    if low.row_req is None:
        return fab_issue_ps
    return fab_issue_ps[jnp.asarray(low.row_req)]


def pad_rows(hops: Hops, n_rows: int) -> Hops:
    """Pad a hop table with trailing invalid rows (channel -1, no joins) so
    lowerings of different row counts stack for a vmapped fabric pass."""
    n, h = hops.channel.shape
    if n_rows < n:
        raise ValueError(f"cannot pad {n} rows down to {n_rows}")
    if n_rows == n:
        return hops
    m = n_rows - n

    def pad2(x, fill, dtype):
        return jnp.concatenate(
            [jnp.asarray(x), jnp.full((m, h), fill, dtype)])

    out = Hops(
        channel=pad2(hops.channel, -1, jnp.int32),
        nbytes=pad2(hops.nbytes, 0, jnp.int64),
        direction=pad2(hops.direction, 0, jnp.int8),
        row=pad2(hops.row, -1, jnp.int32),
        fixed_after_ps=pad2(hops.fixed_after_ps, 0, jnp.int64),
        is_payload=pad2(hops.is_payload, False, bool),
        valid=pad2(hops.valid, False, bool),
    )
    if hops.extra_wire_bytes is not None:
        out = out._replace(
            extra_wire_bytes=pad2(hops.extra_wire_bytes, 0, jnp.int64),
            retrain_after_ps=pad2(hops.retrain_after_ps, 0, jnp.int64))
    if hops.join_id is not None:
        def pad1(x, fill):
            return jnp.concatenate(
                [jnp.asarray(x), jnp.full((m,), fill, jnp.int32)])
        out = out._replace(join_id=pad1(hops.join_id, -1),
                           join_wait=pad1(hops.join_wait, -1),
                           join_arity=pad1(hops.join_arity, 0))
    return out


def concat_background(low: CoherenceLowering, issue_ps,
                      background: "Workload | None"):
    """Stack the coherence rows (first) with a background demand Workload
    built on the same graph, padding hop columns and reliability tables.
    ``issue_ps`` must already cover every coherence row (`coherence_issue`).
    Returns ``(hops, issue)`` for the engine."""
    if background is None:
        return low.hops, jnp.asarray(issue_ps)
    a, b = low.hops, background.hops
    ha, hb = a.channel.shape[1], b.channel.shape[1]
    h = max(ha, hb)

    def pad(x, fill):
        x = np.asarray(x)
        if x.shape[1] == h:
            return x
        return np.pad(x, ((0, 0), (0, h - x.shape[1])), constant_values=fill)

    def join(name, fill):
        return jnp.asarray(np.concatenate(
            [pad(getattr(a, name), fill), pad(getattr(b, name), fill)]))

    hops = Hops(
        channel=join("channel", -1), nbytes=join("nbytes", 0),
        direction=join("direction", 0), row=join("row", -1),
        fixed_after_ps=join("fixed_after_ps", 0),
        is_payload=join("is_payload", False), valid=join("valid", False),
    )
    if a.extra_wire_bytes is not None or b.extra_wire_bytes is not None:
        def rel(x, name):
            f = getattr(x, name)
            return (np.asarray(f) if f is not None
                    else np.zeros(np.asarray(x.channel).shape, np.int64))

        hops = hops._replace(
            extra_wire_bytes=jnp.asarray(np.concatenate(
                [pad(rel(a, "extra_wire_bytes"), 0),
                 pad(rel(b, "extra_wire_bytes"), 0)])),
            retrain_after_ps=jnp.asarray(np.concatenate(
                [pad(rel(a, "retrain_after_ps"), 0),
                 pad(rel(b, "retrain_after_ps"), 0)])),
        )
    if a.join_id is not None:
        # background rows never wait or contribute; coherence rows stay
        # first, so group ids keep pointing at the same row index space
        nb = b.channel.shape[0]
        hops = hops._replace(
            join_id=jnp.concatenate(
                [jnp.asarray(a.join_id), jnp.full((nb,), -1, jnp.int32)]),
            join_wait=jnp.concatenate(
                [jnp.asarray(a.join_wait), jnp.full((nb,), -1, jnp.int32)]),
            join_arity=jnp.concatenate(
                [jnp.asarray(a.join_arity), jnp.zeros((nb,), jnp.int32)]),
        )
    issue = jnp.concatenate(
        [jnp.asarray(issue_ps), jnp.asarray(background.issue_ps)])
    return hops, issue


def simulate_coupled(addr, is_write, rid, sf_cfg: SFConfig,
                     cache_cfg: CacheConfig, graph: FabricGraph,
                     spec: CoherenceFabricSpec, n_requesters: int = 1,
                     background: "Workload | None" = None,
                     options: SimOptions | None = None,
                     max_iters: int = 8, tol_ps: int = 0,
                     fanout: str = "concurrent",
                     upgrade_bisnp: bool | None = None,
                     max_rounds: int = None,
                     damping: bool = None) -> CoupledResult:
    """Fabric-coupled DCOH simulation (the §V-B/§V-C studies with the
    infinite bus replaced by real routed CXL traffic).

    Outer fixpoint (the `routing.adaptive` control-loop shape): (1) run
    the SF scan with the current per-request stall times (the analytic
    constants seed the first pass), (2) lower its event log + issue
    clocks onto the fabric and co-schedule with ``background`` demand
    traffic, (3) feed each miss's measured round trip back as its stall
    time.  Decisions never change across iterations (stream-order
    property), so the lowering happens once; only issue times and
    latencies iterate.  Convergence: max |lat - lat_prev| <= tol_ps.

    ``damping=True`` injects the *average of the last two latency
    vectors* — ``fab <- (fab + measured) // 2`` — instead of the raw
    measurement from the second iteration on.  Picard iteration on this
    map can oscillate far past any practical budget (the latency response
    to an issue-time shift is a step function: a re-timed request collides
    with a different packet or flips a half-duplex turnaround), and the
    averaged update collapses the oscillation amplitude geometrically:
    configs that bounce by hundreds of ns forever converge within
    ``tol_ps`` in a comparable budget, landing (measured) within ~1 ps of
    the exact fixpoint.  Pass ``tol_ps >= 1`` with damping: the integer
    floor can leave the averaged iterate sitting 1 ps from its
    measurement indefinitely, so exact tol-0 convergence is the undamped
    mode's job.  ``CoupledResult.damped`` counts the averaged updates.
    The default stays undamped — PR-4 trajectories bit-for-bit.

    ``options`` is the uniform `engine.SimOptions` knob set: ``max_rounds``
    (0 = the computed join-depth bound of the lowered workload, resolved
    once — the hop tables are a fixpoint invariant), ``check`` / ``use_kernel``
    forwarded to every inner `simulate_auto` pass, and ``damping`` as
    described above.  The bare ``max_rounds=`` / ``damping=`` kwargs are
    deprecated shims.
    """
    if max_iters < 1:
        raise ValueError("max_iters must be >= 1")
    opts = _merge_options("simulate_coupled", options,
                          max_rounds=max_rounds, damping=damping)
    damping = opts.damping
    addr_j = jnp.asarray(addr)
    wr_j = jnp.asarray(is_write)
    rid_j = jnp.asarray(rid)
    channels = make_channels(graph, graph.topo.endpoint.row_hit_extra_ps,
                             graph.topo.endpoint.row_miss_extra_ps)

    res, ev = simulate_sf(addr_j, wr_j, rid_j, sf_cfg, cache_cfg,
                          n_requesters=n_requesters, return_events=True)
    low = lower_coherence(graph, spec, sf_cfg, addr, is_write, rid, ev,
                          fanout=fanout, upgrade_bisnp=upgrade_bisnp)
    miss = jnp.asarray(low.miss)
    T = int(miss.shape[0])
    # hop tables are a fixpoint invariant — concat with the background once;
    # only the issue vector changes across iterations
    hops_all, _ = concat_background(low, coherence_issue(low, ev.fab_issue_ps),
                                    background)
    inner = SimOptions(max_rounds=opts.max_rounds or round_bound(hops_all),
                       check=opts.check, use_kernel=opts.use_kernel)
    bg_issue = (None if background is None
                else jnp.asarray(background.issue_ps))

    def issue_vec(ev):
        coh = coherence_issue(low, ev.fab_issue_ps)
        return coh if bg_issue is None else jnp.concatenate([coh, bg_issue])

    fab = None
    sched = None
    used_oracle = False
    iters = 0
    converged = False
    damped = 0
    total_rounds = 0
    resid_hist = []           # convergence telemetry: max |Δ| per iteration
    for iters in range(1, max_iters + 1):
        if fab is not None:
            res, ev = simulate_sf(addr_j, wr_j, rid_j, sf_cfg, cache_cfg,
                                  n_requesters=n_requesters,
                                  fabric_lat_ps=fab, return_events=True)
        issue_all = issue_vec(ev)
        sched, used_oracle = simulate_auto(hops_all, channels, issue_all,
                                           inner)
        total_rounds += int(sched.rounds)
        new_fab = jnp.where(miss, sched.complete[:T] - issue_all[:T],
                            jnp.int64(0))
        if fab is not None:
            resid = int(jnp.max(jnp.abs(new_fab - fab)))
            resid_hist.append(resid)
            if resid <= tol_ps:
                fab = new_fab
                converged = True
                break
        if damping and fab is not None:
            fab = (fab + new_fab) // 2      # averaged (damped) update
            damped += 1
        else:
            fab = new_fab

    # On exact convergence (tol 0) the loop's last SF/fabric pair already
    # used the final ``fab`` — every reported field is consistent as is
    # (even after damped updates: the break condition is measured ==
    # injected).  Otherwise (tolerance break or max_iters limit cycle) run
    # one final SF + fabric pass so sf, schedule, bisnp_lat_ps and
    # issue_ps all belong to the same iteration.
    if not (converged and tol_ps == 0):
        res, ev = simulate_sf(addr_j, wr_j, rid_j, sf_cfg, cache_cfg,
                              n_requesters=n_requesters, fabric_lat_ps=fab,
                              return_events=True)
        issue_all = issue_vec(ev)
        sched, used_oracle = simulate_auto(hops_all, channels, issue_all,
                                           inner)
        total_rounds += int(sched.rounds)
    return CoupledResult(
        sf=res, events=ev, schedule=sched, lowering=low, fabric_lat_ps=fab,
        bisnp_lat_ps=bisnp_latencies(sched, low),
        issue_ps=ev.fab_issue_ps, iters=iters, converged=converged,
        used_oracle=used_oracle, damped=damped, rounds=total_rounds,
        residual_ps=np.asarray(resid_hist, dtype=np.int64),
        fabric_hops=hops_all, fabric_issue_ps=issue_all,
    )


class CoherenceStream:
    """Chunked ``(hops, issue_ps)`` source for `streaming.simulate_stream`
    — the §V-E-scale front end of the §V-B/§V-C coherence machinery.

    Iterates the request stream ``chunk`` requests at a time; each chunk
    resumes the DCOH scan from the carried `SFState` (bit-exact with the
    monolithic scan — protocol decisions depend only on request order),
    lowers its event log onto the fabric (`lower_coherence`; join groups
    are chunk-local by construction, exactly the streaming driver's chunk
    contract) and yields ``(hops, issue_ps)`` ready for the windowed
    engine.

    One-pass (uncoupled) lowering: issue clocks come from the analytic SF
    scan and fabric-measured latencies are *not* fed back — the
    `simulate_coupled` fixpoint needs the whole trace's latencies at once,
    so coupling the streamed path is follow-on work.  Chunk-min issue
    monotonicity (the driver's stream contract) holds whenever every
    requester appears in every chunk (round-robin interleaves do); the
    driver asserts it regardless.

    With ``fanout="chain"`` on a deterministic-reliability graph the
    streamed schedule is bit-exact with lowering the whole trace at once
    (row order and per-row hop order are both preserved, so every FCFS
    tie-break agrees); with stochastic retrain sampling the chunked
    lowering draws per-chunk sample streams — deterministic, but not
    equal to the monolithic draw.

    Attributes update as chunks are consumed: ``sf_state`` (the carried
    protocol state; its counters are cumulative), ``n_done``, and — with
    ``keep_results=True`` — ``sf_results`` (per-chunk `SFResult` list).
    """

    def __init__(self, addr, is_write, rid, sf_cfg: SFConfig,
                 cache_cfg: CacheConfig, graph: FabricGraph,
                 spec: CoherenceFabricSpec, *, chunk: int,
                 n_requesters: int = 1, fanout: str = "chain",
                 upgrade_bisnp: bool | None = None,
                 init_state=None, keep_results: bool = False):
        if chunk < 1:
            raise ValueError("chunk must be >= 1")
        self.addr = np.asarray(addr)
        self.is_write = np.asarray(is_write)
        self.rid = np.asarray(rid)
        self.sf_cfg, self.cache_cfg = sf_cfg, cache_cfg
        self.graph, self.spec = graph, spec
        self.chunk = int(chunk)
        self.n_requesters = int(n_requesters)
        self.fanout = fanout
        self.upgrade_bisnp = upgrade_bisnp
        self.sf_state = (init_state if init_state is not None
                         else sf_init_state(sf_cfg, cache_cfg, n_requesters))
        self.keep_results = keep_results
        self.sf_results: list[SFResult] = []
        self.n_done = 0

    def channels(self):
        """The engine channel table matching this stream's graph."""
        ep = self.graph.topo.endpoint
        return make_channels(self.graph, ep.row_hit_extra_ps,
                             ep.row_miss_extra_ps)

    def __iter__(self):
        T = self.addr.shape[0]
        for lo in range(0, T, self.chunk):
            hi = min(lo + self.chunk, T)
            a, w, r = self.addr[lo:hi], self.is_write[lo:hi], self.rid[lo:hi]
            res, ev, self.sf_state = simulate_sf(
                jnp.asarray(a), jnp.asarray(w), jnp.asarray(r),
                self.sf_cfg, self.cache_cfg, n_requesters=self.n_requesters,
                return_events=True, init_state=self.sf_state,
                return_state=True)
            if self.keep_results:
                self.sf_results.append(res)
            low = lower_coherence(self.graph, self.spec, self.sf_cfg,
                                  a, w, r, ev, fanout=self.fanout,
                                  upgrade_bisnp=self.upgrade_bisnp)
            self.n_done = hi
            yield low.hops, coherence_issue(low, ev.fab_issue_ps)
