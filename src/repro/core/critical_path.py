"""Critical-path extraction and bottleneck blame attribution.

`core.telemetry` (PR 6) answers *how much* time each request spent queueing,
serializing, or stalled — this module answers *which* event gated it.  For
every request in a resolved `engine.Schedule` it reconstructs the chain of
gating events: the FCFS predecessor on each hop's channel, the request's own
previous hop, the slowest fork/join contributor, or a retraining
``down_until`` release.  The reconstruction replays the engine's segmented
scan **with argmax backpointers** on the host — a pure observer in the
`engine.replay_round` sense: the schedule is a fixed point of the round map,
so one replay reproduces every ``start``/``depart`` bit-for-bit (asserted
under ``check=True``) and the schedule itself is never recomputed.

From the backpointer forest it derives

  * per-request **critical paths** — chains of typed edges whose time
    contributions sum *exactly* to ``complete − issue`` (the conservation
    invariant; edges are clipped against the request's issue time so
    priority-inverted predecessors that started before the request even
    issued cannot over-attribute),
  * aggregated **blame tables** per channel × edge kind with top-k
    bottleneck ranking and per-switch rollups (`Blame.by_switch`), and
  * coz-style **what-if estimates** — `speedup_if(bp, channel, factor)`
    re-propagates event times along the frozen backpointer DAG with the
    target channel's serialization scaled, without re-running contention.

Everything here is host-side NumPy over a pulled-back schedule: nothing is
jit- or scan-reachable, sizes are bench-scale (the streaming layer handles
million-request traces by folding *local* blame instead — see
`telemetry.channel_blame` and `streaming`).
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np

from .engine import Channels, Hops, Schedule

# Edge kinds of a critical path.  ISSUE terminates every path (the walk
# reached an event at or before the request's own issue time); JOIN crosses
# from a waiter row to its slowest fork/join contributor; QUEUE crosses to
# the FCFS predecessor whose depart (+ turnaround) floored the grant;
# RETRAIN crosses to the item/marker whose down interval floored it; WIRE is
# the item's own serialization, ROW its row-buffer penalty, FIXED the
# post-transmission fixed latency between consecutive hops of one row.
K_ISSUE, K_JOIN, K_QUEUE, K_RETRAIN, K_WIRE, K_ROW, K_FIXED = range(7)
KIND_NAMES = ("issue", "join", "queue", "retrain", "wire", "row", "fixed")
N_KINDS = len(KIND_NAMES)

# grant-time binding of a serving item (Backpointers.bind)
B_NONE, B_ARRIVE, B_QUEUE, B_RETRAIN = -1, 0, 1, 2


class PathEdge(NamedTuple):
    """One edge of a request's critical path.

    ``row``/``hop`` is the gated item (``hop == -1`` for row-level JOIN /
    ISSUE edges); ``src_row``/``src_hop`` the event the walk crosses to
    (``-1`` when the edge stays within the item).  ``channel`` is the
    channel billed (-1 for channel-less kinds: issue, join, fixed).
    ``t_lo``/``t_hi`` bound the edge in time; ``ps`` is the *clipped*
    contribution — per request, contributions sum exactly to
    ``complete − issue``.
    """

    kind: int
    row: int
    hop: int
    src_row: int
    src_hop: int
    channel: int
    t_lo: int
    t_hi: int
    ps: int


class Backpointers:
    """Frozen argmax backpointers of one resolved schedule (host arrays).

    Produced by `extract_backpointers`; consumed by `critical_path`,
    `blame`, and `speedup_if`.  All arrays are NumPy; times int64
    picoseconds, exactly the engine's.
    """

    def __init__(self, *, n, h, c, issue, arrive, start, depart, valid,
                 serving, channel, wire, row_extra, fixed, bind, qpred_row,
                 qpred_hop, rsrc_row, rsrc_hop, gate_row):
        self.n, self.h, self.c = n, h, c
        self.issue = issue          # (N,)
        self.arrive = arrive        # (N, H+1)
        self.start = start          # (N, H)
        self.depart = depart        # (N, H)
        self.complete = arrive[:, h]
        self.valid = valid          # (N, H) hop exists
        self.serving = serving      # (N, H) occupies its channel
        self.channel = channel      # (N, H)
        self.wire = wire            # (N, H) serialization ps
        self.row_extra = row_extra  # (N, H) row-buffer penalty ps
        self.fixed = fixed          # (N, H) fixed_after ps
        self.bind = bind            # (N, H) B_* grant binding
        self.qpred_row = qpred_row  # (N, H) FCFS predecessor item
        self.qpred_hop = qpred_hop
        self.rsrc_row = rsrc_row    # (N, H) retrain-source item/marker
        self.rsrc_hop = rsrc_hop
        self.gate_row = gate_row    # (N,) binding join contributor, -1


def _np_wire_ser_ps(nbytes, ch: Channels, chan_clipped, extra_wire=None):
    """NumPy port of `engine.wire_ser_ps`, bit-exact for int64 inputs."""
    bw = np.asarray(ch.bw_MBps)[chan_clipped]
    base = (nbytes * 1_000_000) // bw
    if ch.flit_size is None:
        return base
    fsize = np.asarray(ch.flit_size)[chan_clipped]
    fpay = np.maximum(np.asarray(ch.flit_payload)[chan_clipped], 1)
    wire = ((nbytes + fpay - 1) // fpay) * fsize
    if extra_wire is not None:
        wire = wire + extra_wire
    fser = (wire * 1_000_000) // bw
    if ch.replay_ppm is not None:
        ppm = np.asarray(ch.replay_ppm)[chan_clipped]
        scale = 1_000_000 + ppm
        q, r = fser // 1_000_000, fser % 1_000_000
        fser = q * scale + (r * scale) // 1_000_000
    return np.where(fsize > 0, fser, base)


def extract_backpointers(hops: Hops, channels: Channels, sched: Schedule,
                         issue_ps, check: bool = True) -> Backpointers:
    """Replay the engine's scan with argmax backpointers (pure observer).

    Walks the lexsorted item sequence exactly as `engine._one_round` does —
    same segment keys, same carried per-channel state, same marker
    semantics — recording for every serving item which term of
    ``start = max(arrive, depart_prev + gap, down_until)`` bound the grant
    (ties prefer ARRIVE, then QUEUE: only strictly-gating events become
    cross edges).  ``check=True`` asserts the replay reproduces the
    schedule's ``start``/``depart``/``arrive`` columns and the join gates
    bit-for-bit, i.e. that the observer did not perturb anything.

    Streaming-window schedules (seeded carries) are not supported here —
    the streaming layer folds local blame instead (`streaming`).
    """
    n, h = hops.channel.shape
    c = int(np.asarray(channels.bw_MBps).shape[0])
    k = n * h

    arrive = np.asarray(sched.arrive, dtype=np.int64)
    start_ref = np.asarray(sched.start, dtype=np.int64)
    depart_ref = np.asarray(sched.depart, dtype=np.int64)
    issue = np.asarray(issue_ps, dtype=np.int64)

    chan2 = np.asarray(hops.channel, dtype=np.int64)
    valid2 = np.asarray(hops.valid, dtype=bool)
    nbytes2 = np.asarray(hops.nbytes, dtype=np.int64)
    dir2 = np.asarray(hops.direction, dtype=np.int64)
    row2 = np.asarray(hops.row, dtype=np.int64)
    fixed2 = np.asarray(hops.fixed_after_ps, dtype=np.int64)
    extra2 = (np.asarray(hops.extra_wire_bytes, dtype=np.int64)
              if hops.extra_wire_bytes is not None else None)
    retr2 = (np.asarray(hops.retrain_after_ps, dtype=np.int64)
             if hops.retrain_after_ps is not None else None)
    has_retrain = retr2 is not None

    flat_arrive = arrive[:, :h].reshape(k)
    flat_chan = chan2.reshape(k)
    flat_valid = valid2.reshape(k)
    flat_bytes = nbytes2.reshape(k)
    flat_dir = dir2.reshape(k)
    flat_row = row2.reshape(k)
    flat_retr = retr2.reshape(k) if has_retrain else None
    sort_chan = np.where(flat_valid, flat_chan, c)
    order = np.lexsort((np.arange(k), flat_arrive, sort_chan))

    clip_c = np.minimum(flat_chan, c - 1)
    flat_ser = _np_wire_ser_ps(
        flat_bytes, channels, clip_c,
        extra_wire=extra2.reshape(k) if extra2 is not None else None)
    turn_t = np.asarray(channels.turnaround_ps)[clip_c]
    rhit_t = np.asarray(channels.row_hit_ps)[clip_c]
    rmiss_t = np.asarray(channels.row_miss_ps)[clip_c]

    start_out = flat_arrive.copy()
    depart_out = flat_arrive.copy()
    wire_out = np.zeros(k, np.int64)
    rowx_out = np.zeros(k, np.int64)
    bind_out = np.full(k, B_NONE, np.int8)
    qpred_out = np.full(k, -1, np.int64)
    rsrc_out = np.full(k, -1, np.int64)

    # carried scan state, exactly `engine._one_round`'s (plus the argmax
    # shadows: which item set the depart frontier / the down interval)
    pc, pd, pdir, prow, pdown = -1, 0, -1, -2, 0
    p_item = -1       # flat index behind pd (-1 after a marker head reset)
    pdown_src = -1    # flat index behind pdown

    for f in order:
        ch_f = int(flat_chan[f])
        v0 = bool(flat_valid[f])
        arr = int(flat_arrive[f])
        nb = int(flat_bytes[f])
        retrain = int(flat_retr[f]) if has_retrain else 0
        marker = has_retrain and v0 and nb == 0 and retrain > 0
        srv = v0 and nb > 0
        if not (srv or marker):
            continue  # padded or pass-through: outputs stay at arrive
        same = ch_f == pc
        drn = int(flat_dir[f])
        if srv:
            gap = int(turn_t[f]) if (same and drn != pdir) else 0
            floor_q = pd + gap
            seg_down = (pdown if same else 0) if has_retrain else 0
            nodown = max(arr, floor_q) if same else arr
            start = max(nodown, seg_down) if same else arr
            row = int(flat_row[f])
            row_extra = ((int(rhit_t[f]) if (same and row == prow)
                          else int(rmiss_t[f])) if row >= 0 else 0)
            ser = int(flat_ser[f])
            depart = start + ser + row_extra
            start_out[f] = start
            depart_out[f] = depart
            wire_out[f] = ser
            rowx_out[f] = row_extra
            if start == arr:
                bind_out[f] = B_ARRIVE
            elif start == nodown:
                bind_out[f] = B_QUEUE
                if p_item < 0:
                    raise AssertionError(
                        "queue-bound grant with no predecessor item")
                qpred_out[f] = p_item
            else:
                bind_out[f] = B_RETRAIN
                if pdown_src < 0:
                    raise AssertionError(
                        "retrain-bound grant with no down source")
                rsrc_out[f] = pdown_src
            pc, pd, pdir = ch_f, depart, drn
            if row >= 0:
                prow = row
            p_item = f
        else:  # link-down marker: occupies nothing, raises down_until
            head = not same
            pc = ch_f
            if head:
                pd, pdir, prow, p_item = 0, drn, -2, -1
            depart = arr
        if has_retrain:
            seg_down = pdown if same else 0
            seg_src = pdown_src if same else -1
            contrib = depart + retrain if retrain > 0 else 0
            if contrib > seg_down:
                pdown, pdown_src = contrib, f
            else:
                pdown, pdown_src = seg_down, seg_src

    start2 = start_out.reshape(n, h)
    depart2 = depart_out.reshape(n, h)
    serving2 = valid2 & (nbytes2 > 0)

    # fork/join gates: reproduce `_join_gate` at the fixpoint and record the
    # argmax contributor of every gate that strictly delayed its waiter
    gate_row = np.full(n, -1, np.int64)
    if hops.join_id is not None:
        jid = np.asarray(hops.join_id, dtype=np.int64)
        jwait = np.asarray(hops.join_wait, dtype=np.int64)
        comp = arrive[:, h]
        gmax = np.zeros(n, np.int64)
        argrow = np.full(n, -1, np.int64)
        for r in np.nonzero(jid >= 0)[0]:  # ascending: ties pick lowest row
            g = int(jid[r])
            if comp[r] > gmax[g]:
                gmax[g], argrow[g] = comp[r], r
        waiters = jwait >= 0
        gclip = np.clip(jwait, 0, n - 1)
        gate = np.where(waiters, np.maximum(issue, gmax[gclip]), issue)
        binds = waiters & (gmax[gclip] > issue)
        gate_row[binds] = argrow[gclip[binds]]
        if check and not np.array_equal(arrive[:, 0], gate):
            raise AssertionError("join-gate replay diverged from schedule")
    elif check and not np.array_equal(arrive[:, 0], issue):
        raise AssertionError("issue replay diverged from schedule")

    if check:
        if not np.array_equal(start2, start_ref):
            raise AssertionError("backpointer replay diverged: start")
        if not np.array_equal(depart2, depart_ref):
            raise AssertionError("backpointer replay diverged: depart")
        prop = arrive[:, 0]
        for j in range(h):
            prop = np.where(valid2[:, j], depart2[:, j] + fixed2[:, j], prop)
            if not np.array_equal(arrive[:, j + 1], prop):
                raise AssertionError("backpointer replay diverged: arrive")

    qp = qpred_out
    rs = rsrc_out
    return Backpointers(
        n=n, h=h, c=c, issue=issue, arrive=arrive, start=start2,
        depart=depart2, valid=valid2, serving=serving2, channel=chan2,
        wire=wire_out.reshape(n, h), row_extra=rowx_out.reshape(n, h),
        fixed=fixed2, bind=bind_out.reshape(n, h),
        qpred_row=np.where(qp >= 0, qp // h, -1).reshape(n, h),
        qpred_hop=np.where(qp >= 0, qp % h, -1).reshape(n, h),
        rsrc_row=np.where(rs >= 0, rs // h, -1).reshape(n, h),
        rsrc_hop=np.where(rs >= 0, rs % h, -1).reshape(n, h),
        gate_row=gate_row,
    )


def critical_path(bp: Backpointers, r: int) -> list[PathEdge]:
    """The chain of gating events behind request ``r``'s completion.

    Walks backward from the completion event along the frozen backpointers,
    emitting one `PathEdge` per gating interval.  Every contribution is
    clipped against ``issue[r]`` (events wholly before the request issued
    contribute nothing, and the walk stops there), so

        sum(e.ps for e in path) == complete[r] − issue[r]

    holds exactly — the conservation invariant `blame` re-asserts.
    """
    issue_r = int(bp.issue[r])
    h = bp.h

    def clip(lo, hi):
        return max(hi, issue_r) - max(lo, issue_r)

    edges: list[PathEdge] = []
    tag, p, j = "A", int(r), h
    t = int(bp.arrive[r, h])
    limit = 16 * (bp.n * (h + 2) + 8)
    for _ in range(limit):
        if t <= issue_r:
            edges.append(PathEdge(K_ISSUE, p, -1, -1, -1, -1, t, t, 0))
            break
        if tag == "A":
            if j == 0:
                g = int(bp.gate_row[p])
                if g >= 0:  # join gate bound: cross to slowest contributor
                    edges.append(PathEdge(K_JOIN, p, -1, g, -1, -1, t, t, 0))
                    tag, p, j = "A", g, h
                else:  # reached an issue event: terminal edge absorbs rest
                    edges.append(PathEdge(
                        K_ISSUE, p, -1, -1, -1, -1, issue_r, t,
                        clip(issue_r, t)))
                    break
            else:
                jj = j - 1
                if bp.valid[p, jj]:
                    lo = int(bp.depart[p, jj])
                    ps = clip(lo, t)
                    if ps > 0:
                        edges.append(PathEdge(
                            K_FIXED, p, jj, -1, -1, -1, lo, t, ps))
                    tag, j, t = "D", jj, lo
                else:
                    j = jj  # padded hop passes the arrival through
        elif tag == "D":
            if bp.serving[p, j]:
                st = int(bp.start[p, j])
                mid = st + int(bp.wire[p, j])
                cch = int(bp.channel[p, j])
                if t > mid:
                    edges.append(PathEdge(
                        K_ROW, p, j, -1, -1, cch, mid, t, clip(mid, t)))
                if mid > st:
                    edges.append(PathEdge(
                        K_WIRE, p, j, -1, -1, cch, st, mid, clip(st, mid)))
                tag, t = "S", st
            else:
                tag = "A"  # marker / pass-through: depart == arrive
        else:  # "S": how was the grant bound?
            b = int(bp.bind[p, j])
            cch = int(bp.channel[p, j])
            if b == B_QUEUE:
                pr, pj = int(bp.qpred_row[p, j]), int(bp.qpred_hop[p, j])
                lo = int(bp.depart[pr, pj])
                edges.append(PathEdge(
                    K_QUEUE, p, j, pr, pj, cch, lo, t, clip(lo, t)))
                tag, p, j, t = "D", pr, pj, lo
            elif b == B_RETRAIN:
                sr, sj = int(bp.rsrc_row[p, j]), int(bp.rsrc_hop[p, j])
                lo = int(bp.depart[sr, sj])
                edges.append(PathEdge(
                    K_RETRAIN, p, j, sr, sj, cch, lo, t, clip(lo, t)))
                tag, p, j, t = "D", sr, sj, lo
            else:  # ARRIVE: start == arrive, zero-width move
                tag = "A"
    else:
        raise RuntimeError("critical-path walk did not terminate")
    edges.reverse()
    return edges


def critical_paths(bp: Backpointers, rows=None) -> list[list[PathEdge]]:
    """Critical paths of ``rows`` (default: every request)."""
    if rows is None:
        rows = range(bp.n)
    return [critical_path(bp, int(r)) for r in rows]


def path_total(path) -> int:
    """Sum of a path's edge contributions (== complete − issue)."""
    return sum(e.ps for e in path)


class Blame:
    """Aggregated critical-path blame: channel × edge-kind table.

    ``table`` has shape (C+1, N_KINDS); row ``C`` collects channel-less
    edges (issue / join / fixed).  All entries are int64 picoseconds and
    sum to ``total_ps`` — the summed ``complete − issue`` of the requests
    aggregated (the conservation invariant, asserted at build time).
    """

    def __init__(self, table: np.ndarray, n_requests: int, total_ps: int):
        self.table = table
        self.n_requests = n_requests
        self.total_ps = total_ps

    def by_kind(self) -> dict[str, int]:
        tot = self.table.sum(axis=0)
        return {KIND_NAMES[i]: int(tot[i]) for i in range(N_KINDS)}

    def by_channel(self) -> np.ndarray:
        """(C+1,) blame per channel (last row: channel-less edges)."""
        return self.table.sum(axis=1)

    def top(self, k: int = 5) -> list[dict]:
        """Top-k (channel, kind) bottleneck cells, largest blame first."""
        c1 = self.table.shape[0]
        flat = self.table.reshape(-1)
        order = np.argsort(flat, kind="stable")[::-1][:k]
        out = []
        denom = max(self.total_ps, 1)
        for ix in order:
            ch, kd = divmod(int(ix), N_KINDS)
            ps = int(flat[ix])
            if ps <= 0:
                break
            out.append({
                "channel": ch if ch < c1 - 1 else None,
                "kind": KIND_NAMES[kd],
                "ps": ps,
                "share": ps / denom,
            })
        return out

    def by_switch(self, graph) -> dict[int, int]:
        """Roll channel blame up to fabric nodes, largest first.

        A link channel's blame implicates both endpoint nodes; a service
        channel implicates its memory device.  Channel-less blame (issue /
        join / fixed) is not attributed to any node.
        """
        chan_nodes: dict[int, set[int]] = {}
        for (u, v), (ch_ix, _) in graph._edge.items():
            chan_nodes.setdefault(int(ch_ix), set()).update((int(u), int(v)))
        svc = np.asarray(graph._service_chan)
        for m in range(svc.shape[0]):
            for bk in range(svc.shape[1]):
                if svc[m, bk] >= 0:
                    chan_nodes.setdefault(int(svc[m, bk]), set()).add(m)
        per_chan = self.by_channel()
        out: dict[int, int] = {}
        for ch_ix, nodes in chan_nodes.items():
            ps = int(per_chan[ch_ix]) if ch_ix < self.table.shape[0] - 1 else 0
            for node in nodes:
                out[node] = out.get(node, 0) + ps
        return dict(sorted(out.items(), key=lambda kv: -kv[1]))


def blame(bp: Backpointers, rows=None, paths=None) -> Blame:
    """Aggregate per-request critical paths into a `Blame` table.

    Asserts the conservation invariant per request: edge contributions sum
    exactly to ``complete − issue``.
    """
    if rows is None:
        rows = list(range(bp.n))
    else:
        rows = [int(r) for r in rows]
    if paths is None:
        paths = [critical_path(bp, r) for r in rows]
    table = np.zeros((bp.c + 1, N_KINDS), np.int64)
    total = 0
    for r, path in zip(rows, paths):
        want = int(bp.complete[r]) - int(bp.issue[r])
        got = path_total(path)
        if got != want:
            raise AssertionError(
                f"conservation violated for row {r}: path sums to {got} ps, "
                f"complete - issue = {want} ps")
        total += want
        for e in path:
            ch_ix = e.channel if e.channel >= 0 else bp.c
            table[ch_ix, e.kind] += e.ps
    return Blame(table, len(rows), total)


def speedup_if(bp: Backpointers, channel: int, factor: float) -> dict:
    """Coz-style what-if: completion times if ``channel`` were ``factor``×
    faster, re-propagated along the frozen backpointer DAG.

    Serialization on the target channel scales to ``wire // factor``; every
    other edge weight (turnaround gaps, retrain intervals, row penalties,
    fixed latencies) and every backpointer is kept frozen, and event times
    are recomputed as ``max`` over each event's recorded parents (own
    arrival always remains a floor, so estimates stay causally sane).  This
    is a first-order estimate — contention is not re-resolved, FCFS order
    never changes — exact for ``factor == 1`` and monotone for speedups
    along the frozen DAG.
    """
    n, h = bp.n, bp.h
    on_chan = bp.serving & (bp.channel == channel)
    new_wire = np.where(on_chan,
                        (bp.wire.astype(np.float64) / factor).astype(np.int64),
                        bp.wire)
    # frozen edge weights, from the baseline schedule
    q_gap = bp.start - np.where(
        bp.bind == B_QUEUE, bp.depart[bp.qpred_row, bp.qpred_hop], bp.start)
    r_gap = bp.start - np.where(
        bp.bind == B_RETRAIN, bp.depart[bp.rsrc_row, bp.rsrc_hop], bp.start)

    A = np.full((n, h + 1), -1, np.int64)
    S = np.full((n, h), -1, np.int64)
    D = np.full((n, h), -1, np.int64)

    stack = [("A", r, h) for r in range(n)]
    budget = 64 * (n * (2 * h + 1) + 8)
    while stack:
        budget -= 1
        if budget < 0:
            raise RuntimeError("speedup_if propagation did not terminate "
                               "(cyclic backpointers?)")
        tag, p, j = stack[-1]
        if tag == "A":
            if A[p, j] >= 0:
                stack.pop()
                continue
            if j == 0:
                g = int(bp.gate_row[p])
                if g >= 0:
                    if A[g, h] < 0:
                        stack.append(("A", g, h))
                        continue
                    A[p, 0] = max(int(bp.issue[p]), int(A[g, h]))
                else:
                    A[p, 0] = int(bp.issue[p])
            elif bp.valid[p, j - 1]:
                if D[p, j - 1] < 0:
                    stack.append(("D", p, j - 1))
                    continue
                A[p, j] = int(D[p, j - 1]) + int(bp.fixed[p, j - 1])
            else:
                if A[p, j - 1] < 0:
                    stack.append(("A", p, j - 1))
                    continue
                A[p, j] = A[p, j - 1]
            stack.pop()
        elif tag == "D":
            if D[p, j] >= 0:
                stack.pop()
                continue
            if not bp.serving[p, j]:
                if A[p, j] < 0:
                    stack.append(("A", p, j))
                    continue
                D[p, j] = A[p, j]
            else:
                if S[p, j] < 0:
                    stack.append(("S", p, j))
                    continue
                D[p, j] = int(S[p, j]) + int(new_wire[p, j]) \
                    + int(bp.row_extra[p, j])
            stack.pop()
        else:  # "S"
            if S[p, j] >= 0:
                stack.pop()
                continue
            if A[p, j] < 0:
                stack.append(("A", p, j))
                continue
            b = int(bp.bind[p, j])
            if b == B_QUEUE:
                pr, pj = int(bp.qpred_row[p, j]), int(bp.qpred_hop[p, j])
                if D[pr, pj] < 0:
                    stack.append(("D", pr, pj))
                    continue
                S[p, j] = max(int(A[p, j]), int(D[pr, pj]) + int(q_gap[p, j]))
            elif b == B_RETRAIN:
                sr, sj = int(bp.rsrc_row[p, j]), int(bp.rsrc_hop[p, j])
                if D[sr, sj] < 0:
                    stack.append(("D", sr, sj))
                    continue
                S[p, j] = max(int(A[p, j]), int(D[sr, sj]) + int(r_gap[p, j]))
            else:
                S[p, j] = A[p, j]
            stack.pop()

    new_complete = A[:, h]
    base = bp.complete
    lat_new = new_complete - bp.issue
    lat_old = base - bp.issue
    nreq = max(n, 1)
    return {
        "channel": int(channel),
        "factor": float(factor),
        "complete_ps": new_complete,
        "baseline_complete_ps": base,
        "latency_delta_ps": lat_new - lat_old,
        "mean_latency_ps": int(lat_new.sum()) // nreq,
        "baseline_mean_latency_ps": int(lat_old.sum()) // nreq,
        "saved_ps": int((lat_old - lat_new).sum()),
    }
