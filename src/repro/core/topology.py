"""Interconnect layer: topology graph + routing (ESF §III-A, §III-C).

The ESF interconnect layer receives, at initialization, a set of device pairs
configured as directly connected by physical links, builds an internal topology
graph, and computes a default shortest-path routing strategy that all devices
(and in particular PBR switches) query during simulation.

This module is the JAX-framework port of that layer.  Topology construction and
all-pairs routing happen once at config time in numpy (exactly like ESF's init
phase); the resulting dense tables (channel table, next-hop matrices, routes)
are consumed by the tensorized engine (`core.engine`) which is pure JAX.

Nodes are integers with a *kind* (REQUESTER / SWITCH / MEMORY).  Every physical
link materializes as either

  * two directed *channels* (full-duplex PCIe semantics; each direction gets the
    full configured bandwidth — ESF's "bandwidth allocation unit"), or
  * one shared channel with a direction-change turnaround penalty (half-duplex,
    ESF's configurable fallback used to model DDR-style buses).

Memory endpoints additionally own one or more *service channels* (one per DRAM
bank group when the banked endpoint model is enabled) so that endpoint service
contention is resolved by the same FCFS machinery as link contention.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from . import link_layer
from .link_layer import FlitConfig

REQUESTER, SWITCH, MEMORY = 0, 1, 2
KIND_NAMES = {REQUESTER: "requester", SWITCH: "switch", MEMORY: "memory"}

FULL, HALF = "full", "half"

# A value safely larger than any real path cost but far from int overflow.
_INF = np.int64(1) << 48


@dataclass(frozen=True)
class LinkSpec:
    """One configured physical link between nodes ``a`` and ``b``.

    bw_MBps      serialization bandwidth per direction, in MB/s (1e6 bytes/s).
                 For flit-mode links this is the lane rate after line
                 encoding but before flit framing (`calibration.*_RAW_MBPS`);
                 CRC/FEC flit overhead and credit caps are applied by
                 `core.link_layer`.
    fixed_ps     per-traversal fixed latency in picoseconds (port delay +
                 propagation; ESF Table III: 25 ns port + 1 ns bus).
    duplex       "full" or "half".
    turnaround_ps  half-duplex direction-change penalty.
    flit         link-layer config (`link_layer.FlitConfig`), a mode string
                 ("none" | "flit68" | "flit256"), or None for the seed's
                 byte-exact serialization.
    """

    a: int
    b: int
    bw_MBps: int
    fixed_ps: int
    duplex: str = FULL
    turnaround_ps: int = 0
    flit: FlitConfig | str | None = None


@dataclass(frozen=True)
class EndpointSpec:
    """Service model of a memory endpoint (stands in for DRAMsim3/SimpleSSD).

    ESF integrates cycle/event simulators as endpoint components (§III-E); we
    reproduce the integration seam as a pluggable latency/bandwidth/bank model.

    bw_MBps        endpoint service bandwidth (aggregated DIMM bandwidth).
    fixed_ps       controller processing time (Table III: 40 ns).
    banks          number of independently schedulable banks (1 = flat model).
    row_hit_extra_ps / row_miss_extra_ps   row-buffer model: an access to the
                 same row as the previous access to that bank pays the hit
                 cost, otherwise the miss cost (activate+precharge).
    lines_per_row  cachelines per DRAM row (for row id derivation).
    """

    bw_MBps: int = 153_600  # 4x DDR5-4800 DIMMs
    fixed_ps: int = 40_000
    banks: int = 1
    row_hit_extra_ps: int = 0
    row_miss_extra_ps: int = 0
    lines_per_row: int = 128


@dataclass
class Topology:
    """A configured system: node kinds + physical links + endpoint models."""

    kinds: np.ndarray
    links: list[LinkSpec]
    name: str = "custom"
    endpoint: EndpointSpec = field(default_factory=EndpointSpec)
    switching_ps: int = 20_000  # Table III switching time, applied per switch hop

    @property
    def n_nodes(self) -> int:
        return int(len(self.kinds))

    def requesters(self) -> np.ndarray:
        return np.where(self.kinds == REQUESTER)[0]

    def memories(self) -> np.ndarray:
        return np.where(self.kinds == MEMORY)[0]

    def build(self) -> "FabricGraph":
        return FabricGraph(self)


class FabricGraph:
    """Built topology: channel tables + all-pairs next-hop routing.

    Mirrors ESF's interconnect layer: after construction, ``route(src, dst)``
    returns the default shortest-path node sequence; ``routing_table(switch)``
    exposes the per-switch PBR table (next hop for every destination) the way
    ESF switches consume graph information to build internal routing tables.
    ``route_alternatives`` enumerates equal-cost paths for adaptive routing.
    """

    def __init__(self, topo: Topology):
        self.topo = topo
        n = topo.n_nodes
        kinds = topo.kinds

        # ---- channels ------------------------------------------------------
        # channel arrays: bw, fixed, turnaround, is_service + flit tables
        bw, fixed, turn, is_service = [], [], [], []
        f_size, f_pay, f_ppm = [], [], []
        # stochastic-reliability sampling parameters (consumed at build time
        # by devices.build_workload via link_layer.sample_hop_tables; they
        # never enter the engine's channel arrays)
        r_sto, r_p, r_win, r_thr, r_down, r_seed = [], [], [], [], [], []
        # full-duplex pairing (reverse channel of each direction; -1 for
        # half-duplex and service channels) + credit-return DLLP config
        pair, c_dllp, c_win = [], [], []
        # directed edge lookup: (u, v) -> (channel, direction flag)
        self._edge: dict[tuple[int, int], tuple[int, int]] = {}
        self._adj: list[list[int]] = [[] for _ in range(n)]
        self._link_cost = np.full((n, n), _INF, dtype=np.int64)

        for ls in topo.links:
            a, b = ls.a, ls.b
            # link-layer lowering: credit-capped bandwidth, FEC latency into
            # the per-traversal fixed cost, flit geometry + replay tables
            low = link_layer.lower_link(ls.bw_MBps, ls.flit)
            n_dirs = 2 if ls.duplex == FULL else 1
            if ls.duplex == FULL:
                c0 = len(bw)
                turn += [0, 0]
                pair += [c0 + 1, c0]
                self._edge[(a, b)] = (c0, 0)
                self._edge[(b, a)] = (c0 + 1, 0)
            else:
                c0 = len(bw)
                turn += [ls.turnaround_ps]
                pair += [-1]
                self._edge[(a, b)] = (c0, 0)
                self._edge[(b, a)] = (c0, 1)
            bw += [low.eff_bw_MBps] * n_dirs
            c_dllp += [low.credit_dllp] * n_dirs
            c_win += [low.credit_window] * n_dirs
            fixed += [ls.fixed_ps + low.extra_fixed_ps] * n_dirs
            is_service += [False] * n_dirs
            f_size += [low.flit_size] * n_dirs
            f_pay += [low.flit_payload] * n_dirs
            f_ppm += [low.replay_ppm] * n_dirs
            r_sto += [low.stochastic] * n_dirs
            r_p += [low.flit_err_p] * n_dirs
            r_win += [low.retry_window] * n_dirs
            r_thr += [low.retrain_threshold] * n_dirs
            r_down += [low.retrain_ps] * n_dirs
            r_seed += [low.rel_seed] * n_dirs
            self._adj[a].append(b)
            self._adj[b].append(a)
            cost = np.int64(ls.fixed_ps) + (1 << 20)  # hop-count dominant, latency tiebreak
            self._link_cost[a, b] = min(self._link_cost[a, b], cost)
            self._link_cost[b, a] = min(self._link_cost[b, a], cost)

        # ---- endpoint service channels (one per bank) ----------------------
        ep = topo.endpoint
        self._service_chan = np.full((n, ep.banks), -1, dtype=np.int64)
        for m in np.where(kinds == MEMORY)[0]:
            for bk in range(ep.banks):
                self._service_chan[m, bk] = len(bw)
                bw.append(ep.bw_MBps)
                fixed.append(ep.fixed_ps)
                turn.append(0)
                is_service.append(True)
                f_size.append(0)
                f_pay.append(0)
                f_ppm.append(0)
                r_sto.append(False)
                r_p.append(0.0)
                r_win.append(0)
                r_thr.append(0)
                r_down.append(0)
                r_seed.append(0)
                pair.append(-1)
                c_dllp.append(False)
                c_win.append(0)

        self.chan_bw_MBps = np.asarray(bw, dtype=np.int64)
        self.chan_fixed_ps = np.asarray(fixed, dtype=np.int64)
        self.chan_turnaround_ps = np.asarray(turn, dtype=np.int64)
        self.chan_is_service = np.asarray(is_service, dtype=bool)
        self.chan_flit_size = np.asarray(f_size, dtype=np.int64)
        self.chan_flit_payload = np.asarray(f_pay, dtype=np.int64)
        self.chan_replay_ppm = np.asarray(f_ppm, dtype=np.int64)
        self.chan_rel_stochastic = np.asarray(r_sto, dtype=bool)
        self.chan_flit_err_p = np.asarray(r_p, dtype=np.float64)
        self.chan_retry_window = np.asarray(r_win, dtype=np.int64)
        self.chan_retrain_threshold = np.asarray(r_thr, dtype=np.int64)
        self.chan_retrain_ps = np.asarray(r_down, dtype=np.int64)
        self.chan_rel_seed = np.asarray(r_seed, dtype=np.int64)
        self.chan_pair = np.asarray(pair, dtype=np.int64)
        self.chan_credit_dllp = np.asarray(c_dllp, dtype=bool)
        self.chan_credit_window = np.asarray(c_win, dtype=np.int64)
        self.n_channels = len(bw)

        # ---- all-pairs shortest paths (Floyd–Warshall w/ next-hop) ---------
        dist = self._link_cost.copy()
        np.fill_diagonal(dist, 0)
        nxt = np.where(dist < _INF, np.arange(n)[None, :], -1).astype(np.int64)
        np.fill_diagonal(nxt, np.arange(n))
        for k in range(n):
            alt = dist[:, k, None] + dist[None, k, :]
            better = alt < dist
            dist = np.where(better, alt, dist)
            nxt = np.where(better, nxt[:, k, None], nxt)
        self.dist = dist
        self.next_hop = nxt

        # equal-cost next-hop alternatives for adaptive routing (ESF switches
        # may "access detailed graph information to create dedicated routing")
        self._alt_next: list[list[list[int]]] = [[[] for _ in range(n)] for _ in range(n)]
        for u in range(n):
            for v in range(n):
                if u == v or dist[u, v] >= _INF:
                    continue
                for w in self._adj[u]:
                    if self._link_cost[u, w] + dist[w, v] == dist[u, v]:
                        self._alt_next[u][v].append(w)

    # ---- routing queries ---------------------------------------------------
    def route(self, src: int, dst: int, alt: int = 0) -> list[int]:
        """Default shortest-path node sequence src..dst.

        ``alt`` selects among equal-cost paths: at every node the ``alt``-th
        (mod fan-out) equal-cost next hop is taken — the ECMP-style alternative
        set used by the adaptive routing strategy (paper §V-A, Fig. 13).
        """
        if src == dst:
            return [src]
        if self.dist[src, dst] >= _INF:
            raise ValueError(f"no route {src}->{dst} in topology {self.topo.name!r}")
        path = [src]
        u = src
        while u != dst:
            opts = self._alt_next[u][dst]
            u = opts[alt % len(opts)]
            path.append(u)
            if len(path) > self.topo.n_nodes + 1:
                raise RuntimeError("routing loop")
        return path

    def n_route_alternatives(self, src: int, dst: int) -> int:
        """Effective count of equal-cost path alternatives: the maximum
        equal-cost branching factor along the default route (each route(alt=k)
        rotates the choice at every branching node by k)."""
        if src == dst:
            return 1
        n = 1
        u = src
        hops = 0
        while u != dst:
            opts = self._alt_next[u][dst]
            n = max(n, len(opts))
            u = opts[0]
            hops += 1
            if hops > self.topo.n_nodes:  # pragma: no cover
                raise RuntimeError("routing loop")
        return n

    def routing_table(self, switch: int) -> np.ndarray:
        """PBR routing table for one switch: next hop per destination node id.

        This is exactly the structure an ESF PBR switch builds from the
        interconnect layer's graph data (§III-C): on packet arrival it forwards
        toward ``table[dst]``.
        """
        return self.next_hop[switch].copy()

    def edge_channel(self, u: int, v: int) -> tuple[int, int]:
        """(channel id, direction flag) of directed edge u->v."""
        return self._edge[(u, v)]

    def service_channel(self, mem: int, bank: int = 0) -> int:
        c = int(self._service_chan[mem, bank % self.topo.endpoint.banks])
        if c < 0:
            raise ValueError(f"node {mem} is not a memory endpoint")
        return c

    def hops(self, src: int, dst: int) -> int:
        return len(self.route(src, dst)) - 1


# ---------------------------------------------------------------------------
# Topology builders for the paper's five studied fabrics (Fig. 9) + CXL basics
# ---------------------------------------------------------------------------

def _mk(kinds: Sequence[int], links: list[LinkSpec], name: str, **kw) -> Topology:
    return Topology(np.asarray(kinds, dtype=np.int64), links, name=name, **kw)


def _pair_switch_nodes(n_pairs: int, per_leaf: int = 1):
    """kinds + attach lists for the §V-A fabrics: N requesters on one side of
    the fabric, N memories on the other (the segregation visible in Fig. 9 —
    it is what makes every request/response cross the fabric and lets the
    'bridge' routes of chain/tree saturate at exactly one port's bandwidth).

    Returns (kinds, switch_ids, req_ids, mem_ids, leaf_of) where the first
    half of switch_ids host requesters and the second half host memories.
    """
    kinds: list[int] = []
    reqs, mems, leaf_of_req, leaf_of_mem = [], [], [], []
    n_side = max(n_pairs // per_leaf, 1)
    switches = list(range(2 * n_side))
    kinds += [SWITCH] * (2 * n_side)
    for i in range(n_pairs):
        reqs.append(len(kinds))
        kinds.append(REQUESTER)
        leaf_of_req.append(i // per_leaf)
    for i in range(n_pairs):
        mems.append(len(kinds))
        kinds.append(MEMORY)
        leaf_of_mem.append(n_side + i // per_leaf)
    return kinds, switches, reqs, mems, (leaf_of_req, leaf_of_mem)


def _attach_endpoints(links, reqs, mems, leaf_of, switches, bw, fixed):
    leaf_of_req, leaf_of_mem = leaf_of
    for r, lf in zip(reqs, leaf_of_req):
        links.append(LinkSpec(r, switches[lf], bw, fixed))
    for m, lf in zip(mems, leaf_of_mem):
        links.append(LinkSpec(m, switches[lf], bw, fixed))


def chain(n_pairs: int, bw_MBps: int = 64_000, fixed_ps: int = 26_000, **kw) -> Topology:
    """N leaf switches in a line, each hosting one requester + one memory."""
    kinds, sw, reqs, mems, leaf_of = _pair_switch_nodes(n_pairs)
    links: list[LinkSpec] = []
    for i in range(len(sw) - 1):
        links.append(LinkSpec(sw[i], sw[i + 1], bw_MBps, fixed_ps))
    _attach_endpoints(links, reqs, mems, leaf_of, sw, bw_MBps, fixed_ps)
    return _mk(kinds, links, f"chain{n_pairs}", **kw)


def tree(n_pairs: int, bw_MBps: int = 64_000, fixed_ps: int = 26_000, **kw) -> Topology:
    """Binary tree of switches; leaf switches host one requester + one memory.

    Routes adjacent to the root are the 'bridge' routes of paper §V-A.
    """
    kinds, sw, reqs, mems, leaf_of = _pair_switch_nodes(n_pairs)
    links: list[LinkSpec] = []
    # build a binary tree over the leaf switches: internal switches appended
    level = list(sw)
    next_id = len(kinds)
    while len(level) > 1:
        parents = []
        for i in range(0, len(level), 2):
            p = next_id
            next_id += 1
            kinds.append(SWITCH)
            links.append(LinkSpec(level[i], p, bw_MBps, fixed_ps))
            if i + 1 < len(level):
                links.append(LinkSpec(level[i + 1], p, bw_MBps, fixed_ps))
            parents.append(p)
        level = parents
    _attach_endpoints(links, reqs, mems, leaf_of, sw, bw_MBps, fixed_ps)
    return _mk(kinds, links, f"tree{n_pairs}", **kw)


def ring(n_pairs: int, bw_MBps: int = 64_000, fixed_ps: int = 26_000, **kw) -> Topology:
    kinds, sw, reqs, mems, leaf_of = _pair_switch_nodes(n_pairs)
    links: list[LinkSpec] = []
    for i in range(len(sw)):
        links.append(LinkSpec(sw[i], sw[(i + 1) % len(sw)], bw_MBps, fixed_ps))
    _attach_endpoints(links, reqs, mems, leaf_of, sw, bw_MBps, fixed_ps)
    return _mk(kinds, links, f"ring{n_pairs}", **kw)


def spine_leaf(n_pairs: int, n_spines: int = 2, per_leaf: int = 2,
               bw_MBps: int = 64_000, fixed_ps: int = 26_000, **kw) -> Topology:
    """Leaves host ``per_leaf`` requester/memory pairs; every leaf uplinks to
    every spine.  With per_leaf=2 and 2 spines the leaf uplinks are 2:1
    oversubscribed against endpoint ports, reproducing the paper's N/2 scaling
    (§V-A observes residual 'competition among requesters on ports in leaf
    switches')."""
    kinds, leaves, reqs, mems, leaf_of = _pair_switch_nodes(n_pairs, per_leaf=per_leaf)
    links: list[LinkSpec] = []
    spines = []
    for _ in range(n_spines):
        spines.append(len(kinds))
        kinds.append(SWITCH)
    for lf in leaves:
        for sp in spines:
            links.append(LinkSpec(lf, sp, bw_MBps, fixed_ps))
    _attach_endpoints(links, reqs, mems, leaf_of, leaves, bw_MBps, fixed_ps)
    return _mk(kinds, links, f"spineleaf{n_pairs}", **kw)


def fully_connected(n_pairs: int, bw_MBps: int = 64_000, fixed_ps: int = 26_000, **kw) -> Topology:
    kinds, sw, reqs, mems, leaf_of = _pair_switch_nodes(n_pairs)
    links: list[LinkSpec] = []
    for i in range(len(sw)):
        for j in range(i + 1, len(sw)):
            links.append(LinkSpec(sw[i], sw[j], bw_MBps, fixed_ps))
    _attach_endpoints(links, reqs, mems, leaf_of, sw, bw_MBps, fixed_ps)
    return _mk(kinds, links, f"fc{n_pairs}", **kw)


def single_bus(n_mems: int = 4, bw_MBps: int = 64_000, fixed_ps: int = 26_000,
               duplex: str = FULL, turnaround_ps: int = 0, **kw) -> Topology:
    """The §IV validation system: one requester -- bus(switch) -- N memories."""
    kinds = [REQUESTER, SWITCH] + [MEMORY] * n_mems
    links = [LinkSpec(0, 1, bw_MBps, fixed_ps, duplex, turnaround_ps)]
    for m in range(n_mems):
        links.append(LinkSpec(1, 2 + m, bw_MBps, fixed_ps, duplex, turnaround_ps))
    return _mk(kinds, links, f"bus{n_mems}", **kw)


def with_flit(topo: Topology, flit: FlitConfig | str | None) -> Topology:
    """Copy of ``topo`` with every physical link running the given flit
    config — the one-liner that moves a whole fabric between byte-exact,
    68 B-flit (PCIe 5 / CXL 2.0) and 256 B-flit (PCIe 6 / CXL 3.x) modes."""
    from dataclasses import replace as _replace

    return Topology(
        topo.kinds.copy(),
        [_replace(ls, flit=flit) for ls in topo.links],
        name=topo.name, endpoint=topo.endpoint,
        switching_ps=topo.switching_ps,
    )


TOPOLOGY_BUILDERS = {
    "chain": chain,
    "tree": tree,
    "ring": ring,
    "spine_leaf": spine_leaf,
    "fully_connected": fully_connected,
}
