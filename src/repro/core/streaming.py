"""Streaming windowed simulation: million-request traces at flat memory.

`engine.simulate` resolves one bounded workload in a single fixpoint over all
rows — O(N·H) schedule arrays, a wall for production-shaped traces.  This
module turns the same engine into a **stream processor**: a long trace is
consumed as an iterator of chunks, each chunk is resolved as one fixed-size
*window* seeded with the carried fabric state, and the resolved schedule is
folded into running accumulators (`telemetry.StreamTelemetry`) instead of
being materialized.  Memory is bounded by the window size, never the trace.

Correctness rests on one property of the FCFS engine: service order on a
channel equals the global key order ``(arrival, flat item index)``.  Let
``T_next`` be the minimum issue time of every not-yet-consumed row.  Then any
item whose **arrival is <= T_next** is *settled*: every item that could still
appear has arrival >= its issue >= ``T_next`` and loses the flat-index
tie-break (later rows get larger global ids), so nothing can ever precede the
settled item on its channel — its grant is final.  Per channel the settled
items form a key-order prefix, so the whole service history collapses to the
state after the last settled item — exactly `engine.StreamCarry`:

  * per-channel ``(depart, direction, DRAM row)`` frontier of the last
    settled serving item,
  * per-channel ``down_until`` — the running max of settled retraining
    contributions (served hops *and* link-down markers; a settled marker can
    never out-key an unsettled item, so it folds entirely into the carry),
  * per-join-group max completion of already-retired contributors.

Rows with unsettled items re-enter the next window as *suffixes*: hops before
the first unsettled valid hop ``k0`` are final, so the row restarts with
``issue = arrive[k0]``.  A fork/join waiter whose gated arrival exceeds
``T_next`` is carried whole (``k0 = 0``) with its nominal issue and its
``join_wait`` intact — its gate is re-resolved next window from the carried
group seed plus any still-in-flight contributors.  (A gated arrival <=
``T_next`` is self-consistently final: the gate bounds every contributor
completion, which bounds every contributor arrival, so all contributors are
settled and the max is exact.)

Window assembly preserves bit-exactness by construction: rows are laid out as
``[carried rows in original global order] + [chunk rows] + [padding]``, which
preserves the lexicographic (row, hop) order of flat indices and therefore
every FCFS tie-break; the `ref_des` oracle accepts the same carry, so the
windowed run — any window size — equals the monolithic run bit for bit (the
property suite pins this).

Contracts on the chunk stream (asserted here):
  * chunk minimum issue times are non-decreasing along the stream (chunks
    are windows of a time-ordered trace);
  * every fork/join group is wholly contained in one chunk, with chunk-local
    group ids (`stream_windows` cuts on group boundaries automatically);
  * all chunks share one optional-field layout (reliability / join tables).
"""

from __future__ import annotations

import warnings
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

import jax

from . import ref_des, verify
from .engine import (Channels, Hops, SimOptions, StreamCarry, replay_round,
                     round_bound, simulate)
from .telemetry import (StreamTelemetry, stream_telemetry_finalize,
                        stream_telemetry_fold, stream_telemetry_new)

_INT64_MAX = np.iinfo(np.int64).max

_BASE_FIELDS = ("channel", "nbytes", "direction", "row", "fixed_after_ps",
                "is_payload", "valid")
_COLLECT_KEYS = ("item_row", "item_hop", "item_start", "item_depart",
                 "item_arrive", "row_id", "row_complete", "gate_row",
                 "gate_arrive0")


def _np(x):
    return None if x is None else np.asarray(x)


def _fold_backlog(run, peak, t, c, y):
    """Fold flushed ±1 backlog events into per-channel (run, peak) in place.

    Events are sorted (time, arrivals-first) per channel — the monolithic
    `telemetry.channel_telemetry` order.  The peak is invariant under
    reordering *within* one (channel, time, type) group (equal deltas
    commute), so any stable per-channel fold of the settled history equals
    the global sort bit-for-bit.
    """
    for cv in np.unique(c):
        m = c == cv
        o = np.lexsort((y[m], t[m]))
        bl = run[cv] + np.cumsum(np.where(y[m][o] == 0, 1, -1))
        peak[cv] = max(int(peak[cv]), int(bl.max()))
        run[cv] = int(bl[-1])


@jax.jit
def _stall_replay(hops: Hops, channels: Channels, sched, carry: StreamCarry):
    """Per-item retraining stall of one window, replayed from its seeded
    fixpoint (`engine.replay_round` with the window's carry)."""
    return replay_round(hops, channels, sched, carry=carry)[2]


class StreamState:
    """Host-side state carried across windows: the per-channel frontier
    (mirroring `engine.StreamCarry`), the in-flight row suffixes, retired
    join-group maxes, and the running telemetry fold.  Construct with
    `StreamState(channels)`; `simulate_stream` mutates it in place."""

    def __init__(self, channels: Channels):
        c = int(channels.bw_MBps.shape[0])
        self.n_channels = c
        self.ch_dep = np.zeros(c, np.int64)
        self.ch_dir = np.full(c, -1, np.int8)
        self.ch_row = np.full(c, -2, np.int32)
        self.ch_down = np.zeros(c, np.int64)
        self.carried: list[dict] = []   # gid-ordered in-flight row suffixes
        self.jseed: dict = {}           # group key -> retired-contributor max
        self.telemetry: StreamTelemetry = stream_telemetry_new(c)
        self.layout = None              # (has_extra, has_retrain, has_join)
        self.windows = 0
        self.oracle_windows = 0
        self.n_rows = 0
        self.carried_peak = 0
        self.chunk_idx = 0
        self.gid_next = 0
        # fixpoint diagnostics folded across windows (mirrors what
        # `benchmarks.run --json` records for monolithic runs)
        self.rounds_sum = 0
        self.rounds_max = 0
        self.windows_converged = 0
        # streamed peak backlog: pending ±1 events (arrive +1 / grant −1)
        # not yet flushable — events at or after T_next must wait, because
        # later windows can still emit events at exactly T_next — plus the
        # carried per-channel running backlog and peak over flushed history
        self.bl_t = np.zeros(0, np.int64)   # pending event times
        self.bl_c = np.zeros(0, np.int64)   # pending event channels
        self.bl_y = np.zeros(0, np.int8)    # pending type: 0 arrive, 1 grant
        self.bl_run = np.zeros(c, np.int64)
        self.bl_peak = np.zeros(c, np.int64)


class StreamResult(NamedTuple):
    """What a finished stream run hands back: the telemetry fold plus the
    overhead counters the bench records (`windows`, `carried_peak` — peak
    in-flight rows at any window edge — and how many windows needed the
    oracle fallback).  ``collected`` (only under ``collect_schedule=True``,
    test scale) holds the settled per-item schedule in global coordinates
    for bit-exact comparison against a monolithic run.

    ``rounds`` / ``converged`` / ``residual_ps`` are the unified fixpoint
    diagnostics every entry point reports (`engine.Schedule`,
    `coherence_traffic.CoupledResult`): total engine rounds across all
    windows, whether every window's fixpoint converged on its own (a
    ``False`` here means the oracle fallback resolved some windows), and
    the residual of the *returned* schedule — always 0 for a stream, since
    a non-converged window is either oracle-resolved exactly or raises."""

    telemetry: StreamTelemetry
    windows: int
    carried_peak: int
    oracle_windows: int
    n_rows: int
    state: StreamState
    collected: dict | None = None
    rounds: int = 0
    converged: bool = True
    residual_ps: int = 0

    def summary(self, qs=(0.5, 0.99, 0.999)) -> dict:
        out = stream_telemetry_finalize(self.telemetry, qs)
        out.update(windows=self.windows, carried_peak=self.carried_peak,
                   oracle_windows=self.oracle_windows, n_rows=self.n_rows,
                   rounds_sum=self.state.rounds_sum,
                   rounds_max=self.state.rounds_max,
                   windows_converged=self.state.windows_converged)
        # drain any pending backlog events into copies: exact for a finished
        # stream (the final window flushes everything), best-effort mid-run
        run, peak = self.state.bl_run.copy(), self.state.bl_peak.copy()
        _fold_backlog(run, peak, self.state.bl_t, self.state.bl_c,
                      self.state.bl_y)
        out["peak_backlog"] = peak
        return out


def _min_issue(issue) -> int:
    return int(np.min(np.asarray(issue)))


def _ensure_layout(state: StreamState, ck_hops: Hops) -> tuple:
    layout = (ck_hops.extra_wire_bytes is not None,
              ck_hops.retrain_after_ps is not None,
              ck_hops.join_id is not None)
    if state.layout is None:
        state.layout = layout
    elif state.layout != layout:
        raise ValueError("all chunks must share one optional-field layout; "
                         f"got {layout} after {state.layout}")
    return layout


def _process_window(state: StreamState, channels: Channels, ck_hops: Hops,
                    ck_issue, t_next: int, opts: SimOptions, pad_to: int,
                    oracle_fallback: bool, collect: dict | None) -> None:
    has_extra, has_retrain, has_join = _ensure_layout(state, ck_hops)

    c_np = {f: _np(getattr(ck_hops, f)) for f in _BASE_FIELDS}
    if has_extra:
        c_np["extra_wire_bytes"] = _np(ck_hops.extra_wire_bytes)
    if has_retrain:
        c_np["retrain_after_ps"] = _np(ck_hops.retrain_after_ps)
    n_c, h_c = c_np["channel"].shape
    c_issue = np.asarray(ck_issue, np.int64)
    ci = state.chunk_idx
    carried = state.carried
    n_k = len(carried)
    n_raw = n_k + n_c

    # ---- window group-id space: carried groups first, then chunk groups
    keys: dict = {}
    if has_join:
        for r in carried:
            for key in (r["jwait"], r["jid"]):
                if key is not None:
                    keys.setdefault(key, len(keys))
        cj = _np(ck_hops.join_id)
        cw = _np(ck_hops.join_wait)
        ca = _np(ck_hops.join_arity)
        for g in np.unique(np.concatenate([cj[cj >= 0], cw[cw >= 0]])):
            keys.setdefault((ci, int(g)), len(keys))
    n_groups = len(keys)

    n_pad = -(-max(n_raw, n_groups, 1) // pad_to) * pad_to
    h_w = max([h_c, 1] + [r["hops"]["channel"].shape[0] for r in carried])

    # ---- assemble the window: carried suffixes, chunk rows, padding
    W = {
        "channel": np.zeros((n_pad, h_w), np.int32),
        "nbytes": np.zeros((n_pad, h_w), np.int64),
        "direction": np.zeros((n_pad, h_w), np.int8),
        "row": np.full((n_pad, h_w), -1, np.int32),
        "fixed_after_ps": np.zeros((n_pad, h_w), np.int64),
        "is_payload": np.zeros((n_pad, h_w), bool),
        "valid": np.zeros((n_pad, h_w), bool),
    }
    if has_extra:
        W["extra_wire_bytes"] = np.zeros((n_pad, h_w), np.int64)
    if has_retrain:
        W["retrain_after_ps"] = np.zeros((n_pad, h_w), np.int64)
    issue_w = np.zeros(n_pad, np.int64)
    orig_issue = np.zeros(n_pad, np.int64)
    gid_w = np.full(n_pad, -1, np.int64)
    hop0_w = np.zeros(n_pad, np.int64)
    if has_join:
        jid_w = np.full(n_pad, -1, np.int32)
        jwait_w = np.full(n_pad, -1, np.int32)

    for i, r in enumerate(carried):
        length = r["hops"]["channel"].shape[0]
        for f, a in r["hops"].items():
            W[f][i, :length] = a
        issue_w[i] = r["issue"]
        orig_issue[i] = r["orig_issue"]
        gid_w[i] = r["gid"]
        hop0_w[i] = r["hop0"]
        if has_join:
            if r["jid"] is not None:
                jid_w[i] = keys[r["jid"]]
            if r["jwait"] is not None:
                jwait_w[i] = keys[r["jwait"]]
    for f in W:
        W[f][n_k:n_raw, :h_c] = c_np[f]
    issue_w[n_k:n_raw] = c_issue
    orig_issue[n_k:n_raw] = c_issue
    gid_w[n_k:n_raw] = state.gid_next + np.arange(n_c)
    state.gid_next += n_c
    if has_join:
        for src, dst in ((cj, jid_w), (cw, jwait_w)):
            m = src >= 0
            dst[n_k:n_raw][m] = np.fromiter(
                (keys[(ci, int(g))] for g in src[m]), np.int32, int(m.sum()))
        # arity contract rewritten to the contributors actually present in
        # this window; retired contributors act through the group seed
        counts = np.bincount(jid_w[jid_w >= 0], minlength=max(n_groups, 1))
        jar_w = np.zeros(n_pad, np.int32)
        wm = jwait_w >= 0
        jar_w[wm] = counts[jwait_w[wm]].astype(np.int32)
        del ca
        seed = np.zeros(n_pad, np.int64)
        for key, v in state.jseed.items():
            seed[keys[key]] = v

    hops_w = Hops(
        channel=jnp.asarray(W["channel"]),
        nbytes=jnp.asarray(W["nbytes"]),
        direction=jnp.asarray(W["direction"]),
        row=jnp.asarray(W["row"]),
        fixed_after_ps=jnp.asarray(W["fixed_after_ps"]),
        is_payload=jnp.asarray(W["is_payload"]),
        valid=jnp.asarray(W["valid"]),
        extra_wire_bytes=(jnp.asarray(W["extra_wire_bytes"])
                          if has_extra else None),
        retrain_after_ps=(jnp.asarray(W["retrain_after_ps"])
                          if has_retrain else None),
        join_id=jnp.asarray(jid_w) if has_join else None,
        join_wait=jnp.asarray(jwait_w) if has_join else None,
        join_arity=jnp.asarray(jar_w) if has_join else None,
    )
    # copies, not views: jnp.asarray can alias host numpy buffers, and the
    # async _stall_replay below would otherwise race the in-place frontier
    # update at the end of this window
    carry = StreamCarry(
        depart_ps=jnp.asarray(state.ch_dep.copy()),
        last_dir=jnp.asarray(state.ch_dir.copy()),
        last_row=jnp.asarray(state.ch_row.copy()),
        down_until_ps=jnp.asarray(state.ch_down.copy()),
        join_seed_ps=jnp.asarray(seed) if has_join else None,
    )

    # ---- resolve the window from the carried frontier
    sched = simulate(hops_w, channels, jnp.asarray(issue_w), opts,
                     carry=carry)
    if bool(sched.converged):
        arr = np.asarray(sched.arrive)
        st = np.asarray(sched.start)
        dp = np.asarray(sched.depart)
        fold_sched = sched
    else:
        if not oracle_fallback:
            raise RuntimeError(
                f"window {state.windows} did not converge in "
                f"{opts.max_rounds or round_bound(hops_w)} rounds "
                "(check='off' disables the oracle fallback)")
        ref = ref_des.simulate_ref(hops_w, channels, issue_w, carry=carry)
        arr, st, dp = ref["arrive"], ref["start"], ref["depart"]
        fold_sched = ref_des.ref_schedule(ref)
        state.oracle_windows += 1
    r_used = int(sched.rounds)
    state.rounds_sum += r_used
    state.rounds_max = max(state.rounds_max, r_used)
    state.windows_converged += int(bool(sched.converged))

    # ---- settlement: arrival <= T_next is final (see module docstring)
    valid_np = W["valid"]
    arr_h = arr[:, :h_w]
    settled = arr_h <= t_next
    real = gid_w >= 0
    uns = valid_np & ~settled
    anyu = uns.any(axis=1)
    k0 = np.where(anyu, uns.argmax(axis=1), h_w)
    if has_join:
        hold = (jwait_w >= 0) & (arr[:, 0] > t_next) & real
        k0 = np.where(hold, 0, k0)
    else:
        hold = np.zeros(n_pad, bool)
    carried_mask = real & (anyu | hold)
    retired = real & ~carried_mask

    # ---- fold settled items / retired rows into the running telemetry
    # gated arrival (hence the row's join wait) is final once the row
    # retires or makes progress — each global row is recorded exactly once
    gate_rec = (real & (hop0_w == 0)
                & (retired | (carried_mask & (k0 > 0))))
    lat = np.where(retired, arr[:, h_w] - orig_issue, 0)
    gate_wait = np.where(gate_rec, arr[:, 0] - orig_issue, 0)
    if has_retrain:
        stall = _stall_replay(hops_w, channels, fold_sched, carry)
    else:
        stall = jnp.zeros((n_pad, h_w), jnp.int64)
    state.telemetry = stream_telemetry_fold(
        state.telemetry, hops_w, channels, fold_sched,
        jnp.asarray(valid_np & settled), jnp.asarray(retired),
        jnp.asarray(lat), stall, jnp.asarray(gate_rec),
        jnp.asarray(gate_wait))

    if collect is not None:
        si, sh = np.nonzero((valid_np & settled) & real[:, None])
        collect["item_row"].append(gid_w[si])
        collect["item_hop"].append(hop0_w[si] + sh)
        collect["item_start"].append(st[si, sh])
        collect["item_depart"].append(dp[si, sh])
        collect["item_arrive"].append(arr[si, sh])
        rr = np.nonzero(retired)[0]
        collect["row_id"].append(gid_w[rr])
        collect["row_complete"].append(arr[rr, h_w])
        rec = np.nonzero(gate_rec)[0]
        collect["gate_row"].append(gid_w[rec])
        collect["gate_arrive0"].append(arr[rec, 0])

    # ---- advance the per-channel frontier past this window's settled prefix
    serving = valid_np & (W["nbytes"] > 0)
    ssi = serving & settled
    ri, hi = np.nonzero(ssi)
    if ri.size:
        chs = W["channel"][ri, hi].astype(np.int64)
        ars = arr_h[ri, hi]
        fls = ri * h_w + hi
        order = np.lexsort((fls, ars, chs))
        sc = chs[order]
        lastm = np.append(sc[1:] != sc[:-1], True)
        sel = order[lastm]
        lc = sc[lastm]
        state.ch_dep[lc] = dp[ri[sel], hi[sel]]
        state.ch_dir[lc] = W["direction"][ri[sel], hi[sel]]
        rows = W["row"][ri, hi]
        rm = rows >= 0
        if rm.any():
            order2 = np.lexsort((fls[rm], ars[rm], chs[rm]))
            sc2 = chs[rm][order2]
            lastm2 = np.append(sc2[1:] != sc2[:-1], True)
            state.ch_row[sc2[lastm2]] = rows[rm][order2[lastm2]]
    if has_retrain:
        ret = W["retrain_after_ps"]
        m1 = ssi & (ret > 0)
        if m1.any():
            np.maximum.at(state.ch_down, W["channel"][m1], dp[m1] + ret[m1])
        mk = valid_np & (W["nbytes"] == 0) & (ret > 0) & settled
        if mk.any():
            np.maximum.at(state.ch_down, W["channel"][mk],
                          arr_h[mk] + ret[mk])

    # ---- streamed peak backlog: settled serving items emit +1 at arrival,
    # −1 at grant; events strictly before T_next are flushed into the
    # per-channel running fold (every future event is >= T_next: carried
    # items arrive after it, new chunks issue at or after it), events at or
    # after T_next stay pending so later same-instant arrivals keep the
    # monolithic (time, arrivals-first) order
    ev_t = np.concatenate([state.bl_t, arr_h[ri, hi], st[ri, hi]])
    bc = W["channel"][ri, hi].astype(np.int64)
    ev_c = np.concatenate([state.bl_c, bc, bc])
    ev_y = np.concatenate([state.bl_y, np.zeros(ri.size, np.int8),
                           np.ones(ri.size, np.int8)])
    fl = ev_t < t_next
    if fl.any():
        _fold_backlog(state.bl_run, state.bl_peak,
                      ev_t[fl], ev_c[fl], ev_y[fl])
    keep = ~fl
    state.bl_t, state.bl_c, state.bl_y = ev_t[keep], ev_c[keep], ev_y[keep]

    # ---- extract the rows still in flight as next-window suffixes
    inv = {v: k for k, v in keys.items()} if has_join else {}
    new_carried = []
    for p in np.nonzero(carried_mask)[0]:
        k = int(k0[p])
        vrow = valid_np[p]
        top = max((h_w - int(vrow[::-1].argmax())) if vrow.any() else 0, k)
        jw = jd = None
        if has_join:
            if hold[p]:
                jw = inv[int(jwait_w[p])]
            if jid_w[p] >= 0:
                jd = inv[int(jid_w[p])]
        new_carried.append(dict(
            hops={f: W[f][p, k:top].copy() for f in W},
            issue=int(issue_w[p]) if k == 0 else int(arr[p, k]),
            orig_issue=int(orig_issue[p]),
            gid=int(gid_w[p]),
            hop0=int(hop0_w[p]) + k,
            jwait=jw, jid=jd,
        ))

    # retired contributors of still-gated groups act through the seed;
    # groups whose every waiter retired are dead — drop their entries
    alive = {r["jwait"] for r in new_carried if r["jwait"] is not None}
    new_seed = {k: v for k, v in state.jseed.items() if k in alive}
    if has_join and alive:
        for p in np.nonzero(retired & (jid_w >= 0))[0]:
            key = inv[int(jid_w[p])]
            if key in alive:
                new_seed[key] = max(new_seed.get(key, 0), int(arr[p, h_w]))
    state.jseed = new_seed

    state.carried = new_carried
    state.carried_peak = max(state.carried_peak, len(new_carried))
    state.windows += 1
    state.n_rows += n_c
    state.chunk_idx += 1


def simulate_stream(chunks, channels: Channels, state: StreamState = None,
                    options: SimOptions | None = None, *,
                    pad_to: int = 64, collect_schedule: bool = False,
                    max_rounds: int = None, oracle_fallback: bool = None,
                    static_check: bool = None) -> StreamResult:
    """Drive a chunked trace through windowed simulation (module docstring).

    chunks    iterator/iterable of ``(Hops, issue_ps)`` — e.g.
              `stream_windows` over a monolithic trace,
              `traces.request_stream(..., chunk=...)` lowered per chunk, or
              `coherence_traffic.stream_coherence`.  One chunk of lookahead
              is held to know ``T_next``; chunk min-issues must be
              non-decreasing (asserted).
    state     carry from a previous call (continues the fold); a fresh
              `StreamState(channels)` when None.  The final window settles
              everything, so each call drains (no rows stay in flight).
    options   `engine.SimOptions` — the uniform knob set of every entry
              point.  ``max_rounds=0`` gives each window its computed
              join-depth bound; ``check`` maps onto the stream's two
              guards: ``"static"`` (default here) runs the fabric-IR
              verifier over every incoming chunk *and* keeps the per-window
              `ref_des` oracle fallback, ``"oracle"`` keeps only the
              fallback, ``"off"`` disables both (a non-converged window
              then raises).  The chunk verifier matters because the
              settlement rule silently mis-settles on tables that break
              the engine contracts — chunks from third-party lowerings are
              checked at the door (host-side numpy, a few percent of
              window cost; raises `verify.VerifyError`).  ``use_kernel``
              is forwarded to the engine's serve round.
    pad_to    row-count bucket for window shapes — bounds jit recompiles.
    collect_schedule
              accumulate every settled item's (start, depart, arrive) and
              every row's completion/gated-arrival in global coordinates —
              the equivalence-test hook; O(trace) memory, test scale only.
    max_rounds / oracle_fallback / static_check
              deprecated — pass ``options=SimOptions(...)`` instead.

    Returns `StreamResult`; tail quantiles via ``result.summary()``.
    """
    if options is not None and not isinstance(options, SimOptions):
        raise TypeError(
            f"options must be a SimOptions, got {type(options).__name__}")
    check = "static" if options is None else options.check
    mr = 0 if options is None else options.max_rounds
    do_static = check == "static"
    do_oracle = check != "off"
    for name, val in (("max_rounds", max_rounds),
                      ("oracle_fallback", oracle_fallback),
                      ("static_check", static_check)):
        if val is not None:
            warnings.warn(
                f"simulate_stream({name}=...) is deprecated; pass "
                "options=SimOptions(...) instead",
                DeprecationWarning, stacklevel=2)
    if max_rounds is not None:
        mr = max_rounds
    if oracle_fallback is not None:
        do_oracle = oracle_fallback
    if static_check is not None:
        do_static = static_check
    win_opts = SimOptions(
        max_rounds=mr, check="off",
        use_kernel=False if options is None else options.use_kernel)
    if state is None:
        state = StreamState(channels)
    collect = {k: [] for k in _COLLECT_KEYS} if collect_schedule else None
    it = iter(chunks)
    cur = next(it, None)
    prev_min = None
    while cur is not None:
        nxt = next(it, None)
        while nxt is not None and int(np.asarray(nxt[1]).shape[0]) == 0:
            nxt = next(it, None)
        if int(np.asarray(cur[1]).shape[0]) == 0:
            cur = nxt
            continue
        # layout mismatch is a caller error with a specific remedy — report
        # it as such rather than as whatever IR findings the odd chunk
        # happens to produce against the shared channel tables
        _ensure_layout(state, cur[0])
        if do_static:
            verify.assert_valid(cur[0], channels, cur[1])
        mn = _min_issue(cur[1])
        if prev_min is not None and mn < prev_min:
            raise ValueError(
                f"chunk stream out of order: min issue {mn} after "
                f"{prev_min} — chunks must be windows of a time-ordered "
                "trace")
        prev_min = mn
        t_next = _INT64_MAX if nxt is None else _min_issue(nxt[1])
        _process_window(state, channels, cur[0], cur[1], t_next, win_opts,
                        pad_to, do_oracle, collect)
        cur = nxt
    if state.carried:
        raise AssertionError(
            f"{len(state.carried)} rows still in flight after the final "
            "window — settlement bug (the last window's T_next is +inf)")
    collected = None
    if collect is not None:
        collected = {k: (np.concatenate(v) if v else np.zeros(0, np.int64))
                     for k, v in collect.items()}
    return StreamResult(telemetry=state.telemetry, windows=state.windows,
                        carried_peak=state.carried_peak,
                        oracle_windows=state.oracle_windows,
                        n_rows=state.n_rows, state=state,
                        collected=collected, rounds=state.rounds_sum,
                        converged=state.windows_converged == state.windows,
                        residual_ps=0)


def stream_windows(hops: Hops, issue_ps, window_rows: int):
    """Slice a monolithic ``(Hops, issue_ps)`` into `simulate_stream` chunks
    of ``window_rows`` rows (host arrays, no device transfer).

    Fork/join groups are never split: a window boundary slides forward past
    any row range a group spans, and group ids are remapped chunk-local (the
    chunk contract).  Rows must already be in non-decreasing issue order —
    the driver asserts the resulting chunk mins.
    """
    fields = {f: _np(getattr(hops, f)) for f in Hops._fields}
    issue = np.asarray(issue_ps, np.int64)
    n = fields["channel"].shape[0]
    has_join = fields["join_id"] is not None
    blocked = np.zeros(n + 1, bool)
    if has_join:
        lo: dict = {}
        hi: dict = {}
        for p in range(n):
            for g in (int(fields["join_id"][p]), int(fields["join_wait"][p])):
                if g >= 0:
                    lo[g] = min(lo.get(g, p), p)
                    hi[g] = max(hi.get(g, p), p)
        for g, a in lo.items():
            blocked[a + 1:hi[g] + 1] = True
    a = 0
    while a < n:
        b = min(a + window_rows, n)
        while b < n and blocked[b]:
            b += 1
        kw = {}
        if has_join:
            jid_s = fields["join_id"][a:b].copy()
            jw_s = fields["join_wait"][a:b].copy()
            present = np.unique(np.concatenate(
                [jid_s[jid_s >= 0], jw_s[jw_s >= 0]]))
            if present.size:
                lut = np.full(int(present.max()) + 1, -1, np.int32)
                lut[present] = np.arange(present.size, dtype=np.int32)
                jid_s[jid_s >= 0] = lut[jid_s[jid_s >= 0]]
                jw_s[jw_s >= 0] = lut[jw_s[jw_s >= 0]]
            kw = dict(join_id=jid_s, join_wait=jw_s,
                      join_arity=fields["join_arity"][a:b])
        for f in ("extra_wire_bytes", "retrain_after_ps"):
            if fields[f] is not None:
                kw[f] = fields[f][a:b]
        yield Hops(*(fields[f][a:b] for f in _BASE_FIELDS), **kw), issue[a:b]
        a = b
