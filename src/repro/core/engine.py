"""Tensorized transaction schedule engine (ESF device layer, TPU-native).

The C++ ESF resolves link/endpoint contention with an event loop.  An event
loop is data-dependent control flow — the worst shape for an accelerator — so
this port reformulates transaction-level simulation as a fixpoint of dense
tensor ops, which jits and (crucially) ``vmap``s over whole sweeps of system
configurations:

  * Every transaction is a row of hop records ``(channel, bytes, direction,
    row, fixed_after)`` (request hops, an endpoint-service hop, response hops).
  * FCFS contention per channel is a *segmented tropical scan*: with items
    sorted by (channel, arrival, tiebreak), within a channel segment

        start_i  = max(arrive_i, depart_{i-1} [+ turnaround if direction flip])
        depart_i = start_i + serialize_i [+ row-buffer penalty]

  * Arrival times satisfy ``arrive[p, h+1] = depart[p, h] + fixed_after[p, h]``.
    We initialize arrivals with the contention-free schedule (a lower bound)
    and iterate sort→scan→propagate until the integer fixpoint is reached.
    Delays only ever grow toward the true FCFS schedule, whose exactness is
    checked against a pure-Python event-driven oracle (`core.ref_des`) in the
    test suite.

All times are int64 **picoseconds** and all sizes int64 bytes, so schedules are
exact and tie-breaking (by flat item index = packet-major order) is
deterministic and identical to the oracle.

The per-channel carried state (busy-until, last direction, last DRAM row,
and — under stochastic link reliability — retraining down-until) is what
lets one mechanism model full-duplex PCIe links, half-duplex buses with
turnaround, switch ports, banked DRAM endpoints, and link-down stalls
uniformly — ESF's "decoupling design" (§III-A) expressed as data instead of
classes.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

PS_PER_S = 1_000_000_000_000


def ser_ps(nbytes, bw_MBps):
    """Exact integer serialization time: bytes / (MB/s) in picoseconds.

    bytes * 1e6 // MBps  ==  bytes * 1e12 // (MBps * 1e6) exactly, with an
    int64 overflow headroom of ~9 TB per packet instead of ~9 MB."""
    return (nbytes * 1_000_000) // bw_MBps


def wire_ser_ps(nbytes, ch: "Channels", chan_clipped, extra_wire=None):
    """Serialization time of ``nbytes`` logical bytes on their channels,
    honouring the link-layer flit tables (`core.link_layer`):

      * flit channels transmit whole flits — ceil(bytes/payload) * size wire
        bytes — and stretch by the expected Go-Back-N CRC-replay overhead
        ``(1 + replay_ppm/1e6)``, floored to exact integer picoseconds;
      * byte-exact channels (flit_size 0, or seed-layout Channels with no
        flit tables at all) keep the seed formula bit-for-bit;
      * ``extra_wire`` (stochastic reliability, `Hops.extra_wire_bytes`)
        adds the build-time-sampled CRC-replay wire bytes of each item —
        zero off flit channels, and mutually exclusive with a nonzero
        ``replay_ppm`` on the same channel by the lowering contract.
    """
    bw = ch.bw_MBps[chan_clipped]
    base = ser_ps(nbytes, bw)
    if ch.flit_size is None:
        return base
    fsize = ch.flit_size[chan_clipped]
    fpay = jnp.maximum(ch.flit_payload[chan_clipped], 1)
    wire = ((nbytes + fpay - 1) // fpay) * fsize
    if extra_wire is not None:
        wire = wire + extra_wire
    fser = ser_ps(wire, bw)
    if ch.replay_ppm is not None:
        ppm = ch.replay_ppm[chan_clipped]
        # floor(fser * (1e6 + ppm) / 1e6), decomposed so the product never
        # exceeds int64 even with ppm at the MAX_REPLAY_PPM clamp (1e9):
        # identical to the oracle's arbitrary-precision formula for any
        # fser below ~9.2e15 ps
        scale = 1_000_000 + ppm
        q, r = fser // 1_000_000, fser % 1_000_000
        fser = q * scale + (r * scale) // 1_000_000
    return jnp.where(fsize > 0, fser, base)


class Channels(NamedTuple):
    """Static per-channel tables (from `FabricGraph`).

    The three optional flit tables are the link-layer lowering contract of
    `core.link_layer`: a channel with ``flit_size > 0`` serializes whole
    flits (``ceil(bytes / flit_payload) * flit_size`` wire bytes) and pays
    the expected CRC-replay overhead ``replay_ppm`` (parts-per-million of
    extra transmissions under Go-Back-N retry).  ``None`` — the seed layout —
    or all-zero tables reproduce byte-exact serialization bit-for-bit.
    Because they are plain per-channel arrays, BER / flit-mode sweeps
    ``vmap`` over them without rebuilding hop tables.
    """

    bw_MBps: jnp.ndarray        # (C,) int64
    turnaround_ps: jnp.ndarray  # (C,) int64, half-duplex direction-flip cost
    row_hit_ps: jnp.ndarray     # (C,) int64 extra when row matches
    row_miss_ps: jnp.ndarray    # (C,) int64 extra when row differs / cold
    flit_size: jnp.ndarray | None = None     # (C,) int64, 0 = byte-exact
    flit_payload: jnp.ndarray | None = None  # (C,) int64
    replay_ppm: jnp.ndarray | None = None    # (C,) int64


class Hops(NamedTuple):
    """Per-transaction hop table, shape (N, H); padded hops have valid=False.

    The two optional (N, H) tables carry the stochastic link-reliability
    samples (`core.link_layer.sample_hop_tables`, seeded at build time):
    ``extra_wire_bytes`` — sampled Go-Back-N replay wire bytes added to the
    hop's serialization; ``retrain_after_ps`` — link-down interval the hop's
    channel enters when the hop departs (retraining stall; the channel
    grants nothing until it ends).  ``None`` — the deterministic
    expected-value layout — keeps the scan structurally identical to PR 1.

    The three optional (N,) tables are the **fork/join primitive**: a row
    whose ``join_wait >= 0`` does not issue at its nominal issue time but at
    ``max(issue, max completion of every row whose join_id names the same
    group)`` — max-of-arrivals join semantics (a DCOH collecting the *last*
    BIRsp of a concurrent BISnp fan-out, CXL 3.x BI flows).  ``join_id``
    marks a row as a contributor to a group; ``join_arity`` (meaningful on
    waiter rows) is the contract: the number of contributors the group must
    receive, which the event-driven oracle uses as its release count and
    validates against the table.  Group ids live in the row index space —
    ``0 <= id < N`` — because the engine resolves group maxes with an
    N-sized scatter (the oracle validates the bound).  Groups must form a
    DAG through rows
    (a row may both wait on one group and contribute to another — the
    coherence lowering chains request -> snoop fan-out -> demand leg this
    way); a cycle deadlocks the oracle (detected and raised) and never
    converges in the engine.  ``None`` — no joins — keeps the fixpoint
    structurally identical to the chain-only engine.
    """

    channel: jnp.ndarray      # (N, H) int32
    nbytes: jnp.ndarray       # (N, H) int64 serialized bytes on this hop
    direction: jnp.ndarray    # (N, H) int8  0/1 for half-duplex channels
    row: jnp.ndarray          # (N, H) int32 DRAM row id, -1 = not row-managed
    fixed_after_ps: jnp.ndarray  # (N, H) int64 latency after transmission
    is_payload: jnp.ndarray   # (N, H) bool — payload (vs header) bytes
    valid: jnp.ndarray        # (N, H) bool
    extra_wire_bytes: jnp.ndarray | None = None   # (N, H) int64
    retrain_after_ps: jnp.ndarray | None = None   # (N, H) int64
    join_id: jnp.ndarray | None = None     # (N,) int32 group fed, -1 = none
    join_wait: jnp.ndarray | None = None   # (N,) int32 group gating issue, -1
    join_arity: jnp.ndarray | None = None  # (N,) int32 contributors expected


class Schedule(NamedTuple):
    """Resolved schedule + the unified convergence diagnostics every
    simulation result type in `repro.core` exposes under the same names:
    ``rounds`` / ``converged`` / ``residual_ps`` (see also `CoupledResult`
    and `streaming.StreamResult`)."""

    arrive: jnp.ndarray    # (N, H+1) arrival per hop; [:, H] = completion
    start: jnp.ndarray     # (N, H) channel grant time
    depart: jnp.ndarray    # (N, H) transmission end
    complete: jnp.ndarray  # (N,)
    rounds: jnp.ndarray    # () iterations used
    converged: jnp.ndarray  # () bool
    residual_ps: jnp.ndarray | None = None  # () last round's max |Δarrive|


class StreamCarry(NamedTuple):
    """Per-channel frontier state carried across streaming windows
    (`core.streaming`).

    The FCFS service order on a channel equals the global key order
    ``(arrival, flat index)``, so once every item that can still arrive has
    a later key, the channel's history collapses to the state after its
    last settled item — exactly the scan carry `_one_round` threads through
    a segment.  A window seeded with this state schedules its items
    bit-identically to the monolithic run (the `ref_des` oracle mirrors the
    same seeds via its ``free_at`` map).

    depart_ps      (C,) int64 — busy-until of the last settled serving item
                   (0 = channel never served).
    last_dir       (C,) int8 — its direction (-1 = none: no turnaround due).
    last_row       (C,) int32 — last settled DRAM row (-2 = cold).
    down_until_ps  (C,) int64 — max retraining down interval contributed by
                   settled items/markers (0 = link up).
    join_seed_ps   (N,) int64 or None — carried fork/join group maxes in the
                   *window's* group-id space: entry ``g`` is the max
                   completion of the group's already-retired contributors
                   (`_join_gate` folds it into the scatter-max).  When
                   non-None the window's `Hops` must carry join tables.
    """

    depart_ps: jnp.ndarray
    last_dir: jnp.ndarray
    last_row: jnp.ndarray
    down_until_ps: jnp.ndarray
    join_seed_ps: jnp.ndarray | None = None


def empty_carry(n_channels: int, n_rows: int | None = None) -> StreamCarry:
    """A cold carry: seeding `simulate` with it is bit-identical to no carry
    (fresh channels, no down intervals, no retired join contributors)."""
    return StreamCarry(
        depart_ps=jnp.zeros(n_channels, jnp.int64),
        last_dir=jnp.full(n_channels, -1, jnp.int8),
        last_row=jnp.full(n_channels, -2, jnp.int32),
        down_until_ps=jnp.zeros(n_channels, jnp.int64),
        join_seed_ps=(None if n_rows is None
                      else jnp.zeros(n_rows, jnp.int64)),
    )


_CHECK_MODES = ("off", "static", "oracle")


@dataclasses.dataclass(frozen=True)
class SimOptions:
    """One options surface for every simulation entry point.

    `simulate`, `simulate_auto`, `coherence_traffic.simulate_coupled` and
    `streaming.simulate_stream` all accept an ``options=SimOptions(...)``
    argument; each consumes the subset of fields that applies to it and
    ignores the rest, so one options object can be threaded through a whole
    pipeline.  The historical per-function kwargs (``max_rounds=``,
    ``check=True/False``, ``damping=``, ``static_check=``,
    ``oracle_fallback=``) remain as deprecated shims that warn and fold
    into an equivalent ``SimOptions``.

    max_rounds  fixpoint round budget; 0 (default) = the computed
                join-depth-aware `round_bound` — provably sufficient, so
                explicit budgets are only for experiments that *want* a
                truncated fixpoint.
    check       "off"    — no verification, no host sync (the returned
                           schedule may be unconverged);
                "static" — run the fabric-IR verifier (`core.verify`)
                           before tracing, then behave as "oracle";
                "oracle" — fall back to the event-driven `ref_des` oracle
                           when the fixpoint reports non-convergence
                           (replaces the old ``check=True`` bool /
                           ``check="static"`` string overload).
    damping     damped Picard iteration in `simulate_coupled`'s outer
                coherence fixpoint (ignored by the other entry points).
    use_kernel  run the inner serve round through the Pallas kernel
                (`kernels.serve_round`): ``True`` = backend auto-dispatch
                (TPU kernel, lax elsewhere), or an explicit impl string
                ``"pallas"`` / ``"interpret"`` / ``"ref"``.
    """

    max_rounds: int = 0
    check: str = "oracle"
    damping: bool = False
    use_kernel: bool | str = False

    def __post_init__(self):
        if self.check not in _CHECK_MODES:
            raise ValueError(
                f"SimOptions.check must be one of {_CHECK_MODES}, "
                f"got {self.check!r}")

    @property
    def kernel_impl(self) -> str:
        """`_one_round` dispatch string for ``use_kernel``."""
        if self.use_kernel is False:
            return "scan"
        if self.use_kernel is True:
            return "auto"
        return self.use_kernel


def _legacy_check(val) -> str:
    """Map the historical ``check=`` overload onto `SimOptions.check`."""
    if val == "static":
        return "static"
    if isinstance(val, str) and val in _CHECK_MODES:
        return val
    return "oracle" if val else "off"


def _merge_options(fn: str, options, **legacy) -> SimOptions:
    """Resolve ``options`` plus deprecated per-call kwargs (``None`` =
    not passed) into one `SimOptions`, warning per legacy kwarg."""
    if isinstance(options, int):
        # historical positional max_rounds
        legacy = {**legacy, "max_rounds": options}
        options = None
    opts = options if options is not None else SimOptions()
    if not isinstance(opts, SimOptions):
        raise TypeError(f"{fn}: options must be a SimOptions, "
                        f"got {type(opts).__name__}")
    updates = {}
    for name, val in legacy.items():
        if val is None:
            continue
        if name == "check":
            val = _legacy_check(val)
        warnings.warn(
            f"{fn}({name}=...) is deprecated; pass "
            f"options=SimOptions({name}={val!r})",
            DeprecationWarning, stacklevel=3)
        updates[name] = val
    return dataclasses.replace(opts, **updates) if updates else opts


def round_bound(hops: Hops) -> int:
    """Join-depth-aware fixpoint round budget for a lowered `Hops` table —
    ``(join_depth + 1) * (3*H + 8)`` (see `verify.round_bound` for the
    derivation).  Host-side: called on concrete tables at build time or by
    the `simulate` wrapper.  Inside a ``jit``/``vmap`` trace the join
    tables are abstract, so the bound degrades to the chain-only term —
    join-heavy sweeps should compute the bound on the concrete tables and
    pass ``SimOptions(max_rounds=round_bound(hops))`` explicitly.
    """
    from . import verify  # host-side helper module, no jax imports

    h = int(hops.channel.shape[-1])
    jid, jw = hops.join_id, hops.join_wait
    if jid is None or jw is None:
        return verify.round_bound(h)
    if isinstance(jid, jax.core.Tracer) or isinstance(jw, jax.core.Tracer):
        return verify.round_bound(h)
    jid, jw = np.asarray(jid), np.asarray(jw)
    if jid.ndim == 1:
        return verify.round_bound(h, jid, jw)
    # stacked tables (host-side sweep layouts): the max over members
    return max(verify.round_bound(h, j, w)
               for j, w in zip(jid.reshape(-1, jid.shape[-1]),
                               jw.reshape(-1, jw.shape[-1])))


def _one_round(hops: Hops, ch: Channels, issue_ps, arrive, with_stalls=False,
               carry: StreamCarry | None = None, impl: str = "scan"):
    """One sort→segmented-scan→propagate pass.  arrive: (N, H+1).

    ``with_stalls=True`` (telemetry replay, `core.telemetry`) additionally
    returns the per-item retraining-stall share of the queueing wait —
    ``start − max(arrive, contention floor)``, the part of the grant delay
    attributable to the channel's link-down interval alone.  The default
    path is byte-identical to the plain round (the extra outputs exist only
    under the flag, which is resolved at trace time).

    ``carry`` (streaming windows, `core.streaming`) seeds every segment
    head with the channel's carried frontier instead of a cold channel:
    the head's previous-item state comes from a per-channel gather, the
    turnaround gap applies only when a direction is actually carried
    (``last_dir != -1``), and down-until state is threaded even without
    per-hop retrain tables.  Resolved at trace time — ``carry=None``
    compiles the exact historical scan.
    """
    n, h = hops.channel.shape
    k = n * h
    flat_arrive = arrive[:, :h].reshape(k)
    flat_chan = hops.channel.reshape(k)
    flat_valid = hops.valid.reshape(k)
    # push invalid items to a dummy tail segment so they never contend
    sort_chan = jnp.where(flat_valid, flat_chan, jnp.int32(ch.bw_MBps.shape[0]))

    # lexsort by (channel, arrive, flat index): two stable passes
    order = jnp.argsort(flat_arrive, stable=True)
    order = order[jnp.argsort(sort_chan[order], stable=True)]

    chan_clipped = jnp.minimum(flat_chan[order], ch.bw_MBps.shape[0] - 1)
    s_chan = flat_chan[order]
    s_valid = flat_valid[order]
    s_arrive = flat_arrive[order]
    s_dir = hops.direction.reshape(k)[order]
    s_row = hops.row.reshape(k)[order]
    s_bytes = hops.nbytes.reshape(k)[order]
    s_extra = (hops.extra_wire_bytes.reshape(k)[order]
               if hops.extra_wire_bytes is not None else None)
    s_ser = wire_ser_ps(s_bytes, ch, chan_clipped, extra_wire=s_extra)
    s_turn = ch.turnaround_ps[chan_clipped]
    s_rowhit = ch.row_hit_ps[chan_clipped]
    s_rowmiss = ch.row_miss_ps[chan_clipped]
    # stochastic retraining stalls extend the carry with per-channel
    # down-until state — resolved at trace time so the deterministic layout
    # compiles to the exact PR-1 scan
    has_retrain = hops.retrain_after_ps is not None
    has_carry = carry is not None
    if impl != "scan":
        # Pallas serve-round kernel (`kernels.serve_round`): one code path
        # for every layout — deterministic/no-carry configs ride the carry
        # semantics with cold seeds, bit-identical by the empty-carry
        # equivalence the streaming suite property-tests
        from ..kernels.serve_round.ops import serve_round

        s_retrain = (hops.retrain_after_ps.reshape(k)[order]
                     if has_retrain else jnp.zeros(k, jnp.int64))
        if has_carry:
            seed_ix = jnp.clip(s_chan, 0, ch.bw_MBps.shape[0] - 1)
            sd = (carry.depart_ps[seed_ix], carry.last_dir[seed_ix],
                  carry.last_row[seed_ix], carry.down_until_ps[seed_ix])
        else:
            sd = (jnp.zeros(k, jnp.int64), jnp.full(k, -1, jnp.int8),
                  jnp.full(k, -2, jnp.int32), jnp.zeros(k, jnp.int64))
        serving = s_valid & (s_bytes > 0)
        marker = s_valid & (s_bytes == 0) & (s_retrain > 0)
        s_start, s_depart, s_stall = serve_round(
            s_chan, serving, marker, s_arrive, s_dir, s_row, s_ser,
            s_turn, s_rowhit, s_rowmiss, s_retrain, *sd, impl=impl)
        return _scatter_round(hops, issue_ps, order, s_start, s_depart,
                              s_stall if with_stalls else None)
    xs = (s_chan, s_valid, s_arrive, s_dir, s_row, s_ser, s_turn, s_rowhit,
          s_rowmiss, s_bytes)
    if has_retrain:
        xs = xs + (hops.retrain_after_ps.reshape(k)[order],)
    if has_carry:
        seed_ix = jnp.clip(s_chan, 0, ch.bw_MBps.shape[0] - 1)
        xs = xs + (carry.depart_ps[seed_ix], carry.last_dir[seed_ix],
                   carry.last_row[seed_ix], carry.down_until_ps[seed_ix])

    def scan_fn(state, x):
        if has_retrain or has_carry:
            prev_chan, prev_depart, prev_dir, prev_row, prev_down = state
        else:
            prev_chan, prev_depart, prev_dir, prev_row = state
        chan, valid, arr, drn, row, ser, turn, rhit, rmiss, nbytes = x[:10]
        ix = 10
        if has_retrain:
            retrain = x[ix]
            ix += 1
        if has_carry:
            sd_dep, sd_dir, sd_row, sd_down = x[ix:ix + 4]
        # zero-byte packets ride a side channel (e.g. DRAM command path):
        # they pass through instantly and do not occupy or turn the bus.
        # Exception: a zero-byte hop carrying retrain_after_ps is a
        # *link-down marker* (`link_layer.insert_retrain_markers`) — it
        # still occupies nothing but pushes its channel's down_until to
        # (arrival + retrain), mirroring a full-duplex partner's stall.
        if has_retrain:
            marker = valid & (nbytes == 0) & (retrain > 0)
        valid = valid & (nbytes > 0)
        same = chan == prev_chan
        if has_carry:
            # segment heads resume from the carried per-channel frontier
            # (gathered seeds) instead of a cold channel; the turnaround
            # gap requires an actually-carried direction
            eff_dep = jnp.where(same, prev_depart, sd_dep)
            eff_dir = jnp.where(same, prev_dir, sd_dir)
            eff_row = jnp.where(same, prev_row, sd_row)
            eff_down = jnp.where(same, prev_down, sd_down)
            gap = jnp.where((eff_dir != jnp.int8(-1)) & (drn != eff_dir),
                            turn, 0)
            start = jnp.maximum(arr, jnp.maximum(eff_dep + gap, eff_down))
            if with_stalls:
                # grant time on a healthy link: the carried/segment down
                # interval is the only extra term, so the stall is whatever
                # it adds on top of contention + turnaround
                stall = jnp.where(valid,
                                  start - jnp.maximum(arr, eff_dep + gap), 0)
            row_extra = jnp.where(
                row >= 0, jnp.where(row == eff_row, rhit, rmiss), 0)
        else:
            gap = jnp.where(same & (drn != prev_dir), turn, 0)
            floor = prev_depart + gap
            if has_retrain:
                # a retraining link grants nothing until down_until passes;
                # the state is per channel, i.e. per scan segment — reset
                # on entry
                seg_down = jnp.where(same, prev_down, jnp.int64(0))
                if with_stalls:
                    # grant time the item would have seen on a healthy
                    # link — the retrain stall is whatever the down
                    # interval adds on top
                    nodown = jnp.where(same, jnp.maximum(arr, floor), arr)
                floor = jnp.maximum(floor, seg_down)
            start = jnp.where(same, jnp.maximum(arr, floor), arr)
            if with_stalls:
                stall = (jnp.where(valid, start - nodown, 0) if has_retrain
                         else jnp.zeros_like(start))
            row_managed = row >= 0
            row_extra = jnp.where(
                row_managed,
                jnp.where(same & (row == prev_row), rhit, rmiss),
                0,
            )
        depart = start + ser + row_extra
        start = jnp.where(valid, start, arr)
        depart = jnp.where(valid, depart, arr)
        ys = (start, depart) + ((stall,) if with_stalls else ())
        if has_carry:
            # markers keep the seeded frontier alive (the carried channel
            # history must survive a marker opening a segment) and only
            # raise down_until; serving items advance it as usual
            mk = marker if has_retrain else jnp.zeros_like(valid)
            upd = valid | mk
            new_carry = (
                jnp.where(upd, chan, prev_chan),
                jnp.where(valid, depart, jnp.where(mk, eff_dep, prev_depart)),
                jnp.where(valid, drn, jnp.where(mk, eff_dir, prev_dir)),
                jnp.where(valid & (row >= 0), row,
                          jnp.where(upd, eff_row, prev_row)),
            )
            contrib = (jnp.where(retrain > 0, depart + retrain, jnp.int64(0))
                       if has_retrain else jnp.int64(0))
            new_down = jnp.maximum(eff_down, contrib)
            new_carry = new_carry + (jnp.where(upd, new_down, prev_down),)
            return new_carry, ys
        if not has_retrain:
            new_carry = (
                jnp.where(valid, chan, prev_chan),
                jnp.where(valid, depart, prev_depart),
                jnp.where(valid, drn, prev_dir),
                jnp.where(valid & (row >= 0), row, prev_row),
            )
            return new_carry, ys
        # a marker opening a segment initializes the channel state to "no
        # previous item" (depart 0, row -2) so the next real hop sees a
        # fresh channel plus the marker's down interval; mid-segment it
        # leaves everything but down_until untouched.  Markers are only
        # emitted for full-duplex pairs (turnaround 0, not row-managed),
        # so the stored direction never creates a spurious turnaround.
        head = marker & ~same
        new_carry = (
            jnp.where(valid | marker, chan, prev_chan),
            jnp.where(valid, depart, jnp.where(head, jnp.int64(0),
                                               prev_depart)),
            jnp.where(valid, drn, jnp.where(head, drn, prev_dir)),
            jnp.where(valid & (row >= 0), row,
                      jnp.where(head, jnp.int32(-2), prev_row)),
        )
        new_down = jnp.maximum(
            seg_down, jnp.where(retrain > 0, depart + retrain,
                                jnp.int64(0)))
        new_carry = new_carry + (
            jnp.where(valid | marker, new_down, prev_down),)
        return new_carry, ys

    init = (jnp.int32(-1), jnp.int64(0), jnp.int8(-1), jnp.int32(-2))
    if has_retrain or has_carry:
        init = init + (jnp.int64(0),)
    _, out = jax.lax.scan(scan_fn, init, xs)
    return _scatter_round(hops, issue_ps, order, out[0], out[1],
                          out[2] if with_stalls else None)


def _scatter_round(hops: Hops, issue_ps, order, s_start, s_depart, s_stall):
    """Scatter sorted per-item grants back to (N, H) and propagate exact
    arrivals (padded hops pass the previous arrival through)."""
    n, h = hops.channel.shape
    k = n * h
    start = jnp.zeros(k, dtype=jnp.int64).at[order].set(s_start).reshape(n, h)
    depart = jnp.zeros(k, dtype=jnp.int64).at[order].set(s_depart).reshape(n, h)

    cols = [issue_ps]
    for j in range(h):
        cols.append(jnp.where(
            hops.valid[:, j], depart[:, j] + hops.fixed_after_ps[:, j], cols[-1]
        ))
    new_arrive = jnp.stack(cols, axis=1)
    if s_stall is not None:
        stall = jnp.zeros(k, dtype=jnp.int64).at[order].set(
            s_stall).reshape(n, h)
        return new_arrive, start, depart, stall
    return new_arrive, start, depart


def _join_gate(hops: Hops, issue_ps, arrive, join_seed=None):
    """Fork/join issue gating: the effective issue time of a waiter row is
    ``max(issue, max completion of its group's contributors)``.

    Group maxes are resolved as a scatter-max over the current iterate's
    completion column — a per-group running max folded between FCFS scan
    rounds rather than inside one (the scan runs in (channel, arrival)
    order, where a running max over completions is not computable; between
    rounds it is exact at the fixpoint, and join delays only ever grow, so
    the contention-free initialization stays a valid lower bound).

    ``join_seed`` ((N,) int64, streaming windows) folds in the carried
    completions of contributors that already retired in earlier windows —
    `StreamCarry.join_seed_ps`, indexed in the window's group-id space.
    """
    n, h = hops.channel.shape
    comp = arrive[:, h]
    contrib = hops.join_id >= 0
    gmax = jnp.zeros((n,), jnp.int64).at[
        jnp.where(contrib, hops.join_id, 0)
    ].max(jnp.where(contrib, comp, jnp.int64(0)))
    if join_seed is not None:
        gmax = jnp.maximum(gmax, join_seed)
    wait = hops.join_wait >= 0
    gate = gmax[jnp.clip(hops.join_wait, 0, n - 1)]
    return jnp.where(wait, jnp.maximum(issue_ps, gate), issue_ps)


def simulate(hops: Hops, channels: Channels, issue_ps: jnp.ndarray,
             options: SimOptions | None = None, *,
             carry: StreamCarry | None = None,
             max_rounds: int | None = None) -> Schedule:
    """Resolve the exact FCFS schedule of all transactions.

    ``options`` (`SimOptions`) selects the round budget and the serve-round
    implementation; ``options=None`` is ``SimOptions()``.  The default
    budget (``max_rounds=0``) is the computed join-depth-aware
    `round_bound` — sufficient for every verifier-legal lowering, so
    convergence is provable rather than hand-tuned; truncated-fixpoint
    experiments pass an explicit ``SimOptions(max_rounds=...)``.
    Convergence is reported in ``Schedule.converged`` and the last round's
    max arrival delta in ``Schedule.residual_ps`` (0 at the fixpoint).

    ``carry`` (`StreamCarry`, built by `core.streaming`) seeds the window
    with the per-channel frontier / down-until state and retired join-group
    maxes of everything already settled — the streaming windowed mode.
    ``carry=None`` (the default) traces the exact historical program, so
    non-streaming entry points stay bit- and jit-cache-identical.

    ``max_rounds=`` as a direct kwarg is deprecated (folds into
    ``options`` with a `DeprecationWarning`).

    The budget is resolved host-side and passed to the jitted fixpoint as
    a *traced* operand, so sweeping budgets (or growing the computed bound
    across lowerings of one shape) never recompiles; the
    ``lax.while_loop`` early-exits on the first unchanged round, so a
    generous bound costs nothing at runtime.
    """
    opts = _merge_options("simulate", options, max_rounds=max_rounds)
    budget = opts.max_rounds if opts.max_rounds > 0 else round_bound(hops)
    return _simulate_fixpoint(hops, channels, issue_ps, jnp.int64(budget),
                              carry, opts.kernel_impl)


@functools.partial(jax.jit, static_argnames=("impl",))
def _simulate_fixpoint(hops: Hops, channels: Channels, issue_ps, rounds,
                       carry: StreamCarry | None, impl: str) -> Schedule:
    n, h = hops.channel.shape
    has_join = hops.join_id is not None
    join_seed = carry.join_seed_ps if carry is not None else None

    # contention-free lower bound initialization (sampled replay stretch
    # included: it delays the item even uncontended; retraining stalls and
    # join gates only ever delay items, so they keep this a valid lower
    # bound)
    ser0 = wire_ser_ps(hops.nbytes, channels,
                       jnp.minimum(hops.channel, channels.bw_MBps.shape[0] - 1),
                       extra_wire=hops.extra_wire_bytes)
    step = jnp.where(hops.valid, ser0 + hops.fixed_after_ps, 0)
    arrive0 = issue_ps[:, None] + jnp.concatenate(
        [jnp.zeros((n, 1), jnp.int64), jnp.cumsum(step, axis=1)], axis=1
    )

    def cond(state):
        i, arrive, _, _, resid = state
        return (i < rounds) & (resid != 0)

    def body(state):
        i, arrive, _, _, _ = state
        eff_issue = (_join_gate(hops, issue_ps, arrive, join_seed)
                     if has_join else issue_ps)
        new_arrive, start, depart = _one_round(hops, channels, eff_issue,
                                               arrive, carry=carry, impl=impl)
        resid = jnp.max(jnp.abs(new_arrive - arrive))
        return i + 1, new_arrive, start, depart, resid

    z = jnp.zeros((n, h), jnp.int64)
    i, arrive, start, depart, resid = jax.lax.while_loop(
        cond, body, (jnp.int64(0), arrive0, z, z, jnp.int64(-1))
    )
    return Schedule(
        arrive=arrive, start=start, depart=depart,
        complete=arrive[:, h], rounds=i, converged=resid == 0,
        residual_ps=jnp.maximum(resid, 0),
    )


def replay_round(hops: Hops, channels: Channels, sched: Schedule,
                 carry: StreamCarry | None = None):
    """Re-run one FCFS round from a resolved schedule (telemetry replay).

    The exact schedule is a fixed point of the round map, so replaying one
    sort→scan pass from ``sched.arrive`` reproduces ``start``/``depart``
    bit-for-bit — and on the way extracts the per-hop **retraining-stall**
    share of each grant delay (the only latency component the final
    schedule arrays alone cannot separate from ordinary queueing).  Returns
    ``(start, depart, retrain_stall)``, each ``(N, H)``; the stall table is
    all zeros for deterministic-reliability layouts.  Pure observer: the
    schedule is an input, never recomputed.

    ``carry`` replays a streaming window from its seeded frontier
    (`core.streaming` folds per-window blame with it); a window's schedule
    is a fixpoint of the *seeded* round map, so the same argument applies.
    """
    _, start, depart, stall = _one_round(
        hops, channels, sched.arrive[:, 0], sched.arrive, with_stalls=True,
        carry=carry)
    return start, depart, stall


# ---------------------------------------------------------------------------
# Post-schedule metrics (paper Figs. 10–12, 16, 17)
# ---------------------------------------------------------------------------

def simulate_auto(hops: Hops, channels: Channels, issue_ps: jnp.ndarray,
                  options: SimOptions | None = None, *,
                  carry: StreamCarry | None = None,
                  max_rounds: int | None = None,
                  check: bool | str | None = None) -> tuple[Schedule, bool]:
    """Exact schedule with oracle fallback.

    The fixpoint converges within the computed `round_bound` for
    feed-forward traffic (the common case: topology sweeps, collective
    traces, join-gated coherence flows).  Tight feedback loops — requests
    and responses interleaving on one shared half-duplex channel — can
    converge only a few queue positions per round; rather than burn
    unbounded rounds, fall back to the event-driven oracle
    (`core.ref_des`), which is exact by construction and fast at bench
    sizes.  Returns (schedule, used_oracle).

    ``SimOptions.check`` selects the verification mode:

    "off"     skip the ``bool(sched.converged)`` readback — the only
              device→host sync on this path.  Callers that already pull
              the schedule to the host (the streaming driver does, every
              window, for carry extraction) use it to keep the window
              pipeline transfer-free and run their own fallback; the
              returned schedule may then be unconverged.
    "oracle"  (default) fall back to the oracle on non-convergence.
    "static"  additionally run the fabric-IR verifier (`core.verify`)
              over the lowered triple *before* tracing anything and raise
              `verify.VerifyError` on any contract violation — the
              belt-and-braces mode for tables a third-party lowering
              produced.  An explicit round budget below the computed
              bound is a ``join.depth`` finding.

    ``carry`` threads streaming window state into both the fixpoint and
    the oracle fallback.  ``max_rounds=`` / ``check=`` direct kwargs are
    deprecated shims (``check=True`` ≙ "oracle", ``check=False`` ≙ "off").
    """
    opts = _merge_options("simulate_auto", options, max_rounds=max_rounds,
                          check=check)
    if opts.check == "static":
        from . import verify  # local import: host-side checker only

        verify.assert_valid(hops, channels, issue_ps, carry=carry,
                            max_rounds=opts.max_rounds or None)
    sched = simulate(hops, channels, issue_ps, opts, carry=carry)
    if opts.check == "off":
        return sched, False
    if bool(sched.converged):
        return sched, False
    from . import ref_des  # local import: oracle pulls in heapq only

    ref = ref_des.simulate_ref(hops, channels, issue_ps, carry=carry)
    return Schedule(
        arrive=jnp.asarray(ref["arrive"]),
        start=jnp.asarray(ref["start"]),
        depart=jnp.asarray(ref["depart"]),
        complete=jnp.asarray(ref["complete"]),
        rounds=sched.rounds,
        converged=jnp.bool_(True),
        residual_ps=jnp.int64(0),
    ), True


def channel_stats(hops: Hops, sched: Schedule, channels: Channels,
                  window: tuple[jnp.ndarray, jnp.ndarray] | None = None) -> dict:
    """Per-channel busy time, payload time and queue waits.

    bus utility (Fig. 17)        = busy / window, averaged over directions
    transmission efficiency      = payload transmit time / busy time

    Payload time counts *logical* payload bytes while busy time is actual
    wire occupancy, so on flit-mode channels (`core.link_layer`) efficiency
    directly measures the flit packing fraction: a saturated stream of
    fully packed 256 B flits reads 236/256, shrinking as CRC replays grow.
    """
    c = channels.bw_MBps.shape[0]
    busy_item = jnp.where(hops.valid, sched.depart - sched.start, 0)
    wait_item = jnp.where(hops.valid, sched.start - sched.arrive[:, :-1], 0)
    ser_item = ser_ps(hops.nbytes, channels.bw_MBps[jnp.minimum(hops.channel, c - 1)])
    pay_item = jnp.where(hops.valid & hops.is_payload, ser_item, 0)
    flat_c = jnp.where(hops.valid, hops.channel, c).reshape(-1)
    busy = jnp.zeros(c + 1, jnp.int64).at[flat_c].add(busy_item.reshape(-1))[:c]
    payload = jnp.zeros(c + 1, jnp.int64).at[flat_c].add(pay_item.reshape(-1))[:c]
    wait = jnp.zeros(c + 1, jnp.int64).at[flat_c].add(wait_item.reshape(-1))[:c]
    if window is None:
        t0 = jnp.min(sched.arrive[:, 0])
        t1 = jnp.max(sched.complete)
    else:
        t0, t1 = window
    span = jnp.maximum(t1 - t0, 1)
    return {
        "busy_ps": busy,
        "payload_ps": payload,
        "wait_ps": wait,
        "utility": busy / span,
        "efficiency": payload / jnp.maximum(busy, 1),
        "window_ps": span,
    }


def request_stats(hops: Hops, sched: Schedule, issue_ps: jnp.ndarray,
                  payload_bytes: jnp.ndarray, measured: jnp.ndarray) -> dict:
    """Per-request latency/wait and steady-state aggregate bandwidth."""
    latency = sched.complete - issue_ps
    wait = jnp.sum(
        jnp.where(hops.valid, sched.start - sched.arrive[:, :-1], 0), axis=1
    )
    n_hops = jnp.sum(hops.valid, axis=1)
    t0 = jnp.min(jnp.where(measured, issue_ps, jnp.int64(1) << 60))
    t1 = jnp.max(jnp.where(measured, sched.complete, 0))
    span_ps = jnp.maximum(t1 - t0, 1)
    total_payload = jnp.sum(jnp.where(measured, payload_bytes, 0))
    bw_MBps = total_payload * PS_PER_S // (span_ps * 1_000_000)

    # steady-state bandwidth: completion rate inside the 30%..90% completion
    # quantile window (robust to warm-up ramp and drain tail, which an
    # open-loop flood necessarily has)
    comp_sorted = jnp.sort(sched.complete)
    n = comp_sorted.shape[0]
    lo, hi = (3 * n) // 10, (9 * n) // 10
    win = jnp.maximum(comp_sorted[hi] - comp_sorted[lo], 1)
    mean_pay = jnp.sum(payload_bytes) // jnp.maximum(n, 1)
    steady_bw_MBps = (hi - lo) * mean_pay * PS_PER_S // (win * 1_000_000)
    return {
        "latency_ps": latency,
        "queue_wait_ps": wait,
        "n_hops": n_hops,
        "span_ps": span_ps,
        "bandwidth_MBps": bw_MBps,
        "steady_bandwidth_MBps": steady_bw_MBps,
        "mean_latency_ps": jnp.sum(jnp.where(measured, latency, 0))
        // jnp.maximum(jnp.sum(measured), 1),
    }


def make_channels(graph, row_hit_ps: int = 0, row_miss_ps: int = 0) -> Channels:
    """Lift a FabricGraph's channel tables into engine form.

    Graphs whose links carry a flit config (`topology.LinkSpec.flit`)
    contribute the per-channel flit-mode tables; a graph with no flit links
    lowers to the seed's 4-field layout so ``flit_mode="none"`` stays
    structurally (and therefore jit-cache and bit-) identical.
    """
    c = graph.n_channels
    rh = np.where(graph.chan_is_service, row_hit_ps, 0).astype(np.int64)
    rm = np.where(graph.chan_is_service, row_miss_ps, 0).astype(np.int64)
    base = Channels(
        bw_MBps=jnp.asarray(graph.chan_bw_MBps),
        turnaround_ps=jnp.asarray(graph.chan_turnaround_ps),
        row_hit_ps=jnp.asarray(rh),
        row_miss_ps=jnp.asarray(rm),
    )
    fsize = getattr(graph, "chan_flit_size", None)
    if fsize is None or not np.any(np.asarray(fsize) > 0):
        return base
    return base._replace(
        flit_size=jnp.asarray(fsize),
        flit_payload=jnp.asarray(graph.chan_flit_payload),
        replay_ppm=jnp.asarray(graph.chan_replay_ppm),
    )
