"""Fabric telemetry: latency attribution, channel counters, windowed series,
streaming quantile sketches.

The engine answers *when* every transaction moved; the paper's §V studies
(and any calibration against hardware — Cohet, CXLRAMSim) need *why*: where
a request's latency went, which channel is the bottleneck, how tails evolve
over a run.  This module is the pure-observer instrumentation layer over
``(Hops, Channels, Schedule, issue_ps)``:

  * **Latency attribution** (`attribute_latency`) — an exact partition of
    every request's end-to-end latency into join-wait stall, FCFS queueing
    wait, retraining stall, wire serialization, DRAM row-buffer extras and
    fixed post-latency.  The partition is *conservative by construction*:
    the components of row ``i`` sum to ``complete[i] − issue[i]`` with zero
    residual, in exact int64 picoseconds (`conservation_residual` exposes
    the per-row check).  The retraining share is recovered by replaying one
    scan round from the resolved schedule (`engine.replay_round` — the
    schedule is a fixpoint of the round map, so the replay is exact and the
    schedule itself is never touched).

  * **Per-channel counters** (`channel_telemetry`) — logical payload bytes,
    actual wire bytes (flit quantization + sampled CRC-replay overhead),
    busy time, utilization, total queue wait, and peak backlog (the maximum
    number of simultaneously queued items, arrivals counted before the
    same-instant grant).

  * **Windowed series** (`windowed_series`) — time-bucketed busy fraction,
    completion throughput and mean in-flight over a fixed bin grid: the
    shape the ROADMAP's chunked streaming engine emits per window.

  * **Streaming quantile sketch** (`QuantileSketch`) — a fixed-shape
    HDR-style log-bucketed histogram (int64 ps, ~1.6 % relative error)
    with O(1)-state update/merge/query: the online p50/p99/p99.9
    accumulator that windowed simulation carries across chunks instead of
    materializing whole ``Schedule``s.

  * **SF protocol counters** (`sf_telemetry`) — hit rate, BISnp fan-out
    histogram (per-request snooped-owner popcounts) and InvBlk/writeback
    volume from the dense `SFEvents` log.

Everything here is a **pure function of already-computed results** — jit-
and vmap-safe (sweep telemetry vmaps alongside the sweep itself), and
provably non-perturbing: computing metrics cannot change a schedule, which
the test suite pins by re-simulating around a telemetry pass.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .engine import Channels, Hops, Schedule, replay_round, wire_ser_ps
from .snoop_filter import SFEvents, owner_count

# ---------------------------------------------------------------------------
# Latency attribution
# ---------------------------------------------------------------------------


class LatencyAttribution(NamedTuple):
    """Exact per-request partition of ``complete − issue`` (int64 ps).

    ``join_wait + queue_wait + retrain_stall + wire + row_extra + fixed ==
    total`` holds per row with zero residual — the conservation invariant
    the property suite checks across flit-mode × reliability × join
    configs.  Components:

    join_wait_ps      fork/join release stall: the gap between a waiter
                      row's nominal issue and the max completion of its
                      contributor group (0 for non-waiters).
    queue_wait_ps     FCFS contention wait (turnaround gaps included),
                      *excluding* the retraining share below.
    retrain_stall_ps  grant delay attributable to link-down intervals
                      alone (stochastic reliability; 0 otherwise).
    wire_ps           wire serialization — flit quantization, expected
                      CRC-replay stretch and sampled replay bytes included.
    row_extra_ps      DRAM row-buffer hit/miss extras on service hops.
    fixed_ps          fixed post-hop latency (propagation, FEC, switching,
                      endpoint fixed service).
    total_ps          ``complete − issue``.
    """

    join_wait_ps: jnp.ndarray
    queue_wait_ps: jnp.ndarray
    retrain_stall_ps: jnp.ndarray
    wire_ps: jnp.ndarray
    row_extra_ps: jnp.ndarray
    fixed_ps: jnp.ndarray
    total_ps: jnp.ndarray


def attribute_latency(hops: Hops, channels: Channels, sched: Schedule,
                      issue_ps: jnp.ndarray) -> LatencyAttribution:
    """Attribute every request's latency to its mechanism (see
    `LatencyAttribution`).  Pure observer — reads the schedule, never
    recomputes it — and jit/vmap-safe (sweep telemetry vmaps over stacked
    ``Channels``/``Schedule`` axes like the sweep itself)."""
    c = channels.bw_MBps.shape[0]
    valid = hops.valid
    occupied = valid & (hops.nbytes > 0)
    clip = jnp.clip(hops.channel, 0, c - 1)

    hop_wait = jnp.where(valid, sched.start - sched.arrive[:, :-1], 0)
    hop_serv = jnp.where(valid, sched.depart - sched.start, 0)
    wire = jnp.where(
        occupied,
        wire_ser_ps(hops.nbytes, channels, clip,
                    extra_wire=hops.extra_wire_bytes),
        0,
    )
    if hops.retrain_after_ps is not None:
        _, _, stall = replay_round(hops, channels, sched)
        retrain = jnp.sum(jnp.where(valid, stall, 0), axis=1)
    else:
        retrain = jnp.zeros(valid.shape[0], jnp.int64)
    join_wait = sched.arrive[:, 0] - issue_ps
    return LatencyAttribution(
        join_wait_ps=join_wait,
        queue_wait_ps=jnp.sum(hop_wait, axis=1) - retrain,
        retrain_stall_ps=retrain,
        wire_ps=jnp.sum(wire, axis=1),
        row_extra_ps=jnp.sum(hop_serv - wire, axis=1),
        fixed_ps=jnp.sum(jnp.where(valid, hops.fixed_after_ps, 0), axis=1),
        total_ps=sched.complete - issue_ps,
    )


def conservation_residual(att: LatencyAttribution) -> jnp.ndarray:
    """Per-row conservation residual — exactly zero when the attribution
    partitions the latency (the hard invariant; nonzero means a schedule
    that is not a fixpoint of the round map, or a telemetry bug)."""
    parts = (att.join_wait_ps + att.queue_wait_ps + att.retrain_stall_ps
             + att.wire_ps + att.row_extra_ps + att.fixed_ps)
    return att.total_ps - parts


# ---------------------------------------------------------------------------
# Per-channel counters
# ---------------------------------------------------------------------------


class ChannelTelemetry(NamedTuple):
    """Per-channel counters over one schedule, shape (C,) unless noted.

    payload_bytes   logical payload bytes transmitted (header/DLLP bytes
                    excluded — `Hops.is_payload`).
    wire_bytes      actual wire bytes: flit-quantized (+ sampled CRC-replay
                    bytes under stochastic reliability).  The *expected*
                    replay model stretches time, not bytes — its overhead
                    shows in ``busy_ps``.
    busy_ps         total channel occupancy (serialization + row extras).
    wait_ps         total FCFS queue wait paid on the channel.
    utilization     ``busy_ps / window`` (float).
    peak_backlog    max simultaneously queued items (arrived, not yet
                    granted; same-instant arrivals counted before grants).
    window_ps       () — observation window (defaults to first arrival →
                    last completion).
    """

    payload_bytes: jnp.ndarray
    wire_bytes: jnp.ndarray
    busy_ps: jnp.ndarray
    wait_ps: jnp.ndarray
    utilization: jnp.ndarray
    peak_backlog: jnp.ndarray
    window_ps: jnp.ndarray


def hop_wire_bytes(hops: Hops, channels: Channels) -> jnp.ndarray:
    """Actual wire bytes of every hop: flit quantization plus the sampled
    per-hop CRC-replay bytes (`Hops.extra_wire_bytes`); byte-exact channels
    pass logical bytes through.  Zero on invalid / zero-byte hops."""
    c = channels.bw_MBps.shape[0]
    occupied = hops.valid & (hops.nbytes > 0)
    clip = jnp.clip(hops.channel, 0, c - 1)
    wire = hops.nbytes
    if channels.flit_size is not None:
        fsize = channels.flit_size[clip]
        fpay = jnp.maximum(channels.flit_payload[clip], 1)
        quant = ((hops.nbytes + fpay - 1) // fpay) * fsize
        if hops.extra_wire_bytes is not None:
            quant = quant + hops.extra_wire_bytes
        wire = jnp.where(fsize > 0, quant, wire)
    return jnp.where(occupied, wire, 0)


def channel_telemetry(hops: Hops, channels: Channels, sched: Schedule,
                      window: tuple | None = None) -> ChannelTelemetry:
    """Per-channel counters (see `ChannelTelemetry`).  Pure observer,
    jit/vmap-safe."""
    c = channels.bw_MBps.shape[0]
    n, h = hops.channel.shape
    k = n * h
    occupied = (hops.valid & (hops.nbytes > 0)).reshape(k)
    flat_c = jnp.where(occupied, hops.channel.reshape(k), c)

    busy_item = (sched.depart - sched.start).reshape(k)
    wait_item = (sched.start - sched.arrive[:, :h]).reshape(k)
    pay_item = jnp.where(hops.is_payload.reshape(k), hops.nbytes.reshape(k), 0)
    wire_item = hop_wire_bytes(hops, channels).reshape(k)

    def per_chan(x):
        return jnp.zeros(c + 1, jnp.int64).at[flat_c].add(
            jnp.where(occupied, x, 0))[:c]

    busy = per_chan(busy_item)
    wait = per_chan(wait_item)
    payload = per_chan(pay_item)
    wire = per_chan(wire_item)

    # peak backlog: ±1 events (arrival +1, grant −1) lexsorted by
    # (channel, time, arrivals-first); every channel's deltas sum to zero
    # and segments are channel-contiguous, so the global running sum IS the
    # per-channel backlog and a per-channel scatter-max reads the peak.
    times = jnp.concatenate([sched.arrive[:, :h].reshape(k),
                             sched.start.reshape(k)])
    chans2 = jnp.concatenate([flat_c, flat_c])
    delta = jnp.concatenate([jnp.where(occupied, 1, 0),
                             jnp.where(occupied, -1, 0)]).astype(jnp.int64)
    typ = jnp.concatenate([jnp.zeros(k, jnp.int32), jnp.ones(k, jnp.int32)])
    order = jnp.argsort(typ, stable=True)
    order = order[jnp.argsort(times[order], stable=True)]
    order = order[jnp.argsort(chans2[order], stable=True)]
    backlog = jnp.cumsum(delta[order])
    peak = jnp.zeros(c + 1, jnp.int64).at[chans2[order]].max(backlog)[:c]

    if window is None:
        t0 = jnp.min(sched.arrive[:, 0])
        t1 = jnp.max(sched.complete)
    else:
        t0, t1 = window
    span = jnp.maximum(t1 - t0, 1)
    return ChannelTelemetry(
        payload_bytes=payload, wire_bytes=wire, busy_ps=busy, wait_ps=wait,
        utilization=busy / span, peak_backlog=peak, window_ps=span,
    )


# ---------------------------------------------------------------------------
# Channel blame (aggregate bottleneck attribution)
# ---------------------------------------------------------------------------


class ChannelBlame(NamedTuple):
    """Aggregate per-channel blame: where the fleet's latency went.

    The per-request partition of `attribute_latency`, re-scattered onto the
    channel that charged each component — the jit/vmap-safe aggregate view
    of `core.critical_path`'s per-request walks (which add *which-event*
    structure on the host).  Conservation:

        Σ queue + Σ retrain + Σ wire + Σ row_extra + join + fixed == total

    exactly (int64 ps; `blame_conservation_residual`).

    queue_ps      (C,) FCFS contention wait per channel (turnaround gaps
                  included, retraining share excluded).
    retrain_ps    (C,) link-down stall per channel.
    wire_ps       (C,) serialization time per channel.
    row_extra_ps  (C,) row-buffer penalties per channel.
    join_ps       ()  fork/join release stall (channel-less).
    fixed_ps      ()  fixed post-hop latency (channel-less).
    total_ps      ()  Σ ``complete − issue``.
    """

    queue_ps: jnp.ndarray
    retrain_ps: jnp.ndarray
    wire_ps: jnp.ndarray
    row_extra_ps: jnp.ndarray
    join_ps: jnp.ndarray
    fixed_ps: jnp.ndarray
    total_ps: jnp.ndarray


def channel_blame(hops: Hops, channels: Channels, sched: Schedule,
                  issue_ps: jnp.ndarray) -> ChannelBlame:
    """Aggregate blame per channel (see `ChannelBlame`).  Pure observer,
    jit/vmap-safe; the retraining share comes from the same fixpoint replay
    as `attribute_latency`."""
    c = channels.bw_MBps.shape[0]
    n, h = hops.channel.shape
    k = n * h
    occupied = (hops.valid & (hops.nbytes > 0)).reshape(k)
    flat_c = jnp.where(occupied, hops.channel.reshape(k), c)
    clip = jnp.clip(hops.channel, 0, c - 1)

    def per_chan(x):
        return jnp.zeros(c + 1, jnp.int64).at[flat_c].add(
            jnp.where(occupied, x, 0))[:c]

    if hops.retrain_after_ps is not None:
        _, _, stall = replay_round(hops, channels, sched)
    else:
        stall = jnp.zeros((n, h), jnp.int64)
    wait = (sched.start - sched.arrive[:, :h]).reshape(k)
    busy = (sched.depart - sched.start).reshape(k)
    wire_t = wire_ser_ps(hops.nbytes, channels, clip,
                         extra_wire=hops.extra_wire_bytes).reshape(k)
    return ChannelBlame(
        queue_ps=per_chan(wait - stall.reshape(k)),
        retrain_ps=per_chan(stall.reshape(k)),
        wire_ps=per_chan(wire_t),
        row_extra_ps=per_chan(busy - wire_t),
        join_ps=jnp.sum(sched.arrive[:, 0] - issue_ps),
        fixed_ps=jnp.sum(jnp.where(hops.valid, hops.fixed_after_ps, 0)),
        total_ps=jnp.sum(sched.complete - issue_ps),
    )


def blame_conservation_residual(b: ChannelBlame) -> jnp.ndarray:
    """() int64 — zero iff the blame table partitions the total latency."""
    parts = (jnp.sum(b.queue_ps) + jnp.sum(b.retrain_ps) + jnp.sum(b.wire_ps)
             + jnp.sum(b.row_extra_ps) + b.join_ps + b.fixed_ps)
    return b.total_ps - parts


# ---------------------------------------------------------------------------
# Windowed series
# ---------------------------------------------------------------------------


class WindowedSeries(NamedTuple):
    """Fixed-grid time series over one schedule (all shapes (K,)).

    busy_ps        total channel occupancy inside each bin (all channels).
    busy_frac      ``busy_ps / (C · bin)`` — mean busy fraction (float).
    completions    requests completing inside each bin.
    inflight       time-averaged in-flight requests per bin (float).
    t0_ps, bin_ps  () — grid origin and bin width.
    """

    busy_ps: jnp.ndarray
    busy_frac: jnp.ndarray
    completions: jnp.ndarray
    inflight: jnp.ndarray
    t0_ps: jnp.ndarray
    bin_ps: jnp.ndarray


@functools.partial(jax.jit, static_argnames=("n_bins",))
def windowed_series(hops: Hops, channels: Channels, sched: Schedule,
                    issue_ps: jnp.ndarray, n_bins: int = 32,
                    window: tuple | None = None) -> WindowedSeries:
    """Bucket the schedule onto a fixed ``n_bins`` grid (see
    `WindowedSeries`).  Occupancy is split *exactly* across bins (partial
    overlap of a transmission with a bin counts its overlap), so the series
    sums to the channel totals.  ``n_bins`` is static (output shape)."""
    c = channels.bw_MBps.shape[0]
    n, h = hops.channel.shape
    if window is None:
        t0 = jnp.min(sched.arrive[:, 0])
        t1 = jnp.max(sched.complete)
    else:
        t0, t1 = window
    bin_ps = jnp.maximum((t1 - t0 + n_bins - 1) // n_bins, 1)
    edges = t0 + bin_ps * jnp.arange(n_bins + 1, dtype=jnp.int64)

    def coverage(lo, hi):
        """Σ overlap of the [lo, hi) intervals with each bin, exactly."""
        dur = jnp.maximum(hi - lo, 0).reshape(-1)
        lo = lo.reshape(-1)
        # f(t) = Σ clip(t − lo, 0, dur); per-bin coverage = f(e+1) − f(e)
        f = jnp.sum(jnp.clip(edges[:, None] - lo[None, :], 0,
                             dur[None, :]), axis=1)
        return f[1:] - f[:-1]

    occupied = hops.valid & (hops.nbytes > 0)
    busy = coverage(jnp.where(occupied, sched.start, 0),
                    jnp.where(occupied, sched.depart, 0))
    infl = coverage(issue_ps, sched.complete)

    comp = sched.complete
    in_range = (comp >= t0) & (comp <= t1)
    idx = jnp.clip((comp - t0) // bin_ps, 0, n_bins - 1)
    completions = jnp.zeros(n_bins, jnp.int64).at[idx].add(
        jnp.where(in_range, 1, 0))
    return WindowedSeries(
        busy_ps=busy,
        busy_frac=busy / (c * bin_ps),
        completions=completions,
        inflight=infl / bin_ps,
        t0_ps=t0, bin_ps=bin_ps,
    )


# ---------------------------------------------------------------------------
# Streaming quantile sketch (online p50/p99/p99.9)
# ---------------------------------------------------------------------------

SKETCH_SUB_BITS = 5                     # 32 sub-buckets per octave
_SKETCH_M = 1 << SKETCH_SUB_BITS
SKETCH_BINS = (64 - SKETCH_SUB_BITS) * _SKETCH_M
SKETCH_REL_ERROR = 1.0 / _SKETCH_M      # worst-case relative bucket width


class QuantileSketch(NamedTuple):
    """Streaming log-bucketed histogram over nonneg int64 picoseconds.

    HDR-histogram bucketing: values below 2^SKETCH_SUB_BITS are exact;
    above, each power-of-two octave splits into 2^SKETCH_SUB_BITS linear
    sub-buckets (≤ ~1.6 % relative error at the bucket midpoint).  State is
    one fixed-shape count vector plus exact min/max — O(1) memory, update /
    merge / quantile are all jit- and vmap-safe, and merging two sketches
    equals sketching the concatenation: the accumulator a chunked streaming
    engine carries across windows instead of materializing schedules.
    """

    counts: jnp.ndarray   # (SKETCH_BINS,) int64
    n: jnp.ndarray        # () int64
    min_ps: jnp.ndarray   # () int64 exact minimum (max int64 when empty)
    max_ps: jnp.ndarray   # () int64 exact maximum (0 when empty)


def sketch_new() -> QuantileSketch:
    return QuantileSketch(
        counts=jnp.zeros(SKETCH_BINS, jnp.int64),
        n=jnp.int64(0),
        min_ps=jnp.int64((1 << 62) - 1 + (1 << 62)),   # int64 max
        max_ps=jnp.int64(0),
    )


def sketch_bin(values: jnp.ndarray) -> jnp.ndarray:
    """Bucket index of each value (negative values clamp to 0)."""
    v = jnp.maximum(jnp.asarray(values, jnp.int64), 0)
    e = jnp.zeros_like(v)
    for s in (32, 16, 8, 4, 2, 1):      # e = floor(log2(max(v, 1)))
        e = e + jnp.where((v >> (e + s)) > 0, s, 0)
    small = v < _SKETCH_M
    sub = (v >> jnp.maximum(e - SKETCH_SUB_BITS, 0)) - _SKETCH_M
    return jnp.where(small, v,
                     (e - SKETCH_SUB_BITS + 1) * _SKETCH_M + sub)


def sketch_value(bins: jnp.ndarray) -> jnp.ndarray:
    """Representative (midpoint) value of each bucket index."""
    b = jnp.asarray(bins, jnp.int64)
    small = b < _SKETCH_M
    k = jnp.maximum(b // _SKETCH_M, 1)
    shift = k - 1                        # == octave − SKETCH_SUB_BITS
    lo = (_SKETCH_M + b % _SKETCH_M) << shift
    return jnp.where(small, b, lo + ((jnp.int64(1) << shift) >> 1))


def sketch_update(sk: QuantileSketch, values: jnp.ndarray,
                  mask: jnp.ndarray | None = None) -> QuantileSketch:
    """Fold a batch of values (optionally masked) into the sketch."""
    v = jnp.asarray(values, jnp.int64).reshape(-1)
    m = (jnp.ones(v.shape, bool) if mask is None
         else jnp.asarray(mask, bool).reshape(-1))
    idx = jnp.where(m, sketch_bin(v), 0)
    one = jnp.where(m, jnp.int64(1), 0)
    big = jnp.int64((1 << 62) - 1 + (1 << 62))
    return QuantileSketch(
        counts=sk.counts.at[idx].add(one),
        n=sk.n + jnp.sum(one),
        min_ps=jnp.minimum(sk.min_ps, jnp.min(jnp.where(m, v, big))),
        max_ps=jnp.maximum(sk.max_ps, jnp.max(jnp.where(m, v, 0))),
    )


def sketch_merge(a: QuantileSketch, b: QuantileSketch) -> QuantileSketch:
    return QuantileSketch(
        counts=a.counts + b.counts, n=a.n + b.n,
        min_ps=jnp.minimum(a.min_ps, b.min_ps),
        max_ps=jnp.maximum(a.max_ps, b.max_ps),
    )


def sketch_quantile(sk: QuantileSketch, q) -> jnp.ndarray:
    """Estimate the q-quantile (scalar or vector ``q`` in [0, 1]).

    Returns the representative value of the bucket holding the
    ``ceil(q·n)``-th smallest sample, clamped to the exact observed
    [min, max] — so p0/p100 are exact and every estimate is within one
    bucket (≤ ~1.6 % relative) of a true sample quantile.  0 when empty.
    """
    q = jnp.asarray(q, jnp.float64)
    cum = jnp.cumsum(sk.counts)
    rank = jnp.clip(jnp.ceil(q * sk.n).astype(jnp.int64), 1, jnp.maximum(sk.n, 1))
    idx = jnp.searchsorted(cum, rank, side="left")
    val = jnp.clip(sketch_value(jnp.minimum(idx, SKETCH_BINS - 1)),
                   sk.min_ps, sk.max_ps)
    # ranks 1 and n are the exact observed order statistics
    val = jnp.where(rank >= sk.n, sk.max_ps, val)
    val = jnp.where(rank <= 1, sk.min_ps, val)
    return jnp.where(sk.n > 0, val, 0)


def sketch_quantiles(sk: QuantileSketch,
                     qs=(0.5, 0.99, 0.999)) -> jnp.ndarray:
    """The tail vector the benches gate on — default (p50, p99, p99.9)."""
    return sketch_quantile(sk, jnp.asarray(qs))


# ---------------------------------------------------------------------------
# Streaming fold (windowed simulation accumulator)
# ---------------------------------------------------------------------------


class StreamTelemetry(NamedTuple):
    """Running accumulator for windowed simulation (`core.streaming`) —
    what the driver carries instead of materializing per-window
    ``Schedule``s.

    Per-window contributions are masked to *settled* items / *retired*
    rows, so boundary-spanning rows (which reappear in later windows as
    carried suffixes) fold exactly once and streaming totals equal the
    monolithic `channel_telemetry` counters bit-for-bit.  The latency
    sketch is `QuantileSketch` (mergeable, so merging per-window folds
    equals sketching the monolithic latencies).  Blame components
    (retrain / row-extra / join / fixed) fold from the same settled masks —
    a streamed `ChannelBlame` is derivable in `stream_telemetry_finalize`
    and equals the monolithic `channel_blame` bit-for-bit.  (Peak backlog
    needs a windowed event sort over the settled prefix; the streaming
    driver itself maintains it — `streaming.StreamState`.)

    payload_bytes/wire_bytes/busy_ps/wait_ps  (C,) int64 channel counters.
    retrain_ps    (C,) int64 link-down stall per channel (settled items).
    row_extra_ps  (C,) int64 row-buffer penalties per channel.
    join_ps       () int64 fork/join release stall (rows counted once, at
                  gate settlement).
    fixed_ps      () int64 fixed post-hop latency of settled items.
    sketch        latency `QuantileSketch` over retired requests.
    n_retired     () int64 requests retired so far.
    t0_ps/t1_ps   () int64 observation span (min issue / max completion of
                  retired requests; int64-max / 0 while empty).
    """

    sketch: QuantileSketch
    payload_bytes: jnp.ndarray
    wire_bytes: jnp.ndarray
    busy_ps: jnp.ndarray
    wait_ps: jnp.ndarray
    retrain_ps: jnp.ndarray
    row_extra_ps: jnp.ndarray
    join_ps: jnp.ndarray
    fixed_ps: jnp.ndarray
    n_retired: jnp.ndarray
    t0_ps: jnp.ndarray
    t1_ps: jnp.ndarray


def stream_telemetry_new(n_channels: int) -> StreamTelemetry:
    z = jnp.zeros(n_channels, jnp.int64)
    return StreamTelemetry(
        sketch=sketch_new(), payload_bytes=z, wire_bytes=z, busy_ps=z,
        wait_ps=z, retrain_ps=z, row_extra_ps=z,
        join_ps=jnp.int64(0), fixed_ps=jnp.int64(0), n_retired=jnp.int64(0),
        t0_ps=jnp.int64((1 << 62) - 1 + (1 << 62)), t1_ps=jnp.int64(0),
    )


@jax.jit
def stream_telemetry_fold(acc: StreamTelemetry, hops: Hops,
                          channels: Channels, sched: Schedule,
                          settled: jnp.ndarray, retired: jnp.ndarray,
                          latency_ps: jnp.ndarray,
                          stall_ps: jnp.ndarray,
                          gate_mask: jnp.ndarray,
                          gate_wait_ps: jnp.ndarray) -> StreamTelemetry:
    """Fold one resolved window into the accumulator.

    settled      (N, H) bool — items whose (start, depart) are final this
                 window (never again: the driver's settlement mask),
                 already AND-ed with validity.
    retired      (N,) bool — rows completing this window (padding excluded).
    latency_ps   (N,) int64 — ``complete − original issue`` per retired row
                 (the original issue survives window re-entry; junk where
                 ``retired`` is False).
    stall_ps     (N, H) int64 — per-item retraining stall from the window's
                 carry-seeded fixpoint replay (zeros without retrain
                 tables); a settled item's stall is final, so folding it
                 settled-masked reproduces the monolithic replay exactly.
    gate_mask    (N,) bool — rows whose hop-0 gate (join wait / issue)
                 became final this window; the driver guarantees each
                 global row is flagged exactly once across the stream.
    gate_wait_ps (N,) int64 — ``arrive[:, 0] − original issue`` per row
                 (junk where ``gate_mask`` is False).
    """
    c = channels.bw_MBps.shape[0]
    n, h = hops.channel.shape
    k = n * h
    occupied = (hops.valid & (hops.nbytes > 0) & settled).reshape(k)
    flat_c = jnp.where(occupied, hops.channel.reshape(k), c)
    clip = jnp.clip(hops.channel, 0, c - 1)

    def per_chan(x):
        return jnp.zeros(c + 1, jnp.int64).at[flat_c].add(
            jnp.where(occupied, x, 0))[:c]

    busy_item = (sched.depart - sched.start).reshape(k)
    wire_time = wire_ser_ps(hops.nbytes, channels, clip,
                            extra_wire=hops.extra_wire_bytes).reshape(k)
    busy = per_chan(busy_item)
    wait = per_chan((sched.start - sched.arrive[:, :h]).reshape(k))
    payload = per_chan(jnp.where(hops.is_payload.reshape(k),
                                 hops.nbytes.reshape(k), 0))
    wire = per_chan(hop_wire_bytes(hops, channels).reshape(k))
    retrain = per_chan(stall_ps.reshape(k))
    row_extra = per_chan(busy_item - wire_time)
    fixed = jnp.sum(jnp.where(settled, hops.fixed_after_ps, 0))
    join = jnp.sum(jnp.where(gate_mask, gate_wait_ps, 0))

    big = jnp.int64((1 << 62) - 1 + (1 << 62))
    iss = sched.complete - latency_ps
    return StreamTelemetry(
        sketch=sketch_update(acc.sketch, latency_ps, mask=retired),
        payload_bytes=acc.payload_bytes + payload,
        wire_bytes=acc.wire_bytes + wire,
        busy_ps=acc.busy_ps + busy,
        wait_ps=acc.wait_ps + wait,
        retrain_ps=acc.retrain_ps + retrain,
        row_extra_ps=acc.row_extra_ps + row_extra,
        join_ps=acc.join_ps + join,
        fixed_ps=acc.fixed_ps + fixed,
        n_retired=acc.n_retired + jnp.sum(retired.astype(jnp.int64)),
        t0_ps=jnp.minimum(acc.t0_ps, jnp.min(jnp.where(retired, iss, big))),
        t1_ps=jnp.maximum(acc.t1_ps,
                          jnp.max(jnp.where(retired, sched.complete, 0))),
    )


def stream_telemetry_finalize(acc: StreamTelemetry,
                              qs=(0.5, 0.99, 0.999)) -> dict:
    """Host-side summary of a finished (or in-progress) stream fold.

    The ``blame`` entry is the streamed `ChannelBlame` decomposition —
    queue wait is the folded wait minus the retraining share, wire time is
    folded busy minus row extras; with every window folded it equals the
    monolithic `channel_blame` bit-for-bit (property-tested).
    """
    span = max(int(acc.t1_ps) - int(acc.t0_ps), 1)
    import numpy as np

    wait = np.asarray(acc.wait_ps)
    busy = np.asarray(acc.busy_ps)
    retrain = np.asarray(acc.retrain_ps)
    row_extra = np.asarray(acc.row_extra_ps)
    return {
        "n_retired": int(acc.n_retired),
        "quantiles_ps": np.asarray(sketch_quantiles(acc.sketch, qs)),
        "payload_bytes": np.asarray(acc.payload_bytes),
        "wire_bytes": np.asarray(acc.wire_bytes),
        "busy_ps": busy,
        "wait_ps": wait,
        "utilization": busy / span,
        "span_ps": span,
        "blame": {
            "queue_ps": wait - retrain,
            "retrain_ps": retrain,
            "wire_ps": busy - row_extra,
            "row_extra_ps": row_extra,
            "join_ps": int(acc.join_ps),
            "fixed_ps": int(acc.fixed_ps),
        },
    }


# ---------------------------------------------------------------------------
# Snoop-filter protocol counters
# ---------------------------------------------------------------------------


class SFTelemetry(NamedTuple):
    """Protocol-decision counters from a dense `SFEvents` log.

    hit_rate      () float — local-cache hit fraction.
    fanout_hist   (R+1,) int64 — histogram of per-request snooped-owner
                  counts (index = popcount of ``bisnp_mask``; 0 = request
                  issued no snoops).
    bisnp_legs    () int64 — total BISnp legs (Σ owner popcounts).
    invblk_lines  () int64 — lines invalidated by InvBlk/conflict flows.
    wb_lines      () int64 — dirty lines written back.
    """

    hit_rate: jnp.ndarray
    fanout_hist: jnp.ndarray
    bisnp_legs: jnp.ndarray
    invblk_lines: jnp.ndarray
    wb_lines: jnp.ndarray


@functools.partial(jax.jit, static_argnames=("n_requesters",))
def sf_telemetry(events: SFEvents, n_requesters: int) -> SFTelemetry:
    owners = owner_count(events.bisnp_mask).astype(jnp.int64)
    hist = jnp.zeros(n_requesters + 1, jnp.int64).at[
        jnp.clip(owners, 0, n_requesters)].add(1)
    t = events.cache_hit.shape[0]
    return SFTelemetry(
        hit_rate=jnp.sum(events.cache_hit) / jnp.maximum(t, 1),
        fanout_hist=hist,
        bisnp_legs=jnp.sum(owners),
        invblk_lines=jnp.sum(events.inv_lines.astype(jnp.int64)),
        wb_lines=jnp.sum(events.wb_lines.astype(jnp.int64)),
    )


# ---------------------------------------------------------------------------
# Convenience aggregation
# ---------------------------------------------------------------------------


def fabric_metrics(hops: Hops, channels: Channels, sched: Schedule,
                   issue_ps: jnp.ndarray, n_bins: int = 32,
                   check: bool = True) -> dict:
    """One-call telemetry bundle: attribution + channel counters + windowed
    series + a latency sketch.  ``check=True`` (host-side, not jittable)
    raises if the conservation invariant fails."""
    att = attribute_latency(hops, channels, sched, issue_ps)
    if check:
        bad = int(jnp.max(jnp.abs(conservation_residual(att))))
        if bad != 0:
            raise AssertionError(
                f"latency attribution violates conservation by {bad} ps — "
                "the schedule is not a fixpoint of the round map (did it "
                "converge?) or telemetry has a bug")
    blame = channel_blame(hops, channels, sched, issue_ps)
    if check:
        bad = int(blame_conservation_residual(blame))
        if bad != 0:
            raise AssertionError(
                f"channel blame violates conservation by {bad} ps")
    sk = sketch_update(sketch_new(), att.total_ps)
    return {
        "attribution": att,
        "blame": blame,
        "channels": channel_telemetry(hops, channels, sched),
        "series": windowed_series(hops, channels, sched, issue_ps,
                                  n_bins=n_bins),
        "latency_sketch": sk,
        "latency_quantiles_ps": sketch_quantiles(sk),
        "rounds": sched.rounds,
        "converged": sched.converged,
    }
