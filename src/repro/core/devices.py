"""Device layer: computational components (requesters) and workload building.

ESF's computational component (§III-B) has three units:

  * request queue — issue capability, modeled by an inter-issue interval
    (open-loop intensity control; the loaded-latency knob of §IV),
  * address translation unit — interleaving policy across memory endpoints,
  * cache-coherence management unit — collaborates with the DCOH; handled in
    `core.snoop_filter` and composed with this layer by the benches.

``build_workload`` turns a set of RequesterSpecs into the dense hop tables the
engine consumes: for each access it resolves the route (default shortest-path
from the interconnect layer, or one of the equal-cost alternatives under the
adaptive strategy), then emits request hops, the endpoint service hop, and
response hops.

Packetization (header model, paper §V-D): a read sends a header-sized request
packet toward the endpoint and a payload-sized response back; a write sends
the payload toward the endpoint and a header-sized completion back.  This is
the model under which single-type traffic leaves one full-duplex direction to
headers only (utility 1/2 at zero header overhead) and a 1:1 mix doubles
bandwidth — and under which the gain vanishes exactly when header == payload,
matching Fig. 16/17.  A "symmetric" variant (headers on every packet) is also
provided for sensitivity runs.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from .topology import FabricGraph, SWITCH
from .engine import Channels, Hops, make_channels
from . import link_layer

HEADER_MODELS = ("esf", "symmetric")


def packetize(header_model: str, write: bool, payload: int,
              header_bytes: int) -> tuple[int, int, bool, bool]:
    """Logical forward/backward packet bytes of one access (paper §V-D).

    Returns (fwd_bytes, bwd_bytes, fwd_is_payload, bwd_is_payload).  Bytes
    are *logical* TLP bytes; flit-mode channels quantize them to whole-flit
    wire bytes during serialization (`link_layer` lowering contract), so the
    byte-exact ``flit_mode="none"`` path is untouched.
    """
    if header_model == "esf":
        fwd_b = payload if write else header_bytes
        bwd_b = header_bytes if write else payload
    else:  # symmetric: header on every packet, payload rides with data
        fwd_b = header_bytes + (payload if write else 0)
        bwd_b = header_bytes + (0 if write else payload)
    return fwd_b, bwd_b, write, not write


@dataclass
class RequesterSpec:
    """One requester's traffic program (open loop)."""

    node: int
    n_requests: int
    targets: Sequence[int]
    pattern: str = "uniform"        # uniform | stream | skewed | trace
    read_ratio: float = 1.0
    issue_interval_ps: int = 10_000
    start_ps: int = 0
    payload_bytes: int = 64
    seed: int = 0
    # skewed pattern: hot fraction of footprint getting hot_ratio of accesses
    footprint_lines: int = 4096
    hot_frac: float = 0.1
    hot_ratio: float = 0.9
    issue_jitter: str = "none"      # "none" | "exp" (Poisson arrivals)
    # trace replay (ESF trace-based mode): overrides pattern when set
    trace_addr: np.ndarray | None = None
    trace_is_write: np.ndarray | None = None
    trace_interval_ps: np.ndarray | None = None


@dataclass
class Workload:
    hops: Hops
    channels: Channels
    issue_ps: jnp.ndarray
    payload_bytes: jnp.ndarray
    measured: jnp.ndarray
    requester: np.ndarray       # (N,) requester node per transaction
    target: np.ndarray          # (N,) memory node per transaction
    is_write: np.ndarray
    n_link_hops: np.ndarray     # (N,) link hops one way (for Fig. 11 grouping)
    route_alt: np.ndarray       # (N,) which equal-cost alternative was taken

    @property
    def n_demand(self) -> int:
        """Count of real (routable) demand transactions.  ``build_workload``
        appends pseudo-rows — credit-return DLLPs, requester -1 — *after*
        the demand rows, and their count is route-dependent: anything that
        indexes per-transaction route choices (`core.routing`) or
        per-request metrics must address the demand prefix only."""
        return int((self.requester >= 0).sum())


def _gen_addresses(spec: RequesterSpec, rng: np.random.Generator) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    n = spec.n_requests
    if spec.trace_addr is not None:
        addr = np.asarray(spec.trace_addr[:n], dtype=np.int64)
        wr = np.asarray(spec.trace_is_write[:n], dtype=bool)
        iv = (np.asarray(spec.trace_interval_ps[:n], dtype=np.int64)
              if spec.trace_interval_ps is not None
              else np.full(n, spec.issue_interval_ps, np.int64))
        return addr, wr, iv
    if spec.pattern == "stream":
        addr = np.arange(n, dtype=np.int64) % spec.footprint_lines
    elif spec.pattern == "skewed":
        hot_n = max(int(spec.footprint_lines * spec.hot_frac), 1)
        is_hot = rng.random(n) < spec.hot_ratio
        addr = np.where(
            is_hot,
            rng.integers(0, hot_n, n),
            hot_n + rng.integers(0, max(spec.footprint_lines - hot_n, 1), n),
        ).astype(np.int64)
    else:  # uniform
        addr = rng.integers(0, spec.footprint_lines, n).astype(np.int64)
    wr = rng.random(n) >= spec.read_ratio
    if spec.issue_jitter == "exp":
        iv = np.maximum(rng.exponential(spec.issue_interval_ps, n), 1).astype(np.int64)
    else:
        iv = np.full(n, spec.issue_interval_ps, np.int64)
    return addr, wr, iv


def _interleave(addr: np.ndarray, targets: Sequence[int], policy: str) -> np.ndarray:
    """Address translation unit: map line address -> endpoint (§III-B)."""
    t = np.asarray(targets, dtype=np.int64)
    if policy == "line":          # fine-grained line interleaving
        return t[addr % len(t)]
    if policy == "block":         # contiguous block per endpoint
        return t[(addr * len(t)) // max(int(addr.max()) + 1, 1) % len(t)]
    raise ValueError(f"unknown interleave policy {policy!r}")


def _credit_dllp_plan(graph: FabricGraph, override: link_layer.FlitConfig):
    """Per-channel credit-DLLP emission tables, or None when disabled.

    Returns (enabled mask, window flits, flit payload) — a channel emits
    one `calibration.CREDIT_DLLP_B`-byte hop on its full-duplex pair
    (`FabricGraph.chan_pair`) per ``window`` flits transmitted.  Minimal
    version: half-duplex links (no pair) never emit.
    """
    has_pair = graph.chan_pair >= 0
    if override.active:
        if not override.credit_dllp:
            return None
        size, payload = override.geometry
        mask = has_pair & ~graph.chan_is_service & (size > 0)
        window = np.full(graph.n_channels, max(override.rx_credits, 1))
        pay = np.full(graph.n_channels, max(payload, 1))
    else:
        mask = (np.asarray(graph.chan_credit_dllp, bool) & has_pair
                & (graph.chan_flit_size > 0))
        window = np.maximum(graph.chan_credit_window, 1)
        pay = np.maximum(graph.chan_flit_payload, 1)
    if not mask.any():
        return None
    return mask, window.astype(np.int64), pay.astype(np.int64)


def finish_hops(graph: FabricGraph, flit_cfg: "link_layer.FlitConfig",
                chan, nbytes, direction, row_id, fixed_after, is_payload,
                valid, stream_salt: int = 0, join_id=None, join_wait=None,
                join_arity=None) -> Hops:
    """Final build step shared by every hop-table producer: sample the
    stochastic link-reliability tables (when the graph or override carries
    them) and mirror full-duplex retraining stalls onto the paired channel
    as link-down markers, then assemble the engine `Hops`.

    Deterministic graphs return the arrays untouched (bit-exact layout).
    ``stream_salt`` offsets the per-channel sampling seeds — hop tables
    that will be co-scheduled with another table built from the same graph
    (e.g. coherence rows alongside a background workload) must pass a
    distinct salt, or the two tables replay byte-identical fault
    histories instead of independent draws.

    The optional per-row ``join_id``/``join_wait``/``join_arity`` triple
    (the engine fork/join primitive, all three or none) passes through
    untouched: marker insertion only shifts hop *columns*, never rows.
    """
    joins = (join_id, join_wait, join_arity)
    if any(j is not None for j in joins) and any(j is None for j in joins):
        raise ValueError("join_id/join_wait/join_arity come as a triple")
    extra_wire = retrain_after = None
    rel = _reliability_tables(graph, flit_cfg)
    if rel is not None:
        if stream_salt:
            rel = dict(rel, rel_seed=np.asarray(rel["rel_seed"])
                       + stream_salt)
        extra_wire, retrain_after = link_layer.sample_hop_tables(
            chan, nbytes, valid, **rel)
        (chan, nbytes, direction, row_id, fixed_after, is_payload, valid,
         extra_wire, retrain_after) = link_layer.insert_retrain_markers(
            chan, nbytes, direction, row_id, fixed_after, is_payload,
            valid, extra_wire, retrain_after, graph.chan_pair)
    hops = Hops(
        channel=jnp.asarray(chan), nbytes=jnp.asarray(nbytes),
        direction=jnp.asarray(direction), row=jnp.asarray(row_id),
        fixed_after_ps=jnp.asarray(fixed_after),
        is_payload=jnp.asarray(is_payload), valid=jnp.asarray(valid),
    )
    if extra_wire is not None:
        hops = hops._replace(extra_wire_bytes=jnp.asarray(extra_wire),
                             retrain_after_ps=jnp.asarray(retrain_after))
    if join_id is not None:
        hops = hops._replace(
            join_id=jnp.asarray(join_id, jnp.int32),
            join_wait=jnp.asarray(join_wait, jnp.int32),
            join_arity=jnp.asarray(join_arity, jnp.int32))
    return hops


def marker_column_map(hops: Hops) -> np.ndarray:
    """Map pre-marker hop columns to their post-`finish_hops` positions.

    ``out[j, i]`` is the column the original hop ``(j, i)`` occupies in
    the finished table (the identity when no markers were inserted) — the
    remap consumers of a fixed column layout (e.g.
    `coherence_traffic.bisnp_latencies`) apply to read the schedule back.
    """
    chan = np.asarray(hops.channel)
    mk = link_layer.retrain_marker_mask(
        chan, np.asarray(hops.nbytes), np.asarray(hops.valid),
        None if hops.retrain_after_ps is None
        else np.asarray(hops.retrain_after_ps))
    h_old = chan.shape[1] - (int(mk.sum(axis=1).max()) if mk.any() else 0)
    # stable argsort puts each row's non-marker columns first, in order
    return np.argsort(mk, axis=1, kind="stable")[:, :h_old].astype(np.int64)


def _reliability_tables(graph: FabricGraph, override: link_layer.FlitConfig):
    """Per-channel stochastic-sampling parameters, or None when every
    channel runs the deterministic expected-value model.

    Graph-carried flit configs (`LinkSpec.flit`) supply per-channel tables;
    a workload-level override broadcasts one config over the link channels
    (service channels never sample — they are byte-exact by contract).
    """
    if override.active:
        if not override.stochastic:
            return None
        return link_layer.broadcast_reliability_tables(
            override, graph.n_channels, ~graph.chan_is_service)
    if not np.any(graph.chan_rel_stochastic):
        return None
    return dict(
        stochastic=graph.chan_rel_stochastic,
        err_p=graph.chan_flit_err_p,
        flit_size=graph.chan_flit_size,
        flit_payload=graph.chan_flit_payload,
        retry_window=graph.chan_retry_window,
        retrain_threshold=graph.chan_retrain_threshold,
        retrain_ps=graph.chan_retrain_ps,
        rel_seed=graph.chan_rel_seed,
    )


def build_workload(
    graph: FabricGraph,
    specs: Sequence[RequesterSpec],
    header_bytes: int = 64,
    header_model: str = "esf",
    interleave: str = "line",
    warmup_frac: float = 0.5,
    route_choice: np.ndarray | None = None,
    requester_overhead_ps: int = 22_000,   # Table III: 10 ns process + 12 ns cache
    flit: "link_layer.FlitConfig | str | None" = None,
) -> Workload:
    """Expand requester traffic programs into engine hop tables.

    ``route_choice`` (optional, per-transaction int) selects among equal-cost
    route alternatives — the hook the adaptive routing strategy uses
    (see `core.routing.adaptive_schedule`).

    ``flit`` overrides the link layer of every *link* channel (service
    channels stay byte-exact) without rebuilding the graph: hop bytes are
    emitted logically and the flit tables installed on ``Workload.channels``
    quantize them to wire flits in the engine, while the per-hop FEC decode
    latency is added to ``fixed_after`` here.  ``None`` defers to the flit
    configs already carried by the graph's ``LinkSpec``s (which may also be
    "none" — the seed's byte-exact path, bit-for-bit).  Passing any explicit
    config (even "none") on a graph whose links already carry flit configs
    raises: the graph's lowering is baked into its channel tables, so switch
    modes by rebuilding the topology (`topology.with_flit`).
    """
    assert header_model in HEADER_MODELS
    ep = graph.topo.endpoint
    flit_cfg = link_layer.normalize(flit)
    if flit is not None and np.any(graph.chan_flit_size > 0):
        # an active override would double-count FEC latency, and an explicit
        # "none" cannot un-fold the FEC already baked into chan_fixed_ps —
        # rebuild the topology (with_flit(topo, ...)) instead
        raise ValueError(
            "graph links already carry flit configs (LinkSpec.flit); "
            "rebuild the topology with the desired flit mode (e.g. "
            "with_flit(topo, ...)) instead of overriding at workload level")
    flit_fec_ps = flit_cfg.fec_latency_ps if flit_cfg.active else 0

    rows: list[dict] = []
    tx = 0
    for spec in specs:
        rng = np.random.default_rng(spec.seed + 7919 * spec.node)
        addr, wr, iv = _gen_addresses(spec, rng)
        tgt = _interleave(addr, spec.targets, interleave)
        t = spec.start_ps + np.cumsum(iv) - iv[0]
        for i in range(spec.n_requests):
            rows.append(dict(
                req=spec.node, mem=int(tgt[i]), write=bool(wr[i]),
                addr=int(addr[i]), issue=int(t[i]) + requester_overhead_ps,
                payload=spec.payload_bytes, idx=tx, ntgt=len(spec.targets),
                measured=i >= int(spec.n_requests * warmup_frac),
            ))
            tx += 1

    n = len(rows)
    # resolve routes; longest path defines padding
    paths = []
    alts = np.zeros(n, dtype=np.int64)
    for j, r in enumerate(rows):
        alt = int(route_choice[j]) if route_choice is not None else 0
        alts[j] = alt % graph.n_route_alternatives(r["req"], r["mem"])
        paths.append(graph.route(r["req"], r["mem"], alt=alt))
    max_links = max(len(p) - 1 for p in paths)
    h = 2 * max_links + 1  # request hops + service + response hops

    channel = np.full((n, h), -1, dtype=np.int32)
    nbytes = np.zeros((n, h), dtype=np.int64)
    direction = np.zeros((n, h), dtype=np.int8)
    row_id = np.full((n, h), -1, dtype=np.int32)
    fixed_after = np.zeros((n, h), dtype=np.int64)
    is_payload = np.zeros((n, h), dtype=bool)
    valid = np.zeros((n, h), dtype=bool)

    sw_ps = graph.topo.switching_ps
    for j, (r, path) in enumerate(zip(rows, paths)):
        write = r["write"]
        pay = r["payload"]
        fwd_b, bwd_b, fwd_pay, bwd_pay = packetize(
            header_model, write, pay, header_bytes)
        k = 0
        for u, v in zip(path[:-1], path[1:]):
            c, d = graph.edge_channel(u, v)
            channel[j, k] = c
            nbytes[j, k] = fwd_b
            direction[j, k] = d
            fixed_after[j, k] = (graph.chan_fixed_ps[c] + flit_fec_ps
                                 + (sw_ps if graph.topo.kinds[v] == SWITCH else 0))
            is_payload[j, k] = fwd_pay
            valid[j, k] = True
            k += 1
        # endpoint service hop (banked; row-buffer state carried per bank).
        # The line-interleave across endpoints consumes the low addr bits, so
        # bank/row derive from the per-endpoint line index (addr // n_targets)
        # — otherwise every request to an endpoint would land in one bank.
        ep_line = r["addr"] // max(r["ntgt"], 1)
        bank = ep_line % ep.banks
        c = graph.service_channel(r["mem"], bank)
        channel[j, k] = c
        nbytes[j, k] = pay
        row_id[j, k] = (ep_line // ep.lines_per_row) % (1 << 30)
        fixed_after[j, k] = ep.fixed_ps
        is_payload[j, k] = True
        valid[j, k] = True
        k += 1
        for u, v in zip(path[::-1][:-1], path[::-1][1:]):
            c, d = graph.edge_channel(u, v)
            channel[j, k] = c
            nbytes[j, k] = bwd_b
            direction[j, k] = d
            fixed_after[j, k] = (graph.chan_fixed_ps[c] + flit_fec_ps
                                 + (sw_ps if graph.topo.kinds[v] == SWITCH else 0))
            is_payload[j, k] = bwd_pay
            valid[j, k] = True
            k += 1

    # ---- credit-return DLLP traffic (FlitConfig(credit_dllp=True)) -------
    # every credit-return window of flits transmitted on a full-duplex flit
    # channel emits one DLLP-sized hop on the paired reverse channel, issued
    # with the transaction that crossed the window boundary (build-time
    # approximation) — credit starvation couples to reverse congestion.
    dllp = _credit_dllp_plan(graph, flit_cfg)
    if dllp is not None:
        from .calibration import CREDIT_DLLP_B

        d_mask, d_win, d_pay = dllp
        cum = np.zeros(graph.n_channels, np.int64)
        d_rows: list[tuple[int, int]] = []   # (issue_ps, reverse channel)
        # accumulate in issue-time order, not build (requester-major) order,
        # so each window's DLLP is stamped with the transaction that
        # actually crossed it when several requesters share a channel
        order = np.argsort([r["issue"] for r in rows], kind="stable")
        for j in order:
            for k in range(h):
                c = channel[j, k]
                if not valid[j, k] or c < 0 or not d_mask[c] \
                        or nbytes[j, k] <= 0:
                    continue
                cum[c] += -(-nbytes[j, k] // d_pay[c])
                while cum[c] >= d_win[c]:
                    cum[c] -= d_win[c]
                    d_rows.append((rows[j]["issue"], int(graph.chan_pair[c])))
        if d_rows:
            m = len(d_rows)
            channel = np.vstack([channel, np.full((m, h), -1, np.int32)])
            nbytes = np.vstack([nbytes, np.zeros((m, h), np.int64)])
            direction = np.vstack([direction, np.zeros((m, h), np.int8)])
            row_id = np.vstack([row_id, np.full((m, h), -1, np.int32)])
            fixed_after = np.vstack([fixed_after, np.zeros((m, h), np.int64)])
            is_payload = np.vstack([is_payload, np.zeros((m, h), bool)])
            valid = np.vstack([valid, np.zeros((m, h), bool)])
            for i, (iss, rc) in enumerate(d_rows):
                channel[n + i, 0] = rc
                nbytes[n + i, 0] = CREDIT_DLLP_B
                # same per-hop fixed cost as every other hop on this path
                # (flit_fec_ps is nonzero only on the override path; the
                # graph-carried path bakes FEC into chan_fixed_ps)
                fixed_after[n + i, 0] = graph.chan_fixed_ps[rc] + flit_fec_ps
                valid[n + i, 0] = True
                rows.append(dict(req=-1, mem=-1, write=False, addr=0,
                                 issue=iss, payload=0, idx=n + i, ntgt=1,
                                 measured=False))
                paths.append([-1, -1])
            alts = np.concatenate([alts, np.zeros(m, np.int64)])
            n += m

    # stochastic link reliability: sample the per-hop replay/retraining
    # tables from the seeded per-channel streams (build time, like issue
    # jitter, so sweeps can stack the sampled tables and vmap) and mirror
    # full-duplex retraining stalls onto the paired channel.  The
    # expected-value mode leaves Hops in the PR-1 layout untouched.
    hops = finish_hops(graph, flit_cfg, channel, nbytes, direction, row_id,
                       fixed_after, is_payload, valid)
    channels = make_channels(graph, ep.row_hit_extra_ps, ep.row_miss_extra_ps)
    if flit_cfg.active:
        channels = link_layer.apply_flit(
            channels, ~graph.chan_is_service, flit_cfg)
    return Workload(
        hops=hops,
        channels=channels,
        issue_ps=jnp.asarray(np.array([r["issue"] for r in rows], np.int64)),
        payload_bytes=jnp.asarray(np.array([r["payload"] for r in rows], np.int64)),
        measured=jnp.asarray(np.array([r["measured"] for r in rows], bool)),
        requester=np.array([r["req"] for r in rows], np.int64),
        target=np.array([r["mem"] for r in rows], np.int64),
        is_write=np.array([r["write"] for r in rows], bool),
        n_link_hops=np.array([len(p) - 1 for p in paths], np.int64),
        route_alt=alts,
    )
