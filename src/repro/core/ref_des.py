"""Pure-Python event-driven reference simulator (oracle for `core.engine`).

This is, structurally, the C++ ESF: a classic discrete-event loop over channel
queues with FCFS-by-arrival arbitration.  It exists solely to prove that the
tensorized fixpoint engine computes the *exact* same integer schedule; the
test suite runs both on randomized topologies/workloads and asserts equality.

Semantics (must match `core.engine.simulate` bit-for-bit):
  * per channel, items are served in order of (arrival time, flat item index);
  * service time = bytes * 1e12 // (bw_MBps * 1e6)  [integer picoseconds];
  * half-duplex: when the served item's direction differs from the previous
    item's on that channel, the channel frees `turnaround_ps` later;
  * row-managed channels (DRAM banks) add row_hit/row_miss extra occupancy
    depending on the previously accessed row (cold access counts as miss);
  * flit-mode channels (`core.link_layer`) serialize whole flits —
    ``ceil(bytes / flit_payload) * flit_size`` wire bytes — stretched by the
    expected Go-Back-N CRC-replay factor ``(1 + replay_ppm/1e6)``, floored;
  * stochastic reliability (per-hop sampled tables in `Hops`): the hop's
    sampled replay wire bytes add to its flit-quantized wire bytes, and a
    hop with ``retrain_after_ps > 0`` puts its channel into a link-down
    interval at departure — subsequent grants on that channel start no
    earlier than ``down_until`` (the engine's scan-carry state, mirrored
    here as per-channel state so equality stays bit-exact per seed);
  * a *link-down marker* — a valid zero-byte hop with ``retrain_after_ps
    > 0`` (`link_layer.insert_retrain_markers`, the full-duplex partner
    of a retraining channel) — takes no service and occupies nothing: it
    contributes a down interval (its arrival + retrain) that delays
    exactly the channel's items *after it* in the global FCFS key order
    (arrival, flat index) — the engine's segmented-scan semantics.  It is
    processed punctually at its arrival (never queued), so its own
    transaction chain continues undelayed;
  * fork/join (per-row ``join_id`` / ``join_wait`` / ``join_arity`` in
    `Hops`): a waiter row is held back until every contributor of its
    group has completed, then issues at ``max(issue, slowest contributor
    completion)`` — max-of-arrivals join semantics.  The release is
    event-driven: the group's ``join_arity``-th completion triggers it,
    and ``join_arity`` is validated against the actual contributor count
    (the lowering contract).  A release lands at exactly the completing
    row's timestamp, so all arrivals of a timestamp — including cascaded
    releases — are drained before any channel serves (see the batch loop);
  * arrival at hop h+1 = departure at hop h + fixed_after[h].
"""

from __future__ import annotations

import heapq

import numpy as np

from .engine import Channels, Hops, Schedule


def ref_schedule(ref: dict) -> Schedule:
    """Wrap a `simulate_ref` result dict as a `Schedule` so every
    post-schedule reduction (`channel_stats`, `core.telemetry`) runs
    unchanged against the oracle — the metric-equality cross-check."""
    import jax.numpy as jnp

    return Schedule(
        arrive=jnp.asarray(ref["arrive"]),
        start=jnp.asarray(ref["start"]),
        depart=jnp.asarray(ref["depart"]),
        complete=jnp.asarray(ref["complete"]),
        rounds=jnp.int32(0),
        converged=jnp.bool_(True),
        residual_ps=jnp.int64(0),
    )


def simulate_ref(hops: Hops, channels: Channels, issue_ps,
                 carry=None) -> dict:
    """``carry`` (`engine.StreamCarry`, streaming windows) seeds the
    per-channel ``free_at`` state — busy-until, last direction, last DRAM
    row, down-until — and the per-group join maxes of contributors that
    retired in earlier windows, mirroring the engine's carry-seeded scan so
    windowed oracle fallbacks stay bit-exact against the monolithic run."""
    chan = np.asarray(hops.channel)
    nbytes = np.asarray(hops.nbytes)
    direction = np.asarray(hops.direction)
    row = np.asarray(hops.row)
    fixed = np.asarray(hops.fixed_after_ps)
    valid = np.asarray(hops.valid)
    issue = np.asarray(issue_ps)
    bw = np.asarray(channels.bw_MBps)
    turn = np.asarray(channels.turnaround_ps)
    rhit = np.asarray(channels.row_hit_ps)
    rmiss = np.asarray(channels.row_miss_ps)
    fsize = (np.asarray(channels.flit_size)
             if channels.flit_size is not None else None)
    fpay = (np.asarray(channels.flit_payload)
            if channels.flit_payload is not None else None)
    rppm = (np.asarray(channels.replay_ppm)
            if channels.replay_ppm is not None else None)
    extra_wire = (np.asarray(hops.extra_wire_bytes)
                  if hops.extra_wire_bytes is not None else None)
    retrain = (np.asarray(hops.retrain_after_ps)
               if hops.retrain_after_ps is not None else None)
    join_id = (np.asarray(hops.join_id)
               if hops.join_id is not None else None)
    join_wait = (np.asarray(hops.join_wait)
                 if hops.join_wait is not None else None)
    join_arity = (np.asarray(hops.join_arity)
                  if hops.join_arity is not None else None)

    def ser_time(p: int, hop: int, c: int) -> int:
        nb = int(nbytes[p, hop])
        if fsize is None or fsize[c] == 0:
            return (nb * 1_000_000) // int(bw[c])
        wire = -(-nb // max(int(fpay[c]), 1)) * int(fsize[c])
        if extra_wire is not None:
            wire += int(extra_wire[p, hop])
        fser = (wire * 1_000_000) // int(bw[c])
        if rppm is not None:
            fser = (fser * (1_000_000 + int(rppm[c]))) // 1_000_000
        return fser

    n, h = chan.shape
    arrive = np.zeros((n, h + 1), dtype=np.int64)
    start = np.zeros((n, h), dtype=np.int64)
    depart = np.zeros((n, h), dtype=np.int64)

    # channel state
    free_at = {}      # channel -> (time, last_dir, last_row, down_until)
    queues = {}       # channel -> heap of (arrival, flat_idx, pkt, hop)
    markers = {}      # channel -> list of ((arrival, flat_idx), down_end)
    jseed = None
    if carry is not None:
        c_dep = np.asarray(carry.depart_ps)
        c_dir = np.asarray(carry.last_dir)
        c_row = np.asarray(carry.last_row)
        c_down = np.asarray(carry.down_until_ps)
        for c in range(c_dep.shape[0]):
            free_at[c] = (int(c_dep[c]), int(c_dir[c]), int(c_row[c]),
                          int(c_down[c]))
        if carry.join_seed_ps is not None:
            jseed = np.asarray(carry.join_seed_ps)

    # fork/join state: contributor counts, running (count, max-completion)
    # per group, and the waiter rows each group releases on completion
    if join_id is not None:
        if max(int(join_id.max()), int(join_wait.max())) >= n:
            raise ValueError(
                f"join group ids must be < n_rows ({n}): the engine "
                "resolves group maxes with a row-indexed scatter")
        n_contrib = np.zeros(n, np.int64)
        for p in range(n):
            if join_id[p] >= 0:
                n_contrib[join_id[p]] += 1
        waiters = {}
        for p in range(n):
            g = int(join_wait[p])
            if g < 0:
                continue
            if int(join_arity[p]) != int(n_contrib[g]):
                raise ValueError(
                    f"row {p}: join_arity {int(join_arity[p])} != "
                    f"{int(n_contrib[g])} contributors of group {g}")
            if n_contrib[g] > 0:      # empty groups never gate (engine: max
                waiters.setdefault(g, []).append(p)   # over nothing == 0)
        jdone = {}                    # group -> [completions seen, max comp]
        if jseed is not None:
            # carried group maxes: completions of contributors that retired
            # in earlier windows count toward the release max (their arity
            # share was already subtracted by the streaming driver)
            for g in range(n):
                if jseed[g] > 0:
                    jdone[g] = (0, int(jseed[g]))
        completed = np.zeros(n, bool)
        released = np.zeros(n, bool)

    # event heap: (time, seq, kind, payload)  kind 0=arrival at hop, 1=channel free
    ev = []
    seq = 0
    for p in range(n):
        if join_id is not None and int(join_wait[p]) >= 0:
            g = int(join_wait[p])
            if n_contrib[g] > 0:
                continue              # held until the group's join releases
            if jseed is not None and jseed[g] > 0:
                # every contributor already retired: the gate is the
                # carried max, resolvable at push time
                arrive[p, 0] = max(int(issue[p]), int(jseed[g]))
                heapq.heappush(ev, (int(arrive[p, 0]), seq, 0, (p, 0)))
                seq += 1
                continue
        arrive[p, 0] = issue[p]
        heapq.heappush(ev, (int(issue[p]), seq, 0, (p, 0)))
        seq += 1

    def try_serve(c, now):
        nonlocal seq
        q = queues.get(c)
        if not q:
            return
        t_free, last_dir, last_row, down_until = free_at.get(c, (0, -1, -2, 0))
        if t_free > now:
            return
        # FCFS by (arrival, flat index); only items that have arrived
        arr, fi, p, hop = q[0]
        if arr > now:
            heapq.heappush(ev, (int(arr), seq, 1, c)); seq += 1
            return
        heapq.heappop(q)
        gap = int(turn[c]) if (last_dir != -1 and direction[p, hop] != last_dir) else 0
        # a retraining channel grants nothing before down_until (the gap is
        # NOT re-paid on top of it: mirror of the engine's max(floor, down));
        # link-down markers apply only to items after them in FCFS key order.
        # A grant never starts before ``now`` (st >= now by construction),
        # so markers whose down interval already ended are dead — prune
        # them to keep the scan short on retrain-heavy runs.
        down = down_until
        ml = markers.get(c)
        if ml:
            ml[:] = [m for m in ml if m[1] > now]
            for key, dend in ml:
                if key < (arr, p * h + hop):
                    down = max(down, dend)
        st = max(arr, t_free + gap, down)
        ser = ser_time(p, hop, c)
        extra = 0
        r = int(row[p, hop])
        if r >= 0:
            extra = int(rhit[c]) if r == last_row else int(rmiss[c])
        dp = st + ser + extra
        start[p, hop] = st
        depart[p, hop] = dp
        if retrain is not None and retrain[p, hop] > 0:
            down_until = max(down_until, dp + int(retrain[p, hop]))
        free_at[c] = (dp, int(direction[p, hop]),
                      r if r >= 0 else last_row, down_until)
        arrive[p, hop + 1] = dp + int(fixed[p, hop])
        heapq.heappush(ev, (int(arrive[p, hop + 1]), seq, 0, (p, hop + 1))); seq += 1
        heapq.heappush(ev, (dp, seq, 1, c)); seq += 1

    def complete_row(p):
        """Row p reached its completion column: feed its join group and
        release the group's waiters once the arity-th contributor lands."""
        nonlocal seq
        if join_id is None or completed[p]:
            return
        completed[p] = True
        g = int(join_id[p])
        if g < 0:
            return
        cnt, gmax = jdone.get(g, (0, 0))
        cnt, gmax = cnt + 1, max(gmax, int(arrive[p, h]))
        jdone[g] = (cnt, gmax)
        if cnt < n_contrib[g]:
            return
        for w in waiters.get(g, ()):
            if released[w]:
                continue
            released[w] = True
            arrive[w, 0] = max(int(issue[w]), gmax)
            heapq.heappush(ev, (int(arrive[w, 0]), seq, 0, (w, 0)))
            seq += 1

    # Events are processed in *timestamp batches*: every event at the
    # current time is drained — arrivals enqueued, link-down markers
    # registered, join releases cascaded — before any channel serves.
    # Within one timestamp the serve order is then fully determined by the
    # queue key (arrival, flat index), independent of event delivery
    # order — exactly the engine's global sort order, which is what makes
    # equality bit-exact even when many arrivals tie (regular traffic like
    # the coherence lowering produces dense ties).  Arrivals are processed
    # one pop at a time (not pre-collected) because a join release lands at
    # exactly the completing row's timestamp: the released row's first hop
    # must enter its channel queue before this timestamp's serves, or a
    # same-arrival larger-flat-index item would overtake it.
    while ev:
        now = ev[0][0]
        serves = []
        while ev and ev[0][0] == now:
            _, _, kind, payload = heapq.heappop(ev)
            if kind != 0:
                serves.append(payload)
                continue
            p, hop = payload
            # skip padded hops and zero-byte packets: the latter ride a side
            # channel (command path) — instant pass-through, no bus occupancy,
            # no direction turn (mirror of the engine semantics).  A link-down
            # marker (valid, zero-byte, retrain > 0) is also a pass-through,
            # but registers its down interval for the channel's later-keyed
            # items on the way past.
            while hop < h and (not valid[p, hop] or nbytes[p, hop] == 0):
                if (valid[p, hop] and retrain is not None
                        and retrain[p, hop] > 0):
                    a = int(arrive[p, hop])
                    markers.setdefault(int(chan[p, hop]), []).append(
                        ((a, p * h + hop), a + int(retrain[p, hop])))
                start[p, hop] = arrive[p, hop]
                depart[p, hop] = arrive[p, hop]
                arrive[p, hop + 1] = arrive[p, hop] + (
                    int(fixed[p, hop]) if valid[p, hop] else 0
                )
                hop += 1
            if hop >= h:
                complete_row(p)
                continue
            c = int(chan[p, hop])
            queues.setdefault(c, [])
            heapq.heappush(queues[c],
                           (int(arrive[p, hop]), p * h + hop, p, hop))
            serves.append(c)
        for c in serves:
            try_serve(c, now)

    if join_id is not None:
        stuck = [p for p in range(n)
                 if int(join_wait[p]) >= 0 and n_contrib[int(join_wait[p])] > 0
                 and not released[p]]
        if stuck:
            raise RuntimeError(
                f"join deadlock: rows {stuck[:8]} were never released — "
                "the join groups do not form a DAG")

    return {
        "arrive": arrive,
        "start": start,
        "depart": depart,
        "complete": arrive[:, h],
    }
