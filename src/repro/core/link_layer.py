"""PCIe 6.0 FLIT link layer: flit packing, FEC/CRC retry, credit flow control.

The seed modeled the whole PCIe link layer as one bandwidth constant.  This
module makes it a first-class subsystem, following Das Sharma's CXL
interconnect overview (arXiv 2306.11227):

  * **Flit packing** — PCIe 6.0 / CXL 3.x links carry fixed 256 B flits:
    236 B of TLP payload plus 6 B DLLP, 8 B CRC and 6 B FEC check symbols.
    PCIe 5 / CXL 2.0 links in CXL's 68 B flit mode carry 64 B slots with a
    2 B CRC and 2 B protocol-ID header.  A logical packet of ``n`` bytes
    therefore occupies ``ceil(n / payload) * size`` wire bytes.

  * **Lightweight FEC + CRC retry** — the 3-way interleaved FEC of PCIe 6.0
    adds a small fixed decode latency per hop (~2 ns).  Flits that fail CRC
    after FEC are replayed link-level with Go-Back-N: the failed flit and
    every flit in flight behind it retransmit.  Under a bit error rate
    ``ber`` the per-flit error probability is ``1 - (1 - ber)^bits`` and the
    expected transmissions per flit is ``(1 - p + p*W) / (1 - p)`` for a
    replay window of ``W`` flits.  The *expected* overhead is folded into
    serialization deterministically (as integer parts-per-million), which
    keeps the engine exact and bit-reproducible and makes goodput a
    monotone function of BER — what the sensitivity sweeps need.

  * **Credit-based flow control** — the receiver grants ``rx_credits`` flit
    buffers; the sender stalls when the in-flight window exceeds them.  A
    credit loop of round-trip ``credit_rtt_ps`` therefore caps sustained
    throughput at ``credits * flit_size / rtt`` regardless of raw lane
    speed — the classic bandwidth-delay-product bound, applied as a
    per-channel effective-bandwidth derate.

Lowering contract: everything a flit link does to traffic is expressed as
three per-channel integer tables (``flit_size``, ``flit_payload``,
``replay_ppm``) consumed by ``core.engine`` / ``core.ref_des`` during
serialization, plus an effective bandwidth and a fixed per-hop latency add.
``flit_mode="none"`` produces empty tables and reproduces the seed's
byte-exact schedules bit-for-bit.  Because the tables are plain arrays in
``engine.Channels``, whole BER x bandwidth x flit-mode sweeps ``vmap`` in
one jit (see ``kernels.flit_pack`` for the analytic-efficiency companion).

  * **Stochastic reliability** (``FlitConfig(reliability="stochastic")``) —
    the expected-value replay model above is exact in the mean but blind to
    tails: every packet pays the same stretch, so p99 == p50 scaled.  The
    stochastic mode instead samples, per flit and per channel from a seeded
    stream at build time (like issue jitter), the actual Go-Back-N failure
    counts — landing as per-hop ``extra_wire_bytes`` — and **retraining
    stalls**: a flit failing ``retrain_threshold`` times consecutively drops
    the link into a microsecond-scale Recovery interval (per-hop
    ``retrain_after_ps``), during which the channel grants nothing — the
    per-channel ``down_until`` state the engine carries in its scan (its
    first stateful extension beyond FCFS).  Both tables ride in ``Hops``,
    not ``Channels``, so seeded BER sweeps still ``vmap`` (stack the sampled
    tables); at BER 0 the samples are all zero and the schedule equals the
    deterministic path exactly, and ``core.ref_des`` mirrors both effects so
    engine == oracle stays bit-exact for any fixed seed.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from .calibration import (CRC_REPLAY_RTT_PS, FEC_LATENCY_PS, FLIT68_PAYLOAD_B,
                          FLIT68_SIZE_B, FLIT256_PAYLOAD_B, FLIT256_SIZE_B,
                          LINK_RETRAIN_PS)

PPM = 1_000_000
RELIABILITY_MODES = ("expected", "stochastic")
# Ceiling on the expected Go-Back-N replay overhead: 1000x extra
# transmissions per flit.  The expected-value model diverges as the flit
# error probability approaches 1, but a real link retrains long before
# that (see the lane-margining ROADMAP item); the clamp also keeps
# replay_ppm within the flit_pack kernel's int32 tables, and the engine's
# decomposed replay stretch (engine.wire_ser_ps) stays int64-exact with
# ppm at this clamp for serializations up to ~9.2e15 ps.
MAX_REPLAY_PPM = 1000 * PPM

# mode -> (flit size on the wire, TLP payload bytes per flit)
FLIT_GEOMETRY: dict[str, tuple[int, int]] = {
    "none": (0, 0),
    "flit68": (FLIT68_SIZE_B, FLIT68_PAYLOAD_B),      # PCIe 5 / CXL 2.0
    "flit256": (FLIT256_SIZE_B, FLIT256_PAYLOAD_B),   # PCIe 6 / CXL 3.x
}
FLIT_MODES = tuple(FLIT_GEOMETRY)


@dataclass(frozen=True)
class FlitConfig:
    """Link-layer configuration of one physical link (both directions).

    mode            "none" (byte-exact seed semantics) | "flit68" | "flit256".
    ber             residual bit error rate the CRC sees — i.e. *after* the
                    lightweight FEC has corrected what it can (FEC escapes).
                    Datasheet raw lane BERs (~1e-6 for PCIe 6.0) must be
                    mapped through the FEC correction model first; residual
                    rates are typically orders of magnitude lower.
    rx_credits      receiver buffer, in flits, granted to the sender.  The
                    default (256) covers the bandwidth-delay product of any
                    realistic lane rate at the default credit RTT, so credit
                    flow control only binds when a study shrinks it.
    credit_rtt_ps   credit-return loop latency (propagation + DLLP processing).
    retry_window    Go-Back-N replay window, in flits in flight.
    fec_ps          per-hop FEC decode latency; None = mode default
                    (lightweight FEC exists only in 256 B flit mode).
    reliability     "expected" — CRC replay folded into serialization as the
                    deterministic expected-value stretch (``replay_ppm``; the
                    PR-1 model, exact and monotone, what sweeps want);
                    "stochastic" — seeded per-flit Bernoulli replay sampled
                    at build time (`sample_replays`): per-packet replay
                    *counts* instead of a mean goodput scale, so tail
                    latency sees bursts, plus retraining stalls below.
    rel_seed        seed of the stochastic sampling stream.  Each channel
                    derives an independent substream from (rel_seed, channel
                    id), so a fixed seed gives one reproducible fault
                    history for the whole fabric.
    retrain_threshold  consecutive failed transmissions of one flit that
                    force link retraining (0 disables).  Only meaningful in
                    stochastic mode — the expected-value model clamps at
                    MAX_REPLAY_PPM instead (see its comment).
    retrain_ps      link-down interval per retraining event; None = the
                    calibrated microsecond-scale `LINK_RETRAIN_PS`.  While
                    down, the channel grants nothing (per-channel
                    ``down_until`` state carried in the engine scan); the
                    paired reverse direction of a full-duplex link goes
                    down with it (retraining re-equalizes the physical
                    link), mirrored onto the paired channel as zero-byte
                    link-down marker hops at build time.
    credit_dllp     model credit-return DLLPs as real traffic: every
                    ``rx_credits`` flits transmitted on a full-duplex flit
                    channel emit one ``CREDIT_DLLP_B``-byte hop on the
                    paired reverse channel (a real flit on the wire), so
                    credit starvation couples to reverse-direction
                    congestion.  Off (default), credits stay a pure
                    bandwidth cap — the byte-exact seed semantics.
    """

    mode: str = "none"
    ber: float = 0.0
    rx_credits: int = 256
    credit_rtt_ps: int = CRC_REPLAY_RTT_PS
    retry_window: int = 16
    fec_ps: int | None = None
    reliability: str = "expected"
    rel_seed: int = 0
    retrain_threshold: int = 0
    retrain_ps: int | None = None
    credit_dllp: bool = False

    def __post_init__(self):
        if self.mode not in FLIT_GEOMETRY:
            raise ValueError(f"unknown flit mode {self.mode!r}; "
                             f"expected one of {FLIT_MODES}")
        if not 0.0 <= self.ber < 1.0:
            raise ValueError(f"ber {self.ber} out of [0, 1)")
        if self.rx_credits < 1:
            raise ValueError("rx_credits must be >= 1")
        if self.reliability not in RELIABILITY_MODES:
            raise ValueError(f"unknown reliability {self.reliability!r}; "
                             f"expected one of {RELIABILITY_MODES}")
        if self.retrain_threshold < 0:
            raise ValueError("retrain_threshold must be >= 0")
        if self.retrain_ps is not None and self.retrain_ps < 0:
            raise ValueError("retrain_ps must be >= 0")

    @property
    def active(self) -> bool:
        return self.mode != "none"

    @property
    def geometry(self) -> tuple[int, int]:
        return FLIT_GEOMETRY[self.mode]

    @property
    def fec_latency_ps(self) -> int:
        if self.fec_ps is not None:
            return self.fec_ps
        return FEC_LATENCY_PS if self.mode == "flit256" else 0

    @property
    def stochastic(self) -> bool:
        return self.active and self.reliability == "stochastic"

    @property
    def retrain_down_ps(self) -> int:
        return LINK_RETRAIN_PS if self.retrain_ps is None else self.retrain_ps


def normalize(flit: "FlitConfig | str | None") -> FlitConfig:
    """Accept a FlitConfig, a mode string, or None (= byte-exact)."""
    if flit is None:
        return FlitConfig("none")
    if isinstance(flit, str):
        return FlitConfig(flit)
    return flit


# ---------------------------------------------------------------------------
# Flit packing
# ---------------------------------------------------------------------------

def wire_bytes(nbytes, mode: str):
    """Wire bytes of an ``nbytes`` logical packet: whole flits, incl. CRC/FEC.

    Accepts scalars or numpy arrays.  ``mode="none"`` is the identity.
    """
    size, payload = FLIT_GEOMETRY[mode]
    if size == 0:
        return nbytes
    return -(-np.asarray(nbytes) // payload) * size if np.ndim(nbytes) \
        else -(-nbytes // payload) * size


def flit_efficiency(mode: str) -> float:
    """Analytic zero-BER payload fraction of a fully packed flit stream."""
    size, payload = FLIT_GEOMETRY[mode]
    return 1.0 if size == 0 else payload / size


# ---------------------------------------------------------------------------
# FEC/CRC retry (Go-Back-N replay, expected-value model)
# ---------------------------------------------------------------------------

def flit_error_prob(ber: float, mode: str) -> float:
    """Probability one flit still fails CRC: 1 - (1-ber)^bits over the flit.

    ``ber`` is the residual post-FEC rate (see FlitConfig), so the geometry
    term is the whole flit (CRC covers every wire byte).
    """
    size, _ = FLIT_GEOMETRY[mode]
    if size == 0 or ber <= 0.0:
        return 0.0
    return -math.expm1(8 * size * math.log1p(-ber))


def replay_overhead_ppm(ber: float, mode: str, retry_window: int = 16) -> int:
    """Expected *extra* transmissions per flit, in parts-per-million.

    Go-Back-N with window W and flit error probability p retransmits, in
    expectation, ``E - 1 = p * W / (1 - p)`` extra flits per delivered flit
    (E = (1 - p + p*W)/(1 - p)).  Returned as an integer ppm so the engine
    can fold it into serialization without leaving int64 arithmetic; the
    divergence as p -> 1 is clamped at ``MAX_REPLAY_PPM`` (a link that bad
    retrains rather than replaying forever).
    """
    p = flit_error_prob(ber, mode)
    if p <= 0.0:
        return 0
    if p >= 1.0:
        return MAX_REPLAY_PPM
    return min(int(round(p * max(retry_window, 1) / (1.0 - p) * PPM)),
               MAX_REPLAY_PPM)


def goodput_efficiency(mode: str, ber: float = 0.0,
                       retry_window: int = 16) -> float:
    """Payload fraction of wire time including expected CRC replays."""
    ppm = replay_overhead_ppm(ber, mode, retry_window)
    return flit_efficiency(mode) / (1.0 + ppm / PPM)


# ---------------------------------------------------------------------------
# Stochastic replay + retraining (seeded per-flit sampling, build time)
# ---------------------------------------------------------------------------

def _clamp_flit_p(p: float, retry_window: int) -> float:
    """The stochastic twin of the MAX_REPLAY_PPM divergence guard: cap the
    per-flit failure probability where the expected Go-Back-N extras per
    flit (W * p / (1 - p)) reach the expected-value model's ceiling — a
    link that bad retrains rather than replaying forever.  Also keeps the
    geometric success probability strictly positive once `flit_error_prob`
    rounds to 1.0."""
    max_fails = MAX_REPLAY_PPM / PPM / max(retry_window, 1)
    return min(p, max_fails / (1.0 + max_fails))


def retrain_event_prob(ber: float, mode: str, retrain_threshold: int,
                       retry_window: int = 16) -> float:
    """Probability one flit fails CRC ``retrain_threshold`` times in a row.

    Transmissions of one flit are independent Bernoulli(p) failures, so a
    run of R consecutive failures — the condition that drops the link into
    retraining — has probability p**R per flit, with p clamped exactly as
    `sample_replays` clamps it so the analytic helper matches the sampler
    in the high-BER regime.
    """
    if retrain_threshold <= 0:
        return 0.0
    p = _clamp_flit_p(flit_error_prob(ber, mode), retry_window)
    return p ** retrain_threshold


def sample_replays(n_flits: np.ndarray, p: float, retry_window: int,
                   retrain_threshold: int,
                   rng: np.random.Generator) -> tuple[np.ndarray, np.ndarray]:
    """Sample one channel's per-hop Go-Back-N replay flits + retrain events.

    ``n_flits[i]`` is the flit count of hop ``i`` on this channel, in flat
    hop order (the deterministic sampling order for a fixed seed).  Per
    flit, the failed transmissions before CRC success are geometric with
    failure probability ``p``; each failure replays the ``retry_window``
    flits in flight behind it, so a hop of ``n`` flits carries
    ``W * NegBinomial(n, 1 - p)`` extra flit transmissions — whose mean,
    ``n * W * p / (1 - p)``, is exactly the expected-value model's
    ``replay_ppm`` stretch.

    Retraining events (a flit failing ``retrain_threshold`` times
    consecutively) are *coupled to the sampled replay total*: iid
    Geometric(p) per-flit failure counts are exactly (NegBinomial total,
    uniform composition over flits), so conditional on the hop's total
    failures ``f`` the probability any one flit reached ``R`` failures is
    ``prod_{j<R} (f-j)/(n+f-1-j)`` — events are drawn
    ``Binomial(n, that)``, clamped to the hard bound ``f // R``.  The
    unclamped marginal event rate is exactly ``n * p**R`` (the clamp only
    removes the rare Binomial overshoots past what the sampled failures
    can explain, shaving it slightly below), a hop can no longer retrain
    without having sampled the failures that caused it (the independence
    approximation this replaces allowed that), and the replay draw itself
    is byte-identical to before, so the stream is unchanged for any
    ``retrain_threshold`` sharing a seed.

    Returns ``(extra_flits, retrain_events)`` int64 arrays shaped like
    ``n_flits``.
    """
    n_flits = np.asarray(n_flits, dtype=np.int64)
    extra = np.zeros_like(n_flits)
    events = np.zeros_like(n_flits)
    if n_flits.size == 0 or p <= 0.0:
        return extra, events
    w = max(retry_window, 1)
    p = _clamp_flit_p(p, w)
    pos = n_flits > 0
    extra[pos] = rng.negative_binomial(n_flits[pos], 1.0 - p) * w
    if retrain_threshold > 0:
        n = n_flits[pos]
        f = extra[pos] // w                      # sampled failures per hop
        r = retrain_threshold
        q = np.ones(n.shape, dtype=np.float64)
        for j in range(r):
            q *= np.clip(f - j, 0, None) / np.maximum(n + f - 1 - j, 1)
        ev = rng.binomial(n, q)
        events[pos] = np.minimum(ev, f // r)
    return extra, events


def broadcast_reliability_tables(cfg: "FlitConfig", n_channels: int,
                                 link_mask: np.ndarray) -> dict:
    """One stochastic config broadcast into per-channel sampling tables.

    The kwargs of `sample_hop_tables` for a fabric whose link channels
    (``link_mask`` true) all run ``cfg`` — the single definition shared by
    the workload-level override path (`devices.build_workload(flit=...)`)
    and any caller resampling tables off an existing hop layout (e.g. the
    vmapped BER sweeps in ``bench_link_reliability``).
    """
    size, payload = cfg.geometry
    return dict(
        stochastic=np.asarray(link_mask, bool),
        err_p=np.full(n_channels, flit_error_prob(cfg.ber, cfg.mode)),
        flit_size=np.full(n_channels, size, np.int64),
        flit_payload=np.full(n_channels, payload, np.int64),
        retry_window=np.full(n_channels, cfg.retry_window, np.int64),
        retrain_threshold=np.full(n_channels, cfg.retrain_threshold,
                                  np.int64),
        retrain_ps=np.full(n_channels, cfg.retrain_down_ps, np.int64),
        rel_seed=np.full(n_channels, cfg.rel_seed, np.int64),
    )


def channel_rng(rel_seed: int, channel: int) -> np.random.Generator:
    """The per-channel sampling stream: independent substreams per channel
    id, reproducible for a fixed ``rel_seed`` regardless of which other
    channels exist or sample first."""
    return np.random.default_rng(
        np.random.SeedSequence(entropy=int(rel_seed),
                               spawn_key=(int(channel),)))


def sample_hop_tables(chan: np.ndarray, nbytes: np.ndarray, valid: np.ndarray,
                      *, stochastic: np.ndarray, err_p: np.ndarray,
                      flit_size: np.ndarray, flit_payload: np.ndarray,
                      retry_window: np.ndarray, retrain_threshold: np.ndarray,
                      retrain_ps: np.ndarray,
                      rel_seed: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Sample the per-hop stochastic tables for a whole hop matrix.

    All per-channel arrays come from the `FabricGraph` lowering (or a
    broadcast workload-level override).  Returns ``(extra_wire_bytes,
    retrain_after_ps)`` int64 arrays of ``chan``'s shape: sampled replay
    wire bytes added to the hop's serialization, and the sampled link-down
    interval the channel enters when the hop departs (events x per-event
    retraining stall).
    """
    chan = np.asarray(chan)
    nbytes = np.asarray(nbytes, dtype=np.int64)
    valid = np.asarray(valid, dtype=bool)
    extra_wire = np.zeros(chan.shape, dtype=np.int64)
    retrain_after = np.zeros(chan.shape, dtype=np.int64)
    for c in np.where(np.asarray(stochastic, bool))[0]:
        payload = max(int(flit_payload[c]), 1)
        mask = (chan == c) & valid & (nbytes > 0)
        if not mask.any():
            continue
        n_flits = -(-nbytes[mask] // payload)
        extra, events = sample_replays(
            n_flits, float(err_p[c]), int(retry_window[c]),
            int(retrain_threshold[c]), channel_rng(int(rel_seed[c]), int(c)))
        extra_wire[mask] = extra * int(flit_size[c])
        retrain_after[mask] = events * int(retrain_ps[c])
    return extra_wire, retrain_after


# ---------------------------------------------------------------------------
# Full-duplex retraining mirror (link-down marker hops)
# ---------------------------------------------------------------------------
# Retraining re-equalizes the physical link, so BOTH directions of a
# full-duplex link stall together.  The engine's per-channel down-until
# state is segment-local (one channel per scan segment), so the reverse
# direction's stall is expressed as data: a zero-byte *link-down marker*
# hop on the paired channel, inserted right after the triggering hop.  A
# marker occupies nothing and turns nothing — it only pushes the paired
# channel's ``down_until`` to (its arrival + retrain_after_ps).  Markers
# are identified structurally: ``valid & nbytes == 0 & retrain_after_ps
# > 0`` (see `engine._one_round` / `ref_des.simulate_ref`).

def retrain_marker_mask(channel, nbytes, valid, retrain_after) -> np.ndarray:
    """Boolean mask of link-down marker hops in a hop matrix."""
    if retrain_after is None:
        return np.zeros(np.asarray(channel).shape, dtype=bool)
    return (np.asarray(valid, bool) & (np.asarray(nbytes) == 0)
            & (np.asarray(retrain_after) > 0))


def insert_retrain_markers(channel, nbytes, direction, row, fixed_after,
                           is_payload, valid, extra_wire, retrain_after,
                           chan_pair) -> tuple:
    """Insert a link-down marker after every hop that samples a retraining
    event on a channel with a full-duplex pair (``chan_pair[c] >= 0``).

    The trigger's ``fixed_after`` moves onto the marker so the marker
    arrives exactly at the trigger's departure (= the instant the link
    drops) and downstream arrivals are unchanged.  Returns the ten arrays
    with columns widened by the maximum per-row marker count; a hop matrix
    with no triggering hops is returned unchanged (bit-exact layout).
    """
    chan_pair = np.asarray(chan_pair)
    trigger = ((np.asarray(retrain_after) > 0) & np.asarray(valid, bool)
               & (np.asarray(nbytes) > 0)
               & (chan_pair[np.asarray(channel)] >= 0))
    maxk = int(trigger.sum(axis=1).max()) if trigger.any() else 0
    if maxk == 0:
        return (channel, nbytes, direction, row, fixed_after, is_payload,
                valid, extra_wire, retrain_after)
    n, h = np.asarray(channel).shape
    h2 = h + maxk
    out = dict(
        channel=np.full((n, h2), -1, np.int32),
        nbytes=np.zeros((n, h2), np.int64),
        direction=np.zeros((n, h2), np.int8),
        row=np.full((n, h2), -1, np.int32),
        fixed_after=np.zeros((n, h2), np.int64),
        is_payload=np.zeros((n, h2), bool),
        valid=np.zeros((n, h2), bool),
        extra_wire=np.zeros((n, h2), np.int64),
        retrain_after=np.zeros((n, h2), np.int64),
    )
    src = (channel, nbytes, direction, row, fixed_after, is_payload, valid,
           extra_wire, retrain_after)
    names = tuple(out)
    for j in range(n):
        k = 0
        for i in range(h):
            for name, arr in zip(names, src):
                out[name][j, k] = arr[j, i]
            k += 1
            if trigger[j, i]:
                out["channel"][j, k] = chan_pair[channel[j, i]]
                out["valid"][j, k] = True
                out["retrain_after"][j, k] = retrain_after[j, i]
                out["fixed_after"][j, k] = fixed_after[j, i]
                out["fixed_after"][j, k - 1] = 0
                k += 1
    return tuple(out[name] for name in names)


def remove_retrain_markers(channel, nbytes, direction, row, fixed_after,
                           is_payload, valid, extra_wire,
                           retrain_after) -> tuple:
    """Exact inverse of `insert_retrain_markers` (test/bench helper):
    drop marker columns, hand each marker's ``fixed_after`` back to its
    triggering hop, and left-justify to the original width."""
    marker = retrain_marker_mask(channel, nbytes, valid, retrain_after)
    if not marker.any():
        return (channel, nbytes, direction, row, fixed_after, is_payload,
                valid, extra_wire, retrain_after)
    n, h2 = np.asarray(channel).shape
    h = h2 - int(marker.sum(axis=1).max())
    out = dict(
        channel=np.full((n, h), -1, np.int32),
        nbytes=np.zeros((n, h), np.int64),
        direction=np.zeros((n, h), np.int8),
        row=np.full((n, h), -1, np.int32),
        fixed_after=np.zeros((n, h), np.int64),
        is_payload=np.zeros((n, h), bool),
        valid=np.zeros((n, h), bool),
        extra_wire=np.zeros((n, h), np.int64),
        retrain_after=np.zeros((n, h), np.int64),
    )
    src = (channel, nbytes, direction, row, fixed_after, is_payload, valid,
           extra_wire, retrain_after)
    names = tuple(out)
    for j in range(n):
        k = 0
        for i in range(h2):
            if marker[j, i]:
                out["fixed_after"][j, k - 1] = fixed_after[j, i]
                continue
            if k >= h:
                break
            for name, arr in zip(names, src):
                out[name][j, k] = arr[j, i]
            k += 1
    return tuple(out[name] for name in names)


def _hops_arrays(hops) -> tuple:
    """The nine insert/remove arrays of an engine ``Hops``, in contract
    order (missing reliability tables become zeros)."""
    n, h = np.asarray(hops.channel).shape
    return (np.asarray(hops.channel), np.asarray(hops.nbytes),
            np.asarray(hops.direction), np.asarray(hops.row),
            np.asarray(hops.fixed_after_ps), np.asarray(hops.is_payload),
            np.asarray(hops.valid),
            np.zeros((n, h), np.int64) if hops.extra_wire_bytes is None
            else np.asarray(hops.extra_wire_bytes),
            np.zeros((n, h), np.int64) if hops.retrain_after_ps is None
            else np.asarray(hops.retrain_after_ps))


def _hops_from_arrays(arrs) -> "object":
    import jax.numpy as jnp

    from .engine import Hops

    chan, nbytes, direction, row, fixed, pay, valid, extra, retrain = arrs
    return Hops(
        channel=jnp.asarray(chan), nbytes=jnp.asarray(nbytes),
        direction=jnp.asarray(direction), row=jnp.asarray(row),
        fixed_after_ps=jnp.asarray(fixed), is_payload=jnp.asarray(pay),
        valid=jnp.asarray(valid), extra_wire_bytes=jnp.asarray(extra),
        retrain_after_ps=jnp.asarray(retrain))


def apply_retrain_markers(hops, chan_pair) -> "object":
    """`insert_retrain_markers` at the engine-``Hops`` level."""
    return _hops_from_arrays(
        insert_retrain_markers(*_hops_arrays(hops), chan_pair))


def strip_retrain_markers(hops) -> "object":
    """`remove_retrain_markers` at the engine-``Hops`` level (the exact
    inverse of the build path's marker insertion — test/bench helper)."""
    return _hops_from_arrays(remove_retrain_markers(*_hops_arrays(hops)))


# ---------------------------------------------------------------------------
# Credit-based flow control
# ---------------------------------------------------------------------------

def credit_limited_MBps(bw_MBps: int, cfg: FlitConfig) -> int:
    """Sustained-rate cap from the credit loop: credits*flit_size per RTT.

    With enough rx credits to cover the bandwidth-delay product this returns
    ``bw_MBps`` unchanged; a shallow receiver buffer caps the link below its
    lane rate (the knob the rx-buffer sizing studies sweep).
    """
    size, _ = cfg.geometry
    if size == 0 or cfg.credit_rtt_ps <= 0:
        return bw_MBps
    # credits * size bytes per rtt ps -> MB/s: bytes * 1e12 / (rtt * 1e6)
    cap = cfg.rx_credits * size * PPM // cfg.credit_rtt_ps
    return min(bw_MBps, max(int(cap), 1))


# ---------------------------------------------------------------------------
# Lowering to engine channel tables
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LoweredLink:
    """Per-direction channel entries a flit link contributes to the graph.

    The first five fields are the deterministic engine tables (PR-1
    contract).  The reliability block parameterizes build-time stochastic
    sampling (`sample_hop_tables`); it never enters the engine's channel
    arrays — sampled outcomes land in per-hop ``Hops`` tables instead, which
    is what keeps BER sweeps vmappable.  In stochastic mode ``replay_ppm``
    is 0: the sampled per-flit replays replace the expected-value stretch
    (double counting would bias goodput low).
    """

    eff_bw_MBps: int      # credit-capped serialization bandwidth
    extra_fixed_ps: int   # FEC decode latency added to per-hop fixed latency
    flit_size: int        # 0 = byte-exact channel
    flit_payload: int
    replay_ppm: int       # expected CRC-replay overhead (Go-Back-N)
    stochastic: bool = False   # sample per-flit replays at build time
    flit_err_p: float = 0.0    # per-flit CRC failure probability
    retry_window: int = 0      # Go-Back-N window (flits replayed per failure)
    retrain_threshold: int = 0  # consecutive failures forcing retraining
    retrain_ps: int = 0        # link-down interval per retraining event
    rel_seed: int = 0          # sampling stream seed
    credit_dllp: bool = False  # emit credit-return DLLP reverse hops
    credit_window: int = 0     # flits per credit-return DLLP


def lower_link(bw_MBps: int, flit: "FlitConfig | str | None") -> LoweredLink:
    """Lower one link's flit config into engine channel-table entries."""
    cfg = normalize(flit)
    if not cfg.active:
        return LoweredLink(bw_MBps, 0, 0, 0, 0)
    size, payload = cfg.geometry
    return LoweredLink(
        eff_bw_MBps=credit_limited_MBps(bw_MBps, cfg),
        extra_fixed_ps=cfg.fec_latency_ps,
        flit_size=size,
        flit_payload=payload,
        replay_ppm=0 if cfg.stochastic
        else replay_overhead_ppm(cfg.ber, cfg.mode, cfg.retry_window),
        stochastic=cfg.stochastic,
        flit_err_p=flit_error_prob(cfg.ber, cfg.mode) if cfg.stochastic
        else 0.0,
        retry_window=cfg.retry_window,
        retrain_threshold=cfg.retrain_threshold if cfg.stochastic else 0,
        retrain_ps=cfg.retrain_down_ps if cfg.stochastic else 0,
        rel_seed=cfg.rel_seed,
        credit_dllp=cfg.credit_dllp,
        credit_window=max(cfg.rx_credits, 1),
    )


def apply_flit(channels, link_mask: np.ndarray, flit: "FlitConfig | str | None"):
    """Override every masked channel of an engine ``Channels`` with ``flit``.

    The workload-level override path (`devices.build_workload(flit=...)`):
    returns a new Channels whose flit tables are set on link channels
    (``link_mask`` true) and zero elsewhere (service channels stay
    byte-exact).  ``flit=None``/"none" returns ``channels`` unchanged — the
    seed's structurally identical byte-exact path.
    """
    import jax.numpy as jnp

    from .engine import Channels

    cfg = normalize(flit)
    if not cfg.active:
        return channels
    size, payload = cfg.geometry
    # stochastic reliability replaces the expected stretch with sampled
    # per-hop tables (devices.build_workload), so the channel ppm stays 0
    ppm = 0 if cfg.stochastic \
        else replay_overhead_ppm(cfg.ber, cfg.mode, cfg.retry_window)
    mask = jnp.asarray(link_mask, bool)
    bw = jnp.where(
        mask,
        jnp.minimum(channels.bw_MBps,
                    credit_limited_MBps(1 << 40, cfg)),
        channels.bw_MBps,
    )
    zeros = jnp.zeros_like(channels.bw_MBps)
    return Channels(
        bw_MBps=bw,
        turnaround_ps=channels.turnaround_ps,
        row_hit_ps=channels.row_hit_ps,
        row_miss_ps=channels.row_miss_ps,
        flit_size=jnp.where(mask, size, zeros),
        flit_payload=jnp.where(mask, payload, zeros),
        replay_ppm=jnp.where(mask, ppm, zeros),
    )


# Ready-made configurations for the paper's studied link generations.
PCIE5_FLIT = FlitConfig(mode="flit68")
PCIE6_FLIT = FlitConfig(mode="flit256")
