"""PCIe 6.0 FLIT link layer: flit packing, FEC/CRC retry, credit flow control.

The seed modeled the whole PCIe link layer as one bandwidth constant.  This
module makes it a first-class subsystem, following Das Sharma's CXL
interconnect overview (arXiv 2306.11227):

  * **Flit packing** — PCIe 6.0 / CXL 3.x links carry fixed 256 B flits:
    236 B of TLP payload plus 6 B DLLP, 8 B CRC and 6 B FEC check symbols.
    PCIe 5 / CXL 2.0 links in CXL's 68 B flit mode carry 64 B slots with a
    2 B CRC and 2 B protocol-ID header.  A logical packet of ``n`` bytes
    therefore occupies ``ceil(n / payload) * size`` wire bytes.

  * **Lightweight FEC + CRC retry** — the 3-way interleaved FEC of PCIe 6.0
    adds a small fixed decode latency per hop (~2 ns).  Flits that fail CRC
    after FEC are replayed link-level with Go-Back-N: the failed flit and
    every flit in flight behind it retransmit.  Under a bit error rate
    ``ber`` the per-flit error probability is ``1 - (1 - ber)^bits`` and the
    expected transmissions per flit is ``(1 - p + p*W) / (1 - p)`` for a
    replay window of ``W`` flits.  The *expected* overhead is folded into
    serialization deterministically (as integer parts-per-million), which
    keeps the engine exact and bit-reproducible and makes goodput a
    monotone function of BER — what the sensitivity sweeps need.

  * **Credit-based flow control** — the receiver grants ``rx_credits`` flit
    buffers; the sender stalls when the in-flight window exceeds them.  A
    credit loop of round-trip ``credit_rtt_ps`` therefore caps sustained
    throughput at ``credits * flit_size / rtt`` regardless of raw lane
    speed — the classic bandwidth-delay-product bound, applied as a
    per-channel effective-bandwidth derate.

Lowering contract: everything a flit link does to traffic is expressed as
three per-channel integer tables (``flit_size``, ``flit_payload``,
``replay_ppm``) consumed by ``core.engine`` / ``core.ref_des`` during
serialization, plus an effective bandwidth and a fixed per-hop latency add.
``flit_mode="none"`` produces empty tables and reproduces the seed's
byte-exact schedules bit-for-bit.  Because the tables are plain arrays in
``engine.Channels``, whole BER x bandwidth x flit-mode sweeps ``vmap`` in
one jit (see ``kernels.flit_pack`` for the analytic-efficiency companion).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from .calibration import (CRC_REPLAY_RTT_PS, FEC_LATENCY_PS, FLIT68_PAYLOAD_B,
                          FLIT68_SIZE_B, FLIT256_PAYLOAD_B, FLIT256_SIZE_B)

PPM = 1_000_000
# Ceiling on the expected Go-Back-N replay overhead: 1000x extra
# transmissions per flit.  The expected-value model diverges as the flit
# error probability approaches 1, but a real link retrains long before
# that (see the lane-margining ROADMAP item); the clamp also keeps
# replay_ppm within the flit_pack kernel's int32 tables, and the engine's
# decomposed replay stretch (engine.wire_ser_ps) stays int64-exact with
# ppm at this clamp for serializations up to ~9.2e15 ps.
MAX_REPLAY_PPM = 1000 * PPM

# mode -> (flit size on the wire, TLP payload bytes per flit)
FLIT_GEOMETRY: dict[str, tuple[int, int]] = {
    "none": (0, 0),
    "flit68": (FLIT68_SIZE_B, FLIT68_PAYLOAD_B),      # PCIe 5 / CXL 2.0
    "flit256": (FLIT256_SIZE_B, FLIT256_PAYLOAD_B),   # PCIe 6 / CXL 3.x
}
FLIT_MODES = tuple(FLIT_GEOMETRY)


@dataclass(frozen=True)
class FlitConfig:
    """Link-layer configuration of one physical link (both directions).

    mode            "none" (byte-exact seed semantics) | "flit68" | "flit256".
    ber             residual bit error rate the CRC sees — i.e. *after* the
                    lightweight FEC has corrected what it can (FEC escapes).
                    Datasheet raw lane BERs (~1e-6 for PCIe 6.0) must be
                    mapped through the FEC correction model first; residual
                    rates are typically orders of magnitude lower.
    rx_credits      receiver buffer, in flits, granted to the sender.  The
                    default (256) covers the bandwidth-delay product of any
                    realistic lane rate at the default credit RTT, so credit
                    flow control only binds when a study shrinks it.
    credit_rtt_ps   credit-return loop latency (propagation + DLLP processing).
    retry_window    Go-Back-N replay window, in flits in flight.
    fec_ps          per-hop FEC decode latency; None = mode default
                    (lightweight FEC exists only in 256 B flit mode).
    """

    mode: str = "none"
    ber: float = 0.0
    rx_credits: int = 256
    credit_rtt_ps: int = CRC_REPLAY_RTT_PS
    retry_window: int = 16
    fec_ps: int | None = None

    def __post_init__(self):
        if self.mode not in FLIT_GEOMETRY:
            raise ValueError(f"unknown flit mode {self.mode!r}; "
                             f"expected one of {FLIT_MODES}")
        if not 0.0 <= self.ber < 1.0:
            raise ValueError(f"ber {self.ber} out of [0, 1)")
        if self.rx_credits < 1:
            raise ValueError("rx_credits must be >= 1")

    @property
    def active(self) -> bool:
        return self.mode != "none"

    @property
    def geometry(self) -> tuple[int, int]:
        return FLIT_GEOMETRY[self.mode]

    @property
    def fec_latency_ps(self) -> int:
        if self.fec_ps is not None:
            return self.fec_ps
        return FEC_LATENCY_PS if self.mode == "flit256" else 0


def normalize(flit: "FlitConfig | str | None") -> FlitConfig:
    """Accept a FlitConfig, a mode string, or None (= byte-exact)."""
    if flit is None:
        return FlitConfig("none")
    if isinstance(flit, str):
        return FlitConfig(flit)
    return flit


# ---------------------------------------------------------------------------
# Flit packing
# ---------------------------------------------------------------------------

def wire_bytes(nbytes, mode: str):
    """Wire bytes of an ``nbytes`` logical packet: whole flits, incl. CRC/FEC.

    Accepts scalars or numpy arrays.  ``mode="none"`` is the identity.
    """
    size, payload = FLIT_GEOMETRY[mode]
    if size == 0:
        return nbytes
    return -(-np.asarray(nbytes) // payload) * size if np.ndim(nbytes) \
        else -(-nbytes // payload) * size


def flit_efficiency(mode: str) -> float:
    """Analytic zero-BER payload fraction of a fully packed flit stream."""
    size, payload = FLIT_GEOMETRY[mode]
    return 1.0 if size == 0 else payload / size


# ---------------------------------------------------------------------------
# FEC/CRC retry (Go-Back-N replay, expected-value model)
# ---------------------------------------------------------------------------

def flit_error_prob(ber: float, mode: str) -> float:
    """Probability one flit still fails CRC: 1 - (1-ber)^bits over the flit.

    ``ber`` is the residual post-FEC rate (see FlitConfig), so the geometry
    term is the whole flit (CRC covers every wire byte).
    """
    size, _ = FLIT_GEOMETRY[mode]
    if size == 0 or ber <= 0.0:
        return 0.0
    return -math.expm1(8 * size * math.log1p(-ber))


def replay_overhead_ppm(ber: float, mode: str, retry_window: int = 16) -> int:
    """Expected *extra* transmissions per flit, in parts-per-million.

    Go-Back-N with window W and flit error probability p retransmits, in
    expectation, ``E - 1 = p * W / (1 - p)`` extra flits per delivered flit
    (E = (1 - p + p*W)/(1 - p)).  Returned as an integer ppm so the engine
    can fold it into serialization without leaving int64 arithmetic; the
    divergence as p -> 1 is clamped at ``MAX_REPLAY_PPM`` (a link that bad
    retrains rather than replaying forever).
    """
    p = flit_error_prob(ber, mode)
    if p <= 0.0:
        return 0
    if p >= 1.0:
        return MAX_REPLAY_PPM
    return min(int(round(p * max(retry_window, 1) / (1.0 - p) * PPM)),
               MAX_REPLAY_PPM)


def goodput_efficiency(mode: str, ber: float = 0.0,
                       retry_window: int = 16) -> float:
    """Payload fraction of wire time including expected CRC replays."""
    ppm = replay_overhead_ppm(ber, mode, retry_window)
    return flit_efficiency(mode) / (1.0 + ppm / PPM)


# ---------------------------------------------------------------------------
# Credit-based flow control
# ---------------------------------------------------------------------------

def credit_limited_MBps(bw_MBps: int, cfg: FlitConfig) -> int:
    """Sustained-rate cap from the credit loop: credits*flit_size per RTT.

    With enough rx credits to cover the bandwidth-delay product this returns
    ``bw_MBps`` unchanged; a shallow receiver buffer caps the link below its
    lane rate (the knob the rx-buffer sizing studies sweep).
    """
    size, _ = cfg.geometry
    if size == 0 or cfg.credit_rtt_ps <= 0:
        return bw_MBps
    # credits * size bytes per rtt ps -> MB/s: bytes * 1e12 / (rtt * 1e6)
    cap = cfg.rx_credits * size * PPM // cfg.credit_rtt_ps
    return min(bw_MBps, max(int(cap), 1))


# ---------------------------------------------------------------------------
# Lowering to engine channel tables
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LoweredLink:
    """Per-direction channel entries a flit link contributes to the graph."""

    eff_bw_MBps: int      # credit-capped serialization bandwidth
    extra_fixed_ps: int   # FEC decode latency added to per-hop fixed latency
    flit_size: int        # 0 = byte-exact channel
    flit_payload: int
    replay_ppm: int       # expected CRC-replay overhead (Go-Back-N)


def lower_link(bw_MBps: int, flit: "FlitConfig | str | None") -> LoweredLink:
    """Lower one link's flit config into engine channel-table entries."""
    cfg = normalize(flit)
    if not cfg.active:
        return LoweredLink(bw_MBps, 0, 0, 0, 0)
    size, payload = cfg.geometry
    return LoweredLink(
        eff_bw_MBps=credit_limited_MBps(bw_MBps, cfg),
        extra_fixed_ps=cfg.fec_latency_ps,
        flit_size=size,
        flit_payload=payload,
        replay_ppm=replay_overhead_ppm(cfg.ber, cfg.mode, cfg.retry_window),
    )


def apply_flit(channels, link_mask: np.ndarray, flit: "FlitConfig | str | None"):
    """Override every masked channel of an engine ``Channels`` with ``flit``.

    The workload-level override path (`devices.build_workload(flit=...)`):
    returns a new Channels whose flit tables are set on link channels
    (``link_mask`` true) and zero elsewhere (service channels stay
    byte-exact).  ``flit=None``/"none" returns ``channels`` unchanged — the
    seed's structurally identical byte-exact path.
    """
    import jax.numpy as jnp

    from .engine import Channels

    cfg = normalize(flit)
    if not cfg.active:
        return channels
    size, payload = cfg.geometry
    ppm = replay_overhead_ppm(cfg.ber, cfg.mode, cfg.retry_window)
    mask = jnp.asarray(link_mask, bool)
    bw = jnp.where(
        mask,
        jnp.minimum(channels.bw_MBps,
                    credit_limited_MBps(1 << 40, cfg)),
        channels.bw_MBps,
    )
    zeros = jnp.zeros_like(channels.bw_MBps)
    return Channels(
        bw_MBps=bw,
        turnaround_ps=channels.turnaround_ps,
        row_hit_ps=channels.row_hit_ps,
        row_miss_ps=channels.row_miss_ps,
        flit_size=jnp.where(mask, size, zeros),
        flit_payload=jnp.where(mask, payload, zeros),
        replay_ppm=jnp.where(mask, ppm, zeros),
    )


# Ready-made configurations for the paper's studied link generations.
PCIE5_FLIT = FlitConfig(mode="flit68")
PCIE6_FLIT = FlitConfig(mode="flit256")
