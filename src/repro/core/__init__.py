"""ESF core: the paper's contribution (interconnect layer + device layer).

The schedule engine does exact integer arithmetic in picoseconds, so importing
``repro.core`` enables JAX 64-bit mode.  All model/framework code elsewhere in
this repo is dtype-explicit (bf16/f32/int32), so enabling x64 is safe and does
not change compiled training/serving programs (verified by the dry-run tests).
"""

import jax

jax.config.update("jax_enable_x64", True)

from . import topology, engine, devices, link_layer  # noqa: E402,F401
from .topology import (  # noqa: E402,F401
    REQUESTER, SWITCH, MEMORY,
    Topology, LinkSpec, EndpointSpec, FabricGraph,
    chain, tree, ring, spine_leaf, fully_connected, single_bus, with_flit,
    TOPOLOGY_BUILDERS,
)
from .link_layer import (  # noqa: E402,F401
    FlitConfig, FLIT_MODES, PCIE5_FLIT, PCIE6_FLIT,
    flit_efficiency, goodput_efficiency, replay_overhead_ppm,
    credit_limited_MBps,
)
from .engine import (  # noqa: E402,F401
    Channels, Hops, Schedule, SimOptions, StreamCarry, simulate,
    simulate_auto, channel_stats, request_stats, make_channels, ser_ps,
    empty_carry, round_bound,
)
from .devices import RequesterSpec, Workload, build_workload  # noqa: E402,F401
from . import calibration, traces, routing, snoop_filter  # noqa: E402,F401
from .snoop_filter import (  # noqa: E402,F401
    SFConfig, CacheConfig, SFEvents, SFState, simulate_sf, sf_init_state,
    POLICIES, make_skewed_stream, make_sequential_stream,
)
from .traces import (  # noqa: E402,F401
    ARRIVAL_PATTERNS, WORKLOADS, arrival_times, request_stream, tenant_mix,
)
from . import coherence_traffic  # noqa: E402,F401
from .coherence_traffic import (  # noqa: E402,F401
    CoherenceFabricSpec, CoherenceStream, CoupledResult, FANOUT_MODES,
    LEG_NAMES, bisnp_latencies, coherence_issue, hop_legs, leg_blame,
    lower_coherence, pad_rows, simulate_coupled,
)
from . import streaming  # noqa: E402,F401
from .streaming import (  # noqa: E402,F401
    StreamResult, StreamState, simulate_stream, stream_windows,
)
from . import verify  # noqa: E402,F401
from .verify import (  # noqa: E402,F401
    Finding, VerifyError, VerifyReport, assert_valid, join_depth,
    verify_built, verify_workload,
)
from .routing import route_and_simulate, STRATEGIES  # noqa: E402,F401
from . import telemetry, trace_export  # noqa: E402,F401
from .telemetry import (  # noqa: E402,F401
    LatencyAttribution, ChannelTelemetry, ChannelBlame, WindowedSeries,
    QuantileSketch, SFTelemetry, attribute_latency, conservation_residual,
    channel_telemetry, channel_blame, blame_conservation_residual,
    windowed_series, sketch_new, sketch_update, sketch_merge,
    sketch_quantile, sketch_quantiles, sf_telemetry, fabric_metrics,
    StreamTelemetry, stream_telemetry_new, stream_telemetry_fold,
    stream_telemetry_finalize,
)
from . import critical_path  # noqa: E402,F401
from .critical_path import (  # noqa: E402,F401
    KIND_NAMES, Backpointers, Blame, PathEdge, blame, critical_path as
    extract_critical_path, critical_paths, extract_backpointers, path_total,
    speedup_if,
)
from .trace_export import (  # noqa: E402,F401
    channel_names, schedule_trace, coupled_trace, validate_trace, write_trace,
)
from . import fabric_model, autotune, vcs  # noqa: E402,F401
from .fabric_model import TPUFabric, predict_collective  # noqa: E402,F401
from .autotune import WorkloadDims, Layout, autotune as autotune_layouts  # noqa: E402,F401

# The supported public surface.  Grouped by layer; every simulation entry
# point (`simulate`, `simulate_auto`, `simulate_coupled`, `simulate_stream`)
# takes the same `SimOptions`, and every result type (`Schedule`,
# `CoupledResult`, `StreamResult`) reports `rounds`/`converged`/`residual_ps`.
__all__ = [
    # topology / link layer
    "REQUESTER", "SWITCH", "MEMORY", "Topology", "LinkSpec", "EndpointSpec",
    "FabricGraph", "chain", "tree", "ring", "spine_leaf", "fully_connected",
    "single_bus", "with_flit", "TOPOLOGY_BUILDERS", "FlitConfig",
    "FLIT_MODES", "PCIE5_FLIT", "PCIE6_FLIT", "flit_efficiency",
    "goodput_efficiency", "replay_overhead_ppm", "credit_limited_MBps",
    # schedule engine
    "Channels", "Hops", "Schedule", "SimOptions", "StreamCarry", "simulate",
    "simulate_auto", "round_bound", "channel_stats", "request_stats",
    "make_channels", "ser_ps", "empty_carry",
    # device layer / workloads / traces
    "RequesterSpec", "Workload", "build_workload", "ARRIVAL_PATTERNS",
    "WORKLOADS", "arrival_times", "request_stream", "tenant_mix",
    # snoop filter + coupled coherence
    "SFConfig", "CacheConfig", "SFEvents", "SFState", "simulate_sf",
    "sf_init_state", "POLICIES", "make_skewed_stream",
    "make_sequential_stream", "CoherenceFabricSpec", "CoherenceStream",
    "CoupledResult", "FANOUT_MODES", "LEG_NAMES", "bisnp_latencies",
    "coherence_issue", "hop_legs", "leg_blame", "lower_coherence",
    "pad_rows", "simulate_coupled",
    # streaming
    "StreamResult", "StreamState", "simulate_stream", "stream_windows",
    # verification
    "Finding", "VerifyError", "VerifyReport", "assert_valid", "join_depth",
    "verify_built", "verify_workload",
    # routing / telemetry / attribution / export
    "route_and_simulate", "STRATEGIES", "LatencyAttribution",
    "ChannelTelemetry", "ChannelBlame", "WindowedSeries", "QuantileSketch",
    "SFTelemetry", "attribute_latency", "conservation_residual",
    "channel_telemetry", "channel_blame", "blame_conservation_residual",
    "windowed_series", "sketch_new", "sketch_update", "sketch_merge",
    "sketch_quantile", "sketch_quantiles", "sf_telemetry", "fabric_metrics",
    "StreamTelemetry", "stream_telemetry_new", "stream_telemetry_fold",
    "stream_telemetry_finalize", "KIND_NAMES", "Backpointers", "Blame",
    "PathEdge", "blame", "extract_critical_path", "critical_paths",
    "extract_backpointers", "path_total", "speedup_if", "channel_names",
    "schedule_trace", "coupled_trace", "validate_trace", "write_trace",
    # accelerator-side models
    "TPUFabric", "predict_collective", "WorkloadDims", "Layout",
    "autotune_layouts",
    # submodules
    "topology", "engine", "devices", "link_layer", "calibration", "traces",
    "routing", "snoop_filter", "coherence_traffic", "streaming", "verify",
    "telemetry", "trace_export", "critical_path", "fabric_model",
    "autotune", "vcs",
]
