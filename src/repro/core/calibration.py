"""Calibration constants and hardware reference curves (paper §IV, Table III).

Latency constants come from the paper's Table III (calibrated against an
Intel Xeon 6416H + Montage MXC CXL 2.0 memory expander platform plus prior
measurement studies [5, 26, 32, 40, 44, 49, 55]).

``REFERENCE_HW`` holds the hardware-measured values the paper validates
against (digitized from Fig. 7/8 and cross-checked against the public CXL
measurement literature, e.g. Sun et al., MICRO'23).  The validation benchmark
replays the MLC-style experiments in the simulator and reports error against
these references, mirroring the paper's 0.1–10 % bandwidth and ≤12 %
loaded-latency error claims.
"""

from __future__ import annotations

from dataclasses import dataclass

PS = 1
NS = 1_000


@dataclass(frozen=True)
class TableIII:
    requester_process_ps: int = 10 * NS
    cache_access_ps: int = 12 * NS
    device_controller_ps: int = 40 * NS
    pcie_port_delay_ps: int = 25 * NS
    bus_time_ps: int = 1 * NS
    switching_ps: int = 20 * NS


CAL = TableIII()

# PCIe 5.0 x16: 32 GT/s * 16 lanes / 8 b/B * 128/130 encoding ~= 63 GB/s/dir.
PCIE5_X16_MBPS = 63_000
# PCIe 6.0 x16 (CXL 3.1 target): 64 GT/s, PAM4 + FLIT -> ~121 GB/s/dir.
PCIE6_X16_MBPS = 121_000
# Pre-flit-framing lane rates for flit-mode links: core.link_layer models
# the flit CRC/FEC overhead explicitly, so flit links are configured with
# the rate *after* line encoding but *before* flit framing.  PCIe 6.0 PAM4
# uses no 128b/130b encoding (64 GT/s * 16 / 8 b/B); PCIe 5.0 is NRZ with
# 128b/130b, which link_layer does not model, so its encoding stays in.
PCIE6_X16_RAW_MBPS = 128_000
PCIE5_X16_RAW_MBPS = 63_015  # 32 GT/s * 16 / 8 b/B * 128/130

# ---------------------------------------------------------------------------
# FLIT link-layer geometry (Das Sharma, arXiv 2306.11227, Fig. 5/9)
# ---------------------------------------------------------------------------
# PCIe 6.0 / CXL 3.x 256 B flit: 236 B TLP + 6 B DLLP + 8 B CRC + 6 B FEC.
FLIT256_SIZE_B = 256
FLIT256_PAYLOAD_B = 236
# PCIe 5 / CXL 2.0 68 B flit: four 16 B slots (64 B) + 2 B CRC + 2 B proto ID.
FLIT68_SIZE_B = 68
FLIT68_PAYLOAD_B = 64
# Lightweight 3-way interleaved FEC decode latency (~2 ns per hop) and the
# link-level Go-Back-N replay / credit-return loop latency.
FEC_LATENCY_PS = 2 * NS
CRC_REPLAY_RTT_PS = 100 * NS
# Credit-return DLLP: 6 B of DLLP payload + framing, modeled as 8 logical
# bytes (one flit on the wire once quantized) per credit-return window.
CREDIT_DLLP_B = 8
# Link retraining (recovery) interval: when CRC replays storm past the retry
# threshold the link drops to Recovery and re-equalizes — a microsecond-scale
# stall during which the channel grants nothing (Das Sharma, arXiv 2306.11227
# puts PCIe recovery in the us range; lane margining studies measure 1-10 us).
LINK_RETRAIN_PS = 1_000 * NS
# One DDR5-4800 DIMM ~ 38.4 GB/s; the MXC expander and each NUMA node carry 4.
DDR5_DIMM_MBPS = 38_400
EXPANDER_MBPS = 4 * DDR5_DIMM_MBPS

# DRAM service timing for the banked endpoint model (DRAMsim3 stand-in).
DRAM_ROW_HIT_PS = 15 * NS
DRAM_ROW_MISS_PS = 40 * NS

# ---------------------------------------------------------------------------
# Hardware reference points (paper Fig. 7/8; CXL literature cross-check)
# ---------------------------------------------------------------------------

REFERENCE_HW = {
    # idle (unloaded) read latency, ns
    "idle_latency_ns": {
        "local_dram": 108.0,
        "remote_numa_dram": 191.0,
        "cxl_mxc": 256.0,
    },
    # peak bandwidth vs read:write ratio, GB/s (Fig. 7 right; CXL rises with
    # mixing because PCIe is full duplex; DRAM platforms *fall* as writes mix
    # in — captured by the half-duplex/turnaround DDR bus model)
    "peak_bw_GBs": {
        #            R:W = 1:0    3:1    2:1    1:1
        "cxl_mxc":      [26.0,  33.0,  36.0,  42.0],
        "local_dram":   [118.0, 108.0, 104.0, 98.0],
        "remote_numa_dram": [50.0, 47.0, 45.0, 43.0],
    },
    "rw_ratios": [(1, 0), (3, 1), (2, 1), (1, 1)],
    # loaded-latency anchor points for CXL reads: (bandwidth GB/s, latency ns)
    "loaded_latency_cxl_read": [
        (2.0, 258.0), (8.0, 266.0), (16.0, 290.0), (22.0, 340.0), (25.0, 430.0),
    ],
    # SPEC CPU2017 execution-time overhead of CXL memory vs local DRAM
    # (paper Table IV, hardware row)
    "spec_overhead": {"gcc": 0.180, "mcf": 0.242},
    # the paper's own accuracy statements, used as acceptance gates
    "paper_error_bands": {
        "bandwidth_rel_err_max": 0.10,
        "loaded_latency_rel_err_max": 0.12,
        "loaded_latency_rel_err_avg": 0.043,
    },
}

# Paper Table IV: simulated CXL execution-time overheads per platform.
TABLE_IV = {
    "CXL Hardware":  {"gcc": 0.180, "mcf": 0.242},
    "ESF standalone": {"gcc": 0.187, "mcf": 0.298},
    "gem5-ESF":      {"gcc": 0.156, "mcf": 0.198},
    "NUMA emulation": {"gcc": 0.200, "mcf": 0.150},
    "gem5-garnet":   {"gcc": 0.122, "mcf": 0.152},
}

# Paper Fig. 10 normalized system bandwidth targets (claim F1), scale->value.
FIG10_TARGETS = {
    "chain": "flat ~1x port bandwidth",
    "tree": "flat ~1x port bandwidth",
    "ring": "~2x port bandwidth at scale",
    "spine_leaf": "~N/2 x port bandwidth",
    "fully_connected": "~N x port bandwidth",
}

# Paper Fig. 18/19 trace-replay ratios vs chain (claim F7).
FIG18_TARGETS = {"ring": 1.72, "spine_leaf": 2.27, "fully_connected": 3.63}
FIG19_TARGETS = {"ring": 0.57, "spine_leaf": 0.44, "fully_connected": 0.28}

# Paper Fig. 14 (claim F4): LIFO vs FIFO.
FIG14_TARGETS = {"bandwidth": 1.05, "latency": 0.85, "invalidation": 0.84}

# Fig. 20b: +0.1 mix degree ~ +9% bandwidth on full-duplex links.
FIG20_SLOPE_PER_01 = 0.09
