"""Fabric-IR verifier: static contract checking for lowered workloads.

The engine's input IR — a lowered ``(Hops, Channels, issue_ps)`` triple, plus
the optional reliability / fork-join / streaming-carry extensions — carries
five layers of implicit contracts accumulated across the link-layer,
reliability, coherence, fork/join and streaming subsystems.  Every one of
them is otherwise enforced only at runtime, deep inside a jitted scan or by
the `ref_des` oracle raising mid-simulation.  Third-party lowerings (new
device back-ends, rack-scale topology generators) hand the engine tables we
did not author, and a config-level mistake silently produces
plausible-but-wrong latency curves rather than an error.

This module is the static half of that enforcement: a pure, importable
checker that validates a lowered workload against the full contract set
*without running the engine*.  It returns a structured `VerifyReport` — a
list of typed `Finding`s with row/hop/channel coordinates — rather than
raising, so callers can render, count, or gate on findings; ``strict``
entry points (`assert_valid`, ``simulate_auto(check="static")``, the
`core.streaming` precondition, the benchmark setup gates) raise
`VerifyError` on the first dirty report.

Contract set (one code family per subsystem):

  shape.*   every (N, H) table shares one shape; issue/join tables are (N,)
  dtype.*   int32 index columns, int8 directions, bool masks, int64
            ps-domain clocks and byte counts (the int64 contract is what the
            scan's exact integer arithmetic rests on — a silently int32
            clock column wraps at ~2.1 ms)
  chan.*    channel indices of valid hops in [0, C); channel tables
            positive/non-negative where required; flit tables come as a
            trio with sane geometry; ``chan_pair`` is symmetric
  hop.*     non-negative bytes and fixed latencies on valid hops
  join.*    join tables come as a triple; group ids row-indexed < N (the
            engine resolves group maxes with an N-sized scatter);
            ``join_arity`` equals the group's actual contributor count; the
            group graph is a DAG (a cycle deadlocks the oracle and never
            converges in the engine); an explicit ``max_rounds`` budget
            below the computed `round_bound` is flagged (``join.depth``)
  rel.*     reliability tables come as a pair and are non-negative; replay
            bytes only on serving hops; link-down markers are structurally
            valid (zero-byte, not row-managed, zero-turnaround channel,
            paired with their triggering hop when ``chan_pair`` is given);
            replay bytes never double-count with an expected-value
            ``replay_ppm`` channel; with the per-channel sampling tables
            the quantization invariants hold (``extra_wire_bytes`` a
            multiple of the flit wire quantum, ``retrain_after_ps`` a
            multiple of the per-event stall, and retrain events bounded by
            ``failures // retrain_threshold`` — the `link_layer`
            coupled-draw invariant)
  issue.*   int64 issue clocks; non-decreasing when the caller's settlement
            rule requires it (``monotone_issue=True`` — `stream_windows`
            input contract)
  carry.*   `StreamCarry` frontier shapes/dtypes match the channel count;
            departures and down-until clocks non-negative; directions in
            {-1, 0, 1}; rows >= -2; ``join_seed_ps`` only alongside join
            tables and sized to the window's row count
  sf.*      `SFEvents` columns share the request count; counters
            non-negative; a cache hit snoops only on a write conflict

Everything runs host-side on numpy views — no jit, no device transfer — so
the checker is safe to call from benchmark setup, test fixtures, and the
streaming driver's per-chunk precondition.
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np


class Finding(NamedTuple):
    """One contract violation.  ``code`` is the stable, typed identifier
    (``family.check``, e.g. ``"join.cycle"``); ``row``/``hop``/``channel``
    locate the first offending coordinate (-1 = not applicable)."""

    code: str
    message: str
    row: int = -1
    hop: int = -1
    channel: int = -1

    def __str__(self) -> str:
        loc = ", ".join(f"{k}={v}" for k, v in
                        (("row", self.row), ("hop", self.hop),
                         ("channel", self.channel)) if v >= 0)
        return f"[{self.code}] {self.message}" + (f" ({loc})" if loc else "")


class VerifyError(ValueError):
    """Raised by strict verification; carries the full report."""

    def __init__(self, report: "VerifyReport"):
        self.report = report
        super().__init__(report.summary())


class VerifyReport(NamedTuple):
    findings: tuple[Finding, ...]
    n_rows: int
    n_channels: int

    @property
    def ok(self) -> bool:
        return not self.findings

    @property
    def codes(self) -> tuple[str, ...]:
        return tuple(f.code for f in self.findings)

    def summary(self) -> str:
        if self.ok:
            return (f"verify: OK ({self.n_rows} rows, "
                    f"{self.n_channels} channels)")
        head = (f"verify: {len(self.findings)} finding(s) on "
                f"{self.n_rows} rows / {self.n_channels} channels")
        return "\n".join([head] + [f"  {f}" for f in self.findings[:20]])

    def raise_if_failed(self) -> "VerifyReport":
        if not self.ok:
            raise VerifyError(self)
        return self


def _np(x):
    return None if x is None else np.asarray(x)


def _first(mask) -> tuple[int, int]:
    """(row, hop) of the first True in a 1-D or 2-D mask."""
    idx = np.argwhere(mask)
    if idx.size == 0:
        return -1, -1
    if idx.shape[1] == 1:
        return int(idx[0, 0]), -1
    return int(idx[0, 0]), int(idx[0, 1])


class _Checker:
    def __init__(self):
        self.findings: list[Finding] = []

    def add(self, code, message, row=-1, hop=-1, channel=-1):
        self.findings.append(Finding(code, message, row, hop, channel))

    def expect_dtype(self, arr, want: str, name: str, code="dtype"):
        kinds = {"int64": ("i", 8), "int32": ("i", 4), "int8": ("i", 1),
                 "bool": ("b", 1)}
        kind, size = kinds[want]
        if arr.dtype.kind != kind or arr.dtype.itemsize != size:
            self.add(f"{code}.{name}",
                     f"{name} must be {want}, got {arr.dtype}")
            return False
        return True


# ---------------------------------------------------------------------------
# Per-subsystem checks
# ---------------------------------------------------------------------------

def _check_shapes_dtypes(ck: _Checker, hops, issue) -> bool:
    """Table geometry + dtype contracts.  Returns False when the geometry
    is too broken for the value checks to index safely."""
    chan = _np(hops.channel)
    if chan.ndim != 2:
        ck.add("shape.table", f"channel must be (N, H), got {chan.shape}")
        return False
    shape = chan.shape
    usable = True
    for f in ("nbytes", "direction", "row", "fixed_after_ps", "is_payload",
              "valid", "extra_wire_bytes", "retrain_after_ps"):
        a = _np(getattr(hops, f))
        if a is not None and a.shape != shape:
            ck.add("shape.table", f"{f} shape {a.shape} != channel {shape}")
            usable = False
    if issue.shape != (shape[0],):
        ck.add("shape.issue",
               f"issue_ps shape {issue.shape} != ({shape[0]},)")
        usable = False
    for f in ("join_id", "join_wait", "join_arity"):
        a = _np(getattr(hops, f))
        if a is not None and a.shape != (shape[0],):
            ck.add("shape.join", f"{f} shape {a.shape} != ({shape[0]},)")
            usable = False

    ck.expect_dtype(chan, "int32", "channel")
    ck.expect_dtype(_np(hops.nbytes), "int64", "nbytes")
    ck.expect_dtype(_np(hops.direction), "int8", "direction")
    ck.expect_dtype(_np(hops.row), "int32", "row")
    ck.expect_dtype(_np(hops.fixed_after_ps), "int64", "fixed_after_ps")
    ck.expect_dtype(_np(hops.is_payload), "bool", "is_payload")
    ck.expect_dtype(_np(hops.valid), "bool", "valid")
    ck.expect_dtype(issue, "int64", "issue_ps", code="issue")
    for f in ("extra_wire_bytes", "retrain_after_ps"):
        a = _np(getattr(hops, f))
        if a is not None:
            ck.expect_dtype(a, "int64", f)
    for f in ("join_id", "join_wait", "join_arity"):
        a = _np(getattr(hops, f))
        if a is not None:
            ck.expect_dtype(a, "int32", f)
    return usable


def _check_channels(ck: _Checker, channels):
    bw = _np(channels.bw_MBps)
    if bw.ndim != 1:
        ck.add("chan.table", f"bw_MBps must be (C,), got {bw.shape}")
        return
    for f in ("turnaround_ps", "row_hit_ps", "row_miss_ps"):
        a = _np(getattr(channels, f))
        if a.shape != bw.shape:
            ck.add("chan.table", f"{f} shape {a.shape} != bw {bw.shape}")
            return
    for f in ("bw_MBps", "turnaround_ps", "row_hit_ps", "row_miss_ps"):
        ck.expect_dtype(_np(getattr(channels, f)), "int64", f, code="chan")
    if np.any(bw < 1):
        c, _ = _first(bw < 1)
        ck.add("chan.table", "bw_MBps must be >= 1 (ser_ps divides by it)",
               channel=c)
    for f in ("turnaround_ps", "row_hit_ps", "row_miss_ps"):
        a = _np(getattr(channels, f))
        if np.any(a < 0):
            c, _ = _first(a < 0)
            ck.add("chan.table", f"{f} must be non-negative", channel=c)

    flit = [_np(getattr(channels, f))
            for f in ("flit_size", "flit_payload", "replay_ppm")]
    present = [a is not None for a in flit]
    if any(present) and not all(present):
        ck.add("chan.flit", "flit_size/flit_payload/replay_ppm come as a "
               "trio (the link-layer lowering contract)")
        return
    if not any(present):
        return
    fsize, fpay, ppm = flit
    for name, a in (("flit_size", fsize), ("flit_payload", fpay),
                    ("replay_ppm", ppm)):
        if a.shape != bw.shape:
            ck.add("chan.flit", f"{name} shape {a.shape} != bw {bw.shape}")
            return
        ck.expect_dtype(a, "int64", name, code="chan")
    on = fsize > 0
    if np.any(fsize < 0):
        ck.add("chan.flit", "flit_size must be >= 0 (0 = byte-exact)",
               channel=_first(fsize < 0)[0])
    if np.any(on & (fpay < 1)):
        ck.add("chan.flit", "flit_payload must be >= 1 on flit channels",
               channel=_first(on & (fpay < 1))[0])
    if np.any(on & (fpay > fsize)):
        ck.add("chan.flit", "flit_payload cannot exceed flit_size "
               "(payload bytes ride inside the flit)",
               channel=_first(on & (fpay > fsize))[0])
    if np.any(ppm < 0):
        ck.add("chan.flit", "replay_ppm must be non-negative",
               channel=_first(ppm < 0)[0])


def _check_hops(ck: _Checker, hops, n_channels: int):
    chan = _np(hops.channel)
    valid = _np(hops.valid)
    nbytes = _np(hops.nbytes)
    fixed = _np(hops.fixed_after_ps)
    oob = valid & ((chan < 0) | (chan >= n_channels))
    if np.any(oob):
        r, h = _first(oob)
        ck.add("chan.bounds",
               f"valid hop channel {int(chan[r, h])} outside [0, "
               f"{n_channels})", row=r, hop=h)
    if np.any(valid & (nbytes < 0)):
        r, h = _first(valid & (nbytes < 0))
        ck.add("hop.negative", "nbytes must be non-negative on valid hops",
               row=r, hop=h)
    if np.any(valid & (fixed < 0)):
        r, h = _first(valid & (fixed < 0))
        ck.add("hop.negative",
               "fixed_after_ps must be non-negative on valid hops",
               row=r, hop=h)


def _check_join(ck: _Checker, hops):
    jid = _np(hops.join_id)
    jw = _np(hops.join_wait)
    ja = _np(hops.join_arity)
    present = [a is not None for a in (jid, jw, ja)]
    if not any(present):
        return
    if not all(present):
        ck.add("join.partial",
               "join_id/join_wait/join_arity come as a triple")
        return
    n = jid.shape[0]
    for name, a in (("join_id", jid), ("join_wait", jw)):
        bad = (a < -1) | (a >= n)
        if np.any(bad):
            r, _ = _first(bad)
            ck.add("join.bounds",
                   f"{name} {int(a[r])} outside [-1, {n}): the engine "
                   "resolves group maxes with a row-indexed scatter", row=r)
            return

    n_contrib = np.bincount(jid[jid >= 0], minlength=n) if n else \
        np.zeros(0, np.int64)
    waiters = np.nonzero(jw >= 0)[0]
    bad_ar = waiters[ja[waiters] != n_contrib[jw[waiters]]]
    if bad_ar.size:
        r = int(bad_ar[0])
        g = int(jw[r])
        ck.add("join.arity",
               f"row {r}: join_arity {int(ja[r])} != {int(n_contrib[g])} "
               f"contributors of group {g} (the oracle's release count)",
               row=r)

    # group-graph acyclicity: a contributor row held by an unreleased group
    # blocks its own group's release — propagate releases to a fixpoint
    # (mirrors the oracle's event cascade) and report what never releases
    gated = np.zeros(n, bool)
    gated[waiters[n_contrib[jw[waiters]] > 0]] = True
    remaining = n_contrib.copy()
    np.subtract.at(remaining, jid[(jid >= 0) & ~gated],
                   np.ones(int(((jid >= 0) & ~gated).sum()), np.int64))
    by_wait: dict[int, list[int]] = {}
    for p in waiters[gated[waiters] & (jid[waiters] >= 0)]:
        by_wait.setdefault(int(jw[p]), []).append(int(p))
    queue = list(np.nonzero((remaining == 0) & (n_contrib > 0))[0])
    released = set(queue)
    while queue:
        g = queue.pop()
        for p in by_wait.get(int(g), ()):
            tg = int(jid[p])
            remaining[tg] -= 1
            if remaining[tg] == 0 and tg not in released:
                released.add(tg)
                queue.append(tg)
    stuck = np.nonzero((n_contrib > 0) & (remaining > 0))[0]
    if stuck.size:
        ck.add("join.cycle",
               f"join groups {[int(g) for g in stuck[:8]]} never release — "
               "the group graph is not a DAG (deadlocks the oracle, never "
               "converges in the engine)", row=int(stuck[0]))


def _check_reliability(ck: _Checker, hops, channels, chan_pair=None,
                       reliability=None):
    extra = _np(hops.extra_wire_bytes)
    retrain = _np(hops.retrain_after_ps)
    if extra is None and retrain is None:
        return
    if (extra is None) != (retrain is None):
        ck.add("rel.partial",
               "extra_wire_bytes/retrain_after_ps come as a pair "
               "(finish_hops lowering contract)")
        return
    chan = _np(hops.channel)
    valid = _np(hops.valid)
    nbytes = _np(hops.nbytes)
    n_ch = _np(channels.bw_MBps).shape[0]
    cc = np.clip(chan, 0, n_ch - 1)
    for name, a in (("extra_wire_bytes", extra), ("retrain_after_ps",
                                                  retrain)):
        if np.any(a < 0):
            r, h = _first(a < 0)
            ck.add("rel.negative", f"{name} must be non-negative",
                   row=r, hop=h)
    # NB: extra_wire_bytes on invalid or zero-byte hops is NOT an
    # engine-level error — the engine masks invalid hops entirely and
    # wire_ser_ps serializes extra bytes on valid zero-byte hops just
    # fine.  It only breaks the *sampler's* contract (sample_hop_tables
    # masks on valid & nbytes > 0), so it's checked in `_check_sampling`,
    # which runs when the per-channel sampling tables are supplied
    # (verify_built / an explicit ``reliability=``).
    ppm = _np(channels.replay_ppm)
    if ppm is not None and np.any((extra > 0) & (ppm[cc] > 0) & valid):
        r, h = _first((extra > 0) & (ppm[cc] > 0) & valid)
        ck.add("rel.double-count",
               "sampled replay bytes on a channel with expected-value "
               "replay_ppm > 0 — the two reliability models are mutually "
               "exclusive per channel", row=r, hop=h,
               channel=int(chan[r, h]))

    marker = valid & (nbytes == 0) & (retrain > 0)
    if np.any(marker):
        turn = _np(channels.turnaround_ps)
        row_t = _np(hops.row)
        bad = marker & (turn[cc] != 0)
        if np.any(bad):
            r, h = _first(bad)
            ck.add("rel.marker",
                   "link-down marker on a channel with turnaround != 0 — "
                   "markers are full-duplex-pair mirrors only", row=r, hop=h,
                   channel=int(chan[r, h]))
        bad = marker & (row_t >= 0)
        if np.any(bad):
            r, h = _first(bad)
            ck.add("rel.marker", "link-down marker on a row-managed hop",
                   row=r, hop=h)
        if chan_pair is not None:
            pair = np.asarray(chan_pair)
            for r, h in np.argwhere(marker):
                trig_c = int(chan[r, h - 1]) if h > 0 else -1
                if (h == 0 or not valid[r, h - 1] or nbytes[r, h - 1] <= 0
                        or retrain[r, h - 1] != retrain[r, h]
                        or trig_c < 0 or pair[trig_c] != chan[r, h]):
                    ck.add("rel.marker-pair",
                           "link-down marker not paired with an immediately "
                           "preceding triggering hop on its chan_pair "
                           "partner", row=int(r), hop=int(h),
                           channel=int(chan[r, h]))
                    break

    if reliability is not None:
        _check_sampling(ck, chan, valid, nbytes, extra, retrain, marker
                        if np.any(marker) else np.zeros_like(valid),
                        reliability)


def _check_sampling(ck: _Checker, chan, valid, nbytes, extra, retrain,
                    marker, rel: dict):
    """Quantization + coupled-draw invariants of `link_layer.sample_replays`
    against the per-channel sampling tables (`devices._reliability_tables`
    / `link_layer.broadcast_reliability_tables` layout)."""
    stoch = np.asarray(rel["stochastic"], bool)
    fsize = np.asarray(rel["flit_size"])
    rwin = np.asarray(rel["retry_window"])
    rthr = np.asarray(rel["retrain_threshold"])
    rps = np.asarray(rel["retrain_ps"])
    serving = valid & (nbytes > 0)
    # sample_hop_tables only writes extra bytes where valid & nbytes > 0 —
    # a sample anywhere else means the tables didn't come from the sampler
    if np.any((extra > 0) & ~serving):
        r, h = _first((extra > 0) & ~serving)
        ck.add("rel.extra-on-empty",
               "extra_wire_bytes on a non-serving hop (the sampler only "
               "draws replays for valid hops with payload bytes)",
               row=r, hop=h)
    for c in np.nonzero(stoch)[0]:
        m = serving & (chan == c)
        if not m.any():
            continue
        quantum = max(int(fsize[c]), 1) * max(int(rwin[c]), 1)
        if np.any(extra[m] % quantum != 0):
            r, h = _first(m & (extra % quantum != 0))
            ck.add("rel.replay-quantum",
                   f"extra_wire_bytes not a multiple of the replay quantum "
                   f"{quantum} (flit_size x retry_window) on channel "
                   f"{int(c)}", row=r, hop=h, channel=int(c))
            continue
        ev_m = m | (marker & (chan == c))
        if int(rps[c]) <= 0 or int(rthr[c]) <= 0:
            if np.any(retrain[ev_m] > 0):
                r, h = _first(ev_m & (retrain > 0))
                ck.add("rel.events",
                       f"retrain_after_ps > 0 on channel {int(c)} whose "
                       "sampling tables disable retraining", row=r, hop=h,
                       channel=int(c))
            continue
        if np.any(retrain[ev_m] % int(rps[c]) != 0):
            r, h = _first(ev_m & (retrain % int(rps[c]) != 0))
            ck.add("rel.events",
                   f"retrain_after_ps not a multiple of retrain_ps "
                   f"{int(rps[c])} on channel {int(c)}", row=r, hop=h,
                   channel=int(c))
            continue
        failures = extra // quantum
        events = retrain // int(rps[c])
        bound = failures // int(rthr[c])
        bad = m & (events > bound)
        if np.any(bad):
            r, h = _first(bad)
            ck.add("rel.events",
                   f"retrain events {int(events[r, h])} > failures // "
                   f"retrain_threshold = {int(bound[r, h])} on channel "
                   f"{int(c)} — a hop cannot retrain without having "
                   "sampled the failures that caused it", row=r, hop=h,
                   channel=int(c))


def _check_issue(ck: _Checker, issue, monotone: bool):
    if monotone and issue.size > 1 and np.any(np.diff(issue) < 0):
        r = int(np.argmax(np.diff(issue) < 0)) + 1
        ck.add("issue.monotone",
               f"issue_ps decreases at row {r} — the streaming settlement "
               "rule requires non-decreasing issue clocks", row=r)


def _check_carry(ck: _Checker, carry, n_channels: int, hops):
    for f in ("depart_ps", "last_dir", "last_row", "down_until_ps"):
        a = _np(getattr(carry, f))
        if a.shape != (n_channels,):
            ck.add("carry.shape",
                   f"{f} shape {a.shape} != ({n_channels},)")
            return
    ck.expect_dtype(_np(carry.depart_ps), "int64", "depart_ps", code="carry")
    ck.expect_dtype(_np(carry.last_dir), "int8", "last_dir", code="carry")
    ck.expect_dtype(_np(carry.last_row), "int32", "last_row", code="carry")
    ck.expect_dtype(_np(carry.down_until_ps), "int64", "down_until_ps",
                    code="carry")
    dep = _np(carry.depart_ps)
    down = _np(carry.down_until_ps)
    if np.any(dep < 0):
        ck.add("carry.frontier", "depart_ps frontier must be non-negative "
               "(0 = channel never served)", channel=_first(dep < 0)[0])
    if np.any(down < 0):
        ck.add("carry.frontier", "down_until_ps must be non-negative",
               channel=_first(down < 0)[0])
    # a settled down_until marker can extend past the frontier, but a
    # serving frontier behind time 0 or a direction outside the encoding
    # is a corrupted carry
    ld = _np(carry.last_dir)
    if np.any((ld < -1) | (ld > 1)):
        ck.add("carry.frontier", "last_dir must be in {-1, 0, 1}",
               channel=_first((ld < -1) | (ld > 1))[0])
    lr = _np(carry.last_row)
    if np.any(lr < -2):
        ck.add("carry.frontier", "last_row must be >= -2 (-2 = cold)",
               channel=_first(lr < -2)[0])
    seed = _np(carry.join_seed_ps)
    if seed is not None:
        if _np(hops.join_id) is None:
            ck.add("carry.join-seed",
                   "join_seed_ps without join tables on the window's Hops "
                   "(StreamCarry contract)")
        elif seed.shape != (_np(hops.channel).shape[0],):
            ck.add("carry.join-seed",
                   f"join_seed_ps shape {seed.shape} != window rows "
                   f"({_np(hops.channel).shape[0]},) — seeds live in the "
                   "window's group-id space")
        elif np.any(seed < 0):
            ck.add("carry.join-seed", "join_seed_ps must be non-negative",
                   row=_first(seed < 0)[0])


def _check_sf_events(ck: _Checker, ev):
    fab = _np(ev.fab_issue_ps)
    t = fab.shape[0] if fab.ndim == 1 else -1
    if t < 0:
        ck.add("sf.shape", f"fab_issue_ps must be (T,), got {fab.shape}")
        return
    for f in ("cache_hit", "bisnp_mask", "inv_lines", "wb_lines",
              "need_victim", "conflict", "invblk_len"):
        a = _np(getattr(ev, f))
        if a.shape != (t,):
            ck.add("sf.shape", f"{f} shape {a.shape} != ({t},)")
            return
    if np.any(fab < 0):
        ck.add("sf.negative", "fab_issue_ps must be non-negative",
               row=_first(fab < 0)[0])
    for f in ("bisnp_mask", "inv_lines", "wb_lines", "invblk_len"):
        a = _np(getattr(ev, f))
        if np.any(a < 0):
            ck.add("sf.negative", f"{f} must be non-negative",
                   row=_first(a < 0)[0])
    hit = _np(ev.cache_hit).astype(bool)
    snoop = _np(ev.bisnp_mask) != 0
    conflict = _np(ev.conflict).astype(bool)
    bad = hit & snoop & ~conflict
    if np.any(bad):
        ck.add("sf.hit-snoop",
               "cache hit with BISnp traffic but no write conflict — hits "
               "only snoop as upgrade-BISnps (lowering contract)",
               row=_first(bad)[0])


# ---------------------------------------------------------------------------
# Round-bound derivation (host-side; engine.round_bound wraps it)
# ---------------------------------------------------------------------------

def join_depth(join_id, join_wait) -> int:
    """Longest fork/join chain through rows — the join nesting depth.

    ``depth(p) = 0`` for a row that waits on no group, else ``1 + max``
    depth of the rows contributing to the group it waits on (0 when the
    group has no contributors).  The returned value is the maximum over
    all rows: the number of join *levels* a completion time can cascade
    through before every gate is final.  Computed by the same
    release-propagation fixpoint `_check_join` runs for acyclicity —
    vectorized scatter-max passes, each extending every chain by one
    level, so a DAG stabilizes in at most N+1 passes.  A cyclic group
    graph (flagged separately as ``join.cycle``) is capped at N.

    Pure numpy, no engine import — callable at build/verify time.
    """
    if join_id is None or join_wait is None:
        return 0
    jid = np.asarray(join_id).astype(np.int64)
    jw = np.asarray(join_wait).astype(np.int64)
    n = jid.shape[0]
    if n == 0 or not np.any(jw >= 0):
        return 0
    contrib = jid >= 0
    cid = np.where(contrib, jid, 0)
    depth = np.zeros(n, np.int64)
    for _ in range(n + 1):
        gd = np.zeros(n, np.int64)
        np.maximum.at(gd, cid[contrib], depth[contrib])
        new = np.where(jw >= 0, 1 + gd[np.clip(jw, 0, n - 1)], 0)
        if np.array_equal(new, depth):
            return int(depth.max())
        depth = new
    return n  # cyclic group graph: flagged by join.cycle, cap the bound


def round_bound(n_hops: int, join_id=None, join_wait=None) -> int:
    """Sufficient fixpoint round budget for a lowered workload.

    Chain-only traffic needs at most one round per queue position a delay
    can cascade through — ``3*H + 8`` covers every chain-only layout in
    the suite with slack (the engine's historical default).  Each join
    level re-gates issue times *after* a full sub-schedule resolves, so a
    join-depth-D lowering needs at most D+1 such phases:

        bound = (join_depth + 1) * (3*H + 8)

    Chain-only lowerings (depth 0) get exactly the historical heuristic;
    join-heavy coherence lowerings get a budget that provably covers their
    gating cascade instead of a hand-tuned constant.  Generosity is free
    at runtime — `engine.simulate` early-exits its ``lax.while_loop`` on
    the first unchanged round.
    """
    per_level = 3 * int(n_hops) + 8
    return (join_depth(join_id, join_wait) + 1) * per_level


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def verify_workload(hops, channels, issue_ps, *, carry=None, sf_events=None,
                    reliability=None, chan_pair=None,
                    monotone_issue: bool = False,
                    max_rounds: int | None = None) -> VerifyReport:
    """Validate a lowered ``(Hops, Channels, issue_ps)`` triple statically.

    Optional extensions widen the contract set actually checked:

    carry          `engine.StreamCarry` about to seed this window.
    sf_events      `snoop_filter.SFEvents` the lowering consumed.
    reliability    per-channel sampling tables (the dict shape of
                   `devices._reliability_tables` /
                   `link_layer.broadcast_reliability_tables`) — enables the
                   replay-quantum and ``events <= failures //
                   retrain_threshold`` invariants.
    chan_pair      `FabricGraph.chan_pair` — enables full-duplex pair
                   symmetry and marker-pairing checks.
    monotone_issue require non-decreasing issue clocks (the
                   `streaming.stream_windows` input contract).
    max_rounds     an explicit round budget the caller intends to run the
                   fixpoint with — flagged as ``join.depth`` when it is
                   positive but below the computed `round_bound` (the
                   budget cannot guarantee convergence).  ``None`` / 0
                   (engine default = computed bound) checks nothing.

    Returns a `VerifyReport`; never raises on findings (use `assert_valid`
    or ``report.raise_if_failed()`` for the strict mode).
    """
    ck = _Checker()
    issue = _np(issue_ps)
    n_ch = int(_np(channels.bw_MBps).shape[0])
    _check_channels(ck, channels)
    if chan_pair is not None:
        pair = np.asarray(chan_pair)
        has = pair >= 0
        idx = np.nonzero(has)[0]
        bad = idx[(pair[idx] >= pair.shape[0])
                  | (np.where(pair[idx] < pair.shape[0],
                              pair[np.clip(pair[idx], 0, pair.shape[0] - 1)],
                              -1) != idx)]
        if bad.size:
            ck.add("chan.pair",
                   f"chan_pair asymmetry: pair[pair[{int(bad[0])}]] != "
                   f"{int(bad[0])} — full-duplex retrain mirroring needs "
                   "an involution", channel=int(bad[0]))
    if _check_shapes_dtypes(ck, hops, issue):
        _check_hops(ck, hops, n_ch)
        _check_join(ck, hops)
        _check_reliability(ck, hops, channels, chan_pair=chan_pair,
                           reliability=reliability)
        _check_issue(ck, issue, monotone_issue)
        if carry is not None:
            _check_carry(ck, carry, n_ch, hops)
        if max_rounds is not None and max_rounds > 0:
            jid, jw = _np(hops.join_id), _np(hops.join_wait)
            depth = join_depth(jid, jw)
            bound = round_bound(_np(hops.channel).shape[1], jid, jw)
            if max_rounds < bound:
                ck.add("join.depth",
                       f"round budget {max_rounds} below the computed "
                       f"bound {bound} (join depth {depth}) — the fixpoint "
                       "may report converged=False on traffic the bound "
                       "provably covers")
    if sf_events is not None:
        _check_sf_events(ck, sf_events)
    return VerifyReport(findings=tuple(ck.findings),
                        n_rows=int(_np(hops.channel).shape[0])
                        if _np(hops.channel).ndim == 2 else 0,
                        n_channels=n_ch)


def assert_valid(hops, channels, issue_ps, **kw) -> VerifyReport:
    """Strict one-liner for benchmark setups and test fixtures: verify and
    raise `VerifyError` on any finding; returns the clean report."""
    return verify_workload(hops, channels, issue_ps, **kw).raise_if_failed()


def verify_built(workload, graph=None) -> VerifyReport:
    """Verify a `devices.Workload` (optionally against its source graph's
    ``chan_pair`` / reliability tables) — the benchmark-setup gate."""
    kw = {}
    if graph is not None:
        kw["chan_pair"] = graph.chan_pair
        if np.any(np.asarray(graph.chan_rel_stochastic)):
            kw["reliability"] = dict(
                stochastic=graph.chan_rel_stochastic,
                err_p=graph.chan_flit_err_p,
                flit_size=graph.chan_flit_size,
                flit_payload=graph.chan_flit_payload,
                retry_window=graph.chan_retry_window,
                retrain_threshold=graph.chan_retrain_threshold,
                retrain_ps=graph.chan_retrain_ps,
                rel_seed=graph.chan_rel_seed,
            )
    return verify_workload(workload.hops, workload.channels,
                           workload.issue_ps, **kw)
