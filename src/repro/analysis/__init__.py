"""Static-analysis layer: jit-safety lint + kernel signature cross-checks.

``python -m repro.analysis [paths...]`` runs the repo-specific AST lint
(`repro.analysis.jitlint`) over the source tree and gates on the committed
per-file allowlist (``baseline.toml``) — intentional host syncs (the
`ref_des` oracle, trace export, benchmark drivers) are explicit, and any
new violation fails CI.  The fabric-IR verifier this pairs with lives in
`repro.core.verify`; ``python -m repro.analysis.verify_smoke`` runs it over
every lowering path the benchmarks exercise.
"""

from .jitlint import Finding, lint_paths, load_baseline, apply_baseline  # noqa: F401
