"""CLI for the jit-safety lint: ``python -m repro.analysis [paths...]``.

Exit status:
  0  — no findings beyond the committed baseline
  1  — new findings (printed one per line, ``file:line: [rule] message``)
  2  — usage / baseline-format error

The baseline (``--baseline``, default: the committed
``src/repro/analysis/baseline.toml``) allowlists *intentional* violations
per (file, rule) with a count and a one-line reason.  If a file's live
count for a rule exceeds its baselined count, the overflow is reported as
new findings; if the live count drops below the baseline, a "stale"
warning is printed (non-fatal) so the entry can be tightened.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from pathlib import Path

from .jitlint import apply_baseline, lint_paths, load_baseline

_DEFAULT_BASELINE = Path(__file__).resolve().parent / "baseline.toml"


def _emit_baseline(findings) -> str:
    """Render the current findings as a baseline.toml skeleton."""
    counts: dict[tuple[str, str], int] = {}
    for f in findings:
        counts[(f.path, f.rule)] = counts.get((f.path, f.rule), 0) + 1
    lines = ["# jit-safety lint baseline — every entry needs a reason.", ""]
    for (file, rule), n in sorted(counts.items()):
        lines += [
            "[[baseline]]",
            f'file = "{file}"',
            f'rule = "{rule}"',
            f"count = {n}",
            'reason = "TODO: justify or fix"',
            "",
        ]
    return "\n".join(lines)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Repo-specific jit-safety AST lint.",
    )
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to lint (default: src)")
    ap.add_argument("--baseline", type=Path, default=_DEFAULT_BASELINE,
                    help="baseline TOML path (default: committed baseline)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, ignoring the baseline")
    ap.add_argument("--emit-baseline", action="store_true",
                    help="print a baseline.toml covering current findings")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit findings as a JSON array")
    args = ap.parse_args(argv)

    paths = [Path(p) for p in (args.paths or ["src"])]
    missing = [p for p in paths if not p.exists()]
    if missing:
        print(f"error: no such path: {', '.join(map(str, missing))}",
              file=sys.stderr)
        return 2

    findings = lint_paths(paths)

    if args.emit_baseline:
        print(_emit_baseline(findings))
        return 0

    stale: list[str] = []
    if not args.no_baseline:
        if args.baseline.exists():
            try:
                entries = load_baseline(args.baseline)
            except ValueError as e:
                print(f"error: bad baseline {args.baseline}: {e}",
                      file=sys.stderr)
                return 2
            findings, stale = apply_baseline(findings, entries)
        elif args.baseline != _DEFAULT_BASELINE:
            print(f"error: baseline not found: {args.baseline}",
                  file=sys.stderr)
            return 2

    if args.as_json:
        print(json.dumps([dataclasses.asdict(f) for f in findings], indent=2))
    else:
        for f in findings:
            print(str(f))
        for s in stale:
            print(f"warning: stale {s}", file=sys.stderr)
        if findings:
            n = len(findings)
            print(f"\n{n} new finding{'s' if n != 1 else ''} "
                  "(fix, or baseline with a reason)", file=sys.stderr)
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
