"""Verifier smoke: build + statically verify every lowering path.

``python -m repro.analysis.verify_smoke`` constructs one small instance of
each lowering the benchmarks exercise — demand workloads, stochastic link
reliability (sampled replay tables + retrain markers), coherence traffic
under both fan-out models, and streaming windows — and runs
`repro.core.verify` over the result.  Any structured finding is a bug in a
lowering (or in the verifier's model of its contract) and fails the run.

This is the CI-facing complement to ``tests/test_verify.py``: the tests
prove the verifier *catches* seeded-invalid tables; this proves every real
lowering *passes* it.
"""

from __future__ import annotations

import sys

import numpy as np

import repro.core  # noqa: F401  (x64)
from repro.core import topology as T
from repro.core import verify
from repro.core.coherence_traffic import (CoherenceFabricSpec,
                                          coherence_issue, lower_coherence)
from repro.core.devices import RequesterSpec, build_workload
from repro.core.engine import make_channels
from repro.core.link_layer import FlitConfig
from repro.core.snoop_filter import (CacheConfig, SFConfig,
                                     make_skewed_stream, simulate_sf)
from repro.core.streaming import stream_windows


def _report(name: str, rep: verify.VerifyReport) -> bool:
    status = "ok" if rep.ok else "FAIL"
    print(f"  {name:<28s} {status}  "
          f"({rep.n_rows} rows x {rep.n_channels} channels)")
    if not rep.ok:
        print(rep.summary())
    return rep.ok


def smoke_demand() -> bool:
    """Deterministic demand lowering on tree + single-bus topologies."""
    ok = True
    for name, topo in [
        ("demand/tree", T.tree(n_pairs=4, bw_MBps=64_000)),
        ("demand/single_bus", T.single_bus(n_mems=3, bw_MBps=64_000)),
    ]:
        graph = topo.build()
        mems = [int(i) for i in
                np.flatnonzero(graph.topo.kinds == T.MEMORY)]
        spec = RequesterSpec(node=int(np.flatnonzero(
                                 graph.topo.kinds == T.REQUESTER)[0]),
                             n_requests=200, targets=mems,
                             read_ratio=0.5, issue_interval_ps=40_000,
                             payload_bytes=256, seed=3)
        wl = build_workload(graph, [spec], header_bytes=64, warmup_frac=0.0)
        ok &= _report(name, verify.verify_built(wl, graph))
    return ok


def smoke_reliability() -> bool:
    """Stochastic flit reliability: sampled replay bytes, retrain markers,
    chan_pair mirroring — the invariants `rel.*` / `chan.pair` gate."""
    # ber/threshold chosen so the sampled tables actually contain replay
    # bytes AND retrain markers (~170 at this scale) — a quieter link would
    # leave the rel.marker / chan.pair checks vacuous.
    flit = FlitConfig("flit256", ber=1e-4, reliability="stochastic",
                      rel_seed=7, retrain_threshold=2, retrain_ps=2_000_000)
    topo = T.with_flit(T.single_bus(n_mems=4, bw_MBps=64_000), flit)
    graph = topo.build()
    spec = RequesterSpec(node=0, n_requests=600, targets=[2, 3, 4, 5],
                         pattern="uniform", read_ratio=0.5,
                         issue_interval_ps=100, payload_bytes=944, seed=11)
    wl = build_workload(graph, [spec], header_bytes=64, warmup_frac=0.0)
    return _report("reliability/stochastic", verify.verify_built(wl, graph))


def _coherence(graph, spec, fanout: str, n_req: int):
    addr, wr, rid = make_skewed_stream(300, 256, write_ratio=0.3,
                                       n_requesters=n_req, seed=5)
    cfg = SFConfig(capacity=32, policy="fifo", footprint_lines=256)
    _, ev = simulate_sf(addr, wr, rid, cfg, CacheConfig(capacity=32),
                        n_requesters=n_req, return_events=True)
    low = lower_coherence(graph, spec, cfg, addr, wr, rid, ev, fanout=fanout)
    ch = make_channels(graph)
    issue = coherence_issue(low, ev.fab_issue_ps)
    return verify.verify_workload(low.hops, ch, issue, sf_events=ev,
                                  chan_pair=graph.chan_pair)


def smoke_coherence() -> bool:
    """Coherence lowering: serialized chain and fork/join concurrent
    fan-out (the `join.*` invariants only exist on the concurrent path)."""
    n_req = 2
    kinds = [T.SWITCH] + [T.REQUESTER] * n_req + [T.MEMORY]
    links = [T.LinkSpec(i, 0, 64_000, 26_000) for i in range(1, len(kinds))]
    graph = T.Topology(np.asarray(kinds, np.int64), links,
                       name="star").build()
    spec = CoherenceFabricSpec(dev_node=n_req + 1,
                               req_nodes=tuple(range(1, n_req + 1)))
    ok = True
    for fanout in ("chain", "concurrent"):
        ok &= _report(f"coherence/{fanout}",
                      _coherence(graph, spec, fanout, n_req))
    return ok


def smoke_streaming() -> bool:
    """Every window a trace splitter emits must verify stand-alone (the
    same precondition `streaming.simulate_stream` now checks per chunk)."""
    topo = T.single_bus(n_mems=3, bw_MBps=64_000)
    graph = topo.build()
    spec = RequesterSpec(node=0, n_requests=500, targets=[2, 3, 4],
                         read_ratio=0.5, issue_interval_ps=30_000,
                         payload_bytes=128, seed=9)
    wl = build_workload(graph, [spec], header_bytes=64, warmup_frac=0.0)
    ok, n = True, 0
    for i, (h, issue) in enumerate(
            stream_windows(wl.hops, np.asarray(wl.issue_ps), 128)):
        rep = verify.verify_workload(h, wl.channels, issue)
        n += 1
        if not rep.ok:
            ok = _report(f"streaming/window[{i}]", rep)
    if ok:
        print(f"  {'streaming/windows':<28s} ok  ({n} windows)")
    return ok


def main() -> int:
    print("verify_smoke: static verification of every lowering path")
    ok = True
    ok &= smoke_demand()
    ok &= smoke_reliability()
    ok &= smoke_coherence()
    ok &= smoke_streaming()
    print("verify_smoke:", "clean" if ok else "FINDINGS — see above")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
