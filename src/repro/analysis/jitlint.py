"""jit-safety lint: an AST analysis pass over the repo's hot paths.

JAX's tracing model makes a specific bug class *silent*: code that is
perfectly legal Python but wrong (or a performance cliff) inside a jitted
computation.  This repo has been bitten before — the int64 scratch store in
the `link_contention` kernel (PR 1) no-op'd through exactly the pattern
rule 1 catches.  The rules are repo-specific, not generic style:

  discarded-at-update   ``x.at[i].set(v)`` (or ``.add/.max/.min/.mul/
                        .divide/.power/.apply``) used as a statement — JAX
                        arrays are immutable, so the un-assigned result is
                        a silent no-op.
  host-sync-in-jit      ``.item()``, ``.tolist()``, ``.block_until_ready()``,
                        ``np.asarray``/``np.array``, ``jax.device_get``, or
                        ``int()/float()/bool()`` on a non-literal — inside
                        a function *reachable from a jit/scan body* (the
                        call graph is computed from the module ASTs: scan/
                        while_loop/cond/fori_loop body functions, ``jax.jit``
                        call sites and decorators are the roots).  Under
                        trace these either fail or force a blocking
                        device→host transfer per call.
  traced-truthiness     ``if x:`` / ``while x:`` where ``x`` flows from a
                        ``jnp`` op inside a jit-reachable function —
                        a guaranteed ``TracerBoolConversionError`` at jit
                        time, but only on the branch that traces it.
  np-in-scan            any ``np.*`` call inside a jit-reachable function
                        of ``core/engine.py`` or ``core/streaming.py`` —
                        the two modules whose scan callees must stay pure
                        jnp (a numpy op in a scan body constant-folds the
                        traced value or breaks the trace).
  kernel-signature      each ``kernels/*/kernel.py`` public entry must
                        match its ``ref.py`` oracle's positional signature
                        and be wrapped by ``ops.py`` — the dispatch
                        contract that keeps oracle equality tests honest.

The pass is *static over-approximation kept deliberately tight*: call
edges resolve only through same-module scopes, explicit ``from X import
f`` bindings, and module-alias attribute calls (``link_layer.f(...)``), so
reachability never guesses across unrelated same-named functions.  What it
cannot prove it does not flag; what it flags and a human has judged
intentional lives in ``baseline.toml`` with a one-line reason, and
`apply_baseline` fails anything beyond the committed counts.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass
from pathlib import Path

AT_UPDATE_METHODS = frozenset(
    {"set", "add", "max", "min", "mul", "multiply", "divide", "power",
     "apply", "get"})
HOST_SYNC_ATTRS = frozenset({"item", "tolist", "block_until_ready"})
NP_SYNC_FUNCS = frozenset({"asarray", "array"})
SCALARIZERS = frozenset({"int", "float", "bool"})
NP_SCAN_MODULES = ("repro.core.engine", "repro.core.streaming")

# (callable dotted-name suffix) -> positional indices of function operands
_TRACE_ENTRY_ARGS = {
    "lax.scan": (0,),
    "lax.while_loop": (0, 1),
    "lax.cond": (1, 2),
    "lax.fori_loop": (2,),
    "lax.associative_scan": (0,),
    "jax.jit": (0,),
    "jax.vmap": (0,),
    "jax.pmap": (0,),
    "jax.grad": (0,),
    "jax.checkpoint": (0,),
}


@dataclass(frozen=True)
class Finding:
    path: str     # repo-relative posix path
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclass
class _Func:
    module: "_Module"
    qualname: str
    node: ast.AST                  # FunctionDef / AsyncFunctionDef / Lambda
    parent: "_Func | None"
    children: dict                 # name -> _Func (direct defs only)
    calls: list                    # ast.Call nodes in this body (not nested)
    reachable: bool = False
    root_reason: str = ""


class _Module:
    def __init__(self, path: Path, rel: str, name: str, tree: ast.Module):
        self.path = path
        self.rel = rel
        self.name = name
        self.is_pkg = path.name == "__init__.py"
        self.tree = tree
        self.funcs: dict[int, _Func] = {}       # id(node) -> _Func
        self.top: dict[str, _Func] = {}          # module-level defs
        self.aliases: dict[str, str] = {}        # alias -> module fullname
        self.from_imports: dict[str, tuple[str, str]] = {}  # name -> (mod, orig)


def _module_name(path: Path, root: Path) -> str:
    try:
        rel = path.relative_to(root).with_suffix("")
    except ValueError:
        rel = Path(path.parent.name) / path.with_suffix("").name
    parts = list(rel.parts)
    if parts and parts[0] == "src":
        parts = parts[1:]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _resolve_relative(module: str, level: int, target: str | None,
                      is_pkg: bool) -> str:
    # Module names never include "__init__": a package's own name is its
    # package, a plain module's package is its parent.
    base = module.split(".") if is_pkg else module.split(".")[:-1]
    if level > 1:
        base = base[: len(base) - (level - 1)]
    return ".".join(base + ([target] if target else []))


class _Collector(ast.NodeVisitor):
    """One pass per module: function index, import maps, call lists."""

    def __init__(self, mod: _Module):
        self.mod = mod
        self.stack: list[_Func] = []

    def _add_func(self, name: str, node) -> _Func:
        parent = self.stack[-1] if self.stack else None
        qual = (parent.qualname + "." + name) if parent else name
        f = _Func(self.mod, qual, node, parent, {}, [])
        self.mod.funcs[id(node)] = f
        if parent is None:
            self.mod.top[name] = f
        else:
            parent.children[name] = f
        return f

    def _walk_func(self, f: _Func, body):
        self.stack.append(f)
        for stmt in body:
            self.visit(stmt)
        self.stack.pop()

    def visit_Import(self, node):
        for a in node.names:
            self.mod.aliases[a.asname or a.name.split(".")[0]] = (
                a.name if a.asname else a.name.split(".")[0])

    def visit_ImportFrom(self, node):
        src = (_resolve_relative(self.mod.name, node.level, node.module,
                                 self.mod.is_pkg)
               if node.level else (node.module or ""))
        for a in node.names:
            if a.name == "*":
                continue
            self.mod.from_imports[a.asname or a.name] = (src, a.name)

    def visit_FunctionDef(self, node):
        f = self._add_func(node.name, node)
        for d in node.decorator_list:
            self.visit(d)
        self._walk_func(f, node.body)

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node):
        f = self._add_func(f"<lambda:{node.lineno}>", node)
        self.stack.append(f)
        self.visit(node.body)
        self.stack.pop()

    def visit_Call(self, node):
        if self.stack:
            self.stack[-1].calls.append(node)
        self.generic_visit(node)


def _dotted(node) -> str | None:
    """'a.b.c' for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class Linter:
    def __init__(self, files: list[Path], repo_root: Path):
        self.repo_root = repo_root
        self.modules: list[_Module] = []
        self.by_name: dict[str, _Module] = {}
        self.findings: list[Finding] = []
        for p in sorted(files):
            try:
                tree = ast.parse(p.read_text(), filename=str(p))
            except SyntaxError as e:
                self._emit(p, e.lineno or 0, "syntax-error", str(e.msg))
                continue
            rel = p.relative_to(repo_root).as_posix() \
                if p.is_relative_to(repo_root) else p.as_posix()
            mod = _Module(p, rel, _module_name(p, repo_root), tree)
            _Collector(mod).visit(tree)
            self.modules.append(mod)
            self.by_name[mod.name] = mod

    def _emit(self, path, line, rule, message):
        rel = (path.relative_to(self.repo_root).as_posix()
               if isinstance(path, Path) and path.is_relative_to(self.repo_root)
               else str(path))
        self.findings.append(Finding(rel, int(line), rule, message))

    # -- call resolution ---------------------------------------------------

    def _resolve_name(self, mod: _Module, scope: _Func | None,
                      name: str) -> _Func | None:
        s = scope
        while s is not None:
            if name in s.children:
                return s.children[name]
            s = s.parent
        if name in mod.top:
            return mod.top[name]
        if name in mod.from_imports:
            src, orig = mod.from_imports[name]
            target = self.by_name.get(src)
            if target is not None:
                return target.top.get(orig)
        return None

    def _resolve_call(self, mod: _Module, scope: _Func | None,
                      func_expr) -> _Func | None:
        if isinstance(func_expr, ast.Name):
            return self._resolve_name(mod, scope, func_expr.id)
        if isinstance(func_expr, ast.Attribute) and \
                isinstance(func_expr.value, ast.Name):
            alias = func_expr.value.id
            target_name = None
            if alias in mod.from_imports:          # from repro.core import x
                src, orig = mod.from_imports[alias]
                target_name = src + "." + orig
            elif alias in mod.aliases:             # import repro.core as x
                target_name = mod.aliases[alias]
            if target_name is not None:
                target = self.by_name.get(target_name)
                if target is not None:
                    return target.top.get(func_expr.attr)
        return None

    # -- jit-root discovery + reachability ---------------------------------

    def _mark_roots(self):
        for mod in self.modules:
            # decorator roots: @jax.jit / @jit / @partial(jax.jit, ...)
            for f in mod.funcs.values():
                node = f.node
                for d in getattr(node, "decorator_list", ()):
                    expr = d.func if isinstance(d, ast.Call) else d
                    name = _dotted(expr) or ""
                    inner = ""
                    if isinstance(d, ast.Call) and name.endswith("partial") \
                            and d.args:
                        inner = _dotted(d.args[0]) or ""
                    for cand in (name, inner):
                        if cand in ("jit", "jax.jit", "pjit", "jax.pjit") or \
                                cand.endswith(".jit"):
                            f.reachable = True
                            f.root_reason = f"@{cand}"
            # call-site roots: functions handed to scan/while/cond/jit/vmap
            for f in list(mod.funcs.values()) + [None]:
                calls = (f.calls if f is not None else
                         [n for n in ast.walk(mod.tree)
                          if isinstance(n, ast.Call)
                          and id(n) not in self._calls_in_funcs(mod)])
                for call in calls:
                    name = _dotted(call.func) or ""
                    for suffix, arg_ix in _TRACE_ENTRY_ARGS.items():
                        if not (name == suffix or name.endswith("." + suffix)
                                or ("." in suffix
                                    and name == suffix.split(".")[-1])):
                            continue
                        for i in arg_ix:
                            if i >= len(call.args):
                                continue
                            arg = call.args[i]
                            target = None
                            if isinstance(arg, (ast.Lambda,)):
                                target = mod.funcs.get(id(arg))
                            elif isinstance(arg, ast.Name):
                                target = self._resolve_name(mod, f, arg.id)
                            if target is not None and not target.reachable:
                                target.reachable = True
                                target.root_reason = f"passed to {name}"

    def _calls_in_funcs(self, mod: _Module) -> set[int]:
        ids: set[int] = set()
        for f in mod.funcs.values():
            ids.update(id(c) for c in f.calls)
        return ids

    def _propagate(self):
        work = [f for mod in self.modules for f in mod.funcs.values()
                if f.reachable]
        seen = {id(f.node) for f in work}
        while work:
            f = work.pop()
            for call in f.calls:
                target = self._resolve_call(f.module, f, call.func)
                if target is not None and id(target.node) not in seen:
                    seen.add(id(target.node))
                    target.reachable = True
                    target.root_reason = (
                        f"called from {f.qualname} ({f.root_reason})")
                    work.append(target)

    # -- rules -------------------------------------------------------------

    def _rule_discarded_at(self):
        for mod in self.modules:
            for node in ast.walk(mod.tree):
                if not isinstance(node, ast.Expr):
                    continue
                call = node.value
                if not (isinstance(call, ast.Call)
                        and isinstance(call.func, ast.Attribute)
                        and call.func.attr in AT_UPDATE_METHODS):
                    continue
                base = call.func.value
                if isinstance(base, ast.Subscript) and \
                        isinstance(base.value, ast.Attribute) and \
                        base.value.attr == "at":
                    self._emit(mod.path, node.lineno, "discarded-at-update",
                               f".at[...].{call.func.attr}(...) result "
                               "discarded — JAX arrays are immutable, this "
                               "is a silent no-op")

    def _rule_host_sync(self):
        for mod in self.modules:
            in_scan_mod = mod.name in NP_SCAN_MODULES
            for f in mod.funcs.values():
                if not f.reachable:
                    continue
                for call in f.calls:
                    name = _dotted(call.func) or ""
                    where = f"in jit-reachable {f.qualname} ({f.root_reason})"
                    if isinstance(call.func, ast.Attribute) and \
                            call.func.attr in HOST_SYNC_ATTRS and \
                            not name.startswith(("np.", "numpy.")):
                        self._emit(mod.path, call.lineno, "host-sync-in-jit",
                                   f".{call.func.attr}() {where} forces a "
                                   "device sync under trace")
                        continue
                    if name.split(".")[0] in ("np", "numpy"):
                        attr = name.split(".", 1)[1] if "." in name else ""
                        if attr in NP_SYNC_FUNCS:
                            self._emit(mod.path, call.lineno,
                                       "host-sync-in-jit",
                                       f"{name}() {where} pulls the traced "
                                       "value to the host")
                        elif in_scan_mod:
                            self._emit(mod.path, call.lineno, "np-in-scan",
                                       f"{name}() {where} — engine/streaming "
                                       "scan callees must stay pure jnp")
                        continue
                    if name in ("jax.device_get",):
                        self._emit(mod.path, call.lineno, "host-sync-in-jit",
                                   f"{name}() {where}")
                        continue
                    if isinstance(call.func, ast.Name) and \
                            call.func.id in SCALARIZERS and \
                            len(call.args) == 1 and not call.keywords and \
                            self._test_is_traced(
                                call.args[0], self._tracked_names(f)):
                        self._emit(mod.path, call.lineno, "host-sync-in-jit",
                                   f"{call.func.id}(...) on a jnp-derived "
                                   f"value {where} concretizes a traced "
                                   "value")

    def _rule_traced_truthiness(self):
        for mod in self.modules:
            for f in mod.funcs.values():
                if not f.reachable or not isinstance(
                        f.node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                tracked = self._tracked_names(f)
                if not tracked:
                    continue
                for node in self._own_nodes(f):
                    if not isinstance(node, (ast.If, ast.While)):
                        continue
                    if self._test_is_traced(node.test, tracked):
                        self._emit(mod.path, node.lineno,
                                   "traced-truthiness",
                                   "Python truthiness on a value that flows "
                                   f"from a jnp op, in jit-reachable "
                                   f"{f.qualname} — raises "
                                   "TracerBoolConversionError under trace")

    def _own_nodes(self, f: _Func):
        """All AST nodes of a function body, not descending into nested
        function definitions (they have their own _Func records)."""
        body = getattr(f.node, "body", [])
        stack = list(body) if isinstance(body, list) else [body]
        while stack:
            node = stack.pop()
            yield node
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                continue
            stack.extend(ast.iter_child_nodes(node))

    def _tracked_names(self, f: _Func) -> set[str]:
        """Names assigned (transitively) from a jnp op inside this body."""
        tracked: set[str] = set()
        for node in self._own_nodes(f):
            if not isinstance(node, (ast.Assign, ast.AugAssign)):
                continue
            value = node.value
            is_jnp = False
            for sub in ast.walk(value):
                if isinstance(sub, ast.Call):
                    name = _dotted(sub.func) or ""
                    if name.split(".")[0] == "jnp":
                        is_jnp = True
                elif isinstance(sub, ast.Name) and sub.id in tracked:
                    is_jnp = True
            if not is_jnp:
                continue
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for t in targets:
                for sub in ast.walk(t):
                    if isinstance(sub, ast.Name):
                        tracked.add(sub.id)
        return tracked

    # Attribute reads that stay static under trace (safe in `if`):
    _STATIC_ATTRS = frozenset(
        {"shape", "ndim", "dtype", "size", "at", "weak_type", "sharding"})

    def _test_is_traced(self, test, tracked: set[str]) -> bool:
        if isinstance(test, ast.Name):
            return test.id in tracked
        if isinstance(test, ast.UnaryOp):
            return self._test_is_traced(test.operand, tracked)
        if isinstance(test, ast.BoolOp):
            return any(self._test_is_traced(v, tracked) for v in test.values)
        if isinstance(test, ast.Compare):
            if all(isinstance(op, (ast.Is, ast.IsNot, ast.In, ast.NotIn))
                   for op in test.ops):
                return False  # identity/membership checks are static
            return (self._test_is_traced(test.left, tracked)
                    or any(self._test_is_traced(c, tracked)
                           for c in test.comparators))
        if isinstance(test, ast.BinOp):
            return (self._test_is_traced(test.left, tracked)
                    or self._test_is_traced(test.right, tracked))
        if isinstance(test, ast.Subscript):
            return self._test_is_traced(test.value, tracked)
        if isinstance(test, ast.Attribute):
            if test.attr in self._STATIC_ATTRS:
                return False
            return self._test_is_traced(test.value, tracked)
        if isinstance(test, ast.Call):
            name = _dotted(test.func) or ""
            if name.split(".")[0] == "jnp":
                return True
            if isinstance(test.func, ast.Attribute):
                # method on a tracked value: x.sum(), x.any(), ...
                return self._test_is_traced(test.func.value, tracked)
        return False

    # -- kernel signature cross-check --------------------------------------

    def _rule_kernel_signatures(self):
        pkgs: dict[str, dict[str, _Module]] = {}
        for mod in self.modules:
            parts = mod.name.split(".")
            if len(parts) >= 3 and parts[-3] == "kernels" and \
                    parts[-1] in ("kernel", "ref", "ops"):
                pkgs.setdefault(".".join(parts[:-1]), {})[parts[-1]] = mod
        for pkg, mods in sorted(pkgs.items()):
            if set(mods) != {"kernel", "ref", "ops"}:
                missing = {"kernel", "ref", "ops"} - set(mods)
                anymod = next(iter(mods.values()))
                self._emit(anymod.path, 1, "kernel-signature",
                           f"kernel package {pkg} is missing "
                           f"{sorted(missing)} modules")
                continue
            self._check_kernel_pkg(pkg, mods)

    @staticmethod
    def _positional(node) -> list[str]:
        a = node.args
        return [x.arg for x in list(a.posonlyargs) + list(a.args)]

    def _check_kernel_pkg(self, pkg: str, mods: dict[str, _Module]):
        kmod, rmod, omod = mods["kernel"], mods["ref"], mods["ops"]
        refs = {n: f for n, f in rmod.top.items()
                if n.endswith("_ref") and not n.startswith("_")}
        if len(refs) != 1:
            self._emit(rmod.path, 1, "kernel-signature",
                       f"{pkg}/ref.py must expose exactly one public "
                       f"*_ref oracle, found {sorted(refs) or 'none'}")
            return
        (ref_name, ref_f), = refs.items()
        base = ref_name[: -len("_ref")]
        entries = {n: f for n, f in kmod.top.items()
                   if not n.startswith("_") and n.startswith(base)}
        if len(entries) != 1:
            self._emit(kmod.path, 1, "kernel-signature",
                       f"{pkg}/kernel.py must expose exactly one public "
                       f"entry named {base}* matching {ref_name}, found "
                       f"{sorted(entries) or 'none'}")
            return
        (k_name, k_f), = entries.items()
        kp, rp = self._positional(k_f.node), self._positional(ref_f.node)
        if kp != rp:
            self._emit(kmod.path, k_f.node.lineno, "kernel-signature",
                       f"{k_name}{tuple(kp)} positional signature differs "
                       f"from oracle {ref_name}{tuple(rp)} — oracle "
                       "equality tests cannot swap implementations")
        imported = {orig for (src, orig) in omod.from_imports.values()
                    if src in (kmod.name, rmod.name)}
        for need in (k_name, ref_name):
            if need not in imported:
                self._emit(omod.path, 1, "kernel-signature",
                           f"{pkg}/ops.py does not import {need} — every "
                           "kernel entry must be wrapped by its ops "
                           "dispatcher")

    # -- driver ------------------------------------------------------------

    def run(self) -> list[Finding]:
        self._mark_roots()
        self._propagate()
        self._rule_discarded_at()
        self._rule_host_sync()
        self._rule_traced_truthiness()
        self._rule_kernel_signatures()
        self.findings.sort(key=lambda f: (f.path, f.line, f.rule))
        return self.findings


def lint_paths(paths, repo_root: str | Path | None = None) -> list[Finding]:
    """Lint every ``*.py`` under ``paths`` (files or directories)."""
    root = Path(repo_root).resolve() if repo_root else Path.cwd().resolve()
    files: list[Path] = []
    for p in paths:
        p = Path(p).resolve()
        if p.is_dir():
            files.extend(q for q in p.rglob("*.py")
                         if "__pycache__" not in q.parts)
        else:
            files.append(p)
    return Linter(files, root).run()


# ---------------------------------------------------------------------------
# Baseline: committed allowlist of intentional findings
# ---------------------------------------------------------------------------

def _parse_toml_minimal(text: str) -> dict:
    """Tiny TOML-subset reader for ``baseline.toml`` (py3.10 has no
    tomllib): ``[[baseline]]`` tables of ``key = value`` scalars only."""
    out: dict = {"baseline": []}
    cur = None
    for raw in text.splitlines():
        line = raw.split("#", 1)[0].strip() if not raw.strip().startswith(
            '"') else raw.strip()
        if not line:
            continue
        if line == "[[baseline]]":
            cur = {}
            out["baseline"].append(cur)
            continue
        if "=" in line and cur is not None:
            key, _, val = line.partition("=")
            val = val.strip()
            if val.startswith('"') and val.endswith('"'):
                cur[key.strip()] = val[1:-1]
            else:
                cur[key.strip()] = int(val)
    return out


def load_baseline(path: str | Path) -> list[dict]:
    text = Path(path).read_text()
    try:
        import tomllib
        data = tomllib.loads(text)
    except ModuleNotFoundError:
        data = _parse_toml_minimal(text)
    entries = data.get("baseline", [])
    for e in entries:
        for key in ("file", "rule", "count", "reason"):
            if key not in e:
                raise ValueError(f"baseline entry missing {key!r}: {e}")
    return entries


def apply_baseline(findings: list[Finding], entries: list[dict]
                   ) -> tuple[list[Finding], list[str]]:
    """Split findings into (new, stale-baseline messages).

    A finding is *baselined* when a ``(file, rule)`` entry covers it and
    the per-entry count is not exceeded; everything past the committed
    count — or with no entry at all — is new and should fail the build.
    Entries whose violation count dropped come back as stale warnings so
    the allowlist shrinks with the code.
    """
    allowed: dict[tuple[str, str], int] = {}
    for e in entries:
        allowed[(e["file"], e["rule"])] = \
            allowed.get((e["file"], e["rule"]), 0) + int(e["count"])
    counts: dict[tuple[str, str], int] = {}
    new: list[Finding] = []
    for f in findings:
        key = (f.path, f.rule)
        counts[key] = counts.get(key, 0) + 1
        if counts[key] > allowed.get(key, 0):
            new.append(f)
    stale = [f"baseline entry {key[0]} [{key[1]}] allows {cap} but only "
             f"{counts.get(key, 0)} found — shrink the baseline"
             for key, cap in sorted(allowed.items())
             if counts.get(key, 0) < cap]
    return new, stale
