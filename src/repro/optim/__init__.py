"""Optimizers: AdamW (ZeRO-shardable), LR schedules, gradient compression."""
