"""AdamW with f32 master weights and ZeRO-shardable state.

Parameters may live in bf16; the optimizer keeps f32 master copies and
moments.  State sharding is declared through `state_axes` (same logical axes
as the parameters), so pjit shards m/v/master over the full mesh — ZeRO-1/2
is a sharding-rule choice, not a code path (see parallel.sharding and the
dry-run, which verifies the 314B-param grok state fits per-device HBM).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict
    master: dict


def init(params) -> AdamWState:
    f32 = lambda t: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=f32(params),
        v=f32(params),
        master=jax.tree.map(lambda x: x.astype(jnp.float32), params),
    )


def state_axes(param_axes_tree) -> AdamWState:
    """Sharding specs for every state leaf (ZeRO: same layout as params).
    Expects a tree of PartitionSpecs (from parallel.sharding.param_specs)."""
    from jax.sharding import PartitionSpec as P

    return AdamWState(step=P(), m=param_axes_tree, v=param_axes_tree,
                      master=param_axes_tree)


def update(state: AdamWState, grads, params, *, lr, b1=0.9, b2=0.95,
           eps=1e-8, weight_decay=0.1, grad_clip=1.0):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    gnorm = jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, w):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        mhat = m_new / c1
        vhat = v_new / c2
        w_new = w - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * w)
        return m_new, v_new, w_new

    out = jax.tree.map(upd, grads, state.m, state.v, state.master)
    m = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    master = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda w, p: w.astype(p.dtype), master, params)
    return new_params, AdamWState(step, m, v, master), {"grad_norm": gnorm}
