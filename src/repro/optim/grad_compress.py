"""Gradient compression for cross-pod (DCN) reduction.

Two schemes with error feedback (residual accumulation), used by the trainer
for the 'pod' axis where bandwidth is ~8x scarcer than ICI (the ESF fabric
model quantifies exactly this, core.fabric_model):

  * int8 stochastic-rounding quantization (8x smaller all-reduce payload);
  * top-k sparsification (magnitude): send k% of entries + indices.

Error feedback keeps both unbiased-in-the-limit: the residual (what
compression dropped) is added back before the next compression, which is the
standard convergence-preserving construction (Karimireddy et al., 2019).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x, key):
    """Stochastic int8 quantization; returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    y = x / scale
    noise = jax.random.uniform(key, x.shape, minval=-0.5, maxval=0.5)
    q = jnp.clip(jnp.round(y + noise), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def topk_sparsify(x, frac: float):
    """Keep the top-`frac` entries by magnitude; returns (sparse_x, mask)."""
    flat = x.reshape(-1)
    k = max(int(flat.shape[0] * frac), 1)
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = jnp.abs(x) >= thresh
    return x * mask, mask


def compress_with_feedback(grad, residual, key, *, method: str = "int8",
                           topk_frac: float = 0.05):
    """(compressed_payload, new_residual).  The payload is what crosses DCN;
    decompress with `decompress`."""
    g = grad.astype(jnp.float32) + residual
    if method == "int8":
        q, scale = quantize_int8(g, key)
        approx = dequantize_int8(q, scale)
        return (q, scale), g - approx
    if method == "topk":
        sparse, mask = topk_sparsify(g, topk_frac)
        return (sparse, None), g - sparse
    raise ValueError(method)


def decompress(payload, method: str = "int8"):
    if method == "int8":
        q, scale = payload
        return dequantize_int8(q, scale)
    return payload[0]


def compression_ratio(method: str, topk_frac: float = 0.05) -> float:
    return 0.25 if method == "int8" else topk_frac * 2  # value+index
