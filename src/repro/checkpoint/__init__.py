"""Checkpointing: atomic, hashed, async, elastic restore."""
