"""Fault-tolerant checkpointing: atomic, content-hashed, async, elastic.

Layout:  <dir>/step_<N>/arrays.npz + manifest.json; a checkpoint becomes
visible only by the final atomic rename of its temp directory, so a crash
mid-save can never corrupt the restore path.  The manifest records per-leaf
tree paths, shapes, dtypes and a payload sha256 — restore verifies integrity
before any array reaches a device.  `restore` device_puts against whatever
sharding the *current* mesh dictates, which is exactly the elastic-resize
path (save on 512 chips, resume on 256: same call).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    dtypes = {}
    for path, leaf in leaves:
        key = jax.tree_util.keystr(path)
        arr = np.asarray(leaf)
        dtypes[key] = str(arr.dtype)
        if arr.dtype == "bfloat16":  # npz cannot hold ml_dtypes; store bits
            arr = arr.view(np.uint16)
        out[key] = arr
    return out, dtypes


def save(ckpt_dir: str, step: int, tree, *, keep_last: int = 3,
         blocking: bool = True) -> str:
    """Write checkpoint; returns final path.  blocking=False saves in a
    background thread (the caller must not mutate `tree` buffers — jax arrays
    are immutable, so passing the live train state is safe)."""
    flat, dtypes = _flatten(tree)

    def _write():
        os.makedirs(ckpt_dir, exist_ok=True)
        tmp = os.path.join(ckpt_dir, f".tmp_step_{step}_{os.getpid()}")
        final = os.path.join(ckpt_dir, f"step_{step:09d}")
        os.makedirs(tmp, exist_ok=True)
        payload = os.path.join(tmp, "arrays.npz")
        np.savez(payload, **flat)
        digest = hashlib.sha256(open(payload, "rb").read()).hexdigest()
        manifest = {
            "step": step,
            "time": time.time(),
            "sha256": digest,
            "leaves": {k: {"shape": list(v.shape), "dtype": dtypes[k]}
                       for k, v in flat.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)          # atomicity point
        _gc(ckpt_dir, keep_last)
        return final

    if blocking:
        return _write()
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return os.path.join(ckpt_dir, f"step_{step:09d}")


def _gc(ckpt_dir: str, keep_last: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    return int(steps[-1].split("_")[1]) if steps else None


def restore(ckpt_dir: str, example_tree, step: int | None = None,
            shardings=None):
    """Restore into the structure of `example_tree` (abstract or concrete).

    `shardings`: optional matching pytree of NamedShardings — arrays are
    device_put against them (the elastic reshard path).  Integrity (sha256)
    is verified before anything is materialized.
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:09d}")
    manifest = json.load(open(os.path.join(path, "manifest.json")))
    payload = os.path.join(path, "arrays.npz")
    digest = hashlib.sha256(open(payload, "rb").read()).hexdigest()
    if digest != manifest["sha256"]:
        raise IOError(f"checkpoint {path} failed integrity check")
    arrays = np.load(payload)

    flat_paths = jax.tree_util.tree_flatten_with_path(example_tree)[0]
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(flat_paths))
    out = []
    for (pathkeys, leaf), shd in zip(flat_paths, shard_leaves):
        key = jax.tree_util.keystr(pathkeys)
        arr = arrays[key]
        want = manifest["leaves"][key]["dtype"]
        if want == "bfloat16":
            import ml_dtypes

            arr = arr.view(ml_dtypes.bfloat16)
        if shd is not None:
            arr = jax.device_put(arr, shd)
        out.append(arr)
    tree_def = jax.tree_util.tree_structure(example_tree)
    return jax.tree_util.tree_unflatten(tree_def, out), step
