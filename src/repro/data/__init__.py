"""Data pipelines: deterministic synthetic LM + ESF trace replay."""
