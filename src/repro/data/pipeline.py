"""Deterministic synthetic LM data pipeline (stateless, elastic-friendly).

Batches are pure functions of (seed, step, shard), so any host can produce
its shard for any step — resuming from a checkpoint or re-sharding after an
elastic resize needs no data-loader state.  The generator mixes a Markov
babble source (so the LM has learnable structure: loss drops well below
log(vocab)) with the ESF trace-replay source for systems-flavored runs.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    order: int = 1          # Markov order of the synthetic source


def _markov_table(cfg: DataConfig) -> np.ndarray:
    rng = np.random.default_rng(cfg.seed + 1)
    t = rng.dirichlet(np.full(min(cfg.vocab, 256), 0.05),
                      size=min(cfg.vocab, 256))
    return t


class SyntheticLM:
    """Markov-chain token stream; `batch(step)` -> host-local shard."""

    def __init__(self, cfg: DataConfig, shard: int = 0, n_shards: int = 1):
        self.cfg = cfg
        self.shard = shard
        self.n_shards = n_shards
        self.table = _markov_table(cfg)
        self.eff_vocab = self.table.shape[0]
        assert cfg.global_batch % n_shards == 0
        self.local_batch = cfg.global_batch // n_shards

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng(
            (self.cfg.seed, step, self.shard, 0xE5F))
        b, s = self.local_batch, self.cfg.seq_len
        toks = np.empty((b, s), np.int64)
        toks[:, 0] = rng.integers(0, self.eff_vocab, b)
        u = rng.random((b, s))
        cum = np.cumsum(self.table, axis=1)
        for t in range(1, s):
            toks[:, t] = (u[:, t:t + 1] <
                          cum[toks[:, t - 1]]).argmax(axis=1)
        tokens = jnp.asarray(toks, jnp.int32)
        return {"tokens": tokens, "labels": tokens}


class TraceLM:
    """ESF trace-replay source: workload memory traces tokenized as
    (address-delta bucket, r/w) events — systems data through the same API."""

    def __init__(self, cfg: DataConfig, workload: str = "silo",
                 shard: int = 0, n_shards: int = 1):
        from repro.core import traces as TR

        self.cfg = cfg
        self.local_batch = cfg.global_batch // n_shards
        tr = TR.generate(workload, n=200_000, seed=cfg.seed + shard)
        delta = np.diff(tr["addr"], prepend=tr["addr"][0])
        bucket = np.clip(np.abs(delta), 0, cfg.vocab // 2 - 1)
        self.stream = (bucket * 2 + tr["is_write"]).astype(np.int64) \
            % cfg.vocab

    def batch(self, step: int) -> dict:
        b, s = self.local_batch, self.cfg.seq_len
        n = len(self.stream)
        idx = (np.arange(b)[:, None] * 9973 + step * b * s
               + np.arange(s)[None]) % (n - 1)
        tokens = jnp.asarray(self.stream[idx], jnp.int32)
        return {"tokens": tokens, "labels": tokens}


def make_source(kind: str, cfg: DataConfig, **kw):
    return {"synthetic": SyntheticLM, "trace": TraceLM}[kind](cfg, **kw)
