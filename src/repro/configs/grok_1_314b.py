"""Auto-maintained architecture config (assigned pool).  See base.py."""

from repro.configs.base import ArchConfig, MoESpec  # noqa: F401

"""grok-1-314b [moe]: 64L d6144 48H (GQA kv=8) ff32768, 8 experts top-2,
v131072.

8 experts do not divide the 16-way model axis, so experts replicate on
the expert dim and the expert FFN is tensor-parallel over 'model'
(DESIGN.md §Arch-applicability / moe_axes('ffn'))."""
CONFIG = ArchConfig(
    name="grok-1-314b", family="moe", n_layers=64, d_model=6144,
    n_heads=48, n_kv=8, d_ff=32768, vocab=131072, head_dim=128,
    pattern=("attn_moe",), moe=MoESpec(n_experts=8, top_k=2),
    rope_theta=10_000.0,
    notes="8 experts top-2 [hf:xai-org/grok-1]")
SMOKE = ArchConfig(
    name="grok-1-314b-smoke", family="moe", n_layers=2, d_model=64,
    n_heads=4, n_kv=2, d_ff=128, vocab=256, head_dim=16,
    pattern=("attn_moe",),
    moe=MoESpec(n_experts=4, top_k=2, capacity_factor=8.0), max_seq=512)
