"""Auto-maintained architecture config (assigned pool).  See base.py."""

from repro.configs.base import ArchConfig, MoESpec  # noqa: F401

"""llama3-8b [dense]: 32L d4096 32H (GQA kv=8) ff14336 v128256."""
CONFIG = ArchConfig(
    name="llama3-8b", family="dense", n_layers=32, d_model=4096,
    n_heads=32, n_kv=8, d_ff=14336, vocab=128256, head_dim=128,
    rope_theta=500_000.0,
    notes="GQA, 128k vocab [arXiv:2407.21783]")
SMOKE = ArchConfig(
    name="llama3-8b-smoke", family="dense", n_layers=4, d_model=64,
    n_heads=8, n_kv=2, d_ff=160, vocab=512, head_dim=8, max_seq=512,
    rope_theta=500_000.0)
