"""Architecture configuration schema + input-shape registry.

Every assigned architecture is a module in `repro.configs` exposing `CONFIG`
(an ArchConfig with the exact published dimensions) and the registry maps
``--arch <id>`` to it.  `smoke()` returns the reduced same-family config used
by the per-arch CPU smoke tests; the full config is exercised only via the
dry-run (ShapeDtypeStruct, no allocation).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | hybrid | moe | audio | ssm | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    # block pattern, repeated over the stack: entries from
    #   attn | attn_local | attn_moe | rglru | ssd | cross
    pattern: tuple[str, ...] = ("attn",)
    window: int = 4096          # sliding window for attn_local
    moe: MoESpec | None = None
    # ssm (mamba2)
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    ssm_state: int = 0
    # enc-dec (whisper): encoder layers + stub frontend length
    enc_layers: int = 0
    enc_frames: int = 1500
    # vlm stub frontend: number of patch embeddings prepended
    vision_patches: int = 0
    rope_theta: float = 10_000.0
    causal: bool = True
    # perf knobs (hillclimb targets; see EXPERIMENTS.md §Perf)
    ssd_chunk: int = 128
    moe_group: int = 512
    attn_chunk: int = 1024
    max_seq: int = 524_288
    tie_embeddings: bool = True
    sub_quadratic: bool = False  # True -> long_500k decode is runnable
    notes: str = ""

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        assert self.n_layers % len(self.pattern) == 0 or True

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.pattern)

    def params_count(self) -> int:
        att = self.d_model * (self.n_heads + 2 * self.n_kv) * self.head_dim \
            + self.n_heads * self.head_dim * self.d_model
        per_layer = {
            "attn": att + 3 * self.d_model * self.d_ff,
            "attn_local": att + 3 * self.d_model * self.d_ff,
            "cross": 2 * att + 3 * self.d_model * self.d_ff,
            "attn_moe": att + (3 * self.d_model * self.d_ff
                               * (self.moe.n_experts if self.moe else 1))
            + self.d_model * (self.moe.n_experts if self.moe else 0),
            "rglru": 5 * self.d_model * self.d_model
            + 3 * self.d_model * self.d_ff,
            "ssd": self.d_model * (2 * self.ssm_heads * self.ssm_head_dim * 2
                                   + 2 * self.ssm_state + self.ssm_heads),
        }
        total = 0
        for i in range(self.n_layers):
            total += per_layer[self.pattern[i % len(self.pattern)]]
        total += self.enc_layers * (att + 3 * self.d_model * self.d_ff)
        total += self.vocab * self.d_model
        return total

    def active_params_count(self) -> int:
        if not self.moe:
            return self.params_count()
        dense = replace(self, moe=MoESpec(1, 1),
                        pattern=tuple("attn" if p == "attn_moe" else p
                                      for p in self.pattern))
        att_moe_layers = sum(1 for i in range(self.n_layers)
                             if self.pattern[i % len(self.pattern)] == "attn_moe")
        return dense.params_count() + att_moe_layers * 3 * self.d_model \
            * self.d_ff * (self.moe.top_k - 1)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode | long_decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "long_decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether an (arch x shape) cell runs; reason recorded in DESIGN.md."""
    if shape.kind == "long_decode" and not cfg.sub_quadratic:
        return False, ("pure full-attention architecture: 512k dense decode "
                       "is O(S^2)/token with no sub-quadratic path")
    return True, ""
