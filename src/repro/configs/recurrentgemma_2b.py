"""Auto-maintained architecture config (assigned pool).  See base.py."""

from repro.configs.base import ArchConfig, MoESpec  # noqa: F401

"""recurrentgemma-2b [hybrid]: 26L d2560 10H (MQA kv=1) ff7680 v256000.

Griffin pattern: (RG-LRU, RG-LRU, local attention) repeating, window 2048
— 26 layers = 8 full periods + a 2-block recurrent tail.  Sub-quadratic:
the long_500k cell runs (DESIGN.md §Arch-applicability).
"""
CONFIG = ArchConfig(
    name="recurrentgemma-2b", family="hybrid", n_layers=26, d_model=2560,
    n_heads=10, n_kv=1, d_ff=7680, vocab=256000, head_dim=256,
    pattern=("rglru", "rglru", "attn_local"), window=2048,
    sub_quadratic=True, rope_theta=10_000.0,
    notes="RG-LRU + local attn 1:2 [arXiv:2402.19427; hf]")
SMOKE = ArchConfig(
    name="recurrentgemma-2b-smoke", family="hybrid", n_layers=5,
    d_model=64, n_heads=4, n_kv=1, d_ff=128, vocab=256, head_dim=16,
    pattern=("rglru", "rglru", "attn_local"), window=32,
    sub_quadratic=True, max_seq=512)
