"""Auto-maintained architecture config (assigned pool).  See base.py."""

from repro.configs.base import ArchConfig, MoESpec  # noqa: F401

"""mamba2-1.3b [ssm]: 48L d2048 attention-free, SSD state 128, v50280.

d_inner = 2*d_model = 4096 = 64 heads x 64 head_dim.  Sub-quadratic:
long_500k runs with O(1) decode state."""
CONFIG = ArchConfig(
    name="mamba2-1.3b", family="ssm", n_layers=48, d_model=2048,
    n_heads=1, n_kv=1, d_ff=0, vocab=50280, head_dim=64,
    pattern=("ssd",), ssm_heads=64, ssm_head_dim=64, ssm_state=128,
    sub_quadratic=True,
    notes="SSD state-space duality [arXiv:2405.21060]")
SMOKE = ArchConfig(
    name="mamba2-1.3b-smoke", family="ssm", n_layers=3, d_model=64,
    n_heads=1, n_kv=1, d_ff=0, vocab=256, head_dim=16, pattern=("ssd",),
    ssm_heads=4, ssm_head_dim=16, ssm_state=16, sub_quadratic=True,
    max_seq=512)
