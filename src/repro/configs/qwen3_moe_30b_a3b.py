"""Auto-maintained architecture config (assigned pool).  See base.py."""

from repro.configs.base import ArchConfig, MoESpec  # noqa: F401

"""qwen3-moe-30b-a3b [moe]: 48L d2048 32H (GQA kv=4) per-expert ff768,
128 experts top-8, v151936."""
CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe", n_layers=48, d_model=2048,
    n_heads=32, n_kv=4, d_ff=768, vocab=151936, head_dim=128,
    pattern=("attn_moe",), moe=MoESpec(n_experts=128, top_k=8),
    rope_theta=1_000_000.0,
    notes="128 experts top-8 [hf:Qwen/Qwen3-30B-A3B]")
SMOKE = ArchConfig(
    name="qwen3-moe-30b-a3b-smoke", family="moe", n_layers=2, d_model=64,
    n_heads=4, n_kv=2, d_ff=32, vocab=256, head_dim=16,
    pattern=("attn_moe",),
    # dropless capacity in the smoke config: capacity dropping is batch-
    # global (non-causal), so train/serve consistency checks need cf high
    moe=MoESpec(n_experts=8, top_k=2, capacity_factor=8.0), max_seq=512)
