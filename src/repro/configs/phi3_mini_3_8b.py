"""Auto-maintained architecture config (assigned pool).  See base.py."""

from repro.configs.base import ArchConfig, MoESpec  # noqa: F401

"""phi3-mini-3.8b [dense]: 32L d3072 32H (kv=32, MHA) ff8192 v32064."""
CONFIG = ArchConfig(
    name="phi3-mini-3.8b", family="dense", n_layers=32, d_model=3072,
    n_heads=32, n_kv=32, d_ff=8192, vocab=32064, head_dim=96,
    rope_theta=10_000.0,
    notes="RoPE SwiGLU, kv=heads [arXiv:2404.14219]")
SMOKE = ArchConfig(
    name="phi3-mini-3.8b-smoke", family="dense", n_layers=3, d_model=48,
    n_heads=4, n_kv=4, d_ff=96, vocab=256, head_dim=12, max_seq=512)
