"""Auto-maintained architecture config (assigned pool).  See base.py."""

from repro.configs.base import ArchConfig, MoESpec  # noqa: F401

"""command-r-plus-104b [dense]: 64L d12288 96H (GQA kv=8) ff33792 v256000."""
CONFIG = ArchConfig(
    name="command-r-plus-104b", family="dense", n_layers=64,
    d_model=12288, n_heads=96, n_kv=8, d_ff=33792, vocab=256000,
    head_dim=128, rope_theta=75_000.0,
    notes="GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01]")
SMOKE = ArchConfig(
    name="command-r-plus-104b-smoke", family="dense", n_layers=4,
    d_model=96, n_heads=12, n_kv=2, d_ff=192, vocab=512, head_dim=8,
    max_seq=512)
