"""Auto-maintained architecture config (assigned pool).  See base.py."""

from repro.configs.base import ArchConfig, MoESpec  # noqa: F401

"""whisper-base [audio]: 6L enc + 6L dec, d512 8H ff2048 v51865.

Enc-dec backbone; the conv audio frontend is a stub — input_specs()
supplies precomputed 1500-frame encoder embeddings (B, 1500, d)."""
CONFIG = ArchConfig(
    name="whisper-base", family="audio", n_layers=6, d_model=512,
    n_heads=8, n_kv=8, d_ff=2048, vocab=51865, head_dim=64,
    pattern=("cross",), enc_layers=6, enc_frames=1500,
    rope_theta=10_000.0,
    notes="enc-dec, conv frontend stubbed [arXiv:2212.04356]")
SMOKE = ArchConfig(
    name="whisper-base-smoke", family="audio", n_layers=2, d_model=64,
    n_heads=4, n_kv=4, d_ff=128, vocab=256, head_dim=16,
    pattern=("cross",), enc_layers=2, enc_frames=16, max_seq=512)
