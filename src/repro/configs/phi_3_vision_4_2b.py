"""Auto-maintained architecture config (assigned pool).  See base.py."""

from repro.configs.base import ArchConfig, MoESpec  # noqa: F401

"""phi-3-vision-4.2b [vlm]: phi3-mini backbone + CLIP frontend stub.

32L d3072 32H kv=32 ff8192 v32064; input_specs() supplies 576 projected
patch embeddings (B, 576, d) that replace the prompt prefix."""
CONFIG = ArchConfig(
    name="phi-3-vision-4.2b", family="vlm", n_layers=32, d_model=3072,
    n_heads=32, n_kv=32, d_ff=8192, vocab=32064, head_dim=96,
    vision_patches=576, rope_theta=10_000.0,
    notes="phi3-mini + CLIP stub [hf:microsoft/Phi-3-vision-128k-instruct]")
SMOKE = ArchConfig(
    name="phi-3-vision-4.2b-smoke", family="vlm", n_layers=3, d_model=48,
    n_heads=4, n_kv=4, d_ff=96, vocab=256, head_dim=12,
    vision_patches=8, max_seq=512)
