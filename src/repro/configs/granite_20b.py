"""Auto-maintained architecture config (assigned pool).  See base.py."""

from repro.configs.base import ArchConfig, MoESpec  # noqa: F401

"""granite-20b [dense]: 52L d6144 48H (MQA kv=1) ff24576 v49152.

IBM Granite 20B code model (arXiv:2405.04324): llama-style blocks with
multi-query attention (single KV head).  MQA means the KV cache cannot be
sharded over heads; the serving path shards it over batch axes instead
(DESIGN.md §4).
"""
CONFIG = ArchConfig(
    name="granite-20b", family="dense", n_layers=52, d_model=6144,
    n_heads=48, n_kv=1, d_ff=24576, vocab=49152, head_dim=128,
    rope_theta=10_000.0,
    notes="llama-arch, code; MQA kv=1 [arXiv:2405.04324; hf]")
SMOKE = ArchConfig(
    name="granite-20b-smoke", family="dense", n_layers=4, d_model=64,
    n_heads=8, n_kv=1, d_ff=128, vocab=256, head_dim=8, max_seq=512)
