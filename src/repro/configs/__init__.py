"""Architecture registry: ``--arch <id>`` -> (full config, smoke config)."""

from __future__ import annotations

import importlib

from .base import ArchConfig, MoESpec, ShapeSpec, SHAPES, shape_applicable  # noqa: F401

ARCH_MODULES = {
    "granite-20b": "granite_20b",
    "llama3-8b": "llama3_8b",
    "command-r-plus-104b": "command_r_plus_104b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "grok-1-314b": "grok_1_314b",
    "whisper-base": "whisper_base",
    "mamba2-1.3b": "mamba2_1_3b",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
}

ARCH_IDS = tuple(ARCH_MODULES)


def get_config(arch: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{ARCH_MODULES[arch]}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{ARCH_MODULES[arch]}")
    return mod.SMOKE
