"""Benchmark driver: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig10,...]``

Prints ``name,us_per_call,derived`` CSV.  ``derived`` carries the reproduced
quantity and the paper target it validates against (see DESIGN.md §7 for the
experiment index).  Framework-level benches (fabric collective model, kernels,
autotune) live alongside the paper-figure benches.
"""

from __future__ import annotations

import argparse
import sys
import time

MODULES = (
    ("validation", "benchmarks.bench_validation"),
    ("topology", "benchmarks.bench_topology"),
    ("routing", "benchmarks.bench_routing"),
    ("snoop_filter", "benchmarks.bench_snoop_filter"),
    ("invblk", "benchmarks.bench_invblk"),
    ("full_duplex", "benchmarks.bench_full_duplex"),
    ("link_layer", "benchmarks.bench_link_layer"),
    ("link_reliability", "benchmarks.bench_link_reliability"),
    ("coherence_fabric", "benchmarks.bench_coherence_fabric"),
    ("traces", "benchmarks.bench_traces"),
    ("coherence_modes", "benchmarks.bench_coherence_modes"),
    ("fabric", "benchmarks.bench_fabric"),
    ("kernels", "benchmarks.bench_kernels"),
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes (CI-friendly)")
    ap.add_argument("--only", type=str, default="",
                    help="comma-separated bench names")
    args = ap.parse_args()
    only = {s.strip() for s in args.only.split(",") if s.strip()}

    import importlib

    t0 = time.time()
    failed: list[str] = []
    unknown = only - {name for name, _ in MODULES}
    if unknown:
        # a typo in --only must not silently skip an acceptance gate
        print(f"unknown bench names: {sorted(unknown)}", file=sys.stderr)
        sys.exit(2)
    print("name,us_per_call,derived")
    for name, modname in MODULES:
        if only and name not in only:
            continue
        try:
            mod = importlib.import_module(modname)
        except ImportError as e:  # pragma: no cover
            print(f"{name}/import_error,0.0,{e}")
            failed.append(name)
            continue
        try:
            rows = mod.run(quick=args.quick)
        except Exception as e:
            print(f"{name}/run_error,0.0,{type(e).__name__}:{e}")
            failed.append(name)
            continue
        for r in rows:
            print(r.csv())
            sys.stdout.flush()
    print(f"total_wall_s,{time.time() - t0:.1f},")
    if failed:
        # embedded acceptance gates (AssertionErrors in bench run()) must
        # fail the CI smoke step, not just print a run_error row
        print(f"failed,{len(failed)},{';'.join(failed)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
