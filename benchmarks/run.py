"""Benchmark driver: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig10,...]
[--json BENCH_smoke.json]``

Prints ``name,us_per_call,derived`` CSV.  ``derived`` carries the reproduced
quantity and the paper target it validates against (see DESIGN.md §7 for the
experiment index).  Framework-level benches (fabric collective model, kernels,
autotune) live alongside the paper-figure benches.

``--json`` additionally writes the rows as a machine-readable snapshot —
CI uploads these as ``BENCH_*.json`` workflow artifacts on every run, so the
repo accumulates a perf trajectory without committing result files.  Any
bench whose embedded acceptance gate fails (AssertionError in its ``run()``)
exits nonzero, failing the CI job.
"""

from __future__ import annotations

import argparse
import json
import platform
import sys
import time

MODULES = (
    ("validation", "benchmarks.bench_validation"),
    ("topology", "benchmarks.bench_topology"),
    ("routing", "benchmarks.bench_routing"),
    ("snoop_filter", "benchmarks.bench_snoop_filter"),
    ("invblk", "benchmarks.bench_invblk"),
    ("full_duplex", "benchmarks.bench_full_duplex"),
    ("link_layer", "benchmarks.bench_link_layer"),
    ("link_reliability", "benchmarks.bench_link_reliability"),
    ("coherence_fabric", "benchmarks.bench_coherence_fabric"),
    ("telemetry", "benchmarks.bench_telemetry"),
    ("critical_path", "benchmarks.bench_critical_path"),
    ("streaming", "benchmarks.bench_streaming"),
    ("traces", "benchmarks.bench_traces"),
    ("coherence_modes", "benchmarks.bench_coherence_modes"),
    ("fabric", "benchmarks.bench_fabric"),
    ("kernels", "benchmarks.bench_kernels"),
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes (CI-friendly)")
    ap.add_argument("--only", type=str, default="",
                    help="comma-separated bench names")
    ap.add_argument("--json", type=str, default="",
                    help="also write results to this JSON file "
                         "(CI perf-trajectory artifact)")
    args = ap.parse_args()
    only = {s.strip() for s in args.only.split(",") if s.strip()}

    import importlib

    t0 = time.time()
    failed: list[str] = []
    errors: dict[str, str] = {}
    results: list[dict] = []
    unknown = only - {name for name, _ in MODULES}
    if unknown:
        # a typo in --only must not silently skip an acceptance gate
        print(f"unknown bench names: {sorted(unknown)}", file=sys.stderr)
        sys.exit(2)
    print("name,us_per_call,derived")
    for name, modname in MODULES:
        if only and name not in only:
            continue
        t_imp = time.perf_counter()
        try:
            mod = importlib.import_module(modname)
        except ImportError as e:  # pragma: no cover
            print(f"{name}/import_error,0.0,{e}")
            failed.append(name)
            errors[name] = f"ImportError:{e}"
            continue
        import_s = time.perf_counter() - t_imp
        t_run = time.perf_counter()
        try:
            rows = mod.run(quick=args.quick)
        except Exception as e:
            print(f"{name}/run_error,0.0,{type(e).__name__}:{e}")
            failed.append(name)
            errors[name] = f"{type(e).__name__}:{e}"
            continue
        run_s = time.perf_counter() - t_run
        for r in rows:
            print(r.csv())
            sys.stdout.flush()
            row = {"name": r.name, "us_per_call": r.us_per_call,
                   "derived": r.derived}
            # convergence/telemetry counters + host-side phase wall-clock
            # (build/lower/compile/execute when the bench reports them;
            # whole-module import/run always)
            meta = dict(r.meta) if getattr(r, "meta", None) else {}
            phases = dict(meta.get("host_phases", {}))
            phases.setdefault("import_s", round(import_s, 6))
            phases.setdefault("run_s", round(run_s, 6))
            meta["host_phases"] = phases
            row["meta"] = meta
            results.append(row)
    wall_s = time.time() - t0
    print(f"total_wall_s,{wall_s:.1f},")
    if args.json:
        import jax

        snapshot = {
            "quick": args.quick,
            "only": sorted(only),
            "wall_s": round(wall_s, 1),
            "python": platform.python_version(),
            "jax": jax.__version__,
            "platform": platform.platform(),
            "rows": results,
            "failed": sorted(failed),
            "errors": errors,
        }
        with open(args.json, "w") as f:
            json.dump(snapshot, f, indent=1, sort_keys=True)
        print(f"wrote {args.json} ({len(results)} rows)", file=sys.stderr)
    if failed:
        # embedded acceptance gates (AssertionErrors in bench run()) must
        # fail the CI smoke step, not just print a run_error row
        print(f"failed,{len(failed)},{';'.join(failed)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
