"""Paper §IV validation: idle latency, peak bandwidth vs R:W mix,
loaded-latency curves (Fig. 7/8) and the SPEC CPU2017 overhead proxy
(Table IV).

Three platforms are modeled, mirroring the paper's hardware testbed:

  local   CPU -> memory-controller hub -> 4x DDR5 DIMM endpoints.  The DDR
          data bus is half-duplex with a write<->read turnaround, which is why
          hardware DRAM bandwidth *falls* as writes mix in.
  numa    same, behind a UPI-like half-duplex socket interconnect (+fixed hop).
  cxl     requester -> PCIe5/CXL switch port -> MXC expander with 4 DIMMs.
          Full-duplex link with 16B CXL.mem header slots; effective per-
          direction link bandwidth 26 GB/s (MXC controller efficiency, cf.
          Sun et al. MICRO'23), which is why CXL bandwidth *rises* with mix.

Latency constants are Table III; references are `calibration.REFERENCE_HW`.
The bench reports relative errors against the same acceptance bands the paper
claims (bandwidth 0.1-10%, loaded latency <=12%).
"""

from __future__ import annotations

import numpy as np

from repro.core import topology as T
from repro.core.calibration import (CAL, DRAM_ROW_HIT_PS, DRAM_ROW_MISS_PS,
                                    REFERENCE_HW, TABLE_IV)
from repro.core.devices import RequesterSpec, build_workload
from repro.core.engine import request_stats, simulate_auto
from repro.core.verify import verify_built

from .common import Row, Timer

PLATFORMS = {
    # bus_MBps, duplex, turnaround_ps, link_fixed_ps, header, n_hubs(=switch)
    "local": dict(bus=118_000, duplex="half", turn=300, fixed=1_500, header=0,
                  extra_fixed=0),
    "numa": dict(bus=50_000, duplex="half", turn=700, fixed=1_500, header=0,
                 extra_fixed=41_000),
    "cxl": dict(bus=26_000, duplex="full", turn=0, fixed=26_000, header=16,
                extra_fixed=0),
}


def build_platform(name: str) -> tuple[T.Topology, dict]:
    p = PLATFORMS[name]
    # DDR5 DIMM: 8 schedulable bank groups; row activate+precharge only on
    # row switch (streaming MLC-style traffic amortizes it to ~0)
    # DDR5 DIMM: 32 banks (x2 ranks folded in); tCAS ~15ns per access, row
    # activate+precharge adds ~40ns more on a row switch
    ep = T.EndpointSpec(bw_MBps=38_400, fixed_ps=CAL.device_controller_ps,
                        banks=32, row_hit_extra_ps=DRAM_ROW_HIT_PS,
                        row_miss_extra_ps=DRAM_ROW_HIT_PS + DRAM_ROW_MISS_PS)
    kinds = [T.REQUESTER, T.SWITCH] + [T.MEMORY] * 4
    links = [T.LinkSpec(0, 1, p["bus"], p["fixed"] + p["extra_fixed"],
                        p["duplex"], p["turn"])]
    for m in range(4):
        links.append(T.LinkSpec(1, 2 + m, p["bus"], p["fixed"],
                                p["duplex"], p["turn"]))
    sw_ps = CAL.switching_ps if name == "cxl" else 2_000
    topo = T.Topology(np.asarray(kinds, np.int64), links, name=name,
                      endpoint=ep, switching_ps=sw_ps)
    return topo, p


def measure(name: str, read_ratio: float, interval_ps: int, n: int = 3000,
            pattern: str = "stream", jitter: str = "none"):
    """MLC-style measurement: bandwidth tests stream sequentially (row-buffer
    friendly, like MLC's --peak_injection_bandwidth); idle-latency tests use
    dependent random loads (pattern="uniform", every access a row miss)."""
    topo, p = build_platform(name)
    graph = topo.build()
    spec = RequesterSpec(node=0, n_requests=n, targets=[2, 3, 4, 5],
                         pattern=pattern, read_ratio=read_ratio,
                         issue_interval_ps=interval_ps, issue_jitter=jitter,
                         footprint_lines=1 << 18, seed=7)
    # warmup 0 + span-based bandwidth: conservation-exact for mixed traffic
    # (percentile-window estimates are distorted by type-phase completion
    # bunching; see DESIGN.md measurement notes)
    wl = build_workload(graph, [spec], header_bytes=p["header"],
                        warmup_frac=0.0)
    verify_built(wl, graph).raise_if_failed()
    sched, _ = simulate_auto(wl.hops, wl.channels, wl.issue_ps)
    r = request_stats(wl.hops, sched, wl.issue_ps, wl.payload_bytes, wl.measured)
    meas = np.asarray(wl.measured)
    lat_ns = float(np.asarray(r["latency_ps"])[meas].mean()) / 1000.0
    bw_GBs = float(r["bandwidth_MBps"]) / 1000.0
    return lat_ns, bw_GBs


def run(quick: bool = False) -> list[Row]:
    rows: list[Row] = []
    n = 1000 if quick else 4000

    # ---- Fig. 7 left: idle latency --------------------------------------
    for name, ref_key in (("local", "local_dram"), ("numa", "remote_numa_dram"),
                          ("cxl", "cxl_mxc")):
        with Timer() as t:
            lat, _ = measure(name, 1.0, 700_000, n=300, pattern="uniform")
        ref = REFERENCE_HW["idle_latency_ns"][ref_key]
        rows.append(Row(
            f"fig7/idle_latency/{name}", t.us,
            f"sim={lat:.0f}ns;hw={ref:.0f}ns;rel_err={abs(lat - ref) / ref:.3f}",
        ))

    # ---- Fig. 7 right: peak bandwidth vs R:W ratio ----------------------
    for name, ref_key in (("local", "local_dram"), ("numa", "remote_numa_dram"),
                          ("cxl", "cxl_mxc")):
        refs = REFERENCE_HW["peak_bw_GBs"][ref_key]
        for (rr, ww), ref in zip(REFERENCE_HW["rw_ratios"], refs):
            ratio = rr / (rr + ww)
            with Timer() as t:
                _, bw = measure(name, ratio, 150, n=n)
            rows.append(Row(
                f"fig7/peak_bw/{name}/rw{rr}to{ww}", t.us,
                f"sim={bw:.1f}GBs;hw={ref:.1f}GBs;rel_err={abs(bw - ref) / ref:.3f}",
            ))

    # ---- Fig. 8: loaded latency (CXL reads) ------------------------------
    curve = []
    for iv in (60_000, 24_000, 12_000, 6_000, 4_000, 3_400, 3_000,
               2_800, 2_700, 2_620, 2_560, 2_510):
        with Timer() as t:
            # Poisson arrivals: MLC loaded-latency traffic is stochastic;
            # deterministic intervals would give a step-function knee
            lat, bw = measure("cxl", 1.0, iv, n=n, pattern="uniform",
                              jitter="exp")
        curve.append((bw, lat))
        rows.append(Row(f"fig8/loaded/cxl_read/iv{iv}", t.us,
                        f"bw={bw:.1f}GBs;lat={lat:.0f}ns"))
    errs = []
    xs = np.array([c[0] for c in curve])
    ys = np.array([c[1] for c in curve])
    o = np.argsort(xs)
    for ref_bw, ref_lat in REFERENCE_HW["loaded_latency_cxl_read"]:
        sim_lat = float(np.interp(ref_bw, xs[o], ys[o]))
        errs.append(abs(sim_lat - ref_lat) / ref_lat)
    rows.append(Row(
        "fig8/loaded/error_summary", 0.0,
        f"avg_rel_err={np.mean(errs):.3f};max_rel_err={np.max(errs):.3f};"
        f"paper_band_avg={REFERENCE_HW['paper_error_bands']['loaded_latency_rel_err_avg']};"
        f"paper_band_max={REFERENCE_HW['paper_error_bands']['loaded_latency_rel_err_max']}",
    ))

    # ---- Table IV: SPEC CPU2017 overhead proxy ---------------------------
    # Execution time = instrs*CPI + LLC-misses * effective latency * (1-MLP).
    # (mpki, cpi_ns, mlp_overlap) calibrated per workload; the *platform
    # latencies are simulated*, so the overhead error tracks sim accuracy.
    spec_params = {"gcc": (0.9, 0.30, 0.53), "mcf": (8.0, 0.25, 0.938)}
    lat_local, _ = measure("local", 1.0, 700_000, n=300, pattern="uniform")
    lat_cxl, _ = measure("cxl", 1.0, 700_000, n=300, pattern="uniform")
    for wlname, (mpki, cpi, mlp) in spec_params.items():
        n_instr = 1e6
        misses = mpki * n_instr / 1000
        exec_local = n_instr * cpi + misses * lat_local * (1 - mlp)
        exec_cxl = n_instr * cpi + misses * lat_cxl * (1 - mlp)
        ovh = exec_cxl / exec_local - 1
        hw = TABLE_IV["CXL Hardware"][wlname]
        esf = TABLE_IV["ESF standalone"][wlname]
        rows.append(Row(
            f"tab4/spec_overhead/{wlname}", 0.0,
            f"sim={ovh:.3f};hw={hw:.3f};paper_esf={esf:.3f};"
            f"delta_vs_hw={abs(ovh - hw):.3f}",
        ))
    return rows
