"""HDM coherence modes: host-managed (HDM-H) vs device-managed (HDM-DB).

The paper's central scalability argument (§II-A, §II-C): with DMC, devices
carry their own DCOH and coherence traffic resolves peer-to-peer, "eliminating
the need for a central coherence engine".  Under HDM-H every coherent miss
must be mediated by the host's coherency bridge — on a multi-requester fabric
that adds a host round-trip per miss *and* concentrates traffic on the host
links (a bridge bottleneck, exactly like Fig. 10's tree root).

Setup: N accelerators + 1 host on a spine-leaf fabric, each accelerator
issuing coherent accesses to pooled type-2/3 memory devices:

  * HDM-DB: requests route accelerator -> memory directly; the device-side SF
    handles invalidations (BISnp latency folded per §V-B rates).
  * HDM-H : requests route accelerator -> host -> memory (coherency-bridge
    mediation), so every access crosses the host leaf twice.

Reported: aggregate bandwidth and mean latency vs accelerator count — the
scalability curve the paper argues DMC wins.
"""

from __future__ import annotations

import numpy as np

from repro.core import topology as T
from repro.core.devices import RequesterSpec, build_workload
from repro.core.engine import request_stats, simulate
from repro.core.verify import verify_built

from .common import Row, Timer

PORT = 64_000
FIXED = 26_000


def build_fabric(n_acc: int, n_mem: int = 4):
    kinds, links = [], []

    def add(kind):
        kinds.append(kind)
        return len(kinds) - 1

    spines = [add(T.SWITCH), add(T.SWITCH)]
    host_leaf = add(T.SWITCH)
    acc_leaves = [add(T.SWITCH) for _ in range(max(n_acc // 4, 1))]
    mem_leaves = [add(T.SWITCH) for _ in range(max(n_mem // 2, 1))]
    for lf in [host_leaf] + acc_leaves + mem_leaves:
        for sp in spines:
            links.append(T.LinkSpec(lf, sp, PORT, FIXED))
    host = add(T.REQUESTER)
    links.append(T.LinkSpec(host, host_leaf, PORT, FIXED))
    # the host's coherency bridge: the serviceable endpoint HDM-H requests
    # must visit before memory (CXL.cache mediation)
    host_cb = add(T.MEMORY)
    links.append(T.LinkSpec(host_cb, host_leaf, PORT, FIXED))
    accs = []
    for i in range(n_acc):
        a = add(T.REQUESTER)
        accs.append(a)
        links.append(T.LinkSpec(a, acc_leaves[i % len(acc_leaves)], PORT, FIXED))
    mems = []
    for i in range(n_mem):
        m = add(T.MEMORY)
        mems.append(m)
        links.append(T.LinkSpec(m, mem_leaves[i % len(mem_leaves)], PORT, FIXED))
    topo = T.Topology(np.asarray(kinds, np.int64), links, name="coh")
    return topo, host, host_cb, accs, mems


def run_mode(mode: str, n_acc: int, n_per: int = 300):
    """HDM-DB: direct accesses.  HDM-H: each access first visits the host
    (coherency bridge), modeled by targeting the host's leaf as an
    intermediate hop via a two-transaction decomposition."""
    topo, host, host_cb, accs, mems = build_fabric(n_acc)
    graph = topo.build()
    rng = np.random.default_rng(3)

    if mode == "hdm_db":
        specs = [RequesterSpec(node=a, n_requests=n_per, targets=mems,
                               issue_interval_ps=1_000, seed=i)
                 for i, a in enumerate(accs)]
        wl = build_workload(graph, specs, header_bytes=16, warmup_frac=0.25,
                            route_choice=rng.integers(0, 1 << 20,
                                                      n_per * n_acc))
        verify_built(wl, graph).raise_if_failed()
        sched = simulate(wl.hops, wl.channels, wl.issue_ps)
        r = request_stats(wl.hops, sched, wl.issue_ps, wl.payload_bytes,
                          wl.measured)
        return (float(r["steady_bandwidth_MBps"]),
                float(r["mean_latency_ps"]) / 1e3)

    # hdm_h: leg 1 accelerator->host memory-side proxy; leg 2 host->memory.
    # Model as chained transactions: each access becomes acc->host (header
    # snoop) then host->mem (data), the host mediating every miss.
    specs = [RequesterSpec(node=a, n_requests=n_per, targets=[host_cb],
                           issue_interval_ps=1_000, seed=i, payload_bytes=16)
             for i, a in enumerate(accs)]
    # host relays all traffic to the memories at matching rate
    specs.append(RequesterSpec(node=host, n_requests=n_per * n_acc,
                               targets=mems,
                               issue_interval_ps=max(1_000 // n_acc, 60),
                               seed=99))
    wl = build_workload(graph, specs, header_bytes=16, warmup_frac=0.25,
                        route_choice=rng.integers(0, 1 << 20,
                                                  2 * n_per * n_acc))
    verify_built(wl, graph).raise_if_failed()
    sched = simulate(wl.hops, wl.channels, wl.issue_ps)
    r = request_stats(wl.hops, sched, wl.issue_ps, wl.payload_bytes,
                      wl.measured)
    # latency of a mediated access = snoop leg + data leg (mean of each class)
    lat = np.asarray(r["latency_ps"])
    meas = np.asarray(wl.measured)
    own = wl.requester != host
    lat_total = lat[meas & own].mean() + lat[meas & ~own].mean()
    relay = wl.requester == host
    comp = np.asarray(sched.complete)[relay]
    iss = np.asarray(wl.issue_ps)[relay]
    bw = (n_per * n_acc) * 64 * 1e12 / (comp.max() - iss.min()) / 1e6
    return float(bw), float(lat_total) / 1e3


def run(quick: bool = False) -> list[Row]:
    rows: list[Row] = []
    counts = (2, 4) if quick else (2, 4, 8)
    for n_acc in counts:
        with Timer() as t:
            bw_db, lat_db = run_mode("hdm_db", n_acc)
            bw_h, lat_h = run_mode("hdm_h", n_acc)
        rows.append(Row(
            f"coherence/scale{n_acc}", t.us,
            f"hdm_db_bw={bw_db:.0f};hdm_h_bw={bw_h:.0f};"
            f"dmc_speedup={bw_db / max(bw_h, 1):.2f};"
            f"hdm_db_lat={lat_db:.0f}ns;hdm_h_lat={lat_h:.0f}ns",
        ))
    return rows
