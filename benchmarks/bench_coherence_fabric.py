"""Fabric-coupled device coherence: isolated-vs-coupled divergence sweep.

The §V-B snoop-filter study isolates the DCOH on an infinite bus; the
`core.coherence_traffic` subsystem lowers the same protocol onto the
fabric engine, so SF service time feels real congestion: BISnp legs share
the device's egress channel with demand responses and any background
demand traffic targeting the device.

Reported, per victim policy (the six §V-B/§V-C policies vmapped through
one stacked fabric simulate per fixpoint iteration):

  * **SF-capacity x fabric-load sweep** — mean miss latency under the
    coupled model as background demand load on the device ramps from idle
    to saturating, against the load-independent isolated model.  The
    acceptance gate: the isolated-vs-coupled divergence is nonzero and
    grows monotonically with fabric load (at idle the fabric round trip
    is close to the analytic constants; under load it cannot be).

  * **BISnp inflation** — mean measured BISnp round trip vs the analytic
    ``bisnp_rtt_ps`` constant, the quantity the isolated model fixes by
    assumption.

  * **serialized-vs-concurrent fan-out** — mean snooped-miss latency under
    the ``fanout="chain"`` (PR-4 serialized snoop collection) and
    ``fanout="concurrent"`` (fork/join, CXL 3.x BI flow) lowerings of the
    *same* event log, as the snooped owner count ramps.  The chain model
    sums k BISnp round trips where the concurrent model waits for the
    slowest of k, so the acceptance gate: the chain-minus-concurrent
    divergence grows monotonically with owner count.

  * **trace mode** (§V-E) — the same coupled pipeline driven by
    `traces.request_stream` workloads (xsbench/silo) instead of the
    synthetic skewed footprint.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import topology as T
from repro.core import traces
from repro.core.coherence_traffic import (CoherenceFabricSpec, bisnp_latencies,
                                          coherence_issue, concat_background,
                                          lower_coherence, pad_rows)
from repro.core.devices import RequesterSpec, build_workload
from repro.core.engine import (SimOptions, make_channels, round_bound,
                               simulate)
from repro.core.verify import verify_built, verify_workload
from repro.core.snoop_filter import (CacheConfig, SFConfig,
                                     make_sequential_stream,
                                     make_skewed_stream, simulate_sf)

from .common import Row, Timer

POLICIES = ("fifo", "lru", "lfi", "lifo", "mru", "blp")
PORT = 64_000
FIXED = 26_000


N_BG = 3


def build_coherence_fabric(n_req: int = 2):
    """Star fabric: ``n_req`` coherent requesters + ``N_BG`` background
    requesters + the DCOH device (MEMORY) behind one switch.  Background
    traffic targets the device, so it contends with demand requests,
    demand responses *and* BISnp legs on the switch<->device channels;
    several independent background sources keep the merged arrival process
    bursty at the shared link (a single shaped stream would not queue)."""
    kinds = ([T.SWITCH] + [T.REQUESTER] * n_req + [T.MEMORY]
             + [T.REQUESTER] * N_BG)
    dev = n_req + 1
    bgs = list(range(n_req + 2, n_req + 2 + N_BG))
    links = [T.LinkSpec(i, 0, PORT, FIXED) for i in range(1, len(kinds))]
    topo = T.Topology(np.asarray(kinds, np.int64), links, name="cohfab")
    graph = topo.build()
    spec = CoherenceFabricSpec(dev_node=dev,
                               req_nodes=tuple(range(1, n_req + 1)))
    return graph, spec, bgs


BG_PAYLOAD = 1024
BG_ROW_CAP = 8_000


def _background(graph, bg_nodes, dev_node, load: float, span_ps: int):
    """Sustained background demand on the device at ``load`` x the device
    link's serialization capacity, spanning the (estimated) coherent run,
    split over the independent background requesters so the merged stream
    stays bursty at the shared link.  ``load=0`` disables background."""
    if load <= 0:
        return None
    ser_ps = BG_PAYLOAD * 1_000_000 // PORT      # one payload's wire time
    interval = max(int(ser_ps * len(bg_nodes) / load), 1)
    n = min(int(span_ps // interval) + 1, BG_ROW_CAP // len(bg_nodes))
    specs = [RequesterSpec(node=b, n_requests=n, targets=[dev_node],
                           read_ratio=0.5, issue_interval_ps=interval,
                           payload_bytes=BG_PAYLOAD, seed=17 + i,
                           issue_jitter="exp")   # Poisson arrivals
             for i, b in enumerate(bg_nodes)]
    wl = build_workload(graph, specs, header_bytes=16, warmup_frac=0.0)
    verify_built(wl, graph).raise_if_failed()
    return wl


def _sf_cfg(policy: str, capacity: int, footprint: int) -> SFConfig:
    return SFConfig(capacity=capacity, policy=policy,
                    invblk_max=2 if policy == "blp" else 1,
                    footprint_lines=footprint)


def coupled_policy_sweep(stream, capacity: int, footprint: int,
                         n_requesters: int, bg_load: float,
                         policies=POLICIES, max_iters: int = 6,
                         tol_ps: int = 0, fanout: str = "concurrent") -> dict:
    """Run the coupled fixpoint for every victim policy, with the fabric
    pass vmapped over the stacked per-policy hop tables.

    The hop layouts are per-policy (different event logs, and under
    ``fanout="concurrent"`` different fork/BISnp row counts), so they are
    row-padded to one shape and the expensive stage — the FCFS fixpoint
    over the fabric — runs as a single ``jax.vmap`` jit per outer
    iteration; only the cheap per-policy SF scans stay sequential.
    Returns per-policy coupled and isolated metrics.
    """
    addr, wr, rid = stream
    graph, spec, bg_nodes = build_coherence_fabric(n_requesters)
    ep = graph.topo.endpoint
    channels = make_channels(graph, ep.row_hit_extra_ps, ep.row_miss_extra_ps)
    cache = CacheConfig(capacity=capacity)
    T_req = int(np.asarray(addr).shape[0])

    cfgs = {p: _sf_cfg(p, capacity, footprint) for p in policies}
    lows, evs, isolated = {}, {}, {}
    for p in policies:
        res, ev = simulate_sf(addr, wr, rid, cfgs[p], cache,
                              n_requesters=n_requesters, return_events=True)
        isolated[p] = res
        evs[p] = ev
        lows[p] = lower_coherence(graph, spec, cfgs[p], addr, wr, rid, ev,
                                  fanout=fanout)
        verify_workload(lows[p].hops, channels,
                        coherence_issue(lows[p], ev.fab_issue_ps),
                        sf_events=ev,
                        chan_pair=graph.chan_pair).raise_if_failed()
    span = max(int(isolated[p].total_time_ps) for p in policies)
    background = _background(graph, bg_nodes, spec.dev_node, bg_load, span)

    # hop tables are fixpoint invariants: pad/concat/stack them once; each
    # iteration only rebuilds the issue vectors.  Row padding (appended
    # *after* the background rows) equalizes the per-policy fork/BISnp row
    # counts without disturbing the [:T] primary prefix or join group ids.
    per_policy = [concat_background(
        lows[p], coherence_issue(lows[p], evs[p].fab_issue_ps), background)[0]
        for p in policies]
    n_rows = max(h.channel.shape[0] for h in per_policy)
    stacked = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *[pad_rows(h, n_rows) for h in per_policy])
    bg_issue = (None if background is None
                else jnp.asarray(background.issue_ps))

    def issue_vec(p, ev):
        coh = coherence_issue(lows[p], ev.fab_issue_ps)
        full = (coh if bg_issue is None
                else jnp.concatenate([coh, bg_issue]))
        return jnp.concatenate(
            [full, jnp.zeros(n_rows - full.shape[0], jnp.int64)])

    # hops are vmapped tracers inside the jit: resolve the round bound
    # host-side from the concrete stacked tables
    opts = SimOptions(max_rounds=round_bound(stacked))

    @jax.jit
    def fabric_pass(hops, issues):
        return jax.vmap(
            lambda h, i: simulate(h, channels, i, opts)
        )(hops, issues)

    miss = {p: jnp.asarray(lows[p].miss) for p in policies}
    fab = {p: None for p in policies}
    sf = {p: isolated[p] for p in policies}
    sched = None
    done = False
    iters_used = 0
    for iters_used in range(1, max_iters + 1):
        issues = []
        for p in policies:
            if fab[p] is not None:
                sf[p], evs[p] = simulate_sf(
                    addr, wr, rid, cfgs[p], cache,
                    n_requesters=n_requesters, fabric_lat_ps=fab[p],
                    return_events=True)
            issues.append(issue_vec(p, evs[p]))
        sched = fabric_pass(stacked, jnp.stack(issues))
        assert bool(sched.converged.all()), "fabric fixpoint did not converge"
        done = True
        for i, p in enumerate(policies):
            new = jnp.where(miss[p],
                            sched.complete[i, :T_req] - issues[i][:T_req],
                            jnp.int64(0))
            if fab[p] is None or int(jnp.max(jnp.abs(new - fab[p]))) > tol_ps:
                done = False
            fab[p] = new
        if done:
            break
    if not done:
        # limit cycle at max_iters: re-sync the SF view and the schedule
        # with the final stall times (mirror of simulate_coupled's final
        # pass) so the reported metrics belong to one iteration
        issues = []
        for p in policies:
            sf[p], evs[p] = simulate_sf(
                addr, wr, rid, cfgs[p], cache, n_requesters=n_requesters,
                fabric_lat_ps=fab[p], return_events=True)
            issues.append(issue_vec(p, evs[p]))
        sched = fabric_pass(stacked, jnp.stack(issues))
        assert bool(sched.converged.all())

    out = {}
    for i, p in enumerate(policies):
        m = np.asarray(miss[p])
        lat_iso = np.asarray(isolated[p].latency_ps)
        lat_cpl = np.asarray(sf[p].latency_ps)
        from repro.core.engine import Schedule
        sched_p = Schedule(*[x[i] for x in sched])
        bl = np.asarray(bisnp_latencies(sched_p, lows[p]))
        out[p] = {
            "iso_miss_lat_ns": float(lat_iso[m].mean()) / 1e3,
            "cpl_miss_lat_ns": float(lat_cpl[m].mean()) / 1e3,
            "iso_bw_MBps": float(isolated[p].bandwidth_MBps),
            "cpl_bw_MBps": float(sf[p].bandwidth_MBps),
            "bisnp_meas_ns": float(bl[bl > 0].mean()) / 1e3
            if (bl > 0).any() else 0.0,
            "bisnp_model_ns": cfgs[p].bisnp_rtt_ps / 1e3,
        }
    # convergence telemetry riding into --json rows (ISSUE 6): the trend
    # the planned round-budget/Pallas work will gate against
    out["_meta"] = {
        "fixpoint_iters": iters_used,
        "fixpoint_converged": bool(done),
        "engine_rounds": [int(r) for r in np.asarray(sched.rounds)],
        "engine_converged": bool(sched.converged.all()),
    }
    return out


def run_divergence_sweep(n: int = 1200, footprint: int = 1024,
                         capacity: int | None = None,
                         loads=(0.0, 0.3, 0.6, 0.9),
                         policies=POLICIES) -> list[dict]:
    """Mean coupled miss latency vs background load (fraction of the device
    link's capacity; 0 = no background).  The divergence gate lives on the
    fifo column: strictly growing with load and nonzero under load."""
    # capacity at the hot-set size: the stream touches more unique
    # lines than the SF holds, so capacity victims (the policy-
    # differentiating BISnp source) actually fire at bench sizes
    cap = capacity or int(0.1 * footprint)
    stream = make_skewed_stream(n, footprint, write_ratio=0.2,
                                n_requesters=2, seed=7)
    rows = []
    for load in loads:
        res = coupled_policy_sweep(stream, cap, footprint, 2, load,
                                   policies=policies)
        rows.append({"load": load, "policies": res})
    return rows


def divergence_gate(sweep: list[dict], policy: str = "fifo") -> dict:
    """Isolated-vs-coupled divergence per load level, and the gate."""
    iso = sweep[0]["policies"][policy]["iso_miss_lat_ns"]
    div = [r["policies"][policy]["cpl_miss_lat_ns"] - iso for r in sweep]
    grows = all(b > a for a, b in zip(div, div[1:]))
    return {"divergence_ns": div, "grows_with_load": grows,
            "nonzero": div[-1] > 0}


def run_fanout_sweep(owner_counts=(1, 2, 3, 4), n: int = 600,
                     footprint: int = 256) -> list[dict]:
    """Serialized-vs-concurrent snoop fan-out divergence vs owner count.

    A sequential stream interleaved over R requesters makes every SF entry
    R-way shared (each requester's first touch reaches the device and adds
    its owner bit), so capacity victims fire R-owner BISnp groups.  Both
    lowerings of the *same* event log run on the same fabric; the chain
    model pays the k snoop round trips in sequence, the fork/join model
    pays the slowest — so mean snooped-miss latency diverges more the more
    owners a snoop targets.
    """
    out = []
    for r_cnt in owner_counts:
        graph, spec, _ = build_coherence_fabric(r_cnt)
        ep = graph.topo.endpoint
        channels = make_channels(graph, ep.row_hit_extra_ps,
                                 ep.row_miss_extra_ps)
        addr, wr, rid = make_sequential_stream(n, footprint,
                                               n_requesters=r_cnt)
        cap = max(int(0.1 * footprint), 8)
        cfg = SFConfig(capacity=cap, policy="fifo",
                       footprint_lines=footprint)
        _, ev = simulate_sf(addr, wr, rid, cfg, CacheConfig(capacity=cap),
                            n_requesters=r_cnt, return_events=True)
        lat = {}
        rounds = {}
        owners = np.zeros(1)
        for fanout in ("chain", "concurrent"):
            low = lower_coherence(graph, spec, cfg, addr, wr, rid, ev,
                                  fanout=fanout, upgrade_bisnp=False)
            issue = coherence_issue(low, ev.fab_issue_ps)
            verify_workload(low.hops, channels, issue, sf_events=ev,
                            chan_pair=graph.chan_pair).raise_if_failed()
            sched = simulate(low.hops, channels, issue)
            assert bool(sched.converged), f"fanout={fanout} did not converge"
            rounds[fanout] = int(sched.rounds)
            t_req = low.miss.shape[0]
            snooped = low.miss & (np.asarray(ev.bisnp_mask) > 0)
            lat[fanout] = float(np.mean(
                np.asarray(sched.complete[:t_req])[snooped]
                - np.asarray(ev.fab_issue_ps)[snooped]))
            owners = np.array([bin(int(m)).count("1") for m in
                               np.asarray(ev.bisnp_mask)[snooped]])
        out.append({
            "owners": r_cnt,
            "mean_snooped": float(owners.mean()) if owners.size else 0.0,
            "chain_ns": lat["chain"] / 1e3,
            "conc_ns": lat["concurrent"] / 1e3,
            "div_ns": (lat["chain"] - lat["concurrent"]) / 1e3,
            "engine_rounds": rounds,
        })
    return out


def fanout_gate(sweep: list[dict]) -> dict:
    """Chain-minus-concurrent divergence must grow monotonically with the
    snooped owner count and be positive once snoops actually fan out."""
    div = [r["div_ns"] for r in sweep]
    grows = all(b > a for a, b in zip(div, div[1:]))
    return {"divergence_ns": div, "grows_with_owners": grows,
            "nonzero": div[-1] > 0}


def run_trace_mode(names=("xsbench", "silo"), n: int = 800,
                   footprint: int = 1024, load: float = 0.6) -> dict:
    """§V-E trace workloads through the coupled pipeline (fifo + lifo)."""
    out = {}
    for name in names:
        stream = traces.request_stream(name, n=n, footprint_lines=footprint,
                                       n_requesters=2, seed=3)
        res = coupled_policy_sweep(stream, int(0.1 * footprint), footprint,
                                   2, load, policies=("fifo", "lifo"))
        out[name] = res
    return out


def run(quick: bool = False) -> list[Row]:
    rows: list[Row] = []
    n = 400 if quick else 1200
    footprint = 512 if quick else 1024
    policies = ("fifo", "lru", "lifo", "blp") if quick else POLICIES

    with Timer() as t:
        sweep = run_divergence_sweep(n=n, footprint=footprint,
                                     policies=policies)
    for r in sweep:
        f = r["policies"]["fifo"]
        rows.append(Row(
            f"coherence_fabric/load{r['load']:g}", t.us,
            f"iso_lat={f['iso_miss_lat_ns']:.0f}ns;"
            f"cpl_lat={f['cpl_miss_lat_ns']:.0f}ns;"
            f"bisnp_meas={f['bisnp_meas_ns']:.0f}ns;"
            f"bisnp_model={f['bisnp_model_ns']:.0f}ns",
            meta=r["policies"].get("_meta"),
        ))
    top = sweep[-1]["policies"]
    order = ";".join(f"{p}={top[p]['cpl_miss_lat_ns']:.0f}" for p in policies)
    rows.append(Row("coherence_fabric/policies_at_load", t.us, order))
    gate = divergence_gate(sweep)
    rows.append(Row(
        "coherence_fabric/divergence_gate", t.us,
        f"div_ns={','.join(f'{d:.0f}' for d in gate['divergence_ns'])};"
        f"grows={gate['grows_with_load']};nonzero={gate['nonzero']};"
        f"gate={gate['grows_with_load'] and gate['nonzero']}",
    ))
    assert gate["grows_with_load"] and gate["nonzero"], \
        "isolated-vs-coupled divergence gate failed"

    with Timer() as t:
        fsweep = run_fanout_sweep(owner_counts=(1, 2, 3) if quick
                                  else (1, 2, 3, 4),
                                  n=300 if quick else 600,
                                  footprint=footprint // 2)
    for r in fsweep:
        rows.append(Row(
            f"coherence_fabric/fanout_owners{r['owners']}", t.us,
            f"chain={r['chain_ns']:.0f}ns;conc={r['conc_ns']:.0f}ns;"
            f"div={r['div_ns']:.0f}ns;snooped={r['mean_snooped']:.2f}",
            meta={"engine_rounds": r["engine_rounds"],
                  "engine_converged": True},
        ))
    fgate = fanout_gate(fsweep)
    rows.append(Row(
        "coherence_fabric/fanout_gate", t.us,
        f"div_ns={','.join(f'{d:.0f}' for d in fgate['divergence_ns'])};"
        f"grows={fgate['grows_with_owners']};nonzero={fgate['nonzero']};"
        f"gate={fgate['grows_with_owners'] and fgate['nonzero']}",
    ))
    assert fgate["grows_with_owners"] and fgate["nonzero"], \
        "serialized-vs-concurrent fan-out divergence gate failed"

    with Timer() as t:
        tr = run_trace_mode(n=300 if quick else 800,
                            footprint=footprint)
    for name, res in tr.items():
        f = res["fifo"]
        rows.append(Row(
            f"coherence_fabric/trace_{name}", t.us,
            f"iso_lat={f['iso_miss_lat_ns']:.0f}ns;"
            f"cpl_lat={f['cpl_miss_lat_ns']:.0f}ns;"
            f"lifo_cpl={res['lifo']['cpl_miss_lat_ns']:.0f}ns",
            meta=res.get("_meta"),
        ))
    return rows
