"""PCIe 5 vs PCIe 6 flit link layer + BER sensitivity (core.link_layer).

Reproduces the paper's PCIe-generation comparison with the link layer as a
first-class subsystem instead of one bandwidth constant, and adds the two
studies the flit model enables:

  * **generation comparison** — the §IV validation bus run byte-exact at the
    PCIe 5 effective rate (the seed's model), in 68 B flit mode on the raw
    PCIe 5 lane rate, and in 256 B flit mode on the raw PCIe 6 lane rate.
    PCIe 6 should land at ~2x goodput with flit overhead visibly below the
    raw 2.03x lane-rate ratio.

  * **flit-efficiency check** — a saturated fully-packed write stream in
    256 B flit mode at BER 0 must measure the analytic 236/256 payload
    fraction on the requester uplink to < 0.5 % (acceptance gate).

  * **BER sensitivity** — goodput vs bit error rate under Go-Back-N CRC
    replay, swept as one ``vmap`` over the per-channel ``replay_ppm`` table
    (no hop-table rebuild); goodput must fall monotonically with BER.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import topology as T
from repro.core.calibration import (PCIE5_X16_MBPS, PCIE5_X16_RAW_MBPS,
                                    PCIE6_X16_RAW_MBPS)
from repro.core.devices import RequesterSpec, build_workload
from repro.core.engine import channel_stats, request_stats, simulate_auto
from repro.core.verify import verify_built
from repro.core.link_layer import (FlitConfig, flit_efficiency,
                                   replay_overhead_ppm)

from .common import Row, Timer

BERS = (0.0, 1e-8, 1e-7, 3e-7, 1e-6, 3e-6, 1e-5)


def _bus_workload(bw_MBps: int, flit, n: int, payload: int = 944,
                  read_ratio: float = 0.0):
    """§IV validation system, saturated open loop (944 B = 4 full flits)."""
    topo = T.with_flit(T.single_bus(n_mems=4, bw_MBps=bw_MBps), flit)
    g = topo.build()
    spec = RequesterSpec(node=0, n_requests=n, targets=[2, 3, 4, 5],
                         pattern="uniform", read_ratio=read_ratio,
                         issue_interval_ps=100, payload_bytes=payload, seed=11)
    wl = build_workload(g, [spec], header_bytes=64, warmup_frac=0.0)
    verify_built(wl, g).raise_if_failed()
    return wl


def run_generation(gen: str, n: int = 2500) -> tuple[float, float]:
    """(goodput MB/s, mean latency ns) of one link-generation config."""
    cfgs = {
        "pcie5_bytes": (PCIE5_X16_MBPS, None),           # the seed's model
        "pcie5_flit68": (PCIE5_X16_RAW_MBPS, FlitConfig("flit68")),
        "pcie6_flit256": (PCIE6_X16_RAW_MBPS, FlitConfig("flit256")),
    }
    bw, flit = cfgs[gen]
    wl = _bus_workload(bw, flit, n, read_ratio=0.5)
    sched, _ = simulate_auto(wl.hops, wl.channels, wl.issue_ps)
    r = request_stats(wl.hops, sched, wl.issue_ps, wl.payload_bytes,
                      wl.measured)
    return float(r["bandwidth_MBps"]), float(r["mean_latency_ps"]) / 1000


def run_efficiency_check(n: int = 2000) -> tuple[float, float]:
    """(measured uplink efficiency, relative error vs analytic 236/256).

    Write-only traffic with 944 B payloads (4 fully packed 236 B flits) at
    BER 0: every uplink transmission is payload, so channel efficiency —
    logical payload time over wire busy time — is exactly the flit packing
    fraction.
    """
    wl = _bus_workload(PCIE6_X16_RAW_MBPS, FlitConfig("flit256"), n)
    sched, _ = simulate_auto(wl.hops, wl.channels, wl.issue_ps)
    c = channel_stats(wl.hops, sched, wl.channels)
    measured = float(np.asarray(c["efficiency"])[0])  # requester uplink
    analytic = flit_efficiency("flit256")
    return measured, abs(measured - analytic) / analytic


def run_ber_sweep(bers=BERS, n: int = 1500) -> list[tuple[float, float]]:
    """[(ber, goodput MB/s)] — one vmapped jit over the replay_ppm table."""
    wl = _bus_workload(PCIE6_X16_RAW_MBPS, FlitConfig("flit256"), n,
                       read_ratio=0.5)
    link = ~np.asarray(wl.channels.flit_size == 0)
    ppms = jnp.asarray([replay_overhead_ppm(b, "flit256") for b in bers],
                       jnp.int64)

    def one(ppm):
        ch = wl.channels._replace(
            replay_ppm=jnp.where(jnp.asarray(link), ppm, 0))
        from repro.core.engine import simulate
        s = simulate(wl.hops, ch, wl.issue_ps)
        r = request_stats(wl.hops, s, wl.issue_ps, wl.payload_bytes,
                          wl.measured)
        return r["bandwidth_MBps"], s.converged

    goodput, conv = jax.vmap(one)(ppms)
    assert bool(conv.all()), "BER sweep instance failed to converge"
    return [(b, float(g)) for b, g in zip(bers, np.asarray(goodput))]


def run(quick: bool = False) -> list[Row]:
    rows: list[Row] = []
    n = 800 if quick else 2500

    base = None
    for gen in ("pcie5_bytes", "pcie5_flit68", "pcie6_flit256"):
        with Timer() as t:
            bw, lat = run_generation(gen, n)
        base = base or bw
        rows.append(Row(f"link_layer/gen/{gen}", t.us,
                        f"goodput_MBps={bw:.0f};vs_pcie5={bw / base:.2f};"
                        f"latency_ns={lat:.0f}"))

    with Timer() as t:
        eff, rel_err = run_efficiency_check(max(n, 1000))
    rows.append(Row("link_layer/flit256_efficiency", t.us,
                    f"measured={eff:.4f};analytic={flit_efficiency('flit256'):.4f};"
                    f"rel_err={rel_err:.4f};pass={rel_err < 0.005}"))

    with Timer() as t:
        sweep = run_ber_sweep(BERS[:4] if quick else BERS, n=min(n, 1500))
    mono = all(g1 >= g2 for (_, g1), (_, g2) in zip(sweep, sweep[1:]))
    rows.append(Row("link_layer/ber_sweep", t.us,
                    ";".join(f"ber{b:g}={g:.0f}" for b, g in sweep)
                    + f";monotone={mono}"))
    return rows
