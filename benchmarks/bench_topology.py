"""Paper Fig. 10/11/12: system bandwidth & latency across fabric topologies.

Reproduces claim F1 (chain/tree saturate at ~1x port bandwidth; ring ~2x;
spine-leaf ~N/2; fully-connected ~N) and F2 (hop-count latency breakdown;
bridge-route congestion; ISO-bisection comparison).

Experimental setup mirrors §V-A: N requesters + N memories on PBR switches,
uniform random traffic of every requester to every memory, port bandwidth
fixed, bandwidth normalized to one switch port.  Header bytes = payload
(64 B CXL flit realism) so request and response packets both load the fabric.
"""

from __future__ import annotations

import numpy as np

from repro.core import topology as T
from repro.core.devices import RequesterSpec, build_workload
from repro.core.engine import channel_stats, request_stats, simulate
from repro.core.verify import verify_built

from .common import Row, Timer

PORT_MBPS = 64_000
FIXED_PS = 26_000  # 25 ns port delay + 1 ns bus
FLOOD_IV_PS = 500
LOAD_IV_PS = 6_000


def _specs(topo: T.Topology, n_per_pair: int, interval_ps: int, seed: int = 0):
    reqs = topo.requesters()
    mems = topo.memories()
    return [
        RequesterSpec(node=int(r), n_requests=n_per_pair * len(mems),
                      targets=[int(m) for m in mems], pattern="uniform",
                      read_ratio=1.0, issue_interval_ps=interval_ps,
                      footprint_lines=4096 * len(mems), seed=seed + i)
        for i, r in enumerate(reqs)
    ]


def build_topo(kind: str, n_pairs: int, bw: int = PORT_MBPS) -> T.Topology:
    kw = dict(bw_MBps=bw, fixed_ps=FIXED_PS)
    if kind == "spine_leaf":
        return T.spine_leaf(n_pairs, n_spines=2, per_leaf=min(4, n_pairs), **kw)
    return T.TOPOLOGY_BUILDERS[kind](n_pairs, **kw)


def run_one(kind: str, n_pairs: int, n_per_pair: int, interval_ps: int,
            bw: int = PORT_MBPS, seed: int = 0):
    """ECMP tie-breaking spreads equal-cost flows (the PBR default; without
    it, deterministic alternative-0 routing collapses ring/spine-leaf onto a
    single boundary link — visible if ``route_choice`` is omitted)."""
    topo = build_topo(kind, n_pairs, bw)
    graph = topo.build()
    n_tx = sum(sp.n_requests for sp in _specs(topo, n_per_pair, interval_ps))
    rng = np.random.default_rng(seed + 17)
    wl = build_workload(graph, _specs(topo, n_per_pair, interval_ps),
                        header_bytes=64,
                        route_choice=rng.integers(0, 1 << 20, n_tx))
    verify_built(wl, graph).raise_if_failed()
    sched = simulate(wl.hops, wl.channels, wl.issue_ps)
    rstats = request_stats(wl.hops, sched, wl.issue_ps, wl.payload_bytes,
                           wl.measured)
    cstats = channel_stats(wl.hops, sched, wl.channels)
    return wl, sched, rstats, cstats


# Analytic bisection link counts for the ISO-bisection configuration (Fig. 12)
def bisection_links(kind: str, n_pairs: int) -> int:
    if kind in ("chain", "tree"):
        return 1
    if kind == "ring":
        return 2
    if kind == "spine_leaf":
        return 2 * max(n_pairs // 4, 1)      # spines x requester leaves
    if kind == "fully_connected":
        return n_pairs * n_pairs             # direct req-side/mem-side links
    raise KeyError(kind)


def run(quick: bool = False) -> list[Row]:
    rows: list[Row] = []
    scales = (2, 4, 8) if quick else (2, 4, 8, 16)
    n_per_pair = 30 if quick else 120

    # ---- Fig. 10: normalized aggregate bandwidth vs scale ---------------
    for kind in ("chain", "tree", "ring", "spine_leaf", "fully_connected"):
        for n_pairs in scales:
            with Timer() as t:
                _, sched, rstats, _ = run_one(kind, n_pairs, n_per_pair, FLOOD_IV_PS)
            norm_bw = float(rstats["steady_bandwidth_MBps"]) / PORT_MBPS
            rows.append(Row(
                f"fig10/{kind}/scale{2 * n_pairs}", t.us,
                f"norm_bw={norm_bw:.2f};target={_fig10_target(kind, n_pairs):.2f};"
                f"converged={bool(sched.converged)}",
            ))

    # ---- Fig. 11: latency grouped by hop count (scale 16) ----------------
    n_pairs = 4 if quick else 8
    for kind in ("chain", "tree", "ring", "spine_leaf", "fully_connected"):
        with Timer() as t:
            wl, sched, rstats, _ = run_one(kind, n_pairs, n_per_pair, LOAD_IV_PS)
        lat = np.asarray(rstats["latency_ps"]) / 1000.0
        wait = np.asarray(rstats["queue_wait_ps"]) / 1000.0
        hops = wl.n_link_hops
        meas = np.asarray(wl.measured)
        parts = []
        for h in np.unique(hops):
            m = meas & (hops == h)
            if m.sum():
                parts.append(f"h{h}:lat={lat[m].mean():.0f}ns:wait={wait[m].mean():.0f}ns")
        rows.append(Row(f"fig11/{kind}/scale{2 * n_pairs}", t.us, ";".join(parts)))

    # ---- Fig. 12: ISO-bisection-bandwidth latency -----------------------
    base_bisect = bisection_links("fully_connected", n_pairs)
    for kind in ("chain", "tree", "ring", "spine_leaf", "fully_connected"):
        scale = max(base_bisect // bisection_links(kind, n_pairs), 1)
        with Timer() as t:
            wl, sched, rstats, _ = run_one(kind, n_pairs, n_per_pair,
                                           LOAD_IV_PS, bw=PORT_MBPS * scale)
        lat = np.asarray(rstats["latency_ps"]) / 1000.0
        hops = wl.n_link_hops
        meas = np.asarray(wl.measured)
        lo = lat[meas & (hops == hops[meas].min())].mean()
        hi = lat[meas & (hops == hops[meas].max())].mean()
        rows.append(Row(
            f"fig12/{kind}/iso_bisection", t.us,
            f"mean_lat={lat[meas].mean():.0f}ns;minhop={lo:.0f}ns;maxhop={hi:.0f}ns;"
            f"congestion_ratio={hi / max(lo, 1e-9):.2f}",
        ))
    return rows


def _fig10_target(kind: str, n_pairs: int) -> float:
    n = n_pairs
    return {"chain": 1.0, "tree": 1.0, "ring": 2.0,
            "spine_leaf": n / 2, "fully_connected": float(n)}[kind]
