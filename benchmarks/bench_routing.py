"""Paper Fig. 13: oblivious vs adaptive routing under noisy neighbours.

Setup per §V-A: a spine-leaf system with eight memory endpoints, eight noisy
neighbours intensively accessing the memories, and one observed host accessing
at a fixed rate.  We measure the observed host's achieved bandwidth,
normalized to the maximum port bandwidth.

Strategies: oblivious (deterministic shortest-path — all equal-cost ties
resolve to the same spine, so the noisy uplink crowd the host), ecmp
(hash-spread, an oblivious flavour included for reference), adaptive
(congestion-driven re-selection via `core.routing`).  Expected reproduction:
adaptive >> oblivious for the observed host.
"""

from __future__ import annotations

import numpy as np

from repro.core import topology as T
from repro.core.devices import RequesterSpec
from repro.core.engine import request_stats
from repro.core.routing import route_and_simulate

from .common import Row, Timer

PORT = 64_000
FIXED = 26_000


def build_system():
    """2 spines; 3 requester leaves (host + 8 noisy); 4 memory leaves (8 mems).

    The memory side has ample uplink capacity (8 ports for ~3.5 ports of
    demand), so the contended resource is the requester-leaf uplink choice —
    exactly where the routing strategy acts.
    """
    kinds, links = [], []

    def add(kind):
        kinds.append(kind)
        return len(kinds) - 1

    spines = [add(T.SWITCH), add(T.SWITCH)]
    rleaves = [add(T.SWITCH) for _ in range(3)]
    mleaves = [add(T.SWITCH) for _ in range(4)]
    for lf in rleaves + mleaves:
        for sp in spines:
            links.append(T.LinkSpec(lf, sp, PORT, FIXED))
    host = add(T.REQUESTER)
    links.append(T.LinkSpec(host, rleaves[0], PORT, FIXED))
    noisy = []
    for i in range(8):
        r = add(T.REQUESTER)
        noisy.append(r)
        links.append(T.LinkSpec(r, rleaves[i % 3], PORT, FIXED))
    mems = []
    for i in range(8):
        m = add(T.MEMORY)
        mems.append(m)
        links.append(T.LinkSpec(m, mleaves[i % 4], PORT, FIXED))
    return T.Topology(np.asarray(kinds, np.int64), links, name="fig13"), host, noisy, mems


def run_strategy(strategy: str, n_host: int, n_noisy: int):
    topo, host, noisy, mems = build_system()
    graph = topo.build()
    specs = [RequesterSpec(node=host, n_requests=n_host, targets=mems,
                           pattern="uniform", issue_interval_ps=1_200, seed=1)]
    specs += [RequesterSpec(node=r, n_requests=n_noisy, targets=mems,
                            pattern="uniform", issue_interval_ps=2_400, seed=2 + i)
              for i, r in enumerate(noisy)]
    wl, sched, stats = route_and_simulate(graph, specs, strategy=strategy,
                                          header_bytes=64)
    rst = request_stats(wl.hops, sched, wl.issue_ps, wl.payload_bytes,
                        wl.measured)
    host_mask = (wl.requester == host) & np.asarray(wl.measured)
    lat = np.asarray(rst["latency_ps"])[host_mask].mean() / 1000.0
    comp = np.asarray(sched.complete)[wl.requester == host]
    iss = np.asarray(wl.issue_ps)[wl.requester == host]
    host_bw = n_host * 64 * 1e12 / (comp.max() - iss.min()) / 1e6
    return host_bw / PORT, lat


def run(quick: bool = False) -> list[Row]:
    n_host = 200 if quick else 600
    n_noisy = 250 if quick else 800
    rows: list[Row] = []
    base = None
    for strat in ("oblivious", "ecmp", "adaptive"):
        with Timer() as t:
            bw, lat = run_strategy(strat, n_host, n_noisy)
        if base is None:
            base = bw
        rows.append(Row(
            f"fig13/{strat}", t.us,
            f"host_norm_bw={bw:.3f};vs_oblivious={bw / base:.2f};host_lat={lat:.0f}ns",
        ))
    return rows
