"""Tail latency under stochastic link reliability (core.link_layer).

The expected-value CRC-replay model (PR 1, `bench_link_layer`) is exact in
the mean but structurally blind to tails: every packet pays the same
deterministic stretch, so p99/p50 is flat in BER.  This bench runs the same
§IV validation bus in ``reliability="stochastic"`` mode — seeded per-flit
Go-Back-N replay counts plus retraining stalls sampled at build time — and
reports what the deterministic model cannot express:

  * **tail sweep** — p50/p99 request latency vs BER for both reliability
    modes.  The stochastic p99-p50 spread must grow with BER (replay
    bursts and retraining stalls land on unlucky packets) and overtake the
    expected-value spread, which only widens with the uniform queueing
    slowdown.  The per-flit sampling has the expected model as its mean,
    but under saturation the stalls legitimately shift the whole
    distribution, medians included.

  * **zero-BER equivalence** — at BER 0 the sampled tables are all zero, so
    the stochastic schedule must equal the deterministic one *exactly*
    (acceptance gate).

  * **retraining stalls** — with a retrain threshold, CRC-failure storms
    drop a channel into microsecond link-down intervals (per-channel
    ``down_until`` scan state).  Enabling retraining on the same seeded
    fault history must strictly delay the makespan once any event fires.

The stochastic sweep still runs as one vmapped jit: the sampled outcomes
live in per-hop ``Hops`` tables (not channel tables), so the per-BER
tables — including the full-duplex retraining-mirror markers the build
path inserts — pad to one width and stack along a leading axis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import topology as T
from repro.core.calibration import PCIE6_X16_RAW_MBPS
from repro.core.devices import RequesterSpec, build_workload
from repro.core.engine import simulate
from repro.core.verify import verify_built
from repro.core.link_layer import (FlitConfig, apply_retrain_markers,
                                   broadcast_reliability_tables,
                                   replay_overhead_ppm, sample_hop_tables)

from .common import Row, Timer

BERS = (0.0, 1e-6, 1e-5, 3e-5, 1e-4)
RETRAIN_THRESHOLD = 2
RETRAIN_PS = 1_000_000  # 1 us link-down per retraining event


def _bus_workload(flit, n: int, payload: int = 944, seed: int = 11,
                  with_graph: bool = False):
    """§IV validation system, saturated open loop (944 B = 4 full flits).

    ``with_graph=True`` also returns the built graph, so callers that need
    channel metadata (e.g. ``chan_pair`` for marker insertion) read it
    from the exact object the workload was lowered against.
    """
    topo = T.with_flit(T.single_bus(n_mems=4, bw_MBps=PCIE6_X16_RAW_MBPS),
                       flit)
    graph = topo.build()
    spec = RequesterSpec(node=0, n_requests=n, targets=[2, 3, 4, 5],
                         pattern="uniform", read_ratio=0.5,
                         issue_interval_ps=100, payload_bytes=payload,
                         seed=seed)
    wl = build_workload(graph, [spec], header_bytes=64, warmup_frac=0.0)
    verify_built(wl, graph).raise_if_failed()
    return (wl, graph) if with_graph else wl


def _stochastic_cfg(ber: float, rel_seed: int = 0,
                    retrain_threshold: int = RETRAIN_THRESHOLD) -> FlitConfig:
    return FlitConfig("flit256", ber=ber, reliability="stochastic",
                      rel_seed=rel_seed, retrain_threshold=retrain_threshold,
                      retrain_ps=RETRAIN_PS)


def run_tail_sweep(bers=BERS, n: int = 1500,
                   rel_seed: int = 0) -> list[dict]:
    """Per BER: p50/p99 latency (ns) of the expected and stochastic modes.

    Expected mode vmaps over the per-channel ``replay_ppm`` table; the
    stochastic mode vmaps over the stacked per-hop sampled tables — both
    sweeps are one jit each over an identical hop layout.
    """
    wl, graph = _bus_workload(FlitConfig("flit256"), n, with_graph=True)
    link = jnp.asarray(np.asarray(wl.channels.flit_size) > 0)

    def one_expected(ppm):
        ch = wl.channels._replace(replay_ppm=jnp.where(link, ppm, 0))
        s = simulate(wl.hops, ch, wl.issue_ps)
        return s.complete, s.converged

    ppms = jnp.asarray([replay_overhead_ppm(b, "flit256") for b in bers],
                       jnp.int64)
    comp_e, conv_e = jax.vmap(one_expected)(ppms)
    assert bool(conv_e.all()), "expected-mode sweep failed to converge"

    # stochastic: sample each BER's tables off the shared workload's arrays
    # (identical streams to a per-BER build: same channel ids, seeds and
    # parameters) and mirror the full-duplex retraining stalls exactly as
    # the build path does — each per-BER table is then bit-identical to a
    # real build.  Marker insertion widens rows per BER, so the tables are
    # padded to one width and the whole Hops pytree vmaps in one jit.
    c = int(wl.channels.bw_MBps.shape[0])
    chan_np = np.asarray(wl.hops.channel)
    nbytes_np = np.asarray(wl.hops.nbytes)
    valid_np = np.asarray(wl.hops.valid)
    link_np = np.asarray(wl.channels.flit_size) > 0
    chan_pair = graph.chan_pair
    hops_by_ber = []
    for b in bers:
        extra, retrain = sample_hop_tables(
            chan_np, nbytes_np, valid_np,
            **broadcast_reliability_tables(_stochastic_cfg(b, rel_seed), c,
                                           link_np))
        hops_by_ber.append(apply_retrain_markers(
            wl.hops._replace(extra_wire_bytes=jnp.asarray(extra),
                             retrain_after_ps=jnp.asarray(retrain)),
            chan_pair))
    ch_s = wl.channels._replace(
        replay_ppm=jnp.zeros_like(wl.channels.replay_ppm))

    h_max = max(h.channel.shape[1] for h in hops_by_ber)
    fills = dict(channel=-1, nbytes=0, direction=0, row=-1,
                 fixed_after_ps=0, is_payload=False, valid=False,
                 extra_wire_bytes=0, retrain_after_ps=0)

    def pad(h):
        return h._replace(**{
            f: jnp.asarray(np.pad(
                np.asarray(getattr(h, f)),
                ((0, 0), (0, h_max - getattr(h, f).shape[1])),
                constant_values=v))
            for f, v in fills.items()})

    stacked = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *[pad(h) for h in hops_by_ber])

    def one_stochastic(h):
        s = simulate(h, ch_s, wl.issue_ps)
        return s.complete, s.converged

    comp_s, conv_s = jax.vmap(one_stochastic)(stacked)
    assert bool(conv_s.all()), "stochastic sweep failed to converge"

    out = []
    for i, b in enumerate(bers):
        lat_e = (comp_e[i] - wl.issue_ps) / 1000
        lat_s = (comp_s[i] - wl.issue_ps) / 1000
        out.append({
            "ber": b,
            "expected_p50_ns": float(jnp.percentile(lat_e, 50)),
            "expected_p99_ns": float(jnp.percentile(lat_e, 99)),
            "stochastic_p50_ns": float(jnp.percentile(lat_s, 50)),
            "stochastic_p99_ns": float(jnp.percentile(lat_s, 99)),
        })
    return out


def run_zero_ber_equivalence(n: int = 800) -> bool:
    """BER-0 stochastic schedule == deterministic schedule, bit for bit."""
    wl_e = _bus_workload(FlitConfig("flit256"), n)
    wl_s = _bus_workload(_stochastic_cfg(0.0), n)
    s_e = simulate(wl_e.hops, wl_e.channels, wl_e.issue_ps)
    s_s = simulate(wl_s.hops, wl_s.channels, wl_s.issue_ps)
    return (np.array_equal(np.asarray(s_e.complete), np.asarray(s_s.complete))
            and np.array_equal(np.asarray(s_e.start), np.asarray(s_s.start)))


def run_retrain_stall(ber: float = 1e-4, n: int = 800,
                      rel_seed: int = 0) -> dict:
    """Makespan with vs without retraining on one seeded fault history.

    Threshold 0 disables retraining but draws the replay totals from the
    same stream, so the two runs share every sampled replay burst and
    differ only by the link-down intervals.
    """
    from repro.core.link_layer import strip_retrain_markers

    wl_off = _bus_workload(_stochastic_cfg(ber, rel_seed,
                                           retrain_threshold=0), n)
    wl_on = _bus_workload(_stochastic_cfg(ber, rel_seed), n)
    assert np.array_equal(
        np.asarray(wl_off.hops.extra_wire_bytes),
        np.asarray(strip_retrain_markers(wl_on.hops).extra_wire_bytes))
    s_off = simulate(wl_off.hops, wl_off.channels, wl_off.issue_ps)
    s_on = simulate(wl_on.hops, wl_on.channels, wl_on.issue_ps)
    events = int((np.asarray(wl_on.hops.retrain_after_ps) > 0).sum())
    down_ns = int(np.asarray(wl_on.hops.retrain_after_ps).sum()) / 1000
    return {
        "events": events,
        "down_ns_total": down_ns,
        "makespan_off_ns": int(jnp.max(s_off.complete)) / 1000,
        "makespan_on_ns": int(jnp.max(s_on.complete)) / 1000,
    }


def run(quick: bool = False) -> list[Row]:
    rows: list[Row] = []
    n = 500 if quick else 1500

    with Timer() as t:
        ok = run_zero_ber_equivalence(min(n, 800))
    rows.append(Row("link_reliability/zero_ber_equivalence", t.us,
                    f"stochastic_matches_deterministic={ok}"))
    assert ok, "zero-BER stochastic != deterministic (acceptance gate)"

    with Timer() as t:
        # quick mode keeps the endpoints: the divergence is decisive at the
        # top BER, not in the middle of the range
        sweep = run_tail_sweep((0.0, 1e-5, 1e-4) if quick else BERS, n=n)
    for r in sweep:
        rows.append(Row(f"link_reliability/tail/ber{r['ber']:g}", t.us,
                        f"exp_p50={r['expected_p50_ns']:.0f};"
                        f"exp_p99={r['expected_p99_ns']:.0f};"
                        f"sto_p50={r['stochastic_p50_ns']:.0f};"
                        f"sto_p99={r['stochastic_p99_ns']:.0f}"))
    spread0 = sweep[0]["stochastic_p99_ns"] - sweep[0]["stochastic_p50_ns"]
    spread1 = sweep[-1]["stochastic_p99_ns"] - sweep[-1]["stochastic_p50_ns"]
    spread_e = sweep[-1]["expected_p99_ns"] - sweep[-1]["expected_p50_ns"]
    diverges = spread1 > spread0 and spread1 > spread_e
    rows.append(Row("link_reliability/tail_divergence", t.us,
                    f"p99_minus_p50_ber0={spread0:.0f};"
                    f"p99_minus_p50_top={spread1:.0f};"
                    f"expected_top={spread_e:.0f};"
                    f"diverges={diverges}"))
    assert diverges, "stochastic tail fails to diverge (acceptance gate)"

    with Timer() as t:
        st = run_retrain_stall(n=min(n, 800))
    rows.append(Row("link_reliability/retrain_stall", t.us,
                    f"events={st['events']};down_ns={st['down_ns_total']:.0f};"
                    f"makespan_off={st['makespan_off_ns']:.0f};"
                    f"makespan_on={st['makespan_on_ns']:.0f};"
                    f"stalls={st['makespan_on_ns'] > st['makespan_off_ns']}"))
    assert st["makespan_on_ns"] > st["makespan_off_ns"], \
        "retraining fails to stall the schedule (acceptance gate)"
    return rows
