"""Critical-path extraction & bottleneck blame: acceptance gates + artifact.

Exercises `core.critical_path` over three representative fabrics and gates
the invariants the observability layer promises (AssertionErrors fail the
CI smoke step):

  * **conservation** — every request's critical-path edge contributions
    sum exactly to ``complete − issue`` (`blame` raises otherwise), and
    the aggregated table equals the summed path totals;
  * **pure observer** — extraction replays the scan on host copies; the
    schedule re-simulates bit-for-bit afterwards, and
    `extract_backpointers(check=True)` asserts its replayed grant times
    equal the engine's;
  * **flow trace** — the Perfetto export with gating-edge flows and the
    blame counter track passes `validate_trace` with zero violations;
  * **what-ifs** — `speedup_if` is exact at ``factor == 1`` (zero saved
    ps) and monotone in the factor on the busiest channel;
  * **streamed blame** — the windowed `StreamTelemetry` blame fold equals
    monolithic `channel_blame` bit for bit on the streaming smoke config;
  * **protocol legs** — `coherence_traffic.leg_blame` buckets the
    coherence config's paths into BISnp/BIRsp/writeback/demand legs and
    conserves the summed path totals.

Writes the aggregated blame tables, top-k bottlenecks, per-switch rollup
and what-if results to ``blame-critical-path.json`` (uploaded as a CI
artifact next to the ``BENCH_*.json`` perf snapshots).
"""

from __future__ import annotations

import json

import numpy as np

from repro.core import topology as T
from repro.core.calibration import PCIE6_X16_RAW_MBPS
from repro.core.coherence_traffic import (coherence_issue, leg_blame,
                                          lower_coherence)
from repro.core.critical_path import (KIND_NAMES, blame, critical_paths,
                                      extract_backpointers, path_total,
                                      speedup_if)
from repro.core.devices import RequesterSpec, build_workload
from repro.core.engine import make_channels, simulate
from repro.core.link_layer import FlitConfig
from repro.core.snoop_filter import (CacheConfig, SFConfig,
                                     make_sequential_stream, simulate_sf)
from repro.core.streaming import simulate_stream, stream_windows
from repro.core.telemetry import channel_blame
from repro.core.trace_export import (channel_names, schedule_trace,
                                     validate_trace)
from repro.core.verify import verify_built

from .bench_coherence_fabric import build_coherence_fabric
from .bench_streaming import _channels as _stream_channels
from .bench_streaming import _chunk as _stream_chunk
from .common import Phases, Row, Timer

ARTIFACT = "blame-critical-path.json"


def _coherence_config(quick: bool):
    """Snooped misses on the star coherence fabric (concurrent fan-out)."""
    graph, spec, _ = build_coherence_fabric(2)
    ep = graph.topo.endpoint
    channels = make_channels(graph, ep.row_hit_extra_ps, ep.row_miss_extra_ps)
    n = 200 if quick else 600
    addr, wr, rid = make_sequential_stream(n, 128, n_requesters=2)
    cfg = SFConfig(capacity=16, policy="fifo", footprint_lines=128)
    _, ev = simulate_sf(addr, wr, rid, cfg, CacheConfig(capacity=16),
                        n_requesters=2, return_events=True)
    low = lower_coherence(graph, spec, cfg, addr, wr, rid, ev,
                          fanout="concurrent")
    return graph, channels, low, coherence_issue(low, ev.fab_issue_ps)


def _reliability_config(quick: bool):
    """§IV bus under a stochastic flit link with retraining stalls — the
    layout family where RETRAIN edges actually bind."""
    flit = FlitConfig("flit256", ber=1e-4, reliability="stochastic",
                      rel_seed=3, retrain_threshold=2, retrain_ps=1_000_000)
    topo = T.with_flit(T.single_bus(n_mems=4, bw_MBps=PCIE6_X16_RAW_MBPS),
                       flit)
    graph = topo.build()
    spec = RequesterSpec(node=0, n_requests=150 if quick else 500,
                         targets=[2, 3, 4, 5], pattern="uniform",
                         read_ratio=0.5, issue_interval_ps=100,
                         payload_bytes=944, seed=11)
    wl = build_workload(graph, [spec], header_bytes=64, warmup_frac=0.0)
    verify_built(wl, graph).raise_if_failed()
    return graph, wl.channels, wl.hops, wl.issue_ps


def _gate_config(name, hops, channels, issue, graph=None):
    """Run every per-config gate; returns (blame, paths, artifact entry)."""
    sched = simulate(hops, channels, issue)
    assert bool(sched.converged), f"{name}: schedule did not converge"
    # extraction asserts replayed grants == engine grants (check=True)
    bp = extract_backpointers(hops, channels, sched, issue)
    paths = critical_paths(bp)
    bl = blame(bp, paths=paths)  # raises on any conservation violation
    assert bl.total_ps == sum(path_total(p) for p in paths)
    assert bl.total_ps == int(
        (np.asarray(bp.complete) - np.asarray(bp.issue)).sum())

    # pure observer: the schedule re-simulates bit-for-bit after extraction
    sched2 = simulate(hops, channels, issue)
    for field in ("start", "depart", "arrive", "complete"):
        assert np.array_equal(np.asarray(getattr(sched, field)),
                              np.asarray(getattr(sched2, field))), \
            f"{name}: extraction perturbed the schedule ({field})"

    # flow-event trace passes the schema gate
    names = channel_names(graph) if graph is not None else None
    trace = schedule_trace(hops, channels, sched, names=names,
                           flows=bp, blame=bl)
    errs = validate_trace(trace)
    assert not errs, f"{name}: trace schema violations: {errs[:3]}"

    # what-ifs on the busiest channel: identity at 1x, monotone beyond
    busiest = int(np.argmax(bl.by_channel()[:-1]))
    what_ifs = {}
    saved_prev = -1
    for factor in (1.0, 2.0, 4.0):
        w = speedup_if(bp, busiest, factor)
        saved = int(w["saved_ps"])
        if factor == 1.0:
            assert saved == 0, f"{name}: speedup_if(1.0) saved {saved} ps"
        assert saved >= saved_prev, \
            f"{name}: speedup_if not monotone at {factor}x"
        saved_prev = saved
        what_ifs[f"{factor:g}x"] = {
            "saved_ps": saved,
            "mean_latency_ps": int(w["mean_latency_ps"]),
            "baseline_mean_latency_ps": int(w["baseline_mean_latency_ps"]),
        }

    entry = {
        "n_requests": bl.n_requests,
        "total_ps": bl.total_ps,
        "by_kind": bl.by_kind(),
        "by_channel": [int(v) for v in bl.by_channel()],
        "top": [{"channel": t["channel"], "kind": t["kind"],
                 "ps": t["ps"], "share": round(t["share"], 4)}
                for t in bl.top(5)],
        "flow_events": sum(1 for e in trace["traceEvents"]
                           if e.get("ph") == "s"),
        "busiest_channel": busiest,
        "speedup_if": what_ifs,
    }
    if graph is not None:
        entry["by_switch"] = {str(k): v
                              for k, v in bl.by_switch(graph).items()}
    return bp, paths, bl, entry


def run(quick: bool = False) -> list[Row]:
    rows: list[Row] = []
    phases = Phases()
    artifact: dict = {}

    # ---- coherence fabric: blame + protocol-leg mapping ------------------
    with phases("lower"):
        graph, channels, low, issue = _coherence_config(quick)
    with Timer() as t, phases("execute"):
        bp, paths, bl, entry = _gate_config(
            "coherence", low.hops, channels, issue, graph=graph)
    legs = leg_blame(low, paths)
    assert sum(legs.values()) == bl.total_ps, \
        "leg blame does not conserve the summed path totals"
    assert legs["bisnp"] > 0 and legs["service"] > 0, \
        f"coherence paths never crossed snoop/service legs: {legs}"
    entry["leg_blame"] = legs
    artifact["coherence_fabric"] = entry
    top = bl.top(1)[0]
    rows.append(Row(
        "critical_path/coherence_fabric", t.us,
        f"rows={bp.n};total_ms={bl.total_ps / 1e9:.2f};"
        f"top={top['kind']}@ch{top['channel']}:{top['share']:.0%};"
        f"conservation=exact",
        meta=entry))

    # ---- reliability bus: retrain edges on the critical path -------------
    with phases("build"):
        rgraph, rch, rhops, rissue = _reliability_config(quick)
    with Timer() as t, phases("execute"):
        _, _, rbl, rentry = _gate_config(
            "reliability", rhops, rch, rissue, graph=rgraph)
    assert rbl.by_kind()["retrain"] > 0, \
        "stochastic retraining config produced no RETRAIN blame"
    artifact["reliability_bus"] = rentry
    rows.append(Row(
        "critical_path/reliability_bus", t.us,
        f"rows={rentry['n_requests']};"
        f"retrain_us={rbl.by_kind()['retrain'] / 1e6:.1f};"
        f"queue_us={rbl.by_kind()['queue'] / 1e6:.1f};conservation=exact",
        meta=rentry))

    # ---- streaming smoke: windowed blame fold == monolithic --------------
    with phases("build"):
        sch = _stream_channels()
        shops, sissue = _stream_chunk(0, 2000 if quick else 8000, 0, seed=0)
    with Timer() as t, phases("execute"):
        mono = simulate(shops, sch, sissue)
        assert bool(mono.converged)
        mb = channel_blame(shops, sch, mono, sissue)
        out = simulate_stream(
            stream_windows(shops, np.asarray(sissue), 512), sch)
        sb = out.summary()["blame"]
    for key, ref in (("queue_ps", mb.queue_ps), ("retrain_ps", mb.retrain_ps),
                     ("wire_ps", mb.wire_ps),
                     ("row_extra_ps", mb.row_extra_ps)):
        assert np.array_equal(np.asarray(sb[key]), np.asarray(ref)), \
            f"streamed blame {key} != monolithic channel_blame"
    assert int(sb["join_ps"]) == int(mb.join_ps)
    assert int(sb["fixed_ps"]) == int(mb.fixed_ps)
    artifact["streaming_smoke"] = {
        "windows": out.windows,
        "blame": {key: (int(v) if np.ndim(v) == 0
                        else np.asarray(v).tolist())
                  for key, v in sb.items()},
    }
    rows.append(Row(
        "critical_path/streaming_blame_gate", t.us,
        f"windows={out.windows};blame=bitexact",
        meta=artifact["streaming_smoke"]))

    artifact["kinds"] = list(KIND_NAMES)
    artifact["host_phases"] = phases.asdict()
    with open(ARTIFACT, "w") as f:
        json.dump(artifact, f, indent=1, sort_keys=True)
    for row in rows:
        row.meta = dict(row.meta or {}, host_phases=phases.asdict())
    return rows
