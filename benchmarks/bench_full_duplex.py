"""Paper Fig. 16/17: full-duplex PCIe transmission vs read:write mix.

System per §V-D: one requester, one bus, four memory endpoints.  Sweeps the
read:write ratio and the header overhead (normalized to payload length), for
full-duplex and half-duplex bus configurations.  Expected reproduction:

  * full duplex, zero header: a 1:1 mix nearly doubles bandwidth vs read-only;
  * the improvement decays as header overhead grows and vanishes at h == p;
  * half duplex: bandwidth is flat in the mix ratio;
  * bus utility (busy fraction averaged over directions) of single-type
    traffic rises with header overhead; transmission efficiency falls.
"""

from __future__ import annotations

import numpy as np

from repro.core import topology as T
from repro.core.devices import RequesterSpec, build_workload
from repro.core.engine import channel_stats, request_stats, simulate_auto
from repro.core.verify import verify_built

from .common import Row, Timer

BW = 64_000
RATIOS = ((1, 0), (3, 1), (2, 1), (1, 1))
HEADERS = (0, 16, 32, 64)


def run_one(read_ratio: float, header: int, duplex: str, n: int = 4000,
            turnaround_ps: int = 2_000):
    topo = T.single_bus(n_mems=4, bw_MBps=BW, duplex=duplex,
                        turnaround_ps=turnaround_ps if duplex == "half" else 0)
    graph = topo.build()
    spec = RequesterSpec(node=0, n_requests=n, targets=[2, 3, 4, 5],
                         pattern="uniform", read_ratio=read_ratio,
                         issue_interval_ps=200, seed=11)
    wl = build_workload(graph, [spec], header_bytes=header, warmup_frac=0.0)
    verify_built(wl, graph).raise_if_failed()
    sched, used_oracle = simulate_auto(wl.hops, wl.channels, wl.issue_ps)
    rstats = request_stats(wl.hops, sched, wl.issue_ps, wl.payload_bytes,
                           wl.measured)
    cstats = channel_stats(wl.hops, sched, wl.channels)
    # the requester<->switch bus: channels 0 (and 1 when full duplex)
    n_dirs = 2 if duplex == "full" else 1
    util = float(np.asarray(cstats["utility"])[:n_dirs].mean()) * (
        1.0 if duplex == "full" else 1.0)
    eff = float(np.asarray(cstats["efficiency"])[:n_dirs].mean())
    # span-based (conservation-exact) bandwidth: an overloaded open-loop
    # run has no steady completion window, so total payload / makespan is
    # the right estimator here (drain-phase completion bunching otherwise
    # inflates percentile-window estimates)
    return float(rstats["bandwidth_MBps"]), util, eff


def run(quick: bool = False) -> list[Row]:
    rows: list[Row] = []
    n = 1200 if quick else 4000
    headers = (0, 32, 64) if quick else HEADERS
    for duplex in ("full", "half"):
        for h in headers:
            base = None
            for r, w in RATIOS:
                rr = r / (r + w)
                with Timer() as t:
                    bw, util, eff = run_one(rr, h, duplex, n)
                if base is None:
                    base = bw
                rows.append(Row(
                    f"fig16_17/{duplex}/h{h}/rw{r}to{w}", t.us,
                    f"bw_MBps={bw:.0f};vs_read_only={bw / base:.2f};"
                    f"bus_utility={util:.2f};efficiency={eff:.2f}",
                ))
    return rows
