"""Framework bench: ESF fabric model vs analytic collective costs.

Beyond-paper: the ESF engine predicts TPU collective times on the v5e torus
(`core.fabric_model`).  This bench cross-checks the simulated ring collectives
against the closed-form alpha-beta model (they must agree when there is no
contention) and quantifies the contention penalty the analytic model misses
for all-to-all (MoE dispatch) — the exact class of effect the paper builds a
simulator to expose.
"""

from __future__ import annotations

from repro.core.fabric_model import (TPUFabric, analytic_ring_seconds,
                                     predict_collective)

from .common import Row, Timer

MB = 1 << 20


def run(quick: bool = False) -> list[Row]:
    rows: list[Row] = []
    fab = TPUFabric(nx=8 if quick else 16, ny=8 if quick else 16)
    graph = fab.build()
    sizes = (16 * MB, 128 * MB) if quick else (16 * MB, 64 * MB, 256 * MB)
    for nbytes in sizes:
        with Timer() as t:
            ar = predict_collective(fab, graph, "all_reduce", "x", nbytes)
        ana = analytic_ring_seconds(nbytes, fab.nx)
        rows.append(Row(
            f"fabric/all_reduce/{nbytes // MB}MB", t.us,
            f"sim_ms={ar.seconds * 1e3:.3f};alpha_beta_ms={ana * 1e3:.3f};"
            f"ratio={ar.seconds / ana:.3f}",
        ))
    with Timer() as t:
        a2a = predict_collective(fab, graph, "all_to_all", "x", 64 * MB)
    naive = 64 * MB / fab.nx * (fab.nx - 1) / (50_000 * 1e6 * 2)
    rows.append(Row(
        "fabric/all_to_all/64MB", t.us,
        f"sim_ms={a2a.seconds * 1e3:.3f};contention_free_ms={naive * 1e3:.3f};"
        f"contention_factor={a2a.seconds / naive:.2f}",
    ))
    if not quick:
        fab2 = TPUFabric(nx=16, ny=16, pods=2)
        graph2 = fab2.build()
        with Timer() as t:
            pr = predict_collective(fab2, graph2, "pod_all_reduce", "x", 64 * MB)
        rows.append(Row(
            "fabric/pod_all_reduce/64MB", t.us,
            f"sim_ms={pr.seconds * 1e3:.3f};detail={pr.detail}",
        ))
    return rows
