"""Paper Fig. 14: snoop-filter victim selection policies (claim F4).

Setup per §V-B: one requester issues coherent requests in a skewed pattern
(90% of accesses to hot data = 10% of the footprint).  The requester's local
cache (20% of footprint — large enough for all hot lines) filters hits; the
bus has infinite bandwidth to isolate SF behaviour.  SF capacity equals the
cache.  Policies: FIFO, LRU, LFI, LIFO, MRU.

Expected reproduction: because nearly every request that reaches the
*inclusive* SF is a cold-data cache miss, FIFO/LRU victimize hot entries
(whose owners still cache them) and behave alike, while LIFO/MRU victimize
just-inserted cold entries: higher bandwidth, lower latency, fewer
back-invalidations.  LFI reduces invalidations vs FIFO but periodically purges
hot lines when insert counts equalize, landing between the two pairs.
"""

from __future__ import annotations

import numpy as np

from repro.core.calibration import FIG14_TARGETS
from repro.core.snoop_filter import (CacheConfig, SFConfig, make_skewed_stream,
                                     simulate_sf)

from .common import Row, Timer

POLICY_ORDER = ("fifo", "lru", "lfi", "lifo", "mru")


def run_policy(policy: str, n: int, footprint: int):
    cap = int(0.2 * footprint)
    addr, wr, rid = make_skewed_stream(n, footprint, hot_frac=0.1,
                                       hot_ratio=0.9, write_ratio=0.1, seed=3)
    cfg = SFConfig(capacity=cap, policy=policy, footprint_lines=footprint)
    res = simulate_sf(addr, wr, rid, cfg, CacheConfig(capacity=cap),
                      n_requesters=1)
    lat = np.asarray(res.latency_ps)[n // 2:]  # steady-state half
    return {
        "bandwidth_MBps": float(res.bandwidth_MBps),
        "mean_latency_ns": float(lat.mean()) / 1000.0,
        "invalidations": int(res.bisnp_events),
        "hit_rate": float(np.asarray(res.cache_hit).mean()),
    }


def run(quick: bool = False) -> list[Row]:
    n = 8_000 if quick else 32_000
    footprint = 2_048 if quick else 4_096
    rows: list[Row] = []
    base = None
    for pol in POLICY_ORDER:
        with Timer() as t:
            m = run_policy(pol, n, footprint)
        if base is None:
            base = m
        rows.append(Row(
            f"fig14/{pol}", t.us,
            f"bw_vs_fifo={m['bandwidth_MBps'] / base['bandwidth_MBps']:.3f};"
            f"lat_vs_fifo={m['mean_latency_ns'] / base['mean_latency_ns']:.3f};"
            f"inval_vs_fifo={m['invalidations'] / max(base['invalidations'], 1):.3f};"
            f"hit_rate={m['hit_rate']:.3f}",
        ))
    rows.append(Row(
        "fig14/paper_targets", 0.0,
        f"lifo_bw~{FIG14_TARGETS['bandwidth']};lifo_lat~{FIG14_TARGETS['latency']};"
        f"lifo_inval~{FIG14_TARGETS['invalidation']}",
    ))
    return rows
