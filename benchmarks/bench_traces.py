"""Paper Fig. 18/19/20: real-world trace replay (claim F7).

Replays the five representative workload traces (synthetic stand-ins with the
published access statistics; `core.traces`) through ESF:

  * Fig. 18/19: throughput and mean latency on the five fabric topologies,
    normalized to chain.  Paper targets: ring 1.72x/0.57x, spine-leaf
    2.27x/0.44x, fully-connected 3.63x/0.28x (throughput/latency vs chain).
  * Fig. 20a: execution speedup of a full-duplex vs half-duplex bus per
    trace, ordered by the trace's R/W mix degree.
  * Fig. 20b: per-1000-access bandwidth vs window mix degree; the paper
    reports ~+9% bandwidth per +0.1 mix degree.
"""

from __future__ import annotations

import numpy as np

from repro.core import topology as T
from repro.core import traces as TR
from repro.core.devices import RequesterSpec, build_workload
from repro.core.engine import request_stats, simulate, simulate_auto
from repro.core.verify import verify_built

from .common import Row, Timer
from .bench_topology import build_topo, PORT_MBPS


def replay_topology(kind: str, trace: dict, n_pairs: int = 8,
                    per_req: int = 400, interval_ps: int = 1_000, seed: int = 0):
    """Shard the trace across the fabric's requesters and replay."""
    topo = build_topo(kind, n_pairs)
    graph = topo.build()
    reqs = topo.requesters()
    mems = [int(m) for m in topo.memories()]
    specs = []
    for i, r in enumerate(reqs):
        lo = i * per_req
        specs.append(RequesterSpec(
            node=int(r), n_requests=per_req, targets=mems,
            issue_interval_ps=interval_ps, seed=seed,
            trace_addr=trace["addr"][lo:lo + per_req],
            trace_is_write=trace["is_write"][lo:lo + per_req],
        ))
    rng = np.random.default_rng(seed + 23)
    n_tx = per_req * len(reqs)
    wl = build_workload(graph, specs, header_bytes=64, warmup_frac=0.0,
                        route_choice=rng.integers(0, 1 << 20, n_tx))
    verify_built(wl, graph).raise_if_failed()
    sched = simulate(wl.hops, wl.channels, wl.issue_ps)
    r = request_stats(wl.hops, sched, wl.issue_ps, wl.payload_bytes, wl.measured)
    thr = float(r["bandwidth_MBps"])
    lat = float(r["mean_latency_ps"]) / 1000.0
    return thr, lat


def replay_bus(trace: dict, duplex: str, n: int = 3000):
    topo = T.single_bus(n_mems=4, bw_MBps=PORT_MBPS, duplex=duplex,
                        turnaround_ps=1_000 if duplex == "half" else 0)
    graph = topo.build()
    spec = RequesterSpec(node=0, n_requests=n, targets=[2, 3, 4, 5],
                         issue_interval_ps=300, seed=3,
                         trace_addr=trace["addr"], trace_is_write=trace["is_write"])
    wl = build_workload(graph, [spec], header_bytes=16, warmup_frac=0.0)
    verify_built(wl, graph).raise_if_failed()
    sched, _ = simulate_auto(wl.hops, wl.channels, wl.issue_ps)
    comp = np.asarray(sched.complete)
    makespan = comp.max() - int(np.asarray(wl.issue_ps).min())
    return n * 64 * 1e12 / makespan / 1e6, comp  # MB/s, completions


def run(quick: bool = False) -> list[Row]:
    rows: list[Row] = []
    per_req = 150 if quick else 400
    n_bus = 2_000 if quick else 6_000
    names = list(TR.WORKLOADS)

    # ---- Fig. 18/19: topology impact on real traces ----------------------
    targets_thr = {"ring": 1.72, "spine_leaf": 2.27, "fully_connected": 3.63}
    targets_lat = {"ring": 0.57, "spine_leaf": 0.44, "fully_connected": 0.28}
    for name in (names if not quick else names[:3]):
        tr = TR.generate(name, n=8 * per_req, footprint_lines=1 << 14, seed=1)
        base_thr = base_lat = None
        for kind in ("chain", "tree", "ring", "spine_leaf", "fully_connected"):
            with Timer() as t:
                thr, lat = replay_topology(kind, tr, per_req=per_req)
            if base_thr is None:
                base_thr, base_lat = thr, lat
            rows.append(Row(
                f"fig18_19/{name}/{kind}", t.us,
                f"thr_vs_chain={thr / base_thr:.2f};lat_vs_chain={lat / base_lat:.2f};"
                f"paper_thr={targets_thr.get(kind, 1.0):.2f};"
                f"paper_lat={targets_lat.get(kind, 1.0):.2f}",
            ))

    # ---- Fig. 20a: full- vs half-duplex speedup by mix degree -------------
    speedups = []
    for name in names:
        tr = TR.generate(name, n=n_bus, footprint_lines=1 << 14, seed=2)
        with Timer() as t:
            bw_f, comp_f = replay_bus(tr, "full", n=n_bus)
            bw_h, _ = replay_bus(tr, "half", n=n_bus)
        sp = bw_f / bw_h
        speedups.append((tr["mix_degree"], sp))
        rows.append(Row(
            f"fig20a/{name}", t.us,
            f"mix_degree={tr['mix_degree']:.2f};fullduplex_speedup={sp:.2f}",
        ))
    speedups.sort()
    mono = all(b[1] >= a[1] - 0.05 for a, b in zip(speedups, speedups[1:]))
    rows.append(Row("fig20a/monotone_in_mix", 0.0, f"monotone={mono}"))

    # ---- Fig. 20b: windowed bandwidth vs mix degree (slope per +0.1) ------
    # Issue-ordered windows of consecutive accesses on a *saturated* bus:
    # window bandwidth = window size / time the bus spent completing it.
    # (Completion-ordered windows conflate phases of the queue and can even
    # show negative slopes — issue order is what Fig. 20b plots.)
    tr = TR.generate("silo", n=n_bus, footprint_lines=1 << 14, seed=4)
    _, comp = replay_bus(tr, "full", n=n_bus)
    win = 200 if quick else 500
    xs, ys = [], []
    wr = tr["is_write"][:n_bus]
    windows = range(win, n_bus - 2 * win, win)
    for lo in windows:
        w = float(wr[lo:lo + win].mean())
        mix = min(w, 1 - w)
        dur = float(np.max(comp[lo:lo + win]) - np.max(comp[lo - win:lo]))
        if dur > 0:
            xs.append(mix)
            ys.append(win * 64 * 1e12 / dur / 1e6 / PORT_MBPS)
    if len(xs) > 2:
        slope = float(np.polyfit(xs, ys, 1)[0])
        mean_y = float(np.mean(ys))
        slope_rel = slope * 0.1 / mean_y  # fractional bw gain per +0.1 mix
    else:
        slope_rel = float("nan")
    rows.append(Row(
        "fig20b/mix_bandwidth_slope", 0.0,
        f"rel_slope_per_0.1_mix={slope_rel:+.3f};paper=+0.09;n_windows={len(xs)}",
    ))
    return rows
