"""Framework bench: kernel oracles (XLA fast paths) + interpret-mode checks.

This container is CPU-only, so wall-times here measure the pure-jnp oracle
paths (the XLA baselines the Pallas kernels must beat on TPU); each row also
re-validates kernel-vs-oracle agreement at a representative shape so the
bench doubles as an integration check.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.devices import RequesterSpec, build_workload
from repro.core.engine import SimOptions, simulate
from repro.kernels.flash_attention.kernel import flash_attention_gqa
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.link_contention.kernel import segmented_depart
from repro.kernels.link_contention.ref import segmented_depart_ref
from repro.kernels.serve_round.kernel import NEG
from repro.kernels.serve_round.ref import serve_scan_ref
from repro.kernels.rglru_scan.kernel import rglru_scan_pallas
from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.kernels.ssd_chunk.kernel import ssd_chunk_pallas
from repro.kernels.ssd_chunk.ref import ssd_chunk_ref

from .common import Row, Timer


def _time(f, *args, reps=3):
    out = f(*args)
    jax.block_until_ready(out)
    with Timer() as t:
        for _ in range(reps):
            out = f(*args)
        jax.block_until_ready(out)
    return out, t.us / reps


def run(quick: bool = False) -> list[Row]:
    rows: list[Row] = []
    rng = np.random.default_rng(0)

    # flash attention
    b, kv, g, s, d = (1, 2, 2, 512, 64) if quick else (2, 4, 4, 1024, 128)
    q = jnp.asarray(rng.normal(0, 1, (b, kv, g, s, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (b, kv, s, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (b, kv, s, d)).astype(np.float32))
    ref_fn = jax.jit(lambda a, b_, c: flash_attention_ref(a, b_, c, causal=True))
    ref, us = _time(ref_fn, q, k, v)
    small = flash_attention_gqa(q[:, :1, :1, :256], k[:, :1, :256],
                                v[:, :1, :256], causal=True, q_blk=128,
                                kv_blk=128, interpret=True)
    ok = np.allclose(np.asarray(small),
                     np.asarray(flash_attention_ref(
                         q[:, :1, :1, :256], k[:, :1, :256], v[:, :1, :256],
                         causal=True)), atol=1e-4)
    flops = 4 * b * kv * g * s * s * d / 2
    rows.append(Row("kernels/flash_attention", us,
                    f"xla_oracle_gflops={flops / us / 1e3:.1f};"
                    f"pallas_interpret_allclose={ok}"))

    # rglru
    b2, s2, d2 = (2, 1024, 512) if quick else (4, 4096, 1024)
    a = jnp.asarray(rng.uniform(0.9, 0.999, (b2, s2, d2)).astype(np.float32))
    bb = jnp.asarray(rng.normal(0, 0.1, (b2, s2, d2)).astype(np.float32))
    ref_fn = jax.jit(rglru_scan_ref)
    _, us = _time(ref_fn, a, bb)
    small = rglru_scan_pallas(a[:1, :256, :128], bb[:1, :256, :128],
                              chunk=128, d_blk=128, interpret=True)
    ok = np.allclose(np.asarray(small),
                     np.asarray(rglru_scan_ref(a[:1, :256, :128],
                                               bb[:1, :256, :128])), atol=1e-5)
    rows.append(Row("kernels/rglru_scan", us,
                    f"elems_per_us={b2 * s2 * d2 / us:.0f};"
                    f"pallas_interpret_allclose={ok}"))

    # ssd
    b3, s3, h3, p3, n3 = (1, 1024, 4, 64, 128) if quick else (2, 4096, 8, 64, 128)
    x = jnp.asarray(rng.normal(0, 1, (b3, s3, h3, p3)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (b3, s3, h3)).astype(np.float32))
    al = jnp.asarray(np.log(rng.uniform(1, 8, h3)).astype(np.float32))
    bm = jnp.asarray(rng.normal(0, 1, (b3, s3, n3)).astype(np.float32))
    cm = jnp.asarray(rng.normal(0, 1, (b3, s3, n3)).astype(np.float32))
    ref_fn = jax.jit(lambda *xs: ssd_chunk_ref(*xs))
    _, us = _time(ref_fn, x, dt, al, bm, cm)
    small = ssd_chunk_pallas(x[:1, :256], dt[:1, :256], al, bm[:1, :256],
                             cm[:1, :256], chunk=128, interpret=True)
    ok = np.allclose(np.asarray(small),
                     np.asarray(ssd_chunk_ref(x[:1, :256], dt[:1, :256], al,
                                              bm[:1, :256], cm[:1, :256])),
                     atol=3e-4)
    rows.append(Row("kernels/ssd_chunk", us,
                    f"tokens_per_us={b3 * s3 / us:.1f};"
                    f"pallas_interpret_allclose={ok}"))

    # link contention (engine hotspot): XLA scan oracle vs blocked kernel
    kk = 100_000 if quick else 400_000
    chan = np.sort(rng.integers(0, 64, kk)).astype(np.int32)
    arrive = rng.integers(0, 1 << 24, kk).astype(np.int32)
    order = np.lexsort((arrive, chan))
    chan, arrive = jnp.asarray(chan[order]), jnp.asarray(arrive[order])
    ser = jnp.asarray(rng.integers(0, 1000, kk).astype(np.int32))
    ref_fn = jax.jit(segmented_depart_ref)
    ref, us = _time(ref_fn, chan, arrive, ser)
    small_n = 4096
    small = segmented_depart(chan[:small_n], arrive[:small_n], ser[:small_n],
                             blk=1024, interpret=True)
    ok = bool(np.array_equal(
        np.asarray(small),
        np.asarray(segmented_depart_ref(chan[:small_n], arrive[:small_n],
                                        ser[:small_n]))))
    rows.append(Row("kernels/link_contention", us,
                    f"items_per_us={kk / us:.0f};pallas_interpret_exact={ok}"))

    # serve round ((max,+) affine scan): raw composition-scan throughput
    ks = 100_000 if quick else 400_000
    def comp(p_neg, hi=1 << 16):
        x = rng.integers(0, hi, ks).astype(np.int32)
        return jnp.asarray(np.where(rng.random(ks) < p_neg, NEG, x))
    maps = [comp(0.3), comp(0.5), comp(0.5), comp(0.3),
            comp(0.2, 1 << 20), comp(0.2, 1 << 20)]
    ref_fn = jax.jit(serve_scan_ref)
    _, us = _time(ref_fn, *maps)
    rows.append(Row("kernels/serve_round/scan", us,
                    f"items_per_us={ks / us:.0f}"))

    # serve round, engine-level: whole fixpoint (rows x rounds) through the
    # kernel formulation vs the default lax path, same workload.  Gates:
    # bit-exact completions, and the kernel formulation must not regress
    # the engine (<= 1.5x the lax path wall-time on this backend).
    from .bench_topology import build_topo
    topo = build_topo("tree", 8)
    graph = topo.build()
    mems = [int(m) for m in topo.memories()]
    per_req = 150 if quick else 500
    specs = [RequesterSpec(node=int(r), n_requests=per_req, targets=mems,
                           issue_interval_ps=1_000, seed=11)
             for r in topo.requesters()]
    wl = build_workload(graph, specs, header_bytes=64, warmup_frac=0.0)
    lax_fn = jax.jit(lambda: simulate(wl.hops, wl.channels, wl.issue_ps))
    krn_fn = jax.jit(lambda: simulate(wl.hops, wl.channels, wl.issue_ps,
                                      SimOptions(use_kernel="ref")))
    ref, us_lax = _time(lax_fn)
    out, us_krn = _time(krn_fn)
    exact = bool(np.array_equal(np.asarray(ref.complete),
                                np.asarray(out.complete)))
    ratio = us_krn / us_lax
    n_rows = int(np.asarray(wl.hops.channel).shape[0])
    rows.append(Row(
        "kernels/serve_round/engine", us_krn,
        f"rows={n_rows};rounds={int(out.rounds)};lax_us={us_lax:.0f};"
        f"ratio_vs_lax={ratio:.2f};bit_exact={exact};"
        f"gate={exact and ratio <= 1.5}"))
    assert exact, "serve-round kernel path diverged from the lax engine"
    assert ratio <= 1.5, \
        f"serve-round kernel path regressed the engine ({ratio:.2f}x lax)"

    # interpret-mode Pallas through the whole engine at a small shape
    small_specs = [RequesterSpec(node=int(r), n_requests=20, targets=mems,
                                 issue_interval_ps=1_000, seed=12)
                   for r in topo.requesters()]
    wl_s = build_workload(graph, small_specs, header_bytes=64,
                          warmup_frac=0.0)
    want = simulate(wl_s.hops, wl_s.channels, wl_s.issue_ps)
    with Timer() as t:
        got = simulate(wl_s.hops, wl_s.channels, wl_s.issue_ps,
                       SimOptions(use_kernel="interpret"))
        jax.block_until_ready(got.complete)
    ok = bool(np.array_equal(np.asarray(want.complete),
                             np.asarray(got.complete)))
    rows.append(Row("kernels/serve_round/pallas_interpret", t.us,
                    f"rows={int(np.asarray(wl_s.hops.channel).shape[0])};"
                    f"bit_exact={ok}"))
    assert ok, "interpret-mode serve-round kernel diverged from the engine"
    return rows
