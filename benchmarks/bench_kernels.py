"""Framework bench: kernel oracles (XLA fast paths) + interpret-mode checks.

This container is CPU-only, so wall-times here measure the pure-jnp oracle
paths (the XLA baselines the Pallas kernels must beat on TPU); each row also
re-validates kernel-vs-oracle agreement at a representative shape so the
bench doubles as an integration check.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.kernel import flash_attention_gqa
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.link_contention.kernel import segmented_depart
from repro.kernels.link_contention.ref import segmented_depart_ref
from repro.kernels.rglru_scan.kernel import rglru_scan_pallas
from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.kernels.ssd_chunk.kernel import ssd_chunk_pallas
from repro.kernels.ssd_chunk.ref import ssd_chunk_ref

from .common import Row, Timer


def _time(f, *args, reps=3):
    out = f(*args)
    jax.block_until_ready(out)
    with Timer() as t:
        for _ in range(reps):
            out = f(*args)
        jax.block_until_ready(out)
    return out, t.us / reps


def run(quick: bool = False) -> list[Row]:
    rows: list[Row] = []
    rng = np.random.default_rng(0)

    # flash attention
    b, kv, g, s, d = (1, 2, 2, 512, 64) if quick else (2, 4, 4, 1024, 128)
    q = jnp.asarray(rng.normal(0, 1, (b, kv, g, s, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (b, kv, s, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (b, kv, s, d)).astype(np.float32))
    ref_fn = jax.jit(lambda a, b_, c: flash_attention_ref(a, b_, c, causal=True))
    ref, us = _time(ref_fn, q, k, v)
    small = flash_attention_gqa(q[:, :1, :1, :256], k[:, :1, :256],
                                v[:, :1, :256], causal=True, q_blk=128,
                                kv_blk=128, interpret=True)
    ok = np.allclose(np.asarray(small),
                     np.asarray(flash_attention_ref(
                         q[:, :1, :1, :256], k[:, :1, :256], v[:, :1, :256],
                         causal=True)), atol=1e-4)
    flops = 4 * b * kv * g * s * s * d / 2
    rows.append(Row("kernels/flash_attention", us,
                    f"xla_oracle_gflops={flops / us / 1e3:.1f};"
                    f"pallas_interpret_allclose={ok}"))

    # rglru
    b2, s2, d2 = (2, 1024, 512) if quick else (4, 4096, 1024)
    a = jnp.asarray(rng.uniform(0.9, 0.999, (b2, s2, d2)).astype(np.float32))
    bb = jnp.asarray(rng.normal(0, 0.1, (b2, s2, d2)).astype(np.float32))
    ref_fn = jax.jit(rglru_scan_ref)
    _, us = _time(ref_fn, a, bb)
    small = rglru_scan_pallas(a[:1, :256, :128], bb[:1, :256, :128],
                              chunk=128, d_blk=128, interpret=True)
    ok = np.allclose(np.asarray(small),
                     np.asarray(rglru_scan_ref(a[:1, :256, :128],
                                               bb[:1, :256, :128])), atol=1e-5)
    rows.append(Row("kernels/rglru_scan", us,
                    f"elems_per_us={b2 * s2 * d2 / us:.0f};"
                    f"pallas_interpret_allclose={ok}"))

    # ssd
    b3, s3, h3, p3, n3 = (1, 1024, 4, 64, 128) if quick else (2, 4096, 8, 64, 128)
    x = jnp.asarray(rng.normal(0, 1, (b3, s3, h3, p3)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (b3, s3, h3)).astype(np.float32))
    al = jnp.asarray(np.log(rng.uniform(1, 8, h3)).astype(np.float32))
    bm = jnp.asarray(rng.normal(0, 1, (b3, s3, n3)).astype(np.float32))
    cm = jnp.asarray(rng.normal(0, 1, (b3, s3, n3)).astype(np.float32))
    ref_fn = jax.jit(lambda *xs: ssd_chunk_ref(*xs))
    _, us = _time(ref_fn, x, dt, al, bm, cm)
    small = ssd_chunk_pallas(x[:1, :256], dt[:1, :256], al, bm[:1, :256],
                             cm[:1, :256], chunk=128, interpret=True)
    ok = np.allclose(np.asarray(small),
                     np.asarray(ssd_chunk_ref(x[:1, :256], dt[:1, :256], al,
                                              bm[:1, :256], cm[:1, :256])),
                     atol=3e-4)
    rows.append(Row("kernels/ssd_chunk", us,
                    f"tokens_per_us={b3 * s3 / us:.1f};"
                    f"pallas_interpret_allclose={ok}"))

    # link contention (engine hotspot): XLA scan oracle vs blocked kernel
    kk = 100_000 if quick else 400_000
    chan = np.sort(rng.integers(0, 64, kk)).astype(np.int32)
    arrive = rng.integers(0, 1 << 24, kk).astype(np.int32)
    order = np.lexsort((arrive, chan))
    chan, arrive = jnp.asarray(chan[order]), jnp.asarray(arrive[order])
    ser = jnp.asarray(rng.integers(0, 1000, kk).astype(np.int32))
    ref_fn = jax.jit(segmented_depart_ref)
    ref, us = _time(ref_fn, chan, arrive, ser)
    small_n = 4096
    small = segmented_depart(chan[:small_n], arrive[:small_n], ser[:small_n],
                             blk=1024, interpret=True)
    ok = bool(np.array_equal(
        np.asarray(small),
        np.asarray(segmented_depart_ref(chan[:small_n], arrive[:small_n],
                                        ser[:small_n]))))
    rows.append(Row("kernels/link_contention", us,
                    f"items_per_us={kk / us:.0f};pallas_interpret_exact={ok}"))
    return rows
