"""Paper Fig. 15: InvBlk command length (claim F5).

Setup per §V-C: two requesters issue sequential (streaming) requests; cache,
SF size and request counts as in §V-B; the SF uses block-length-prioritized
victim selection (longest run of address-contiguous entries, LIFO among ties)
and clears up to `invblk_max` contiguous lines per BISnp.  Unlike §V-B the bus
is finite, so flushed lines compete with demand traffic for bandwidth.

Expected reproduction: length 2 amortizes BISnp waiting and improves
bandwidth/latency; lengths 3-4 pay growing requester-cache access overheads
and bus competition from flush data, so they give no further improvement
(paper: "no improvement compared to length=1").
"""

from __future__ import annotations

import numpy as np

from repro.core.snoop_filter import (CacheConfig, SFConfig,
                                     make_sequential_stream, simulate_sf)

from .common import Row, Timer


def run_len(invblk: int, n: int, footprint: int):
    cap = int(0.2 * footprint)
    addr, wr, rid = make_sequential_stream(n, footprint, n_requesters=2,
                                           write_ratio=0.5, seed=5)
    cfg = SFConfig(capacity=cap, policy="blp", invblk_max=invblk,
                   footprint_lines=footprint, bus_MBps=12_000,
                   writeback_ps=30_000)
    res = simulate_sf(addr, wr, rid, cfg, CacheConfig(capacity=cap),
                      n_requesters=2)
    lat = np.asarray(res.latency_ps)[n // 2:]
    return {
        "bandwidth_MBps": float(res.bandwidth_MBps),
        "mean_latency_ns": float(lat.mean()) / 1000.0,
        "bisnp": int(res.bisnp_events),
        "lines": int(res.invalidated_lines),
    }


def run(quick: bool = False) -> list[Row]:
    n = 8_000 if quick else 32_000
    footprint = 2_048 if quick else 4_096
    rows: list[Row] = []
    base = None
    for L in (1, 2, 3, 4):
        with Timer() as t:
            m = run_len(L, n, footprint)
        if base is None:
            base = m
        rows.append(Row(
            f"fig15/invblk_len{L}", t.us,
            f"bw_vs_len1={m['bandwidth_MBps'] / base['bandwidth_MBps']:.3f};"
            f"lat_vs_len1={m['mean_latency_ns'] / base['mean_latency_ns']:.3f};"
            f"bisnp_vs_len1={m['bisnp'] / max(base['bisnp'], 1):.3f};"
            f"lines={m['lines']}",
        ))
    return rows
