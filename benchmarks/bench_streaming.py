"""Streaming windowed engine: million-request traces at flat memory.

Drives a bursty open-loop demand trace through `core.streaming.
simulate_stream` — fixed-size windows resolved from the carried fabric
state, folded into the running `StreamTelemetry` instead of materializing
O(N·H) schedules.  Quick mode streams 60k requests (CI smoke); full mode
streams 1.2M — the paper's §V-E trace scale — through 64k-row windows.

Acceptance gates (AssertionErrors fail the CI smoke step):

  * exactness — a small streamed run equals the monolithic engine bit for
    bit (every item's start/depart/arrive, every row's completion);
  * conservation — every request retires exactly once;
  * flat memory — peak in-flight rows at window edges stays a small
    fraction of the window (the whole point of windowing);
  * ordering — streamed tail quantiles satisfy p50 <= p99 <= p99.9.

Rows carry ``meta`` (window count, carried-row peak, oracle fallbacks,
tail quantiles) into the ``--json`` snapshot.
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core.engine import Channels, Hops, simulate
from repro.core.streaming import simulate_stream, stream_windows
from repro.core.telemetry import channel_blame, channel_telemetry
from repro.core.verify import assert_valid
from repro.core.traces import arrival_times

from .common import Phases, Row, Timer

N_LANES = 4
SVC = N_LANES                 # endpoint service channel
MEAN_GAP_PS = 6000            # ~70% endpoint utilization (stable queue)
H = 3                         # request -> service -> response


def _channels() -> Channels:
    bw = np.full(N_LANES + 1, 64_000, np.int64)
    bw[SVC] = 128_000
    turn = np.zeros(N_LANES + 1, np.int64)
    turn[:N_LANES] = 1500                      # half-duplex lanes
    rh = np.zeros(N_LANES + 1, np.int64)
    rm = np.zeros(N_LANES + 1, np.int64)
    rh[SVC], rm[SVC] = 1000, 9000              # row-managed endpoint
    return Channels(jnp.asarray(bw), jnp.asarray(turn), jnp.asarray(rh),
                    jnp.asarray(rm))


def _chunk(lo: int, hi: int, t0: int, seed: int):
    """One numpy-built chunk of the open-loop trace: each request runs
    request -> endpoint service -> response on its lane, bursty arrivals."""
    idx = np.arange(lo, hi, dtype=np.int64)
    m = idx.shape[0]
    lane = (idx % N_LANES).astype(np.int32)
    mix = (idx * 2654435761) & 0xFFFFFFFF      # cheap deterministic hash
    chan = np.stack([lane, np.full(m, SVC, np.int32), lane], 1)
    nbytes = np.stack([np.full(m, 64, np.int64),
                       np.where(mix % 3 == 0, 256, 64),
                       np.where(mix % 5 == 0, 256, 64)], 1)
    dirn = np.stack([np.zeros(m, np.int8), np.zeros(m, np.int8),
                     np.ones(m, np.int8)], 1)
    row = np.full((m, H), -1, np.int32)
    row[:, 1] = ((idx >> 2) % 7).astype(np.int32)
    fixed = np.full((m, H), 2000, np.int64)
    valid = np.ones((m, H), bool)
    hops = Hops(jnp.asarray(chan), jnp.asarray(nbytes), jnp.asarray(dirn),
                jnp.asarray(row), jnp.asarray(fixed), jnp.asarray(valid),
                jnp.asarray(valid))
    issue = t0 + arrival_times(m, mean_gap_ps=MEAN_GAP_PS, pattern="bursty",
                               seed=seed)
    return hops, jnp.asarray(issue)


def _trace(n: int, chunk: int):
    t0 = 0
    for lo in range(0, n, chunk):
        hi = min(lo + chunk, n)
        yield _chunk(lo, hi, t0, seed=lo)
        t0 += (hi - lo) * MEAN_GAP_PS


def run(quick: bool = False) -> list[Row]:
    rows: list[Row] = []
    phases = Phases()
    with phases("build"):
        ch = _channels()
        small_h, small_i = _chunk(0, 2000, 0, seed=0)

    # gate: streamed == monolithic, bit for bit, at test scale -------------
    assert_valid(small_h, ch, small_i)
    mono = simulate(small_h, ch, small_i)
    assert bool(mono.converged)
    out = simulate_stream(stream_windows(small_h, np.asarray(small_i), 256),
                          ch, collect_schedule=True)
    col = out.collected
    r = col["item_row"].astype(np.int64)
    k = col["item_hop"].astype(np.int64)
    assert r.size == 2000 * H, "settled items folded more or less than once"
    assert np.array_equal(col["item_start"], np.asarray(mono.start)[r, k])
    assert np.array_equal(col["item_depart"], np.asarray(mono.depart)[r, k])
    assert np.array_equal(col["item_arrive"], np.asarray(mono.arrive)[r, k])
    rr = col["row_id"].astype(np.int64)
    assert np.array_equal(col["row_complete"],
                          np.asarray(mono.complete)[rr]), \
        "streamed completions diverge from the monolithic engine"

    # gate: streamed blame fold + peak backlog == monolithic ---------------
    small_sum = out.summary()
    mb = channel_blame(small_h, ch, mono, small_i)
    sb = small_sum["blame"]
    for key, ref in (("queue_ps", mb.queue_ps), ("retrain_ps", mb.retrain_ps),
                     ("wire_ps", mb.wire_ps),
                     ("row_extra_ps", mb.row_extra_ps)):
        assert np.array_equal(np.asarray(sb[key]), np.asarray(ref)), \
            f"streamed blame {key} diverges from monolithic channel_blame"
    assert int(sb["join_ps"]) == int(mb.join_ps)
    assert int(sb["fixed_ps"]) == int(mb.fixed_ps)
    mono_peak = np.asarray(channel_telemetry(small_h, ch, mono).peak_backlog)
    assert np.array_equal(np.asarray(small_sum["peak_backlog"]), mono_peak), \
        "streamed peak_backlog diverges from monolithic channel_telemetry"
    assert small_sum["windows_converged"] == out.windows

    # the headline run: flat-memory windowed streaming ---------------------
    n = 60_000 if quick else 1_200_000
    window = 8_192 if quick else 65_536
    with Timer() as t, phases("execute"):
        res = simulate_stream(_trace(n, window), ch)
    s = res.summary()

    # gates ----------------------------------------------------------------
    assert s["n_retired"] == n, \
        f"retired {s['n_retired']} of {n} requests"
    assert res.carried_peak <= max(window // 8, 64), \
        f"carried rows {res.carried_peak} not small vs window {window}"
    p50, p99, p999 = (int(q) for q in s["quantiles_ps"])
    assert 0 < p50 <= p99 <= p999, "tail quantiles out of order"
    util = float(np.max(s["utilization"]))
    assert 0.0 < util <= 1.0, f"utilization {util} out of (0, 1]"

    req_per_s = n / (t.us / 1e6)
    rows.append(Row(
        "streaming/windowed_trace", t.us,
        f"n={n};window={window};req_per_s={req_per_s:.0f};"
        f"p50={p50 / 1e3:.0f}ns;p99={p99 / 1e3:.0f}ns;"
        f"p999={p999 / 1e3:.0f}ns",
        meta={"n_requests": n, "window_rows": window,
              "windows": res.windows, "carried_peak": res.carried_peak,
              "oracle_windows": res.oracle_windows,
              "quantiles_ps": [p50, p99, p999],
              "max_utilization": util,
              "span_ps": s["span_ps"],
              # per-window fixpoint diagnostics + streamed observability
              "rounds_sum": s["rounds_sum"],
              "rounds_max": s["rounds_max"],
              "windows_converged": s["windows_converged"],
              "peak_backlog": np.asarray(s["peak_backlog"]).tolist(),
              "blame": {key: (int(v) if np.ndim(v) == 0
                              else np.asarray(v).tolist())
                        for key, v in s["blame"].items()},
              "host_phases": phases.asdict()},
    ))
    rows.append(Row(
        "streaming/equivalence_gate", 0.0,
        f"rows=2000;windows={out.windows};bitexact=True;blame=bitexact;"
        f"peak_backlog=bitexact",
        meta={"windows": out.windows, "carried_peak": out.carried_peak,
              "rounds_sum": small_sum["rounds_sum"],
              "rounds_max": small_sum["rounds_max"],
              "windows_converged": small_sum["windows_converged"]},
    ))
    return rows
