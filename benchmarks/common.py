"""Shared benchmark utilities: row schema, timing, CSV emission.

Every bench module exposes ``run(quick: bool) -> list[Row]``; ``benchmarks.run``
aggregates and prints ``name,us_per_call,derived`` CSV (one row per measured
quantity; ``derived`` carries the paper-comparison payload).
"""

from __future__ import annotations

import sys
import time
from dataclasses import dataclass


@dataclass
class Row:
    name: str
    us_per_call: float
    derived: str
    # structured telemetry riding along in --json snapshots (convergence
    # counters — Schedule.rounds/converged, coupled-fixpoint iterations —
    # quantiles, utilizations); never printed in the CSV line
    meta: dict | None = None

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.3f},{self.derived}"


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.us = (time.perf_counter() - self.t0) * 1e6


def emit(rows: list[Row]) -> None:
    for r in rows:
        print(r.csv())
        sys.stdout.flush()
