"""Shared benchmark utilities: row schema, timing, CSV emission.

Every bench module exposes ``run(quick: bool) -> list[Row]``; ``benchmarks.run``
aggregates and prints ``name,us_per_call,derived`` CSV (one row per measured
quantity; ``derived`` carries the paper-comparison payload).
"""

from __future__ import annotations

import contextlib
import sys
import time
from dataclasses import dataclass


@dataclass
class Row:
    name: str
    us_per_call: float
    derived: str
    # structured telemetry riding along in --json snapshots (convergence
    # counters — Schedule.rounds/converged, coupled-fixpoint iterations —
    # quantiles, utilizations); never printed in the CSV line
    meta: dict | None = None

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.3f},{self.derived}"


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.us = (time.perf_counter() - self.t0) * 1e6


class Phases:
    """Host-side wall-clock phase accumulator for a bench's canonical
    stages (build / lower / compile / execute).  Re-entering a named phase
    accumulates, so per-config loops fold into one bucket:

        phases = Phases()
        with phases("build"): ...
        row.meta["host_phases"] = phases.asdict()

    The driver (`benchmarks.run`) additionally stamps whole-module
    ``import_s`` / ``run_s`` onto every JSON row."""

    def __init__(self):
        self.seconds: dict[str, float] = {}

    @contextlib.contextmanager
    def __call__(self, name: str):
        t0 = time.perf_counter()
        try:
            yield self
        finally:
            self.seconds[name] = (self.seconds.get(name, 0.0)
                                  + time.perf_counter() - t0)

    def asdict(self) -> dict[str, float]:
        return {k: round(v, 6) for k, v in sorted(self.seconds.items())}


def emit(rows: list[Row]) -> None:
    for r in rows:
        print(r.csv())
        sys.stdout.flush()
