"""Telemetry layer: metric-reduction throughput + observer/conservation gates.

Times the jitted telemetry pass — latency attribution, per-channel
counters, windowed series, quantile-sketch fold — standalone and vmapped
across a stochastic-BER sweep, on top of the link-reliability bus workload
(the heaviest per-hop tables in the suite: flit quantization, sampled
replay bytes, retraining markers).

Acceptance gates (AssertionErrors fail the CI smoke step):

  * conservation — attribution components sum exactly to
    ``complete − issue`` on every request at every BER;
  * pure observer — re-simulating after the full telemetry + trace pass
    is bit-identical;
  * ordering — sketch p50 <= p99 <= p99.9, channel utilization in [0, 1];
  * trace — the exported Chrome-trace JSON passes `validate_trace`.

Rows carry ``meta`` (convergence counters + latency quantiles) into the
``--json`` snapshot.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import telemetry as tm
from repro.core import topology as T
from repro.core import trace_export as tx
from repro.core.devices import RequesterSpec, build_workload
from repro.core.engine import SimOptions, round_bound, simulate
from repro.core.link_layer import FlitConfig
from repro.core.verify import verify_built

from .common import Row, Timer

BUS_BW = 128_000


def _bus_wl(ber: float, n: int):
    cfg = FlitConfig("flit256", ber=ber, reliability="stochastic",
                     rel_seed=7, retrain_threshold=2, retrain_ps=1_000_000)
    topo = T.with_flit(T.single_bus(n_mems=4, bw_MBps=BUS_BW), cfg)
    spec = RequesterSpec(node=0, n_requests=n, targets=[2, 3, 4, 5],
                         read_ratio=0.5, issue_interval_ps=300,
                         payload_bytes=944, seed=3)
    graph = topo.build()
    wl = build_workload(graph, [spec], warmup_frac=0.0)
    verify_built(wl, graph).raise_if_failed()
    return wl


def _pad_stack(hops_list):
    h_max = max(h.channel.shape[1] for h in hops_list)
    fills = dict(channel=-1, nbytes=0, direction=0, row=-1, fixed_after_ps=0,
                 is_payload=False, valid=False, extra_wire_bytes=0,
                 retrain_after_ps=0)

    def pad(h):
        return h._replace(**{
            f: jnp.asarray(np.pad(
                np.asarray(getattr(h, f)),
                ((0, 0), (0, h_max - getattr(h, f).shape[1])),
                constant_values=v))
            for f, v in fills.items()})

    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                  *[pad(h) for h in hops_list])


def _time(fn, *args, reps: int = 3):
    fn(*args)                       # compile + warm cache
    with Timer() as t:
        for _ in range(reps):
            out = fn(*args)
        jax.block_until_ready(out)
    return out, t.us / reps


def run(quick: bool = False) -> list[Row]:
    rows: list[Row] = []
    n = 150 if quick else 600
    bers = (1e-5, 1e-4, 3e-4)

    wls = [_bus_wl(b, n) for b in bers]
    stacked = _pad_stack([w.hops for w in wls])
    ch, issue = wls[0].channels, wls[0].issue_ps
    # hops are vmapped tracers inside the jit: resolve the round bound
    # host-side from the concrete stacked tables
    opts = SimOptions(max_rounds=round_bound(stacked))

    @jax.jit
    def schedule_sweep(hops):
        return jax.vmap(lambda h: simulate(h, ch, issue, opts))(hops)

    @jax.jit
    def metric_sweep(hops, sched):
        att = jax.vmap(lambda h, s: tm.attribute_latency(h, ch, s,
                                                         issue))(hops, sched)
        chans = jax.vmap(lambda h, s: tm.channel_telemetry(h, ch,
                                                           s))(hops, sched)
        series = jax.vmap(lambda h, s: tm.windowed_series(
            h, ch, s, issue, n_bins=32))(hops, sched)
        sk = jax.vmap(lambda v: tm.sketch_update(tm.sketch_new(),
                                                 v))(att.total_ps)
        return att, chans, series, jax.vmap(tm.sketch_quantiles)(sk)

    sched, t_sched = _time(schedule_sweep, stacked)
    assert bool(sched.converged.all()), "BER sweep failed to converge"
    (att, chans, series, quants), t_metrics = _time(metric_sweep,
                                                    stacked, sched)

    # gates -----------------------------------------------------------------
    resid = int(jnp.max(jnp.abs(tm.conservation_residual(att))))
    assert resid == 0, f"conservation violated by {resid} ps"
    util = np.asarray(chans.utilization)
    assert (util >= 0).all() and (util <= 1).all(), "utilization out of [0,1]"
    q = np.asarray(quants)
    assert ((q[:, 0] <= q[:, 1]) & (q[:, 1] <= q[:, 2])).all(), \
        "quantiles out of order"

    # pure observer: the telemetry + trace pass cannot perturb a schedule
    before = np.asarray(sched.complete).copy()
    trace = tx.schedule_trace(
        jax.tree_util.tree_map(lambda x: x[-1], stacked), ch,
        jax.tree_util.tree_map(lambda x: x[-1], sched))
    errs = tx.validate_trace(trace)
    assert errs == [], f"trace schema violations: {errs[:3]}"
    again = schedule_sweep(stacked)
    assert np.array_equal(before, np.asarray(again.complete)), \
        "telemetry perturbed the schedule"

    n_hops = int(jnp.sum(stacked.valid))
    rows.append(Row(
        "telemetry/schedule_sweep", t_sched,
        f"bers={len(bers)};rows={n};hops={n_hops}",
        meta={"engine_rounds": [int(r) for r in np.asarray(sched.rounds)],
              "engine_converged": True},
    ))
    for i, b in enumerate(bers):
        stall_ns = int(jnp.sum(att.retrain_stall_ps[i])) / 1e3
        rows.append(Row(
            f"telemetry/attribution_ber{b:g}", t_metrics,
            f"p50={q[i, 0] / 1e3:.0f}ns;p99={q[i, 1] / 1e3:.0f}ns;"
            f"p999={q[i, 2] / 1e3:.0f}ns;retrain_stall={stall_ns:.0f}ns",
            meta={"quantiles_ps": [int(x) for x in q[i]],
                  "retrain_stall_ps": int(jnp.sum(att.retrain_stall_ps[i])),
                  "queue_wait_ps": int(jnp.sum(att.queue_wait_ps[i])),
                  "peak_backlog": [int(x) for x in
                                   np.asarray(chans.peak_backlog[i])]},
        ))
    # retraining stall must ramp with BER (the attribution separates it
    # from FCFS queueing; identical workload otherwise)
    stalls = np.asarray(jnp.sum(att.retrain_stall_ps, axis=1))
    assert stalls[0] < stalls[-1], "retrain stall did not grow with BER"
    # per-channel blame conserves end to end on the heaviest table
    last = jax.tree_util.tree_map(lambda x: x[-1], stacked)
    bl = tm.channel_blame(last, ch,
                          jax.tree_util.tree_map(lambda x: x[-1], sched),
                          issue)
    assert int(tm.blame_conservation_residual(bl)) == 0, \
        "channel_blame does not conserve complete - issue"
    n_events = sum(1 for e in trace["traceEvents"] if e["ph"] != "M")
    rows.append(Row(
        "telemetry/metrics_per_sweep", t_metrics,
        f"conservation=0ps;max_util={util.max():.3f};"
        f"trace_events={n_events};trace_valid=True;blame_residual=0ps",
        meta={"max_utilization": float(util.max()),
              "blame": {"queue_ps": int(jnp.sum(bl.queue_ps)),
                        "retrain_ps": int(jnp.sum(bl.retrain_ps)),
                        "wire_ps": int(jnp.sum(bl.wire_ps)),
                        "row_extra_ps": int(jnp.sum(bl.row_extra_ps)),
                        "join_ps": int(bl.join_ps),
                        "fixed_ps": int(bl.fixed_ps)}},
    ))
    return rows
