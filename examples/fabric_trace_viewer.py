"""Export a Perfetto-loadable timeline of the coherence fabric demo.

Runs the fabric-coupled coherence scenario (coherent requesters + Poisson
background demand sharing one DCOH device behind a switch), then renders
the converged schedule with `core.trace_export`:

  * one track per fabric channel (BISnp legs, demand responses and
    background payloads as duration events, FCFS queue wait in ``args``);
  * per-channel link-down tracks when stochastic retraining is enabled;
  * the coupled fixpoint's per-iteration residual as a counter series.

Open the output in https://ui.perfetto.dev (or ``chrome://tracing``):

    PYTHONPATH=src python examples/fabric_trace_viewer.py --out trace.json
    PYTHONPATH=src python examples/fabric_trace_viewer.py --quick

A latency-attribution summary (where each request's time went, p50/p99/
p99.9 from the streaming sketch) prints alongside, from `core.telemetry`.
"""

import argparse

import numpy as np

import repro.core  # noqa: F401  (x64)
from repro.core import telemetry as tm
from repro.core import topology as T
from repro.core import trace_export as tx
from repro.core.coherence_traffic import CoherenceFabricSpec, simulate_coupled
from repro.core.devices import RequesterSpec, build_workload
from repro.core.engine import make_channels
from repro.core.snoop_filter import (CacheConfig, SFConfig, make_skewed_stream,
                                     simulate_sf)

FOOTPRINT = 512
CAP = FOOTPRINT // 10
PORT, FIXED = 64_000, 26_000
BG_PAYLOAD = 1024


def star_fabric(n_req: int = 2, n_bg: int = 3):
    """Same star fabric as the coherence demo (self-contained on purpose —
    examples run with only ``PYTHONPATH=src``)."""
    kinds = ([T.SWITCH] + [T.REQUESTER] * n_req + [T.MEMORY]
             + [T.REQUESTER] * n_bg)
    links = [T.LinkSpec(i, 0, PORT, FIXED) for i in range(1, len(kinds))]
    graph = T.Topology(np.asarray(kinds, np.int64), links, name="star").build()
    spec = CoherenceFabricSpec(dev_node=n_req + 1,
                               req_nodes=tuple(range(1, n_req + 1)))
    return graph, spec, list(range(n_req + 2, n_req + 2 + n_bg))


def run_scenario(n: int, load: float = 0.6):
    graph, spec, bg_nodes = star_fabric()
    addr, wr, rid = make_skewed_stream(n, FOOTPRINT, write_ratio=0.2,
                                       n_requesters=2, seed=7)
    cfg = SFConfig(capacity=CAP, policy="fifo", footprint_lines=FOOTPRINT)
    cache = CacheConfig(capacity=CAP)
    iso = simulate_sf(addr, wr, rid, cfg, cache, n_requesters=2)
    bg = None
    if load > 0:
        interval = max(int(BG_PAYLOAD * 1_000_000 // PORT
                           * len(bg_nodes) / load), 1)
        n_bg = min(int(iso.total_time_ps) // interval + 1, 3_000)
        bg = build_workload(graph, [
            RequesterSpec(node=b, n_requests=n_bg, targets=[spec.dev_node],
                          read_ratio=0.5, issue_interval_ps=interval,
                          payload_bytes=BG_PAYLOAD, seed=17 + i,
                          issue_jitter="exp")
            for i, b in enumerate(bg_nodes)], header_bytes=16,
            warmup_frac=0.0)
    res = simulate_coupled(addr, wr, rid, cfg, cache, graph, spec,
                           n_requesters=2, background=bg, max_iters=10,
                           tol_ps=1_000)
    return res, graph


def print_attribution(res, graph) -> None:
    ch = make_channels(graph)
    att = tm.attribute_latency(res.fabric_hops, ch, res.schedule,
                               res.fabric_issue_ps)
    assert int(np.abs(np.asarray(
        tm.conservation_residual(att))).max()) == 0
    total = int(np.asarray(att.total_ps).sum())
    print("== where the latency went (all scheduled rows) ==")
    for name, field in (("join/fork wait", att.join_wait_ps),
                        ("FCFS queueing", att.queue_wait_ps),
                        ("retrain stall", att.retrain_stall_ps),
                        ("wire serialization", att.wire_ps),
                        ("row-buffer extras", att.row_extra_ps),
                        ("fixed latency", att.fixed_ps)):
        v = int(np.asarray(field).sum())
        print(f"  {name:20s} {v / 1e6:10.1f} us  ({100 * v / total:5.1f}%)")
    sk = tm.sketch_update(tm.sketch_new(), att.total_ps)
    p50, p99, p999 = (int(x) for x in np.asarray(tm.sketch_quantiles(sk)))
    print(f"  latency p50/p99/p99.9: {p50 / 1e3:.0f} / {p99 / 1e3:.0f} /"
          f" {p999 / 1e3:.0f} ns")
    ct = tm.channel_telemetry(res.fabric_hops, ch, res.schedule)
    util = np.asarray(ct.utilization)
    names = tx.channel_names(graph)
    hot = int(util.argmax())
    print(f"  hottest channel: {names[hot]} at {100 * util[hot]:.1f}% "
          f"(peak backlog {int(ct.peak_backlog[hot])})")
    print(f"  coupled fixpoint: {res.iters} iters"
          f"{'' if res.converged else ' (cap)'}, residuals "
          f"{[int(x) for x in res.residual_ps]} ps")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="trace.json",
                    help="output path for the Chrome-trace JSON")
    ap.add_argument("--quick", action="store_true",
                    help="smaller scenario (CI smoke)")
    args = ap.parse_args()

    res, graph = run_scenario(n=200 if args.quick else 600)
    print_attribution(res, graph)

    trace = tx.coupled_trace(res, graph)
    errs = tx.validate_trace(trace)
    assert errs == [], f"exported trace failed validation: {errs[:3]}"
    tx.write_trace(trace, args.out)
    n_ev = sum(1 for e in trace["traceEvents"] if e["ph"] != "M")
    print(f"\nwrote {args.out}: {n_ev} events on "
          f"{graph.n_channels} channel tracks "
          f"- load it at https://ui.perfetto.dev")


if __name__ == "__main__":
    main()
