"""ESF design-space exploration: sweep fabrics, policies and duplex modes.

Reproduces the paper's §V exploration loop interactively:

    PYTHONPATH=src python examples/topology_explorer.py
"""

import numpy as np

import repro.core  # noqa: F401
from repro.core import RequesterSpec, build_workload, request_stats
from repro.core.engine import simulate
from repro.core.routing import route_and_simulate
from repro.core.snoop_filter import (CacheConfig, SFConfig, make_skewed_stream,
                                     simulate_sf)
from repro.core.topology import TOPOLOGY_BUILDERS, spine_leaf

SCALE = 8  # requester/memory pairs


def bandwidth_sweep():
    print(f"== aggregated bandwidth, scale {2 * SCALE} (x port bw) ==")
    for kind in TOPOLOGY_BUILDERS:
        topo = (spine_leaf(SCALE, per_leaf=4) if kind == "spine_leaf"
                else TOPOLOGY_BUILDERS[kind](SCALE))
        g = topo.build()
        mems = [int(m) for m in topo.memories()]
        specs = [RequesterSpec(node=int(r), n_requests=80 * len(mems),
                               targets=mems, issue_interval_ps=500, seed=i)
                 for i, r in enumerate(topo.requesters())]
        n_tx = sum(s.n_requests for s in specs)
        rng = np.random.default_rng(7)
        wl = build_workload(g, specs, header_bytes=64,
                            route_choice=rng.integers(0, 1 << 20, n_tx))
        sched = simulate(wl.hops, wl.channels, wl.issue_ps)
        r = request_stats(wl.hops, sched, wl.issue_ps, wl.payload_bytes,
                          wl.measured)
        print(f"  {kind:16s} {float(r['steady_bandwidth_MBps']) / 64_000:5.2f}x"
              f"   mean latency {float(r['mean_latency_ps']) / 1000:6.0f} ns")


def snoop_filter_sweep():
    print("\n== DCOH victim policy sweep (skewed 90/10 stream) ==")
    footprint, n = 2048, 8000
    cap = int(0.2 * footprint)
    addr, wr, rid = make_skewed_stream(n, footprint, seed=3)
    base = None
    for pol in ("fifo", "lru", "lfi", "lifo", "mru"):
        res = simulate_sf(addr, wr, rid,
                          SFConfig(capacity=cap, policy=pol,
                                   footprint_lines=footprint),
                          CacheConfig(capacity=cap))
        bw = float(res.bandwidth_MBps)
        base = base or bw
        print(f"  {pol:5s} bandwidth {bw / base:5.2f}x fifo   "
              f"BISnp {int(res.bisnp_events):6d}")


def adaptive_routing_demo():
    print("\n== routing strategies under noisy neighbours ==")
    from benchmarks.bench_routing import run_strategy

    for strat in ("oblivious", "ecmp", "adaptive"):
        bw, lat = run_strategy(strat, 200, 250)
        print(f"  {strat:10s} observed-host bw {bw:5.3f}x port, "
              f"latency {lat:5.0f} ns")


if __name__ == "__main__":
    bandwidth_sweep()
    snoop_filter_sweep()
    try:
        adaptive_routing_demo()
    except ImportError:
        print("(benchmarks package not on path — skip routing demo)")
