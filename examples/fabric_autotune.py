"""Fabric-aware sharding autotune + straggler what-if (beyond-paper demo).

The paper's loop — simulate the interconnect, then design the system — turned
on the training fleet itself:

    PYTHONPATH=src python examples/fabric_autotune.py
"""

import repro.core  # noqa: F401

from repro.core.autotune import WorkloadDims, autotune
from repro.core.fabric_model import TPUFabric, predict_collective
from repro.runtime.straggler import estimate_step_impact, mitigation_decision

fab = TPUFabric(nx=16, ny=16)
graph = fab.build()

print("== layout ranking: grok-1-314b train_4k (ESF-engine collective term) ==")
dims = WorkloadDims(n_layers=64, d_model=6144, d_ff=32768, n_heads=48, n_kv=8,
                    head_dim=128, vocab=131072, batch=256, seq=4096,
                    n_experts=8, top_k=2)
for s in autotune(dims, fab, graph=graph, use_engine=True)[:4]:
    print(f"  {s.layout.name:12s} step={s.step_s:7.3f} s bound={s.bound:10s} "
          f"hbm={s.hbm_bytes_per_chip / 2**30:5.2f} GiB  "
          f"coll={s.collective_s * 1e3:7.1f} ms")

print("\n== MoE all-to-all: contention the alpha-beta model misses ==")
est = predict_collective(fab, graph, "all_to_all", "y", 128 << 20)
naive = (128 << 20) / 16 * 15 / (50e9 * 2)
print(f"  ESF engine {est.seconds * 1e3:.2f} ms vs contention-free "
      f"{naive * 1e3:.2f} ms -> factor {est.seconds / naive:.2f}x")

print("\n== straggler what-if: one chip's links at 0.25x bandwidth ==")
# grok-1 bf16 grads / 256 chips ~ 2.4 GB/chip reduce-scattered per step
impact = estimate_step_impact(fab, graph, grad_bytes_per_chip=2_400 << 20,
                              slow_factor=4.0, compute_s=0.9)
print(f"  healthy step {impact['healthy_step_s']:.3f}s, degraded "
      f"{impact['degraded_step_s']:.3f}s (slowdown {impact['slowdown']:.3f}x)")
for remaining in (200, 20_000):
    d = mitigation_decision(impact["slowdown"], restart_cost_steps=50,
                            remaining_steps=remaining)
    print(f"  {remaining} steps left -> {d}")
