"""Stochastic link reliability: replay bursts, tail latency, retraining.

The expected-value CRC-replay model gives every packet the same stretch, so
the deterministic sweeps of `link_explorer` can never show a tail.  This
demo runs the same saturated bus in ``reliability="stochastic"`` mode —
seeded per-flit Go-Back-N replay sampling plus retraining stalls — and
prints what changes:

    PYTHONPATH=src python examples/link_reliability_demo.py
"""

import numpy as np

import repro.core  # noqa: F401
from repro.core import RequesterSpec, build_workload
from repro.core.calibration import PCIE6_X16_RAW_MBPS
from repro.core.engine import simulate
from repro.core.link_layer import FlitConfig
from repro.core.topology import single_bus, with_flit


def build(flit, n: int = 1200):
    topo = with_flit(single_bus(n_mems=4, bw_MBps=PCIE6_X16_RAW_MBPS), flit)
    spec = RequesterSpec(node=0, n_requests=n, targets=[2, 3, 4, 5],
                         read_ratio=0.5, issue_interval_ps=100,
                         payload_bytes=944, seed=11)
    return build_workload(topo.build(), [spec], warmup_frac=0.0)


def latencies_ns(wl) -> np.ndarray:
    sched = simulate(wl.hops, wl.channels, wl.issue_ps)
    assert bool(sched.converged)
    return np.asarray(sched.complete - wl.issue_ps) / 1000


def tail_sweep() -> None:
    print("== p50 / p99 request latency (ns): expected vs stochastic ==")
    print(f"  {'BER':>8s} {'exp p50':>9s} {'exp p99':>9s}"
          f" {'sto p50':>9s} {'sto p99':>9s} {'sto p99/p50':>12s}")
    for ber in (0.0, 1e-6, 1e-5, 3e-5, 1e-4):
        le = latencies_ns(build(FlitConfig("flit256", ber=ber)))
        ls = latencies_ns(build(FlitConfig(
            "flit256", ber=ber, reliability="stochastic", rel_seed=1)))
        print(f"  {ber:8.0e} {np.percentile(le, 50):9.0f}"
              f" {np.percentile(le, 99):9.0f}"
              f" {np.percentile(ls, 50):9.0f} {np.percentile(ls, 99):9.0f}"
              f" {np.percentile(ls, 99) / np.percentile(ls, 50):12.2f}")
    print("  (expected mode scales every packet alike; the stochastic p99"
          " pulls away\n   from its p50 as replay bursts land on unlucky"
          " packets)")


def retraining_demo() -> None:
    print("\n== retraining stalls (BER 1e-4, threshold 2, 1 us per event) ==")
    cfg_off = FlitConfig("flit256", ber=1e-4, reliability="stochastic",
                         rel_seed=1, retrain_threshold=0)
    cfg_on = FlitConfig("flit256", ber=1e-4, reliability="stochastic",
                        rel_seed=1, retrain_threshold=2,
                        retrain_ps=1_000_000)
    wl_off, wl_on = build(cfg_off), build(cfg_on)
    events = int((np.asarray(wl_on.hops.retrain_after_ps) > 0).sum())
    l_off, l_on = latencies_ns(wl_off), latencies_ns(wl_on)
    print(f"  sampled retraining events : {events}")
    print(f"  makespan without retraining: {l_off.max():8.0f} ns")
    print(f"  makespan with retraining   : {l_on.max():8.0f} ns")
    print(f"  p99 without / with         : {np.percentile(l_off, 99):.0f}"
          f" / {np.percentile(l_on, 99):.0f} ns")
    print("  (same seeded fault history; only the link-down intervals"
          " differ)")


if __name__ == "__main__":
    tail_sweep()
    retraining_demo()
