"""Fabric-coupled device coherence: BISnp traffic meets demand congestion.

The isolated snoop-filter model (§V-B) fixes the BISnp round trip and miss
path as constants; `core.coherence_traffic` lowers the same protocol onto
the fabric engine, so every BISnp/BIRsp/writeback is a routed transaction
contending with demand traffic.  This demo ramps background demand load on
the device and prints what the isolated model structurally cannot show —
coherence latency rising with fabric congestion, and the measured BISnp
round trip pulling away from its analytic constant:

    PYTHONPATH=src python examples/coherence_fabric_demo.py
"""

import numpy as np

import repro.core  # noqa: F401  (x64)
from repro.core import topology as T
from repro.core.coherence_traffic import CoherenceFabricSpec, simulate_coupled
from repro.core.devices import RequesterSpec, build_workload
from repro.core.snoop_filter import (CacheConfig, SFConfig, make_skewed_stream,
                                     simulate_sf)
from repro.core.traces import request_stream

FOOTPRINT = 512
N = 600
CAP = FOOTPRINT // 10
PORT, FIXED = 64_000, 26_000
BG_PAYLOAD = 1024


def star_fabric(n_req: int = 2, n_bg: int = 3):
    """Coherent requesters + background requesters + DCOH device, one switch.

    Deliberately mirrors `benchmarks.bench_coherence_fabric` rather than
    importing it: examples run with only ``PYTHONPATH=src`` (the
    ``benchmarks`` package is not importable from here), and staying
    self-contained keeps the demo copy-pasteable.
    """
    kinds = ([T.SWITCH] + [T.REQUESTER] * n_req + [T.MEMORY]
             + [T.REQUESTER] * n_bg)
    links = [T.LinkSpec(i, 0, PORT, FIXED) for i in range(1, len(kinds))]
    graph = T.Topology(np.asarray(kinds, np.int64), links, name="star").build()
    spec = CoherenceFabricSpec(dev_node=n_req + 1,
                               req_nodes=tuple(range(1, n_req + 1)))
    return graph, spec, list(range(n_req + 2, n_req + 2 + n_bg))


def background(graph, bg_nodes, dev, load: float, span_ps: int):
    """Poisson demand on the device at ``load`` x the device link capacity."""
    if load <= 0:
        return None
    interval = max(int(BG_PAYLOAD * 1_000_000 // PORT * len(bg_nodes) / load), 1)
    n = min(int(span_ps // interval) + 1, 3_000)
    specs = [RequesterSpec(node=b, n_requests=n, targets=[dev],
                           read_ratio=0.5, issue_interval_ps=interval,
                           payload_bytes=BG_PAYLOAD, seed=17 + i,
                           issue_jitter="exp")
             for i, b in enumerate(bg_nodes)]
    return build_workload(graph, specs, header_bytes=16, warmup_frac=0.0)


def run_point(stream, load: float, policy: str = "fifo"):
    addr, wr, rid = stream
    graph, spec, bg_nodes = star_fabric()
    cfg = SFConfig(capacity=CAP, policy=policy, footprint_lines=FOOTPRINT)
    cache = CacheConfig(capacity=CAP)
    iso = simulate_sf(addr, wr, rid, cfg, cache, n_requesters=2)
    bg = background(graph, bg_nodes, spec.dev_node, load,
                    int(iso.total_time_ps))
    out = simulate_coupled(addr, wr, rid, cfg, cache, graph, spec,
                           n_requesters=2, background=bg, max_iters=10,
                           tol_ps=1_000)
    miss = np.asarray(out.lowering.miss)
    bl = np.asarray(out.bisnp_lat_ps)
    return {
        "iso_ns": float(np.asarray(iso.latency_ps)[miss].mean()) / 1e3,
        "cpl_ns": float(np.asarray(out.sf.latency_ps)[miss].mean()) / 1e3,
        "bisnp_ns": float(bl[bl > 0].mean()) / 1e3 if (bl > 0).any() else 0.0,
        "iters": out.iters,
        "converged": out.converged,
    }


def load_ramp() -> None:
    stream = make_skewed_stream(N, FOOTPRINT, write_ratio=0.2,
                                n_requesters=2, seed=7)
    print("== isolated vs fabric-coupled mean miss latency (fifo DCOH) ==")
    print(f"  {'bg load':>8s} {'isolated':>9s} {'coupled':>9s}"
          f" {'BISnp rtt':>10s} {'fixpoint':>9s}")
    for load in (0.0, 0.3, 0.6, 0.9):
        m = run_point(stream, load)
        print(f"  {load:8.1f} {m['iso_ns']:8.0f}ns {m['cpl_ns']:8.0f}ns"
              f" {m['bisnp_ns']:9.0f}ns  {m['iters']} iters"
              f"{'' if m['converged'] else ' (cap)'}")
    print("  (the isolated column cannot move: its miss path and BISnp RTT"
          " are\n   constants; the coupled column feels the device link's"
          " queueing)")


def trace_mode() -> None:
    print("\n== trace-driven coherence (§V-E workloads, load 0.6) ==")
    for name in ("xsbench", "redis", "silo"):
        stream = request_stream(name, n=N, footprint_lines=FOOTPRINT,
                                n_requesters=2, seed=3)
        m = run_point(stream, 0.6)
        print(f"  {name:10s} isolated {m['iso_ns']:5.0f}ns"
              f"  coupled {m['cpl_ns']:5.0f}ns")


if __name__ == "__main__":
    load_ramp()
    trace_mode()
