"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

    PYTHONPATH=src python examples/train_small_lm.py [--steps 300]

Exercises the full substrate: sharded train step (host mesh), AdamW + ZeRO
state layout, warmup-cosine schedule, async atomic checkpoints + auto-resume
(kill it mid-run and re-launch), straggler detection, stateless data.
On this CPU container a 100M model runs ~2-4 s/step; use --preset smoke for
a seconds-long sanity pass.
"""

import argparse
import sys

sys.argv = [sys.argv[0]] + (sys.argv[1:] or [])

import repro.core  # noqa: F401,E402

from repro.launch import train as T  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--preset", default="100m", choices=("smoke", "100m"))
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    sys.argv = [
        "train", "--arch", "llama3-8b", "--preset", args.preset,
        "--steps", str(args.steps), "--batch", "8",
        "--seq", "256" if args.preset == "100m" else "64",
        "--lr", "1e-3", "--ckpt-dir", args.ckpt_dir,
    ]
    T.main()


if __name__ == "__main__":
    main()
