"""Batched serving example: continuous batching over a slot pool.

    PYTHONPATH=src python examples/serve_decode.py
"""

import numpy as np

import repro.core  # noqa: F401
import jax

from repro.configs import get_smoke_config
from repro.models import transformer as TF
from repro.runtime.server import Request, Server

cfg = get_smoke_config("recurrentgemma-2b")  # hybrid: ring-buffer + RG-LRU caches
params = TF.init_params(cfg, jax.random.key(0))
rng = np.random.default_rng(0)

requests = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, int(rng.integers(4, 12)))
                    .astype(np.int32),
                    max_new=8)
            for i in range(6)]

srv = Server(cfg, params, slots=3, max_len=64, temperature=0.0)
stats = srv.run(requests)
print(f"served {len(requests)} requests in {stats['ticks']} decode ticks "
      f"({stats['generated']} tokens) on {srv.slots} slots")
for r in requests:
    print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.out}")
