"""Link-layer design-space exploration: flit modes, BER, rx credits.

Walks the knobs the PCIe 6.0 FLIT subsystem (`core.link_layer`) adds on top
of the seed's single-bandwidth-constant link model:

    PYTHONPATH=src python examples/link_explorer.py
"""

import numpy as np

import repro.core  # noqa: F401
from repro.core import RequesterSpec, build_workload, request_stats
from repro.core.calibration import PCIE5_X16_MBPS, PCIE6_X16_RAW_MBPS
from repro.core.engine import simulate_auto
from repro.core.link_layer import (FlitConfig, credit_limited_MBps,
                                   goodput_efficiency)
from repro.core.topology import spine_leaf, with_flit


def run_fabric(flit, label: str, scale: int = 4) -> None:
    topo = with_flit(spine_leaf(scale, per_leaf=2,
                                bw_MBps=PCIE6_X16_RAW_MBPS), flit)
    g = topo.build()
    mems = [int(m) for m in topo.memories()]
    specs = [RequesterSpec(node=int(r), n_requests=120 * len(mems),
                           targets=mems, issue_interval_ps=400,
                           payload_bytes=944, read_ratio=0.5, seed=i)
             for i, r in enumerate(topo.requesters())]
    wl = build_workload(g, specs, header_bytes=64, warmup_frac=0.25)
    sched, oracle = simulate_auto(wl.hops, wl.channels, wl.issue_ps)
    r = request_stats(wl.hops, sched, wl.issue_ps, wl.payload_bytes,
                      wl.measured)
    print(f"  {label:28s} goodput {float(r['steady_bandwidth_MBps'])/1000:8.1f}"
          f" GB/s   mean latency {float(r['mean_latency_ps'])/1000:6.0f} ns"
          f"{'   (oracle)' if oracle else ''}")


def flit_mode_sweep() -> None:
    print("== spine-leaf fabric: link generations (PCIe 6 raw lanes) ==")
    run_fabric(None, "byte-exact (seed model)")
    run_fabric(FlitConfig("flit68"), "68 B flits (PCIe 5 / CXL 2.0)")
    run_fabric(FlitConfig("flit256"), "256 B flits (PCIe 6 / CXL 3.x)")


def ber_sweep() -> None:
    print("\n== 256 B flit goodput efficiency vs BER (Go-Back-N replay) ==")
    for ber in (0.0, 1e-8, 1e-7, 1e-6, 1e-5):
        eff = goodput_efficiency("flit256", ber)
        run_fabric(FlitConfig("flit256", ber=ber),
                   f"BER {ber:g} (analytic eff {eff:.3f})")


def credit_sweep() -> None:
    print("\n== rx-credit cap on a PCIe 6 x16 lane (100 ns credit loop) ==")
    for credits in (8, 16, 32, 64, 128, 256):
        cfg = FlitConfig("flit256", rx_credits=credits)
        cap = credit_limited_MBps(PCIE6_X16_RAW_MBPS, cfg)
        bind = "  <- credit-bound" if cap < PCIE6_X16_RAW_MBPS else ""
        print(f"  {credits:4d} credits: effective {cap/1000:7.1f} GB/s{bind}")
    run_fabric(FlitConfig("flit256", rx_credits=16),
               "fabric @ 16 credits")


def kernel_grid() -> None:
    print("\n== flit_pack kernel: packet-size x BER efficiency grid ==")
    from repro.kernels.flit_pack.ops import flit_sweep

    pays = np.asarray([64, 236, 472, 944, 4096])
    bers = (0.0, 1e-7, 1e-6, 1e-5)
    grid = np.asarray(flit_sweep(pays, ["flit68", "flit256"], bers))
    print(f"  payload mix {pays.tolist()} B, mean goodput fraction:")
    for mode, row in zip(("flit68 ", "flit256"), grid):
        cells = "  ".join(f"{v:.3f}" for v in row)
        print(f"  {mode}  ber {list(bers)} -> {cells}")


if __name__ == "__main__":
    flit_mode_sweep()
    ber_sweep()
    credit_sweep()
    kernel_grid()
    print(f"\n(PCIe 5 effective constant was {PCIE5_X16_MBPS/1000:.0f} GB/s — "
          "the whole link layer the seed collapsed into one number.)")
