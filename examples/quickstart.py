"""Quickstart: the whole stack in ~60 lines.

1. simulate a CXL fabric question with the ESF core (the paper),
2. train a small LM with the fabric-aware framework,
3. check what the autotuner would do on the production pod.

    PYTHONPATH=src python examples/quickstart.py
"""

import repro.core as core
import jax
import numpy as np

# ---- 1. the paper: which fabric should my 8+8 CXL system use? -------------
from repro.core import RequesterSpec, build_workload, request_stats, simulate
from repro.core.topology import TOPOLOGY_BUILDERS, spine_leaf

print("== ESF: normalized bandwidth by fabric topology (scale 16) ==")
for kind in ("chain", "ring", "fully_connected"):
    topo = (spine_leaf(8, per_leaf=4) if kind == "spine_leaf"
            else TOPOLOGY_BUILDERS[kind](8))
    g = topo.build()
    mems = [int(m) for m in topo.memories()]
    specs = [RequesterSpec(node=int(r), n_requests=160, targets=mems,
                           issue_interval_ps=500, seed=i)
             for i, r in enumerate(topo.requesters())]
    rng = np.random.default_rng(0)
    wl = build_workload(g, specs, header_bytes=64,
                        route_choice=rng.integers(0, 1 << 20, 160 * 8))
    sched = simulate(wl.hops, wl.channels, wl.issue_ps)
    r = request_stats(wl.hops, sched, wl.issue_ps, wl.payload_bytes,
                      wl.measured)
    print(f"  {kind:16s} {float(r['steady_bandwidth_MBps']) / 64000:.2f}x port")

# ---- 2. train a tiny LM on the same framework ------------------------------
from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, make_source
from repro.launch.mesh import make_host_mesh
from repro.runtime.trainer import TrainConfig, Trainer

print("\n== train a smoke-scale llama on this host ==")
cfg = get_smoke_config("llama3-8b")
trainer = Trainer(cfg, TrainConfig(steps=30, peak_lr=1e-2, warmup_steps=5,
                                   log_every=10), make_host_mesh())
src = make_source("synthetic", DataConfig(vocab=cfg.vocab, seq_len=32,
                                          global_batch=8))
trainer.fit(src)

# ---- 3. what layout would the fabric-aware autotuner pick at scale? --------
from repro.core.autotune import WorkloadDims, autotune
from repro.core.fabric_model import TPUFabric

print("\n== autotuner: llama3-8b train_4k on a 16x16 v5e pod ==")
dims = WorkloadDims(n_layers=32, d_model=4096, d_ff=14336, n_heads=32,
                    n_kv=8, head_dim=128, vocab=128256, batch=256, seq=4096)
for s in autotune(dims, TPUFabric(16, 16))[:3]:
    print(f"  {s.layout.name:12s} step={s.step_s * 1e3:7.1f} ms "
          f"bound={s.bound} hbm={s.hbm_bytes_per_chip / 2**30:.2f} GiB")
