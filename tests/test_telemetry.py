"""Telemetry layer: conservation invariant, oracle metric equality,
pure-observer bit-exactness, streaming quantile sketches, trace export.

The load-bearing properties:

  * **conservation** — `attribute_latency` components sum *exactly* to
    ``complete − issue`` per request, across flit-mode × reliability ×
    join configs (property test via the optional-hypothesis shim);
  * **oracle equality** — every metric reduction computed from the
    engine's schedule equals the same reduction computed from the
    event-driven `ref_des` oracle's schedule;
  * **pure observer** — running telemetry cannot perturb a schedule
    (re-simulating after a full telemetry pass is bit-identical), and
    `replay_round` reproduces the fixpoint schedule bit-for-bit;
  * **jit/vmap** — the reductions run inside one jit, vmapped across a
    BER sweep of stacked hop tables;
  * **sketch** — quantile estimates stay within the bucket resolution of
    exact sample quantiles; merge == concatenation; chunked streaming ==
    one batch;
  * **trace** — exported Chrome-trace JSON passes the schema gate, and
    corrupted traces are rejected.
"""

import json

import numpy as np
import pytest
from _hyp_compat import given, settings, st  # optional-hypothesis shim

import jax
import jax.numpy as jnp

import repro.core  # noqa: F401  (x64)
from repro.core import topology as T
from repro.core.devices import RequesterSpec, build_workload
from repro.core.engine import (Channels, Hops, SimOptions, channel_stats,
                               make_channels, replay_round, round_bound,
                               simulate)
from repro.core.link_layer import FlitConfig
from repro.core.ref_des import ref_schedule, simulate_ref
from repro.core.snoop_filter import (CacheConfig, SFConfig,
                                     make_skewed_stream, owner_count,
                                     simulate_sf)
from repro.core.coherence_traffic import (CoherenceFabricSpec,
                                          coherence_issue, simulate_coupled)
from repro.core import telemetry as tm
from repro.core import trace_export as tx

BUS_BW = 128_000

# flit-mode × reliability axis of the conservation property
FLIT_CONFIGS = {
    "byte": None,                              # byte-exact links
    "flit": FlitConfig("flit256"),             # flit quantization, expected
    "replay": FlitConfig("flit256", ber=1e-4),  # + expected CRC replay
    "stochastic": FlitConfig("flit256", ber=3e-4, reliability="stochastic",
                             rel_seed=7, retrain_threshold=2,
                             retrain_ps=500_000),  # sampled replay+retrain
}


def _bus_wl(flit, n=60, seed=3):
    topo = T.with_flit(T.single_bus(n_mems=4, bw_MBps=BUS_BW), flit)
    spec = RequesterSpec(node=0, n_requests=n, targets=[2, 3, 4, 5],
                         read_ratio=0.5, issue_interval_ps=300,
                         payload_bytes=944, seed=seed)
    return build_workload(topo.build(), [spec], warmup_frac=0.0)


def _join_case(seed, n=24, h=3, c=3):
    """Random hop table + a one-layer join DAG (like test_engine's)."""
    rng = np.random.default_rng(seed)
    ch = Channels(jnp.asarray(rng.integers(10, 100, c).astype(np.int64) * 1000),
                  jnp.asarray(np.where(rng.random(c) < .4,
                                       rng.integers(100, 4000, c),
                                       0).astype(np.int64)),
                  jnp.asarray(np.zeros(c, np.int64)),
                  jnp.asarray(np.zeros(c, np.int64)))
    chan = rng.integers(0, c, (n, h)).astype(np.int32)
    nbytes = np.where(rng.random((n, h)) < 0.15, 0,
                      rng.integers(1, 400, (n, h))).astype(np.int64)
    valid = rng.random((n, h)) < .85
    jid = np.full(n, -1, np.int32)
    jwait = np.full(n, -1, np.int32)
    jarity = np.zeros(n, np.int32)
    half = n // 2
    members = np.arange(half)[rng.random(half) < 0.6]
    if members.size == 0:
        members = np.array([0])
    jid[members] = 0
    jwait[half] = 0
    jarity[half] = members.size
    hops = Hops(jnp.asarray(chan), jnp.asarray(nbytes),
                jnp.asarray(rng.integers(0, 2, (n, h)).astype(np.int8)),
                jnp.asarray(np.full((n, h), -1, np.int32)),
                jnp.asarray(rng.integers(0, 2000, (n, h)).astype(np.int64)),
                jnp.asarray(valid), jnp.asarray(valid),
                join_id=jnp.asarray(jid), join_wait=jnp.asarray(jwait),
                join_arity=jnp.asarray(jarity))
    issue = jnp.asarray(np.sort(rng.integers(0, 5000, n)).astype(np.int64))
    return hops, ch, issue


def _star_coupled(seed=4, n=200, n_req=2):
    kinds = [T.SWITCH] + [T.REQUESTER] * n_req + [T.MEMORY]
    links = [T.LinkSpec(i, 0, 64_000, 26_000) for i in range(1, len(kinds))]
    graph = T.Topology(np.asarray(kinds, np.int64), links,
                       name="star").build()
    spec = CoherenceFabricSpec(dev_node=n_req + 1,
                               req_nodes=tuple(range(1, n_req + 1)))
    addr, wr, rid = make_skewed_stream(n, 256, write_ratio=0.3,
                                       n_requesters=n_req, seed=seed)
    res = simulate_coupled(addr, wr, rid,
                           SFConfig(capacity=32, footprint_lines=256),
                           CacheConfig(capacity=32), graph, spec,
                           n_requesters=n_req, max_iters=8)
    return res, graph


def _assert_conserved(hops, ch, sched, issue):
    att = tm.attribute_latency(hops, ch, sched, issue)
    resid = tm.conservation_residual(att)
    assert int(jnp.max(jnp.abs(resid))) == 0
    for f in ("join_wait_ps", "queue_wait_ps", "retrain_stall_ps",
              "wire_ps", "row_extra_ps", "fixed_ps"):
        assert int(jnp.min(getattr(att, f))) >= 0, f
    return att


# ---------------------------------------------------------------------------
# conservation invariant (the tentpole's hard property)
# ---------------------------------------------------------------------------

@given(st.integers(0, 10_000), st.sampled_from(sorted(FLIT_CONFIGS)))
@settings(max_examples=12, deadline=None)
def test_conservation_flit_reliability(seed, mode):
    wl = _bus_wl(FLIT_CONFIGS[mode], n=40, seed=seed % 97)
    sched = simulate(wl.hops, wl.channels, wl.issue_ps)
    assert bool(sched.converged)
    att = _assert_conserved(wl.hops, wl.channels, sched, wl.issue_ps)
    if mode == "stochastic":
        assert wl.hops.retrain_after_ps is not None
    else:
        assert int(jnp.sum(att.retrain_stall_ps)) == 0


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_conservation_joins(seed):
    hops, ch, issue = _join_case(seed)
    sched = simulate(hops, ch, issue)
    assert bool(sched.converged)
    att = _assert_conserved(hops, ch, sched, issue)
    # the waiter really attributes its release stall to join_wait
    assert int(att.join_wait_ps[hops.channel.shape[0] // 2]) >= 0


def test_conservation_coupled_coherence():
    res, graph = _star_coupled()
    ch = make_channels(graph)
    issue = coherence_issue(res.lowering, res.events.fab_issue_ps)
    att = _assert_conserved(res.lowering.hops, ch, res.schedule, issue)
    assert int(jnp.sum(att.join_wait_ps)) > 0   # BISnp joins stall requests


# ---------------------------------------------------------------------------
# oracle metric equality + pure observer
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", sorted(FLIT_CONFIGS))
def test_metrics_equal_engine_vs_oracle(mode):
    wl = _bus_wl(FLIT_CONFIGS[mode], n=50)
    sched = simulate(wl.hops, wl.channels, wl.issue_ps)
    ref = ref_schedule(simulate_ref(wl.hops, wl.channels, wl.issue_ps))
    a = tm.attribute_latency(wl.hops, wl.channels, sched, wl.issue_ps)
    b = tm.attribute_latency(wl.hops, wl.channels, ref, wl.issue_ps)
    for f in a._fields:
        assert bool(jnp.all(getattr(a, f) == getattr(b, f))), f
    ca = tm.channel_telemetry(wl.hops, wl.channels, sched)
    cb = tm.channel_telemetry(wl.hops, wl.channels, ref)
    for f in ca._fields:
        assert bool(jnp.all(getattr(ca, f) == getattr(cb, f))), f
    wa = tm.windowed_series(wl.hops, wl.channels, sched, wl.issue_ps, n_bins=16)
    wb = tm.windowed_series(wl.hops, wl.channels, ref, wl.issue_ps, n_bins=16)
    for f in ("busy_ps", "completions"):
        assert bool(jnp.all(getattr(wa, f) == getattr(wb, f))), f


def test_telemetry_is_pure_observer():
    """Schedules are bit-exact with metrics on vs. off."""
    wl = _bus_wl(FLIT_CONFIGS["stochastic"], n=50)
    before = simulate(wl.hops, wl.channels, wl.issue_ps)
    snap = {f: np.asarray(getattr(before, f)).copy() for f in before._fields}
    tm.fabric_metrics(wl.hops, wl.channels, before, wl.issue_ps)
    tx.schedule_trace(wl.hops, wl.channels, before)
    after = simulate(wl.hops, wl.channels, wl.issue_ps)
    for f in before._fields:
        assert np.array_equal(snap[f], np.asarray(getattr(after, f))), f


def test_replay_round_reproduces_fixpoint():
    """One replayed round from the converged schedule is bit-identical —
    the property the retraining-stall extraction rests on."""
    for mode in ("byte", "stochastic"):
        wl = _bus_wl(FLIT_CONFIGS[mode], n=50)
        sched = simulate(wl.hops, wl.channels, wl.issue_ps)
        start, depart, stall = replay_round(wl.hops, wl.channels, sched)
        assert np.array_equal(np.asarray(start), np.asarray(sched.start))
        assert np.array_equal(np.asarray(depart), np.asarray(sched.depart))
        if mode == "byte":
            assert int(jnp.sum(stall)) == 0


# ---------------------------------------------------------------------------
# jit + vmap across a BER sweep
# ---------------------------------------------------------------------------

def test_metrics_jit_vmap_ber_sweep():
    wls = [_bus_wl(FlitConfig("flit256", ber=b, reliability="stochastic",
                              rel_seed=7, retrain_threshold=2,
                              retrain_ps=500_000), n=40)
           for b in (1e-5, 3e-4)]
    h_max = max(w.hops.channel.shape[1] for w in wls)
    fills = dict(channel=-1, nbytes=0, direction=0, row=-1, fixed_after_ps=0,
                 is_payload=False, valid=False, extra_wire_bytes=0,
                 retrain_after_ps=0)

    def pad(h):
        return h._replace(**{
            f: jnp.asarray(np.pad(
                np.asarray(getattr(h, f)),
                ((0, 0), (0, h_max - getattr(h, f).shape[1])),
                constant_values=v))
            for f, v in fills.items()})

    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                     *[pad(w.hops) for w in wls])
    ch, issue = wls[0].channels, wls[0].issue_ps
    # the join tables are vmapped tracers inside `sweep`, so resolve the
    # round bound host-side from the concrete stacked hops
    opts = SimOptions(max_rounds=round_bound(stacked))

    @jax.jit
    def sweep(hops):
        sched = jax.vmap(lambda h: simulate(h, ch, issue, opts))(hops)
        att = jax.vmap(lambda h, s: tm.attribute_latency(h, ch, s,
                                                         issue))(hops, sched)
        chans = jax.vmap(lambda h, s: tm.channel_telemetry(h, ch,
                                                           s))(hops, sched)
        sk = jax.vmap(lambda t: tm.sketch_update(tm.sketch_new(),
                                                 t))(att.total_ps)
        return sched, att, chans, jax.vmap(tm.sketch_quantiles)(sk)

    sched, att, chans, q = sweep(stacked)
    assert bool(sched.converged.all())
    assert int(jnp.max(jnp.abs(tm.conservation_residual(att)))) == 0
    # more bit errors -> strictly more retraining stall at these BERs
    stalls = np.asarray(jnp.sum(att.retrain_stall_ps, axis=1))
    assert stalls[1] > stalls[0]
    assert q.shape == (2, 3) and bool((q[:, 0] <= q[:, 2]).all())
    # vmapped rows equal the per-workload scalar path
    solo = simulate(wls[0].hops, ch, issue)
    att0 = tm.attribute_latency(wls[0].hops, ch, solo, issue)
    assert np.array_equal(np.asarray(att.total_ps[0]),
                          np.asarray(att0.total_ps))


# ---------------------------------------------------------------------------
# channel counters + windowed series
# ---------------------------------------------------------------------------

def test_channel_telemetry_matches_channel_stats():
    wl = _bus_wl(FLIT_CONFIGS["flit"], n=60)
    sched = simulate(wl.hops, wl.channels, wl.issue_ps)
    ct = tm.channel_telemetry(wl.hops, wl.channels, sched)
    cs = channel_stats(wl.hops, sched, wl.channels)
    assert np.array_equal(np.asarray(ct.busy_ps), np.asarray(cs["busy_ps"]))
    assert np.array_equal(np.asarray(ct.wait_ps), np.asarray(cs["wait_ps"]))
    # payload bytes: every measured request moved its logical bytes
    assert int(jnp.sum(ct.payload_bytes)) == int(
        jnp.sum(jnp.where(wl.hops.is_payload, wl.hops.nbytes, 0)))
    # flit quantization means wire bytes strictly exceed payload bytes
    assert int(jnp.sum(ct.wire_bytes)) > int(jnp.sum(ct.payload_bytes))


def test_peak_backlog_hand_case():
    """3 requests arrive at t=0 on one channel (ser 100k ps each): backlog
    peaks at 3, drains by one at each grant."""
    ch = Channels(jnp.asarray([1000], dtype=jnp.int64),
                  jnp.zeros(1, jnp.int64), jnp.zeros(1, jnp.int64),
                  jnp.zeros(1, jnp.int64))
    n = 3
    hops = Hops(jnp.zeros((n, 1), jnp.int32),
                jnp.full((n, 1), 100, jnp.int64),
                jnp.zeros((n, 1), jnp.int8),
                jnp.full((n, 1), -1, jnp.int32),
                jnp.zeros((n, 1), jnp.int64),
                jnp.ones((n, 1), bool), jnp.ones((n, 1), bool))
    issue = jnp.zeros(n, jnp.int64)
    sched = simulate(hops, ch, issue)
    ct = tm.channel_telemetry(hops, ch, sched)
    assert int(ct.peak_backlog[0]) == 3
    assert int(ct.busy_ps[0]) == 3 * 100_000
    # staggered arrivals past each grant never queue
    issue2 = jnp.asarray([0, 100_000, 200_000], jnp.int64)
    ct2 = tm.channel_telemetry(hops, ch, simulate(hops, ch, issue2))
    assert int(ct2.peak_backlog[0]) == 1
    assert int(ct2.wait_ps[0]) == 0


def test_windowed_series_sums_to_totals():
    wl = _bus_wl(FLIT_CONFIGS["replay"], n=60)
    sched = simulate(wl.hops, wl.channels, wl.issue_ps)
    ws = tm.windowed_series(wl.hops, wl.channels, sched, wl.issue_ps,
                            n_bins=16)
    ct = tm.channel_telemetry(wl.hops, wl.channels, sched)
    # exact split: binned occupancy sums back to the channel totals
    assert int(jnp.sum(ws.busy_ps)) == int(jnp.sum(ct.busy_ps))
    assert int(jnp.sum(ws.completions)) == int(sched.complete.shape[0])
    # integral of in-flight == total latency mass
    total_lat = int(jnp.sum(sched.complete - wl.issue_ps))
    assert int(jnp.sum(ws.inflight * ws.bin_ps)) == total_lat


# ---------------------------------------------------------------------------
# quantile sketch
# ---------------------------------------------------------------------------

def test_sketch_binning_roundtrip_small_values_exact():
    v = jnp.arange(0, 32, dtype=jnp.int64)
    assert np.array_equal(np.asarray(tm.sketch_bin(v)), np.arange(32))
    assert np.array_equal(np.asarray(tm.sketch_value(tm.sketch_bin(v))),
                          np.asarray(v))


def test_sketch_quantiles_within_resolution():
    rng = np.random.default_rng(11)
    vals = np.concatenate([
        rng.integers(1, 100, 4000),
        (rng.lognormal(13, 1.5, 6000)).astype(np.int64),
    ]).astype(np.int64)
    sk = tm.sketch_update(tm.sketch_new(), jnp.asarray(vals))
    assert int(sk.n) == vals.size
    for q in (0.01, 0.25, 0.5, 0.9, 0.99, 0.999):
        est = int(tm.sketch_quantile(sk, q))
        exact = int(np.quantile(vals, q, method="inverted_cdf"))
        assert abs(est - exact) <= max(exact * 2 * tm.SKETCH_REL_ERROR, 1), q
    # extremes are exact (clamped to observed min/max)
    assert int(tm.sketch_quantile(sk, 0.0)) == int(vals.min())
    assert int(tm.sketch_quantile(sk, 1.0)) == int(vals.max())


def test_sketch_merge_equals_concat_and_streams():
    rng = np.random.default_rng(5)
    a = rng.integers(1, 10**9, 3000).astype(np.int64)
    b = (rng.lognormal(10, 2, 2000)).astype(np.int64)
    one = tm.sketch_update(tm.sketch_new(),
                           jnp.asarray(np.concatenate([a, b])))
    merged = tm.sketch_merge(tm.sketch_update(tm.sketch_new(), jnp.asarray(a)),
                             tm.sketch_update(tm.sketch_new(), jnp.asarray(b)))
    for f in one._fields:
        assert np.array_equal(np.asarray(getattr(one, f)),
                              np.asarray(getattr(merged, f))), f
    # chunked streaming (the windowed-engine pattern) == one batch
    chunks = tm.sketch_new()
    for part in np.array_split(np.concatenate([a, b]), 7):
        chunks = tm.sketch_update(chunks, jnp.asarray(part))
    assert np.array_equal(np.asarray(chunks.counts), np.asarray(one.counts))
    # masked update skips masked-out values
    masked = tm.sketch_update(tm.sketch_new(), jnp.asarray(a),
                              mask=jnp.zeros(a.size, bool))
    assert int(masked.n) == 0
    assert int(tm.sketch_quantile(masked, 0.5)) == 0


def test_fabric_metrics_check_catches_corruption():
    wl = _bus_wl(None, n=30)
    sched = simulate(wl.hops, wl.channels, wl.issue_ps)
    tm.fabric_metrics(wl.hops, wl.channels, sched, wl.issue_ps)  # clean: ok
    bad = sched._replace(complete=sched.complete + 1)
    with pytest.raises(AssertionError, match="conservation"):
        tm.fabric_metrics(wl.hops, wl.channels, bad, wl.issue_ps)


# ---------------------------------------------------------------------------
# SF protocol counters
# ---------------------------------------------------------------------------

def test_owner_count_popcount():
    masks = jnp.asarray([0b0, 0b1, 0b101, 0b1111, (1 << 31) | 1], jnp.int64)
    assert np.array_equal(np.asarray(owner_count(masks)), [0, 1, 2, 4, 2])


def test_sf_telemetry_counters():
    addr, wr, rid = make_skewed_stream(300, 256, write_ratio=0.3,
                                       n_requesters=2, seed=4)
    _, ev = simulate_sf(addr, wr, rid,
                        SFConfig(capacity=32, footprint_lines=256),
                        CacheConfig(capacity=32), n_requesters=2,
                        return_events=True)
    sft = tm.sf_telemetry(ev, n_requesters=2)
    t = int(ev.cache_hit.shape[0])
    assert int(jnp.sum(sft.fanout_hist)) == t
    assert float(sft.hit_rate) == pytest.approx(
        float(jnp.mean(ev.cache_hit.astype(jnp.float64))))
    assert int(sft.bisnp_legs) == int(jnp.sum(owner_count(ev.bisnp_mask)))
    assert int(sft.invblk_lines) == int(jnp.sum(ev.inv_lines))
    assert int(sft.wb_lines) == int(jnp.sum(ev.wb_lines))


# ---------------------------------------------------------------------------
# coupled convergence telemetry
# ---------------------------------------------------------------------------

def test_coupled_residual_history():
    res, graph = _star_coupled()
    assert res.converged
    hist = np.asarray(res.residual_ps)
    assert hist.ndim == 1 and hist.size == res.iters - 1
    assert hist[-1] == 0                      # tol 0: exact fixpoint
    assert res.fabric_hops is not None
    assert res.fabric_issue_ps.shape[0] == res.schedule.complete.shape[0]


# ---------------------------------------------------------------------------
# trace export + schema gate
# ---------------------------------------------------------------------------

def _trace_for(mode):
    wl = _bus_wl(FLIT_CONFIGS[mode], n=40)
    sched = simulate(wl.hops, wl.channels, wl.issue_ps)
    return tx.schedule_trace(wl.hops, wl.channels, sched)


def test_trace_schema_valid():
    tr = _trace_for("flit")
    assert tx.validate_trace(tr) == []
    assert tx.validate_trace(json.dumps(tr)) == []   # round-trips as JSON
    phs = {e["ph"] for e in tr["traceEvents"]}
    assert {"M", "B", "E", "C"} <= phs


def test_trace_retrain_tracks():
    tr = _trace_for("stochastic")
    assert tx.validate_trace(tr) == []
    names = [e["name"] for e in tr["traceEvents"] if e["ph"] == "B"]
    assert "retraining" in names
    assert any(e["ph"] == "i" and e["name"] == "retrain"
               for e in tr["traceEvents"])


def test_coupled_trace_residual_counters():
    res, graph = _star_coupled()
    tr = tx.coupled_trace(res, graph)
    assert tx.validate_trace(tr) == []
    resids = [e for e in tr["traceEvents"]
              if e["ph"] == "C" and e["name"] == "coupled residual"]
    assert len(resids) == res.iters - 1
    names = tx.channel_names(graph)
    assert len(names) == graph.n_channels and all(names)


def test_trace_validator_rejects_corruption():
    tr = _trace_for("byte")
    evs = tr["traceEvents"]
    # unmatched E: drop the last B's partner
    i_b = max(i for i, e in enumerate(evs) if e["ph"] == "B")
    broken = {"traceEvents": evs[:i_b] + evs[i_b + 1:]}
    assert any("unclosed" in v or "without matching" in v
               for v in tx.validate_trace(broken))
    # non-monotone ts
    shuffled = {"traceEvents": list(reversed(evs))}
    assert any("<" in v for v in tx.validate_trace(shuffled))
    # structurally invalid inputs
    assert tx.validate_trace("not json {")[0].startswith("invalid JSON")
    assert tx.validate_trace({"foo": 1}) == ["missing traceEvents object"]
    assert tx.validate_trace({"traceEvents": [{"nope": 1}]})
    bad_ts = {"traceEvents": [{"ph": "B", "pid": 0, "tid": 0, "ts": -5,
                               "name": "x"}]}
    assert any("bad ts" in v for v in tx.validate_trace(bad_ts))


def _flow(ph, ts, fid=1, **kw):
    e = {"ph": ph, "pid": 0, "tid": 0, "ts": ts, "cat": "critical_path",
         "name": "queue", "id": fid}
    e.update(kw)
    return e


def test_trace_validator_flow_schema():
    # a well-formed s/f pair is accepted
    ok = {"traceEvents": [_flow("s", 0), _flow("f", 5, bp="e")]}
    assert tx.validate_trace(ok) == []
    # ...including a step event between them
    ok3 = {"traceEvents": [_flow("s", 0), _flow("t", 2),
                           _flow("f", 5, bp="e")]}
    assert tx.validate_trace(ok3) == []
    # dangling s: no terminating f
    dangling = {"traceEvents": [_flow("s", 0)]}
    assert any("no terminating f" in v for v in tx.validate_trace(dangling))
    # f (and t) without an open s
    orphan = {"traceEvents": [_flow("f", 5, bp="e")]}
    assert any("without open s" in v for v in tx.validate_trace(orphan))
    step = {"traceEvents": [_flow("t", 2)]}
    assert any("without open s" in v for v in tx.validate_trace(step))
    # duplicate s for the same (cat, id)
    dup = {"traceEvents": [_flow("s", 0), _flow("s", 1),
                           _flow("f", 5, bp="e")]}
    assert any("duplicate flow s" in v for v in tx.validate_trace(dup))
    # same id under a different cat is a distinct flow — the second one
    # dangles even though ids collide
    other = {"traceEvents": [_flow("s", 0), _flow("s", 1, cat="other"),
                             _flow("f", 5, bp="e")]}
    assert any("no terminating f" in v and "other" in v
               for v in tx.validate_trace(other))
    # missing id / name are rejected
    noid = {"traceEvents": [{"ph": "s", "pid": 0, "tid": 0, "ts": 0,
                             "cat": "critical_path", "name": "queue"}]}
    assert any("without id" in v for v in tx.validate_trace(noid))
    noname = {"traceEvents": [{"ph": "s", "pid": 0, "tid": 0, "ts": 0,
                               "cat": "critical_path", "id": 1},
                              _flow("f", 5, bp="e")]}
    assert any("without name" in v for v in tx.validate_trace(noname))
    # flow events participate in the global ts-monotonicity check
    unordered = {"traceEvents": [_flow("s", 10), _flow("f", 3, bp="e")]}
    assert any("<" in v for v in tx.validate_trace(unordered))
