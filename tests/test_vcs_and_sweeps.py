"""Multiple-VCS switching (paper §II-B) + vmapped config sweeps."""

import numpy as np
import pytest
from _hyp_compat import given, settings, st  # optional-hypothesis shim

import jax
import jax.numpy as jnp

import repro.core  # noqa: F401
from repro.core.devices import RequesterSpec, build_workload
from repro.core.engine import Channels, simulate
from repro.core.vcs import LogicalDevice, MultiVCS
from repro.core import topology as T


def test_multivcs_default_binding_and_capacity():
    v = MultiVCS(n_usp=2, devices=4, n_logical_per_device=2)
    v.check_invariants()
    # pooled capacity splits evenly by default
    assert v.visible_capacity(0) + v.visible_capacity(1) == pytest.approx(4.0)


def test_rebinding_moves_capacity_without_recabling():
    v = MultiVCS(n_usp=2, devices=2, n_logical_per_device=2)
    before = v.visible_capacity(0)
    # software-compose: move every logical device to USP 0
    for i in range(len(v.pool)):
        v.bind(i, 0)
    assert v.visible_capacity(0) == pytest.approx(2.0)
    assert v.visible_capacity(0) > before
    assert v.visible_capacity(1) == 0.0
    topo, mapping = v.build_topology()
    g = topo.build()
    # USP 0's host reaches every logical device; USP 1's host reaches none
    h0, h1 = mapping["hosts"]
    for m in mapping["logical"]:
        path = g.route(h0, m)
        assert path[-1] == m
        with pytest.raises(ValueError):
            g.route(h1, m)


@given(st.integers(2, 4), st.integers(1, 3), st.integers(0, 99))
@settings(max_examples=15, deadline=None)
def test_multivcs_invariants_under_random_rebinds(n_usp, n_log, seed):
    rng = np.random.default_rng(seed)
    v = MultiVCS(n_usp=n_usp, devices=3, n_logical_per_device=n_log)
    for _ in range(10):
        v.bind(int(rng.integers(0, len(v.pool))), int(rng.integers(0, n_usp)))
    v.check_invariants()
    total = sum(v.visible_capacity(u) for u in range(n_usp))
    assert total == pytest.approx(3.0)
    topo, mapping = v.build_topology()
    g = topo.build()
    for ld, m in zip(v.pool, mapping["logical"]):
        assert g.route(mapping["hosts"][ld.bound_usp], m)[-1] == m


def test_vmapped_bandwidth_sweep_monotone():
    """The tensorized engine's vmap superpower (DESIGN.md §2a): sweep 16 bus
    bandwidths in one call; makespan must fall monotonically with bandwidth
    and every instance must converge."""
    topo = T.single_bus(n_mems=4, bw_MBps=64_000)
    g = topo.build()
    spec = RequesterSpec(node=0, n_requests=200, targets=[2, 3, 4, 5],
                         pattern="uniform", read_ratio=0.5,
                         issue_interval_ps=300, seed=1)
    wl = build_workload(g, [spec], header_bytes=16, warmup_frac=0.0)
    bws = jnp.asarray(np.linspace(16_000, 128_000, 16).astype(np.int64))
    svc = jnp.asarray(g.chan_is_service)

    def one(bw):
        ch = Channels(jnp.where(svc, wl.channels.bw_MBps, bw),
                      wl.channels.turnaround_ps, wl.channels.row_hit_ps,
                      wl.channels.row_miss_ps)
        s = simulate(wl.hops, ch, wl.issue_ps)
        return jnp.max(s.complete), s.converged

    makespans, conv = jax.vmap(one)(bws)
    assert bool(conv.all())
    assert bool((jnp.diff(makespans) <= 0).all())


def test_coherence_modes_dmc_wins():
    """Paper §II-C: device-managed coherence out-scales host mediation."""
    from benchmarks.bench_coherence_modes import run_mode

    bw_db, lat_db = run_mode("hdm_db", 4, n_per=150)
    bw_h, lat_h = run_mode("hdm_h", 4, n_per=150)
    assert bw_db > 1.5 * bw_h
    assert lat_h > 1.5 * lat_db
