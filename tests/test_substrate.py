"""Substrate: checkpointing, elastic scaling, stragglers, compression, data."""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.core  # noqa: F401
from repro.checkpoint import checkpoint as ckpt
from repro.data.pipeline import DataConfig, SyntheticLM, TraceLM
from repro.optim import adamw, grad_compress as gc, schedules
from repro.runtime.elastic import choose_mesh, resize_plan
from repro.runtime.straggler import (StragglerDetector, mitigation_decision)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def _tree(seed=0):
    k = jax.random.key(seed)
    return {"a": jax.random.normal(k, (4, 8), jnp.float32),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                       "c": jax.random.normal(k, (3,), jnp.float32)
                       .astype(jnp.bfloat16)}}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 7, t)
    restored, step = ckpt.restore(str(tmp_path), jax.eval_shape(lambda: t))
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_integrity_detects_corruption(tmp_path):
    ckpt.save(str(tmp_path), 1, _tree())
    payload = os.path.join(str(tmp_path), "step_000000001", "arrays.npz")
    raw = bytearray(open(payload, "rb").read())
    raw[len(raw) // 2] ^= 0xFF
    open(payload, "wb").write(bytes(raw))
    with pytest.raises(IOError):
        ckpt.restore(str(tmp_path), jax.eval_shape(_tree))


def test_checkpoint_gc_and_latest(tmp_path):
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, _tree(), keep_last=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    kept = sorted(os.listdir(tmp_path))
    assert len([d for d in kept if d.startswith("step_")]) == 2


def test_checkpoint_async(tmp_path):
    import time

    ckpt.save(str(tmp_path), 9, _tree(), blocking=False)
    for _ in range(100):
        if ckpt.latest_step(str(tmp_path)) == 9:
            break
        time.sleep(0.05)
    assert ckpt.latest_step(str(tmp_path)) == 9


# ---------------------------------------------------------------------------
# elastic
# ---------------------------------------------------------------------------

def test_choose_mesh_handles_odd_counts():
    for n in (512, 500, 256, 130, 96, 7, 1):
        plan = choose_mesh(n)
        used = np.prod(plan.shape)
        assert used == plan.usable_devices <= n
        assert plan.dropped_devices == n - plan.usable_devices


def test_resize_plan_roundtrip():
    old = choose_mesh(512, prefer_pods=2)
    plan = resize_plan(old, 256)
    assert plan["action"] == "save_restore"
    assert plan["new"].usable_devices == 256


# ---------------------------------------------------------------------------
# straggler
# ---------------------------------------------------------------------------

def test_straggler_detector_flags_sustained_outliers():
    det = StragglerDetector(patience=3)
    verdicts = [det.observe(0, 1.0) for _ in range(20)]
    assert all(v == "ok" for v in verdicts)
    verdicts = [det.observe(1, 3.0) for _ in range(4)]
    assert verdicts[-1] == "straggler"


def test_mitigation_decision_thresholds():
    assert mitigation_decision(1.01, 50, 1000) == "ignore"
    assert mitigation_decision(1.04, 50, 1000) == "rebalance"
    assert mitigation_decision(1.5, 50, 1000) == "checkpoint_evict"


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_int8_error_feedback_converges():
    """With error feedback, the accumulated compressed signal tracks the true
    gradient sum (residual stays bounded)."""
    key = jax.random.key(0)
    g = jax.random.normal(key, (512,), jnp.float32) * 0.1
    residual = jnp.zeros_like(g)
    total_sent = jnp.zeros_like(g)
    for i in range(30):
        key, sub = jax.random.split(key)
        payload, residual = gc.compress_with_feedback(g, residual, sub,
                                                      method="int8")
        total_sent = total_sent + gc.decompress(payload, "int8")
    err = float(jnp.linalg.norm(total_sent - 30 * g) /
                jnp.linalg.norm(30 * g))
    assert err < 0.01, err
    assert float(jnp.max(jnp.abs(residual))) < float(jnp.max(jnp.abs(g)))


def test_topk_error_feedback_preserves_signal():
    key = jax.random.key(1)
    g = jax.random.normal(key, (1024,), jnp.float32)
    residual = jnp.zeros_like(g)
    sent = jnp.zeros_like(g)
    for i in range(40):
        key, sub = jax.random.split(key)
        payload, residual = gc.compress_with_feedback(
            g, residual, sub, method="topk", topk_frac=0.1)
        sent = sent + gc.decompress(payload, "topk")
    rel = float(jnp.linalg.norm(sent - 40 * g) / jnp.linalg.norm(40 * g))
    assert rel < 0.2, rel  # residual bounded => error O(1/steps)


# ---------------------------------------------------------------------------
# optimizer + schedules + data
# ---------------------------------------------------------------------------

def test_adamw_decreases_quadratic():
    w = {"w": jnp.ones((16,), jnp.bfloat16)}
    st = adamw.init(w)

    def loss(p):
        x = p["w"].astype(jnp.float32)
        return jnp.sum((x - 3.0) ** 2)

    for _ in range(200):
        g = jax.grad(loss)(w)
        w, st, _ = adamw.update(st, g, w, lr=0.05, weight_decay=0.0)
    assert loss(w) < 0.2


def test_schedule_shapes():
    import numpy as np

    s = np.array([schedules.warmup_cosine(jnp.int32(i), peak_lr=1.0,
                                          warmup_steps=10, total_steps=100)
                  for i in (0, 5, 10, 50, 100)])
    assert s[0] == 0 and abs(s[2] - 1.0) < 1e-6 and s[4] <= 0.11


def test_data_determinism_and_sharding():
    cfg = DataConfig(vocab=256, seq_len=32, global_batch=8, seed=3)
    a = SyntheticLM(cfg, shard=0, n_shards=2).batch(5)
    b = SyntheticLM(cfg, shard=0, n_shards=2).batch(5)
    c = SyntheticLM(cfg, shard=1, n_shards=2).batch(5)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))
    assert a["tokens"].shape == (4, 32)
    tr = TraceLM(cfg).batch(0)
    assert tr["tokens"].shape == (8, 32)
    assert int(tr["tokens"].max()) < 256
