"""Per-kernel correctness: shape/dtype sweeps, interpret-mode pallas vs the
pure-jnp oracle, plus hypothesis property tests for the engine hotspot."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, st  # optional-hypothesis shim

import repro.core  # noqa: F401  (x64)
from repro.kernels.flash_attention.kernel import flash_attention_gqa
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.link_contention.kernel import segmented_depart
from repro.kernels.link_contention.ops import depart_times
from repro.kernels.link_contention.ref import segmented_depart_ref
from repro.kernels.rglru_scan.kernel import rglru_scan_pallas
from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.kernels.ssd_chunk.kernel import ssd_chunk_pallas
from repro.kernels.ssd_chunk.ref import ssd_chunk_ref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,kv,g,s,d,qb,kb", [
    (1, 2, 2, 256, 64, 128, 128),
    (2, 1, 4, 128, 128, 64, 128),
    (1, 4, 1, 512, 64, 256, 256),
])
def test_flash_attention_sweep(b, kv, g, s, d, qb, kb, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, kv, g, s, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, kv, s, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, kv, s, d), jnp.float32).astype(dtype)
    out = flash_attention_gqa(q, k, v, causal=True, q_blk=qb, kv_blk=kb,
                              interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_flash_attention_windowed():
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (1, 2, 2, 256, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 256, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 256, 64), jnp.float32)
    out = flash_attention_gqa(q, k, v, causal=True, window=64,
                              q_blk=128, kv_blk=128, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


def test_flash_ops_matches_model_layout():
    """The ops wrapper reproduces models.attention.plain_attention."""
    from repro.models.attention import plain_attention
    ks = jax.random.split(jax.random.key(2), 3)
    b, s, h, kvh, d = 2, 128, 8, 2, 64
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kvh, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kvh, d), jnp.float32)
    out = flash_attention(q, k, v, causal=True, impl="interpret",
                          q_blk=64, kv_blk=64)
    ref = plain_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# rglru scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,d,chunk", [(2, 128, 64, 32), (1, 512, 256, 256),
                                         (3, 64, 128, 64)])
def test_rglru_scan_sweep(b, s, d, chunk):
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.uniform(0.8, 0.999, (b, s, d)).astype(np.float32))
    bb = jnp.asarray(rng.normal(0, 0.1, (b, s, d)).astype(np.float32))
    out = rglru_scan_pallas(a, bb, chunk=chunk, d_blk=min(d, 512),
                            interpret=True)
    ref = rglru_scan_ref(a, bb)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5,
                               rtol=1e-5)


def test_rglru_matches_model_block_semantics():
    """Kernel oracle == sequential recurrence (exact per-step check)."""
    rng = np.random.default_rng(1)
    b, s, d = 1, 37, 8
    a = rng.uniform(0.5, 0.99, (b, s, d)).astype(np.float32)
    bb = rng.normal(0, 1, (b, s, d)).astype(np.float32)
    ref = rglru_scan_ref(jnp.asarray(a), jnp.asarray(bb))
    h = np.zeros((b, d), np.float32)
    for t in range(s):
        h = a[:, t] * h + bb[:, t]
        np.testing.assert_allclose(np.asarray(ref[:, t]), h, atol=1e-5)


# ---------------------------------------------------------------------------
# ssd chunk
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 128, 2, 32, 32, 64), (2, 256, 4, 64, 128, 128), (1, 64, 1, 16, 64, 32),
])
def test_ssd_chunk_sweep(b, s, h, p, n, chunk):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (b, s, h, p)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (b, s, h)).astype(np.float32))
    al = jnp.asarray(np.log(rng.uniform(1, 8, h)).astype(np.float32))
    bm = jnp.asarray(rng.normal(0, 1, (b, s, n)).astype(np.float32))
    cm = jnp.asarray(rng.normal(0, 1, (b, s, n)).astype(np.float32))
    out = ssd_chunk_pallas(x, dt, al, bm, cm, chunk=chunk, interpret=True)
    ref = ssd_chunk_ref(x, dt, al, bm, cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5,
                               rtol=3e-4)


def test_ssd_chunk_invariance():
    """Chunk size must not change the result (state handoff exactness)."""
    rng = np.random.default_rng(2)
    b, s, h, p, n = 1, 256, 2, 16, 32
    x = jnp.asarray(rng.normal(0, 1, (b, s, h, p)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (b, s, h)).astype(np.float32))
    al = jnp.asarray(np.log(rng.uniform(1, 8, h)).astype(np.float32))
    bm = jnp.asarray(rng.normal(0, 1, (b, s, n)).astype(np.float32))
    cm = jnp.asarray(rng.normal(0, 1, (b, s, n)).astype(np.float32))
    y64 = ssd_chunk_ref(x, dt, al, bm, cm, chunk=64)
    y256 = ssd_chunk_ref(x, dt, al, bm, cm, chunk=256)
    np.testing.assert_allclose(np.asarray(y64), np.asarray(y256), atol=2e-4,
                               rtol=2e-3)


# ---------------------------------------------------------------------------
# link contention (engine hotspot)
# ---------------------------------------------------------------------------

@given(st.integers(1, 12), st.integers(5, 400), st.integers(0, 2 ** 20),
       st.integers(1, 5))
@settings(max_examples=25, deadline=None)
def test_link_contention_property(nseg, k, tmax, seed):
    """Pallas blocked scan == sequential recurrence, exactly, for any sorted
    stream (hypothesis-driven)."""
    rng = np.random.default_rng(seed)
    chan = np.sort(rng.integers(0, nseg, k)).astype(np.int32)
    arrive = rng.integers(0, max(tmax, 1), k).astype(np.int32)
    order = np.lexsort((arrive, chan))
    chan, arrive = chan[order], arrive[order]
    ser = rng.integers(0, 1000, k).astype(np.int32)
    out = segmented_depart(jnp.asarray(chan), jnp.asarray(arrive),
                           jnp.asarray(ser), blk=128, interpret=True)
    ref = segmented_depart_ref(jnp.asarray(chan), jnp.asarray(arrive),
                               jnp.asarray(ser))
    assert np.array_equal(np.asarray(out), np.asarray(ref))


def test_depart_times_int64_rebase():
    rng = np.random.default_rng(3)
    k = 500
    chan = np.sort(rng.integers(0, 7, k)).astype(np.int64)
    arrive = (rng.integers(0, 1 << 20, k) + (7 << 40)).astype(np.int64)
    order = np.lexsort((arrive, chan))
    chan, arrive = chan[order], arrive[order]
    ser = rng.integers(0, 1000, k).astype(np.int64)
    out = depart_times(jnp.asarray(chan), jnp.asarray(arrive),
                       jnp.asarray(ser), impl="interpret")
    ref = depart_times(jnp.asarray(chan), jnp.asarray(arrive),
                       jnp.asarray(ser), impl="ref")
    assert np.array_equal(np.asarray(out), np.asarray(ref))
    assert np.asarray(out).min() >= (7 << 40)


# ---------------------------------------------------------------------------
# serve round (full engine round as a (max,+) affine scan)
# ---------------------------------------------------------------------------

from repro.core.engine import SimOptions, simulate as engine_simulate  # noqa: E402
from repro.core.ref_des import simulate_ref  # noqa: E402
from repro.core.streaming import simulate_stream, stream_windows  # noqa: E402
from repro.kernels.serve_round.kernel import NEG, serve_scan  # noqa: E402
from repro.kernels.serve_round.ref import serve_scan_ref  # noqa: E402


@given(st.integers(8, 500), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=25, deadline=None)
def test_serve_scan_property(k, seed):
    """Pallas Hillis-Steele composition scan == sequential lax.scan oracle,
    exactly, over random streams of the four map shapes the ops wrapper
    emits (head / serving / marker / pass-through).  Arbitrary saturated
    maps are NOT associative in the tropical -inf garbage region; the
    well-formed shapes keep the state non-negative from the head onward,
    which is the kernel's documented contract."""
    rng = np.random.default_rng(seed)

    def pick(hi):
        return rng.integers(0, hi, k).astype(np.int32)

    kind = rng.integers(0, 4, k)
    kind[0] = 0  # stream starts at a segment head
    neg = np.full(k, NEG, np.int32)
    zero = np.zeros(k, np.int32)
    # magnitudes keep the total round span inside the 2**29 contract
    s, gap, r, arr = pick(1 << 16), pick(1 << 16), pick(1 << 16), pick(1 << 20)
    has_r = rng.random(k) < 0.5
    rp = np.where(has_r, r, NEG)
    # serving map (kind 1)
    m00, m01, c0 = gap + s, s, arr + s
    m10 = np.maximum(m00 + rp, NEG)
    m11 = np.maximum(np.maximum(s + rp, 0), NEG)
    c1 = np.maximum(c0 + rp, NEG)
    # marker (kind 2): identity on depart, raise down to arr + r
    m00 = np.where(kind == 2, zero, m00)
    m01 = np.where(kind == 2, neg, m01)
    c0 = np.where(kind == 2, neg, c0)
    m10 = np.where(kind == 2, neg, m10)
    m11 = np.where(kind == 2, zero, m11)
    c1 = np.where(kind == 2, arr + r, c1)
    # pass-through (kind 3): full identity
    m00 = np.where(kind == 3, zero, m00)
    m01 = np.where(kind == 3, neg, m01)
    c0 = np.where(kind == 3, neg, c0)
    m10 = np.where(kind == 3, neg, m10)
    m11 = np.where(kind == 3, zero, m11)
    c1 = np.where(kind == 3, neg, c1)
    # head (kind 0): seed folded into c, incoming state killed
    m00 = np.where(kind == 0, neg, m00)
    m01 = np.where(kind == 0, neg, m01)
    m10 = np.where(kind == 0, neg, m10)
    m11 = np.where(kind == 0, neg, m11)
    c0 = np.where(kind == 0, arr, c0)
    c1 = np.where(kind == 0, arr + np.where(has_r, r, 0), c1)
    args = [jnp.asarray(a) for a in (m00, m01, m10, m11, c0, c1)]
    out = serve_scan(*args, blk=64, interpret=True)
    ref = serve_scan_ref(*args)
    assert np.array_equal(np.asarray(out), np.asarray(ref))


def _engine_case(seed, **kw):
    from test_engine import _random_case
    hops, ch, issue, _ = _random_case(seed, **kw)
    return hops, ch, issue


@pytest.mark.parametrize("seed", range(8))
def test_serve_round_kernel_bitexact_random(seed):
    """simulate(use_kernel='ref') == the lax-scan path == the oracle, on
    random demand configs with rows, turnaround flips and zero-byte hops."""
    hops, ch, issue = _engine_case(seed)
    lax_s = engine_simulate(hops, ch, jnp.asarray(issue))
    ker_s = engine_simulate(hops, ch, jnp.asarray(issue),
                            SimOptions(use_kernel="ref"))
    ref = simulate_ref(hops, ch, issue)
    for f in ("start", "depart", "arrive", "complete"):
        assert np.array_equal(np.asarray(getattr(lax_s, f)),
                              np.asarray(getattr(ker_s, f))), f
    assert np.array_equal(np.asarray(ker_s.complete), ref["complete"])


def test_serve_round_kernel_interpret_mode():
    """The actual Pallas kernel (interpret mode off-TPU) agrees with the
    lax path bit for bit."""
    hops, ch, issue = _engine_case(123)
    lax_s = engine_simulate(hops, ch, jnp.asarray(issue))
    pal_s = engine_simulate(hops, ch, jnp.asarray(issue),
                            SimOptions(use_kernel="interpret"))
    for f in ("start", "depart", "arrive", "complete"):
        assert np.array_equal(np.asarray(getattr(lax_s, f)),
                              np.asarray(getattr(pal_s, f))), f


@pytest.mark.parametrize("ber", [1e-4, 3e-4])
def test_serve_round_kernel_reliability_markers(ber):
    """Stochastic reliability configs: sampled replay bytes, retraining
    down-until clocks and link-down marker rows all flow through the
    (max,+) maps bit-exactly."""
    from test_link_reliability import _stochastic, _wl
    wl = _wl(_stochastic(ber), n=60)
    lax_s = engine_simulate(wl.hops, wl.channels, wl.issue_ps)
    ker_s = engine_simulate(wl.hops, wl.channels, wl.issue_ps,
                            SimOptions(use_kernel="ref"))
    ref = simulate_ref(wl.hops, wl.channels, wl.issue_ps)
    for f in ("start", "depart", "arrive", "complete"):
        assert np.array_equal(np.asarray(getattr(lax_s, f)),
                              np.asarray(getattr(ker_s, f))), f
    assert np.array_equal(np.asarray(ker_s.complete), ref["complete"])


@pytest.mark.parametrize("seed", range(4))
def test_serve_round_kernel_fork_join(seed):
    from test_engine import _join_case
    hops, ch, issue = _join_case(seed)
    lax_s = engine_simulate(hops, ch, jnp.asarray(issue))
    ker_s = engine_simulate(hops, ch, jnp.asarray(issue),
                            SimOptions(use_kernel="ref"))
    for f in ("start", "depart", "arrive", "complete"):
        assert np.array_equal(np.asarray(getattr(lax_s, f)),
                              np.asarray(getattr(ker_s, f))), f


def test_serve_round_kernel_stream_carry():
    """Windowed streaming with warm carries: the kernel path reproduces the
    monolithic lax schedule through every window boundary."""
    from test_engine import _join_case
    hops, ch, issue = _join_case(9)
    mono = engine_simulate(hops, ch, jnp.asarray(issue))
    out = simulate_stream(stream_windows(hops, np.asarray(issue), 6), ch,
                          options=SimOptions(use_kernel="ref"),
                          collect_schedule=True)
    assert out.converged
    col = out.collected
    r = col["item_row"].astype(np.int64)
    k = col["item_hop"].astype(np.int64)
    assert np.array_equal(col["item_depart"], np.asarray(mono.depart)[r, k])
    assert np.array_equal(col["item_start"], np.asarray(mono.start)[r, k])
