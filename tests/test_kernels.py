"""Per-kernel correctness: shape/dtype sweeps, interpret-mode pallas vs the
pure-jnp oracle, plus hypothesis property tests for the engine hotspot."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, st  # optional-hypothesis shim

import repro.core  # noqa: F401  (x64)
from repro.kernels.flash_attention.kernel import flash_attention_gqa
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.link_contention.kernel import segmented_depart
from repro.kernels.link_contention.ops import depart_times
from repro.kernels.link_contention.ref import segmented_depart_ref
from repro.kernels.rglru_scan.kernel import rglru_scan_pallas
from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.kernels.ssd_chunk.kernel import ssd_chunk_pallas
from repro.kernels.ssd_chunk.ref import ssd_chunk_ref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,kv,g,s,d,qb,kb", [
    (1, 2, 2, 256, 64, 128, 128),
    (2, 1, 4, 128, 128, 64, 128),
    (1, 4, 1, 512, 64, 256, 256),
])
def test_flash_attention_sweep(b, kv, g, s, d, qb, kb, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, kv, g, s, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, kv, s, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, kv, s, d), jnp.float32).astype(dtype)
    out = flash_attention_gqa(q, k, v, causal=True, q_blk=qb, kv_blk=kb,
                              interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_flash_attention_windowed():
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (1, 2, 2, 256, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 256, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 256, 64), jnp.float32)
    out = flash_attention_gqa(q, k, v, causal=True, window=64,
                              q_blk=128, kv_blk=128, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


def test_flash_ops_matches_model_layout():
    """The ops wrapper reproduces models.attention.plain_attention."""
    from repro.models.attention import plain_attention
    ks = jax.random.split(jax.random.key(2), 3)
    b, s, h, kvh, d = 2, 128, 8, 2, 64
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kvh, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kvh, d), jnp.float32)
    out = flash_attention(q, k, v, causal=True, impl="interpret",
                          q_blk=64, kv_blk=64)
    ref = plain_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# rglru scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,d,chunk", [(2, 128, 64, 32), (1, 512, 256, 256),
                                         (3, 64, 128, 64)])
def test_rglru_scan_sweep(b, s, d, chunk):
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.uniform(0.8, 0.999, (b, s, d)).astype(np.float32))
    bb = jnp.asarray(rng.normal(0, 0.1, (b, s, d)).astype(np.float32))
    out = rglru_scan_pallas(a, bb, chunk=chunk, d_blk=min(d, 512),
                            interpret=True)
    ref = rglru_scan_ref(a, bb)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5,
                               rtol=1e-5)


def test_rglru_matches_model_block_semantics():
    """Kernel oracle == sequential recurrence (exact per-step check)."""
    rng = np.random.default_rng(1)
    b, s, d = 1, 37, 8
    a = rng.uniform(0.5, 0.99, (b, s, d)).astype(np.float32)
    bb = rng.normal(0, 1, (b, s, d)).astype(np.float32)
    ref = rglru_scan_ref(jnp.asarray(a), jnp.asarray(bb))
    h = np.zeros((b, d), np.float32)
    for t in range(s):
        h = a[:, t] * h + bb[:, t]
        np.testing.assert_allclose(np.asarray(ref[:, t]), h, atol=1e-5)


# ---------------------------------------------------------------------------
# ssd chunk
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 128, 2, 32, 32, 64), (2, 256, 4, 64, 128, 128), (1, 64, 1, 16, 64, 32),
])
def test_ssd_chunk_sweep(b, s, h, p, n, chunk):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (b, s, h, p)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (b, s, h)).astype(np.float32))
    al = jnp.asarray(np.log(rng.uniform(1, 8, h)).astype(np.float32))
    bm = jnp.asarray(rng.normal(0, 1, (b, s, n)).astype(np.float32))
    cm = jnp.asarray(rng.normal(0, 1, (b, s, n)).astype(np.float32))
    out = ssd_chunk_pallas(x, dt, al, bm, cm, chunk=chunk, interpret=True)
    ref = ssd_chunk_ref(x, dt, al, bm, cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5,
                               rtol=3e-4)


def test_ssd_chunk_invariance():
    """Chunk size must not change the result (state handoff exactness)."""
    rng = np.random.default_rng(2)
    b, s, h, p, n = 1, 256, 2, 16, 32
    x = jnp.asarray(rng.normal(0, 1, (b, s, h, p)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (b, s, h)).astype(np.float32))
    al = jnp.asarray(np.log(rng.uniform(1, 8, h)).astype(np.float32))
    bm = jnp.asarray(rng.normal(0, 1, (b, s, n)).astype(np.float32))
    cm = jnp.asarray(rng.normal(0, 1, (b, s, n)).astype(np.float32))
    y64 = ssd_chunk_ref(x, dt, al, bm, cm, chunk=64)
    y256 = ssd_chunk_ref(x, dt, al, bm, cm, chunk=256)
    np.testing.assert_allclose(np.asarray(y64), np.asarray(y256), atol=2e-4,
                               rtol=2e-3)


# ---------------------------------------------------------------------------
# link contention (engine hotspot)
# ---------------------------------------------------------------------------

@given(st.integers(1, 12), st.integers(5, 400), st.integers(0, 2 ** 20),
       st.integers(1, 5))
@settings(max_examples=25, deadline=None)
def test_link_contention_property(nseg, k, tmax, seed):
    """Pallas blocked scan == sequential recurrence, exactly, for any sorted
    stream (hypothesis-driven)."""
    rng = np.random.default_rng(seed)
    chan = np.sort(rng.integers(0, nseg, k)).astype(np.int32)
    arrive = rng.integers(0, max(tmax, 1), k).astype(np.int32)
    order = np.lexsort((arrive, chan))
    chan, arrive = chan[order], arrive[order]
    ser = rng.integers(0, 1000, k).astype(np.int32)
    out = segmented_depart(jnp.asarray(chan), jnp.asarray(arrive),
                           jnp.asarray(ser), blk=128, interpret=True)
    ref = segmented_depart_ref(jnp.asarray(chan), jnp.asarray(arrive),
                               jnp.asarray(ser))
    assert np.array_equal(np.asarray(out), np.asarray(ref))


def test_depart_times_int64_rebase():
    rng = np.random.default_rng(3)
    k = 500
    chan = np.sort(rng.integers(0, 7, k)).astype(np.int64)
    arrive = (rng.integers(0, 1 << 20, k) + (7 << 40)).astype(np.int64)
    order = np.lexsort((arrive, chan))
    chan, arrive = chan[order], arrive[order]
    ser = rng.integers(0, 1000, k).astype(np.int64)
    out = depart_times(jnp.asarray(chan), jnp.asarray(arrive),
                       jnp.asarray(ser), impl="interpret")
    ref = depart_times(jnp.asarray(chan), jnp.asarray(arrive),
                       jnp.asarray(ser), impl="ref")
    assert np.array_equal(np.asarray(out), np.asarray(ref))
    assert np.asarray(out).min() >= (7 << 40)
