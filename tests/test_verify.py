"""Fabric-IR verifier: seeded-invalid fixtures each produce exactly the
typed finding they seed, and every real lowering path verifies clean.

The fixtures are the PR-8 acceptance set: cyclic join graph, arity
mismatch, out-of-range channel index, invalid carry frontier, and a
reliability table claiming more retrain events than ``failures //
retrain_threshold`` admits.  Each corrupts ONE invariant of an otherwise
valid workload, so a finding with any other code is a verifier bug.
"""

import numpy as np
import pytest

import jax.numpy as jnp

import repro.core  # noqa: F401  (x64)
from repro.core import topology as T
from repro.core import verify
from repro.core.coherence_traffic import (CoherenceFabricSpec,
                                          coherence_issue, lower_coherence)
from repro.core.devices import RequesterSpec, build_workload
from repro.core.engine import Channels, Hops, StreamCarry, make_channels
from repro.core.link_layer import FlitConfig
from repro.core.snoop_filter import (CacheConfig, SFConfig,
                                     make_skewed_stream, simulate_sf)
from repro.core.streaming import stream_windows

from _hyp_compat import given, settings, st


# ---------------------------------------------------------------------------
# hand-built fixture: a tiny valid workload the tests then corrupt
# ---------------------------------------------------------------------------

N, H, C = 4, 2, 3


def tiny(**hops_over):
    """4 transactions x 2 hops over 3 channels; verifies clean as-is."""
    hops = Hops(
        channel=jnp.asarray([[0, 1]] * N, jnp.int32),
        nbytes=jnp.asarray([[64, 256]] * N, jnp.int64),
        direction=jnp.zeros((N, H), jnp.int8),
        row=jnp.full((N, H), -1, jnp.int32),
        fixed_after_ps=jnp.full((N, H), 26_000, jnp.int64),
        is_payload=jnp.asarray([[False, True]] * N),
        valid=jnp.ones((N, H), bool),
    )._replace(**hops_over)
    channels = Channels(
        bw_MBps=jnp.full((C,), 64_000, jnp.int64),
        turnaround_ps=jnp.zeros((C,), jnp.int64),
        row_hit_ps=jnp.zeros((C,), jnp.int64),
        row_miss_ps=jnp.zeros((C,), jnp.int64),
    )
    issue = jnp.asarray([0, 1_000, 2_000, 3_000], jnp.int64)
    return hops, channels, issue


def _joins(jid, jwait, jarity):
    return dict(join_id=jnp.asarray(jid, jnp.int32),
                join_wait=jnp.asarray(jwait, jnp.int32),
                join_arity=jnp.asarray(jarity, jnp.int32))


def test_tiny_fixture_is_clean():
    hops, ch, issue = tiny()
    rep = verify.verify_workload(hops, ch, issue)
    assert rep.ok and rep.findings == ()


# ---------------------------------------------------------------------------
# the five seeded-invalid acceptance fixtures
# ---------------------------------------------------------------------------

def test_cyclic_join_graph_flagged():
    # group 0 waits on group 1 and feeds it via its waiter: rows 0,1 feed
    # group 0; row 2 (waiter of 0) feeds group 1; row 3 (waiter of 1)
    # feeds group 0 — a 2-cycle through waiters that deadlocks the oracle.
    hops, ch, issue = tiny(**_joins(
        jid=[0, 1, 1, 0], jwait=[1, 0, -1, -1], jarity=[2, 2, -1, -1]))
    rep = verify.verify_workload(hops, ch, issue)
    assert not rep.ok
    assert set(rep.codes) == {"join.cycle"}


def test_join_arity_mismatch_flagged():
    # group 0 has two contributors but the waiter declares arity 3
    hops, ch, issue = tiny(**_joins(
        jid=[0, 0, -1, -1], jwait=[-1, -1, 0, -1], jarity=[-1, -1, 3, -1]))
    rep = verify.verify_workload(hops, ch, issue)
    assert not rep.ok
    assert set(rep.codes) == {"join.arity"}
    assert any(f.row == 2 for f in rep.findings)


def test_channel_out_of_range_flagged():
    hops, ch, issue = tiny()
    bad = np.asarray(hops.channel).copy()
    bad[2, 1] = C  # one past the last channel
    rep = verify.verify_workload(
        hops._replace(channel=jnp.asarray(bad)), ch, issue)
    assert not rep.ok
    assert set(rep.codes) == {"chan.bounds"}
    f = next(f for f in rep.findings if f.code == "chan.bounds")
    assert (f.row, f.hop) == (2, 1)


def test_invalid_carry_frontier_flagged():
    hops, ch, issue = tiny()
    carry = StreamCarry(
        depart_ps=jnp.asarray([0, -5, 0], jnp.int64),  # negative frontier
        last_dir=jnp.full((C,), -1, jnp.int8),
        last_row=jnp.full((C,), -2, jnp.int32),
        down_until_ps=jnp.zeros((C,), jnp.int64),
    )
    rep = verify.verify_workload(hops, ch, issue, carry=carry)
    assert not rep.ok
    assert set(rep.codes) == {"carry.frontier"}
    assert any(f.channel == 1 for f in rep.findings)


def _rel_tables(flit_size=256, retry_window=2, retrain_threshold=2,
                retrain_ps=1_000_000):
    link = np.asarray([True, True, True])
    return dict(
        stochastic=link.copy(),
        err_p=np.where(link, 1e-4, 0.0),
        flit_size=np.where(link, flit_size, 0).astype(np.int64),
        flit_payload=np.where(link, 250, 0).astype(np.int64),
        retry_window=np.where(link, retry_window, 0).astype(np.int64),
        retrain_threshold=np.where(link, retrain_threshold, 0)
            .astype(np.int64),
        retrain_ps=np.where(link, retrain_ps, 0).astype(np.int64),
        rel_seed=np.zeros(3, np.int64),
    )


def test_reliability_events_exceed_failures_flagged():
    # hop (0,1) carries 2 failures' worth of replay bytes (2 * 256 * 2
    # wire bytes), so with retrain_threshold=2 at most ONE retrain event
    # is admissible — claim two (retrain_after = 2 * retrain_ps).
    extra = np.zeros((N, H), np.int64)
    retrain = np.zeros((N, H), np.int64)
    extra[0, 1] = 2 * 256 * 2
    retrain[0, 1] = 2 * 1_000_000
    hops, ch, issue = tiny(extra_wire_bytes=jnp.asarray(extra),
                           retrain_after_ps=jnp.asarray(retrain))
    rep = verify.verify_workload(hops, ch, issue,
                                 reliability=_rel_tables())
    assert not rep.ok
    assert set(rep.codes) == {"rel.events"}
    f = next(f for f in rep.findings if f.code == "rel.events")
    assert (f.row, f.hop) == (0, 1)

    # sanity: one admissible event verifies clean
    retrain[0, 1] = 1_000_000
    hops2, _, _ = tiny(extra_wire_bytes=jnp.asarray(extra),
                       retrain_after_ps=jnp.asarray(retrain))
    assert verify.verify_workload(hops2, ch, issue,
                                  reliability=_rel_tables()).ok


# ---------------------------------------------------------------------------
# more corruption coverage (one invariant each)
# ---------------------------------------------------------------------------

def test_wrong_index_dtype_flagged():
    hops, ch, issue = tiny()
    rep = verify.verify_workload(
        hops._replace(channel=jnp.asarray(np.asarray(hops.channel),
                                          jnp.int64)),
        ch, issue)
    assert not rep.ok and any(c.startswith("dtype.") for c in rep.codes)


def test_negative_nbytes_flagged():
    hops, ch, issue = tiny()
    nb = np.asarray(hops.nbytes).copy()
    nb[1, 0] = -1
    rep = verify.verify_workload(hops._replace(nbytes=jnp.asarray(nb)),
                                 ch, issue)
    assert not rep.ok and set(rep.codes) == {"hop.negative"}


def test_partial_join_triple_flagged():
    hops, ch, issue = tiny(join_id=jnp.full((N,), -1, jnp.int32))
    rep = verify.verify_workload(hops, ch, issue)
    assert not rep.ok and set(rep.codes) == {"join.partial"}


def test_join_group_id_out_of_row_space_flagged():
    hops, ch, issue = tiny(**_joins(
        jid=[N, 0, -1, -1], jwait=[-1, -1, 0, -1], jarity=[-1, -1, 1, -1]))
    rep = verify.verify_workload(hops, ch, issue)
    assert not rep.ok and "join.bounds" in rep.codes


def test_monotone_issue_opt_in():
    hops, ch, issue = tiny()
    shuffled = jnp.asarray([3_000, 0, 2_000, 1_000], jnp.int64)
    assert verify.verify_workload(hops, ch, shuffled).ok
    rep = verify.verify_workload(hops, ch, shuffled, monotone_issue=True)
    assert not rep.ok and set(rep.codes) == {"issue.monotone"}


def test_assert_valid_raises_with_report():
    hops, ch, issue = tiny()
    bad = np.asarray(hops.channel).copy()
    bad[0, 0] = -1
    with pytest.raises(verify.VerifyError) as ei:
        verify.assert_valid(hops._replace(channel=jnp.asarray(bad)),
                            ch, issue)
    assert "chan.bounds" in ei.value.report.codes


def test_simulate_auto_static_check():
    from repro.core.engine import SimOptions, simulate_auto
    hops, ch, issue = tiny()
    s, used_oracle = simulate_auto(hops, ch, issue,
                                   SimOptions(check="static"))
    assert bool(s.converged) or used_oracle
    bad = np.asarray(hops.channel).copy()
    bad[0, 0] = C + 4
    with pytest.raises(verify.VerifyError):
        simulate_auto(hops._replace(channel=jnp.asarray(bad)), ch, issue,
                      SimOptions(check="static"))


# ---------------------------------------------------------------------------
# every real lowering path verifies clean (property over seeds/shapes)
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**16 - 1), st.sampled_from([50, 173, 400]))
@settings(max_examples=6, deadline=None)
def test_demand_lowering_verifies_clean(seed, n):
    graph = T.single_bus(n_mems=3, bw_MBps=64_000).build()
    spec = RequesterSpec(node=0, n_requests=n, targets=[2, 3, 4],
                         read_ratio=0.5, issue_interval_ps=10_000,
                         payload_bytes=256, seed=seed)
    wl = build_workload(graph, [spec], header_bytes=64, warmup_frac=0.0)
    assert verify.verify_built(wl, graph).ok


@given(st.integers(0, 2**16 - 1))
@settings(max_examples=4, deadline=None)
def test_stochastic_lowering_verifies_clean(seed):
    flit = FlitConfig("flit256", ber=1e-4, reliability="stochastic",
                      rel_seed=seed, retrain_threshold=2,
                      retrain_ps=2_000_000)
    graph = T.with_flit(T.single_bus(n_mems=4, bw_MBps=64_000),
                        flit).build()
    spec = RequesterSpec(node=0, n_requests=400, targets=[2, 3, 4, 5],
                         pattern="uniform", read_ratio=0.5,
                         issue_interval_ps=100, payload_bytes=944,
                         seed=seed)
    wl = build_workload(graph, [spec], header_bytes=64, warmup_frac=0.0)
    assert verify.verify_built(wl, graph).ok


@pytest.mark.parametrize("fanout", ["chain", "concurrent"])
def test_coherence_lowering_verifies_clean(fanout):
    kinds = [T.SWITCH, T.REQUESTER, T.REQUESTER, T.MEMORY]
    links = [T.LinkSpec(i, 0, 64_000, 26_000) for i in (1, 2, 3)]
    graph = T.Topology(np.asarray(kinds, np.int64), links,
                       name="star").build()
    spec = CoherenceFabricSpec(dev_node=3, req_nodes=(1, 2))
    addr, wr, rid = make_skewed_stream(200, 256, write_ratio=0.3,
                                       n_requesters=2, seed=6)
    cfg = SFConfig(capacity=32, policy="fifo", footprint_lines=256)
    _, ev = simulate_sf(addr, wr, rid, cfg, CacheConfig(capacity=32),
                        n_requesters=2, return_events=True)
    low = lower_coherence(graph, spec, cfg, addr, wr, rid, ev,
                          fanout=fanout)
    rep = verify.verify_workload(low.hops, make_channels(graph),
                                 coherence_issue(low, ev.fab_issue_ps),
                                 sf_events=ev, chan_pair=graph.chan_pair)
    assert rep.ok, rep.summary()


def test_stream_windows_verify_clean():
    graph = T.single_bus(n_mems=3, bw_MBps=64_000).build()
    spec = RequesterSpec(node=0, n_requests=300, targets=[2, 3, 4],
                         read_ratio=0.5, issue_interval_ps=20_000,
                         payload_bytes=128, seed=2)
    wl = build_workload(graph, [spec], header_bytes=64, warmup_frac=0.0)
    wins = list(stream_windows(wl.hops, np.asarray(wl.issue_ps), 64))
    assert len(wins) > 1
    for h, issue in wins:
        assert verify.verify_workload(h, wl.channels, issue).ok
