"""Stochastic link reliability: seeded replay sampling + retraining stalls.

Covers the reliability extension of the flit link layer end to end: config
validation, bit-exactness of the default expected-value path, BER-0
stochastic == deterministic, engine-vs-oracle exactness with sampled
replay/retraining tables (both built and randomized), sampling determinism
and seed decorrelation, the sampled mean tying back to the expected-value
``replay_ppm`` model, and the bench acceptance gates.
"""

import numpy as np
import pytest

import jax.numpy as jnp

import repro.core  # noqa: F401  (x64)
from repro.core import topology as T
from repro.core.devices import RequesterSpec, build_workload
from repro.core.engine import Channels, Hops, simulate
from repro.core.link_layer import (FlitConfig, channel_rng, flit_error_prob,
                                   replay_overhead_ppm, retrain_event_prob,
                                   sample_replays)
from repro.core.ref_des import simulate_ref

BUS_BW = 128_000


def _bus_spec(n=150):
    return RequesterSpec(node=0, n_requests=n, targets=[2, 3, 4, 5],
                         read_ratio=0.5, issue_interval_ps=300,
                         payload_bytes=944, seed=3)


def _wl(flit, n=150, **kw):
    topo = T.with_flit(T.single_bus(n_mems=4, bw_MBps=BUS_BW), flit)
    return build_workload(topo.build(), [_bus_spec(n)], warmup_frac=0.0, **kw)


def _stochastic(ber, *, rel_seed=7, retrain_threshold=2,
                retrain_ps=1_000_000, **kw):
    return FlitConfig("flit256", ber=ber, reliability="stochastic",
                      rel_seed=rel_seed, retrain_threshold=retrain_threshold,
                      retrain_ps=retrain_ps, **kw)


# ---------------------------------------------------------------------------
# config + analytic math
# ---------------------------------------------------------------------------

def test_reliability_config_validation():
    with pytest.raises(ValueError, match="reliability"):
        FlitConfig("flit256", reliability="montecarlo")
    with pytest.raises(ValueError, match="retrain_threshold"):
        FlitConfig("flit256", reliability="stochastic", retrain_threshold=-1)
    with pytest.raises(ValueError, match="retrain_ps"):
        FlitConfig("flit256", reliability="stochastic", retrain_ps=-1)
    cfg = _stochastic(1e-6)
    assert cfg.stochastic
    assert cfg.retrain_down_ps == 1_000_000
    assert not FlitConfig("flit256").stochastic        # default: expected
    assert not FlitConfig("none", reliability="stochastic").stochastic
    # default retrain interval comes from calibration
    from repro.core.calibration import LINK_RETRAIN_PS
    assert FlitConfig("flit256", reliability="stochastic").retrain_down_ps \
        == LINK_RETRAIN_PS


def test_retrain_event_prob():
    p = flit_error_prob(1e-5, "flit256")
    assert retrain_event_prob(1e-5, "flit256", 2) == pytest.approx(p ** 2)
    assert retrain_event_prob(1e-5, "flit256", 0) == 0.0
    assert retrain_event_prob(0.0, "flit256", 3) == 0.0
    # high-BER regime: the analytic helper clamps p exactly as the sampler
    # does, so it stays strictly below 1 even when flit_error_prob hits 1.0
    assert retrain_event_prob(0.05, "flit256", 2) < 1.0


def test_sample_replays_mean_matches_expected_model():
    """The sampled Go-Back-N extras average to the expected-value stretch:
    E[extra per flit] = W * p / (1 - p) = replay_ppm / 1e6."""
    ber, W = 3e-5, 16
    p = flit_error_prob(ber, "flit256")
    n_flits = np.full(20_000, 4, np.int64)
    extra, events = sample_replays(n_flits, p, W, 2, channel_rng(0, 0))
    mean_per_flit = extra.sum() / n_flits.sum()
    want = replay_overhead_ppm(ber, "flit256", W) / 1e6
    assert mean_per_flit == pytest.approx(want, rel=0.15)
    # retrain events follow the p**R per-flit probability
    assert events.sum() == pytest.approx(n_flits.sum() * p ** 2, rel=0.5)


def test_extreme_ber_clamped_not_crashing():
    """High-but-accepted BER must sample finite bursts, mirroring the
    expected model's MAX_REPLAY_PPM divergence guard: flit_error_prob
    rounds to exactly 1.0 here, which previously crashed negative_binomial
    with a zero success probability."""
    from repro.core.link_layer import MAX_REPLAY_PPM, PPM

    assert flit_error_prob(0.05, "flit256") == 1.0
    n_flits = np.full(500, 4, np.int64)
    extra, events = sample_replays(n_flits, 1.0, 16, 2, channel_rng(0, 0))
    assert (extra >= 0).all() and (events >= 0).all()
    # per-flit extras stay near the clamp ceiling, never diverge
    assert extra.sum() / n_flits.sum() <= 2 * MAX_REPLAY_PPM / PPM
    # and the whole build + engine==oracle path holds at that BER
    wl = _wl(_stochastic(0.05), n=20)
    sched = simulate(wl.hops, wl.channels, wl.issue_ps)
    ref = simulate_ref(wl.hops, wl.channels, wl.issue_ps)
    assert np.array_equal(np.asarray(sched.complete), ref["complete"])


def test_bench_direct_sampling_matches_build_path():
    """run_tail_sweep samples tables off the shared hop layout instead of
    rebuilding per BER; the streams must equal a real per-BER build (after
    composing the full-duplex retraining-mirror marker insertion the build
    path applies on top of the sampled tables)."""
    from repro.core.link_layer import (apply_retrain_markers,
                                       broadcast_reliability_tables,
                                       sample_hop_tables)

    cfg = _stochastic(3e-4)
    wl = _wl(FlitConfig("flit256"))
    wl_built = _wl(cfg)
    extra, retrain = sample_hop_tables(
        np.asarray(wl.hops.channel), np.asarray(wl.hops.nbytes),
        np.asarray(wl.hops.valid),
        **broadcast_reliability_tables(
            cfg, int(wl.channels.bw_MBps.shape[0]),
            np.asarray(wl.channels.flit_size) > 0))
    graph = T.with_flit(T.single_bus(n_mems=4, bw_MBps=BUS_BW), cfg).build()
    want = apply_retrain_markers(
        wl.hops._replace(extra_wire_bytes=jnp.asarray(extra),
                         retrain_after_ps=jnp.asarray(retrain)),
        graph.chan_pair)
    assert retrain.any()          # events fired -> markers actually inserted
    assert want.channel.shape[1] > np.asarray(wl.hops.channel).shape[1]
    for field in ("channel", "nbytes", "fixed_after_ps", "valid",
                  "extra_wire_bytes", "retrain_after_ps"):
        assert np.array_equal(np.asarray(getattr(wl_built.hops, field)),
                              np.asarray(getattr(want, field))), field


def test_sample_replays_zero_cases():
    extra, events = sample_replays(np.asarray([4, 0, 7]), 0.0, 16, 2,
                                   channel_rng(0, 0))
    assert not extra.any() and not events.any()
    # zero-flit hops never sample even at huge p
    extra, _ = sample_replays(np.asarray([0, 0]), 0.5, 16, 2,
                              channel_rng(0, 0))
    assert not extra.any()


# ---------------------------------------------------------------------------
# expected mode stays bit-exact; BER 0 stochastic == deterministic
# ---------------------------------------------------------------------------

def test_expected_mode_ignores_reliability_knobs_bitexact():
    """reliability="expected" with retrain knobs set is the PR-1 model."""
    wl0 = _wl(FlitConfig("flit256", ber=1e-6))
    wl1 = _wl(FlitConfig("flit256", ber=1e-6, reliability="expected",
                         rel_seed=99, retrain_threshold=4))
    assert wl1.hops.extra_wire_bytes is None
    assert wl1.hops.retrain_after_ps is None
    s0 = simulate(wl0.hops, wl0.channels, wl0.issue_ps)
    s1 = simulate(wl1.hops, wl1.channels, wl1.issue_ps)
    assert np.array_equal(np.asarray(s0.complete), np.asarray(s1.complete))
    assert np.array_equal(np.asarray(s0.start), np.asarray(s1.start))


def test_zero_ber_stochastic_matches_deterministic_exactly():
    wl_e = _wl(FlitConfig("flit256"))
    wl_s = _wl(_stochastic(0.0))
    # sampled tables exist but are all zero
    assert wl_s.hops.extra_wire_bytes is not None
    assert not np.asarray(wl_s.hops.extra_wire_bytes).any()
    assert not np.asarray(wl_s.hops.retrain_after_ps).any()
    s_e = simulate(wl_e.hops, wl_e.channels, wl_e.issue_ps)
    s_s = simulate(wl_s.hops, wl_s.channels, wl_s.issue_ps)
    assert np.array_equal(np.asarray(s_e.complete), np.asarray(s_s.complete))
    assert np.array_equal(np.asarray(s_e.start), np.asarray(s_s.start))


def test_stochastic_lowering_zeroes_replay_ppm():
    g = T.with_flit(T.single_bus(n_mems=2, bw_MBps=BUS_BW),
                    _stochastic(1e-5)).build()
    link = ~np.asarray(g.chan_is_service)
    assert not np.asarray(g.chan_replay_ppm).any()       # sampled instead
    assert np.asarray(g.chan_rel_stochastic)[link].all()
    assert not np.asarray(g.chan_rel_stochastic)[~link].any()
    assert np.allclose(np.asarray(g.chan_flit_err_p)[link],
                       flit_error_prob(1e-5, "flit256"))
    assert (np.asarray(g.chan_retrain_ps)[link] == 1_000_000).all()


# ---------------------------------------------------------------------------
# engine == oracle exactness (the acceptance bar)
# ---------------------------------------------------------------------------

def test_stochastic_engine_matches_oracle_exactly():
    wl = _wl(_stochastic(3e-4), n=200)
    assert np.asarray(wl.hops.extra_wire_bytes).any()    # bursts sampled
    assert np.asarray(wl.hops.retrain_after_ps).any()    # stalls sampled
    sched = simulate(wl.hops, wl.channels, wl.issue_ps)
    ref = simulate_ref(wl.hops, wl.channels, wl.issue_ps)
    assert bool(sched.converged)
    assert np.array_equal(np.asarray(sched.complete), ref["complete"])
    assert np.array_equal(np.asarray(sched.start), ref["start"])
    assert np.array_equal(np.asarray(sched.depart), ref["depart"])


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5, 6, 7])
def test_random_retrain_tables_engine_matches_oracle(seed):
    """Randomized per-hop replay/retraining tables over a mix of byte-exact
    and flit channels — the oracle must agree exactly, including link-down
    intervals on half-duplex and row-managed channels."""
    rng = np.random.default_rng(seed)
    n, h, c = int(rng.integers(3, 24)), int(rng.integers(1, 6)), \
        int(rng.integers(2, 6))
    bw = rng.integers(10, 100, c).astype(np.int64) * 1000
    turn = np.where(rng.random(c) < .5,
                    rng.integers(100, 5000, c), 0).astype(np.int64)
    fsize = rng.choice([0, 68, 256], c).astype(np.int64)
    fpay = np.where(fsize == 68, 64,
                    np.where(fsize == 256, 236, 0)).astype(np.int64)
    ch = Channels(jnp.asarray(bw), jnp.asarray(turn),
                  jnp.asarray(np.zeros(c, np.int64)),
                  jnp.asarray(np.zeros(c, np.int64)),
                  flit_size=jnp.asarray(fsize),
                  flit_payload=jnp.asarray(fpay),
                  replay_ppm=jnp.asarray(np.zeros(c, np.int64)))
    chan = rng.integers(0, c, (n, h)).astype(np.int32)
    nbytes = rng.integers(0, 1200, (n, h)).astype(np.int64)
    valid = rng.random((n, h)) < .85
    extra = np.where(rng.random((n, h)) < .3,
                     rng.integers(0, 8, (n, h)) * 256, 0).astype(np.int64)
    retrain = np.where(rng.random((n, h)) < .2,
                       rng.integers(1, 4, (n, h)) * 100_000, 0).astype(np.int64)
    hops = Hops(jnp.asarray(chan), jnp.asarray(nbytes),
                jnp.asarray(rng.integers(0, 2, (n, h)).astype(np.int8)),
                jnp.asarray(np.full((n, h), -1, np.int32)),
                jnp.asarray(rng.integers(0, 2000, (n, h)).astype(np.int64)),
                jnp.asarray(valid), jnp.asarray(valid),
                extra_wire_bytes=jnp.asarray(extra),
                retrain_after_ps=jnp.asarray(retrain))
    issue = np.sort(rng.integers(0, 5000, n)).astype(np.int64)
    sched = simulate(hops, ch, jnp.asarray(issue))
    ref = simulate_ref(hops, ch, issue)
    assert bool(sched.converged)
    assert np.array_equal(np.asarray(sched.complete), ref["complete"])
    assert np.array_equal(np.asarray(sched.depart)[valid],
                          ref["depart"][valid])


# ---------------------------------------------------------------------------
# sampling determinism, decorrelation, config threading
# ---------------------------------------------------------------------------

def test_sampling_deterministic_per_seed_and_decorrelated_across_seeds():
    a = np.asarray(_wl(_stochastic(3e-4)).hops.extra_wire_bytes)
    b = np.asarray(_wl(_stochastic(3e-4)).hops.extra_wire_bytes)
    assert np.array_equal(a, b)                     # rebuild reproduces
    c = np.asarray(_wl(_stochastic(3e-4, rel_seed=8)).hops.extra_wire_bytes)
    assert not np.array_equal(a, c)                 # new seed, new history
    # per-channel substreams: the two bus directions sample independently
    ch = np.asarray(_wl(_stochastic(3e-4)).hops.channel)
    up = a[(ch == 0) & (a > 0)]
    assert up.size > 0


def test_workload_override_path_matches_graph_path():
    """build_workload(flit=cfg) samples identically to LinkSpec.flit —
    same channel ids, same per-channel streams, same schedule."""
    cfg = _stochastic(3e-4)
    wl_g = _wl(cfg)
    topo = T.single_bus(n_mems=4, bw_MBps=BUS_BW)
    wl_o = build_workload(topo.build(), [_bus_spec(150)], warmup_frac=0.0,
                          flit=cfg)
    assert np.array_equal(np.asarray(wl_g.hops.extra_wire_bytes),
                          np.asarray(wl_o.hops.extra_wire_bytes))
    assert np.array_equal(np.asarray(wl_g.hops.retrain_after_ps),
                          np.asarray(wl_o.hops.retrain_after_ps))
    sg = simulate(wl_g.hops, wl_g.channels, wl_g.issue_ps)
    so = simulate(wl_o.hops, wl_o.channels, wl_o.issue_ps)
    assert np.array_equal(np.asarray(sg.complete), np.asarray(so.complete))


def test_multivcs_threads_stochastic_reliability():
    from repro.core.vcs import MultiVCS

    v = MultiVCS(n_usp=2, devices=2, flit=_stochastic(1e-5))
    topo, _ = v.build_topology()
    g = topo.build()
    link = ~np.asarray(g.chan_is_service)
    assert np.asarray(g.chan_rel_stochastic)[link].all()
    assert (np.asarray(g.chan_retrain_threshold)[link] == 2).all()


# ---------------------------------------------------------------------------
# retraining stalls + bench gates
# ---------------------------------------------------------------------------

def test_retraining_stalls_delay_schedule():
    """Same seeded fault history; enabling retraining must strictly delay
    completion once any event fires (threshold 0 draws identical replay
    totals, so the runs differ only by link-down intervals — after peeling
    the full-duplex mirror markers off the retraining layout)."""
    from repro.core.link_layer import strip_retrain_markers

    wl_off = _wl(_stochastic(3e-4, retrain_threshold=0), n=200)
    wl_on = _wl(_stochastic(3e-4), n=200)
    assert np.array_equal(
        np.asarray(wl_off.hops.extra_wire_bytes),
        np.asarray(strip_retrain_markers(wl_on.hops).extra_wire_bytes))
    assert not np.asarray(wl_off.hops.retrain_after_ps).any()
    assert np.asarray(wl_on.hops.retrain_after_ps).any()
    s_off = simulate(wl_off.hops, wl_off.channels, wl_off.issue_ps)
    s_on = simulate(wl_on.hops, wl_on.channels, wl_on.issue_ps)
    assert int(jnp.max(s_on.complete)) > int(jnp.max(s_off.complete))
    assert bool((s_on.complete >= s_off.complete).all())


def test_bench_zero_ber_equivalence_gate():
    from benchmarks.bench_link_reliability import run_zero_ber_equivalence

    assert run_zero_ber_equivalence(n=300)


def test_bench_tail_divergence_gate():
    """The p99-p50 spread grows with BER in stochastic mode, and at high
    BER it far exceeds the expected-value spread — replay bursts and
    retraining stalls land on unlucky packets, which the deterministic
    uniform stretch structurally cannot express."""
    from benchmarks.bench_link_reliability import run_tail_sweep

    sweep = run_tail_sweep(bers=(0.0, 1e-5, 1e-4), n=600)
    spreads = [r["stochastic_p99_ns"] - r["stochastic_p50_ns"]
               for r in sweep]
    assert spreads[0] < spreads[1] < spreads[2]
    hi = sweep[-1]
    assert hi["stochastic_p99_ns"] - hi["stochastic_p50_ns"] \
        > 2 * (hi["expected_p99_ns"] - hi["expected_p50_ns"])
    # ber-0 rows are the deterministic schedule in both modes
    lo = sweep[0]
    assert lo["stochastic_p99_ns"] == lo["expected_p99_ns"]
    assert lo["stochastic_p50_ns"] == lo["expected_p50_ns"]


def test_bench_retrain_stall_gate():
    from benchmarks.bench_link_reliability import run_retrain_stall

    st = run_retrain_stall(ber=1e-4, n=300)
    assert st["events"] > 0
    assert st["makespan_on_ns"] > st["makespan_off_ns"]
