"""jit-safety lint: every rule fires on a seeded-bad fixture, stays quiet
on the equivalent-but-correct code, the baseline mechanism admits exactly
the committed counts, and the real source tree is clean under the
committed baseline (the CI gate, run in-process)."""

import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis.jitlint import (apply_baseline, lint_paths,
                                    load_baseline)

REPO = Path(__file__).resolve().parent.parent


def _lint_src(tmp_path, source, name="mod.py"):
    p = tmp_path / name
    p.write_text(textwrap.dedent(source))
    return lint_paths([p], repo_root=tmp_path)


BAD = """\
    import jax
    import jax.numpy as jnp
    import numpy as np

    @jax.jit
    def f(x):
        x.at[0].set(1)
        y = jnp.cumsum(x)
        if y > 0:
            y = -y
        v = int(y)
        w = x.item()
        z = np.asarray(y)
        return v + w + z

    def body(c, x):
        q = float(jnp.sum(x))
        return c, q

    def run(xs):
        return jax.lax.scan(body, 0, xs)
"""

GOOD = """\
    import jax
    import jax.numpy as jnp

    @jax.jit
    def f(x, flag=True):
        x = x.at[0].set(1)            # result assigned: fine
        y = jnp.cumsum(x)
        if flag:                      # static python bool: fine
            y = -y
        if x.shape[0] > 2:            # shapes are static: fine
            y = y + 1
        return jnp.where(y > 0, y, -y)

    def host(x):
        return int(jnp.sum(x))        # not jit-reachable: fine
"""


def test_all_rules_fire_on_bad_fixture(tmp_path):
    rules = {f.rule for f in _lint_src(tmp_path, BAD)}
    assert "discarded-at-update" in rules
    assert "traced-truthiness" in rules
    assert "host-sync-in-jit" in rules


def test_bad_fixture_finding_lines(tmp_path):
    fs = _lint_src(tmp_path, BAD)
    by_rule = {}
    for f in fs:
        by_rule.setdefault(f.rule, []).append(f.line)
    assert by_rule["discarded-at-update"] == [7]
    assert by_rule["traced-truthiness"] == [9]
    # int(), .item(), np.asarray() in f; float() reachable via lax.scan
    assert sorted(by_rule["host-sync-in-jit"]) == [11, 12, 13, 17]


def test_good_fixture_is_clean(tmp_path):
    assert _lint_src(tmp_path, GOOD) == []


def test_unreachable_host_code_not_flagged(tmp_path):
    fs = _lint_src(tmp_path, """\
        import numpy as np

        def driver(arrays):
            # plain host-side python: every construct the lint hunts for,
            # but nothing is jit-reachable
            total = int(np.asarray(arrays[0]).sum())
            if total > 0:
                total = float(total)
            return total
    """)
    assert fs == []


def test_np_in_scan_rule_is_module_scoped(tmp_path):
    src = """\
        import jax
        import numpy as np

        def body(c, x):
            y = np.log2(x)
            return c, y

        def run(xs):
            return jax.lax.scan(body, 0, xs)
    """
    # outside the engine/streaming modules: np.log2 is not a sync call,
    # so nothing fires
    assert _lint_src(tmp_path, src) == []
    # under a hot-path module name the same code violates the pure-jnp
    # contract
    pkg = tmp_path / "src" / "repro" / "core"
    pkg.mkdir(parents=True)
    p = pkg / "engine.py"
    p.write_text(textwrap.dedent(src))
    fs = lint_paths([p], repo_root=tmp_path)
    assert [f.rule for f in fs] == ["np-in-scan"]


def test_syntax_error_is_a_finding(tmp_path):
    fs = _lint_src(tmp_path, "def f(:\n")
    assert [f.rule for f in fs] == ["syntax-error"]


# ---------------------------------------------------------------------------
# baseline mechanism
# ---------------------------------------------------------------------------

def _baseline(tmp_path, entries):
    text = ""
    for file, rule, count, reason in entries:
        text += ("[[baseline]]\n"
                 f'file = "{file}"\nrule = "{rule}"\n'
                 f'count = {count}\nreason = "{reason}"\n\n')
    p = tmp_path / "baseline.toml"
    p.write_text(text or "baseline = []\n")
    return p


def test_baseline_admits_committed_counts(tmp_path):
    fs = _lint_src(tmp_path, BAD)
    host = [f for f in fs if f.rule == "host-sync-in-jit"]
    bl = load_baseline(_baseline(tmp_path, [
        ("mod.py", "host-sync-in-jit", len(host), "fixture"),
        ("mod.py", "discarded-at-update", 1, "fixture"),
        ("mod.py", "traced-truthiness", 1, "fixture"),
    ]))
    new, stale = apply_baseline(fs, bl)
    assert new == [] and stale == []


def test_removing_baseline_entry_resurfaces_finding(tmp_path):
    fs = _lint_src(tmp_path, BAD)
    bl = load_baseline(_baseline(tmp_path, [
        ("mod.py", "host-sync-in-jit", 4, "fixture"),
        ("mod.py", "traced-truthiness", 1, "fixture"),
        # discarded-at-update entry removed while the violation remains
    ]))
    new, _ = apply_baseline(fs, bl)
    assert [f.rule for f in new] == ["discarded-at-update"]


def test_exceeding_baseline_count_fails(tmp_path):
    fs = _lint_src(tmp_path, BAD)
    bl = load_baseline(_baseline(tmp_path, [
        ("mod.py", "host-sync-in-jit", 2, "only two admitted"),
        ("mod.py", "discarded-at-update", 1, "fixture"),
        ("mod.py", "traced-truthiness", 1, "fixture"),
    ]))
    new, _ = apply_baseline(fs, bl)
    assert [f.rule for f in new] == ["host-sync-in-jit"] * 2


def test_stale_baseline_entry_warns(tmp_path):
    fs = _lint_src(tmp_path, GOOD)
    bl = load_baseline(_baseline(tmp_path, [
        ("mod.py", "host-sync-in-jit", 3, "no longer true"),
    ]))
    new, stale = apply_baseline(fs, bl)
    assert new == [] and len(stale) == 1


def test_baseline_requires_reason(tmp_path):
    p = tmp_path / "b.toml"
    p.write_text('[[baseline]]\nfile = "x.py"\nrule = "r"\ncount = 1\n')
    with pytest.raises(ValueError, match="reason"):
        load_baseline(p)


# ---------------------------------------------------------------------------
# kernel signature cross-check
# ---------------------------------------------------------------------------

def _kernel_pkg(tmp_path, ref_sig="a, b", kernel_sig="a, b",
                ops_imports=("dummy_pallas", "dummy_ref")):
    pkg = tmp_path / "src" / "repro" / "kernels" / "dummy"
    pkg.mkdir(parents=True)
    (pkg / "ref.py").write_text(f"def dummy_ref({ref_sig}):\n    return a\n")
    (pkg / "kernel.py").write_text(
        f"def dummy_pallas({kernel_sig}):\n    return a\n")
    (pkg / "ops.py").write_text(
        "from .kernel import {}\nfrom .ref import {}\n".format(*ops_imports))
    return list(pkg.glob("*.py"))


def test_kernel_signatures_match_ok(tmp_path):
    fs = lint_paths(_kernel_pkg(tmp_path), repo_root=tmp_path)
    assert fs == []


def test_kernel_signature_mismatch_flagged(tmp_path):
    fs = lint_paths(_kernel_pkg(tmp_path, ref_sig="a, b", kernel_sig="a, c"),
                    repo_root=tmp_path)
    assert [f.rule for f in fs] == ["kernel-signature"]


def test_kernel_ops_must_wrap_entry(tmp_path):
    fs = lint_paths(_kernel_pkg(tmp_path, ops_imports=("dummy_pallas",
                                                       "unrelated")),
                    repo_root=tmp_path)
    assert [f.rule for f in fs] == ["kernel-signature"]


# ---------------------------------------------------------------------------
# the real tree under the committed baseline (the CI gate, in-process)
# ---------------------------------------------------------------------------

def test_repo_src_clean_under_committed_baseline():
    findings = lint_paths([REPO / "src"], repo_root=REPO)
    entries = load_baseline(REPO / "src" / "repro" / "analysis" /
                            "baseline.toml")
    new, _ = apply_baseline(findings, entries)
    assert new == [], "\n".join(map(str, new))


def test_cli_exit_codes(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(textwrap.dedent(BAD))
    env_ok = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "src"],
        cwd=REPO, capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})
    assert env_ok.returncode == 0, env_ok.stdout + env_ok.stderr
    env_bad = subprocess.run(
        [sys.executable, "-m", "repro.analysis", str(bad), "--no-baseline"],
        cwd=REPO, capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})
    assert env_bad.returncode == 1
    assert "discarded-at-update" in env_bad.stdout
