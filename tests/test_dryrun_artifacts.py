"""Dry-run deliverable: artifact integrity + an end-to-end trainer check.

The full 80-cell sweep runs via `python -m repro.launch.dryrun --all` (it owns
the 512-placeholder-device setting, so it cannot run inside this process);
these tests validate its recorded output and exercise the same step-building
machinery end to end at host scale.
"""

import json
import os

import numpy as np
import pytest

import repro.core  # noqa: F401

ARTIFACT = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                        "dryrun.json")


@pytest.mark.skipif(not os.path.exists(ARTIFACT),
                    reason="run `python -m repro.launch.dryrun --all` first")
def test_dryrun_all_cells_green():
    recs = json.load(open(ARTIFACT))
    from repro.configs import ARCH_IDS, SHAPES

    assert len(recs) == len(ARCH_IDS) * len(SHAPES) * 2  # x {single, multi}
    bad = {k: v.get("error") for k, v in recs.items()
           if v.get("status") not in ("ok", "skipped")}
    assert not bad, bad
    # skips are exactly the documented long_500k x full-attention cells
    skips = [k for k, v in recs.items() if v["status"] == "skipped"]
    assert all("long_500k" in k for k in skips)
    assert len(skips) == 16
    # every compiled cell recorded the roofline inputs
    for k, v in recs.items():
        if v["status"] != "ok":
            continue
        assert v["flops_once"] > 0, k
        assert v["memory"]["peak_per_device_gib"] > 0, k
        assert "collectives_once" in v, k
        if v.get("n_periods", 1) > 1:
            assert "period" in v, k


@pytest.mark.skipif(not os.path.exists(ARTIFACT), reason="needs dryrun.json")
def test_multi_pod_cells_use_pod_axis():
    """The 2x16x16 cells must shard over the pod axis: per-device argument
    bytes shrink (or at worst match) vs single-pod for train cells."""
    recs = json.load(open(ARTIFACT))
    checked = 0
    for k, v in recs.items():
        if not k.endswith("|single") or v.get("status") != "ok" \
                or "train_4k" not in k:
            continue
        mk = k.replace("|single", "|multi")
        mv = recs.get(mk)
        if not mv or mv.get("status") != "ok":
            continue
        assert mv["memory"]["argument_bytes"] <= \
            v["memory"]["argument_bytes"] * 1.01, k
        checked += 1
    assert checked >= 8


def test_trainer_end_to_end_loss_drops(tmp_path):
    """Full substrate integration: sharded step + AdamW + checkpoints +
    resume on a host mesh; loss must drop on the Markov source."""
    import jax

    from repro.configs import get_smoke_config
    from repro.data.pipeline import DataConfig, make_source
    from repro.launch.mesh import make_host_mesh
    from repro.runtime.trainer import TrainConfig, Trainer

    cfg = get_smoke_config("llama3-8b")
    tc = TrainConfig(steps=25, peak_lr=1e-2, warmup_steps=5, log_every=100,
                     ckpt_dir=str(tmp_path), ckpt_every=10)
    trainer = Trainer(cfg, tc, make_host_mesh())
    src = make_source("synthetic", DataConfig(vocab=cfg.vocab, seq_len=32,
                                              global_batch=8))
    trainer.fit(src)
    losses = [m["loss"] for m in trainer.metrics_log]
    assert losses[-1] < losses[0] - 0.4, (losses[0], losses[-1])

    # auto-resume picks up from the saved step
    from repro.checkpoint import checkpoint as ckpt

    assert ckpt.latest_step(str(tmp_path)) == 25
    trainer2 = Trainer(cfg, TrainConfig(steps=26, peak_lr=1e-2,
                                        warmup_steps=5, log_every=100,
                                        ckpt_dir=str(tmp_path)),
                       make_host_mesh())
    params, opt = trainer2.init_state()
    params, opt, start = trainer2.maybe_resume(params, opt)
    assert start == 25
