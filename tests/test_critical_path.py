"""Critical-path extraction & bottleneck blame attribution.

The contract under test (`core.critical_path` + the blame threading
through telemetry / streaming / trace export):

  * **conservation** — per request, critical-path edge contributions sum
    *exactly* to ``complete − issue`` (int64 ps), property-tested across
    the random / reliability-marker / fork-join workload families;
  * **pure observer** — extraction replays the engine's scan on host
    copies (`check=True` asserts replayed grants equal the engine's) and
    re-simulation stays bit-identical;
  * **bindings** — hand-built schedules pin each gating family: FCFS
    QUEUE predecessor, retrain ``down_until`` release, fork/join gates;
  * **what-ifs** — `speedup_if` is the identity at ``factor == 1``,
    monotone beyond, and a no-op on unused channels;
  * **streamed == monolithic** — the windowed `StreamTelemetry` blame
    fold and the streamed per-channel peak backlog equal the monolithic
    reductions bit for bit at any window size.
"""

from __future__ import annotations

import numpy as np
import pytest
from _hyp_compat import given, settings, st  # optional-hypothesis shim

import jax.numpy as jnp

import repro.core  # noqa: F401  (x64)
from repro.core import critical_path as cp
from repro.core import telemetry as tm
from repro.core import trace_export as tx
from repro.core.engine import Channels, Hops, simulate
from repro.core.streaming import simulate_stream, stream_windows
from test_streaming import (WINDOWS, _join_case, _random_case,
                            _reliability_case)

CASES = {"random": _random_case, "rel": _reliability_case,
         "join": _join_case}


def _resolve(hops, ch, issue):
    sched = simulate(hops, ch, jnp.asarray(issue))
    assert bool(sched.converged)
    return sched


def _extract(hops, ch, issue):
    sched = _resolve(hops, ch, issue)
    return sched, cp.extract_backpointers(hops, ch, sched, issue)


# ---------------------------------------------------------------------------
# conservation: every path telescopes exactly to complete - issue
# ---------------------------------------------------------------------------

@given(st.integers(0, 10_000), st.sampled_from(sorted(CASES)))
@settings(max_examples=40, deadline=None)
def test_conservation_exact(seed, family):
    hops, ch, issue = CASES[family](seed)
    _, bp = _extract(hops, ch, issue)
    paths = cp.critical_paths(bp)
    bl = cp.blame(bp, paths=paths)       # raises on any violation
    assert bl.total_ps == int(
        (np.asarray(bp.complete) - np.asarray(bp.issue)).sum())
    assert bl.total_ps == int(bl.table.sum())
    for path in paths:
        for e in path:
            assert e.ps >= 0 and e.t_hi >= e.t_lo
            assert 0 <= e.kind < cp.N_KINDS


@pytest.mark.parametrize("family", sorted(CASES))
def test_pure_observer_resimulates_bitexact(family):
    hops, ch, issue = CASES[family](4)
    sched, bp = _extract(hops, ch, issue)  # check=True inside extraction
    again = _resolve(hops, ch, issue)
    for f in ("start", "depart", "arrive", "complete"):
        assert np.array_equal(np.asarray(getattr(sched, f)),
                              np.asarray(getattr(again, f))), f
    # and the extracted times are the schedule's own
    assert np.array_equal(bp.start, np.asarray(sched.start))
    assert np.array_equal(bp.depart, np.asarray(sched.depart))


# ---------------------------------------------------------------------------
# hand-built bindings: one case per gating family
# ---------------------------------------------------------------------------

def _one_chan(turn=0, rh=0, rm=0):
    return Channels(jnp.asarray([1000]), jnp.asarray([turn], jnp.int64),
                    jnp.asarray([rh], jnp.int64), jnp.asarray([rm], jnp.int64))


def _hops_1hop(nbytes, dirn, retrain=None, row=None):
    n = len(nbytes)
    mk = dict(
        channel=jnp.zeros((n, 1), jnp.int32),
        nbytes=jnp.asarray(np.asarray(nbytes, np.int64).reshape(n, 1)),
        direction=jnp.asarray(np.asarray(dirn, np.int8).reshape(n, 1)),
        row=jnp.asarray(np.full((n, 1), -1, np.int32) if row is None
                        else np.asarray(row, np.int32).reshape(n, 1)),
        fixed_after_ps=jnp.zeros((n, 1), jnp.int64),
        is_payload=jnp.ones((n, 1), bool),
        valid=jnp.ones((n, 1), bool),
    )
    if retrain is not None:
        mk["retrain_after_ps"] = jnp.asarray(
            np.asarray(retrain, np.int64).reshape(n, 1))
    return Hops(**mk)


def test_queue_binding_and_edge():
    # row 1 waits for row 0's grant on the shared channel + the direction
    # turnaround; its path must cross to row 0 through a QUEUE edge
    hops = _hops_1hop([1000, 1000], [0, 1])
    ch = _one_chan(turn=700)
    _, bp = _extract(hops, ch, np.asarray([0, 0], np.int64))
    assert bp.bind[1, 0] == cp.B_QUEUE
    assert (bp.qpred_row[1, 0], bp.qpred_hop[1, 0]) == (0, 0)
    path = cp.critical_path(bp, 1)
    kinds = [e.kind for e in path]
    assert cp.K_QUEUE in kinds
    q = next(e for e in path if e.kind == cp.K_QUEUE)
    assert q.ps == 700 and (q.src_row, q.src_hop) == (0, 0)
    # the wait telescopes into the predecessor's serialization
    assert sum(e.ps for e in path if e.kind == cp.K_WIRE) == 2_000_000
    assert cp.path_total(path) == int(bp.complete[1]) - int(bp.issue[1])


def test_retrain_binding_and_edge():
    # row 0's transmission triggers a 500 ns down window; row 1 arrives
    # mid-window, so its grant binds to the retrain release
    hops = _hops_1hop([1000, 1000], [0, 0], retrain=[500_000, 0])
    ch = _one_chan()
    _, bp = _extract(hops, ch, np.asarray([0, 1_200_000], np.int64))
    assert bp.bind[1, 0] == cp.B_RETRAIN
    assert (bp.rsrc_row[1, 0], bp.rsrc_hop[1, 0]) == (0, 0)
    path = cp.critical_path(bp, 1)
    r = next(e for e in path if e.kind == cp.K_RETRAIN)
    assert r.ps == 300_000          # 1.5e6 release - 1.2e6 arrival
    assert cp.path_total(path) == int(bp.complete[1]) - int(bp.issue[1])


def test_join_gate_edge():
    # find a seeded join case whose slowest contributor actually gates a
    # row's critical path (a gated row can still be contention-bound at a
    # later hop, in which case the walk leaves the row before its gate —
    # so scan gated rows until one surfaces the JOIN edge)
    for seed in range(40):
        hops, ch, issue = _join_case(seed)
        _, bp = _extract(hops, ch, issue)
        for r in np.nonzero(bp.gate_row >= 0)[0]:
            r = int(r)
            path = cp.critical_path(bp, r)
            j = next((e for e in path if e.kind == cp.K_JOIN), None)
            if j is None:
                continue
            assert j.row == r and j.hop == -1
            assert j.src_row == int(bp.gate_row[r])
            assert cp.path_total(path) == (int(bp.complete[r])
                                           - int(bp.issue[r]))
            return
    pytest.fail("no seeded join case surfaced a JOIN edge")


# ---------------------------------------------------------------------------
# what-ifs along the frozen backpointer DAG
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", sorted(CASES))
def test_speedup_if_identity_and_monotone(family):
    hops, ch, issue = CASES[family](7)
    _, bp = _extract(hops, ch, issue)
    busiest = int(np.argmax(cp.blame(bp).by_channel()[:-1]))
    base = cp.speedup_if(bp, busiest, 1.0)
    assert int(base["saved_ps"]) == 0
    assert np.array_equal(np.asarray(base["complete_ps"]),
                          np.asarray(base["baseline_complete_ps"]))
    prev = 0
    for factor in (1.5, 2.0, 8.0):
        w = cp.speedup_if(bp, busiest, factor)
        assert (np.asarray(w["complete_ps"])
                <= np.asarray(w["baseline_complete_ps"])).all()
        assert int(w["saved_ps"]) >= prev
        prev = int(w["saved_ps"])


def test_speedup_if_unused_channel_noop():
    hops = _hops_1hop([1000, 1000], [0, 0])
    ch = Channels(jnp.asarray([1000, 1000]), jnp.zeros(2, jnp.int64),
                  jnp.zeros(2, jnp.int64), jnp.zeros(2, jnp.int64))
    _, bp = _extract(hops, ch, np.asarray([0, 0], np.int64))
    w = cp.speedup_if(bp, 1, 16.0)        # nobody transmits on channel 1
    assert int(w["saved_ps"]) == 0


# ---------------------------------------------------------------------------
# aggregation: blame tables + fabric_metrics + trace flows
# ---------------------------------------------------------------------------

def test_blame_table_rollups():
    hops, ch, issue = _reliability_case(5)
    _, bp = _extract(hops, ch, issue)
    bl = cp.blame(bp)
    assert sum(bl.by_kind().values()) == bl.total_ps
    assert int(bl.by_channel().sum()) == bl.total_ps
    top = bl.top(3)
    assert all(a["ps"] >= b["ps"] for a, b in zip(top, top[1:]))
    assert all(t["kind"] in cp.KIND_NAMES and t["ps"] > 0 for t in top)


def test_fabric_metrics_includes_conserving_blame():
    hops, ch, issue = _random_case(9)
    sched = _resolve(hops, ch, issue)
    m = tm.fabric_metrics(hops, ch, sched, jnp.asarray(issue), check=True)
    bl = m["blame"]
    assert int(tm.blame_conservation_residual(bl)) == 0
    assert int(bl.total_ps) == int(
        (np.asarray(sched.complete) - issue).sum())


def test_flow_event_trace_validates():
    for family in sorted(CASES):
        hops, ch, issue = CASES[family](3)
        sched, bp = _extract(hops, ch, issue)
        tr = tx.schedule_trace(hops, ch, sched, flows=bp, blame=cp.blame(bp))
        assert tx.validate_trace(tr) == []
        assert any(e.get("ph") == "s" for e in tr["traceEvents"]), family


# ---------------------------------------------------------------------------
# streamed fold == monolithic blame / peak backlog, any window size
# ---------------------------------------------------------------------------

@given(st.integers(0, 10_000), st.sampled_from(WINDOWS),
       st.sampled_from(sorted(CASES)))
@settings(max_examples=25, deadline=None)
def test_streamed_blame_equals_monolithic(seed, window, family):
    hops, ch, issue = CASES[family](seed)
    sched = _resolve(hops, ch, issue)
    mb = tm.channel_blame(hops, ch, sched, jnp.asarray(issue))
    out = simulate_stream(stream_windows(hops, issue, window), ch)
    sb = out.summary()["blame"]
    for key in ("queue_ps", "retrain_ps", "wire_ps", "row_extra_ps"):
        assert np.array_equal(np.asarray(sb[key]),
                              np.asarray(getattr(mb, key))), (key, window)
    assert int(sb["join_ps"]) == int(mb.join_ps)
    assert int(sb["fixed_ps"]) == int(mb.fixed_ps)


@given(st.integers(0, 10_000), st.sampled_from(WINDOWS),
       st.sampled_from(sorted(CASES)))
@settings(max_examples=25, deadline=None)
def test_streamed_peak_backlog_equals_monolithic(seed, window, family):
    hops, ch, issue = CASES[family](seed)
    sched = _resolve(hops, ch, issue)
    mono = np.asarray(tm.channel_telemetry(hops, ch, sched).peak_backlog)
    out = simulate_stream(stream_windows(hops, issue, window), ch)
    assert np.array_equal(np.asarray(out.summary()["peak_backlog"]), mono)


def test_stream_fixpoint_diagnostics():
    hops, ch, issue = _random_case(2)
    out = simulate_stream(stream_windows(hops, issue, 5), ch)
    s = out.summary()
    assert s["windows_converged"] == out.windows
    assert s["rounds_sum"] >= out.windows >= 1
    assert 1 <= s["rounds_max"] <= s["rounds_sum"]


# ---------------------------------------------------------------------------
# coherence lowering: blamed rows map back to protocol legs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fanout", ["chain", "concurrent"])
def test_leg_blame_conserves(fanout):
    from repro.core.coherence_traffic import (coherence_issue, hop_legs,
                                              leg_blame, lower_coherence)
    from repro.core.engine import make_channels
    from repro.core.snoop_filter import (CacheConfig, SFConfig,
                                         make_skewed_stream, simulate_sf)
    from test_coherence_traffic import star_graph

    graph, spec = star_graph(2)
    addr, wr, rid = make_skewed_stream(100, 128, write_ratio=0.4,
                                       n_requesters=2, seed=7)
    cfg = SFConfig(capacity=16, policy="fifo", footprint_lines=128)
    _, ev = simulate_sf(addr, wr, rid, cfg, CacheConfig(capacity=16),
                        n_requesters=2, return_events=True)
    low = lower_coherence(graph, spec, cfg, addr, wr, rid, ev, fanout=fanout)
    ch = make_channels(graph)
    issue = coherence_issue(low, ev.fab_issue_ps)
    _, bp = _extract(low.hops, ch, issue)
    paths = cp.critical_paths(bp)

    legs = hop_legs(low)
    valid = np.asarray(low.hops.valid)
    nb = np.asarray(low.hops.nbytes)
    ret = (np.asarray(low.hops.retrain_after_ps)
           if low.hops.retrain_after_ps is not None else np.zeros_like(nb))
    marker = valid & (nb == 0) & (ret > 0)
    assert (legs[valid & ~marker] >= 0).all()
    assert (legs[~valid] == -1).all() and (legs[marker] == -1).all()

    lb = leg_blame(low, paths)
    assert sum(lb.values()) == sum(cp.path_total(p) for p in paths)
    assert lb["service"] > 0
