"""Schedule engine: exactness vs the event-driven oracle + conservation laws."""

import numpy as np
import pytest
from _hyp_compat import given, settings, st  # optional-hypothesis shim

import jax.numpy as jnp

import repro.core  # noqa: F401
from repro.core.engine import (Channels, Hops, SimOptions, channel_stats,
                               request_stats, simulate, simulate_auto)
from repro.core.ref_des import simulate_ref


def _random_case(seed, with_rows=True, with_turnaround=True, zero_bytes=True):
    rng = np.random.default_rng(seed)
    n, h, c = int(rng.integers(3, 40)), int(rng.integers(1, 7)), int(rng.integers(1, 6))
    bw = rng.integers(10, 100, c).astype(np.int64) * 1000
    turn = (np.where(rng.random(c) < .5, rng.integers(100, 5000, c), 0)
            if with_turnaround else np.zeros(c)).astype(np.int64)
    rowm = np.zeros(c, bool)
    if with_rows:
        rowm[-1] = True
    ch = Channels(jnp.asarray(bw), jnp.asarray(turn),
                  jnp.asarray(np.where(rowm, 1000, 0).astype(np.int64)),
                  jnp.asarray(np.where(rowm, 9000, 0).astype(np.int64)))
    chan = rng.integers(0, c, (n, h)).astype(np.int32)
    nbytes = rng.integers(1, 300, (n, h)).astype(np.int64)
    if zero_bytes:
        nbytes = np.where(rng.random((n, h)) < 0.2, 0, nbytes)
    dirn = rng.integers(0, 2, (n, h)).astype(np.int8)
    row = np.where((chan == c - 1) & rowm[-1],
                   rng.integers(0, 3, (n, h)), -1).astype(np.int32)
    fixed = rng.integers(0, 2000, (n, h)).astype(np.int64)
    valid = rng.random((n, h)) < .85
    issue = np.sort(rng.integers(0, 5000, n)).astype(np.int64)
    hops = Hops(jnp.asarray(chan), jnp.asarray(nbytes), jnp.asarray(dirn),
                jnp.asarray(row), jnp.asarray(fixed), jnp.asarray(valid),
                jnp.asarray(valid))
    return hops, ch, issue, valid


@given(st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_engine_exact_vs_oracle(seed):
    hops, ch, issue, valid = _random_case(seed)
    sched = simulate(hops, ch, jnp.asarray(issue))
    ref = simulate_ref(hops, ch, issue)
    assert bool(sched.converged)
    assert np.array_equal(np.asarray(sched.complete), ref["complete"])
    assert np.array_equal(np.asarray(sched.depart)[valid],
                          ref["depart"][valid])


def test_simulate_auto_oracle_fallback_matches():
    hops, ch, issue, _ = _random_case(7)
    # force the fallback by allowing a single round
    sched, used_oracle = simulate_auto(hops, ch, jnp.asarray(issue),
                                       SimOptions(max_rounds=1))
    ref = simulate_ref(hops, ch, issue)
    assert np.array_equal(np.asarray(sched.complete), ref["complete"])


def _tight_feedback_case(n=8000, h=8, c=2, seed=2):
    """Tight feedback: everything issued at t=0 onto two half-duplex
    channels with random direction flips — arrivals interleave requests and
    responses so the fixpoint resolves only a few queue positions per round
    and the default ``3*H + 8`` budget is insufficient."""
    rng = np.random.default_rng(seed)
    ch = Channels(jnp.asarray(rng.integers(10, 60, c).astype(np.int64) * 1000),
                  jnp.asarray(rng.integers(500, 5000, c).astype(np.int64)),
                  jnp.asarray(np.zeros(c, np.int64)),
                  jnp.asarray(np.zeros(c, np.int64)))
    chan = rng.integers(0, c, (n, h)).astype(np.int32)
    nb = rng.integers(1, 300, (n, h)).astype(np.int64)
    dirn = rng.integers(0, 2, (n, h)).astype(np.int8)
    fixed = rng.integers(0, 3000, (n, h)).astype(np.int64)
    valid = np.ones((n, h), bool)
    issue = np.zeros(n, np.int64)
    hops = Hops(jnp.asarray(chan), jnp.asarray(nb), jnp.asarray(dirn),
                jnp.asarray(np.full((n, h), -1, np.int32)),
                jnp.asarray(fixed), jnp.asarray(valid), jnp.asarray(valid))
    return hops, ch, issue


def test_simulate_auto_falls_back_on_natural_nonconvergence():
    """The oracle-fallback path under *natural* non-convergence: the default
    round budget genuinely runs out (no forced max_rounds) and simulate_auto
    must return the event-driven oracle's exact schedule."""
    hops, ch, issue = _tight_feedback_case()
    direct = simulate(hops, ch, jnp.asarray(issue))
    assert not bool(direct.converged), "case unexpectedly converged; " \
        "the fallback path is not being exercised"
    sched, used_oracle = simulate_auto(hops, ch, jnp.asarray(issue))
    assert used_oracle
    assert bool(sched.converged)
    ref = simulate_ref(hops, ch, issue)
    assert np.array_equal(np.asarray(sched.complete), ref["complete"])
    assert np.array_equal(np.asarray(sched.start), ref["start"])
    assert np.array_equal(np.asarray(sched.depart), ref["depart"])


# ---------------------------------------------------------------------------
# fork/join primitive: max-of-arrivals joins, engine == oracle bit-exact
# ---------------------------------------------------------------------------

def _join_case(seed, layers=3):
    """Random hop tables + a random layered join DAG: layer k rows feed
    groups that gate layer k+1 rows (contributor arity varies; some rows
    join nothing, one waiter rides an empty group)."""
    rng = np.random.default_rng(seed)
    n, h, c = int(rng.integers(12, 36)), int(rng.integers(2, 5)), int(rng.integers(2, 5))
    bw = rng.integers(10, 100, c).astype(np.int64) * 1000
    ch = Channels(jnp.asarray(bw),
                  jnp.asarray(np.where(rng.random(c) < .4,
                                       rng.integers(100, 4000, c), 0)
                              .astype(np.int64)),
                  jnp.asarray(np.zeros(c, np.int64)),
                  jnp.asarray(np.zeros(c, np.int64)))
    chan = rng.integers(0, c, (n, h)).astype(np.int32)
    nbytes = rng.integers(1, 400, (n, h)).astype(np.int64)
    nbytes = np.where(rng.random((n, h)) < 0.15, 0, nbytes)
    valid = rng.random((n, h)) < .85
    jid = np.full(n, -1, np.int32)
    jwait = np.full(n, -1, np.int32)
    jarity = np.zeros(n, np.int32)
    # split rows into layers; rows of layer k+1 wait on groups fed by
    # random subsets of layer k (strictly layered => DAG)
    bounds = np.sort(rng.choice(np.arange(1, n), layers, replace=False))
    layer_rows = np.split(np.arange(n), bounds)
    grp = 0
    for up, dn in zip(layer_rows[:-1], layer_rows[1:]):
        for w in dn:
            if rng.random() < 0.5:
                members = up[rng.random(up.shape[0]) < 0.5]
                members = members[jid[members] < 0]
                if members.size == 0:
                    continue
                jid[members] = grp
                jwait[w] = grp
                jarity[w] = members.size
                grp += 1
    # one waiter on an empty group: must issue at its own time
    free = np.nonzero(jwait < 0)[0]
    if free.size:
        jwait[free[-1]] = grp
        jarity[free[-1]] = 0
    hops = Hops(jnp.asarray(chan), jnp.asarray(nbytes),
                jnp.asarray(rng.integers(0, 2, (n, h)).astype(np.int8)),
                jnp.asarray(np.full((n, h), -1, np.int32)),
                jnp.asarray(rng.integers(0, 2000, (n, h)).astype(np.int64)),
                jnp.asarray(valid), jnp.asarray(valid),
                join_id=jnp.asarray(jid), join_wait=jnp.asarray(jwait),
                join_arity=jnp.asarray(jarity))
    issue = np.sort(rng.integers(0, 5000, n)).astype(np.int64)
    return hops, ch, issue


@pytest.mark.parametrize("seed", range(10))
def test_fork_join_engine_matches_oracle(seed):
    hops, ch, issue = _join_case(seed)
    sched = simulate(hops, ch, jnp.asarray(issue))
    ref = simulate_ref(hops, ch, issue)
    assert bool(sched.converged)
    assert np.array_equal(np.asarray(sched.complete), ref["complete"])
    assert np.array_equal(np.asarray(sched.start), ref["start"])
    assert np.array_equal(np.asarray(sched.depart), ref["depart"])


def test_join_waits_for_slowest_contributor():
    """Deterministic 3-row fan-in: the waiter issues exactly at the max of
    its contributors' completions (max-of-arrivals semantics)."""
    c = 3
    ch = Channels(jnp.asarray(np.full(c, 1000, np.int64)),
                  jnp.asarray(np.zeros(c, np.int64)),
                  jnp.asarray(np.zeros(c, np.int64)),
                  jnp.asarray(np.zeros(c, np.int64)))
    # rows 0,1 on distinct channels with different service; row 2 waits
    chan = np.array([[0], [1], [2]], np.int32)
    nbytes = np.array([[100], [300], [50]], np.int64)
    fixed = np.array([[7_000], [11_000], [0]], np.int64)
    valid = np.ones((3, 1), bool)
    hops = Hops(jnp.asarray(chan), jnp.asarray(nbytes),
                jnp.asarray(np.zeros((3, 1), np.int8)),
                jnp.asarray(np.full((3, 1), -1, np.int32)),
                jnp.asarray(fixed), jnp.asarray(valid), jnp.asarray(valid),
                join_id=jnp.asarray(np.array([1, 1, -1], np.int32)),
                join_wait=jnp.asarray(np.array([-1, -1, 1], np.int32)),
                join_arity=jnp.asarray(np.array([0, 0, 2], np.int32)))
    issue = jnp.asarray(np.array([0, 0, 0], np.int64))
    sched = simulate(hops, ch, issue)
    assert bool(sched.converged)
    comp = np.asarray(sched.complete)
    # ser = bytes*1e6/1000 MBps: row0 = 100_000+7_000, row1 = 300_000+11_000
    assert comp[0] == 107_000 and comp[1] == 311_000
    a2 = np.asarray(sched.arrive)[2, 0]
    assert a2 == max(comp[0], comp[1])       # slowest BIRsp releases the join
    assert comp[2] == a2 + 50_000
    ref = simulate_ref(hops, ch, np.asarray(issue))
    assert np.array_equal(comp, ref["complete"])


def test_join_cycle_deadlock_raises_in_oracle():
    """Cyclic join groups violate the DAG contract: the oracle detects the
    never-released waiters instead of silently dropping their rows."""
    c = 1
    ch = Channels(jnp.asarray(np.full(c, 1000, np.int64)),
                  jnp.asarray(np.zeros(c, np.int64)),
                  jnp.asarray(np.zeros(c, np.int64)),
                  jnp.asarray(np.zeros(c, np.int64)))
    ones = np.ones((2, 1), bool)
    hops = Hops(jnp.asarray(np.zeros((2, 1), np.int32)),
                jnp.asarray(np.full((2, 1), 10, np.int64)),
                jnp.asarray(np.zeros((2, 1), np.int8)),
                jnp.asarray(np.full((2, 1), -1, np.int32)),
                jnp.asarray(np.zeros((2, 1), np.int64)),
                jnp.asarray(ones), jnp.asarray(ones),
                join_id=jnp.asarray(np.array([0, 1], np.int32)),
                join_wait=jnp.asarray(np.array([1, 0], np.int32)),
                join_arity=jnp.asarray(np.array([1, 1], np.int32)))
    with pytest.raises(RuntimeError, match="join deadlock"):
        simulate_ref(hops, ch, np.zeros(2, np.int64))


def test_join_arity_contract_validated():
    """join_arity must equal the group's actual contributor count — the
    lowering contract the oracle enforces."""
    c = 1
    ch = Channels(jnp.asarray(np.full(c, 1000, np.int64)),
                  jnp.asarray(np.zeros(c, np.int64)),
                  jnp.asarray(np.zeros(c, np.int64)),
                  jnp.asarray(np.zeros(c, np.int64)))
    ones = np.ones((2, 1), bool)
    hops = Hops(jnp.asarray(np.zeros((2, 1), np.int32)),
                jnp.asarray(np.full((2, 1), 10, np.int64)),
                jnp.asarray(np.zeros((2, 1), np.int8)),
                jnp.asarray(np.full((2, 1), -1, np.int32)),
                jnp.asarray(np.zeros((2, 1), np.int64)),
                jnp.asarray(ones), jnp.asarray(ones),
                join_id=jnp.asarray(np.array([0, -1], np.int32)),
                join_wait=jnp.asarray(np.array([-1, 0], np.int32)),
                join_arity=jnp.asarray(np.array([0, 2], np.int32)))
    with pytest.raises(ValueError, match="join_arity"):
        simulate_ref(hops, ch, np.zeros(2, np.int64))


def test_channel_conservation():
    """No channel is busy more than wall-clock; payload time <= busy time."""
    hops, ch, issue, _ = _random_case(3)
    sched = simulate(hops, ch, jnp.asarray(issue))
    stats = channel_stats(hops, sched, ch)
    assert float(jnp.max(stats["utility"])) <= 1.0 + 1e-9
    assert np.all(np.asarray(stats["payload_ps"])
                  <= np.asarray(stats["busy_ps"]))


def test_latency_positive_and_fcfs_order():
    hops, ch, issue, valid = _random_case(11)
    sched = simulate(hops, ch, jnp.asarray(issue))
    r = request_stats(hops, sched, jnp.asarray(issue),
                      jnp.asarray(np.full(len(issue), 64)),
                      jnp.asarray(np.ones(len(issue), bool)))
    lat = np.asarray(r["latency_ps"])
    assert (lat >= 0).all()
    # starts never precede arrivals
    st_ = np.asarray(sched.start)[valid]
    ar = np.asarray(sched.arrive)[:, :-1][valid]
    assert (st_ >= ar).all()
