"""Per-architecture smoke tests: reduced same-family configs run a real
forward + train step (and a prefill->decode handoff) on CPU, asserting output
shapes and the absence of NaNs.  The FULL configs are exercised only via the
dry-run (launch/dryrun.py, ShapeDtypeStruct — no allocation)."""

import jax
import jax.numpy as jnp
import pytest

import repro.core  # noqa: F401  (x64 on; models are dtype-explicit)
from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import transformer as TF
from repro.models.layers import DTYPE

B, S = 2, 32


def _batch(cfg, key):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.vision_patches:
        batch["frontend_embeds"] = jnp.ones(
            (B, cfg.vision_patches, cfg.d_model), DTYPE) * 0.01
    if cfg.enc_layers:
        batch["frontend_embeds"] = jnp.ones(
            (B, cfg.enc_frames, cfg.d_model), DTYPE) * 0.01
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    params = TF.init_params(cfg, jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))

    logits, _, aux = jax.jit(
        lambda p, b: TF.forward(p, cfg, b["tokens"], mode="train",
                                frontend_embeds=b.get("frontend_embeds"))
    )(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())

    def train_step(p, b):
        (loss, metrics), grads = jax.value_and_grad(
            lambda q: TF.loss_fn(q, cfg, b), has_aux=True)(p)
        new = jax.tree.map(lambda a, g: a - 0.01 * g.astype(a.dtype), p, grads)
        return loss, new

    loss, new_params = jax.jit(train_step)(params, batch)
    assert jnp.isfinite(loss)
    # parameters actually move
    delta = jax.tree.map(
        lambda a, b_: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                            - b_.astype(jnp.float32)))),
        params, new_params)
    assert max(jax.tree.leaves(delta)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode_consistency(arch):
    """Greedy decode after prefill matches teacher-forced forward logits."""
    cfg = get_smoke_config(arch)
    params = TF.init_params(cfg, jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))
    toks = batch["tokens"]
    fe = batch.get("frontend_embeds")

    full_logits, _, _ = jax.jit(
        lambda p, t: TF.forward(p, cfg, t, mode="train", frontend_embeds=fe)
    )(params, toks)

    cut = S // 2
    pre_logits, cache = jax.jit(lambda p, t: TF.prefill(
        p, cfg, t, max_len=S + 8, frontend_embeds=fe))(params, toks[:, :cut])
    # prefill last-token logits == forward logits at position cut-1
    assert jnp.allclose(pre_logits[:, 0].astype(jnp.float32),
                        full_logits[:, cut - 1].astype(jnp.float32),
                        atol=5e-2, rtol=5e-2), arch

    # one decode step with the true next token matches position `cut`
    step = jax.jit(lambda p, c, t, q: TF.decode_step(p, cfg, c, t, q))
    logits, cache = step(params, cache, toks[:, cut:cut + 1],
                         jnp.full((B, 1), cut, jnp.int32))
    assert jnp.allclose(logits[:, 0].astype(jnp.float32),
                        full_logits[:, cut].astype(jnp.float32),
                        atol=5e-2, rtol=5e-2), arch
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


def test_full_configs_match_assignment():
    """The FULL configs carry the exact published dimensions."""
    spec = {
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
        "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "mamba2-1.3b": (48, 2048, 1, 1, 0, 50280),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
    }
    for arch, (L, d, h, kv, ff, v) in spec.items():
        c = get_config(arch)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) \
            == (L, d, h, kv, ff, v), arch
    assert get_config("mamba2-1.3b").ssm_state == 128
    assert get_config("qwen3-moe-30b-a3b").moe.n_experts == 128
    assert get_config("qwen3-moe-30b-a3b").moe.top_k == 8
    assert get_config("grok-1-314b").moe.n_experts == 8
    assert get_config("grok-1-314b").moe.top_k == 2
