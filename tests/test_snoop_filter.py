"""DCOH / inclusive snoop filter: coherence invariants + paper orderings."""

import numpy as np
import pytest
from _hyp_compat import given, settings, st  # optional-hypothesis shim

import jax.numpy as jnp

import repro.core  # noqa: F401
from repro.core.snoop_filter import (CacheConfig, SFConfig, make_skewed_stream,
                                     simulate_sf)


def _run(policy="fifo", n=2000, footprint=512, invblk=1, n_req=1,
         write_ratio=0.1, seed=0, bus=0):
    cap = int(0.2 * footprint)
    addr, wr, rid = make_skewed_stream(n, footprint, write_ratio=write_ratio,
                                       n_requesters=n_req, seed=seed)
    cfg = SFConfig(capacity=cap, policy=policy, invblk_max=invblk,
                   footprint_lines=footprint, bus_MBps=bus)
    return simulate_sf(addr, wr, rid, cfg, CacheConfig(capacity=cap),
                       n_requesters=n_req)


@given(st.sampled_from(["fifo", "lru", "lifo", "mru", "lfi"]),
       st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_inclusivity_invariant(policy, seed):
    """Every line in a requester's cache has a live SF entry listing it as an
    owner (the *inclusive* property the CXL spec mandates for the DCOH)."""
    res = _run(policy=policy, n=1500, seed=seed, n_req=2)
    sf_tags = np.asarray(res.final_sf_tag)
    sf_owner = np.asarray(res.final_sf_owner)
    cache = np.asarray(res.final_cache_tag)
    for r in range(cache.shape[0]):
        lines = set(int(a) for a in cache[r] if a >= 0)
        owned = set(int(t) for t, o in zip(sf_tags, sf_owner)
                    if t >= 0 and (int(o) >> r) & 1)
        missing = lines - owned
        assert not missing, (policy, r, missing)


def test_sf_never_exceeds_capacity_and_unique_tags():
    res = _run(policy="lifo", n=3000)
    tags = np.asarray(res.final_sf_tag)
    live = tags[tags >= 0]
    assert len(live) <= len(tags)
    assert len(np.unique(live)) == len(live)


def test_policy_ordering_matches_paper():
    """Fig. 14 ordering: LIFO/MRU >= LFI >= FIFO~LRU on the skewed stream."""
    out = {p: _run(policy=p, n=6000, footprint=1024) for p in
           ("fifo", "lru", "lfi", "lifo", "mru")}
    bw = {p: float(r.bandwidth_MBps) for p, r in out.items()}
    inval = {p: int(r.bisnp_events) for p, r in out.items()}
    assert bw["lifo"] >= bw["fifo"]
    assert bw["mru"] >= bw["lru"]
    assert inval["lifo"] <= inval["fifo"]
    assert inval["lfi"] <= inval["fifo"]
    assert abs(bw["fifo"] - bw["lru"]) / bw["fifo"] < 0.05  # behave alike
    assert abs(bw["lifo"] - bw["mru"]) / bw["lifo"] < 0.05


def test_invblk_len2_improves_and_clears_more_lines_per_bisnp():
    from repro.core.snoop_filter import make_sequential_stream

    def run_len(L):
        footprint = 1024
        cap = int(0.2 * footprint)
        addr, wr, rid = make_sequential_stream(6000, footprint,
                                               n_requesters=2,
                                               write_ratio=0.5, seed=5)
        cfg = SFConfig(capacity=cap, policy="blp", invblk_max=L,
                       footprint_lines=footprint, bus_MBps=12_000,
                       writeback_ps=30_000)
        return simulate_sf(addr, wr, rid, cfg, CacheConfig(capacity=cap),
                           n_requesters=2)

    r1, r2 = run_len(1), run_len(2)
    assert int(r2.bisnp_events) < int(r1.bisnp_events)
    assert float(r2.bandwidth_MBps) >= float(r1.bandwidth_MBps)
    # lines cleared per BISnp grows with InvBlk
    lpb1 = int(r1.invalidated_lines) / max(int(r1.bisnp_events), 1)
    lpb2 = int(r2.invalidated_lines) / max(int(r2.bisnp_events), 1)
    assert lpb2 > lpb1
