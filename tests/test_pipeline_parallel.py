"""Pipeline parallelism: shard_map schedule == sequential stage application.

Runs in a subprocess with 4 host placeholder devices so the main test process
keeps the single real CPU device (the dry-run owns the 512-device setting).
"""

import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
import numpy as np
from repro.jax_compat import AxisType, make_mesh
from repro.parallel.pipeline_par import pipeline_forward

mesh = make_mesh((4,), ("stage",), axis_types=(AxisType.Auto,))
S, D = 4, 16
rng = np.random.default_rng(0)
ws = jnp.asarray(rng.normal(0, 0.5, (S, D, D)).astype(np.float32))

def stage_fn(w, x):
    return jnp.tanh(x @ w)

x = jnp.asarray(rng.normal(0, 1, (8, D)).astype(np.float32))
out = pipeline_forward(stage_fn, ws, x, mesh=mesh, n_microbatches=4)

ref = x
for s in range(S):
    ref = stage_fn(ws[s], ref)
err = float(jnp.abs(out - ref).max())
assert err < 1e-5, err
print("PIPELINE_OK", err)
"""


def test_pipeline_matches_sequential():
    import os

    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=300, env=env)
    assert "PIPELINE_OK" in r.stdout, r.stdout + r.stderr
