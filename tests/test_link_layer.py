"""PCIe 6.0 FLIT link layer: packing, FEC/CRC retry, credits, integration.

Covers the link_layer lowering contract end to end: config validation and
analytic math, engine-vs-oracle exactness on flit channels, bit-exactness of
the ``flit_mode="none"`` path against the seed layout, vmapped BER sweeps,
the flit_pack kernel, and the acceptance gates of bench_link_layer.
"""

import numpy as np
import pytest
from _hyp_compat import given, settings, st  # optional-hypothesis shim

import jax
import jax.numpy as jnp

import repro.core  # noqa: F401  (x64)
from repro.core import topology as T
from repro.core.devices import RequesterSpec, build_workload
from repro.core.engine import (Channels, Hops, make_channels, simulate,
                               wire_ser_ps)
from repro.core.link_layer import (FLIT_GEOMETRY, FlitConfig,
                                   credit_limited_MBps, flit_efficiency,
                                   flit_error_prob, goodput_efficiency,
                                   lower_link, replay_overhead_ppm,
                                   wire_bytes)
from repro.core.ref_des import simulate_ref


# ---------------------------------------------------------------------------
# config + analytic math
# ---------------------------------------------------------------------------

def test_flit_config_validation():
    with pytest.raises(ValueError):
        FlitConfig("flit512")
    with pytest.raises(ValueError):
        FlitConfig("flit256", ber=1.0)
    with pytest.raises(ValueError):
        FlitConfig("flit256", rx_credits=0)
    assert not FlitConfig("none").active
    assert FlitConfig("flit256").fec_latency_ps > 0
    assert FlitConfig("flit68").fec_latency_ps == 0  # no FEC before PCIe 6


def test_wire_bytes_quantization():
    assert wire_bytes(1, "flit256") == 256
    assert wire_bytes(236, "flit256") == 256
    assert wire_bytes(237, "flit256") == 512
    assert wire_bytes(944, "flit256") == 4 * 256   # 4 fully packed flits
    assert wire_bytes(64, "flit68") == 68
    assert wire_bytes(65, "flit68") == 2 * 68
    assert wire_bytes(12345, "none") == 12345
    np.testing.assert_array_equal(
        wire_bytes(np.array([1, 236, 237]), "flit256"), [256, 256, 512])


def test_flit_efficiency_analytic():
    assert flit_efficiency("flit256") == 236 / 256
    assert flit_efficiency("flit68") == 64 / 68
    assert flit_efficiency("none") == 1.0


def test_replay_ppm_monotone_in_ber():
    ppms = [replay_overhead_ppm(b, "flit256")
            for b in (0.0, 1e-9, 1e-8, 1e-7, 1e-6, 1e-5)]
    assert ppms[0] == 0
    assert all(a < b for a, b in zip(ppms, ppms[1:]))
    # goodput efficiency falls accordingly
    effs = [goodput_efficiency("flit256", b) for b in (0.0, 1e-7, 1e-5)]
    assert effs[0] == flit_efficiency("flit256")
    assert effs[0] > effs[1] > effs[2]


def test_replay_ppm_clamped_at_extreme_ber():
    """High-but-accepted BER must not overflow downstream integer tables:
    ppm is clamped at MAX_REPLAY_PPM (fits int32; engine int64 product
    stays in range), schedules stay finite, and the oracle still agrees."""
    from repro.core.link_layer import MAX_REPLAY_PPM

    assert replay_overhead_ppm(0.01, "flit256") == MAX_REPLAY_PPM
    assert replay_overhead_ppm(0.5, "flit68") == MAX_REPLAY_PPM
    assert MAX_REPLAY_PPM < 2 ** 31  # int32 kernel tables hold it

    g = T.with_flit(T.single_bus(n_mems=2, bw_MBps=128_000),
                    FlitConfig("flit256", ber=0.01)).build()
    wl = build_workload(g, [RequesterSpec(node=0, n_requests=6, targets=[2, 3],
                                          payload_bytes=944)],
                        warmup_frac=0.0)
    sched = simulate(wl.hops, wl.channels, wl.issue_ps)
    ref = simulate_ref(wl.hops, wl.channels, wl.issue_ps)
    assert np.array_equal(np.asarray(sched.complete), ref["complete"])
    assert int(jnp.max(sched.complete)) > 0

    # the kernel path accepts the same extreme config without overflow
    from repro.kernels.flit_pack.ops import flit_sweep
    grid = np.asarray(flit_sweep(np.asarray([236]), ["flit256"],
                                 (0.0, 3e-3, 0.01), impl="ref"))
    assert (np.diff(grid, axis=1) <= 0).all()


def test_wire_ser_ps_no_overflow_at_clamp_with_long_serialization():
    """A 1 GB transfer with replay_ppm at the clamp previously wrapped int64
    (fser * (1e6 + 1e9)); the decomposed stretch must equal the
    arbitrary-precision formula and stay positive."""
    from repro.core.link_layer import MAX_REPLAY_PPM

    ch = Channels(jnp.asarray(np.array([64_000], np.int64)),
                  jnp.zeros(1, jnp.int64), jnp.zeros(1, jnp.int64),
                  jnp.zeros(1, jnp.int64),
                  flit_size=jnp.asarray(np.array([256], np.int64)),
                  flit_payload=jnp.asarray(np.array([236], np.int64)),
                  replay_ppm=jnp.asarray(np.array([MAX_REPLAY_PPM], np.int64)))
    nb = 1_000_000_000
    got = int(wire_ser_ps(jnp.asarray(np.array([nb], np.int64)), ch,
                          jnp.asarray(np.array([0], np.int32)))[0])
    wire = -(-nb // 236) * 256
    want = (wire * 1_000_000 // 64_000) * (1_000_000 + MAX_REPLAY_PPM) \
        // 1_000_000  # python bigints: exact
    assert got == want > 0


def test_flit_error_prob_geometry():
    # one flit of 256 B = 2048 bits; small-ber limit p ~= bits * ber
    p = flit_error_prob(1e-9, "flit256")
    assert p == pytest.approx(2048e-9, rel=1e-3)
    assert flit_error_prob(0.0, "flit256") == 0.0
    assert flit_error_prob(1e-9, "none") == 0.0


def test_credit_limited_bandwidth():
    deep = FlitConfig("flit256", rx_credits=256)
    assert credit_limited_MBps(128_000, deep) == 128_000
    shallow = FlitConfig("flit256", rx_credits=16, credit_rtt_ps=100_000)
    # 16 flits * 256 B per 100 ns = 40.96 GB/s
    assert credit_limited_MBps(128_000, shallow) == 40_960
    caps = [credit_limited_MBps(128_000, FlitConfig("flit256", rx_credits=c))
            for c in (4, 8, 16, 32, 64)]
    assert all(a <= b for a, b in zip(caps, caps[1:]))


def test_lower_link_none_is_identity():
    low = lower_link(63_000, None)
    assert (low.eff_bw_MBps, low.extra_fixed_ps, low.flit_size,
            low.flit_payload, low.replay_ppm) == (63_000, 0, 0, 0, 0)


# ---------------------------------------------------------------------------
# engine + oracle exactness on flit channels
# ---------------------------------------------------------------------------

def _random_flit_case(seed):
    """Random hop tables over a mix of byte-exact / flit68 / flit256
    channels with random replay overheads — the oracle must agree exactly."""
    rng = np.random.default_rng(seed)
    n, h, c = int(rng.integers(3, 30)), int(rng.integers(1, 6)), int(rng.integers(2, 6))
    bw = rng.integers(10, 100, c).astype(np.int64) * 1000
    turn = np.where(rng.random(c) < .5, rng.integers(100, 5000, c), 0).astype(np.int64)
    fsize = rng.choice([0, 68, 256], c).astype(np.int64)
    fpay = np.where(fsize == 68, 64, np.where(fsize == 256, 236, 0)).astype(np.int64)
    ppm = np.where(fsize > 0, rng.integers(0, 300_000, c), 0).astype(np.int64)
    ch = Channels(jnp.asarray(bw), jnp.asarray(turn),
                  jnp.asarray(np.zeros(c, np.int64)),
                  jnp.asarray(np.zeros(c, np.int64)),
                  flit_size=jnp.asarray(fsize),
                  flit_payload=jnp.asarray(fpay),
                  replay_ppm=jnp.asarray(ppm))
    chan = rng.integers(0, c, (n, h)).astype(np.int32)
    nbytes = rng.integers(0, 1200, (n, h)).astype(np.int64)
    dirn = rng.integers(0, 2, (n, h)).astype(np.int8)
    fixed = rng.integers(0, 2000, (n, h)).astype(np.int64)
    valid = rng.random((n, h)) < .85
    issue = np.sort(rng.integers(0, 5000, n)).astype(np.int64)
    hops = Hops(jnp.asarray(chan), jnp.asarray(nbytes), jnp.asarray(dirn),
                jnp.asarray(np.full((n, h), -1, np.int32)),
                jnp.asarray(fixed), jnp.asarray(valid), jnp.asarray(valid))
    return hops, ch, issue, valid


@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_flit_engine_exact_vs_oracle(seed):
    hops, ch, issue, valid = _random_flit_case(seed)
    sched = simulate(hops, ch, jnp.asarray(issue))
    ref = simulate_ref(hops, ch, issue)
    assert bool(sched.converged)
    assert np.array_equal(np.asarray(sched.complete), ref["complete"])
    assert np.array_equal(np.asarray(sched.depart)[valid], ref["depart"][valid])


def test_wire_ser_ps_flit_semantics():
    ch = Channels(jnp.asarray(np.array([64_000, 64_000], np.int64)),
                  jnp.zeros(2, jnp.int64), jnp.zeros(2, jnp.int64),
                  jnp.zeros(2, jnp.int64),
                  flit_size=jnp.asarray(np.array([0, 256], np.int64)),
                  flit_payload=jnp.asarray(np.array([0, 236], np.int64)),
                  replay_ppm=jnp.asarray(np.array([0, 500_000], np.int64)))
    nb = jnp.asarray(np.array([944, 944], np.int64))
    idx = jnp.asarray(np.array([0, 1], np.int32))
    ser = np.asarray(wire_ser_ps(nb, ch, idx))
    assert ser[0] == 944 * 1_000_000 // 64_000          # byte-exact channel
    base = (4 * 256) * 1_000_000 // 64_000              # 4 flits on the wire
    assert ser[1] == base * 1_500_000 // 1_000_000      # +50% replay


# ---------------------------------------------------------------------------
# flit_mode="none" bit-exactness + integration paths
# ---------------------------------------------------------------------------

def _bus_spec(n=120):
    return RequesterSpec(node=0, n_requests=n, targets=[2, 3, 4, 5],
                         read_ratio=0.5, issue_interval_ps=300,
                         payload_bytes=944, seed=3)


def test_flit_none_reproduces_seed_schedule_bitexact():
    topo = T.single_bus(n_mems=4, bw_MBps=64_000)
    wl_seed = build_workload(topo.build(), [_bus_spec()], warmup_frac=0.0)
    # seed layout: no flit tables at all
    assert wl_seed.channels.flit_size is None
    # graph-level "none" and workload-level None lower to the same layout
    wl_none = build_workload(T.with_flit(topo, "none").build(), [_bus_spec()],
                             warmup_frac=0.0, flit=None)
    assert wl_none.channels.flit_size is None
    s0 = simulate(wl_seed.hops, wl_seed.channels, wl_seed.issue_ps)
    s1 = simulate(wl_none.hops, wl_none.channels, wl_none.issue_ps)
    assert np.array_equal(np.asarray(s0.complete), np.asarray(s1.complete))
    assert np.array_equal(np.asarray(s0.start), np.asarray(s1.start))


def test_graph_and_override_paths_agree():
    """LinkSpec.flit at graph build == build_workload(flit=...) override."""
    cfg = FlitConfig("flit256", ber=1e-6)
    topo = T.single_bus(n_mems=4, bw_MBps=128_000)
    wl_g = build_workload(T.with_flit(topo, cfg).build(), [_bus_spec()],
                          warmup_frac=0.0)
    wl_o = build_workload(topo.build(), [_bus_spec()], warmup_frac=0.0,
                          flit=cfg)
    sg = simulate(wl_g.hops, wl_g.channels, wl_g.issue_ps)
    so = simulate(wl_o.hops, wl_o.channels, wl_o.issue_ps)
    assert np.array_equal(np.asarray(sg.complete), np.asarray(so.complete))


def test_override_on_flit_graph_raises():
    g = T.with_flit(T.single_bus(n_mems=2), "flit256").build()
    spec = RequesterSpec(node=0, n_requests=4, targets=[2, 3])
    with pytest.raises(ValueError, match="rebuild the topology"):
        build_workload(g, [spec], flit="flit68")
    # an explicit "none" must not silently leave the graph's flit tables
    # installed (A/B-baseline hazard) — it raises the same way
    with pytest.raises(ValueError, match="rebuild the topology"):
        build_workload(g, [spec], flit="none")
    # None defers to the graph config: fine
    build_workload(g, [spec])


def test_service_channels_stay_byte_exact():
    g = T.with_flit(T.single_bus(n_mems=2), "flit256").build()
    svc = np.asarray(g.chan_is_service)
    assert np.all(np.asarray(g.chan_flit_size)[svc] == 0)
    assert np.all(np.asarray(g.chan_flit_size)[~svc] == 256)


def test_flit_slows_and_fec_adds_latency():
    topo = T.single_bus(n_mems=4, bw_MBps=64_000)
    wl0 = build_workload(topo.build(), [_bus_spec()], warmup_frac=0.0)
    wl1 = build_workload(T.with_flit(topo, "flit256").build(), [_bus_spec()],
                         warmup_frac=0.0)
    s0 = simulate(wl0.hops, wl0.channels, wl0.issue_ps)
    s1 = simulate(wl1.hops, wl1.channels, wl1.issue_ps)
    # flit CRC/FEC overhead + FEC decode latency strictly slow completion
    assert int(jnp.max(s1.complete)) > int(jnp.max(s0.complete))
    # FEC latency lands in fixed_after on link hops
    assert np.all(np.asarray(wl1.hops.fixed_after_ps[:, 0])
                  > np.asarray(wl0.hops.fixed_after_ps[:, 0]))


def test_multivcs_flit_passthrough():
    from repro.core.vcs import MultiVCS

    v = MultiVCS(n_usp=2, devices=2, flit="flit256")
    topo, _ = v.build_topology()
    g = topo.build()
    link = ~np.asarray(g.chan_is_service)
    assert np.all(np.asarray(g.chan_flit_size)[link] == 256)


def test_vmapped_ber_sweep_monotone_one_jit():
    """BER sweeps vmap over the replay_ppm channel table: no hop rebuild,
    goodput (inverse makespan) monotone non-increasing in BER."""
    g = T.with_flit(T.single_bus(n_mems=4, bw_MBps=128_000), "flit256").build()
    wl = build_workload(g, [_bus_spec()], warmup_frac=0.0)
    link = jnp.asarray(~np.asarray(g.chan_is_service))
    ppms = jnp.asarray([replay_overhead_ppm(b, "flit256")
                        for b in (0.0, 1e-7, 1e-6, 3e-6, 1e-5)], jnp.int64)

    def one(ppm):
        ch = wl.channels._replace(replay_ppm=jnp.where(link, ppm, 0))
        s = simulate(wl.hops, ch, wl.issue_ps)
        return jnp.max(s.complete), s.converged

    makespan, conv = jax.vmap(one)(ppms)
    assert bool(conv.all())
    assert bool((jnp.diff(makespan) >= 0).all())
    assert int(makespan[-1]) > int(makespan[0])


def test_make_channels_picks_up_graph_tables():
    g = T.with_flit(T.single_bus(n_mems=2), FlitConfig("flit68", ber=1e-7)).build()
    ch = make_channels(g)
    assert ch.flit_size is not None
    link = ~np.asarray(g.chan_is_service)
    assert np.all(np.asarray(ch.flit_payload)[link] == 64)
    assert np.all(np.asarray(ch.replay_ppm)[link]
                  == replay_overhead_ppm(1e-7, "flit68"))


# ---------------------------------------------------------------------------
# flit_pack kernel
# ---------------------------------------------------------------------------

@given(st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_flit_pack_kernel_matches_ref(seed):
    from repro.kernels.flit_pack.kernel import flit_pack_pallas
    from repro.kernels.flit_pack.ref import flit_pack_ref

    rng = np.random.default_rng(seed)
    k = int(rng.integers(1, 5000))
    pay = jnp.asarray(rng.integers(1, 1 << 16, k), jnp.int32)
    fsize = jnp.asarray(rng.choice([0, 68, 256], k), jnp.int32)
    fpay = jnp.where(fsize == 68, 64, jnp.where(fsize == 256, 236, 0))
    ppm = jnp.asarray(rng.integers(0, 1_000_000, k), jnp.int32)
    w_k, e_k = flit_pack_pallas(pay, fsize, fpay, ppm, interpret=True)
    w_r, e_r = flit_pack_ref(pay, fsize, fpay, ppm)
    assert np.array_equal(np.asarray(w_k), np.asarray(w_r))
    np.testing.assert_allclose(np.asarray(e_k), np.asarray(e_r), atol=1e-6)


def test_flit_pack_rejects_payloads_above_int32_wire_range():
    from repro.kernels.flit_pack.ops import MAX_PAYLOAD_B, flit_pack

    with pytest.raises(ValueError, match="MAX_PAYLOAD_B"):
        flit_pack(np.asarray([2_100_000_000]), mode="flit256", impl="ref")
    # the bound itself is safe: wire bytes stay positive int32
    wire, _ = flit_pack(np.asarray([MAX_PAYLOAD_B]), mode="flit256",
                        impl="ref")
    assert 0 < int(wire[0]) < 2 ** 31


def test_flit_pack_ops_and_sweep():
    from repro.kernels.flit_pack.ops import flit_pack, flit_sweep

    wire, eff = flit_pack(np.full(8, 236), mode="flit256", ber=0.0, impl="ref")
    assert np.all(np.asarray(wire) == 256)
    np.testing.assert_allclose(np.asarray(eff), 236 / 256, atol=1e-6)
    grid = np.asarray(flit_sweep(np.asarray([236, 944]),
                                 ["flit68", "flit256"],
                                 (0.0, 1e-6, 1e-5), impl="ref"))
    assert grid.shape == (2, 3)
    assert (np.diff(grid, axis=1) < 0).all()  # strictly worse with BER


# ---------------------------------------------------------------------------
# bench acceptance gates
# ---------------------------------------------------------------------------

def test_bench_flit_efficiency_within_half_percent():
    from benchmarks.bench_link_layer import run_efficiency_check

    measured, rel_err = run_efficiency_check(n=600)
    assert rel_err < 0.005, (measured, rel_err)


def test_bench_ber_goodput_monotone():
    from benchmarks.bench_link_layer import run_ber_sweep

    sweep = run_ber_sweep(bers=(0.0, 1e-7, 1e-6, 1e-5), n=400)
    goods = [g for _, g in sweep]
    assert all(a >= b for a, b in zip(goods, goods[1:]))
    assert goods[0] > goods[-1]
