"""Fabric-coupled device coherence: isolated-mode bit-exactness, event-log
invariants, engine==oracle on device-initiated (reverse-direction) traffic
under both fan-out models (serialized chain and fork/join concurrent),
upgrade-BISnp lowering, cycle-damped fixpoint, full-duplex retraining
mirrors, credit-DLLP coupling, trace streams."""

import numpy as np
import pytest

import jax.numpy as jnp

import repro.core  # noqa: F401  (x64)
from repro.core import topology as T
from repro.core.coherence_traffic import (CoherenceFabricSpec,
                                          bisnp_latencies, coherence_issue,
                                          concat_background, lower_coherence,
                                          pad_rows, simulate_coupled)
from repro.core.devices import RequesterSpec, build_workload
from repro.core.engine import SimOptions, make_channels, simulate
from repro.core.ref_des import simulate_ref
from repro.core.snoop_filter import (CacheConfig, SFConfig,
                                     make_skewed_stream, simulate_sf)


def star_graph(n_req=2, n_extra=0, bw=64_000, fixed=26_000):
    kinds = ([T.SWITCH] + [T.REQUESTER] * n_req + [T.MEMORY]
             + [T.REQUESTER] * n_extra)
    links = [T.LinkSpec(i, 0, bw, fixed) for i in range(1, len(kinds))]
    graph = T.Topology(np.asarray(kinds, np.int64), links,
                       name="star").build()
    spec = CoherenceFabricSpec(dev_node=n_req + 1,
                               req_nodes=tuple(range(1, n_req + 1)))
    return graph, spec


def chain_graph(n_req=2):
    """Requesters and device at opposite ends of a 2-switch chain — longer
    routes, so BISnp legs span multiple links."""
    kinds = [T.SWITCH, T.SWITCH] + [T.REQUESTER] * n_req + [T.MEMORY]
    links = [T.LinkSpec(0, 1, 64_000, 26_000)]
    for i in range(n_req):
        links.append(T.LinkSpec(2 + i, 0, 64_000, 26_000))
    links.append(T.LinkSpec(2 + n_req, 1, 64_000, 26_000))
    graph = T.Topology(np.asarray(kinds, np.int64), links,
                       name="chain2").build()
    spec = CoherenceFabricSpec(dev_node=2 + n_req,
                               req_nodes=tuple(range(2, 2 + n_req)))
    return graph, spec


def _stream(n=400, footprint=256, n_req=2, write_ratio=0.3, seed=4):
    return make_skewed_stream(n, footprint, write_ratio=write_ratio,
                              n_requesters=n_req, seed=seed)


# ---------------------------------------------------------------------------
# default isolated mode stays bit-exact (cross-PR regression goldens)
# ---------------------------------------------------------------------------

# captured from the pre-coupling snoop filter (PR 2 tree) — the §V-B/§V-C
# reproductions must stay bit-for-bit on the default path
GOLDEN = {
    ("fifo", 1, 0): (165750000, 1001360, 509, 509, 83114000,
                     16282, 194, 17081),
    ("lifo", 1, 0): (134449000, 898936, 432, 432, 67357000,
                     16075, 199, 17641),
    ("blp", 2, 12000): (248789155, 1691133, 541, 885, 124569410,
                        24316, 155, 24844),
}


@pytest.mark.parametrize("key", sorted(GOLDEN))
def test_isolated_default_bitexact_golden(key):
    policy, invblk, bus = key
    addr, wr, rid = make_skewed_stream(2000, 512, write_ratio=0.2,
                                       n_requesters=2, seed=9)
    cfg = SFConfig(capacity=102, policy=policy, invblk_max=invblk,
                   footprint_lines=512, bus_MBps=bus)
    r = simulate_sf(addr, wr, rid, cfg, CacheConfig(capacity=102),
                    n_requesters=2)
    lat = np.asarray(r.latency_ps)
    got = (int(lat.sum()), int(np.bitwise_xor.reduce(lat.astype(np.int64))),
           int(r.bisnp_events), int(r.invalidated_lines),
           int(r.total_time_ps), int(np.asarray(r.final_sf_tag).sum()),
           int(np.asarray(r.final_sf_owner).sum()),
           int(np.asarray(r.final_cache_tag).sum()))
    assert got == GOLDEN[key]


def test_event_log_consistent_and_latency_independent():
    """Events agree with the SFResult counters, and are identical under an
    arbitrary fabric-latency override — the coupling-loop invariant."""
    addr, wr, rid = _stream()
    cfg = SFConfig(capacity=48, policy="fifo", footprint_lines=256)
    res, ev = simulate_sf(addr, wr, rid, cfg, CacheConfig(capacity=48),
                          n_requesters=2, return_events=True)
    assert int((np.asarray(ev.bisnp_mask) > 0).sum()) == int(res.bisnp_events)
    assert int(np.asarray(ev.inv_lines).sum()) == int(res.invalidated_lines)
    assert not (np.asarray(ev.need_victim) & np.asarray(ev.cache_hit)).any()
    fab = jnp.full(addr.shape, 777_000, jnp.int64)
    res2, ev2 = simulate_sf(addr, wr, rid, cfg, CacheConfig(capacity=48),
                            n_requesters=2, fabric_lat_ps=fab,
                            return_events=True)
    for f in ev._fields:
        if f == "fab_issue_ps":     # clocks move; decisions must not
            continue
        assert np.array_equal(np.asarray(getattr(ev, f)),
                              np.asarray(getattr(ev2, f))), f
    # the override is actually applied: every miss pays cache + fab + sf
    miss = ~np.asarray(ev2.cache_hit)
    want = cfg.t_cache_ps + 777_000 + cfg.t_sf_ps
    assert (np.asarray(res2.latency_ps)[miss] == want).all()


# ---------------------------------------------------------------------------
# engine == oracle with device-initiated (reverse-direction) hops
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fanout", ["chain", "concurrent"])
@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5])
def test_coupled_engine_matches_oracle(seed, fanout):
    rng = np.random.default_rng(seed)
    n_req = int(rng.integers(1, 4))
    graph, spec = (star_graph(n_req) if seed % 2 == 0
                   else chain_graph(n_req))
    n = int(rng.integers(60, 200))
    footprint = int(rng.choice([64, 128, 256]))
    addr, wr, rid = make_skewed_stream(
        n, footprint, write_ratio=float(rng.uniform(0.1, 0.6)),
        n_requesters=n_req, seed=int(rng.integers(0, 999)))
    cfg = SFConfig(capacity=max(footprint // 8, 4), policy="fifo",
                   footprint_lines=footprint)
    _, ev = simulate_sf(addr, wr, rid, cfg,
                        CacheConfig(capacity=max(footprint // 8, 4)),
                        n_requesters=n_req, return_events=True)
    low = lower_coherence(graph, spec, cfg, addr, wr, rid, ev, fanout=fanout)
    assert int(ev.bisnp_mask.max()) > 0, \
        "case has no BISnp traffic; pick different parameters"
    ch = make_channels(graph)
    issue = coherence_issue(low, ev.fab_issue_ps)
    sched = simulate(low.hops, ch, issue)
    ref = simulate_ref(low.hops, ch, np.asarray(issue))
    assert bool(sched.converged)
    assert np.array_equal(np.asarray(sched.complete), ref["complete"])
    assert np.array_equal(np.asarray(sched.start), ref["start"])
    assert np.array_equal(np.asarray(sched.depart), ref["depart"])


@pytest.mark.parametrize("fanout", ["chain", "concurrent"])
def test_coupled_with_background_engine_matches_oracle(fanout):
    graph, spec = star_graph(2, n_extra=1)
    addr, wr, rid = _stream(n=200)
    cfg = SFConfig(capacity=32, policy="lifo", footprint_lines=256)
    _, ev = simulate_sf(addr, wr, rid, cfg, CacheConfig(capacity=32),
                        n_requesters=2, return_events=True)
    low = lower_coherence(graph, spec, cfg, addr, wr, rid, ev, fanout=fanout)
    bg = build_workload(graph, [RequesterSpec(
        node=4, n_requests=150, targets=[spec.dev_node], read_ratio=0.5,
        issue_interval_ps=2_000, payload_bytes=512, seed=2)],
        header_bytes=16, warmup_frac=0.0)
    hops, issue = concat_background(
        low, coherence_issue(low, ev.fab_issue_ps), bg)
    ch = make_channels(graph)
    sched = simulate(hops, ch, issue)
    ref = simulate_ref(hops, ch, np.asarray(issue))
    assert bool(sched.converged)
    assert np.array_equal(np.asarray(sched.complete), ref["complete"])


# captured from the PR 4 tree (serialized chain lowering, fifo, star(2)/(3),
# the exact stream below): the ``fanout="chain"`` layout and its schedule
# must stay bit-for-bit
CHAIN_GOLDEN = {
    2: (8261597974, 10262994, 106804442098, 86720, (500, 13)),
    3: (6737980178, 12603614, 113607190988, 106752, (500, 17)),
}


@pytest.mark.parametrize("n_req", sorted(CHAIN_GOLDEN))
def test_chain_fanout_bitexact_golden(n_req):
    graph, spec = star_graph(n_req)
    addr, wr, rid = make_skewed_stream(500, 256, write_ratio=0.3,
                                       n_requesters=n_req, seed=4)
    cfg = SFConfig(capacity=32, policy="fifo", footprint_lines=256)
    _, ev = simulate_sf(addr, wr, rid, cfg, CacheConfig(capacity=32),
                        n_requesters=n_req, return_events=True)
    low = lower_coherence(graph, spec, cfg, addr, wr, rid, ev, fanout="chain")
    assert low.hops.join_id is None          # chain layout carries no joins
    sched = simulate(low.hops, make_channels(graph), ev.fab_issue_ps)
    assert bool(sched.converged)
    comp = np.asarray(sched.complete)
    st = np.asarray(sched.start)
    got = (int(comp.sum()), int(np.bitwise_xor.reduce(comp)), int(st.sum()),
           int(np.asarray(low.hops.nbytes).sum()),
           tuple(low.hops.channel.shape))
    assert got == CHAIN_GOLDEN[n_req]


def test_concurrent_joins_on_slowest_birsp():
    """Fork/join lowering: snooped misses complete strictly earlier than the
    serialized chain once snoops target >1 owner (max of k round trips vs
    their sum), and never later."""
    graph, spec = star_graph(3)
    addr, wr, rid = make_skewed_stream(400, 128, write_ratio=0.4,
                                       n_requesters=3, seed=12)
    cfg = SFConfig(capacity=16, policy="fifo", footprint_lines=128)
    _, ev = simulate_sf(addr, wr, rid, cfg, CacheConfig(capacity=16),
                        n_requesters=3, return_events=True)
    ch = make_channels(graph)
    mask = np.asarray(ev.bisnp_mask)
    lats = {}
    for fanout in ("chain", "concurrent"):
        low = lower_coherence(graph, spec, cfg, addr, wr, rid, ev,
                              fanout=fanout, upgrade_bisnp=False)
        issue = coherence_issue(low, ev.fab_issue_ps)
        sched = simulate(low.hops, ch, issue)
        assert bool(sched.converged)
        t = low.miss.shape[0]
        lats[fanout] = (np.asarray(sched.complete[:t])
                        - np.asarray(ev.fab_issue_ps))
    multi = np.array([bin(int(m)).count("1") > 1 for m in mask])
    snooped = np.asarray(~np.asarray(ev.cache_hit)) & (mask > 0)
    assert (snooped & multi).sum() > 0
    # aggregate: max-of-k round trips beats their sum wherever k > 1 (the
    # per-row claim is *almost* universal — appended fork rows shift FCFS
    # tie-breaks, so a few contended rows can go either way)
    assert (lats["concurrent"][snooped & multi].mean()
            < lats["chain"][snooped & multi].mean())
    frac_le = (lats["concurrent"][snooped]
               <= lats["chain"][snooped]).mean()
    assert frac_le > 0.9, frac_le


def test_upgrade_bisnp_rows_lowered_and_timing_preserved():
    """Write conflicts on local-cache hits fork BISnp-only rows (reverse
    traffic with no demand leg) issued at the hit's clock; the hit's own
    primary row stays empty, so demand timing is untouched."""
    graph, spec = star_graph(2)
    addr, wr, rid = _stream(n=500, write_ratio=0.5, seed=13)
    cfg = SFConfig(capacity=48, policy="fifo", footprint_lines=256)
    _, ev = simulate_sf(addr, wr, rid, cfg, CacheConfig(capacity=48),
                        n_requesters=2, return_events=True)
    hit = np.asarray(ev.cache_hit)
    conf = np.asarray(ev.conflict)
    mask = np.asarray(ev.bisnp_mask)
    assert (hit & conf).any(), "stream has no hit-upgrades; reseed"
    low_on = lower_coherence(graph, spec, cfg, addr, wr, rid, ev)
    low_off = lower_coherence(graph, spec, cfg, addr, wr, rid, ev,
                              upgrade_bisnp=False)
    n_up = sum(bin(int(m)).count("1") for m in mask[hit & conf])
    assert (low_on.hops.channel.shape[0]
            == low_off.hops.channel.shape[0] + n_up)
    # upgrade rows carry header-only BISnp/BIRsp legs, no service hop
    t = hit.shape[0]
    up_rows = np.asarray([r for j in np.nonzero(hit & conf)[0]
                          for r in low_on.snoop_rows[j] if r >= 0])
    assert len(up_rows) == n_up
    nb = np.asarray(low_on.hops.nbytes)[up_rows]
    assert (nb[np.asarray(low_on.hops.valid)[up_rows]]
            == spec.header_bytes).all()
    jw = np.asarray(low_on.hops.join_wait)
    assert (jw[up_rows] == -1).all()        # fire at the hit's clock
    # primary rows of hits stay empty either way: hit timing is the seed's
    assert not np.asarray(low_on.hops.valid)[:t][hit].any()
    # the upgrade traffic occupies real reverse-channel wire time (it can
    # only ever delay other transactions, never the hit itself)
    from repro.core.engine import channel_stats

    ch = make_channels(graph)
    s_on = simulate(low_on.hops, ch,
                    coherence_issue(low_on, ev.fab_issue_ps))
    s_off = simulate(low_off.hops, ch,
                     coherence_issue(low_off, ev.fab_issue_ps))
    assert bool(s_on.converged) and bool(s_off.converged)
    ref = simulate_ref(low_on.hops, ch,
                       np.asarray(coherence_issue(low_on, ev.fab_issue_ps)))
    assert np.array_equal(np.asarray(s_on.complete), ref["complete"])
    busy_on = np.asarray(channel_stats(low_on.hops, s_on, ch)["busy_ps"])
    busy_off = np.asarray(channel_stats(low_off.hops, s_off, ch)["busy_ps"])
    up_chans = np.unique(np.asarray(low_on.hops.channel)[up_rows][
        np.asarray(low_on.hops.valid)[up_rows]])
    assert (busy_on[up_chans] > busy_off[up_chans]).all()
    assert (int(jnp.sum(s_on.complete[:t]))
            >= int(jnp.sum(s_off.complete[:t])))


def test_pad_rows_preserves_schedule():
    """Row padding (the vmapped policy sweep's shape equalizer) must not
    disturb the real rows' schedule."""
    graph, spec = star_graph(2)
    addr, wr, rid = _stream(n=150)
    cfg = SFConfig(capacity=32, policy="fifo", footprint_lines=256)
    _, ev = simulate_sf(addr, wr, rid, cfg, CacheConfig(capacity=32),
                        n_requesters=2, return_events=True)
    low = lower_coherence(graph, spec, cfg, addr, wr, rid, ev)
    issue = coherence_issue(low, ev.fab_issue_ps)
    n = low.hops.channel.shape[0]
    padded = pad_rows(low.hops, n + 37)
    issue_p = jnp.concatenate([issue, jnp.zeros(37, jnp.int64)])
    ch = make_channels(graph)
    s0 = simulate(low.hops, ch, issue)
    s1 = simulate(padded, ch, issue_p)
    assert bool(s0.converged) and bool(s1.converged)
    assert np.array_equal(np.asarray(s0.complete),
                          np.asarray(s1.complete)[:n])


# ---------------------------------------------------------------------------
# coupling preserves every protocol decision + invariants
# ---------------------------------------------------------------------------

def test_coupled_decisions_match_isolated():
    graph, spec = star_graph(2)
    addr, wr, rid = _stream()
    cfg = SFConfig(capacity=48, policy="fifo", footprint_lines=256)
    iso = simulate_sf(addr, wr, rid, cfg, CacheConfig(capacity=48),
                      n_requesters=2)
    out = simulate_coupled(addr, wr, rid, cfg, CacheConfig(capacity=48),
                           graph, spec, n_requesters=2, max_iters=10)
    assert out.converged
    assert int(out.sf.bisnp_events) == int(iso.bisnp_events)
    assert int(out.sf.invalidated_lines) == int(iso.invalidated_lines)
    assert np.array_equal(np.asarray(out.sf.final_sf_tag),
                          np.asarray(iso.final_sf_tag))
    assert np.array_equal(np.asarray(out.sf.final_sf_owner),
                          np.asarray(iso.final_sf_owner))
    assert np.array_equal(np.asarray(out.sf.final_cache_tag),
                          np.asarray(iso.final_cache_tag))
    assert np.array_equal(np.asarray(out.sf.cache_hit),
                          np.asarray(iso.cache_hit))
    # coupled latencies differ (the analytic constants are not the fabric)
    assert not np.array_equal(np.asarray(out.sf.latency_ps),
                              np.asarray(iso.latency_ps))


def test_inclusivity_and_owner_consistency_under_coupling():
    """Every cached line has a live SF entry listing its owner — re-checked
    on the coupled result's final protocol state."""
    graph, spec = star_graph(2)
    addr, wr, rid = _stream(n=600, seed=11)
    cfg = SFConfig(capacity=48, policy="lru", footprint_lines=256)
    out = simulate_coupled(addr, wr, rid, cfg, CacheConfig(capacity=48),
                           graph, spec, n_requesters=2, max_iters=10)
    sf_tags = np.asarray(out.sf.final_sf_tag)
    sf_owner = np.asarray(out.sf.final_sf_owner)
    cache = np.asarray(out.sf.final_cache_tag)
    live = sf_tags >= 0
    assert len(np.unique(sf_tags[live])) == live.sum()   # unique tags
    for r in range(cache.shape[0]):
        lines = set(int(a) for a in cache[r] if a >= 0)
        owned = set(int(t) for t, o in zip(sf_tags, sf_owner)
                    if t >= 0 and (int(o) >> r) & 1)
        assert not lines - owned, (r, lines - owned)


def test_bisnp_latencies_cover_snooped_misses():
    graph, spec = star_graph(2)
    addr, wr, rid = _stream()
    cfg = SFConfig(capacity=48, policy="fifo", footprint_lines=256)
    out = simulate_coupled(addr, wr, rid, cfg, CacheConfig(capacity=48),
                           graph, spec, n_requesters=2, max_iters=10)
    bl = np.asarray(out.bisnp_lat_ps)
    mask = np.asarray(out.events.bisnp_mask)
    miss = np.asarray(out.lowering.miss)
    conf = np.asarray(out.events.conflict)
    # concurrent mode measures one round trip per snooped owner of every
    # miss *and* of every upgrade-BISnp (write conflict on a local hit)
    fab = miss | (~miss & conf)
    n_slots = sum(int(((mask[fab] >> b) & 1).sum())
                  for b in range(len(spec.req_nodes)))
    assert int((bl > 0).sum()) == n_slots
    # measured round trips exceed the pure-wire floor (2 hops each way)
    assert bl[bl > 0].min() > 4 * 26_000


def test_bisnp_latencies_chain_mode_covers_misses_only():
    graph, spec = star_graph(2)
    addr, wr, rid = _stream()
    cfg = SFConfig(capacity=48, policy="fifo", footprint_lines=256)
    out = simulate_coupled(addr, wr, rid, cfg, CacheConfig(capacity=48),
                           graph, spec, n_requesters=2, max_iters=10,
                           fanout="chain")
    bl = np.asarray(out.bisnp_lat_ps)
    mask = np.asarray(out.events.bisnp_mask)
    miss = np.asarray(out.lowering.miss)
    n_slots = sum(int(((mask[miss] >> b) & 1).sum())
                  for b in range(len(spec.req_nodes)))
    assert int((bl > 0).sum()) == n_slots
    assert bl[bl > 0].min() > 4 * 26_000


def _stochastic_star():
    from repro.core.link_layer import FlitConfig

    flit = FlitConfig("flit256", ber=2e-4, reliability="stochastic",
                      rel_seed=5, retrain_threshold=2, retrain_ps=500_000)
    kinds = [T.SWITCH, T.REQUESTER, T.REQUESTER, T.MEMORY]
    links = [T.LinkSpec(i, 0, 128_000, 26_000, flit=flit)
             for i in range(1, 4)]
    graph = T.Topology(np.asarray(kinds, np.int64), links,
                       name="star-sto").build()
    return graph, CoherenceFabricSpec(dev_node=3, req_nodes=(1, 2))


def test_lowering_column_map_survives_retrain_markers():
    """On a graph sampling retraining stalls, marker insertion shifts hop
    columns per row; the chain layout's logical->physical col_map must keep
    the service hop and the BISnp round-trip reads exact (regression: the
    map used to be the identity, silently reading demand hops as snoop
    legs)."""
    graph, spec = _stochastic_star()
    addr, wr, rid = _stream(n=300, seed=6)
    cfg = SFConfig(capacity=32, policy="fifo", footprint_lines=256)
    _, ev = simulate_sf(addr, wr, rid, cfg, CacheConfig(capacity=32),
                        n_requesters=2, return_events=True)
    low = lower_coherence(graph, spec, cfg, addr, wr, rid, ev,
                          fanout="chain")
    assert np.asarray(low.hops.retrain_after_ps).any()
    assert low.n_cols > low.col_map.shape[1]     # markers actually shifted
    # the mapped service column holds the service hop on every miss row
    nb = np.asarray(low.hops.nbytes)
    svc_phys = low.col_map[np.arange(nb.shape[0]), low.svc_col]
    assert (nb[np.arange(nb.shape[0]), svc_phys][low.miss]
            == cfg.line_bytes).all()
    sched = simulate(low.hops, make_channels(graph), ev.fab_issue_ps)
    ref = simulate_ref(low.hops, make_channels(graph), ev.fab_issue_ps)
    assert bool(sched.converged)
    assert np.array_equal(np.asarray(sched.complete), ref["complete"])
    bl = np.asarray(bisnp_latencies(sched, low))
    mask = np.asarray(ev.bisnp_mask)
    n_slots = sum(int(((mask[low.miss] >> b) & 1).sum()) for b in range(2))
    assert int((bl > 0).sum()) == n_slots


def test_concurrent_lowering_survives_retrain_markers():
    """The concurrent layout reads BISnp round trips per *row*, so marker
    column shifts must not disturb it — and fork/join + retraining stalls
    must compose bit-exactly against the oracle."""
    graph, spec = _stochastic_star()
    addr, wr, rid = _stream(n=300, seed=6)
    cfg = SFConfig(capacity=32, policy="fifo", footprint_lines=256)
    _, ev = simulate_sf(addr, wr, rid, cfg, CacheConfig(capacity=32),
                        n_requesters=2, return_events=True)
    low = lower_coherence(graph, spec, cfg, addr, wr, rid, ev)
    assert np.asarray(low.hops.retrain_after_ps).any()
    issue = coherence_issue(low, ev.fab_issue_ps)
    sched = simulate(low.hops, make_channels(graph), issue)
    ref = simulate_ref(low.hops, make_channels(graph), np.asarray(issue))
    assert bool(sched.converged)
    assert np.array_equal(np.asarray(sched.complete), ref["complete"])
    bl = np.asarray(bisnp_latencies(sched, low))
    mask = np.asarray(ev.bisnp_mask)
    conf = np.asarray(ev.conflict)
    fab = low.miss | (~low.miss & conf)
    n_slots = sum(int(((mask[fab] >> b) & 1).sum()) for b in range(2))
    assert int((bl > 0).sum()) == n_slots
    assert (bl >= 0).all()


def test_divergence_grows_with_fabric_load():
    from benchmarks.bench_coherence_fabric import (divergence_gate,
                                                   run_divergence_sweep)

    sweep = run_divergence_sweep(n=300, footprint=256,
                                 loads=(0.0, 0.5, 0.9),
                                 policies=("fifo",))
    gate = divergence_gate(sweep)
    assert gate["nonzero"] and gate["grows_with_load"], gate


def test_fanout_divergence_grows_with_owner_count():
    from benchmarks.bench_coherence_fabric import (fanout_gate,
                                                   run_fanout_sweep)

    sweep = run_fanout_sweep(owner_counts=(1, 2, 3), n=240, footprint=128)
    gate = fanout_gate(sweep)
    assert gate["nonzero"] and gate["grows_with_owners"], gate


# ---------------------------------------------------------------------------
# satellite: damped fixpoint converges where Picard iteration oscillates
# ---------------------------------------------------------------------------

def _oscillating_config():
    """Half-duplex star with a large turnaround: a re-timed request flips
    the bus direction against another requester's response, so the latency
    map is a step function and the undamped fixpoint bounces between its
    plateaus by ~hundreds of ns for ~40 iterations."""
    kinds = [T.SWITCH, T.REQUESTER, T.REQUESTER, T.MEMORY]
    links = [T.LinkSpec(i, 0, 8_000, 26_000, T.HALF, 200_000)
             for i in range(1, 4)]
    graph = T.Topology(np.asarray(kinds, np.int64), links,
                       name="hd-osc").build()
    spec = CoherenceFabricSpec(dev_node=3, req_nodes=(1, 2))
    rng = np.random.default_rng(0)
    n = 40
    addr = jnp.asarray(rng.integers(0, 64, n).astype(np.int32))
    wr = jnp.asarray(rng.random(n) < 0.4)
    rid = jnp.asarray((np.arange(n) % 2).astype(np.int32))
    cfg = SFConfig(capacity=8, policy="fifo", footprint_lines=64)
    return graph, spec, addr, wr, rid, cfg


def test_damped_fixpoint_converges_where_picard_oscillates():
    """Regression for the ROADMAP limit-cycle item: same config, same
    budget, same tolerance — the raw Picard iteration is still oscillating
    by ~hundreds of ns when the budget runs out, while the damped update
    (average of the last two latency vectors) converges within tol_ps and
    lands within a few ps of the exact fixpoint."""
    graph, spec, addr, wr, rid, cfg = _oscillating_config()
    kw = dict(n_requesters=2, max_iters=33, tol_ps=2_000)
    raw = simulate_coupled(addr, wr, rid, cfg, CacheConfig(capacity=8),
                           graph, spec, options=SimOptions(damping=False), **kw)
    assert not raw.converged, \
        "config converges undamped now — find a new oscillating config"
    damped = simulate_coupled(addr, wr, rid, cfg, CacheConfig(capacity=8),
                              graph, spec, options=SimOptions(damping=True), **kw)
    assert damped.converged and damped.damped > 0
    # the damped answer is the true fixpoint within the tolerance: the
    # undamped loop does converge exactly given ~39 iterations, and the
    # damped iterate must sit within tol_ps of it (measured: ~351 ps here,
    # vs the ~600,000 ps the raw iteration still oscillates by)
    exact = simulate_coupled(addr, wr, rid, cfg, CacheConfig(capacity=8),
                             graph, spec, n_requesters=2, max_iters=60,
                             tol_ps=0, options=SimOptions(damping=False))
    assert exact.converged
    diff = np.abs(np.asarray(damped.fabric_lat_ps, np.int64)
                  - np.asarray(exact.fabric_lat_ps, np.int64))
    assert int(diff.max()) <= 2_000, int(diff.max())


def test_damping_off_is_default_and_identical():
    """damping=False (the default) must reproduce the PR-4 trajectory —
    and on a config that converges exactly, damping=True must agree on the
    fixpoint within its tolerance."""
    graph, spec = star_graph(2)
    addr, wr, rid = _stream()
    cfg = SFConfig(capacity=48, policy="fifo", footprint_lines=256)
    a = simulate_coupled(addr, wr, rid, cfg, CacheConfig(capacity=48),
                         graph, spec, n_requesters=2, max_iters=10)
    b = simulate_coupled(addr, wr, rid, cfg, CacheConfig(capacity=48),
                         graph, spec, n_requesters=2, max_iters=10,
                         options=SimOptions(damping=False))
    assert a.converged and a.damped == 0
    assert np.array_equal(np.asarray(a.fabric_lat_ps),
                          np.asarray(b.fabric_lat_ps))
    c = simulate_coupled(addr, wr, rid, cfg, CacheConfig(capacity=48),
                         graph, spec, n_requesters=2, max_iters=40,
                         tol_ps=2_000, options=SimOptions(damping=True))
    assert c.converged
    assert int(np.abs(np.asarray(c.fabric_lat_ps)
                      - np.asarray(a.fabric_lat_ps)).max()) <= 2_000


# ---------------------------------------------------------------------------
# satellite: full-duplex retraining takes both directions down
# ---------------------------------------------------------------------------

def _marker_case(seed, c=4):
    """Random hop tables + link-down markers on full-duplex-like channels
    (turnaround 0, not row-managed) — the insertion contract."""
    from repro.core.engine import Channels, Hops

    rng = np.random.default_rng(seed)
    n, h = int(rng.integers(4, 30)), int(rng.integers(2, 6))
    bw = rng.integers(10, 100, c).astype(np.int64) * 1000
    ch = Channels(jnp.asarray(bw), jnp.asarray(np.zeros(c, np.int64)),
                  jnp.asarray(np.zeros(c, np.int64)),
                  jnp.asarray(np.zeros(c, np.int64)))
    chan = rng.integers(0, c, (n, h)).astype(np.int32)
    nbytes = rng.integers(1, 500, (n, h)).astype(np.int64)
    valid = rng.random((n, h)) < .85
    retrain = np.zeros((n, h), np.int64)
    # some hops become markers: zero bytes + a down interval
    mk = (rng.random((n, h)) < 0.25) & valid
    nbytes[mk] = 0
    retrain[mk] = rng.integers(1, 5, mk.sum()) * 50_000
    # some real hops also retrain their own channel
    own = (rng.random((n, h)) < 0.15) & valid & ~mk
    retrain[own] = rng.integers(1, 5, own.sum()) * 50_000
    hops = Hops(jnp.asarray(chan), jnp.asarray(nbytes),
                jnp.asarray(rng.integers(0, 1, (n, h)).astype(np.int8)),
                jnp.asarray(np.full((n, h), -1, np.int32)),
                jnp.asarray(rng.integers(0, 2000, (n, h)).astype(np.int64)),
                jnp.asarray(valid), jnp.asarray(valid),
                extra_wire_bytes=jnp.asarray(np.zeros((n, h), np.int64)),
                retrain_after_ps=jnp.asarray(retrain))
    issue = np.sort(rng.integers(0, 3000, n)).astype(np.int64)
    return hops, ch, issue


@pytest.mark.parametrize("seed", range(8))
def test_link_down_markers_engine_matches_oracle(seed):
    hops, ch, issue = _marker_case(seed)
    sched = simulate(hops, ch, jnp.asarray(issue))
    ref = simulate_ref(hops, ch, issue)
    assert bool(sched.converged)
    assert np.array_equal(np.asarray(sched.complete), ref["complete"])
    assert np.array_equal(np.asarray(sched.start), ref["start"])
    assert np.array_equal(np.asarray(sched.depart), ref["depart"])


def test_retraining_downs_both_directions_of_full_duplex():
    """A retraining stall on the forward channel must also stall the paired
    reverse channel: reverse-direction traffic timed to land inside the
    stall is delayed to its end."""
    from repro.core.link_layer import FlitConfig, retrain_marker_mask

    cfg = FlitConfig("flit256", ber=3e-4, reliability="stochastic",
                     rel_seed=7, retrain_threshold=2, retrain_ps=1_000_000)
    topo = T.with_flit(T.single_bus(n_mems=4, bw_MBps=128_000), cfg)
    graph = topo.build()
    wl = build_workload(graph, [RequesterSpec(
        node=0, n_requests=250, targets=[2, 3, 4, 5], read_ratio=0.5,
        issue_interval_ps=300, payload_bytes=944, seed=3)], warmup_frac=0.0)
    mk = retrain_marker_mask(np.asarray(wl.hops.channel),
                             np.asarray(wl.hops.nbytes),
                             np.asarray(wl.hops.valid),
                             np.asarray(wl.hops.retrain_after_ps))
    assert mk.any(), "no retraining events sampled; raise BER"
    # markers landed on the pair of each triggering hop's channel
    pair = graph.chan_pair
    chn = np.asarray(wl.hops.channel)
    rt = np.asarray(wl.hops.retrain_after_ps)
    trig = (rt > 0) & ~mk & np.asarray(wl.hops.valid)
    assert set(chn[mk]) <= set(int(pair[c]) for c in chn[trig])
    # and the mirrored stall delays the schedule vs markers stripped out
    sched = simulate(wl.hops, wl.channels, wl.issue_ps)
    no_mark = wl.hops._replace(
        retrain_after_ps=jnp.asarray(np.where(mk, 0, rt)))
    sched0 = simulate(no_mark, wl.channels, wl.issue_ps)
    assert bool(sched.converged) and bool(sched0.converged)
    # mirrored stalls delay the run in aggregate (per-row monotonicity is
    # not guaranteed: a delayed transaction can yield a channel to another)
    assert int(jnp.max(sched.complete)) > int(jnp.max(sched0.complete))
    assert int(jnp.sum(sched.complete)) > int(jnp.sum(sched0.complete))


def test_retrain_draw_coupled_to_replay_total():
    """Retrain events are conditioned on the sampled Go-Back-N failures:
    never more events than total failures allow, zero events on clean hops,
    positive correlation across hops, marginal rate preserved."""
    from repro.core.link_layer import channel_rng, sample_replays

    p, W, R = 0.25, 4, 2
    n_flits = np.full(40_000, 6, np.int64)
    extra, events = sample_replays(n_flits, p, W, R, channel_rng(0, 0))
    fails = extra // W
    assert (events <= fails // R).all()          # hard consistency bound
    assert not events[fails < R].any()           # no failure-free retrains
    assert np.corrcoef(fails, events)[0, 1] > 0.2
    assert events.sum() == pytest.approx(n_flits.sum() * p ** R, rel=0.15)


# ---------------------------------------------------------------------------
# satellite: credit-return DLLP traffic
# ---------------------------------------------------------------------------

def test_credit_dllp_off_is_bit_exact_layout():
    from repro.core.link_layer import FlitConfig

    spec = RequesterSpec(node=0, n_requests=120, targets=[2, 3],
                         read_ratio=1.0, issue_interval_ps=400,
                         payload_bytes=944, seed=3)
    g0 = T.with_flit(T.single_bus(n_mems=2, bw_MBps=128_000),
                     FlitConfig("flit256")).build()
    g1 = T.with_flit(T.single_bus(n_mems=2, bw_MBps=128_000),
                     FlitConfig("flit256", credit_dllp=False)).build()
    wl0 = build_workload(g0, [spec], warmup_frac=0.0)
    wl1 = build_workload(g1, [spec], warmup_frac=0.0)
    assert wl0.hops.channel.shape == wl1.hops.channel.shape
    assert np.array_equal(np.asarray(wl0.hops.channel),
                          np.asarray(wl1.hops.channel))


def test_credit_dllp_emits_reverse_hops_and_stays_oracle_exact():
    from repro.core.engine import channel_stats
    from repro.core.link_layer import FlitConfig

    spec = RequesterSpec(node=0, n_requests=120, targets=[2, 3],
                         read_ratio=1.0, issue_interval_ps=400,
                         payload_bytes=944, seed=3)
    cfg = FlitConfig("flit256", credit_dllp=True, rx_credits=16)
    topo = T.with_flit(T.single_bus(n_mems=2, bw_MBps=128_000), cfg)
    graph = topo.build()
    assert graph.chan_credit_dllp[~graph.chan_is_service].all()
    assert (graph.chan_credit_window[~graph.chan_is_service] == 16).all()
    wl = build_workload(graph, [spec], warmup_frac=0.0)
    n_dllp = int((wl.requester < 0).sum())
    assert n_dllp > 0
    assert not np.asarray(wl.measured)[wl.requester < 0].any()
    # DLLP rows are single reverse-channel hops with DLLP payload size
    from repro.core.calibration import CREDIT_DLLP_B
    d = np.asarray(wl.hops.nbytes)[wl.requester < 0]
    assert (d[:, 0] == CREDIT_DLLP_B).all() and not d[:, 1:].any()
    # schedule stays engine == oracle
    sched = simulate(wl.hops, wl.channels, wl.issue_ps)
    ref = simulate_ref(wl.hops, wl.channels, wl.issue_ps)
    assert bool(sched.converged)
    assert np.array_equal(np.asarray(sched.complete), ref["complete"])
    # reverse channels actually carry the DLLPs: busy time grows vs off
    g0 = T.with_flit(T.single_bus(n_mems=2, bw_MBps=128_000),
                     FlitConfig("flit256")).build()
    wl0 = build_workload(g0, [spec], warmup_frac=0.0)
    s0 = simulate(wl0.hops, wl0.channels, wl0.issue_ps)
    busy = np.asarray(channel_stats(wl.hops, sched, wl.channels)["busy_ps"])
    busy0 = np.asarray(channel_stats(wl0.hops, s0, wl0.channels)["busy_ps"])
    rev = np.asarray(np.unique(np.asarray(wl.hops.channel)[wl.requester < 0, 0]))
    assert (busy[rev] > busy0[rev]).all()


def test_credit_dllp_with_adaptive_routing():
    """Route strategies must treat appended DLLP pseudo-rows (requester -1)
    as non-routable: their count is route-dependent, which used to crash
    the adaptive rebuild loop with an IndexError."""
    from repro.core.link_layer import FlitConfig
    from repro.core.routing import route_and_simulate

    topo = T.with_flit(T.spine_leaf(2),
                       FlitConfig("flit256", credit_dllp=True,
                                  rx_credits=16))
    graph = topo.build()
    specs = [RequesterSpec(node=r, n_requests=40,
                           targets=list(graph.topo.memories()),
                           issue_interval_ps=500, payload_bytes=944, seed=i)
             for i, r in enumerate(graph.topo.requesters())]
    for strategy in ("ecmp", "adaptive"):
        wl, sched, stats = route_and_simulate(graph, specs,
                                              strategy=strategy,
                                              warmup_frac=0.0)
        assert (wl.requester < 0).any()          # DLLP rows present
        assert float(stats["utility"].max()) <= 1.0 + 1e-9


# ---------------------------------------------------------------------------
# satellite: trace-driven request streams
# ---------------------------------------------------------------------------

def test_trace_request_stream_contract():
    from repro.core import traces

    addr, wr, rid = traces.request_stream("silo", n=2000,
                                          footprint_lines=512,
                                          n_requesters=3, seed=1)
    assert addr.shape == wr.shape == rid.shape == (2000,)
    assert int(addr.max()) < 512 and int(addr.min()) >= 0
    assert set(np.unique(np.asarray(rid))) == {0, 1, 2}
    w = float(np.asarray(wr).mean())
    assert 0.2 < w < 0.7                       # silo is the most mixed
    # drives the snoop filter pipeline unchanged
    cfg = SFConfig(capacity=64, policy="fifo", footprint_lines=512)
    res = simulate_sf(addr, wr, rid, cfg, CacheConfig(capacity=64),
                      n_requesters=3)
    assert int(res.bisnp_events) > 0


def test_trace_stream_through_coupled_pipeline():
    from repro.core import traces

    graph, spec = star_graph(2)
    addr, wr, rid = traces.request_stream("xsbench", n=250,
                                          footprint_lines=256,
                                          n_requesters=2, seed=1)
    cfg = SFConfig(capacity=32, policy="fifo", footprint_lines=256)
    out = simulate_coupled(addr, wr, rid, cfg, CacheConfig(capacity=32),
                           graph, spec, n_requesters=2, max_iters=16)
    assert out.converged
    assert int(out.fabric_lat_ps.max()) > 0
