"""Optional-hypothesis shim: property tests degrade gracefully when absent.

The tier-1 suite must collect and run on a bare environment (satellite of
the link-layer PR; `hypothesis` ships only in the ``[test]`` extra).  Test
modules import ``given / settings / st`` from here instead of from
hypothesis directly:

  * with hypothesis installed, this re-exports the real thing;
  * without it, ``@given`` expands into a deterministic
    ``pytest.mark.parametrize`` over seeded draws from the (small) strategy
    subset the suite uses — integers and sampled_from — so the
    oracle-exactness properties still execute with real coverage instead of
    being skipped wholesale.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised implicitly by either environment
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import numpy as _np
    import pytest as _pytest

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 8  # per test; deterministic, seeded below

    class _Integers:
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def draw(self, rng):
            return int(rng.integers(self.lo, self.hi + 1))

    class _SampledFrom:
        def __init__(self, elems):
            self.elems = list(elems)

        def draw(self, rng):
            return self.elems[int(rng.integers(0, len(self.elems)))]

    class st:  # noqa: N801 - mirrors `hypothesis.strategies as st`
        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

        @staticmethod
        def sampled_from(elems):
            return _SampledFrom(elems)

    def settings(**kwargs):
        max_examples = kwargs.get("max_examples")

        def deco(fn):
            if max_examples is not None:
                fn._hyp_max_examples = max_examples
            return fn

        return deco

    def given(*strategies):
        def deco(fn):
            n = min(getattr(fn, "_hyp_max_examples", _FALLBACK_EXAMPLES),
                    _FALLBACK_EXAMPLES)
            rng = _np.random.default_rng(0xE5F)
            cases = [tuple(s.draw(rng) for s in strategies) for _ in range(n)]

            def wrapper(_hyp_case):
                return fn(*_hyp_case)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return _pytest.mark.parametrize("_hyp_case", cases)(wrapper)

        return deco
