"""Serving runtime: batched decode correctness + continuous batching."""

import numpy as np

import jax
import jax.numpy as jnp

import repro.core  # noqa: F401
from repro.configs import get_smoke_config
from repro.models import transformer as TF
from repro.runtime.server import Request, Server


def test_server_batched_greedy_matches_manual_decode():
    cfg = get_smoke_config("llama3-8b")
    params = TF.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, L).astype(np.int32)
               for L in (5, 9, 7)]

    # manual single-sequence greedy decode as oracle
    def manual(prompt, n_new):
        logits, cache = TF.prefill(params, cfg, jnp.asarray(prompt[None]),
                                   max_len=64)
        toks = [int(jnp.argmax(logits[0, -1]))]
        pos = len(prompt)
        for _ in range(n_new - 1):
            logits, cache = TF.decode_step(
                params, cfg, cache,
                jnp.asarray([[toks[-1]]], jnp.int32),
                jnp.asarray([[pos]], jnp.int32))
            toks.append(int(jnp.argmax(logits[0, -1])))
            pos += 1
        return toks

    srv = Server(cfg, params, slots=2, max_len=64, temperature=0.0)
    reqs = [Request(rid=i, prompt=p, max_new=6) for i, p in enumerate(prompts)]
    stats = srv.run(reqs)
    assert stats["generated"] >= sum(r.max_new for r in reqs) - len(reqs)
    for r, p in zip(reqs, prompts):
        assert r.out[:6] == manual(p, 6), r.rid


def test_server_slot_reuse():
    cfg = get_smoke_config("mamba2-1.3b")
    params = TF.init_params(cfg, jax.random.key(1))
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 4).astype(np.int32),
                    max_new=3) for i in range(5)]
    srv = Server(cfg, params, slots=2, max_len=32)
    stats = srv.run(reqs)
    assert all(r.done for r in reqs)
    assert all(len(r.out) >= 3 for r in reqs)
