"""Provable round bounds + the unified SimOptions surface.

Covers the join-depth-aware round budget (`verify.join_depth` /
`engine.round_bound`): sufficiency — the computed budget converges with
zero residual across random demand, fork/join DAG, coherence-lowered and
streamed-carry workloads; tightness — on chain-only tables the bound is
exactly the legacy ``3*H + 8`` heuristic, so the computed default never
asks for more rounds than the old magic number did; the ``join.depth``
verifier finding; and the one-options-object API: every entry point
accepts `SimOptions`, every result type reports ``rounds`` /
``converged`` / ``residual_ps``, and the historical kwargs warn.
"""

import warnings

import numpy as np
import pytest
from _hyp_compat import given, settings, st  # optional-hypothesis shim

import jax
import jax.numpy as jnp

import repro.core  # noqa: F401  (x64)
from repro.core import topology as T
from repro.core.coherence_traffic import (CoherenceFabricSpec,
                                          coherence_issue, lower_coherence,
                                          simulate_coupled)
from repro.core.engine import (Hops, SimOptions, make_channels, round_bound,
                               simulate, simulate_auto)
from repro.core.snoop_filter import (CacheConfig, SFConfig,
                                     make_skewed_stream, simulate_sf)
from repro.core.streaming import simulate_stream, stream_windows
from repro.core.verify import join_depth, verify_workload
from repro.core.verify import round_bound as verify_round_bound
from test_engine import _join_case, _random_case, _tight_feedback_case


def _star(n_req=2, bw=64_000, fixed=26_000):
    kinds = [T.SWITCH] + [T.REQUESTER] * n_req + [T.MEMORY]
    links = [T.LinkSpec(i, 0, bw, fixed) for i in range(1, len(kinds))]
    graph = T.Topology(np.asarray(kinds, np.int64), links,
                       name="star").build()
    spec = CoherenceFabricSpec(dev_node=n_req + 1,
                               req_nodes=tuple(range(1, n_req + 1)))
    return graph, spec


# ---------------------------------------------------------------------------
# join_depth: the release-propagation fixpoint over the group DAG
# ---------------------------------------------------------------------------

def test_join_depth_no_joins():
    assert join_depth(None, None) == 0
    assert join_depth(np.full(4, -1, np.int32), np.full(4, -1, np.int32)) == 0


def test_join_depth_single_level():
    # two contributors feed group 0; one waiter
    jid = np.asarray([0, 0, -1], np.int32)
    jw = np.asarray([-1, -1, 0], np.int32)
    assert join_depth(jid, jw) == 1


def test_join_depth_layered_chain():
    # row k waits on group k-1 and contributes to group k: depth = n-1
    n = 6
    jid = np.arange(n, dtype=np.int32)
    jid[-1] = -1
    jw = np.arange(-1, n - 1, dtype=np.int32)
    assert join_depth(jid, jw) == n - 1


def test_join_depth_cycle_capped():
    # A waits on B's group, B waits on A's group — the verifier flags this
    # as join.cycle; the depth helper must terminate with the N cap
    jid = np.asarray([0, 1], np.int32)
    jw = np.asarray([1, 0], np.int32)
    assert join_depth(jid, jw) == 2


def test_round_bound_chain_only_equals_legacy_heuristic():
    """Tightness: without joins the computed bound IS the old 3H+8 magic."""
    for h in (1, 4, 9):
        assert verify_round_bound(h) == 3 * h + 8
    hops, _, _, _ = _random_case(3)
    assert round_bound(hops) == 3 * int(hops.channel.shape[1]) + 8


def test_round_bound_scales_with_join_depth():
    hops, ch, issue = _join_case(11)
    h = int(hops.channel.shape[1])
    d = join_depth(np.asarray(hops.join_id), np.asarray(hops.join_wait))
    assert d >= 1
    assert round_bound(hops) == (d + 1) * (3 * h + 8)


def test_round_bound_stacked_tables_take_member_max():
    a, _, _ = _join_case(1)
    stacked = jax.tree_util.tree_map(lambda x: jnp.stack([x, x]), a)
    assert round_bound(stacked) == round_bound(a)


def test_round_bound_traced_tables_fall_back_to_chain_term():
    """Under jit/vmap the join tables are tracers; the bound degrades to the
    chain-only term instead of crashing (sweeps that need the full bound
    compute it host-side and pass SimOptions(max_rounds=...))."""
    hops, ch, issue = _join_case(2)
    h = int(hops.channel.shape[1])

    @jax.jit
    def probe(hops):
        return jnp.int64(round_bound(hops))

    assert int(probe(hops)) == 3 * h + 8


# ---------------------------------------------------------------------------
# sufficiency: the computed budget converges with zero residual
# ---------------------------------------------------------------------------

@given(st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_bound_sufficient_random_demand(seed):
    hops, ch, issue, _ = _random_case(seed)
    sched = simulate(hops, ch, jnp.asarray(issue))
    assert bool(sched.converged)
    assert int(sched.residual_ps) == 0
    assert int(sched.rounds) <= round_bound(hops)


@pytest.mark.parametrize("seed", range(6))
def test_bound_sufficient_fork_join(seed):
    hops, ch, issue = _join_case(seed)
    sched = simulate(hops, ch, jnp.asarray(issue))
    assert bool(sched.converged)
    assert int(sched.residual_ps) == 0
    assert int(sched.rounds) <= round_bound(hops)


@pytest.mark.parametrize("fanout", ["chain", "concurrent"])
def test_bound_sufficient_coherence_lowering(fanout):
    graph, spec = _star(2)
    addr, wr, rid = make_skewed_stream(160, 64, write_ratio=0.4,
                                       n_requesters=2, seed=9)
    cfg = SFConfig(capacity=24, policy="fifo", footprint_lines=64)
    _, ev = simulate_sf(addr, wr, rid, cfg, CacheConfig(capacity=24),
                        n_requesters=2, return_events=True)
    low = lower_coherence(graph, spec, cfg, addr, wr, rid, ev, fanout=fanout)
    issue = coherence_issue(low, ev.fab_issue_ps)
    sched = simulate(low.hops, make_channels(graph), issue)
    assert bool(sched.converged)
    assert int(sched.residual_ps) == 0
    assert int(sched.rounds) <= round_bound(low.hops)


def test_bound_sufficient_stream_carry():
    hops, ch, issue = _join_case(5)
    out = simulate_stream(stream_windows(hops, np.asarray(issue), 7), ch)
    assert out.converged and out.oracle_windows == 0
    assert out.residual_ps == 0


def test_truncated_budget_reports_residual():
    hops, ch, issue = _tight_feedback_case(n=600, h=6)
    sched = simulate(hops, ch, jnp.asarray(issue), SimOptions(max_rounds=1))
    assert not bool(sched.converged)
    assert int(sched.residual_ps) > 0


# ---------------------------------------------------------------------------
# verifier finding: explicit budgets below the computed bound
# ---------------------------------------------------------------------------

def test_verify_flags_budget_below_bound():
    hops, ch, issue = _join_case(4)
    bound = round_bound(hops)
    rep = verify_workload(hops, ch, issue, max_rounds=bound - 1)
    assert any(f.code == "join.depth" for f in rep.findings)
    rep_ok = verify_workload(hops, ch, issue, max_rounds=bound)
    assert not any(f.code == "join.depth" for f in rep_ok.findings)


# ---------------------------------------------------------------------------
# the unified options surface + deprecated shims
# ---------------------------------------------------------------------------

def test_simoptions_validation():
    with pytest.raises(ValueError, match="check"):
        SimOptions(check="paranoid")
    hops, ch, issue, _ = _random_case(1)
    with pytest.raises(TypeError, match="SimOptions"):
        simulate(hops, ch, jnp.asarray(issue), {"max_rounds": 4})
    assert SimOptions(use_kernel=False).kernel_impl == "scan"
    assert SimOptions(use_kernel=True).kernel_impl == "auto"
    assert SimOptions(use_kernel="ref").kernel_impl == "ref"


def test_one_options_object_threads_through_every_entry_point():
    opts = SimOptions(check="oracle")
    hops, ch, issue, _ = _random_case(2)
    sched = simulate(hops, ch, jnp.asarray(issue), opts)
    sched2, used = simulate_auto(hops, ch, jnp.asarray(issue), opts)
    assert not used
    assert np.array_equal(np.asarray(sched.complete),
                          np.asarray(sched2.complete))
    out = simulate_stream(stream_windows(hops, np.asarray(issue), 9), ch,
                          options=opts)
    assert out.converged

    graph, spec = _star(2)
    addr, wr, rid = make_skewed_stream(80, 32, write_ratio=0.3,
                                       n_requesters=2, seed=2)
    cfg = SFConfig(capacity=16, policy="fifo", footprint_lines=32)
    res = simulate_coupled(addr, wr, rid, cfg, CacheConfig(capacity=16),
                           graph, spec, n_requesters=2, options=opts)
    assert res.converged and res.rounds > 0


def test_unified_result_diagnostics():
    hops, ch, issue, _ = _random_case(5)
    sched = simulate(hops, ch, jnp.asarray(issue))
    for field in ("rounds", "converged", "residual_ps"):
        assert hasattr(sched, field)
    out = simulate_stream(stream_windows(hops, np.asarray(issue), 11), ch)
    for field in ("rounds", "converged", "residual_ps"):
        assert hasattr(out, field)
    assert out.rounds == out.state.rounds_sum
    from repro.core.coherence_traffic import CoupledResult
    for field in ("rounds", "converged", "residual_ps"):
        assert field in CoupledResult._fields


def _deprecations(fn, *args, **kw):
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        out = fn(*args, **kw)
    return out, [str(w.message) for w in rec
                 if issubclass(w.category, DeprecationWarning)]


def test_deprecated_kwargs_warn_and_still_work():
    hops, ch, issue, _ = _random_case(6)
    want = simulate(hops, ch, jnp.asarray(issue))

    got, msgs = _deprecations(simulate, hops, ch, jnp.asarray(issue),
                              max_rounds=400)
    assert len(msgs) == 1 and "SimOptions" in msgs[0]
    assert np.array_equal(np.asarray(want.complete),
                          np.asarray(got.complete))

    (got2, used), msgs = _deprecations(simulate_auto, hops, ch,
                                       jnp.asarray(issue), check=False)
    assert len(msgs) == 1 and not used
    assert np.array_equal(np.asarray(want.complete),
                          np.asarray(got2.complete))

    # legacy positional int budget in the options slot
    got3, msgs = _deprecations(simulate, hops, ch, jnp.asarray(issue), 400)
    assert len(msgs) == 1
    assert np.array_equal(np.asarray(want.complete),
                          np.asarray(got3.complete))

    out, msgs = _deprecations(
        simulate_stream, stream_windows(hops, np.asarray(issue), 9), ch,
        max_rounds=400, oracle_fallback=True, static_check=False)
    assert len(msgs) == 3 and out.converged

    graph, spec = _star(2)
    addr, wr, rid = make_skewed_stream(60, 32, write_ratio=0.3,
                                       n_requesters=2, seed=3)
    cfg = SFConfig(capacity=16, policy="fifo", footprint_lines=32)
    res, msgs = _deprecations(
        simulate_coupled, addr, wr, rid, cfg, CacheConfig(capacity=16),
        graph, spec, n_requesters=2, max_rounds=400, damping=False)
    assert len(msgs) == 2 and res.converged
